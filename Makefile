# odimo build/test/bench driver. The rust workspace lives in rust/
# (manifest: rust/Cargo.toml, workspace root: this directory).

CARGO ?= cargo
PYTHON ?= python3

.PHONY: build test check bench-infer bench-sim bench artifacts clean

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# Full gate: formatting, lints-as-errors, then the tier-1 command.
check:
	$(CARGO) fmt --check
	$(CARGO) clippy -- -D warnings
	$(CARGO) build --release && $(CARGO) test -q

# Quantized-inference engine throughput (engine vs naive oracle,
# single-thread + pool scaling). Emits BENCH_infer.json at repo root
# and appends to results/bench_infer.csv.
bench-infer:
	$(CARGO) bench --bench bench_infer
	@test -f BENCH_infer.json && echo "BENCH_infer.json updated" || \
		echo "warning: BENCH_infer.json missing"

# SoC simulator throughput (DIANA + the 3-accelerator example platform,
# plus min-cost construction). Emits BENCH_simulator.json at repo root
# and appends to results/bench_simulator.csv.
bench-sim:
	$(CARGO) bench --bench bench_simulator
	@test -f BENCH_simulator.json && echo "BENCH_simulator.json updated" || \
		echo "warning: BENCH_simulator.json missing"

# All harness = false bench binaries.
bench:
	$(CARGO) bench

# AOT-lower the JAX graphs to HLO-text artifacts (requires the python
# toolchain; rust artifact-driven tests skip themselves without this).
artifacts:
	$(PYTHON) python/compile/aot.py

clean:
	$(CARGO) clean
