# odimo build/test/bench driver. The rust workspace lives in rust/
# (manifest: rust/Cargo.toml, workspace root: this directory).

CARGO ?= cargo
PYTHON ?= python3

.PHONY: build test check chaos cluster obs import doc api-check examples \
	bench-infer bench-sim bench-mincost bench-serve bench artifacts clean

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# Fault-injection property suite alone: seeded chaos plans against the
# serving loop (no request lost, degraded re-mapping conserves
# channels, reports bit-identical across re-runs and thread counts).
chaos:
	$(CARGO) test --test chaos_props

# Cluster serving suite: the r=1 differential pin against the single
# session, conservation under replicas + faults + stealing, digest
# invariance across thread counts, and the trace-format roundtrip
# (golden fixture, typed errors, > 2^53 decimal-string transport).
cluster:
	$(CARGO) test --test cluster_props --test trace_roundtrip

# Graph import + multi-model serving: the import property suite
# (fixtures byte-canonical, validation errors on documented triggers,
# single-model serve_multi pins, mixed-model conservation), then the
# committed golden fixtures driven end to end — inspect the custom
# graph (import → geometry) and serve a mixed two-model trace through
# the model-aware cluster driver.
import:
	$(CARGO) test --test import_props
	$(CARGO) run --release -- inspect --model config/graph_custom.json
	$(CARGO) run --release -- serve --smoke --requests 24 \
		--results /tmp/odimo_import_smoke \
		--models config/graph_tinycnn.json,config/graph_custom.json

# Observability suite: the obs property tests (span/report
# reconciliation, digest invariance, recorder-off identity, export
# determinism), then a traced serve run validated by the trace-events
# checker and summarized by trace-view.
obs:
	$(CARGO) test --test obs_props
	$(CARGO) run --release -- serve --smoke --requests 24 \
		--results /tmp/odimo_obs_smoke --trace-events /tmp/odimo_obs_trace.json
	$(PYTHON) tools/check_trace_events.py /tmp/odimo_obs_trace.json
	$(CARGO) run --release -- trace-view --trace-events /tmp/odimo_obs_trace.json

# Full gate: formatting, lints-as-errors, then the tier-1 command.
check:
	$(CARGO) fmt --check
	$(CARGO) clippy -- -D warnings
	$(CARGO) build --release && $(CARGO) test -q

# API docs; broken intra-doc links are errors (CI runs this too).
doc:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps

# The api facade's doc-tests (the SessionBuilder example in
# rust/src/api/ is executable documentation — this runs it alone).
api-check:
	$(CARGO) test --doc api

# Build and execute the three deployment examples (CI runs these too:
# they are live end-to-end checks, not compile-only artifacts).
examples:
	$(CARGO) run --release --example deploy_tri
	$(CARGO) run --release --example deploy_gap9
	$(CARGO) run --release --example deploy_mpsoc4

# Quantized-inference engine throughput (engine vs naive oracle, scalar
# vs SIMD kernel backends, direct conv vs forced im2col, pool scaling).
# Emits BENCH_infer.json at repo root and appends to
# results/bench_infer.csv, then gates the kernel numbers: SIMD never
# slower than scalar, and the scalar path within 5% of the
# previously-committed BENCH_infer.json (stashed before the bench
# overwrites it).
bench-infer:
	@cp BENCH_infer.json /tmp/odimo_bench_infer_baseline.json 2>/dev/null || true
	$(CARGO) bench --bench bench_infer
	@test -f BENCH_infer.json && echo "BENCH_infer.json updated" || \
		echo "warning: BENCH_infer.json missing"
	$(PYTHON) tools/check_bench_infer.py BENCH_infer.json \
		--baseline /tmp/odimo_bench_infer_baseline.json

# SoC simulator throughput (DIANA + the 3-accelerator example platform,
# plus min-cost construction). Emits BENCH_simulator.json at repo root
# and appends to results/bench_simulator.csv.
bench-sim:
	$(CARGO) bench --bench bench_simulator
	@test -f BENCH_simulator.json && echo "BENCH_simulator.json updated" || \
		echo "warning: BENCH_simulator.json missing"

# Min-cost mapper: exhaustive enumerator vs the water-filling/DP fast
# path at N=2..4. Emits BENCH_mincost.json at repo root and appends to
# results/bench_mincost.csv. CI smoke-runs this with --smoke so the
# fast path never silently regresses to exponential enumeration.
bench-mincost:
	$(CARGO) bench --bench bench_mincost
	@test -f BENCH_mincost.json && echo "BENCH_mincost.json updated" || \
		echo "warning: BENCH_mincost.json missing"

# Closed-loop serving: img/s and simulated p95 latency at 1/2/8 worker
# threads, batched vs unbatched, plus a faults0 case (empty fault plan)
# whose loop time the overhead gate holds within 5% of batched, and
# cluster cases (one dense trace at r=1 vs r=4) whose deterministic
# virtual img/s the same gate holds at >= 2.5x scaling, and multi-model
# cases (multi_m1 one-model dispatch within 5% of cluster_r1, multi_m2
# two-model mixed trace). Emits BENCH_serve.json at repo root and
# appends to results/bench_serve.csv. CI smoke-runs this with --smoke
# alongside bench-mincost.
bench-serve:
	$(CARGO) bench --bench bench_serve
	@test -f BENCH_serve.json && echo "BENCH_serve.json updated" || \
		echo "warning: BENCH_serve.json missing"
	$(PYTHON) tools/check_bench_overhead.py BENCH_serve.json

# All harness = false bench binaries.
bench:
	$(CARGO) bench

# AOT-lower the JAX graphs to HLO-text artifacts (requires the python
# toolchain; rust artifact-driven tests skip themselves without this).
artifacts:
	$(PYTHON) python/compile/aot.py

clean:
	$(CARGO) clean
