#!/usr/bin/env python3
"""Gate the kernel-backend numbers in ``BENCH_infer.json``.

``BENCH_infer.json`` (written by ``cargo bench --bench bench_infer``)
carries, per model, a ``scalar_img_s`` case (the reference loops) and a
``simd_img_s`` case (the resolved SIMD backend on the same plan). Two
gates:

1. SIMD must never be slower than scalar beyond SIMD_TOLERANCE — the
   dispatch layer must be free and the vector kernels must win (or at
   worst tie, e.g. on the portable fallback of an exotic host).
2. With ``--baseline <file>`` (the committed ``BENCH_infer.json``),
   ``scalar_img_s`` must stay within BASE_TOLERANCE of the baseline per
   model — the SIMD work must not regress the scalar path. Models
   missing from the baseline (or a baseline without kernel cases, e.g.
   from before the backend split) are skipped, not failed, so the gate
   bootstraps cleanly.

Smoke runs (1 iteration) are noisy, hence the generous tolerances:
this is a cliff detector, not a profiler.

Usage: python3 tools/check_bench_infer.py [BENCH_infer.json]
           [--baseline committed/BENCH_infer.json]
"""

import json
import sys

SIMD_TOLERANCE = 0.10  # simd_img_s >= scalar_img_s * (1 - 10%)
BASE_TOLERANCE = 0.05  # scalar_img_s >= baseline * (1 - 5%)
SMOKE_SLACK = 0.40  # widen both gates when either run was a smoke run


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except OSError as e:
        print(f"check_bench_infer: cannot read {path}: {e}")
        return None


def kernel_cases(bench):
    return {
        key: case
        for key, case in sorted(bench.items())
        if isinstance(case, dict) and "scalar_img_s" in case and "simd_img_s" in case
    }


def main() -> int:
    argv = sys.argv[1:]
    baseline_path = None
    if "--baseline" in argv:
        i = argv.index("--baseline")
        baseline_path = argv[i + 1] if i + 1 < len(argv) else None
        if baseline_path is None:
            print("check_bench_infer: --baseline needs a file argument")
            return 1
        del argv[i : i + 2]
    smoke = "--smoke" in argv
    if smoke:
        argv.remove("--smoke")
    path = argv[0] if argv else "BENCH_infer.json"

    bench = load(path)
    if bench is None:
        return 1
    cases = kernel_cases(bench)
    if not cases:
        print(f"check_bench_infer: no scalar/simd cases in {path} — "
              "re-run `make bench-infer` (or the CI smoke) first")
        return 1

    simd_floor = 1.0 - SIMD_TOLERANCE - (SMOKE_SLACK if smoke else 0.0)
    base_floor = 1.0 - BASE_TOLERANCE - (SMOKE_SLACK if smoke else 0.0)

    baseline = {}
    if baseline_path is not None:
        base_bench = load(baseline_path)
        if base_bench is not None:
            baseline = kernel_cases(base_bench)
        else:
            print("check_bench_infer: baseline unreadable — skipping the "
                  "scalar-regression gate")

    failed = False
    for model, case in cases.items():
        scalar = case["scalar_img_s"]
        simd = case["simd_img_s"]
        limit = scalar * simd_floor
        verdict = "ok" if simd >= limit else "FAIL"
        ratio = simd / scalar if scalar > 0 else 0.0
        print(f"{model}: scalar {scalar:10.1f} img/s | simd {simd:10.1f} img/s "
              f"({ratio:4.2f}x) | floor {limit:10.1f} .. {verdict}")
        failed |= simd < limit

        base_case = baseline.get(model)
        if base_case is None:
            continue
        base_scalar = base_case["scalar_img_s"]
        blimit = base_scalar * base_floor
        bverdict = "ok" if scalar >= blimit else "FAIL"
        print(f"{model}: scalar vs committed baseline {base_scalar:10.1f} img/s "
              f"| floor {blimit:10.1f} .. {bverdict}")
        failed |= scalar < blimit

    if failed:
        print("check_bench_infer: kernel gate failed — SIMD slower than scalar "
              "or the scalar path regressed vs the committed baseline")
        return 1
    print("check_bench_infer: kernel backends within budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())
