#!/usr/bin/env python3
"""Gate serve-bench invariants: zero-fault overhead and replica scaling.

``BENCH_serve.json`` (written by ``cargo bench --bench bench_serve``)
contains, per thread count N, a ``batched_tN`` case (no fault plan) and
a ``faults0_tN`` case (identical options plus an *empty* fault plan —
the health tracker attached but inert). This script fails if the inert
tracker costs more than TOLERANCE (5%) of the batched loop time, with a
small absolute slack so sub-millisecond smoke runs don't trip on timer
noise.

It also gates cluster replica scaling: the bench replays one dense
trace through ``cluster_r1`` and ``cluster_r4``; four replicas must
reach at least MIN_SCALING (2.5x) the single replica's *virtual*
throughput. Virtual img/s is computed on the deterministic virtual
timeline, so this gate is noise-free and holds on smoke runs too.

Finally it gates observability overhead: the ``obs_tN`` cases run the
identical batched load with the Basic event recorder *enabled*; they
may cost at most OBS_TOLERANCE (2%) over ``batched_tN`` (plus the same
absolute slack). The default session keeps the recorder disabled, so
this bound covers the disabled recorder a fortiori.

The multi-model gate compares ``multi_m1`` — the identical dense trace
replayed through the multi-model dispatch plane with a one-model set —
against ``cluster_r1`` (same trace, same options); the model-keyed
batcher and per-model routing may cost at most MULTI_TOLERANCE (5%)
over the single-model path. ``multi_m2`` (two models, mixed trace)
must be present with positive virtual throughput so the two-model path
stays exercised.

Usage: python3 tools/check_bench_overhead.py [BENCH_serve.json]
"""

import json
import sys

TOLERANCE = 0.05  # relative: faults0 may cost at most 5% over batched
OBS_TOLERANCE = 0.02  # relative: obs (Basic recorder) at most 2% over batched
SLACK_MS = 1.0  # absolute: ignore sub-ms jitter (smoke runs are tiny)
MIN_SCALING = 2.5  # cluster_r4 virtual img/s must be >= 2.5x cluster_r1
MULTI_TOLERANCE = 0.05  # multi_m1 may cost at most 5% over cluster_r1


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_serve.json"
    try:
        with open(path) as f:
            bench = json.load(f)
    except OSError as e:
        print(f"check_bench_overhead: cannot read {path}: {e}")
        return 1

    pairs = []
    for key, case in sorted(bench.items()):
        if not key.startswith("faults0_t"):
            continue
        threads = key[len("faults0_t") :]
        base = bench.get(f"batched_t{threads}")
        if base is None:
            print(f"check_bench_overhead: {key} has no batched_t{threads} baseline")
            return 1
        pairs.append((threads, base["loop_ms"], case["loop_ms"]))

    if not pairs:
        print(f"check_bench_overhead: no faults0_t* cases in {path} — "
              "re-run `make bench-serve` (or the CI smoke) first")
        return 1

    failed = False
    for threads, base_ms, faults_ms in pairs:
        limit = base_ms * (1.0 + TOLERANCE) + SLACK_MS
        rel = (faults_ms / base_ms - 1.0) * 100.0 if base_ms > 0 else 0.0
        verdict = "ok" if faults_ms <= limit else "FAIL"
        print(f"t{threads}: batched {base_ms:8.2f} ms | faults0 {faults_ms:8.2f} ms "
              f"({rel:+5.1f}%) | limit {limit:8.2f} ms .. {verdict}")
        failed |= faults_ms > limit

    if failed:
        print("check_bench_overhead: zero-fault serve overhead exceeds "
              f"{TOLERANCE:.0%} (+{SLACK_MS} ms slack) — the fault machinery "
              "must stay off the hot path when no plan is attached")
        return 1
    print("check_bench_overhead: zero-fault overhead within budget")

    obs_pairs = []
    for key, case in sorted(bench.items()):
        if not key.startswith("obs_t"):
            continue
        threads = key[len("obs_t") :]
        base = bench.get(f"batched_t{threads}")
        if base is None:
            print(f"check_bench_overhead: {key} has no batched_t{threads} baseline")
            return 1
        obs_pairs.append((threads, base["loop_ms"], case["loop_ms"]))

    if not obs_pairs:
        print(f"check_bench_overhead: no obs_t* cases in {path} — "
              "re-run `make bench-serve` (or the CI smoke) first")
        return 1

    for threads, base_ms, obs_ms in obs_pairs:
        limit = base_ms * (1.0 + OBS_TOLERANCE) + SLACK_MS
        rel = (obs_ms / base_ms - 1.0) * 100.0 if base_ms > 0 else 0.0
        verdict = "ok" if obs_ms <= limit else "FAIL"
        print(f"t{threads}: batched {base_ms:8.2f} ms | obs {obs_ms:8.2f} ms "
              f"({rel:+5.1f}%) | limit {limit:8.2f} ms .. {verdict}")
        failed |= obs_ms > limit

    if failed:
        print("check_bench_overhead: enabled-recorder overhead exceeds "
              f"{OBS_TOLERANCE:.0%} (+{SLACK_MS} ms slack) — recording must "
              "stay off the serve hot path (obs/ is lock-light by contract)")
        return 1
    print("check_bench_overhead: observability overhead within budget")

    r1 = bench.get("cluster_r1")
    r4 = bench.get("cluster_r4")
    if r1 is None or r4 is None:
        print(f"check_bench_overhead: no cluster_r1/cluster_r4 cases in {path} — "
              "re-run `make bench-serve` (or the CI smoke) first")
        return 1
    base = r1["virtual_img_s"]
    quad = r4["virtual_img_s"]
    scaling = quad / base if base > 0 else 0.0
    print(f"cluster: r1 {base:8.1f} virtual img/s | r4 {quad:8.1f} "
          f"({scaling:.2f}x, floor {MIN_SCALING}x)")
    if scaling < MIN_SCALING:
        print(f"check_bench_overhead: 4 replicas scale only {scaling:.2f}x over 1 "
              f"(floor {MIN_SCALING}x) — the router is serializing the cluster")
        return 1
    print("check_bench_overhead: replica scaling within budget")

    m1 = bench.get("multi_m1")
    m2 = bench.get("multi_m2")
    if m1 is None or m2 is None:
        print(f"check_bench_overhead: no multi_m1/multi_m2 cases in {path} — "
              "re-run `make bench-serve` (or the CI smoke) first")
        return 1
    base_ms = r1["loop_ms"]
    m1_ms = m1["loop_ms"]
    limit = base_ms * (1.0 + MULTI_TOLERANCE) + SLACK_MS
    rel = (m1_ms / base_ms - 1.0) * 100.0 if base_ms > 0 else 0.0
    verdict = "ok" if m1_ms <= limit else "FAIL"
    print(f"multi: cluster_r1 {base_ms:8.2f} ms | multi_m1 {m1_ms:8.2f} ms "
          f"({rel:+5.1f}%) | limit {limit:8.2f} ms .. {verdict}")
    if m1_ms > limit:
        print("check_bench_overhead: multi-model dispatch overhead exceeds "
              f"{MULTI_TOLERANCE:.0%} (+{SLACK_MS} ms slack) over the "
              "single-model path — the model-keyed batcher must stay cheap "
              "when one model is served")
        return 1
    if m2.get("virtual_img_s", 0.0) <= 0.0 or m2.get("models") != 2:
        print("check_bench_overhead: multi_m2 must serve two models with "
              "positive virtual throughput")
        return 1
    print(f"multi: multi_m2 {m2['virtual_img_s']:8.1f} virtual img/s "
          f"over {m2['models']} models")
    print("check_bench_overhead: multi-model dispatch overhead within budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())
