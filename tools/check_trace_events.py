#!/usr/bin/env python3
"""Validate an exported Chrome-trace-event file (``serve --trace-events``).

Checks the structural invariants the exporter in ``rust/src/obs/export.rs``
promises (EXPERIMENTS.md §Trace events has the schema):

* the document is ``{"traceEvents": [...]}`` and every event carries
  ``ph``/``pid``/``tid`` (plus ``ts`` for B/E/i phases);
* every ``pid`` that emits events has a ``process_name`` metadata
  record, and every ``(pid, tid)`` track a ``thread_name``;
* per track, ``B``/``E`` events pair up in stack discipline (matching
  names, nothing left open at EOF) and timestamps are monotone
  non-decreasing across B/E/i;
* per-layer attribution spans (``cat == "layer"``) carry the required
  args: ``unit``, ``cycles_img``, ``energy_uj``;
* batch spans (``cat == "batch"``) carry ``point``, ``size``,
  ``per_img_cycles``, ``energy_uj_img`` and the member ``requests``.

Usage: python3 tools/check_trace_events.py trace.json
Exits non-zero on the first class of violation, printing every instance.
"""

import json
import sys

REQUIRED_LAYER_ARGS = ("unit", "cycles_img", "energy_uj")
REQUIRED_BATCH_ARGS = ("point", "size", "per_img_cycles", "energy_uj_img", "requests")


def main() -> int:
    if len(sys.argv) != 2:
        print("usage: check_trace_events.py <trace.json>")
        return 2
    path = sys.argv[1]
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"check_trace_events: cannot load {path}: {e}")
        return 1

    events = doc.get("traceEvents")
    if not isinstance(events, list):
        print("check_trace_events: top-level 'traceEvents' array missing")
        return 1

    errors = []
    proc_names = {}
    thread_names = {}
    used_pids = set()
    used_tracks = set()
    stacks = {}  # (pid, tid) -> [name, ...] of open B events
    last_ts = {}  # (pid, tid) -> last seen timestamp
    counts = {"B": 0, "E": 0, "i": 0, "M": 0}

    for idx, ev in enumerate(events):
        ph = ev.get("ph")
        pid = ev.get("pid")
        tid = ev.get("tid")
        if ph not in ("B", "E", "i", "M"):
            errors.append(f"event {idx}: unknown phase {ph!r}")
            continue
        counts[ph] += 1
        if ph == "M":
            label = ev.get("args", {}).get("name", "")
            if ev.get("name") == "process_name":
                proc_names[pid] = label
            elif ev.get("name") == "thread_name":
                thread_names[(pid, tid)] = label
            continue
        if pid is None or tid is None:
            errors.append(f"event {idx}: missing pid/tid")
            continue
        used_pids.add(pid)
        track = (pid, tid)
        used_tracks.add(track)
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errors.append(f"event {idx}: bad ts {ts!r}")
            continue
        if ts < last_ts.get(track, 0.0):
            errors.append(
                f"event {idx} ({ev.get('name')!r}): ts {ts} goes backwards on "
                f"track pid={pid} tid={tid} (last {last_ts[track]})"
            )
        last_ts[track] = ts
        if ph == "B":
            stacks.setdefault(track, []).append(ev.get("name"))
            cat = ev.get("cat")
            args = ev.get("args", {})
            required = ()
            if cat == "layer":
                required = REQUIRED_LAYER_ARGS
            elif cat == "batch":
                required = REQUIRED_BATCH_ARGS
            for k in required:
                if k not in args:
                    errors.append(
                        f"event {idx} ({ev.get('name')!r}, cat {cat}): "
                        f"missing required arg {k!r}"
                    )
        elif ph == "E":
            stack = stacks.get(track, [])
            if not stack:
                errors.append(
                    f"event {idx} ({ev.get('name')!r}): E with no open B on "
                    f"track pid={pid} tid={tid}"
                )
            else:
                opened = stack.pop()
                if opened != ev.get("name"):
                    errors.append(
                        f"event {idx}: E {ev.get('name')!r} closes B {opened!r} "
                        f"on track pid={pid} tid={tid}"
                    )

    for track, stack in sorted(stacks.items()):
        for name in stack:
            errors.append(f"track pid={track[0]} tid={track[1]}: B {name!r} never closed")
    for pid in sorted(used_pids):
        if pid not in proc_names:
            errors.append(f"pid {pid}: no process_name metadata")
    for track in sorted(used_tracks):
        if track not in thread_names:
            errors.append(f"pid={track[0]} tid={track[1]}: no thread_name metadata")
    if counts["B"] != counts["E"]:
        errors.append(f"unbalanced spans: {counts['B']} B vs {counts['E']} E")

    if errors:
        for e in errors:
            print(f"check_trace_events: {e}")
        print(f"check_trace_events: {len(errors)} violation(s) in {path}")
        return 1
    print(
        f"check_trace_events: {path} ok — {len(events)} events, "
        f"{counts['B']} spans, {counts['i']} instants, "
        f"{len(used_tracks)} tracks across {len(used_pids)} processes"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
