"""Benchmark model definitions as explicit DAGs (L2).

A model is a list of nodes; every node has a unique name and references
its inputs by name. The same table is exported (via ``to_meta``) to the
rust coordinator, which rebuilds the graph for the partition pass and
the DIANA simulator — python and rust share one source of truth.

Node kinds:
  input                     — network input placeholder
  conv  (mappable)          — ODiMO search unit, Eq.-1 supernet in SEARCH
  dwconv (digital-only)     — depthwise conv; DIANA executes these only
                              on the digital accelerator (paper Sec. IV-A)
  add                       — residual join (+ ReLU + re-quant)
  gap                       — global average pool
  fc    (mappable)          — classifier head

Benchmarks (paper Sec. IV-A, with the substitutions of DESIGN.md):
  resnet20   — CIFAR-10-like   32x32x3, 10 classes (exact paper model)
  resnet18s  — TinyImageNet-like 64x64x3; width 0.25x, 24 classes
               (CPU-budget substitution; depth structure preserved)
  mbv1_025   — VWW-like 96x96x3, 2 classes, MobileNetV1 0.25x
  tinycnn    — 3-conv test model for fast integration tests
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L


@dataclass
class Node:
    name: str
    op: str                      # input|conv|dwconv|add|gap|fc
    inputs: List[str] = field(default_factory=list)
    cout: int = 0
    k: int = 1                   # square kernel size
    stride: int = 1
    pad: int = 0
    relu: bool = True
    # filled by shape inference:
    cin: int = 0
    in_hw: Tuple[int, int] = (0, 0)
    out_hw: Tuple[int, int] = (0, 0)


@dataclass
class ModelDef:
    name: str
    input_shape: Tuple[int, int, int]      # (C, H, W)
    classes: int
    nodes: List[Node]
    train_batch: int = 64
    eval_batch: int = 256

    def node(self, name: str) -> Node:
        return self._index[name]

    def finalize(self) -> "ModelDef":
        """Shape inference; populates cin / in_hw / out_hw on every node."""
        self._index = {n.name: n for n in self.nodes}
        shapes: Dict[str, Tuple[int, int, int]] = {}
        c0, h0, w0 = self.input_shape
        for n in self.nodes:
            if n.op == "input":
                shapes[n.name] = (c0, h0, w0)
                n.cout, n.out_hw = c0, (h0, w0)
                continue
            c, h, w = shapes[n.inputs[0]]
            n.cin, n.in_hw = c, (h, w)
            if n.op in ("conv", "dwconv"):
                oh = (h + 2 * n.pad - n.k) // n.stride + 1
                ow = (w + 2 * n.pad - n.k) // n.stride + 1
                if n.op == "dwconv":
                    n.cout = c
                shapes[n.name] = (n.cout, oh, ow)
                n.out_hw = (oh, ow)
            elif n.op == "add":
                ca, ha, wa = shapes[n.inputs[0]]
                cb, hb, wb = shapes[n.inputs[1]]
                assert (ca, ha, wa) == (cb, hb, wb), \
                    f"{n.name}: add shape mismatch {shapes[n.inputs[0]]} vs {shapes[n.inputs[1]]}"
                n.cout, n.out_hw = ca, (ha, wa)
                shapes[n.name] = (ca, ha, wa)
            elif n.op == "gap":
                n.cout, n.out_hw = c, (1, 1)
                shapes[n.name] = (c, 1, 1)
            elif n.op == "fc":
                n.cout, n.out_hw = self.classes, (1, 1)
                shapes[n.name] = (self.classes, 1, 1)
            else:
                raise ValueError(n.op)
        return self

    # ---- derived views -------------------------------------------------

    def mappable(self) -> List[Node]:
        """Nodes ODiMO partitions across accelerators (conv + fc)."""
        return [n for n in self.nodes if n.op in ("conv", "fc")]

    def param_nodes(self) -> List[Node]:
        return [n for n in self.nodes if n.op in ("conv", "dwconv", "fc", "add")]

    def macs(self, n: Node) -> int:
        if n.op == "conv":
            return n.cin * n.k * n.k * n.cout * n.out_hw[0] * n.out_hw[1]
        if n.op == "dwconv":
            return n.cout * n.k * n.k * n.out_hw[0] * n.out_hw[1]
        if n.op == "fc":
            return n.cin * n.cout
        return 0

    # ---- parameters ----------------------------------------------------

    def init_params(self, key) -> Dict[str, Dict[str, jnp.ndarray]]:
        """He-normal weights; quant scales from weight statistics; alpha=0
        (uniform mapping prior). BN is architecturally folded: every conv
        carries its own bias (DESIGN.md §Substitutions)."""
        params: Dict[str, Dict[str, jnp.ndarray]] = {}
        for n in self.param_nodes():
            key, k1 = jax.random.split(key)
            p: Dict[str, jnp.ndarray] = {}
            if n.op == "conv":
                fan_in = n.cin * n.k * n.k
                w = jax.random.normal(k1, (n.cout, n.cin, n.k, n.k)) * math.sqrt(2.0 / fan_in)
            elif n.op == "dwconv":
                fan_in = n.k * n.k
                w = jax.random.normal(k1, (n.cout, 1, n.k, n.k)) * math.sqrt(2.0 / fan_in)
            elif n.op == "fc":
                fan_in = n.cin
                w = jax.random.normal(k1, (n.cout, n.cin)) * math.sqrt(1.0 / fan_in)
            else:  # add
                params[n.name] = {"lsa": jnp.asarray(0.0)}
                continue
            p["w"] = w.astype(jnp.float32)
            p["b"] = jnp.zeros((n.cout,), jnp.float32)
            if n.op in ("conv", "dwconv"):
                # BatchNorm (FLOAT pre-training only; folded before search)
                p["gamma"] = jnp.ones((n.cout,), jnp.float32)
                p["beta"] = jnp.zeros((n.cout,), jnp.float32)
                p["rm"] = jnp.zeros((n.cout,), jnp.float32)
                p["rv"] = jnp.ones((n.cout,), jnp.float32)
            # e^s ~= 3 sigma of the weight distribution
            p["ls8"] = jnp.asarray(math.log(3.0 * math.sqrt(2.0 / fan_in)), jnp.float32)
            if n.op != "dwconv":
                p["lster"] = jnp.asarray(math.log(3.0 * math.sqrt(2.0 / fan_in)), jnp.float32)
                p["alpha"] = jnp.zeros((L.N_ACC, n.cout), jnp.float32)
            p["lsa"] = jnp.asarray(0.0, jnp.float32)  # e^s = 1, matches [0,1] inputs
            params[n.name] = p
        return params

    # ---- forward -------------------------------------------------------

    def apply(self, params, x, *, mode: str, tau=1.0, assign=None,
              bn_stats=None):
        """Run the DAG. ``assign`` maps mappable-node name -> (N, Cout)
        one-hot mask (DEPLOY mode only). In FLOAT mode, pass a dict as
        ``bn_stats`` to run BN on batch statistics and collect them
        (training); leave None to use running statistics (eval)."""
        vals = {}
        for n in self.nodes:
            if n.op == "input":
                vals[n.name] = x if mode == L.FLOAT else L.input_quant(x)
            elif n.op == "conv":
                vals[n.name] = L.mconv_apply(
                    params[n.name], vals[n.inputs[0]], stride=n.stride,
                    pad=n.pad, mode=mode, tau=tau,
                    assign=None if assign is None else assign[n.name],
                    relu=n.relu, name=n.name, bn_stats=bn_stats)
            elif n.op == "dwconv":
                vals[n.name] = L.dwconv_apply(
                    params[n.name], vals[n.inputs[0]], stride=n.stride,
                    pad=n.pad, mode=mode, relu=n.relu, name=n.name,
                    bn_stats=bn_stats)
            elif n.op == "add":
                vals[n.name] = L.add_apply(
                    params[n.name], vals[n.inputs[0]], vals[n.inputs[1]],
                    mode=mode, relu=n.relu)
            elif n.op == "gap":
                vals[n.name] = L.gap_apply(vals[n.inputs[0]])
            elif n.op == "fc":
                vals[n.name] = L.fc_apply(
                    params[n.name], vals[n.inputs[0]], mode=mode, tau=tau,
                    assign=None if assign is None else assign[n.name])
        return vals[self.nodes[-1].name]

    # ---- export --------------------------------------------------------

    def to_meta(self) -> dict:
        return {
            "name": self.name,
            "input_shape": list(self.input_shape),
            "classes": self.classes,
            "train_batch": self.train_batch,
            "eval_batch": self.eval_batch,
            "nodes": [
                {
                    "name": n.name, "op": n.op, "inputs": n.inputs,
                    "cin": n.cin, "cout": n.cout, "k": n.k,
                    "stride": n.stride, "pad": n.pad, "relu": n.relu,
                    "in_hw": list(n.in_hw), "out_hw": list(n.out_hw),
                    "macs": self.macs(n),
                    "mappable": n.op in ("conv", "fc"),
                }
                for n in self.nodes
            ],
        }


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------

def _basic_block(nodes: List[Node], idx: int, x: str, cin: int, cout: int,
                 stride: int) -> str:
    """ResNet basic block: conv-relu-conv (+skip) -relu, BN folded."""
    c1 = f"b{idx}_conv1"
    c2 = f"b{idx}_conv2"
    nodes.append(Node(c1, "conv", [x], cout=cout, k=3, stride=stride, pad=1))
    nodes.append(Node(c2, "conv", [c1], cout=cout, k=3, stride=1, pad=1, relu=False))
    if stride != 1 or cin != cout:
        sk = f"b{idx}_down"
        nodes.append(Node(sk, "conv", [x], cout=cout, k=1, stride=stride,
                          pad=0, relu=False))
        skip = sk
    else:
        skip = x
    out = f"b{idx}_add"
    nodes.append(Node(out, "add", [c2, skip]))
    return out


def resnet20() -> ModelDef:
    """ResNet20 for CIFAR-10 (He et al.): 3 stages x 3 basic blocks,
    16/32/64 channels — the paper's CIFAR-10 reference model."""
    nodes = [Node("in", "input")]
    nodes.append(Node("stem", "conv", ["in"], cout=16, k=3, stride=1, pad=1))
    x, cin, idx = "stem", 16, 0
    for stage, cout in enumerate((16, 32, 64)):
        for b in range(3):
            stride = 2 if (stage > 0 and b == 0) else 1
            x = _basic_block(nodes, idx, x, cin, cout, stride)
            cin = cout
            idx += 1
    nodes.append(Node("gap", "gap", [x]))
    nodes.append(Node("fc", "fc", ["gap"]))
    return ModelDef("resnet20", (3, 32, 32), 10, nodes,
                    train_batch=64, eval_batch=256).finalize()


def resnet18s() -> ModelDef:
    """Width-0.25x ResNet18 on 64x64 inputs, 24 classes — the
    TinyImageNet/ResNet18 substitution (DESIGN.md): same depth/stage
    structure, CPU-trainable size."""
    nodes = [Node("in", "input")]
    nodes.append(Node("stem", "conv", ["in"], cout=16, k=3, stride=1, pad=1))
    x, cin, idx = "stem", 16, 0
    for stage, cout in enumerate((16, 32, 64, 128)):
        for b in range(2):
            stride = 2 if (stage > 0 and b == 0) else 1
            x = _basic_block(nodes, idx, x, cin, cout, stride)
            cin = cout
            idx += 1
    nodes.append(Node("gap", "gap", [x]))
    nodes.append(Node("fc", "fc", ["gap"]))
    return ModelDef("resnet18s", (3, 64, 64), 24, nodes,
                    train_batch=32, eval_batch=128).finalize()


def mbv1_025() -> ModelDef:
    """MobileNetV1 with 0.25 width multiplier, 96x96 inputs, 2 classes
    (VWW person detection). Depthwise convs are digital-only on DIANA;
    ODiMO maps only the pointwise/standard convs and the FC."""
    def ch(c):  # width multiplier
        return max(8, int(c * 0.25))
    nodes = [Node("in", "input")]
    nodes.append(Node("stem", "conv", ["in"], cout=ch(32), k=3, stride=2, pad=1))
    x = "stem"
    cfg = [(64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2),
           (512, 1), (512, 1), (512, 1), (512, 1), (512, 1), (1024, 2),
           (1024, 1)]
    for i, (cout, stride) in enumerate(cfg):
        dw = f"dw{i}"
        pw = f"pw{i}"
        nodes.append(Node(dw, "dwconv", [x], k=3, stride=stride, pad=1))
        nodes.append(Node(pw, "conv", [dw], cout=ch(cout), k=1, stride=1, pad=0))
        x = pw
    nodes.append(Node("gap", "gap", [x]))
    nodes.append(Node("fc", "fc", ["gap"]))
    return ModelDef("mbv1_025", (3, 96, 96), 2, nodes,
                    train_batch=32, eval_batch=128).finalize()


def tinycnn() -> ModelDef:
    """3-conv test model: exercises conv, residual add, gap, fc — runs a
    full ODiMO pipeline in seconds. Used by integration tests only."""
    nodes = [Node("in", "input")]
    nodes.append(Node("stem", "conv", ["in"], cout=8, k=3, stride=1, pad=1))
    nodes.append(Node("c1", "conv", ["stem"], cout=16, k=3, stride=2, pad=1))
    nodes.append(Node("c2", "conv", ["c1"], cout=16, k=3, stride=1, pad=1, relu=False))
    nodes.append(Node("res", "add", ["c2", "c1"]))
    nodes.append(Node("gap", "gap", ["res"]))
    nodes.append(Node("fc", "fc", ["gap"]))
    return ModelDef("tinycnn", (3, 16, 16), 10, nodes,
                    train_batch=32, eval_batch=128).finalize()


BUILDERS = {
    "tinycnn": tinycnn,
    "resnet20": resnet20,
    "resnet18s": resnet18s,
    "mbv1_025": mbv1_025,
}


def build(name: str) -> ModelDef:
    return BUILDERS[name]()
