"""Differentiable hardware cost models (L2) — paper Sec. III-C.

Two model families:

  * ``diana``       — the paper's analytical cycle models of the DIANA
                      accelerators (Eq. 6 AIMC, Eq. 7 digital), including
                      the DMA weight-load terms.
  * ``proportional``— the abstract models of Fig. 5: latency simply
                      proportional to assigned MACs, with throughput and
                      active/idle power supplied as *runtime inputs* so a
                      single lowered HLO covers every Fig.-5 scenario.

Both express per-layer, per-accelerator latency as a function of the
(expected) number of output channels assigned to that accelerator, which
in SEARCH mode is the softmax(alpha) channel mass (continuous), and in
the rust simulator is the exact integer count. ceil() appears in Eq. 6/7;
we evaluate it exactly but give it a straight-through gradient so the
loss stays differentiable.

Units: cycles (@260 MHz on DIANA) and mW; energy in the loss is
mW*cycles, converted to uJ only in reports. DIANA power calibration:
DESIGN.md §Key-numeric-contracts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# DIANA constants
# ---------------------------------------------------------------------------

#: AIMC array geometry (paper Eq. 6): 1152 rows x 512 columns of cells
AIMC_ROWS, AIMC_COLS = 1152, 512
#: digital PE array geometry (paper Eq. 7): 16x16 PEs
DIG_PE = 16
#: clock, for cycle->time conversion in reports
F_CLK_HZ = 260e6
#: average power (mW): [digital, aimc], active and idle. Calibrated so the
#: All-8bit CIFAR-10/ResNet20 point lands on the paper's Table-I scale
#: (1.55 ms / 38.71 uJ at 260 MHz) — see rust/src/hw/energy.rs for the
#: mirrored constants and EXPERIMENTS.md for the calibration check.
P_ACT = (24.0, 26.0)
P_IDLE = (1.3, 1.3)

#: smooth-max sharpness for Eq. 3 (per-layer latencies are normalized by
#: the layer's all-digital latency before the logsumexp, so one constant
#: works across layers of very different size)
SMOOTHMAX_BETA = 8.0


def ceil_ste(x):
    """Exact ceil forward, unit gradient backward."""
    return x + jax.lax.stop_gradient(jnp.ceil(x) - x)


def smooth_max(xs, scale):
    """Differentiable approximation of max(xs) (Eq. 3's substitute).

    logsumexp(beta * x / scale) * scale / beta  >=  max(xs); tight as
    beta -> inf. ``scale`` sets the units so beta is dimensionless.
    """
    b = SMOOTHMAX_BETA
    x = jnp.stack(xs) / scale
    return scale / b * jax.nn.logsumexp(b * x)


# ---------------------------------------------------------------------------
# DIANA analytical latency models (cycles)
# ---------------------------------------------------------------------------

def lat_aimc(cin, fx, fy, ox, oy, cout_a):
    """Paper Eq. 6. cout_a may be fractional (expected channels) in SEARCH.

    First addend: compute — the AIMC macro processes up to 1152 input
    contributions x 512 output columns per activation; second: the DMA
    cycles to (re)program the cells (2 transfers x 4 bytes/cycle lanes).
    When cout_a == 0 both ceil terms are 0 and the whole layer is free,
    which discretization relies on.
    """
    tiles_in = ceil_ste(cin * fx * fy / AIMC_ROWS)
    tiles_out = ceil_ste(cout_a / AIMC_COLS)
    compute = tiles_in * tiles_out * ox * oy
    dma = 2.0 * 4.0 * cin * tiles_out
    return compute + dma


def lat_dig(cin, fx, fy, ox, oy, cout_d):
    """Paper Eq. 7: 16 output channels x 16 output rows per PE-array pass
    (first addend: compute), plus weight-load DMA (second addend)."""
    compute = ceil_ste(cout_d / DIG_PE) * ceil_ste(oy / DIG_PE) * cin * ox * fx * fy
    dma = cin * cout_d * fx * fy
    return compute + dma


def lat_aimc_static(cin, fx, fy, ox, oy, cout_a) -> float:
    """Pure-python Eq. 6 (for normalizer constants, no tracing)."""
    import math
    tiles_in = math.ceil(cin * fx * fy / AIMC_ROWS)
    tiles_out = math.ceil(cout_a / AIMC_COLS)
    return tiles_in * tiles_out * ox * oy + 2.0 * 4.0 * cin * tiles_out


def lat_dig_static(cin, fx, fy, ox, oy, cout_d) -> float:
    """Pure-python Eq. 7 (for normalizer constants, no tracing)."""
    import math
    return (math.ceil(cout_d / DIG_PE) * math.ceil(oy / DIG_PE)
            * cin * ox * fx * fy + cin * cout_d * fx * fy)


def layer_lats_diana(node_meta, cout_d, cout_a):
    """(lat_digital, lat_aimc) for one mappable layer. FC layers are
    1x1x1 convs in this model (fx=fy=ox=oy=1)."""
    cin, fx, fy = node_meta["cin"], node_meta["k"], node_meta["k"]
    ox, oy = node_meta["out_hw"][1], node_meta["out_hw"][0]
    return (lat_dig(cin, fx, fy, ox, oy, cout_d),
            lat_aimc(cin, fx, fy, ox, oy, cout_a))


def layer_lats_dw_diana(node_meta):
    """Depthwise conv: digital-only. Executed channel-by-channel (each
    output channel reads one input channel), so cin=1 in the per-channel
    inner product and cout channels map onto the 16-row PE axis."""
    fx = fy = node_meta["k"]
    ox, oy = node_meta["out_hw"][1], node_meta["out_hw"][0]
    cout = node_meta["cout"]
    compute = ceil_ste(jnp.asarray(float(cout)) / DIG_PE) * \
        ceil_ste(jnp.asarray(float(oy)) / DIG_PE) * ox * fx * fy
    dma = float(cout * fx * fy)
    return compute + dma


# ---------------------------------------------------------------------------
# loss terms (Eq. 3 latency / Eq. 4 energy)
# ---------------------------------------------------------------------------

def _per_layer_costs_diana(model_meta, exp_channels):
    """exp_channels: {name: (cout_d, cout_a)} for mappable nodes.
    Returns list of (lat_d, lat_a, M) per cost-bearing node."""
    out = []
    for nm in model_meta["nodes"]:
        if nm.get("mappable"):
            cd, ca = exp_channels[nm["name"]]
            ld, la = layer_lats_diana(nm, cd, ca)
            ox, oy = nm["out_hw"][1], nm["out_hw"][0]
            scale = max(lat_dig_static(nm["cin"], nm["k"], nm["k"], ox, oy,
                                       nm["cout"]), 1.0)
            m = smooth_max([ld, la], scale)
            out.append((ld, la, m))
        elif nm["op"] == "dwconv":
            ld = layer_lats_dw_diana(nm)
            out.append((ld, jnp.asarray(0.0), ld))
    return out


def loss_latency_diana(model_meta, exp_channels):
    """Eq. 3: sum over layers of smooth-max accelerator latency (cycles)."""
    costs = _per_layer_costs_diana(model_meta, exp_channels)
    return sum(m for _, _, m in costs)


def loss_energy_diana(model_meta, exp_channels):
    """Eq. 4: active + idle energy over both accelerators (mW*cycles)."""
    costs = _per_layer_costs_diana(model_meta, exp_channels)
    total = jnp.asarray(0.0)
    for ld, la, m in costs:
        total = total + P_ACT[0] * ld + P_IDLE[0] * (m - ld)
        total = total + P_ACT[1] * la + P_IDLE[1] * (m - la)
    return total


def loss_proportional(model_meta, exp_channels, thpt, p_act, p_idle):
    """Fig.-5 abstract model: lat_i = assigned_MACs / thpt_i (cycles),
    energy per Eq. 4. ``thpt``(2,), ``p_act``(2,), ``p_idle``(2,) are
    runtime inputs. With p_idle == p_act this reduces (up to a constant)
    to the latency objective — exactly the paper's Fig.-5 observation."""
    total = jnp.asarray(0.0)
    for nm in model_meta["nodes"]:
        if nm.get("mappable"):
            cd, ca = exp_channels[nm["name"]]
            macs_per_ch = float(nm["macs"]) / float(nm["cout"])
            ld = macs_per_ch * cd / thpt[0]
            la = macs_per_ch * ca / thpt[1]
            scale = float(max(nm["macs"], 1))
            m = smooth_max([ld, la], scale / 1.0)
            total = total + p_act[0] * ld + p_idle[0] * (m - ld)
            total = total + p_act[1] * la + p_idle[1] * (m - la)
        elif nm["op"] == "dwconv":
            ld = float(nm["macs"]) / thpt[0]
            total = total + p_act[0] * ld
    return total


# ---------------------------------------------------------------------------
# baseline normalizers
# ---------------------------------------------------------------------------

def all_digital_reference(model_meta):
    """(latency_cycles, energy_mWcycles) of the All-8bit mapping — used to
    normalize the regularizer so lambda is comparable across models.
    Pure python: usable outside a trace (smooth_max of (x, 0) with x/scale
    = 1 evaluates to scale/beta*logsumexp([beta, 0]) ~ x for beta >> 1;
    here we take the exact hard max instead, which is what the rust
    simulator also reports)."""
    lat = 0.0
    en = 0.0
    for nm in model_meta["nodes"]:
        if nm.get("mappable"):
            ox, oy = nm["out_hw"][1], nm["out_hw"][0]
            ld = lat_dig_static(nm["cin"], nm["k"], nm["k"], ox, oy, nm["cout"])
        elif nm["op"] == "dwconv":
            import math
            ox, oy = nm["out_hw"][1], nm["out_hw"][0]
            ld = (math.ceil(nm["cout"] / DIG_PE) * math.ceil(oy / DIG_PE)
                  * ox * nm["k"] * nm["k"] + nm["cout"] * nm["k"] * nm["k"])
        else:
            continue
        lat += ld
        en += P_ACT[0] * ld + P_IDLE[1] * ld  # aimc idles the whole layer
    return float(lat), float(en)
