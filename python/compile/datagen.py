"""Synthetic benchmark datasets (python mirror).

No dataset downloads are possible in this environment, so the three
benchmarks use deterministic, class-conditional synthetic images
(DESIGN.md §Substitutions): each class is a fixed mixture of oriented
sinusoidal gratings ("gabors") with a class color palette; samples add
phase/amplitude jitter plus Gaussian noise. The task is learnable but
not trivial, and — importantly for this paper — *precision-sensitive*:
ternarizing early layers measurably hurts accuracy, which is the
behaviour the mapping search trades off.

The rust runtime generator (rust/src/data/synth.rs) implements the SAME
algorithm from the same SplitMix64 streams; this python copy exists for
kernel/model unit tests only and is never on the artifact path.
"""

from __future__ import annotations

import math

import numpy as np

MASK64 = (1 << 64) - 1

# generator version tag; bump if the algorithm changes (rust mirrors it)
ALGO_VERSION = 1
N_COMPONENTS = 3
NOISE_SIGMA = 0.15
PHASE_JITTER = 0.15  # fraction of 2*pi


def splitmix64(state: int):
    """One SplitMix64 step -> (new_state, u64 output). Matches
    rust/src/util/prng.rs bit-for-bit."""
    state = (state + 0x9E3779B97F4A7C15) & MASK64
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
    z = z ^ (z >> 31)
    return state, z


def _u01(state: int):
    """Uniform in [0,1) from the top 53 bits (same as rust)."""
    state, z = splitmix64(state)
    return state, (z >> 11) * (1.0 / (1 << 53))


class ClassSpec:
    """Per-class grating mixture, derived from (dataset_seed, class)."""

    def __init__(self, dataset_seed: int, cls: int):
        st = (dataset_seed * 0x51_7C_C1B7_2722_0A95 + cls * 0x2545F4914F6CDD1D + 1) & MASK64
        comps = []
        for _ in range(N_COMPONENTS):
            st, u_th = _u01(st)
            st, u_fr = _u01(st)
            st, u_ph = _u01(st)
            st, u_r = _u01(st)
            st, u_g = _u01(st)
            st, u_b = _u01(st)
            st, u_a = _u01(st)
            comps.append({
                "theta": u_th * math.pi,
                "freq": 1.5 + 3.5 * u_fr,
                "phase": u_ph * 2.0 * math.pi,
                "color": (u_r, u_g, u_b),
                "amp": 0.5 + 0.5 * u_a,
            })
        self.comps = comps


def gen_sample(dataset_seed: int, split: int, index: int, cls: int,
               h: int, w: int) -> np.ndarray:
    """One (3, h, w) float32 image in [0, 1]. ``split``: 0 train, 1 test."""
    spec = ClassSpec(dataset_seed, cls)
    st = (dataset_seed ^ (split * 0xD6E8FEB86659FD93) ^ (index * 0xA5A5A5A5A5A5A5A5 + 0x1234567)) & MASK64
    yy = (np.arange(h, dtype=np.float32) / h)[:, None]
    xx = (np.arange(w, dtype=np.float32) / w)[None, :]
    img = np.zeros((3, h, w), np.float32)
    for comp in spec.comps:
        st, u_pj = _u01(st)
        st, u_aj = _u01(st)
        phase = comp["phase"] + (u_pj - 0.5) * 2.0 * math.pi * PHASE_JITTER
        amp = comp["amp"] * (0.8 + 0.4 * u_aj)
        cx = math.cos(comp["theta"]) * comp["freq"]
        cy = math.sin(comp["theta"]) * comp["freq"]
        wave = np.sin(2.0 * math.pi * (cx * xx + cy * yy) + phase).astype(np.float32)
        for ch in range(3):
            img[ch] += amp * comp["color"][ch] * wave
    # per-pixel gaussian noise via Box-Muller on the same stream
    n = 3 * h * w
    noise = np.empty(n, np.float32)
    i = 0
    while i < n:
        st, u1 = _u01(st)
        st, u2 = _u01(st)
        u1 = max(u1, 1e-12)
        r = math.sqrt(-2.0 * math.log(u1))
        noise[i] = r * math.cos(2.0 * math.pi * u2)
        if i + 1 < n:
            noise[i + 1] = r * math.sin(2.0 * math.pi * u2)
        i += 2
    img += NOISE_SIGMA * noise.reshape(3, h, w)
    # squash to [0,1]; 0.5 +- spread
    return np.clip(0.5 + img / (2.0 * N_COMPONENTS), 0.0, 1.0).astype(np.float32)


def gen_batch(dataset_seed: int, split: int, start: int, batch: int,
              classes: int, c: int, h: int, w: int):
    """Deterministic batch: sample ``i`` has class ``i % classes``."""
    assert c == 3
    xs = np.zeros((batch, 3, h, w), np.float32)
    ys = np.zeros((batch,), np.int32)
    for i in range(batch):
        idx = start + i
        cls = idx % classes
        xs[i] = gen_sample(dataset_seed, split, idx, cls, h, w)
        ys[i] = cls
    return xs, ys
