"""Training / evaluation step builders (L2).

Every function here returns a *pure* jax function suitable for one-shot
AOT lowering (aot.py); the rust coordinator then drives the lowered HLO
for the whole ODiMO pipeline:

    pretrain (FLOAT)  ->  search (SEARCH + lambda * L_R)  ->  discretize
    (rust, argmax alpha)  ->  fine-tune (DEPLOY, task loss only)  ->
    eval / deploy (DEPLOY)

Optimizer: SGD with momentum and decoupled weight decay on the weight
tensors; a separate learning rate drives the mapping logits alpha (the
usual DNAS two-rate scheme). All hyper-parameters (lr, lr_alpha, tau,
lambda, weight decay) are *runtime scalar inputs* so a single lowered
artifact serves the whole lambda sweep and any schedule.

Metric vector returned by every step (f32[6]):
    [ loss, correct_count, lat_cycles, energy_mWcycles, reg_term, tau ]
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from . import costmodel as CM
from . import layers as L
from .models import ModelDef


# ---------------------------------------------------------------------------
# pieces
# ---------------------------------------------------------------------------

def cross_entropy(logits: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=1))


def correct_count(logits: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))


def exp_channels_search(model: ModelDef, params, tau):
    """Expected per-accelerator channel mass from the current alphas:
    cout_i^(l) = sum_c softmax(alpha/tau)[i, c]  (continuous relaxation)."""
    out = {}
    for n in model.mappable():
        abar = jax.nn.softmax(params[n.name]["alpha"] / tau, axis=0)
        out[n.name] = (jnp.sum(abar[L.DIG]), jnp.sum(abar[L.AIMC]))
    return out


def exp_channels_assign(model: ModelDef, assign):
    """Exact per-accelerator channel counts from a hard assignment."""
    return {n.name: (jnp.sum(assign[n.name][L.DIG]), jnp.sum(assign[n.name][L.AIMC]))
            for n in model.mappable()}


def sgd_momentum(params, mom, grads, lr, lr_alpha, mu, wd):
    """One SGD+momentum step over the (nested dict) param tree.

    - weight decay (decoupled) on the conv/fc weight tensors only
    - ``lr_alpha`` for the mapping logits, ``lr`` for everything else
    - BN running stats (rm/rv) are not gradient-trained: they pass
      through untouched here and are assigned by the float step
    """
    new_p, new_m = {}, {}
    for node, leaves in params.items():
        new_p[node], new_m[node] = {}, {}
        for leaf, p in leaves.items():
            if leaf in ("rm", "rv"):
                new_p[node][leaf] = p
                new_m[node][leaf] = mom[node][leaf]
                continue
            g = grads[node][leaf]
            m = mu * mom[node][leaf] + g
            step_lr = lr_alpha if leaf == "alpha" else lr
            upd = p - step_lr * m
            if leaf == "w":
                upd = upd - step_lr * wd * p
            new_p[node][leaf] = upd
            new_m[node][leaf] = m
    return new_p, new_m


def zeros_like_tree(params):
    return jax.tree_util.tree_map(jnp.zeros_like, params)


def fold_params(model: ModelDef, params):
    """Fold BN into conv weights/biases and re-derive the quantizer
    scales from the folded weights — the float -> search transition
    (paper Sec. III-B). Mirrored in rust/src/coordinator/fold.rs; the
    python copy exists for unit tests and as the reference semantics.

    - w' = w * gamma / sqrt(rv + eps); b' = (b - rm) * (same) + beta
    - ls8/lster reset to log(max|w'|) per layer (fresh Eq.-5 range)
    - gamma/beta/rm/rv reset to identity so a second fold is a no-op
    - alpha biased toward digital (softmax([2,0]) ~ 88% int8) so the
      search starts from a functioning supernet (see rust fold.rs)
    """
    out = {k: dict(v) for k, v in params.items()}
    for n in model.param_nodes():
        p = out[n.name]
        if "lsa" in p:
            # post-BN ReLU activations live on a ~[0, 4] range (a few
            # sigma of the standardized pre-activation), not the [0, 1]
            # image range the init assumed
            p["lsa"] = jnp.asarray(float(jnp.log(4.0)), jnp.float32)
        if "alpha" in p:
            a = jnp.zeros_like(p["alpha"])
            p["alpha"] = a.at[0].set(2.0)  # digital-biased prior
        if "gamma" in p:
            inv = p["gamma"] / jnp.sqrt(p["rv"] + L.BN_EPS)
            shape = (-1,) + (1,) * (p["w"].ndim - 1)
            p["w"] = p["w"] * inv.reshape(shape)
            p["b"] = (p["b"] - p["rm"]) * inv + p["beta"]
            p["gamma"] = jnp.ones_like(p["gamma"])
            p["beta"] = jnp.zeros_like(p["beta"])
            p["rm"] = jnp.zeros_like(p["rm"])
            p["rv"] = jnp.ones_like(p["rv"])
        if "ls8" in p:
            # fresh Eq.-5 ranges for every quantized weight tensor —
            # including BN-less layers (fc), whose weights also drift
            # from the init-time range during pre-training
            wmax = jnp.maximum(jnp.max(jnp.abs(p["w"])), 1e-4)
            p["ls8"] = jnp.log(wmax)
            if "lster" in p:
                # ternary: a tighter range (~40% of max) keeps more
                # weights off zero, the usual ternarization heuristic
                p["lster"] = jnp.log(wmax * 0.4 + 1e-8)
    return out


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def make_train_step(model: ModelDef, meta: dict, mode: str,
                    reg: Optional[str] = None):
    """Build the train step for one phase.

    mode='float'               : pre-training, no quantization
    mode='search', reg='lat'   : Eq. 2 with the Eq.-3 latency regularizer
    mode='search', reg='en'    : Eq. 2 with the Eq.-4 energy regularizer
    mode='search', reg='prop'  : Fig.-5 abstract model (hw consts inputs)
    mode='deploy'              : fine-tuning with hard assignment inputs

    Signatures (flattened by jax in this arg order):
      float : (params, mom, x, y, lr, lr_alpha, mu, wd)
      search: (params, mom, x, y, lr, lr_alpha, mu, wd, lam, tau[, hw(6,)])
      deploy: (params, mom, assign, x, y, lr, lr_alpha, mu, wd)
    Returns (params', mom', metrics[6]).
    """
    lat0, en0 = CM.all_digital_reference(meta)

    if mode == L.FLOAT:
        def step(params, mom, x, y, lr, lr_alpha, mu, wd):
            def loss_fn(p):
                stats = {}
                logits = model.apply(p, x, mode=L.FLOAT, bn_stats=stats)
                return cross_entropy(logits, y), (logits, stats)
            (loss, (logits, stats)), grads = \
                jax.value_and_grad(loss_fn, has_aux=True)(params)
            params, mom = sgd_momentum(params, mom, grads, lr, lr_alpha, mu, wd)
            # BN running-statistic update (not gradient-driven)
            bnm = L.BN_MOMENTUM
            for name, (bmu, bvar) in stats.items():
                params[name]["rm"] = bnm * params[name]["rm"] + (1 - bnm) * bmu
                params[name]["rv"] = bnm * params[name]["rv"] + (1 - bnm) * bvar
            met = jnp.stack([loss, correct_count(logits, y),
                             jnp.asarray(0.0), jnp.asarray(0.0),
                             jnp.asarray(0.0), jnp.asarray(0.0)])
            return params, mom, met
        return step

    if mode == L.SEARCH:
        assert reg in ("lat", "en", "prop")

        def step(params, mom, x, y, lr, lr_alpha, mu, wd, lam, tau, hw=None):
            def loss_fn(p):
                logits = model.apply(p, x, mode=L.SEARCH, tau=tau)
                task = cross_entropy(logits, y)
                exp = exp_channels_search(model, p, tau)
                lat = CM.loss_latency_diana(meta, exp)
                en = CM.loss_energy_diana(meta, exp)
                if reg == "lat":
                    r = lat / lat0
                elif reg == "en":
                    r = en / en0
                else:
                    thpt, p_act, p_idle = hw[0:2], hw[2:4], hw[4:6]
                    e_prop = CM.loss_proportional(meta, exp, thpt, p_act, p_idle)
                    allc = {nm["name"]: (float(nm["cout"]), 0.0)
                            for nm in meta["nodes"] if nm.get("mappable")}
                    norm = jax.lax.stop_gradient(
                        CM.loss_proportional(meta, allc, thpt, p_act, p_idle))
                    r = e_prop / norm
                loss = task + lam * r
                return loss, (logits, lat, en, r)
            (loss, (logits, lat, en, r)), grads = \
                jax.value_and_grad(loss_fn, has_aux=True)(params)
            params, mom = sgd_momentum(params, mom, grads, lr, lr_alpha, mu, wd)
            met = jnp.stack([loss, correct_count(logits, y), lat, en, r, tau])
            return params, mom, met
        return step

    assert mode == L.DEPLOY

    def step(params, mom, assign, x, y, lr, lr_alpha, mu, wd):
        def loss_fn(p):
            logits = model.apply(p, x, mode=L.DEPLOY, assign=assign)
            return cross_entropy(logits, y), logits
        (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, mom = sgd_momentum(params, mom, grads, lr, lr_alpha, mu, wd)
        exp = exp_channels_assign(model, assign)
        lat = CM.loss_latency_diana(meta, exp)
        en = CM.loss_energy_diana(meta, exp)
        met = jnp.stack([loss, correct_count(logits, y), lat, en,
                         jnp.asarray(0.0), jnp.asarray(0.0)])
        return params, mom, met
    return step


def make_eval(model: ModelDef, mode: str):
    """Evaluation: (params[, assign], x, y) -> [correct_count, loss_sum]."""
    if mode == L.DEPLOY:
        def ev(params, assign, x, y):
            logits = model.apply(params, x, mode=L.DEPLOY, assign=assign)
            ls = cross_entropy(logits, y) * x.shape[0]
            return jnp.stack([correct_count(logits, y), ls])
        return ev

    def ev(params, x, y):
        logits = model.apply(params, x, mode=mode, tau=1.0)
        ls = cross_entropy(logits, y) * x.shape[0]
        return jnp.stack([correct_count(logits, y), ls])
    return ev


def make_infer(model: ModelDef):
    """Deploy-mode logits (rust cross-checks its integer reference conv
    and the partition pass against this graph)."""
    def infer(params, assign, x):
        return model.apply(params, x, mode=L.DEPLOY, assign=assign)
    return infer


# ---------------------------------------------------------------------------
# flat I/O naming (meta contract with rust)
# ---------------------------------------------------------------------------

def param_leaf_names(params) -> List[str]:
    """Flat leaf names 'node/leaf' in jax tree_flatten order (sorted dict
    keys at both levels) — the order of HLO parameters."""
    names = []
    for node in sorted(params.keys()):
        for leaf in sorted(params[node].keys()):
            names.append(f"{node}/{leaf}")
    return names


def assign_names(model: ModelDef) -> List[str]:
    """Assign inputs are a dict {mappable node -> (N, Cout)}; flat order
    is sorted node name (jax dict ordering)."""
    return sorted(n.name for n in model.mappable())
