"""ODiMO supernet layer primitives (L2).

Each mappable Conv/FC layer carries, besides its float weights:
  - ``ls8``, ``lster`` : trainable log-scales of the two weight formats
                         (digital int8, AIMC ternary) — Eq. 5's ``s``
  - ``lsa``            : trainable log-scale of the output activations
  - ``alpha``          : (N, Cout) mapping logits — Eq. 1

Three execution modes:
  FLOAT  — plain float network (pre-training phase)
  SEARCH — continuous relaxation: effective weights are the
           softmax(alpha)-blend of the N fake-quantized copies (Eq. 1),
           activations fake-quantized at the 7-bit worst case
  DEPLOY — hard mapping: a one-hot ``assign`` (N, Cout) input selects the
           format per channel; activations use the exact DIANA formats
           (8-bit storage, 7-bit AIMC D/A-A/D truncation on both the
           input the AIMC sub-layer reads and the channels it writes)

The forward value of the SEARCH blend comes from the fused Pallas kernel
(`kernels.mix`); DEPLOY uses two sub-convolutions (one per accelerator)
which is exactly what the partitioned hardware executes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.mix import mix_ste
from . import quantize as Q

FLOAT, SEARCH, DEPLOY = "float", "search", "deploy"

#: accelerator order everywhere in this codebase: [digital(int8), aimc(ternary)]
BITS = (8, 2)
N_ACC = 2
DIG, AIMC = 0, 1


def conv2d(x, w, stride: int, pad: int, groups: int = 1):
    """NCHW/OIHW convolution."""
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), [(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=groups)


def input_quant(x):
    """Fixed 8-bit quantization of the network input (images in [0,1])."""
    return jnp.round(x * 255.0) / 255.0


BN_EPS = 1e-5
BN_MOMENTUM = 0.9  # running-stat decay used by the float train step


def bn_train(p, y, stats_out, name):
    """BatchNorm with batch statistics (FLOAT pre-training only; the
    paper folds BN into conv/FC before quantization, Sec. III-B — the
    fold itself runs in rust/src/coordinator/fold.rs between phases).
    Records (mean, var) into ``stats_out`` for the running update."""
    mu = jnp.mean(y, axis=(0, 2, 3))
    var = jnp.var(y, axis=(0, 2, 3))
    stats_out[name] = (mu, var)
    yn = (y - mu.reshape(1, -1, 1, 1)) / jnp.sqrt(var.reshape(1, -1, 1, 1) + BN_EPS)
    return p["gamma"].reshape(1, -1, 1, 1) * yn + p["beta"].reshape(1, -1, 1, 1)


def bn_eval(p, y):
    """BatchNorm with running statistics (float evaluation)."""
    rm, rv = p["rm"], p["rv"]
    yn = (y - rm.reshape(1, -1, 1, 1)) / jnp.sqrt(rv.reshape(1, -1, 1, 1) + BN_EPS)
    return p["gamma"].reshape(1, -1, 1, 1) * yn + p["beta"].reshape(1, -1, 1, 1)


def _effective_weights_search(p, tau):
    """Eq. 1 via the fused Pallas kernel; returns same shape as p['w']."""
    w = p["w"]
    w2d = w.reshape(w.shape[0], -1)
    log_scales = jnp.stack([p["ls8"], p["lster"]])
    w_eff = mix_ste(w2d, p["alpha"], log_scales, jnp.asarray(tau, jnp.float32), BITS)
    return w_eff.reshape(w.shape)


def act_out(y, p, mode, assign, relu=True):
    """Output activation quantization for a mappable producer."""
    if relu:
        y = jax.nn.relu(y)
    if mode == FLOAT:
        return y
    if mode == SEARCH:
        return Q.fake_quant_act(y, p["lsa"], 7)
    return Q.fake_quant_act_mixed(y, p["lsa"], assign[AIMC])


def mconv_apply(p, x, *, stride, pad, mode, tau=1.0, assign=None, relu=True,
                name=None, bn_stats=None):
    """Mappable convolution (the ODiMO search unit).

    FLOAT mode applies BatchNorm: batch statistics when ``bn_stats`` is a
    dict to record into (training), running statistics otherwise (eval).
    Quantized modes assume BN was folded into (w, b) beforehand.
    """
    if mode == FLOAT:
        y = conv2d(x, p["w"], stride, pad) + p["b"].reshape(1, -1, 1, 1)
        y = bn_train(p, y, bn_stats, name) if bn_stats is not None else bn_eval(p, y)
        return act_out(y, p, mode, assign, relu)
    if mode == SEARCH:
        w_eff = _effective_weights_search(p, tau)
        y = conv2d(x, w_eff, stride, pad) + p["b"].reshape(1, -1, 1, 1)
        return act_out(y, p, mode, assign, relu)
    # DEPLOY: one sub-convolution per accelerator. The digital array reads
    # the 8-bit stored activations; the AIMC D/A truncates its input to
    # 7 bits. assign is a one-hot (N, Cout) float mask.
    w = p["w"]
    q8 = Q.fake_quant_weight(w, p["ls8"], 8)
    qt = Q.fake_quant_weight(w, p["lster"], 2)
    mask_d = assign[DIG].reshape(-1, 1, 1, 1)
    mask_a = assign[AIMC].reshape(-1, 1, 1, 1)
    x7 = jnp.round(jnp.clip(x, 0.0, 1.0) * 127.0) / 127.0  # AIMC 7-bit D/A read
    y = conv2d(x, q8 * mask_d, stride, pad) + conv2d(x7, qt * mask_a, stride, pad)
    y = y + p["b"].reshape(1, -1, 1, 1)
    return act_out(y, p, mode, assign, relu)


def dwconv_apply(p, x, *, stride, pad, mode, relu=True, name=None, bn_stats=None):
    """Depthwise convolution — digital-only on DIANA (not mappable)."""
    groups = x.shape[1]
    if mode == FLOAT:
        w = p["w"]
    else:
        w = Q.fake_quant_weight(p["w"], p["ls8"], 8)
    y = conv2d(x, w, stride, pad, groups=groups) + p["b"].reshape(1, -1, 1, 1)
    if mode == FLOAT:
        y = bn_train(p, y, bn_stats, name) if bn_stats is not None else bn_eval(p, y)
        return jax.nn.relu(y) if relu else y
    if relu:
        y = jax.nn.relu(y)
    n = 7 if mode == SEARCH else 8
    return Q.fake_quant_act(y, p["lsa"], n)


def fc_apply(p, x, *, mode, tau=1.0, assign=None):
    """Mappable fully-connected classifier head. Logits stay float."""
    if mode == FLOAT:
        return x @ p["w"].T + p["b"]
    if mode == SEARCH:
        log_scales = jnp.stack([p["ls8"], p["lster"]])
        w_eff = mix_ste(p["w"], p["alpha"], log_scales,
                        jnp.asarray(tau, jnp.float32), BITS)
        return x @ w_eff.T + p["b"]
    q8 = Q.fake_quant_weight(p["w"], p["ls8"], 8)
    qt = Q.fake_quant_weight(p["w"], p["lster"], 2)
    mask_d = assign[DIG].reshape(-1, 1)
    mask_a = assign[AIMC].reshape(-1, 1)
    x7 = jnp.round(jnp.clip(x, 0.0, 1.0) * 127.0) / 127.0
    return x @ (q8 * mask_d).T + x7 @ (qt * mask_a).T + p["b"]


def add_apply(p, a, b, *, mode, relu=True):
    """Residual join; re-quantizes the sum with its own activation scale."""
    y = a + b
    if relu:
        y = jax.nn.relu(y)
    if mode == FLOAT:
        return y
    n = 7 if mode == SEARCH else 8
    return Q.fake_quant_act(y, p["lsa"], n)


def gap_apply(x):
    """Global average pooling NCHW -> (N, C)."""
    return jnp.mean(x, axis=(2, 3))
