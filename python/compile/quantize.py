"""Quantization math for ODiMO (paper Eq. 5) + batch-norm folding.

All quantizers are *fake* quantizers: they map float -> float, where the
output is exactly representable on the target integer grid. Gradients pass
through the rounding with the straight-through estimator (STE), while the
trainable scale receives its true gradient through the multiplicative term.

Formats (DIANA, Sec. III-B of the paper):
  - weights, digital accelerator : symmetric int8  (n = 8)
  - weights, AIMC accelerator    : ternary         (n = 2 -> {-1, 0, +1})
  - activations, search phase    : unsigned 7-bit  (worst case of the two)
  - activations, deploy phase    : 8-bit storage, 7-bit AIMC I/O truncation
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ste_round(x: jnp.ndarray) -> jnp.ndarray:
    """round() with a straight-through gradient (identity backward)."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def fake_quant_weight(w: jnp.ndarray, log_scale: jnp.ndarray, n_bits: int) -> jnp.ndarray:
    """Paper Eq. 5 (following its reference [21], FQ-Conv, which normalizes
    by the scale before clipping):

        Q(w) = e^s / L * round(L * clip(w / e^s, -1, 1)),  L = 2^(n-1) - 1

    ``log_scale`` is the trainable ``s``; ``e^s`` keeps the scale positive.
    n_bits=2 gives ternarization (L=1, grid {-1,0,+1} * e^s), the AIMC
    format; n_bits=8 gives symmetric int8, the digital format.
    """
    levels = float(2 ** (n_bits - 1) - 1)
    scale = jnp.exp(log_scale)
    x = jnp.clip(w / scale, -1.0, 1.0)
    return scale / levels * ste_round(levels * x)


def quant_weight_int(w, log_scale, n_bits: int):
    """Integer codes of :func:`fake_quant_weight` (deploy path): returns
    (codes, scale/levels) with codes in [-L, L]."""
    levels = float(2 ** (n_bits - 1) - 1)
    scale = jnp.exp(log_scale)
    codes = jnp.round(levels * jnp.clip(w / scale, -1.0, 1.0))
    return codes, scale / levels


def fake_quant_act(x: jnp.ndarray, log_scale: jnp.ndarray, n_bits: int) -> jnp.ndarray:
    """Unsigned activation fake-quantization (post-ReLU tensors).

        Q(x) = e^s / L * round(L * clip(x / e^s, 0, 1)),  L = 2^n - 1

    The search phase uses n_bits=7, the worst case between the digital
    (8-bit) and AIMC (7-bit D/A-A/D) activation formats; the fine-tune /
    deploy phase quantizes per-channel with the exact format (see
    ``fake_quant_act_mixed``).
    """
    levels = float(2 ** n_bits - 1)
    scale = jnp.exp(log_scale)
    x = jnp.clip(x / scale, 0.0, 1.0)
    return scale / levels * ste_round(levels * x)


def fake_quant_act_mixed(x: jnp.ndarray, log_scale: jnp.ndarray,
                         aimc_mask: jnp.ndarray) -> jnp.ndarray:
    """Exact deployment activation format (paper Sec. III-B): shared data
    is stored on 8 bits but the AIMC D/A-A/D converters run on 7 bits,
    truncating the LSB of the channels the AIMC accelerator produces.

    ``aimc_mask`` is a float (C,) vector, 1.0 where the channel is mapped
    to the AIMC accelerator. x is NCHW; the mask broadcasts over channels.
    """
    q8 = fake_quant_act(x, log_scale, 8)
    q7 = fake_quant_act(x, log_scale, 7)
    m = aimc_mask.reshape((1, -1, 1, 1)) if x.ndim == 4 else aimc_mask.reshape((1, -1))
    return m * q7 + (1.0 - m) * q8


def fold_batchnorm(w, b, gamma, beta, mean, var, eps: float = 1e-5):
    """Fold a BatchNorm that follows a conv/FC into its weights/bias.

    DIANA's accelerators do not implement BN in hardware (paper
    Sec. III-B), so folding happens before fake-quantization. ``w`` is
    OIHW (or (Cout, Cin) for FC); BN params are per output channel.
    """
    inv_std = gamma / jnp.sqrt(var + eps)
    shape = (-1,) + (1,) * (w.ndim - 1)
    w_f = w * inv_std.reshape(shape)
    b_f = (b - mean) * inv_std + beta
    return w_f, b_f
