"""AOT lowering driver: jax -> HLO text artifacts + meta JSON (build time).

This is the single python entry point of the build (`make artifacts`).
For every benchmark model it lowers the full phase set:

    train_float        pre-training step
    train_search_lat   ODiMO search step, Eq.-3 latency regularizer
    train_search_en    ODiMO search step, Eq.-4 energy regularizer
    train_search_prop  ODiMO search step, Fig.-5 abstract hw (hw inputs)
    train_ft           fine-tuning step at exact precision (hard assign)
    eval_float / eval_search / eval_deploy
    infer_deploy       logits for rust-side numeric cross-checks

Interchange format is HLO *text* (not serialized HloModuleProto): the
image's xla_extension 0.5.1 rejects jax>=0.5's 64-bit instruction ids,
while the text parser reassigns ids (see /opt/xla-example/README.md).

The companion ``<model>_meta.json`` file is the contract with the rust
coordinator: flat parameter order, per-graph input/output signatures,
node/geometry table, hw calibration constants.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import costmodel as CM
from . import datagen
from . import layers as L
from . import models as M
from . import train as T


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _dtype_tag(dt) -> str:
    return {"float32": "f32", "int32": "s32"}[str(jnp.dtype(dt))]


def _sig(tree) -> list:
    """Flatten a pytree of ShapeDtypeStructs into [{shape, dtype}] in the
    same order jax flattens HLO parameters."""
    leaves = jax.tree_util.tree_leaves(tree)
    return [{"shape": list(l.shape), "dtype": _dtype_tag(l.dtype)} for l in leaves]


def _named_sig(names, tree) -> list:
    sig = _sig(tree)
    assert len(names) == len(sig), f"{len(names)} names vs {len(sig)} leaves"
    return [{"name": n, **s} for n, s in zip(names, sig)]


def build_artifacts(model_name: str, out_dir: str, graphs_filter=None) -> dict:
    model = M.build(model_name)
    meta_model = model.to_meta()
    key = jax.random.PRNGKey(42)
    params0 = model.init_params(key)
    pnames = T.param_leaf_names(params0)
    p_spec = jax.tree_util.tree_map(lambda a: _sds(a.shape, a.dtype), params0)
    c, h, w = model.input_shape
    bt, be = model.train_batch, model.eval_batch
    x_t, y_t = _sds((bt, c, h, w)), _sds((bt,), jnp.int32)
    x_e, y_e = _sds((be, c, h, w)), _sds((be,), jnp.int32)
    x_i = _sds((8, c, h, w))
    s = _sds(())
    assign_spec = {n.name: _sds((L.N_ACC, n.cout)) for n in model.mappable()}
    anames = T.assign_names(model)

    lat0, en0 = CM.all_digital_reference(meta_model)

    def names_params(prefix):
        return [f"{prefix}:{n}" for n in pnames]

    def names_assign():
        out = []
        for n in sorted(anames):
            out.append(f"assign:{n}")
        return out

    graph_defs = {}

    def add(name, fn, arg_spec, in_names, out_names, out_spec):
        graph_defs[name] = (fn, arg_spec, in_names, out_names, out_spec)

    scal4 = ["lr", "lr_alpha", "mu", "wd"]
    met_names = ["metrics"]
    met_spec = _sds((6,))

    add("train_float", T.make_train_step(model, meta_model, L.FLOAT),
        (p_spec, p_spec, x_t, y_t, s, s, s, s),
        names_params("param") + names_params("mom") + ["x", "y"] + scal4,
        names_params("param") + names_params("mom") + met_names,
        (p_spec, p_spec, met_spec))

    for reg in ("lat", "en"):
        add(f"train_search_{reg}", T.make_train_step(model, meta_model, L.SEARCH, reg),
            (p_spec, p_spec, x_t, y_t, s, s, s, s, s, s),
            names_params("param") + names_params("mom") + ["x", "y"] + scal4 + ["lam", "tau"],
            names_params("param") + names_params("mom") + met_names,
            (p_spec, p_spec, met_spec))

    add("train_search_prop", T.make_train_step(model, meta_model, L.SEARCH, "prop"),
        (p_spec, p_spec, x_t, y_t, s, s, s, s, s, s, _sds((6,))),
        names_params("param") + names_params("mom") + ["x", "y"] + scal4 + ["lam", "tau", "hw"],
        names_params("param") + names_params("mom") + met_names,
        (p_spec, p_spec, met_spec))

    add("train_ft", T.make_train_step(model, meta_model, L.DEPLOY),
        (p_spec, p_spec, assign_spec, x_t, y_t, s, s, s, s),
        names_params("param") + names_params("mom") + names_assign() + ["x", "y"] + scal4,
        names_params("param") + names_params("mom") + met_names,
        (p_spec, p_spec, met_spec))

    add("eval_float", T.make_eval(model, L.FLOAT), (p_spec, x_e, y_e),
        names_params("param") + ["x", "y"], ["stats"], _sds((2,)))
    add("eval_search", T.make_eval(model, L.SEARCH), (p_spec, x_e, y_e),
        names_params("param") + ["x", "y"], ["stats"], _sds((2,)))
    add("eval_deploy", T.make_eval(model, L.DEPLOY),
        (p_spec, assign_spec, x_e, y_e),
        names_params("param") + names_assign() + ["x", "y"], ["stats"], _sds((2,)))
    add("infer_deploy", T.make_infer(model), (p_spec, assign_spec, x_i),
        names_params("param") + names_assign() + ["x"], ["logits"],
        _sds((8, model.classes)))

    graphs_meta = {}
    for gname, (fn, arg_spec, in_names, out_names, out_spec) in graph_defs.items():
        if graphs_filter and gname not in graphs_filter:
            continue
        t0 = time.time()
        lowered = jax.jit(fn).lower(*arg_spec)
        text = to_hlo_text(lowered)
        fname = f"{model_name}_{gname}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        # jax prunes arguments the traced function never uses (e.g. quant
        # scales in float graphs); the rust driver must supply exactly the
        # kept ones, in order.
        all_inputs = _named_sig(in_names, arg_spec)
        kept = lowered._lowering.compile_args.get("kept_var_idx")
        if kept is None:
            kept_idx = list(range(len(all_inputs)))
        else:
            kept_idx = sorted(kept)
        graphs_meta[gname] = {
            "file": fname,
            "inputs": [all_inputs[i] for i in kept_idx],
            "outputs": _named_sig(out_names, out_spec),
        }
        print(f"  [{model_name}] {gname}: {len(text)/1e6:.2f} MB HLO "
              f"({time.time()-t0:.1f}s)")

    init_leaves = jax.tree_util.tree_leaves(params0)
    meta = {
        "model": meta_model,
        "params": [{"name": n, "shape": list(l.shape), "dtype": _dtype_tag(l.dtype)}
                   for n, l in zip(pnames, init_leaves)],
        "mappable": sorted(anames),
        "graphs": graphs_meta,
        "bits": list(L.BITS),
        "hw": {
            "p_act": list(CM.P_ACT), "p_idle": list(CM.P_IDLE),
            "f_clk_hz": CM.F_CLK_HZ, "aimc_rows": CM.AIMC_ROWS,
            "aimc_cols": CM.AIMC_COLS, "dig_pe": CM.DIG_PE,
            "smoothmax_beta": CM.SMOOTHMAX_BETA,
        },
        "norm": {"lat0": lat0, "en0": en0},
        "datagen_algo_version": datagen.ALGO_VERSION,
        "init_seed": 42,
    }
    with open(os.path.join(out_dir, f"{model_name}_meta.json"), "w") as f:
        json.dump(meta, f, indent=1)

    # initial parameter values, as a flat little-endian f32 blob per leaf
    # (rust seeds training from these — keeps init bit-identical between
    # python tests and the rust pipeline)
    import numpy as np
    with open(os.path.join(out_dir, f"{model_name}_init.bin"), "wb") as f:
        for leaf in init_leaves:
            f.write(np.asarray(leaf, np.float32).tobytes())
    return meta


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="tinycnn,resnet20,resnet18s,mbv1_025")
    ap.add_argument("--graphs", default="", help="comma filter, empty = all")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    gf = set(args.graphs.split(",")) - {""} or None
    for mn in args.models.split(","):
        print(f"lowering {mn} ...")
        build_artifacts(mn, args.out, gf)
    print("artifacts complete")


if __name__ == "__main__":
    main()
