"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here, written
with plain jax.numpy ops only. pytest (python/tests/) sweeps shapes and
dtypes with hypothesis and asserts allclose between kernel and oracle;
this file is the single source of truth for kernel semantics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fake_quant_ref(w: jnp.ndarray, scale: jnp.ndarray, n_bits: int) -> jnp.ndarray:
    """Symmetric fake-quantization, Eq. 5 with pre-normalization.

    ``scale`` is e^s (already exponentiated); broadcastable to w.
    """
    levels = float(2 ** (n_bits - 1) - 1)
    x = jnp.clip(w / scale, -1.0, 1.0)
    return scale / levels * jnp.round(levels * x)


def mix_ref(w: jnp.ndarray, alpha: jnp.ndarray, scales: jnp.ndarray,
            bits: tuple, tau: float = 1.0) -> jnp.ndarray:
    """ODiMO effective weights, Eq. 1.

    w      : (Cout, K) layer weights flattened over (Cin*fy*fx)
    alpha  : (N, Cout) trainable mapping logits
    scales : (N,)      e^s per accelerator format
    bits   : static tuple of N bit-widths, e.g. (8, 2)

    Returns (Cout, K):  W_eff[c] = sum_i softmax(alpha/tau)[i,c] * Q_i(w[c])
    """
    abar = jax.nn.softmax(alpha / tau, axis=0)  # (N, Cout)
    out = jnp.zeros_like(w)
    for i, n in enumerate(bits):
        q = fake_quant_ref(w, scales[i], n)
        out = out + abar[i][:, None] * q
    return out


def qmatmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Integer-domain matmul oracle: a (M, K) x b (K, N) -> (M, N).

    Inputs hold integer *codes* stored as f32 (the interchange dtype the
    CPU PJRT path supports everywhere); accumulation is exact in f32 as
    long as |codes| and K stay within the f32 24-bit mantissa budget,
    which the DIANA formats (<= 8-bit codes) respect for every layer in
    the benchmark models.
    """
    return a @ b


def softmax_tau_ref(alpha: jnp.ndarray, tau: float) -> jnp.ndarray:
    """Temperature softmax over axis 0 (the accelerator axis)."""
    return jax.nn.softmax(alpha / tau, axis=0)
