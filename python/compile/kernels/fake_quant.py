"""Pallas kernel: symmetric fake-quantization (paper Eq. 5).

The kernel quantizes a (R, K) tile-at-a-time, keeping each tile resident
in VMEM. On a real TPU this is bandwidth-bound; the BlockSpec below reads
each element of ``w`` exactly once from HBM and writes the quantized copy
once, so the kernel runs at streaming roofline. interpret=True is
mandatory on this CPU-PJRT image (real lowering emits a Mosaic
custom-call the CPU plugin cannot execute).

Gradient note: the kernel is used inside ``ste_wrap`` (below) which
attaches the straight-through estimator, matching
``quantize.fake_quant_weight``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per VMEM tile. 8x128 is the fp32 TPU vreg tile; 256 rows x K<=4608
# cols of f32 is <= 4.7 MB, comfortably inside a 16 MB VMEM budget
# together with the output tile.
_BLOCK_R = 256


def _fq_kernel(w_ref, scale_ref, o_ref, *, levels: float):
    """One (BLOCK_R, K) tile: o = s/L * round(L * clip(w/s, -1, 1))."""
    s = scale_ref[0]
    x = w_ref[...] / s
    x = jnp.clip(x, -1.0, 1.0)
    o_ref[...] = s / levels * jnp.round(levels * x)


def fake_quant_pallas(w: jnp.ndarray, scale: jnp.ndarray, n_bits: int) -> jnp.ndarray:
    """Fake-quantize ``w`` (R, K) with per-tensor scale e^s (shape (1,)).

    Matches :func:`ref.fake_quant_ref` exactly (same op order).
    """
    r, k = w.shape
    levels = float(2 ** (n_bits - 1) - 1)
    br = min(_BLOCK_R, r)
    grid = (pl.cdiv(r, br),)
    return pl.pallas_call(
        functools.partial(_fq_kernel, levels=levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, k), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, k), w.dtype),
        interpret=True,
    )(w, scale)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def fake_quant_ste(w, log_scale, n_bits):
    """STE fake-quant: forward = pallas kernel, backward (below) =
    straight-through for w (clip mask) and the LSQ quantization-residual
    gradient for the trainable log-scale."""
    scale = jnp.exp(log_scale).reshape((1,))
    return fake_quant_pallas(w, scale, n_bits)


def _fq_fwd(w, log_scale, n_bits):
    out = fake_quant_ste(w, log_scale, n_bits)
    return out, (w, jnp.exp(log_scale), out)


def _fq_bwd(n_bits, res, g):
    w, s, q = res
    mask = (jnp.abs(w / s) <= 1.0).astype(w.dtype)
    d_w = mask * g
    # LSQ gradient normalization (see kernels/mix.py::_mix_bwd)
    levels = float(2 ** (n_bits - 1) - 1)
    d_ls = jnp.sum(g * (q - mask * w)) / jnp.sqrt(float(w.size) * levels)
    return d_w, d_ls


fake_quant_ste.defvjp(_fq_fwd, _fq_bwd)
