"""Pallas kernel: fused ODiMO effective-weight computation (paper Eq. 1).

This is the supernet's training-time hot spot: for every layer and every
optimizer step, each output channel's weights must be fake-quantized once
per accelerator format and blended with the channel's softmax(alpha)
coefficients:

    W_eff[c, :] = sum_i softmax(alpha[:, c] / tau)[i] * Q_{bits_i}(W[c, :])

A naive implementation materializes N quantized copies of the weight
tensor in HBM (N+1 reads + N writes per element). The fused kernel below
streams each (BLOCK_C, K) weight tile through VMEM exactly once, computes
all N quantizations and the softmax in registers/VMEM, and writes one
output tile: 1 read + 1 write per element, independent of N — on a real
TPU this puts the op at streaming roofline (it has no MXU work at all).

interpret=True is mandatory on this CPU-PJRT image.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Channels per tile. alpha is tiny ((N, BLOCK_C)); the weight tile
# dominates VMEM: 128 x K. For the largest benchmark layer (K = 4608,
# f32) that is 2.4 MB in + 2.4 MB out, well within budget and large
# enough to amortize grid overhead.
_BLOCK_C = 128


def _mix_kernel(w_ref, alpha_ref, scales_ref, tau_ref, o_ref, *, bits):
    """One (BLOCK_C, K) tile of W plus the matching (N, BLOCK_C) alphas."""
    w = w_ref[...]                                   # (BC, K)
    a = alpha_ref[...] / tau_ref[0]                  # (N, BC)
    # temperature softmax over the accelerator axis, numerically stable
    a = a - jnp.max(a, axis=0, keepdims=True)
    e = jnp.exp(a)
    abar = e / jnp.sum(e, axis=0, keepdims=True)     # (N, BC)
    acc = jnp.zeros_like(w)
    for i, n in enumerate(bits):                     # static unroll over N
        levels = float(2 ** (n - 1) - 1)
        s = scales_ref[i]
        q = s / levels * jnp.round(levels * jnp.clip(w / s, -1.0, 1.0))
        acc = acc + abar[i][:, None] * q
    o_ref[...] = acc


def mix_pallas(w: jnp.ndarray, alpha: jnp.ndarray, scales: jnp.ndarray,
               bits: tuple, tau: float = 1.0) -> jnp.ndarray:
    """Fused Eq.-1 effective weights.

    w      : (Cout, K) float32
    alpha  : (N, Cout) mapping logits
    scales : (N,)      e^s per format (already exponentiated)
    bits   : static tuple of N bit-widths, e.g. (8, 2)

    Matches :func:`ref.mix_ref` to f32 round-off.
    """
    c, k = w.shape
    n = alpha.shape[0]
    assert len(bits) == n and scales.shape == (n,)
    bc = min(_BLOCK_C, c)
    grid = (pl.cdiv(c, bc),)
    tau_arr = jnp.asarray(tau, jnp.float32).reshape((1,))
    return pl.pallas_call(
        functools.partial(_mix_kernel, bits=tuple(bits)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, k), lambda i: (i, 0)),
            pl.BlockSpec((n, bc), lambda i: (0, i)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bc, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, k), w.dtype),
        interpret=True,
    )(w, alpha, scales, tau_arr)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def mix_ste(w, alpha, log_scales, tau, bits):
    """Differentiable Eq.-1 effective weights (the supernet hot path).

    Forward: the fused Pallas kernel. Backward (defined below): the
    standard DNAS/ODiMO gradients —

      dL/dalpha : *exact*, through the softmax, against the HARD
                  quantized copies Q_i(w). This is the signal that tells
                  a channel "ternary hurts you"; a naive STE surrogate
                  (differentiating the round-free blend) cancels the
                  inter-format difference and kills the mapping search.
      dL/dw     : straight-through — sum_i abar_i * 1[|w/s_i| <= 1]
      dL/ds_i   : LSQ-style — abar_i * (Q_i - 1[in-range] * w) / (w grad
                  path), i.e. the quantization residual
      dL/dtau   : 0 (tau is a schedule input, never trained)
    """
    scales = jnp.exp(log_scales)
    return mix_pallas(w, alpha, scales, bits, tau)


def _mix_fwd(w, alpha, log_scales, tau, bits):
    scales = jnp.exp(log_scales)
    out = mix_pallas(w, alpha, scales, bits, tau)
    return out, (w, alpha, scales, tau)


def _mix_bwd(bits, res, g):
    w, alpha, scales, tau = res
    abar = jax.nn.softmax(alpha / tau, axis=0)          # (N, C)
    n_acc = alpha.shape[0]
    qs, masks = [], []
    for i, n in enumerate(bits):
        levels = float(2 ** (n - 1) - 1)
        s = scales[i]
        q = s / levels * jnp.round(levels * jnp.clip(w / s, -1.0, 1.0))
        qs.append(q)
        masks.append((jnp.abs(w / s) <= 1.0).astype(w.dtype))
    # d/d abar[i, c] = sum_k g[c, k] * Q_i[c, k]
    d_abar = jnp.stack([jnp.sum(g * q, axis=1) for q in qs])    # (N, C)
    # softmax backward (per channel), then / tau
    inner = d_abar - jnp.sum(d_abar * abar, axis=0, keepdims=True)
    d_alpha = abar * inner / tau
    # straight-through to w
    d_w = jnp.zeros_like(w)
    for i in range(n_acc):
        d_w = d_w + abar[i][:, None] * masks[i] * g
    # LSQ residual to the log-scales: dQ/d log s = Q - mask * w, with the
    # LSQ gradient normalization 1/sqrt(numel * levels) — without it the
    # per-tensor scalar receives an O(numel)-magnitude sum and a single
    # SGD step destroys the quantization range (observed: loss 1.2 -> 40
    # on the first search step at lr 3e-3).
    numel = float(w.size)
    d_ls = jnp.stack([
        jnp.sum(g * abar[i][:, None] * (qs[i] - masks[i] * w))
        / jnp.sqrt(numel * float(2 ** (bits[i] - 1) - 1))
        for i in range(n_acc)
    ])
    return d_w, d_alpha, d_ls, jnp.zeros_like(tau)


mix_ste.defvjp(_mix_fwd, _mix_bwd)
