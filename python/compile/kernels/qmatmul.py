"""Pallas kernel: tiled integer-domain matmul (deployment-check path).

After discretization the two DIANA sub-layers compute in integer
arithmetic (int8 codes on the digital array, ternary codes on the AIMC
array). The rust simulator cross-checks its integer reference conv
against this kernel's output (lowered into the deploy-check HLO).

Codes are carried as f32 — exact up to 2^24, far above anything the
DIANA formats produce — because f32 is the one dtype the whole
CPU-PJRT interchange path supports uniformly.

TPU mapping (DESIGN.md §Hardware-Adaptation): the digital accelerator's
16x16 weight-stationary PE loop nest becomes a (BM, BK)x(BK, BN) MXU
tile schedule; BlockSpec expresses the HBM<->VMEM movement that DIANA
expresses with DMA bursts into its 64 kB weight memory. The k-loop is
the innermost grid axis, so each output tile accumulates in VMEM
scratch across k-steps (double-buffered by the pallas pipeline).

interpret=True is mandatory on this CPU-PJRT image.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

# MXU-shaped tiles: 128x128 output tile, 128-deep reduction slices.
_BM, _BK, _BN = 128, 128, 128


def _qmm_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    """Grid (i, j, k): accumulate a (BM, BK) x (BK, BN) product."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def qmatmul_pallas(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """a (M, K) @ b (K, N) with M,K,N padded internally to tile multiples.

    Matches :func:`ref.qmatmul_ref` exactly for integer-code inputs.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm, bk, bn = min(_BM, m), min(_BK, k), min(_BN, n)
    # pad to tile multiples; zeros contribute nothing to the accumulation
    mp, kp, np_ = pl.cdiv(m, bm) * bm, pl.cdiv(k, bk) * bk, pl.cdiv(n, bn) * bn
    a_p = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    b_p = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    k_steps = kp // bk
    grid = (mp // bm, np_ // bn, k_steps)
    out = pl.pallas_call(
        functools.partial(_qmm_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(a_p, b_p)
    return out[:m, :n]
