"""BN folding (the float -> search transition) — python reference
semantics, mirrored by rust/src/coordinator/fold.rs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import datagen
from compile import layers as L
from compile import models as M
from compile import train as T


@pytest.fixture(scope="module")
def trained():
    """A briefly trained tinycnn so BN stats are non-trivial."""
    model = M.build("tinycnn")
    meta = model.to_meta()
    params = model.init_params(jax.random.PRNGKey(0))
    mom = T.zeros_like_tree(params)
    step = jax.jit(T.make_train_step(model, meta, L.FLOAT))
    S = lambda v: jnp.asarray(v, jnp.float32)
    for i in range(40):
        xs, ys = datagen.gen_batch(7, 0, i * 32, 32, model.classes, 3, 16, 16)
        params, mom, _ = step(params, mom, jnp.asarray(xs), jnp.asarray(ys),
                              S(0.1), S(0.1), S(0.9), S(1e-4))
    return model, params


def test_fold_preserves_float_eval_function(trained):
    """Folded conv (BN identity) must compute the same function as the
    unfolded conv in *eval* mode (running stats)."""
    model, params = trained
    folded = T.fold_params(model, params)
    xs, _ = datagen.gen_batch(7, 1, 0, 8, model.classes, 3, 16, 16)
    x = jnp.asarray(xs)
    y0 = model.apply(params, x, mode=L.FLOAT)           # eval BN (running stats)
    y1 = model.apply(folded, x, mode=L.FLOAT)
    np.testing.assert_allclose(y0, y1, rtol=1e-4, atol=1e-4)


def test_fold_resets_bn_to_identity(trained):
    model, params = trained
    folded = T.fold_params(model, params)
    for n in model.param_nodes():
        p = folded[n.name]
        if "gamma" in p:
            np.testing.assert_array_equal(p["gamma"], np.ones_like(p["gamma"]))
            np.testing.assert_array_equal(p["rm"], np.zeros_like(p["rm"]))
            np.testing.assert_array_equal(p["rv"], np.ones_like(p["rv"]))


def test_fold_alpha_prior_is_digital(trained):
    """The post-fold mapping prior must favor the digital format so the
    search starts from a functioning supernet (see fold.rs)."""
    model, params = trained
    folded = T.fold_params(model, params)
    for n in model.mappable():
        a = np.asarray(folded[n.name]["alpha"])
        assert (a[0] > a[1]).all(), n.name
        abar = np.exp(a[0]) / (np.exp(a[0]) + np.exp(a[1]))
        assert abar.min() > 0.8


def test_fold_scales_cover_weights(trained):
    """e^ls8 must bound the folded weights (no clipping at init)."""
    model, params = trained
    folded = T.fold_params(model, params)
    for n in model.param_nodes():
        p = folded[n.name]
        if "ls8" not in p:
            continue
        wmax = float(jnp.abs(p["w"]).max())
        assert np.exp(float(p["ls8"])) >= wmax * 0.999
        if "lster" in p:
            assert float(p["lster"]) < float(p["ls8"])


def test_search_forward_works_after_fold(trained):
    """The folded params must produce a usable (finite, non-degenerate)
    SEARCH-mode forward — the state every lambda run starts from."""
    model, params = trained
    folded = T.fold_params(model, params)
    xs, ys = datagen.gen_batch(7, 1, 0, 64, model.classes, 3, 16, 16)
    logits = model.apply(folded, jnp.asarray(xs), mode=L.SEARCH, tau=1.0)
    assert np.isfinite(np.asarray(logits)).all()
    acc = float((jnp.argmax(logits, -1) == jnp.asarray(ys)).mean())
    # digital-biased prior => near-int8 behaviour => well above chance
    assert acc > 0.3, acc
