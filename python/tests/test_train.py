"""Training-step builders: the L2 functions that get AOT-lowered.

These run the *same* python functions that aot.py lowers, on tinycnn,
so a pass here plus an HLO-roundtrip pass on the rust side certifies
the full pipeline.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import datagen
from compile import layers as L
from compile import models as M
from compile import train as T


@pytest.fixture(scope="module")
def setup():
    model = M.build("tinycnn")
    meta = model.to_meta()
    params = model.init_params(jax.random.PRNGKey(0))
    mom = T.zeros_like_tree(params)
    c, h, w = model.input_shape
    xs, ys = datagen.gen_batch(1234, 0, 0, 32, model.classes, c, h, w)
    return model, meta, params, mom, jnp.asarray(xs), jnp.asarray(ys)


S = lambda v: jnp.asarray(v, jnp.float32)


def test_float_training_reduces_loss(setup):
    model, meta, params, mom, x, y = setup
    step = jax.jit(T.make_train_step(model, meta, L.FLOAT))
    losses = []
    for i in range(30):
        params, mom, met = step(params, mom, x, y, S(0.05), S(0.05), S(0.9), S(1e-4))
        losses.append(float(met[0]))
    assert losses[-1] < losses[0] * 0.7, losses[::10]


@pytest.mark.parametrize("reg", ["lat", "en"])
def test_search_step_moves_alpha(setup, reg):
    """With a strong lambda the regularizer must push channel mass toward
    the cheap (AIMC) accelerator."""
    model, meta, params, mom, x, y = setup
    step = jax.jit(T.make_train_step(model, meta, L.SEARCH, reg))
    p = jax.tree_util.tree_map(lambda a: a, params)
    for i in range(15):
        p, mom, met = step(p, mom, x, y, S(0.05), S(0.2), S(0.9), S(0.0),
                           S(2.0), S(1.0))
    # expected AIMC mass should have grown from the uniform 0.5
    masses = []
    for n in model.mappable():
        abar = jax.nn.softmax(p[n.name]["alpha"], axis=0)
        masses.append(float(abar[L.AIMC].mean()))
    assert np.mean(masses) > 0.55, masses
    assert np.isfinite(float(met[0]))


def test_search_metrics_report_costs(setup):
    model, meta, params, mom, x, y = setup
    step = jax.jit(T.make_train_step(model, meta, L.SEARCH, "lat"))
    _, _, met = step(params, mom, x, y, S(0.01), S(0.01), S(0.9), S(0.0),
                     S(0.1), S(1.0))
    loss, correct, lat, en, r, tau = [float(v) for v in met]
    assert lat > 0 and en > 0 and 0 < r < 2.5 and tau == 1.0
    assert 0 <= correct <= x.shape[0]


def test_prop_step_matches_idle_equals_act_equivalence(setup):
    """Fig.-5: with p_idle == p_act the prop regularizer equals the
    normalized latency objective up to scale; its gradient direction on
    alpha must match."""
    model, meta, params, mom, x, y = setup
    step = jax.jit(T.make_train_step(model, meta, L.SEARCH, "prop"))
    hw = jnp.asarray([1.0, 8.0, 2.0, 2.0, 2.0, 2.0])  # thpt, p_act, p_idle
    p, m2, met = step(params, mom, x, y, S(0.0), S(0.1), S(0.0), S(0.0),
                      S(5.0), S(1.0), hw)
    # lr=0 for weights, only alpha moves; AIMC (8x faster) should gain mass
    gained = []
    for n in model.mappable():
        abar = jax.nn.softmax(p[n.name]["alpha"], axis=0)
        gained.append(float(abar[L.AIMC].mean()))
    assert np.mean(gained) > 0.5


def test_ft_step_trains_under_fixed_assignment(setup):
    model, meta, params, mom, x, y = setup
    assign = {}
    rng = np.random.default_rng(0)
    for n in model.mappable():
        pick = rng.integers(0, 2, n.cout)
        a = np.zeros((L.N_ACC, n.cout), np.float32)
        a[pick, np.arange(n.cout)] = 1.0
        assign[n.name] = jnp.asarray(a)
    step = jax.jit(T.make_train_step(model, meta, L.DEPLOY))
    p, mom2, met0 = step(params, mom, assign, x, y, S(0.05), S(0.0), S(0.9), S(0.0))
    for i in range(25):
        p, mom2, met = step(p, mom2, assign, x, y, S(0.05), S(0.0), S(0.9), S(0.0))
    assert float(met[0]) < float(met0[0])
    # alpha must be untouched in deploy mode
    for n in model.mappable():
        np.testing.assert_array_equal(p[n.name]["alpha"], params[n.name]["alpha"])


def test_eval_and_infer_consistency(setup):
    model, meta, params, mom, x, y = setup
    assign = {n.name: jnp.asarray(
        np.eye(2, dtype=np.float32)[:, [0] * n.cout]) for n in model.mappable()}
    ev = jax.jit(T.make_eval(model, L.DEPLOY))
    stats = ev(params, assign, x, y)
    inf = jax.jit(T.make_infer(model))
    logits = inf(params, assign, x[:8])
    correct8 = float(jnp.sum((jnp.argmax(logits, -1) == y[:8])))
    assert stats.shape == (2,)
    assert 0 <= correct8 <= 8


def test_param_leaf_names_order(setup):
    """Leaf order must match jax's dict flattening (sorted keys) — the
    contract rust relies on."""
    model, meta, params, mom, x, y = setup
    names = T.param_leaf_names(params)
    leaves, _ = jax.tree_util.tree_flatten(params)
    assert len(names) == len(leaves)
    flat_with_path = jax.tree_util.tree_flatten_with_path(params)[0]
    for (path, leaf), nm in zip(flat_with_path, names):
        node = path[0].key
        lf = path[1].key
        assert f"{node}/{lf}" == nm
