"""Model DAGs: shape inference, parameters, forward modes, meta export."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers as L
from compile import models as M


ALL = ["tinycnn", "resnet20", "resnet18s", "mbv1_025"]


@pytest.fixture(scope="module")
def tiny():
    model = M.build("tinycnn")
    params = model.init_params(jax.random.PRNGKey(0))
    return model, params


def _x(model, b=2, seed=0):
    c, h, w = model.input_shape
    return jax.random.uniform(jax.random.PRNGKey(seed), (b, c, h, w))


def _onehot_assign(model, which=L.DIG):
    out = {}
    for n in model.mappable():
        a = np.zeros((L.N_ACC, n.cout), np.float32)
        a[which, :] = 1.0
        out[n.name] = jnp.asarray(a)
    return out


# ---------------------------------------------------------------------------
# structure
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ALL)
def test_shape_inference_consistent(name):
    m = M.build(name)
    for n in m.nodes:
        if n.op in ("conv", "dwconv"):
            ih, iw = n.in_hw
            oh = (ih + 2 * n.pad - n.k) // n.stride + 1
            assert n.out_hw == (oh, (iw + 2 * n.pad - n.k) // n.stride + 1)
        if n.op == "dwconv":
            assert n.cin == n.cout


@pytest.mark.parametrize("name", ALL)
def test_forward_shapes_all_modes(name):
    m = M.build(name)
    p = m.init_params(jax.random.PRNGKey(1))
    x = _x(m)
    for mode in (L.FLOAT, L.SEARCH):
        y = m.apply(p, x, mode=mode, tau=1.0)
        assert y.shape == (2, m.classes)
    y = m.apply(p, x, mode=L.DEPLOY, assign=_onehot_assign(m))
    assert y.shape == (2, m.classes)


def test_resnet20_layer_count():
    """ResNet20 = 1 stem + 18 block convs + 2 downsample convs + fc."""
    m = M.build("resnet20")
    convs = [n for n in m.nodes if n.op == "conv"]
    assert len(convs) == 21
    assert len(m.mappable()) == 22  # + fc


def test_mbv1_dw_not_mappable():
    m = M.build("mbv1_025")
    dw = [n for n in m.nodes if n.op == "dwconv"]
    assert len(dw) == 13
    assert all(n.op != "dwconv" for n in m.mappable())


@pytest.mark.parametrize("name", ALL)
def test_meta_roundtrip_fields(name):
    meta = M.build(name).to_meta()
    for nm in meta["nodes"]:
        assert nm["macs"] >= 0
        if nm["mappable"]:
            assert nm["cout"] > 0 and nm["cin"] > 0


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def test_deploy_all_digital_close_to_search_saturated(tiny):
    """Saturating alpha toward digital in SEARCH must approach the
    DEPLOY all-digital forward (difference only from 7- vs 8-bit acts)."""
    model, params = tiny
    x = _x(model, 4)
    p2 = {k: dict(v) for k, v in params.items()}
    for n in model.mappable():
        a = np.zeros((L.N_ACC, n.cout), np.float32)
        a[L.DIG] = 60.0
        a[L.AIMC] = -60.0
        p2[n.name]["alpha"] = jnp.asarray(a)
    y_search = model.apply(p2, x, mode=L.SEARCH, tau=1.0)
    y_deploy = model.apply(p2, x, mode=L.DEPLOY, assign=_onehot_assign(model, L.DIG))
    # logits before softmax: modest tolerance for the act-format gap
    np.testing.assert_allclose(y_search, y_deploy, atol=0.15)


def test_deploy_mapping_changes_output(tiny):
    """All-digital vs all-ternary deployment must differ (the ternary
    path loses information) — otherwise the search has nothing to do."""
    model, params = tiny
    x = _x(model, 4)
    yd = model.apply(params, x, mode=L.DEPLOY, assign=_onehot_assign(model, L.DIG))
    ya = model.apply(params, x, mode=L.DEPLOY, assign=_onehot_assign(model, L.AIMC))
    assert float(jnp.abs(yd - ya).max()) > 1e-3


def test_float_mode_has_no_quant_grid(tiny):
    model, params = tiny
    x = _x(model, 2)
    y = model.apply(params, x, mode=L.FLOAT)
    assert np.asarray(y).dtype == np.float32
    assert np.isfinite(np.asarray(y)).all()


def test_init_deterministic(tiny):
    model, _ = tiny
    p1 = model.init_params(jax.random.PRNGKey(7))
    p2 = model.init_params(jax.random.PRNGKey(7))
    for n in p1:
        for l in p1[n]:
            np.testing.assert_array_equal(p1[n][l], p2[n][l])
