"""Synthetic dataset generator: determinism, value ranges, learnability
signal (class structure present). rust mirrors the algorithm
(rust/src/data/synth.rs); test_prng_vectors pins the shared PRNG."""

import math

import numpy as np
import pytest

from compile import datagen as D


def test_splitmix64_known_vectors():
    """Pin the PRNG so the rust mirror (util/prng.rs) can assert the same
    sequence — seed 0 SplitMix64 reference outputs."""
    st = 0
    outs = []
    for _ in range(3):
        st, z = D.splitmix64(st)
        outs.append(z)
    assert outs == [0xE220A8397B1DCDAF, 0x6E789E6AA1B965F4, 0x06C45D188009454F]


def test_u01_range_and_determinism():
    st = 42
    vals = []
    for _ in range(100):
        st, u = D._u01(st)
        vals.append(u)
    assert all(0.0 <= v < 1.0 for v in vals)
    st2 = 42
    for v in vals[:10]:
        st2, u = D._u01(st2)
        assert u == v


def test_sample_deterministic_and_bounded():
    a = D.gen_sample(7, 0, 3, 1, 16, 16)
    b = D.gen_sample(7, 0, 3, 1, 16, 16)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (3, 16, 16)
    assert a.min() >= 0.0 and a.max() <= 1.0


def test_train_test_splits_differ():
    a = D.gen_sample(7, 0, 3, 1, 16, 16)
    b = D.gen_sample(7, 1, 3, 1, 16, 16)
    assert np.abs(a - b).max() > 1e-3


def test_classes_are_distinguishable():
    """Same-class samples must correlate more than cross-class samples
    (averaged over jitter/noise) — the signal the models learn."""
    def avg(cls, n=8):
        return np.mean([D.gen_sample(7, 0, i * 17 + cls, cls, 32, 32)
                        for i in range(n)], axis=0)
    m0, m1 = avg(0), avg(1)
    m0b = np.mean([D.gen_sample(7, 0, 1000 + i * 13, 0, 32, 32)
                   for i in range(8)], axis=0)
    d_same = np.abs(m0 - m0b).mean()
    d_diff = np.abs(m0 - m1).mean()
    assert d_diff > 2 * d_same, (d_same, d_diff)


def test_gen_batch_labels():
    xs, ys = D.gen_batch(1, 0, 10, 20, 10, 3, 8, 8)
    assert xs.shape == (20, 3, 8, 8) and ys.shape == (20,)
    np.testing.assert_array_equal(ys, (np.arange(10, 30) % 10).astype(np.int32))
