"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles (ref.py).

hypothesis sweeps shapes (including non-multiples of the block sizes),
scales and temperatures; assert_allclose against ref.py is THE
correctness signal for the kernels that end up inside every lowered
artifact.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fake_quant import fake_quant_pallas, fake_quant_ste
from compile.kernels.mix import mix_pallas, mix_ste
from compile.kernels.qmatmul import qmatmul_pallas

SHAPES = st.tuples(st.integers(1, 300), st.integers(1, 80))


def _w(shape, seed=0, scale=0.5):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


# ---------------------------------------------------------------------------
# fake_quant
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(shape=SHAPES, n_bits=st.sampled_from([2, 4, 8]),
       scale=st.floats(0.05, 4.0))
def test_fake_quant_matches_ref(shape, n_bits, scale):
    w = _w(shape)
    s = jnp.asarray([scale], jnp.float32)
    got = fake_quant_pallas(w, s, n_bits)
    want = ref.fake_quant_ref(w, s[0], n_bits)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


def test_fake_quant_output_on_grid():
    """Quantized values must lie on the integer grid scale/L * {-L..L}."""
    w = _w((64, 32), seed=3, scale=2.0)
    s = jnp.asarray([0.7], jnp.float32)
    for n in (2, 8):
        lv = 2 ** (n - 1) - 1
        q = np.asarray(fake_quant_pallas(w, s, n))
        codes = q * lv / 0.7
        np.testing.assert_allclose(codes, np.round(codes), atol=1e-4)
        assert np.abs(codes).max() <= lv + 1e-4


def test_ternary_is_three_valued():
    w = _w((40, 10), seed=1, scale=1.0)
    q = np.asarray(fake_quant_pallas(w, jnp.asarray([0.5]), 2))
    vals = np.unique(np.round(q / 0.5, 6))
    assert set(vals).issubset({-1.0, 0.0, 1.0})


def test_fake_quant_ste_gradients():
    """d/dw is the clip mask; values outside +-e^s get zero gradient."""
    w = jnp.asarray([[-3.0, -0.2, 0.0, 0.2, 3.0]])
    ls = jnp.asarray(0.0)  # e^s = 1
    g = jax.grad(lambda w: fake_quant_ste(w, ls, 8).sum())(w)
    np.testing.assert_allclose(np.asarray(g)[0], [0, 1, 1, 1, 0], atol=1e-6)


# ---------------------------------------------------------------------------
# mix (effective weights, Eq. 1)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(shape=SHAPES, tau=st.floats(0.2, 5.0), seed=st.integers(0, 10))
def test_mix_matches_ref(shape, tau, seed):
    w = _w(shape, seed)
    alpha = _w((2, shape[0]), seed + 100, 1.0)
    scales = jnp.asarray([0.5, 0.9], jnp.float32)
    got = mix_pallas(w, alpha, scales, (8, 2), tau)
    want = ref.mix_ref(w, alpha, scales, (8, 2), tau)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_mix_onehot_alpha_selects_single_format():
    """With saturated alpha the blend equals the single-format quant."""
    w = _w((16, 9), 2)
    scales = jnp.asarray([0.5, 0.5])
    big = 50.0
    alpha_dig = jnp.stack([jnp.full((16,), big), jnp.full((16,), -big)])
    got = mix_pallas(w, alpha_dig, scales, (8, 2), 1.0)
    want = ref.fake_quant_ref(w, scales[0], 8)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_mix_uniform_alpha_is_mean():
    w = _w((8, 4), 5)
    scales = jnp.asarray([0.4, 0.8])
    alpha = jnp.zeros((2, 8))
    got = mix_pallas(w, alpha, scales, (8, 2), 1.0)
    want = 0.5 * (ref.fake_quant_ref(w, scales[0], 8)
                  + ref.fake_quant_ref(w, scales[1], 2))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_mix_ste_alpha_gradient_direction():
    """Pushing alpha toward the format with smaller quant error must
    reduce ||W_eff - W||^2: gradient wrt alpha is nonzero and finite."""
    w = _w((12, 16), 7)
    alpha = jnp.zeros((2, 12))
    ls = jnp.log(jnp.asarray([0.5, 0.5]))

    def loss(alpha):
        eff = mix_ste(w, alpha, ls, jnp.asarray(1.0), (8, 2))
        return jnp.sum((eff - w) ** 2)

    g = jax.grad(loss)(alpha)
    assert np.all(np.isfinite(np.asarray(g)))
    # int8 approximates w better than ternary -> gradient must favor
    # increasing alpha[0] (digital) i.e. d loss / d alpha[0] < 0
    assert np.asarray(g)[0].mean() < 0 < np.asarray(g)[1].mean()


# ---------------------------------------------------------------------------
# qmatmul
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 200), k=st.integers(1, 200), n=st.integers(1, 150),
       seed=st.integers(0, 5))
def test_qmatmul_matches_ref(m, k, n, seed):
    ka, kb = jax.random.split(jax.random.PRNGKey(seed))
    a = jnp.round(jax.random.normal(ka, (m, k)) * 40)
    b = jnp.round(jax.random.normal(kb, (k, n)) * 1.2)
    got = qmatmul_pallas(a, b)
    want = ref.qmatmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_qmatmul_exact_at_diana_extremes():
    """int8 codes x ternary codes at the largest benchmark K stays exact."""
    k = 64 * 9  # largest resnet20 K
    a = jnp.asarray(np.random.default_rng(0).integers(-127, 128, (16, k)), jnp.float32)
    b = jnp.asarray(np.random.default_rng(1).integers(-1, 2, (k, 32)), jnp.float32)
    got = np.asarray(qmatmul_pallas(a, b))
    want = np.asarray(a, np.int64) @ np.asarray(b, np.int64)
    np.testing.assert_array_equal(got, want.astype(np.float32))
