"""Differentiable hardware cost models (Eq. 3/4/6/7) — unit tests.

The same formulas are mirrored in rust/src/hw/latency.rs; the fixture
vectors asserted here are re-asserted there (tests/model_parity.rs), so
any drift between L2's loss and L3's simulator fails both suites.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import costmodel as CM
from compile import models as M


def _nm(cin=16, cout=32, k=3, oh=16, ow=16):
    return {"name": "l", "op": "conv", "cin": cin, "cout": cout, "k": k,
            "out_hw": [oh, ow], "macs": cin * k * k * cout * oh * ow,
            "mappable": True}


# ---------------------------------------------------------------------------
# Eq. 6 / Eq. 7 values
# ---------------------------------------------------------------------------

def test_lat_dig_paper_formula():
    """Hand-computed Eq. 7 example."""
    # cin=16, f=3, o=16x16, cout=32
    want = math.ceil(32 / 16) * math.ceil(16 / 16) * 16 * 16 * 3 * 3 + 16 * 32 * 3 * 3
    got = float(CM.lat_dig(16, 3, 3, 16, 16, 32.0))
    assert got == want == CM.lat_dig_static(16, 3, 3, 16, 16, 32)


def test_lat_aimc_paper_formula():
    want = (math.ceil(16 * 9 / 1152) * math.ceil(32 / 512) * 16 * 16
            + 2 * 4 * 16 * math.ceil(32 / 512))
    got = float(CM.lat_aimc(16, 3, 3, 16, 16, 32.0))
    assert got == want == CM.lat_aimc_static(16, 3, 3, 16, 16, 32)


def test_zero_channels_zero_latency():
    """cout=0 means the accelerator is not used: Eq. 6/7 must vanish so
    discretized all-digital mappings pay nothing on the AIMC side."""
    assert float(CM.lat_aimc(64, 3, 3, 8, 8, 0.0)) == 0.0
    assert float(CM.lat_dig(64, 3, 3, 8, 8, 0.0)) == 0.0


@settings(max_examples=30, deadline=None)
@given(cin=st.integers(1, 512), k=st.sampled_from([1, 3, 7]),
       o=st.integers(1, 64), cout=st.integers(0, 512))
def test_static_and_traced_agree(cin, k, o, cout):
    a1 = float(CM.lat_aimc(cin, k, k, o, o, float(cout)))
    a2 = CM.lat_aimc_static(cin, k, k, o, o, cout)
    d1 = float(CM.lat_dig(cin, k, k, o, o, float(cout)))
    d2 = CM.lat_dig_static(cin, k, k, o, o, cout)
    assert a1 == pytest.approx(a2) and d1 == pytest.approx(d2)


@settings(max_examples=20, deadline=None)
@given(cout=st.integers(1, 511))
def test_latency_monotone_in_channels(cout):
    """More channels on an accelerator can never be faster."""
    base_a = CM.lat_aimc_static(64, 3, 3, 16, 16, cout)
    base_d = CM.lat_dig_static(64, 3, 3, 16, 16, cout)
    assert CM.lat_aimc_static(64, 3, 3, 16, 16, cout + 1) >= base_a
    assert CM.lat_dig_static(64, 3, 3, 16, 16, cout + 1) >= base_d


def test_aimc_much_faster_at_full_width():
    """The AIMC macro's parallelism must dominate the 16x16 digital array
    for a full layer — this asymmetry is what ODiMO exploits."""
    d = CM.lat_dig_static(64, 3, 3, 16, 16, 64)
    a = CM.lat_aimc_static(64, 3, 3, 16, 16, 64)
    assert a < d / 5


# ---------------------------------------------------------------------------
# smooth max / ceil STE
# ---------------------------------------------------------------------------

def test_smooth_max_upper_bounds_max():
    xs = [jnp.asarray(10.0), jnp.asarray(250.0)]
    sm = float(CM.smooth_max(xs, 250.0))
    assert sm >= 250.0
    assert sm <= 250.0 * (1 + math.log(2) / CM.SMOOTHMAX_BETA) + 1e-3


def test_smooth_max_gradient_flows_to_both():
    def f(a, b):
        return CM.smooth_max([a, b], 100.0)
    ga = jax.grad(f, argnums=(0, 1))(jnp.asarray(90.0), jnp.asarray(100.0))
    assert all(float(g) > 0 for g in ga)
    assert float(ga[1]) > float(ga[0])  # larger input gets larger share


def test_ceil_ste_value_and_grad():
    x = jnp.asarray(3.2)
    assert float(CM.ceil_ste(x)) == 4.0
    assert float(jax.grad(lambda v: CM.ceil_ste(v))(x)) == 1.0


# ---------------------------------------------------------------------------
# loss terms
# ---------------------------------------------------------------------------

def _meta():
    return M.build("tinycnn").to_meta()


def test_energy_latency_equivalence_when_no_shutdown():
    """Paper Fig.-5 observation: with P_idle == P_act, Eq. 4 reduces to
    Eq. 3 times total power (up to a constant)."""
    meta = _meta()
    exp = {nm["name"]: (0.5 * nm["cout"], 0.5 * nm["cout"])
           for nm in meta["nodes"] if nm.get("mappable")}
    thpt = jnp.asarray([1.0, 10.0])
    p = jnp.asarray([2.0, 5.0])
    e_no_shutdown = float(CM.loss_proportional(meta, exp, thpt, p, p))
    # manual: sum over layers of (p0+p1) * smooth_max(ld, la)
    want = 0.0
    for nm in meta["nodes"]:
        if nm.get("mappable"):
            cd, ca = exp[nm["name"]]
            macs_per_ch = nm["macs"] / nm["cout"]
            ld, la = macs_per_ch * cd / 1.0, macs_per_ch * ca / 10.0
            m = float(CM.smooth_max([jnp.asarray(ld), jnp.asarray(la)],
                                    float(max(nm["macs"], 1))))
            want += float((p[0] + p[1])) * m
    assert e_no_shutdown == pytest.approx(want, rel=1e-5)


def test_all_digital_reference_matches_loss():
    """The python normalizer must equal the traced latency loss evaluated
    at the all-digital assignment (up to smooth-max slack)."""
    meta = _meta()
    lat0, en0 = CM.all_digital_reference(meta)
    exp = {nm["name"]: (float(nm["cout"]), 0.0)
           for nm in meta["nodes"] if nm.get("mappable")}
    lat_traced = float(CM.loss_latency_diana(meta, exp))
    # smooth max >= hard max, within the logsumexp slack
    assert lat_traced >= lat0 * 0.999
    assert lat_traced <= lat0 * 1.15


def test_energy_decreases_when_work_moves_to_aimc():
    """Moving channels to the (faster) AIMC accelerator must reduce the
    modeled energy for a large layer — the basic effect behind Fig. 4."""
    meta = _meta()

    def en(frac_aimc):
        exp = {nm["name"]: ((1 - frac_aimc) * nm["cout"], frac_aimc * nm["cout"])
               for nm in meta["nodes"] if nm.get("mappable")}
        return float(CM.loss_energy_diana(meta, exp))

    assert en(0.9) < en(0.5) < en(0.1)


def test_latency_gradient_pushes_toward_balance():
    """At an all-digital point the latency gradient wrt AIMC channel mass
    must be flat-or-negative (moving work off the bottleneck helps)."""
    meta = _meta()
    names = [nm["name"] for nm in meta["nodes"] if nm.get("mappable")]
    couts = {nm["name"]: nm["cout"] for nm in meta["nodes"] if nm.get("mappable")}

    def lat(frac):
        exp = {n: ((1 - frac) * couts[n], frac * couts[n]) for n in names}
        return CM.loss_latency_diana(meta, exp)

    g = float(jax.grad(lat)(jnp.asarray(0.0)))
    assert g < 0
