//! Lambda-sweep example: the programmatic version of `odimo fig4` on
//! tinycnn — sweeps the regularization strength, prints the resulting
//! accuracy/energy frontier with baselines, and shows Pareto extraction
//! through the public API.
//!
//!     cargo run --release --example pareto_sweep

use odimo::coordinator::{Pipeline, Regularizer, Schedule};
use odimo::metrics::{ascii_scatter, pareto_front, table_markdown};
use odimo::runtime::{ArtifactMeta, Runtime};

fn main() -> anyhow::Result<()> {
    odimo::util::logging::init();
    let rt = Runtime::cpu()?;
    let meta = ArtifactMeta::load(std::path::Path::new("artifacts"), "tinycnn")?;
    let pipe = Pipeline::new(&rt, &meta, Schedule::smoke());
    let folded = pipe.pretrained_folded()?;

    let mut points = pipe.sweep(&folded, &Regularizer::EnergyDiana, &[0.05, 0.3, 1.0, 3.0])?;
    for b in ["all_8bit", "all_ternary", "min_cost_en"] {
        match pipe.baseline_point(&folded, b) {
            Ok(p) => points.push(p),
            Err(e) => eprintln!("baseline {b} failed: {e:#}"),
        }
    }

    println!("{}", table_markdown("tinycnn accuracy vs energy", &points));
    let front = pareto_front(&points, |p| p.energy_uj);
    println!(
        "Pareto front: {}",
        front
            .iter()
            .map(|&i| points[i].label.as_str())
            .collect::<Vec<_>>()
            .join(" -> ")
    );
    println!("{}", ascii_scatter(&points, |p| p.energy_uj, 64, 14));
    Ok(())
}
