//! Deployment example: the post-training path in isolation.
//!
//! Takes a (random, for demo purposes) channel mapping for ResNet20,
//! runs the Fig.-3 partition pass (channel reordering + consumer
//! fixups), verifies function preservation through the AOT
//! `infer_deploy` graph, and costs the partitioned network on the DIANA
//! simulator with the per-layer utilization timeline (Fig.-6 style).
//!
//!     cargo run --release --example deploy_diana

use anyhow::anyhow;
use odimo::coordinator::partition::partition;
use odimo::coordinator::scheduler::deploy;
use odimo::coordinator::Mapping;
use odimo::data::DataSource;
use odimo::hw::soc::SocConfig;
use odimo::model::{AIMC, DIG};
use odimo::runtime::{assemble_inputs, literal_f32, ArtifactMeta, ParamState, Runtime};
use odimo::util::prng::Pcg32;

fn main() -> anyhow::Result<()> {
    odimo::util::logging::init();
    let rt = Runtime::cpu()?;
    let meta = ArtifactMeta::load(std::path::Path::new("artifacts"), "resnet20")?;
    let g = &meta.model;

    // a demo mapping: interleaved channels, ~60% AIMC
    let mut rng = Pcg32::new(7, 1);
    let mut mapping = Mapping::uniform(g, DIG);
    for n in g.mappable() {
        let ids = (0..n.cout)
            .map(|_| if rng.next_f32() < 0.6 { AIMC as u8 } else { DIG as u8 })
            .collect();
        mapping.assign.insert(n.name.clone(), ids);
    }

    // partition: reorder channels so sub-layers are contiguous
    let values = meta.load_init_values()?;
    let part = partition(&meta, g, &mapping, &values)?;
    let max_frag = part.fragments.values().max().copied().unwrap_or(0);
    println!(
        "partitioned {} layers; worst fragmentation {} contiguous runs",
        part.fragments.len(),
        max_frag
    );

    // numeric cross-check through the AOT deploy graph
    let ds = DataSource::test(g, 5);
    let batch = ds.batch(0, 8);
    let x = literal_f32(&batch.x, &[8, batch.c, batch.h, batch.w])?;
    let before = infer(&rt, &meta, &values, &mapping, &x)?;
    let after = infer(&rt, &meta, &part.values, &part.mapping, &x)?;
    let diff = before
        .iter()
        .zip(&after)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!("partition numeric check: max |logit diff| = {diff:.2e} (must be ~0)");
    anyhow::ensure!(diff < 1e-3, "partition changed the network!");

    // simulate on DIANA
    let rep = deploy(g, &part.mapping, &odimo::hw::Platform::diana(), SocConfig::default());
    println!(
        "\nDIANA simulation: {:.3} ms | {:.2} uJ | D/A util {:.1}%/{:.1}% | both-busy {:.1}%",
        rep.run.latency_ms,
        rep.run.energy_uj,
        100.0 * rep.run.util[0],
        100.0 * rep.run.util[1],
        100.0 * rep.run.timeline.utilization().all_busy_frac,
    );
    println!("\nper-layer busy cycles (first 8 rows):");
    println!("{:<12} {:>10} {:>10} {:>10}", "layer", "digital", "aimc", "span");
    for (layer, busy, span) in rep.run.timeline.per_layer().into_iter().take(8) {
        println!("{layer:<12} {:>10} {:>10} {span:>10}", busy[0], busy[1]);
    }
    Ok(())
}

fn infer(
    rt: &Runtime,
    meta: &ArtifactMeta,
    values: &[Vec<f32>],
    mapping: &Mapping,
    x: &odimo::xla::Literal,
) -> anyhow::Result<Vec<f32>> {
    let exe = rt.load(meta.graph("infer_deploy")?)?;
    let params = ParamState::from_host(meta, values.to_vec())?;
    let assigns: std::collections::BTreeMap<String, odimo::xla::Literal> = meta
        .mappable
        .iter()
        .map(|name| {
            let n = meta.model.node(name).unwrap();
            (
                name.clone(),
                literal_f32(&mapping.onehot(name, 2), &[2, n.cout]).unwrap(),
            )
        })
        .collect();
    let inputs = assemble_inputs(&exe.meta, |tm| match tm.name.as_str() {
        "x" => Ok(x),
        n if n.starts_with("param:") => params.leaf(&n[6..]),
        n if n.starts_with("assign:") => {
            assigns.get(&n[7..]).ok_or_else(|| anyhow!("missing {n}"))
        }
        n => Err(anyhow!("unexpected {n}")),
    })?;
    Ok(exe.run_to_host(&inputs)?.into_iter().next_back().unwrap())
}
