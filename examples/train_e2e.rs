//! End-to-end validation driver (EXPERIMENTS.md §E2E).
//!
//! Exercises every layer of the stack on a real (synthetic-CIFAR)
//! workload: the rust coordinator streams generated batches into the
//! AOT-compiled JAX supernet (whose hot loop is the fused Pallas Eq.-1
//! kernel), through all four ODiMO phases on ResNet20, logging the loss
//! curve, then deploys the discovered mapping on the DIANA simulator.
//!
//!     cargo run --release --example train_e2e [steps_scale]
//!
//! steps_scale (default 1.0) scales the phase lengths; 0.2 gives a
//! ~3-minute smoke run on one CPU.

use odimo::coordinator::{discretize::discretize, scheduler::deploy, Hyper, Trainer};
use odimo::hw::soc::SocConfig;
use odimo::runtime::{ArtifactMeta, Runtime};

fn main() -> anyhow::Result<()> {
    odimo::util::logging::init();
    let scale: f64 = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("steps_scale must be a number"))
        .unwrap_or(1.0);
    let steps = |n: usize| ((n as f64 * scale) as usize).max(5);

    let art = std::path::Path::new("artifacts");
    let rt = Runtime::cpu()?;
    let meta = ArtifactMeta::load(art, "resnet20")?;
    let mut tr = Trainer::new(&rt, &meta, 1234)?;
    let t0 = std::time::Instant::now();

    // ---- phase 1: float pre-training (with BatchNorm) ------------------
    println!("== phase 1: float pre-training ({} steps)", steps(300));
    let h = Hyper { lr: 0.1, lr_alpha: 0.0, wd: 1e-4, ..Default::default() };
    let hist = tr.run_phase("train_float", steps(300), h, None, None)?;
    print_curve("float", &hist);
    let ev = tr.eval("eval_float", None, 2)?;
    println!("   float test accuracy: {:.4}", ev.accuracy);

    // ---- phase 2: BN fold ----------------------------------------------
    println!("== phase 2: fold BatchNorm, re-derive quantizer scales");
    tr.fold_batchnorm()?;

    // ---- phase 3: differentiable mapping search (Eq. 2, energy) --------
    // momentum-free low-lr warm-up first: the post-fold landscape is
    // sharp and momentum turns the first transient gradient into a
    // catastrophic step (DESIGN.md §Implementation-notes)
    println!(
        "== phase 3: ODiMO search (warm-up {} + {} regularized steps, lambda = 10)",
        steps(80),
        steps(120)
    );
    let h_warm = Hyper {
        lr: 0.001,
        lr_alpha: 0.0,
        mu: 0.0,
        lam: 0.0,
        lr_min_frac: 1.0,
        ..Default::default()
    };
    let hist = tr.run_phase("train_search_en", steps(80), h_warm, None, None)?;
    print_curve("warm-up", &hist);
    let h = Hyper {
        lr: 0.005,
        lr_alpha: 0.1,
        lam: 10.0,
        tau_start: 1.0,
        tau_end: 0.2,
        ..Default::default()
    };
    let hist = tr.run_phase("train_search_en", steps(120), h, None, None)?;
    print_curve("search", &hist);

    // ---- phase 4: discretize + fine-tune --------------------------------
    let mapping = discretize(&meta.model, &tr.alphas()?, meta.hw.n_acc())?;
    println!(
        "== phase 4: discretized mapping — {:.1}% of channels on AIMC; fine-tune ({} steps)",
        100.0 * mapping.aimc_fraction(),
        steps(120)
    );
    let h0 = Hyper { lr: 0.001, lr_alpha: 0.0, mu: 0.0, wd: 1e-4,
                     lr_min_frac: 1.0, ..Default::default() };
    tr.run_phase("train_ft", steps(30), h0, Some(&mapping), None)?;
    let h = Hyper { lr: 0.005, lr_alpha: 0.0, wd: 1e-4, ..Default::default() };
    let hist = tr.run_phase("train_ft", steps(90), h, Some(&mapping), None)?;
    print_curve("finetune", &hist);

    // ---- deploy ----------------------------------------------------------
    let ev = tr.eval("eval_deploy", Some(&mapping), 2)?;
    let rep = deploy(&meta.model, &mapping, &odimo::hw::Platform::diana(), SocConfig::default());
    println!("\n== deployment on the DIANA simulator");
    println!(
        "   accuracy {:.4} | latency {:.3} ms | energy {:.2} uJ | D/A util {:.1}%/{:.1}%",
        ev.accuracy,
        rep.run.latency_ms,
        rep.run.energy_uj,
        100.0 * rep.run.util[0],
        100.0 * rep.run.util[1],
    );
    println!("   wall time: {:.1}s over {} total optimizer steps",
             t0.elapsed().as_secs_f64(), tr.history.len());

    // loss curve to results/ for EXPERIMENTS.md
    std::fs::create_dir_all("results")?;
    let mut csv = String::from("step,loss,batch_acc\n");
    for (i, m) in tr.history.iter().enumerate() {
        csv.push_str(&format!("{i},{},{}\n", m.loss, m.batch_acc));
    }
    std::fs::write("results/train_e2e_loss.csv", csv)?;
    println!("   loss curve written to results/train_e2e_loss.csv");
    Ok(())
}

fn print_curve(tag: &str, hist: &[odimo::coordinator::StepMetrics]) {
    let pts: Vec<String> = hist
        .iter()
        .step_by((hist.len() / 6).max(1))
        .map(|m| format!("{:.3}", m.loss))
        .collect();
    println!("   {tag} loss: {}", pts.join(" -> "));
}
