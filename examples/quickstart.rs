//! Quickstart: the smallest useful ODiMO session.
//!
//! Loads the tinycnn artifacts, runs the full pipeline (pretrain ->
//! BN-fold -> differentiable mapping search -> discretize -> fine-tune)
//! at one lambda, and deploys the result on the DIANA simulator next to
//! the All-8bit baseline.
//!
//!     make artifacts && cargo run --release --example quickstart

use odimo::coordinator::{Pipeline, Regularizer, Schedule};
use odimo::runtime::{ArtifactMeta, Runtime};

fn main() -> anyhow::Result<()> {
    odimo::util::logging::init();
    let art = std::path::Path::new("artifacts");
    anyhow::ensure!(
        art.join("tinycnn_meta.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );

    let rt = Runtime::cpu()?;
    let meta = ArtifactMeta::load(art, "tinycnn")?;
    println!(
        "model {}: {} nodes, {} mappable layers, {:.2} MMACs",
        meta.model.name,
        meta.model.nodes.len(),
        meta.model.mappable().len(),
        meta.model.total_macs() as f64 / 1e6
    );

    let pipe = Pipeline::new(&rt, &meta, Schedule::smoke());
    let folded = pipe.pretrained_folded()?;

    // one ODiMO point with the Eq.-4 energy regularizer
    let odimo_pt = pipe.search_point(&folded, &Regularizer::EnergyDiana, 30.0)?;
    // the trivial all-digital mapping for reference
    let base = pipe.baseline_point(&folded, "all_8bit")?;

    println!("\n{:<12} {:>8} {:>10} {:>10} {:>8}", "mapping", "acc", "lat[ms]", "E[uJ]", "A.Ch%");
    for p in [&base, &odimo_pt] {
        println!(
            "{:<12} {:>8.4} {:>10.4} {:>10.2} {:>8.1}",
            p.label,
            p.accuracy,
            p.latency_ms,
            p.energy_uj,
            100.0 * p.aimc_channel_frac
        );
    }
    println!(
        "\nODiMO saves {:.1}% energy at {:+.2}% accuracy vs All-8bit",
        100.0 * (1.0 - odimo_pt.energy_uj / base.energy_uj),
        100.0 * (odimo_pt.accuracy - base.accuracy)
    );
    Ok(())
}
