//! Python <-> rust parity: the constants and formulas that exist on
//! both sides of the AOT boundary must agree. The python cost model
//! (which shapes the training loss) exports its constants into the
//! artifact metadata; the rust simulator mirrors them natively.

use std::path::PathBuf;

use odimo::hw::energy::{P_ACT, P_IDLE};
use odimo::hw::latency::{lat_dig, lat_dw, AIMC_COLS, AIMC_ROWS, DIG_PE, F_CLK_HZ};
use odimo::model::Op;
use odimo::runtime::ArtifactMeta;

fn art_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
fn hw_constants_match_python_export() {
    if !art_dir().join("tinycnn_meta.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let meta = ArtifactMeta::load(&art_dir(), "tinycnn").unwrap();
    assert_eq!(meta.hw.n_acc(), 2, "DIANA artifacts are 2-accelerator");
    assert_eq!(meta.hw.p_act, P_ACT.to_vec(), "active power mismatch vs python");
    assert_eq!(meta.hw.p_idle, P_IDLE.to_vec(), "idle power mismatch vs python");
    assert_eq!(meta.hw.f_clk_hz, F_CLK_HZ);
    assert_eq!(meta.hw.aimc_rows, AIMC_ROWS);
    assert_eq!(meta.hw.aimc_cols, AIMC_COLS);
    assert_eq!(meta.hw.dig_pe, DIG_PE);
    // the built-in platform mirrors the python-exported constants
    let p = odimo::hw::Platform::diana();
    for (i, spec) in p.accelerators.iter().enumerate() {
        assert_eq!(spec.p_act_mw, meta.hw.p_act[i]);
        assert_eq!(spec.p_idle_mw, meta.hw.p_idle[i]);
    }
    assert_eq!(p.f_clk_hz, meta.hw.f_clk_hz);
}

#[test]
fn all_digital_latency_normalizer_matches() {
    // python exports norm.lat0 = sum of per-layer all-digital hard-max
    // latency; the rust Eq. 6/7 mirrors must reproduce it exactly.
    for model in ["tinycnn", "resnet20", "resnet18s", "mbv1_025"] {
        if !art_dir().join(format!("{model}_meta.json")).exists() {
            continue;
        }
        let meta = ArtifactMeta::load(&art_dir(), model).unwrap();
        let mut lat0 = 0u64;
        for n in &meta.model.nodes {
            match n.op {
                Op::Conv | Op::Fc => {
                    let (oy, ox) = (n.out_hw.0 as u64, n.out_hw.1 as u64);
                    lat0 += lat_dig(n.cin as u64, n.k as u64, n.k as u64, ox, oy,
                                    n.cout as u64);
                }
                Op::DwConv => {
                    let (oy, ox) = (n.out_hw.0 as u64, n.out_hw.1 as u64);
                    lat0 += lat_dw(n.k as u64, ox, oy, n.cout as u64);
                }
                _ => {}
            }
        }
        assert_eq!(
            lat0 as f64, meta.norm_lat0,
            "{model}: rust lat0 {lat0} vs python {}",
            meta.norm_lat0
        );
    }
}

#[test]
fn all_digital_energy_normalizer_matches() {
    for model in ["tinycnn", "resnet20"] {
        if !art_dir().join(format!("{model}_meta.json")).exists() {
            continue;
        }
        let meta = ArtifactMeta::load(&art_dir(), model).unwrap();
        // python: en0 = sum over layers of (P_ACT[dig] + P_IDLE[aimc]) * lat_dig
        let en0 = meta.norm_lat0 * (P_ACT[0] + P_IDLE[1]);
        let rel = (en0 - meta.norm_en0).abs() / meta.norm_en0;
        assert!(rel < 1e-9, "{model}: en0 {en0} vs python {}", meta.norm_en0);
    }
}

#[test]
fn datagen_algo_version_matches() {
    if !art_dir().join("tinycnn_meta.json").exists() {
        return;
    }
    let text = std::fs::read_to_string(art_dir().join("tinycnn_meta.json")).unwrap();
    let v = odimo::util::json::parse(&text).unwrap();
    let py_version = v
        .req("datagen_algo_version")
        .unwrap()
        .as_i64()
        .unwrap() as u32;
    assert_eq!(
        py_version,
        odimo::data::ALGO_VERSION,
        "python datagen and rust synth generator versions diverged"
    );
}

#[test]
fn bits_order_matches() {
    // the platform registry's DIANA entry carries the accelerator-order
    // contract the python export pins: [digital int8, ternary aimc]
    let plat_bits: Vec<usize> = odimo::hw::Platform::diana()
        .accelerators
        .iter()
        .map(|a| a.weight_bits as usize)
        .collect();
    assert_eq!(plat_bits, vec![8, 2], "accelerator order contract: [digital, aimc]");
    if !art_dir().join("tinycnn_meta.json").exists() {
        return;
    }
    let text = std::fs::read_to_string(art_dir().join("tinycnn_meta.json")).unwrap();
    let v = odimo::util::json::parse(&text).unwrap();
    let bits = v.req("bits").unwrap().usize_vec().unwrap();
    assert_eq!(bits, plat_bits, "python export disagrees with Platform::diana()");
}
