//! Property tests for the serving stack (serve/): frontier pruning
//! (differential against an O(n^2) all-pairs oracle), the SLA
//! dispatcher's selection invariants, and the end-to-end closed loop on
//! the N = 2..4 built-in platforms. Randomized cases carry printed
//! seeds so failures reproduce deterministically.

mod common;

use std::collections::BTreeMap;

use common::{serve_opts, serve_session};
use odimo::coordinator::Mapping;
use odimo::hw::Platform;
use odimo::model::tinycnn;
use odimo::obs;
use odimo::serve::sweep::{self, dominates, pareto_prune};
use odimo::serve::{dispatch, FrontierPoint, Sla, SweepCfg};
use odimo::util::pool::ThreadPool;
use odimo::util::prng::Pcg32;

const CASES: u64 = 40;

/// Synthetic point cloud on small integer grids, so score ties (and
/// exact duplicates) occur often — the pruning edge cases.
fn synth_points(seed: u64, n: usize) -> Vec<FrontierPoint> {
    let mut rng = Pcg32::new(seed, 51);
    (0..n)
        .map(|i| {
            let cycles = 1_000 + 100 * rng.below(12) as u64;
            FrontierPoint {
                label: format!("p{i}"),
                mapping: Mapping { assign: BTreeMap::new() },
                cycles,
                latency_ms: cycles as f64 * 1e-6,
                energy_uj: 0.5 * rng.below(10) as f64,
                acc_proxy: rng.below(8) as f64 / 8.0,
            }
        })
        .collect()
}

/// The O(n^2) oracle: keep exactly the points no other point dominates.
fn oracle_prune(points: &[FrontierPoint]) -> Vec<usize> {
    (0..points.len())
        .filter(|&i| !points.iter().any(|q| dominates(q, &points[i])))
        .collect()
}

#[test]
fn prop_prune_matches_oracle() {
    for seed in 0..CASES {
        let n = 1 + (seed as usize * 7) % 60;
        let pts = synth_points(seed, n);
        let mut fast = pareto_prune(&pts);
        let mut want = oracle_prune(&pts);
        fast.sort_unstable();
        want.sort_unstable();
        assert_eq!(fast, want, "seed {seed} n {n}");
    }
}

#[test]
fn prop_prune_never_drops_nondominated() {
    // the oracle property stated directly: every kept index is
    // non-dominated, every dropped index is dominated by a kept one
    for seed in 0..CASES {
        let pts = synth_points(seed + 1000, 30);
        let kept = pareto_prune(&pts);
        for &i in &kept {
            assert!(
                !pts.iter().any(|q| dominates(q, &pts[i])),
                "seed {seed}: kept a dominated point {i}"
            );
        }
        for i in 0..pts.len() {
            if !kept.contains(&i) {
                assert!(
                    kept.iter().any(|&k| dominates(&pts[k], &pts[i])),
                    "seed {seed}: dropped point {i} has no kept dominator"
                );
            }
        }
    }
}

#[test]
fn prop_dispatch_selects_cheapest_feasible_on_frontier() {
    for seed in 0..CASES {
        let pts = synth_points(seed + 2000, 25);
        let frontier: Vec<FrontierPoint> =
            pareto_prune(&pts).into_iter().map(|i| pts[i].clone()).collect();
        let mut rng = Pcg32::new(seed, 77);
        for _ in 0..20 {
            let budget = 900 + 100 * rng.below(16) as u64;
            let d = dispatch(&frontier, Sla::LatencyBudget(budget)).unwrap();
            let sel = &frontier[d.point];
            // the selection is a frontier member and non-dominated
            assert!(!frontier.iter().any(|q| dominates(q, sel)), "seed {seed}");
            let feasible: Vec<&FrontierPoint> =
                frontier.iter().filter(|p| p.cycles <= budget).collect();
            if feasible.is_empty() {
                assert!(!d.sla_met, "seed {seed}: miss must be flagged");
                let min_cyc = frontier.iter().map(|p| p.cycles).min().unwrap();
                assert_eq!(sel.cycles, min_cyc, "seed {seed}: fallback must be fastest");
            } else {
                // meets the budget whenever any frontier point does
                assert!(d.sla_met && sel.cycles <= budget, "seed {seed}");
                let min_en =
                    feasible.iter().map(|p| p.energy_uj).fold(f64::INFINITY, f64::min);
                assert_eq!(sel.energy_uj, min_en, "seed {seed}: not cheapest feasible");
            }
            // determinism: same inputs, same decision
            assert_eq!(d, dispatch(&frontier, Sla::LatencyBudget(budget)).unwrap());
        }
        let d = dispatch(&frontier, Sla::MinEnergy).unwrap();
        let min_en = frontier.iter().map(|p| p.energy_uj).fold(f64::INFINITY, f64::min);
        assert_eq!(frontier[d.point].energy_uj, min_en, "seed {seed}");
    }
}

#[test]
fn swept_frontiers_are_nondominated_on_n2_to_n4() {
    let g = tinycnn();
    let pool = ThreadPool::new(2);
    let cfg = SweepCfg { seed: 7, calib: 4, blend_steps: 2 };
    for p in [Platform::diana(), Platform::diana_ne16(), Platform::mpsoc4()] {
        let frontier =
            sweep::sweep_frontier(&g, &p, &cfg, &pool, &obs::Recorder::disabled()).unwrap();
        assert!(!frontier.is_empty(), "{}: empty frontier", p.name);
        for fp in &frontier {
            fp.mapping.validate(&g, p.n_acc()).unwrap();
            assert!(
                !frontier.iter().any(|q| dominates(q, fp)),
                "{}: dominated point '{}' on the frontier",
                p.name,
                fp.label
            );
        }
        // dispatching at every frontier point's own latency must be
        // feasible and land on a point at most that expensive
        for fp in &frontier {
            let d = dispatch(&frontier, Sla::LatencyBudget(fp.cycles)).unwrap();
            assert!(d.sla_met, "{}: budget {} has a feasible point", p.name, fp.cycles);
            assert!(frontier[d.point].cycles <= fp.cycles);
            assert!(frontier[d.point].energy_uj <= fp.energy_uj);
        }
    }
}

#[test]
fn frontier_cache_schema_mismatch_is_a_clear_error() {
    let g = tinycnn();
    let p = Platform::diana();
    let pool = ThreadPool::new(2);
    let cfg = SweepCfg { seed: 3, calib: 4, blend_steps: 2 };
    let dir = std::env::temp_dir().join("odimo_serve_props_schema");
    let _ = std::fs::remove_dir_all(&dir);
    let (_, hit) =
        sweep::load_or_sweep(&dir, &g, &p, &cfg, &pool, &obs::Recorder::disabled()).unwrap();
    assert!(!hit);
    // tamper with the stored schema version; reloads must error clearly
    let path = sweep::frontier_path(&dir, &g.name, &p.name);
    let text = std::fs::read_to_string(&path).unwrap();
    let bumped = text.replace("\"schema_version\":3", "\"schema_version\":999");
    assert_ne!(text, bumped, "version field must be present to tamper with");
    std::fs::write(&path, bumped).unwrap();
    let e = sweep::load_or_sweep(&dir, &g, &p, &cfg, &pool, &obs::Recorder::disabled())
        .unwrap_err()
        .to_string();
    assert!(e.contains("schema version 999"), "{e}");
}

#[test]
fn closed_loop_is_deterministic_and_accounts_every_request() {
    let dir = std::env::temp_dir().join("odimo_serve_props_loop");
    let _ = std::fs::remove_dir_all(&dir);
    // two independent sessions: bitwise-identical reports (frontier
    // cache shared through disk, plan caches cold in both)
    let a = serve_session(&dir, 2, 9).serve(&serve_opts(4)).unwrap();
    let b = serve_session(&dir, 2, 9).serve(&serve_opts(4)).unwrap();
    assert_eq!(a.total_requests, 24);
    assert_eq!(a.total_requests, b.total_requests);
    assert_eq!(a.total_batches, b.total_batches);
    assert_eq!(a.p50_ms, b.p50_ms, "virtual-time latencies must be deterministic");
    assert_eq!(a.p95_ms, b.p95_ms);
    assert_eq!(a.sla_hit_rate, b.sla_hit_rate);
    assert_eq!(a.sim_energy_uj, b.sim_energy_uj);
    assert_eq!(a.rows.len(), b.rows.len());
    for (x, y) in a.rows.iter().zip(&b.rows) {
        assert_eq!(x.label, y.label);
        assert_eq!(x.requests, y.requests);
        assert_eq!(x.sla_hits, y.sla_hits);
    }
    let served: usize = a.rows.iter().map(|r| r.requests).sum();
    assert_eq!(served, 24, "every request lands in exactly one row");
    // the plan cache compiles each touched mapping once, then hits
    assert_eq!(a.plan_misses as usize, a.rows.len());
    assert_eq!(a.plan_hits + a.plan_misses, a.total_batches as u64);
    // second run reused the frontier cache (report still written fresh)
    assert!(sweep::frontier_path(&dir, "tinycnn", "diana").exists());
}

#[test]
fn session_plan_cache_is_warm_on_second_serve() {
    let dir = std::env::temp_dir().join("odimo_serve_props_warm");
    let _ = std::fs::remove_dir_all(&dir);
    let mut session = serve_session(&dir, 2, 9);
    let a = session.serve(&serve_opts(4)).unwrap();
    assert!(a.plan_misses > 0, "cold cache compiles");
    // same session, same stream: every plan is already resident, and
    // the virtual-time metrics are unchanged
    let b = session.serve(&serve_opts(4)).unwrap();
    assert_eq!(b.plan_misses, 0, "warm session must not recompile");
    assert_eq!(b.plan_hits, b.total_batches as u64);
    assert_eq!(a.p50_ms, b.p50_ms);
    assert_eq!(a.p95_ms, b.p95_ms);
    assert_eq!(a.sla_hit_rate, b.sla_hit_rate);
}

#[test]
fn unbatched_mode_runs_one_request_per_batch() {
    let dir = std::env::temp_dir().join("odimo_serve_props_unbatched");
    let _ = std::fs::remove_dir_all(&dir);
    let rep = serve_session(&dir, 2, 5).serve(&serve_opts(1)).unwrap();
    assert_eq!(rep.total_batches, rep.total_requests);
    for r in &rep.rows {
        assert!((r.mean_batch - 1.0).abs() < 1e-12, "{}: batch {}", r.label, r.mean_batch);
    }
}

#[test]
fn serve_report_roundtrips_through_disk() {
    let dir = std::env::temp_dir().join("odimo_serve_props_report");
    let _ = std::fs::remove_dir_all(&dir);
    let mut session = serve_session(&dir, 2, 13);
    let rep = session.serve(&serve_opts(4)).unwrap();
    // the facade loader and a raw metrics load agree with the returned
    // in-memory report
    let back = session.serve_report().unwrap();
    assert_eq!(back.dashboard(), rep.dashboard());
    let raw = odimo::serve::metrics::load_report(&session.report_path()).unwrap();
    assert_eq!(raw.dashboard(), rep.dashboard());
}
