//! Shared fixtures for the serve-family integration suites
//! (`serve_props`, `chaos_props`, `cluster_props`, `trace_roundtrip`).
//! One place owns the canonical session/opts shapes so the
//! differential pins in `cluster_props` compare against exactly the
//! configuration the older suites exercise.

#![allow(dead_code)]

use std::collections::BTreeSet;
use std::path::Path;

use odimo::api::{FaultPlan, ServeOpts, ServeReport, Session, SessionBuilder};
use odimo::hw::Platform;
use odimo::model::tinycnn;
use odimo::serve::sweep;
use odimo::serve::{FrontierPoint, SweepCfg};
use odimo::util::pool::ThreadPool;

/// Request count shared by the closed-loop suites.
pub const N_REQUESTS: usize = 24;
/// Seed shared by the chaos/cluster suites.
pub const SEED: u64 = 9;

/// A `tinycnn`-on-`diana` session at smoke sweep sizes. The plan
/// cache cap is larger than any tinycnn frontier, so each mapping
/// compiles exactly once per cold session.
pub fn serve_session(dir: &Path, threads: usize, seed: u64) -> Session {
    SessionBuilder::new("tinycnn")
        .platform("diana")
        .results_dir(dir)
        .threads(threads)
        .seed(seed)
        .sweep_calib(4)
        .sweep_blend_steps(2)
        .plan_cache_cap(8)
        .build()
        .unwrap()
}

/// The canonical serve load: 24 requests, 15k-cycle mean gap.
pub fn serve_opts(max_batch: usize) -> ServeOpts {
    ServeOpts {
        n_requests: Some(N_REQUESTS),
        max_batch,
        max_wait: 50_000,
        mean_gap: 15_000,
        launch_cycles: 10_000,
        ..ServeOpts::default()
    }
}

/// A `tinycnn`-on-`mpsoc4` session (4 units) for fault/cluster runs.
pub fn chaos_session(dir: &Path, threads: usize) -> Session {
    SessionBuilder::new("tinycnn")
        .platform("mpsoc4")
        .results_dir(dir)
        .threads(threads)
        .seed(SEED)
        .sweep_calib(4)
        .sweep_blend_steps(2)
        .plan_cache_cap(8)
        .build()
        .unwrap()
}

/// The canonical chaos load with an optional fault plan attached.
pub fn chaos_opts(plan: Option<FaultPlan>) -> ServeOpts {
    ServeOpts {
        n_requests: Some(N_REQUESTS),
        max_batch: 4,
        max_wait: 50_000,
        mean_gap: 15_000,
        launch_cycles: 10_000,
        fault_plan: plan,
        ..ServeOpts::default()
    }
}

/// The frontier the sessions above will serve from (same sweep config,
/// same seed — the disk cache makes this literal agreement, but the
/// sweep itself is deterministic so a fresh compute agrees too).
pub fn probe_frontier(p: &Platform) -> Vec<FrontierPoint> {
    let pool = ThreadPool::new(2);
    let cfg = SweepCfg { seed: SEED, calib: 4, blend_steps: 2 };
    sweep::sweep_frontier(&tinycnn(), p, &cfg, &pool, &odimo::obs::Recorder::disabled()).unwrap()
}

/// Unit indices a frontier point assigns at least one channel to.
pub fn units_used(point: &FrontierPoint, n_acc: usize) -> BTreeSet<usize> {
    let mut used = BTreeSet::new();
    for counts in point.mapping.channel_split(n_acc).values() {
        for (i, &c) in counts.iter().enumerate() {
            if c > 0 {
                used.insert(i);
            }
        }
    }
    used
}

/// Digest-plus-rows equality between two serve reports.
pub fn assert_reports_identical(a: &ServeReport, b: &ServeReport, ctx: &str) {
    assert_eq!(a.deterministic_digest(), b.deterministic_digest(), "{ctx}: digest drift");
    assert_eq!(a.rows.len(), b.rows.len(), "{ctx}");
    for (x, y) in a.rows.iter().zip(&b.rows) {
        assert_eq!(x.label, y.label, "{ctx}");
        assert_eq!(x.requests, y.requests, "{ctx}");
        assert_eq!(x.sla_hits, y.sla_hits, "{ctx}");
    }
}
