//! Property tests for the observability layer (obs/): the recorded
//! event stream must *describe* the run without *changing* it. The
//! suite pins three contracts: (1) `BatchExec` member spans partition
//! the served requests and their queue/compute sums reconcile exactly
//! with the `ServeReport` latency fields; (2) the virtual-domain
//! digest is invariant across worker-thread counts and capture levels;
//! (3) an enabled recorder never perturbs report results, and a
//! disabled one records nothing. Export determinism and the
//! `write_atomic` concurrency guarantee ride along.

mod common;

use std::path::{Path, PathBuf};

use common::{assert_reports_identical, serve_opts, N_REQUESTS};
use odimo::api::{ClusterOpts, Session, SessionBuilder};
use odimo::hw::Platform;
use odimo::obs::{export, EventKind, ObsLevel};

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The `common::serve_session` fixture plus an observer level.
fn obs_session(dir: &Path, threads: usize, level: ObsLevel) -> Session {
    SessionBuilder::new("tinycnn")
        .platform("diana")
        .results_dir(dir)
        .threads(threads)
        .seed(9)
        .sweep_calib(4)
        .sweep_blend_steps(2)
        .plan_cache_cap(8)
        .observer(level)
        .build()
        .unwrap()
}

#[test]
fn batchexec_members_partition_and_reconcile_with_report() {
    let dir = tmp("odimo_obs_props_members");
    let mut s = obs_session(&dir, 2, ObsLevel::Basic);
    let rep = s.serve(&serve_opts(4)).unwrap();
    let events = s.recorder().snapshot();
    let mut served = 0usize;
    let mut batches = 0usize;
    let mut queue_cycles = 0u64;
    let mut compute_cycles = 0u64;
    let mut ids = std::collections::BTreeSet::new();
    for e in &events {
        if let EventKind::BatchExec { start, done, size, members, .. } = &e.kind {
            batches += 1;
            assert_eq!(members.len(), *size, "member list sizes the batch");
            assert!(done > start, "batch window must have positive length");
            for &(id, orig) in members {
                assert!(orig <= *start, "request {id} arrived after its batch started");
                assert!(ids.insert(id), "request {id} served twice");
                served += 1;
                queue_cycles += start - orig;
                compute_cycles += done - start;
            }
        }
    }
    // spans partition the request stream: every request in exactly one
    // batch window, every batch in exactly one BatchExec event
    assert_eq!(served, rep.total_requests);
    assert_eq!(served, N_REQUESTS);
    assert_eq!(batches, rep.total_batches);
    // the span sums are the report's latency split, cycle for cycle
    let f_clk = Platform::diana().f_clk_hz;
    let to_ms = |c: u64| c as f64 / f_clk * 1e3;
    let n = served as f64;
    assert!(
        (to_ms(queue_cycles) - rep.mean_queue_ms * n).abs() < 1e-6,
        "queue span sum {} ms != report mean {} ms x {n}",
        to_ms(queue_cycles),
        rep.mean_queue_ms
    );
    assert!(
        (to_ms(compute_cycles) - rep.mean_compute_ms * n).abs() < 1e-6,
        "compute span sum {} ms != report mean {} ms x {n}",
        to_ms(compute_cycles),
        rep.mean_compute_ms
    );
}

#[test]
fn virtual_digest_is_invariant_across_thread_counts_and_levels() {
    let dir = tmp("odimo_obs_props_digest");
    let mut runs = Vec::new();
    for (threads, level) in [
        (1, ObsLevel::Basic),
        (2, ObsLevel::Basic),
        (8, ObsLevel::Basic),
        // Full adds wall-domain engine/kernel spans, which the digest
        // must exclude exactly like the report's wall-clock fields
        (2, ObsLevel::Full),
    ] {
        let mut s = obs_session(&dir, threads, level);
        let rep = s.serve(&serve_opts(4)).unwrap();
        assert!(!s.recorder().is_empty(), "enabled recorder captured the run");
        runs.push((threads, s.recorder().virtual_digest(), rep.deterministic_digest()));
    }
    let (_, ev0, rep0) = runs[0];
    for &(threads, ev, rep) in &runs[1..] {
        assert_eq!(ev, ev0, "event digest drifts at {threads} threads");
        assert_eq!(rep, rep0, "report digest drifts at {threads} threads");
    }
}

#[test]
fn recorder_level_never_changes_results() {
    let dir = tmp("odimo_obs_props_off_on");
    // Off is the default everywhere; Full swaps the engine onto the
    // traced single-plan walk — numerics and virtual time must agree
    let mut off = obs_session(&dir, 2, ObsLevel::Off);
    let rep_off = off.serve(&serve_opts(4)).unwrap();
    assert!(off.recorder().is_empty(), "disabled recorder records nothing");
    let mut full = obs_session(&dir, 2, ObsLevel::Full);
    let rep_full = full.serve(&serve_opts(4)).unwrap();
    assert_reports_identical(&rep_off, &rep_full, "obs level");
    assert_eq!(rep_off.dashboard().lines().count(), rep_full.dashboard().lines().count());
    assert_eq!(rep_off.makespan_ms, rep_full.makespan_ms);
    assert_eq!(rep_off.plan_hits, rep_full.plan_hits);
    assert_eq!(rep_off.plan_misses, rep_full.plan_misses);
    // Full captured wall spans for every executed batch
    let engine_runs = full
        .recorder()
        .snapshot()
        .iter()
        .filter(|e| matches!(e.kind, EventKind::EngineRun { .. }))
        .count();
    assert_eq!(engine_runs, rep_full.total_batches);
}

#[test]
fn trace_export_is_deterministic_and_summarizable() {
    let dir = tmp("odimo_obs_props_export");
    let mut s = obs_session(&dir, 2, ObsLevel::Full);
    s.serve(&serve_opts(4)).unwrap();
    let p1 = dir.join("trace1.json");
    let p2 = dir.join("trace2.json");
    s.export_trace(&p1).unwrap();
    s.export_trace(&p2).unwrap();
    assert_eq!(
        std::fs::read(&p1).unwrap(),
        std::fs::read(&p2).unwrap(),
        "same stream must export byte-identically"
    );
    let text = std::fs::read_to_string(&p1).unwrap();
    // paired span markers and per-layer energy attribution present
    assert_eq!(text.matches("\"ph\":\"B\"").count(), text.matches("\"ph\":\"E\"").count());
    assert!(text.contains("energy_uj"), "per-layer energy args missing");
    let summary = export::summarize(&text, 5).unwrap();
    assert!(summary.contains("trace summary:"), "{summary}");
    assert!(summary.contains("plan cache:"), "{summary}");
    assert!(summary.contains("per-unit busy / energy split"), "{summary}");
}

#[test]
fn cluster_obs_is_deterministic_and_exports() {
    let dir = tmp("odimo_obs_props_cluster");
    let copts = ClusterOpts { replicas: 2, serve: serve_opts(4), ..ClusterOpts::default() };
    let mut digests = Vec::new();
    for threads in [1, 4] {
        let mut s = obs_session(&dir, threads, ObsLevel::Basic);
        let rep = s.serve_cluster(&copts, None).unwrap();
        assert_eq!(rep.accounted(), N_REQUESTS as u64);
        digests.push((s.recorder().virtual_digest(), rep.deterministic_digest()));
        if threads == 1 {
            let path = dir.join("cluster_trace.json");
            s.export_trace(&path).unwrap();
            let text = std::fs::read_to_string(&path).unwrap();
            let summary = export::summarize(&text, 5).unwrap();
            assert!(summary.contains("trace summary:"), "{summary}");
        }
    }
    assert_eq!(digests[0], digests[1], "cluster obs must not depend on thread count");
}

#[test]
fn write_atomic_survives_concurrent_writers() {
    let dir = tmp("odimo_obs_props_atomic");
    let path = dir.join("contended.json");
    std::thread::scope(|sc| {
        for writer in 0..8u64 {
            let path = &path;
            sc.spawn(move || {
                for iter in 0..20u64 {
                    let text = format!("{{\"writer\":{writer},\"iter\":{iter}}}");
                    odimo::exp::store::write_atomic(path, &text).unwrap();
                }
            });
        }
    });
    // the file is exactly one complete write — never interleaved or
    // truncated — and no staging files leak
    let got = std::fs::read_to_string(&path).unwrap();
    assert!(
        got.starts_with("{\"writer\":") && got.trim_end().ends_with('}'),
        "clobbered content: {got}"
    );
    for entry in std::fs::read_dir(&dir).unwrap() {
        let name = entry.unwrap().file_name().into_string().unwrap();
        assert!(!name.ends_with(".tmp"), "leftover staging file {name}");
    }
}
