//! End-to-end pipeline integration tests on tinycnn (smoke schedules).
//!
//! The heavyweight cross-check here is the partition-pass equality: the
//! Fig.-3 reorganization must leave the deployed network's logits
//! unchanged, verified through the AOT `infer_deploy` executable itself
//! (not a rust reimplementation).

use std::path::PathBuf;

use anyhow::anyhow;
use odimo::coordinator::partition::partition;
use odimo::coordinator::{
    discretize::discretize, Mapping, Pipeline, Regularizer, Schedule, Trainer,
};
use odimo::data::DataSource;
use odimo::model::{AIMC, DIG};
use odimo::runtime::{assemble_inputs, literal_f32, ArtifactMeta, ParamState, Runtime};
use odimo::util::prng::Pcg32;

fn art_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    art_dir().join("tinycnn_meta.json").exists()
}

fn random_mapping(meta: &ArtifactMeta, seed: u64) -> Mapping {
    let mut rng = Pcg32::new(seed, 3);
    let mut m = Mapping::uniform(&meta.model, DIG);
    for n in meta.model.mappable() {
        let ids = (0..n.cout)
            .map(|_| if rng.next_f32() < 0.5 { DIG as u8 } else { AIMC as u8 })
            .collect();
        m.assign.insert(n.name.clone(), ids);
    }
    m
}

/// Run infer_deploy with given params snapshot + mapping; returns logits.
fn infer_logits(
    rt: &Runtime,
    meta: &ArtifactMeta,
    values: &[Vec<f32>],
    mapping: &Mapping,
    x: &odimo::xla::Literal,
) -> Vec<f32> {
    let exe = rt.load(meta.graph("infer_deploy").unwrap()).unwrap();
    let params = ParamState::from_host(meta, values.to_vec()).unwrap();
    let assigns: std::collections::BTreeMap<String, odimo::xla::Literal> = meta
        .mappable
        .iter()
        .map(|name| {
            let n = meta.model.node(name).unwrap();
            (
                name.clone(),
                literal_f32(&mapping.onehot(name, 2), &[2, n.cout]).unwrap(),
            )
        })
        .collect();
    let inputs = assemble_inputs(&exe.meta, |tm| match tm.name.as_str() {
        "x" => Ok(x),
        n if n.starts_with("param:") => params.leaf(&n[6..]),
        n if n.starts_with("assign:") => {
            assigns.get(&n[7..]).ok_or_else(|| anyhow!("missing {n}"))
        }
        n => Err(anyhow!("unexpected {n}")),
    })
    .unwrap();
    let out = exe.run_to_host(&inputs).unwrap();
    out.into_iter().next_back().unwrap()
}

#[test]
fn partition_preserves_network_function() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let meta = ArtifactMeta::load(&art_dir(), "tinycnn").unwrap();
    let rt = Runtime::cpu().unwrap();
    let values = meta.load_init_values().unwrap();
    let ds = DataSource::test(&meta.model, 99);
    let batch = ds.batch(0, 8);
    let x = literal_f32(&batch.x, &[8, batch.c, batch.h, batch.w]).unwrap();

    for seed in [1u64, 2, 3] {
        let mapping = random_mapping(&meta, seed);
        let before = infer_logits(&rt, &meta, &values, &mapping, &x);

        let part = partition(&meta, &meta.model, &mapping, &values).unwrap();
        let after = infer_logits(&rt, &meta, &part.values, &part.mapping, &x);

        assert_eq!(before.len(), after.len());
        let max_diff = before
            .iter()
            .zip(&after)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        // identical math up to f32 summation-order noise
        assert!(max_diff < 1e-3, "seed {seed}: logits diverged by {max_diff}");

        // fragment counts: group-leader producers must be contiguous
        assert!(part.fragments["stem"] <= 2, "stem frags {}", part.fragments["stem"]);
        assert!(part.fragments["c1"] <= 2, "c1 frags {}", part.fragments["c1"]);
    }
}

#[test]
fn partition_perms_are_bijections() {
    if !have_artifacts() {
        return;
    }
    let meta = ArtifactMeta::load(&art_dir(), "tinycnn").unwrap();
    let values = meta.load_init_values().unwrap();
    let mapping = random_mapping(&meta, 7);
    let part = partition(&meta, &meta.model, &mapping, &values).unwrap();
    for (name, perm) in &part.perms {
        let mut sorted = perm.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..perm.len()).collect::<Vec<_>>(), "{name}");
    }
    // residual group shares one permutation
    assert_eq!(part.perms["c1"], part.perms["c2"]);
    assert_eq!(part.perms["c1"], part.perms["res"]);
    // network output unpermuted
    assert_eq!(part.perms["fc"], (0..meta.model.classes).collect::<Vec<_>>());
}

#[test]
fn smoke_pipeline_beats_chance_and_baselines_run() {
    if !have_artifacts() {
        return;
    }
    let meta = ArtifactMeta::load(&art_dir(), "tinycnn").unwrap();
    let rt = Runtime::cpu().unwrap();
    let mut pipe = Pipeline::new(&rt, &meta, Schedule::smoke());
    pipe.ckpt_dir = std::env::temp_dir().join("odimo_e2e_ckpt");
    std::fs::remove_dir_all(&pipe.ckpt_dir).ok();
    let folded = pipe.pretrained_folded().unwrap();

    let p = pipe
        .search_point(&folded, &Regularizer::EnergyDiana, 10.0)
        .unwrap();
    // tinycnn has 10 classes; even the smoke schedule should easily
    // beat chance after fine-tuning
    assert!(p.accuracy > 0.2, "acc {}", p.accuracy);
    assert!(p.energy_uj > 0.0 && p.latency_ms > 0.0);
    assert!(p.mapping.validate(&meta.model, 2).is_ok());

    let b = pipe.baseline_point(&folded, "all_8bit").unwrap();
    assert!(b.accuracy > 0.3, "all-8bit acc {}", b.accuracy);
    assert_eq!(b.aimc_channel_frac, 0.0);
    // ODiMO under strong lambda pressure must be no more expensive than
    // all-digital (strictly cheaper once any channel moves)
    assert!(p.energy_uj <= b.energy_uj, "{} vs {}", p.energy_uj, b.energy_uj);
}

#[test]
fn search_alpha_movement_is_lambda_sensitive() {
    if !have_artifacts() {
        return;
    }
    let meta = ArtifactMeta::load(&art_dir(), "tinycnn").unwrap();
    let rt = Runtime::cpu().unwrap();
    let mut pipe = Pipeline::new(&rt, &meta, Schedule::smoke());
    pipe.ckpt_dir = std::env::temp_dir().join("odimo_e2e_ckpt2");
    let folded = pipe.pretrained_folded().unwrap();

    let frac = |lam: f32| -> f64 {
        let mut tr = Trainer::new(&rt, &meta, 1234).unwrap();
        tr.set_params(folded.clone()).unwrap();
        let h = odimo::coordinator::Hyper {
            lr: 0.005,
            lr_alpha: 0.2,
            lam,
            tau_end: 0.5,
            ..Default::default()
        };
        tr.run_phase("train_search_en", 40, h, None, None).unwrap();
        let m = discretize(&meta.model, &tr.alphas().unwrap(), meta.hw.n_acc()).unwrap();
        m.aimc_fraction()
    };
    let low = frac(0.0);
    let high = frac(30.0);
    assert!(
        high > low + 0.05,
        "lambda pressure did not increase AIMC usage: {low} -> {high}"
    );
}

#[test]
fn baseline_mappings_simulate_in_expected_order() {
    // pure-simulator sanity chain on the real resnet20 geometry,
    // through the api facade: min_cost_lat <= all_ternary < all_8bit
    // in latency
    let session = odimo::api::SessionBuilder::new("resnet20")
        .platform("diana")
        .threads(1)
        .build()
        .unwrap();
    let lat = |name: &str| {
        let m = session
            .mapping(&odimo::api::MappingSpec::Baseline(name.into()))
            .unwrap();
        session.simulate(&m).unwrap().total_cycles
    };
    assert!(lat("all_ternary") < lat("all_8bit"));
    assert!(lat("min_cost_lat") <= lat("all_ternary"));
    assert!(lat("min_cost_lat") <= lat("io8_backbone_ternary"));
}
