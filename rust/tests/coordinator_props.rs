//! Property-based tests on coordinator invariants (mini-proptest built
//! on the in-tree PRNG: randomized cases with printed seeds so failures
//! reproduce deterministically). Includes the N>=3 platform properties
//! on the shipped 3-accelerator example SoC.

use std::collections::BTreeMap;

use odimo::coordinator::baselines::CostObjective;
use odimo::coordinator::partition::{partition, sublayers};
use odimo::coordinator::{baselines, discretize::discretize, Mapping, SearchPoint};
use odimo::hw::soc::{simulate, SocConfig};
use odimo::hw::{AcceleratorSpec, LatencyModel, Platform};
use odimo::model::{build, Graph, NodeDef, Op, ALL_MODELS, AIMC, DIG};
use odimo::util::prng::Pcg32;

const CASES: u64 = 40;

fn random_mapping(g: &Graph, rng: &mut Pcg32) -> Mapping {
    let mut m = Mapping::uniform(g, DIG);
    for n in g.mappable() {
        let p = rng.next_f32(); // layer-level bias so extremes appear
        let ids = (0..n.cout)
            .map(|_| if rng.next_f32() < p { AIMC as u8 } else { DIG as u8 })
            .collect();
        m.assign.insert(n.name.clone(), ids);
    }
    m
}

fn random_mapping_n(g: &Graph, n_acc: usize, rng: &mut Pcg32) -> Mapping {
    let mut m = Mapping::uniform(g, 0);
    for n in g.mappable() {
        let ids = (0..n.cout).map(|_| rng.below(n_acc as u32) as u8).collect();
        m.assign.insert(n.name.clone(), ids);
    }
    m
}

#[test]
fn prop_mapping_roundtrips_json() {
    for seed in 0..CASES {
        let mut rng = Pcg32::new(seed, 11);
        let g = build(ALL_MODELS[(seed % 4) as usize]).unwrap();
        let m = random_mapping(&g, &mut rng);
        let j = m.to_json().to_string();
        let back = Mapping::from_json(&odimo::util::json::parse(&j).unwrap()).unwrap();
        assert_eq!(m, back, "seed {seed}");
    }
}

#[test]
fn prop_split_counts_sum_to_cout() {
    for seed in 0..CASES {
        let mut rng = Pcg32::new(seed, 12);
        let g = build(ALL_MODELS[(seed % 4) as usize]).unwrap();
        let m = random_mapping(&g, &mut rng);
        let split = m.channel_split(2);
        for n in g.mappable() {
            let counts = &split[&n.name];
            assert_eq!(counts.iter().sum::<usize>(), n.cout, "seed {seed} layer {}", n.name);
        }
        // aimc_fraction consistent with the split
        let total: usize = g.mappable().iter().map(|n| n.cout).sum();
        let aimc: usize = split.values().map(|c| c[1]).sum();
        assert!((m.aimc_fraction() - aimc as f64 / total as f64).abs() < 1e-12);
    }
}

#[test]
fn prop_simulator_latency_bounded_by_extremes() {
    // any split's latency lies between the best single-accelerator
    // latency per layer (lower bound: max is at least each side alone
    // of the same split... we use global extremes as sanity bounds)
    let p = Platform::diana();
    for seed in 0..CASES {
        let mut rng = Pcg32::new(seed, 13);
        let g = build(ALL_MODELS[(seed % 4) as usize]).unwrap();
        let m = random_mapping(&g, &mut rng);
        let r = simulate(&g, &m.channel_split(2), &p, SocConfig::default());
        let dig = simulate(
            &g,
            &Mapping::uniform(&g, DIG).channel_split(2),
            &p,
            SocConfig::default(),
        );
        assert!(r.total_cycles <= dig.total_cycles, "seed {seed}");
        assert!(r.total_cycles > 0);
        assert!(r.energy_uj > 0.0);
        // utilization fractions are fractions
        assert!(r.util.iter().all(|u| (0.0..=1.0).contains(u)));
    }
}

#[test]
fn prop_min_cost_is_optimal_per_layer() {
    // exhaustive per-layer optimality: no random split may beat the
    // min_cost baseline's per-layer max-latency
    let p = Platform::diana();
    let g = build("resnet20").unwrap();
    let mc = baselines::min_cost(&g, &p, baselines::CostObjective::Latency);
    let split = mc.channel_split(2);
    for seed in 0..CASES {
        let mut rng = Pcg32::new(seed, 14);
        for n in g.mappable() {
            let cd = rng.below(n.cout as u32 + 1) as usize;
            let rand_span = p
                .layer_cycles(0, n, cd as u64)
                .max(p.layer_cycles(1, n, (n.cout - cd) as u64));
            let counts = &split[&n.name];
            let mc_span = p
                .layer_cycles(0, n, counts[0] as u64)
                .max(p.layer_cycles(1, n, counts[1] as u64));
            assert!(
                mc_span <= rand_span,
                "seed {seed} layer {}: min_cost {mc_span} beaten by random {rand_span}",
                n.name,
            );
        }
    }
}

#[test]
fn prop_sublayers_partition_channels() {
    for seed in 0..CASES {
        let mut rng = Pcg32::new(seed, 15);
        let g = build(ALL_MODELS[(seed % 4) as usize]).unwrap();
        let m = random_mapping(&g, &mut rng);
        for n in g.mappable() {
            let subs = sublayers(n, m.layer(&n.name));
            let covered: usize = subs.iter().map(|s| s.2).sum();
            assert_eq!(covered, n.cout, "seed {seed}");
            let mut pos = 0;
            for (acc, start, len) in subs {
                assert_eq!(start, pos);
                assert!(acc == DIG as u8 || acc == AIMC as u8);
                pos += len;
            }
        }
    }
}

#[test]
fn prop_discretize_respects_argmax() {
    for seed in 0..CASES {
        let mut rng = Pcg32::new(seed, 16);
        let g = build("tinycnn").unwrap();
        let mut alphas = BTreeMap::new();
        for n in g.mappable() {
            let v: Vec<f32> = (0..2 * n.cout).map(|_| rng.next_f32() * 4.0 - 2.0).collect();
            alphas.insert(n.name.clone(), v);
        }
        let m = discretize(&g, &alphas, 2).unwrap();
        for n in g.mappable() {
            let a = &alphas[&n.name];
            for c in 0..n.cout {
                let want = if a[n.cout + c] > a[c] { AIMC } else { DIG } as u8;
                assert_eq!(m.layer(&n.name)[c], want, "seed {seed} {} ch {c}", n.name);
            }
        }
    }
}

#[test]
fn prop_pareto_front_is_nondominated() {
    use odimo::metrics::{dominates, pareto_front};
    for seed in 0..CASES {
        let mut rng = Pcg32::new(seed, 17);
        let pts: Vec<SearchPoint> = (0..20)
            .map(|i| SearchPoint {
                label: format!("p{i}"),
                lambda: 0.0,
                accuracy: rng.next_f32() as f64,
                latency_ms: rng.next_f32() as f64 * 10.0,
                energy_uj: rng.next_f32() as f64 * 100.0,
                total_cycles: 1,
                util: vec![0.5, 0.5],
                aimc_channel_frac: 0.0,
                mapping: Mapping { assign: BTreeMap::new() },
            })
            .collect();
        let front = pareto_front(&pts, |p| p.latency_ms);
        // no front point dominated by any other point
        for &i in &front {
            for (j, q) in pts.iter().enumerate() {
                if i != j {
                    assert!(
                        !dominates(q, &pts[i], |p| p.latency_ms),
                        "seed {seed}: front point {i} dominated by {j}"
                    );
                }
            }
        }
        // every non-front point dominated by some front point
        for (j, q) in pts.iter().enumerate() {
            if !front.contains(&j) {
                assert!(
                    front.iter().any(|&i| dominates(&pts[i], q, |p| p.latency_ms)),
                    "seed {seed}: non-front point {j} not dominated"
                );
            }
        }
    }
}

#[test]
fn prop_partition_fragments_bounded() {
    // after partitioning, a group leader has <= 2 fragments and every
    // layer has <= cout fragments; permuted mapping preserves counts
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("tinycnn_meta.json").exists() {
        return;
    }
    let meta = odimo::runtime::ArtifactMeta::load(&dir, "tinycnn").unwrap();
    let values = meta.load_init_values().unwrap();
    for seed in 0..10 {
        let mut rng = Pcg32::new(seed, 18);
        let m = random_mapping(&meta.model, &mut rng);
        let part = partition(&meta, &meta.model, &m, &values).unwrap();
        let before = m.channel_split(2);
        let after = part.mapping.channel_split(2);
        assert_eq!(before, after, "seed {seed}: split counts changed");
        for (layer, frags) in &part.fragments {
            let n = meta.model.node(layer).unwrap();
            assert!(*frags <= n.cout, "seed {seed} {layer}");
        }
    }
}

// ---- min-cost fast path vs the exhaustive enumerator ------------------

/// A random conv layer shape (the geometry min-cost splits over).
fn random_conv_node(rng: &mut Pcg32, max_cout: usize) -> NodeDef {
    let k = [1usize, 3, 3, 5][rng.below(4) as usize];
    let oh = 1 + rng.below(28) as usize;
    let ow = 1 + rng.below(28) as usize;
    NodeDef {
        name: "rand".into(),
        op: Op::Conv,
        inputs: vec!["x".into()],
        cin: 1 + rng.below(128) as usize,
        cout: 1 + rng.below(max_cout as u32) as usize,
        k,
        stride: 1,
        pad: k / 2,
        relu: true,
        in_hw: (oh, ow),
        out_hw: (oh, ow),
    }
}

#[test]
fn prop_water_fill_matches_enumerator() {
    // the water-filling latency fast path must reproduce the exhaustive
    // enumerator exactly — including the tie-break (earlier units
    // maximized) — on both exact-enumeration built-ins
    for (p, max_cout) in [(Platform::diana(), 512), (Platform::diana_ne16(), 192)] {
        for seed in 0..CASES {
            let mut rng = Pcg32::new(seed, 23);
            let node = random_conv_node(&mut rng, max_cout);
            let fast = baselines::layer_counts(&p, &node, CostObjective::Latency);
            let slow = baselines::layer_counts_enum(&p, &node, CostObjective::Latency);
            assert_eq!(
                fast, slow,
                "seed {seed} on {}: cout {} cin {} k {} out {:?}",
                p.name, node.cout, node.cin, node.k, node.out_hw
            );
        }
    }
}

#[test]
fn prop_water_fill_matches_enumerator_on_models() {
    // whole-graph differential: identical mappings on every benchmark
    // model (the shapes the paper's experiments actually use)
    for name in ALL_MODELS {
        let g = build(name).unwrap();
        for p in [Platform::diana(), Platform::diana_ne16()] {
            let fast = baselines::min_cost(&g, &p, CostObjective::Latency);
            let slow = baselines::min_cost_enum(&g, &p, CostObjective::Latency);
            assert_eq!(fast, slow, "{name} on {}", p.name);
        }
    }
}

#[test]
fn prop_energy_dp_cost_matches_enumerator() {
    // the Pareto DP must reach the enumerator's minimal energy cost
    // exactly (mappings may differ only on exact cost ties)
    for (p, max_cout) in [(Platform::diana(), 512), (Platform::diana_ne16(), 160)] {
        for seed in 0..CASES {
            let mut rng = Pcg32::new(seed, 24);
            let node = random_conv_node(&mut rng, max_cout);
            let fast = baselines::layer_counts(&p, &node, CostObjective::Energy);
            let slow = baselines::layer_counts_enum(&p, &node, CostObjective::Energy);
            assert_eq!(fast.iter().sum::<usize>(), node.cout, "seed {seed}");
            let cf = baselines::cost_of_counts(&p, &node, &fast, CostObjective::Energy);
            let cs = baselines::cost_of_counts(&p, &node, &slow, CostObjective::Energy);
            // 1e-9 relative: exact parity modulo f64 association noise
            // in the DP's internal prefix sums
            assert!(
                (cf - cs).abs() <= 1e-9 * cs.abs().max(1.0),
                "seed {seed} on {}: DP cost {cf} != enum cost {cs} (cout {})",
                p.name, node.cout
            );
        }
    }
}

#[test]
fn prop_water_fill_is_latency_optimal_nacc4() {
    // beyond the enumerator's exact range: on the 4-unit MPSoC no
    // random split may beat the water-filled span
    let p = Platform::mpsoc4();
    for seed in 0..CASES {
        let mut rng = Pcg32::new(seed, 25);
        let node = random_conv_node(&mut rng, 256);
        let counts = baselines::layer_counts(&p, &node, CostObjective::Latency);
        assert_eq!(counts.iter().sum::<usize>(), node.cout, "seed {seed}");
        let span = baselines::cost_of_counts(&p, &node, &counts, CostObjective::Latency);
        // random competitor splits
        for _ in 0..20 {
            let mut rival = vec![0usize; p.n_acc()];
            for _ in 0..node.cout {
                rival[rng.below(p.n_acc() as u32) as usize] += 1;
            }
            let rs = baselines::cost_of_counts(&p, &node, &rival, CostObjective::Latency);
            assert!(
                span <= rs,
                "seed {seed}: water-fill span {span} beaten by random {rs} ({rival:?})"
            );
        }
    }
}

/// A synthetic many-unit platform that forces granularity coarsening in
/// both min-cost implementations (6 units -> enum_step/dp_step > 1).
fn six_unit_platform() -> Platform {
    let unit = |i: usize, mpc: f64| AcceleratorSpec {
        name: format!("u{i}"),
        weight_bits: 8,
        act_bits: 8,
        da_bits: None,
        latency: LatencyModel::Proportional { macs_per_cycle: mpc },
        p_act_mw: 10.0 + i as f64,
        p_idle_mw: 0.5 + 0.1 * i as f64,
        wmem_bytes: None,
    };
    Platform {
        name: "six".into(),
        f_clk_hz: 1e9,
        l1_bytes: 1 << 20,
        dw_acc: 0,
        accelerators: (0..6).map(|i| unit(i, [2.0, 3.0, 5.0, 7.0, 11.0, 13.0][i])).collect(),
    }
}

#[test]
fn regression_coarse_granularity_splits_sum_to_cout() {
    // regression for the min-cost granularity bounding: when the
    // channel grid coarsens (many units) and cout is not a multiple of
    // the step, the remainder must still be assigned — every split has
    // to sum to cout exactly, for every objective and implementation
    let p = six_unit_platform();
    let mut rng = Pcg32::new(99, 26);
    for &cout in &[97usize, 250, 333, 500, 511] {
        let mut node = random_conv_node(&mut rng, 512);
        node.cout = cout;
        for objective in [CostObjective::Latency, CostObjective::Energy] {
            for (label, counts) in [
                ("fast", baselines::layer_counts(&p, &node, objective)),
                ("enum", baselines::layer_counts_enum(&p, &node, objective)),
            ] {
                assert_eq!(counts.len(), p.n_acc(), "{label} {objective:?} cout {cout}");
                assert_eq!(
                    counts.iter().sum::<usize>(),
                    cout,
                    "{label} {objective:?}: split {counts:?} does not sum to cout {cout}"
                );
            }
        }
    }
}

#[test]
fn min_cost_mapping_valid_on_all_builtin_platforms() {
    let g = build("tinycnn").unwrap();
    for name in Platform::BUILTIN_NAMES {
        let p = Platform::by_name(name).unwrap();
        for objective in [CostObjective::Latency, CostObjective::Energy] {
            let m = baselines::min_cost(&g, &p, objective);
            m.validate(&g, p.n_acc()).unwrap();
            let split = m.channel_split(p.n_acc());
            for n in g.mappable() {
                assert_eq!(split[&n.name].iter().sum::<usize>(), n.cout, "{name}");
            }
        }
    }
}

// ---- N >= 3 platform properties (3-accelerator example SoC) ----------

#[test]
fn prop_nacc3_split_conservation() {
    let p = Platform::diana_ne16();
    for seed in 0..CASES {
        let mut rng = Pcg32::new(seed, 19);
        let g = build(ALL_MODELS[(seed % 4) as usize]).unwrap();
        let m = random_mapping_n(&g, p.n_acc(), &mut rng);
        m.validate(&g, p.n_acc()).unwrap();
        let split = m.channel_split(p.n_acc());
        for n in g.mappable() {
            let counts = &split[&n.name];
            assert_eq!(counts.len(), p.n_acc(), "seed {seed} {}", n.name);
            assert_eq!(
                counts.iter().sum::<usize>(),
                n.cout,
                "seed {seed} layer {}: counts {counts:?} do not conserve channels",
                n.name
            );
        }
        let fr = m.channel_frac(p.n_acc());
        assert!((fr.iter().sum::<f64>() - 1.0).abs() < 1e-9, "seed {seed}: {fr:?}");
    }
}

#[test]
fn prop_nacc3_busy_frac_bounded() {
    let p = Platform::diana_ne16();
    for seed in 0..CASES {
        let mut rng = Pcg32::new(seed, 20);
        let g = build(ALL_MODELS[(seed % 4) as usize]).unwrap();
        let m = random_mapping_n(&g, p.n_acc(), &mut rng);
        let r = simulate(&g, &m.channel_split(p.n_acc()), &p, SocConfig::default());
        assert_eq!(r.util.len(), p.n_acc());
        for (i, &u) in r.util.iter().enumerate() {
            assert!(
                (0.0..=1.0 + 1e-12).contains(&u),
                "seed {seed}: busy_frac[{i}] = {u} out of [0, 1]"
            );
        }
        assert!(r.total_cycles > 0 && r.energy_uj > 0.0, "seed {seed}");
    }
}

#[test]
fn prop_nacc3_idle_plus_union_is_one() {
    let p = Platform::diana_ne16();
    for seed in 0..CASES {
        let mut rng = Pcg32::new(seed, 21);
        let g = build(ALL_MODELS[(seed % 4) as usize]).unwrap();
        let m = random_mapping_n(&g, p.n_acc(), &mut rng);
        let r = simulate(&g, &m.channel_split(p.n_acc()), &p, SocConfig::default());
        let u = r.timeline.utilization();
        assert!(
            (u.idle_frac + u.union_frac - 1.0).abs() < 1e-9,
            "seed {seed}: idle {} + union {} != 1",
            u.idle_frac,
            u.union_frac
        );
        // union is bounded by the sum of per-unit busy fractions and is
        // at least the largest of them
        let max_busy = u.busy_frac.iter().copied().fold(0.0f64, f64::max);
        let sum_busy: f64 = u.busy_frac.iter().sum();
        assert!(u.union_frac >= max_busy - 1e-9, "seed {seed}");
        assert!(u.union_frac <= sum_busy + 1e-9, "seed {seed}");
        assert!(u.all_busy_frac <= u.union_frac + 1e-12, "seed {seed}");
    }
}

#[test]
fn prop_nacc3_sublayers_cover_all_units() {
    let p = Platform::diana_ne16();
    for seed in 0..CASES {
        let mut rng = Pcg32::new(seed, 22);
        let g = build("resnet20").unwrap();
        let m = random_mapping_n(&g, p.n_acc(), &mut rng);
        for n in g.mappable() {
            let subs = sublayers(n, m.layer(&n.name));
            let covered: usize = subs.iter().map(|s| s.2).sum();
            assert_eq!(covered, n.cout, "seed {seed}");
            assert!(subs.iter().all(|s| (s.0 as usize) < p.n_acc()), "seed {seed}");
        }
    }
}
