//! Property-based tests on coordinator invariants (mini-proptest built
//! on the in-tree PRNG: randomized cases with printed seeds so failures
//! reproduce deterministically).

use std::collections::BTreeMap;

use odimo::coordinator::partition::{partition, sublayers};
use odimo::coordinator::{baselines, discretize::discretize, Mapping, SearchPoint};
use odimo::hw::soc::{simulate, SocConfig};
use odimo::model::{build, Graph, ALL_MODELS, AIMC, DIG};
use odimo::util::prng::Pcg32;

const CASES: u64 = 40;

fn random_mapping(g: &Graph, rng: &mut Pcg32) -> Mapping {
    let mut m = Mapping::uniform(g, DIG);
    for n in g.mappable() {
        let p = rng.next_f32(); // layer-level bias so extremes appear
        let ids = (0..n.cout)
            .map(|_| if rng.next_f32() < p { AIMC as u8 } else { DIG as u8 })
            .collect();
        m.assign.insert(n.name.clone(), ids);
    }
    m
}

#[test]
fn prop_mapping_roundtrips_json() {
    for seed in 0..CASES {
        let mut rng = Pcg32::new(seed, 11);
        let g = build(ALL_MODELS[(seed % 4) as usize]).unwrap();
        let m = random_mapping(&g, &mut rng);
        let j = m.to_json().to_string();
        let back = Mapping::from_json(&odimo::util::json::parse(&j).unwrap()).unwrap();
        assert_eq!(m, back, "seed {seed}");
    }
}

#[test]
fn prop_split_counts_sum_to_cout() {
    for seed in 0..CASES {
        let mut rng = Pcg32::new(seed, 12);
        let g = build(ALL_MODELS[(seed % 4) as usize]).unwrap();
        let m = random_mapping(&g, &mut rng);
        let split = m.channel_split();
        for n in g.mappable() {
            let (d, a) = split[&n.name];
            assert_eq!(d + a, n.cout, "seed {seed} layer {}", n.name);
        }
        // aimc_fraction consistent with the split
        let total: usize = g.mappable().iter().map(|n| n.cout).sum();
        let aimc: usize = split.values().map(|&(_, a)| a).sum();
        assert!((m.aimc_fraction() - aimc as f64 / total as f64).abs() < 1e-12);
    }
}

#[test]
fn prop_simulator_latency_bounded_by_extremes() {
    // any split's latency lies between the best single-accelerator
    // latency per layer (lower bound: max is at least each side alone
    // of the same split... we use global extremes as sanity bounds)
    for seed in 0..CASES {
        let mut rng = Pcg32::new(seed, 13);
        let g = build(ALL_MODELS[(seed % 4) as usize]).unwrap();
        let m = random_mapping(&g, &mut rng);
        let r = simulate(&g, &m.channel_split(), SocConfig::default());
        let dig = simulate(
            &g,
            &Mapping::uniform(&g, DIG).channel_split(),
            SocConfig::default(),
        );
        assert!(r.total_cycles <= dig.total_cycles, "seed {seed}");
        assert!(r.total_cycles > 0);
        assert!(r.energy_uj > 0.0);
        // utilization fractions are fractions
        assert!((0.0..=1.0).contains(&r.util[0]) && (0.0..=1.0).contains(&r.util[1]));
    }
}

#[test]
fn prop_min_cost_is_optimal_per_layer() {
    // exhaustive per-layer optimality: no random split may beat the
    // min_cost baseline's per-layer max-latency
    use odimo::hw::latency::layer_lats;
    let g = build("resnet20").unwrap();
    let mc = baselines::min_cost(&g, baselines::CostObjective::Latency);
    let split = mc.channel_split();
    for seed in 0..CASES {
        let mut rng = Pcg32::new(seed, 14);
        for n in g.mappable() {
            let cd = rng.below(n.cout as u32 + 1) as usize;
            let (rd, ra) = layer_lats(n, cd as u64, (n.cout - cd) as u64);
            let (md, ma) = {
                let (d, a) = split[&n.name];
                layer_lats(n, d as u64, a as u64)
            };
            assert!(
                md.max(ma) <= rd.max(ra),
                "seed {seed} layer {}: min_cost {} beaten by random {}",
                n.name,
                md.max(ma),
                rd.max(ra)
            );
        }
    }
}

#[test]
fn prop_sublayers_partition_channels() {
    for seed in 0..CASES {
        let mut rng = Pcg32::new(seed, 15);
        let g = build(ALL_MODELS[(seed % 4) as usize]).unwrap();
        let m = random_mapping(&g, &mut rng);
        for n in g.mappable() {
            let subs = sublayers(n, m.layer(&n.name));
            let covered: usize = subs.iter().map(|s| s.2).sum();
            assert_eq!(covered, n.cout, "seed {seed}");
            let mut pos = 0;
            for (acc, start, len) in subs {
                assert_eq!(start, pos);
                assert!(acc == DIG as u8 || acc == AIMC as u8);
                pos += len;
            }
        }
    }
}

#[test]
fn prop_discretize_respects_argmax() {
    for seed in 0..CASES {
        let mut rng = Pcg32::new(seed, 16);
        let g = build("tinycnn").unwrap();
        let mut alphas = BTreeMap::new();
        for n in g.mappable() {
            let v: Vec<f32> = (0..2 * n.cout).map(|_| rng.next_f32() * 4.0 - 2.0).collect();
            alphas.insert(n.name.clone(), v);
        }
        let m = discretize(&g, &alphas).unwrap();
        for n in g.mappable() {
            let a = &alphas[&n.name];
            for c in 0..n.cout {
                let want = if a[n.cout + c] > a[c] { AIMC } else { DIG } as u8;
                assert_eq!(m.layer(&n.name)[c], want, "seed {seed} {} ch {c}", n.name);
            }
        }
    }
}

#[test]
fn prop_pareto_front_is_nondominated() {
    use odimo::metrics::{dominates, pareto_front};
    for seed in 0..CASES {
        let mut rng = Pcg32::new(seed, 17);
        let pts: Vec<SearchPoint> = (0..20)
            .map(|i| SearchPoint {
                label: format!("p{i}"),
                lambda: 0.0,
                accuracy: rng.next_f32() as f64,
                latency_ms: rng.next_f32() as f64 * 10.0,
                energy_uj: rng.next_f32() as f64 * 100.0,
                total_cycles: 1,
                util: [0.5, 0.5],
                aimc_channel_frac: 0.0,
                mapping: Mapping { assign: BTreeMap::new() },
            })
            .collect();
        let front = pareto_front(&pts, |p| p.latency_ms);
        // no front point dominated by any other point
        for &i in &front {
            for (j, q) in pts.iter().enumerate() {
                if i != j {
                    assert!(
                        !dominates(q, &pts[i], |p| p.latency_ms),
                        "seed {seed}: front point {i} dominated by {j}"
                    );
                }
            }
        }
        // every non-front point dominated by some front point
        for (j, q) in pts.iter().enumerate() {
            if !front.contains(&j) {
                assert!(
                    front.iter().any(|&i| dominates(&pts[i], q, |p| p.latency_ms)),
                    "seed {seed}: non-front point {j} not dominated"
                );
            }
        }
    }
}

#[test]
fn prop_partition_fragments_bounded() {
    // after partitioning, a group leader has <= 2 fragments and every
    // layer has <= cout fragments; permuted mapping preserves counts
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("tinycnn_meta.json").exists() {
        return;
    }
    let meta = odimo::runtime::ArtifactMeta::load(&dir, "tinycnn").unwrap();
    let values = meta.load_init_values().unwrap();
    for seed in 0..10 {
        let mut rng = Pcg32::new(seed, 18);
        let m = random_mapping(&meta.model, &mut rng);
        let part = partition(&meta, &meta.model, &m, &values).unwrap();
        let before = m.channel_split();
        let after = part.mapping.channel_split();
        assert_eq!(before, after, "seed {seed}: split counts changed");
        for (layer, frags) in &part.fragments {
            let n = meta.model.node(layer).unwrap();
            assert!(*frags <= n.cout, "seed {seed} {layer}");
        }
    }
}
