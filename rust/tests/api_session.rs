//! Integration tests for the `odimo::api` facade through the public
//! surface only: builder validation errors, typed mapping dispatch,
//! the lazily cached sweep frontier (including platform-spec
//! invalidation), and smoke-sized serving defaults.

use odimo::api::{CostObjective, MappingSpec, ServeOpts, Session, SessionBuilder};
use odimo::hw::Platform;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("odimo_api_it_{tag}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn tiny(dir: &std::path::Path) -> Session {
    SessionBuilder::new("tinycnn")
        .platform("diana")
        .threads(2)
        .seed(7)
        .results_dir(dir)
        .sweep_calib(4)
        .sweep_blend_steps(2)
        .build()
        .unwrap()
}

// ---- builder validation -----------------------------------------------

#[test]
fn unknown_model_is_a_build_error() {
    let e = SessionBuilder::new("resnet999").build().unwrap_err().to_string();
    assert!(e.contains("resnet999"), "{e}");
    assert!(e.contains("tinycnn"), "error should list the known models: {e}");
}

#[test]
fn unknown_platform_is_a_build_error() {
    let e = SessionBuilder::new("tinycnn")
        .platform("tpu9000")
        .build()
        .unwrap_err()
        .to_string();
    assert!(e.contains("tpu9000"), "{e}");
    assert!(e.contains("diana"), "error should list the built-ins: {e}");
}

#[test]
fn zero_threads_is_a_build_error() {
    let e = SessionBuilder::new("tinycnn").threads(0).build().unwrap_err().to_string();
    assert!(e.contains("threads"), "{e}");
}

#[test]
fn missing_platform_toml_path_is_a_build_error() {
    let e = SessionBuilder::new("tinycnn")
        .platform("/no/such/platform.toml")
        .build()
        .unwrap_err()
        .to_string();
    assert!(e.contains("platform"), "{e}");
}

#[test]
fn garbage_platform_toml_is_a_build_error() {
    let dir = tmpdir("badtoml");
    let path = dir.join("broken.toml");
    std::fs::write(&path, "[platform\nname = ").unwrap();
    let e = SessionBuilder::new("tinycnn")
        .platform(path.to_str().unwrap())
        .build()
        .unwrap_err()
        .to_string();
    assert!(!e.is_empty(), "{e}");
}

#[test]
fn platform_toml_builds_a_working_session() {
    let dir = tmpdir("goodtoml");
    let path = dir.join("mini.toml");
    std::fs::write(
        &path,
        "[platform]\nname = \"mini\"\nf_clk_hz = 100e6\naccelerators = [\"pe\"]\n\
         [accel.pe]\nkind = \"digital_pe\"\npe = 16\nweight_bits = 8\nact_bits = 8\n\
         p_act_mw = 10.0\np_idle_mw = 1.0\n",
    )
    .unwrap();
    let s = SessionBuilder::new("tinycnn")
        .platform(path.to_str().unwrap())
        .threads(1)
        .build()
        .unwrap();
    assert_eq!(s.platform().name, "mini");
    let m = s.mapping(&MappingSpec::Baseline("all_8bit".into())).unwrap();
    assert!(s.simulate(&m).unwrap().total_cycles > 0);
}

// ---- typed mapping dispatch -------------------------------------------

#[test]
fn unknown_baseline_is_a_clear_error() {
    let dir = tmpdir("badbaseline");
    let s = tiny(&dir);
    let e = s
        .mapping(&MappingSpec::Baseline("fastest_please".into()))
        .unwrap_err()
        .to_string();
    assert!(e.contains("fastest_please"), "{e}");
    assert!(e.contains("min_cost_lat"), "error should list the baselines: {e}");
}

#[test]
fn mapping_file_roundtrips_and_validates() {
    let dir = tmpdir("mapfile");
    let s = tiny(&dir);
    let m = s.mapping(&MappingSpec::MinCost(CostObjective::Latency)).unwrap();
    let path = dir.join("mapping.json");
    std::fs::write(&path, m.to_json().to_string()).unwrap();
    let back = s.mapping(&MappingSpec::File(path.clone())).unwrap();
    assert_eq!(back, m);
    // a file for the wrong model fails validation, not simulation
    let other = SessionBuilder::new("resnet20")
        .platform("diana")
        .threads(1)
        .build()
        .unwrap();
    assert!(other.mapping(&MappingSpec::File(path)).is_err());
    // a missing file is a read error with the path in it
    let e = s
        .mapping(&MappingSpec::File(dir.join("nope.json")))
        .unwrap_err()
        .to_string();
    assert!(e.contains("nope.json"), "{e}");
}

#[test]
fn min_cost_spec_matches_named_baseline() {
    let dir = tmpdir("mincost");
    let s = tiny(&dir);
    let a = s.mapping(&MappingSpec::MinCost(CostObjective::Latency)).unwrap();
    let b = s.mapping(&MappingSpec::Baseline("min_cost_lat".into())).unwrap();
    assert_eq!(a, b);
    let a = s.mapping(&MappingSpec::MinCost(CostObjective::Energy)).unwrap();
    let b = s.mapping(&MappingSpec::Baseline("min_cost_en".into())).unwrap();
    assert_eq!(a, b);
}

// ---- frontier caching & invalidation ----------------------------------

#[test]
fn sweep_caches_in_memory_and_on_disk() {
    let dir = tmpdir("sweepcache");
    let mut s = tiny(&dir);
    let first_len = {
        let r = s.sweep().unwrap();
        assert!(!r.cache_hit, "first sweep computes");
        r.points.len()
    };
    // in-memory: same session, same result object
    assert_eq!(s.sweep().unwrap().points.len(), first_len);
    assert!(s.frontier_path().exists());
    // on-disk: a fresh session over the same results dir hits the cache
    let mut s2 = tiny(&dir);
    let r2 = s2.sweep().unwrap();
    assert!(r2.cache_hit, "second session must hit the disk cache");
    assert_eq!(r2.points.len(), first_len);
}

#[test]
fn non_ideal_l1_sessions_refuse_to_sweep() {
    // same contract as the CLI rejecting --non-ideal-l1 on sweep/serve:
    // the frontier is ideal-L1-scored, so a mismatched simulator config
    // must be an error, not a silent inconsistency
    let dir = tmpdir("l1sweep");
    let mut s = SessionBuilder::new("tinycnn")
        .platform("diana")
        .threads(1)
        .results_dir(&dir)
        .non_ideal_l1(true)
        .build()
        .unwrap();
    let e = s.sweep().unwrap_err().to_string();
    assert!(e.contains("ideal-L1"), "{e}");
    assert!(s.serve(&ServeOpts::default()).is_err());
}

#[test]
fn edited_platform_spec_invalidates_frontier_through_facade() {
    let dir = tmpdir("sweepedit");
    let mut s = tiny(&dir);
    s.sweep().unwrap();
    // same platform *name*, one edited power number — as if the
    // operator edited config/diana.toml between runs
    let mut edited = Platform::diana();
    edited.accelerators[1].p_act_mw += 0.5;
    let mut s2 = SessionBuilder::new("tinycnn")
        .platform_spec(edited)
        .threads(2)
        .seed(7)
        .results_dir(&dir)
        .sweep_calib(4)
        .sweep_blend_steps(2)
        .build()
        .unwrap();
    let r = s2.sweep().unwrap();
    assert!(!r.cache_hit, "edited platform spec must re-sweep, not reuse the cache");
}

// ---- serving through the facade ---------------------------------------

#[test]
fn smoke_sessions_default_to_tiny_request_streams() {
    let dir = tmpdir("smokeserve");
    let mut s = SessionBuilder::new("tinycnn")
        .platform("diana")
        .threads(2)
        .seed(7)
        .results_dir(&dir)
        .sweep_calib(4)
        .sweep_blend_steps(2)
        .smoke(true)
        .build()
        .unwrap();
    let rep = s.serve(&ServeOpts::default()).unwrap();
    assert_eq!(rep.total_requests, 24, "smoke default stream size");
    // explicit n_requests overrides the smoke default
    let rep = s.serve(&ServeOpts { n_requests: Some(10), ..ServeOpts::default() }).unwrap();
    assert_eq!(rep.total_requests, 10);
    // and the report is loadable back through the facade
    assert_eq!(s.serve_report().unwrap().total_requests, 10);
}
