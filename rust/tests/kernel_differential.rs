//! SIMD-vs-scalar differential suite for the kernel backends.
//!
//! The no-FMA contract (docs/ARCHITECTURE.md §Kernels) makes every
//! backend bit-exact against the scalar reference loops: SIMD lanes
//! vectorize across *independent outputs* while each reduction walks k
//! in the same ascending order with separate mul+add rounding. These
//! tests pin that contract with `assert_eq!` (not a tolerance) across
//! every bundled model, three platforms, ragged GEMM shapes, thread
//! counts, and the direct-vs-im2col convolution paths — plus the
//! panel-reuse guarantee: zero scratch heap allocations in steady
//! state.

use odimo::hw::Platform;
use odimo::model::{mbv1_025, resnet18s, resnet20, tinycnn, Graph};
use odimo::quant::simd;
use odimo::quant::{
    synth_mapping, synth_mapping_n, synth_params, synth_params_on, ConvAlgo, Isa, KernelBackend,
    ParamSet, QuantNet,
};
use odimo::util::pool::ThreadPool;
use odimo::util::prng::Pcg32;

fn random_input(g: &Graph, batch: usize, seed: u64) -> Vec<f32> {
    let (c, h, w) = g.input_shape;
    let mut rng = Pcg32::new(seed, 77);
    (0..batch * c * h * w).map(|_| rng.next_f32()).collect()
}

fn compile(
    g: &Graph,
    p: &Platform,
    params: &ParamSet<'_>,
    mapping: &odimo::coordinator::Mapping,
    backend: KernelBackend,
) -> QuantNet {
    QuantNet::compile_params_backend(params, g, mapping, p, backend).unwrap()
}

#[test]
fn simd_matches_scalar_on_every_bundled_model() {
    // all four bundled models on diana; big models at batch 1 to keep
    // the suite quick, small ones with a real batch
    for (g, batch, seed) in [
        (tinycnn(), 4usize, 1001u64),
        (resnet20(), 2, 1002),
        (resnet18s(), 1, 1003),
        (mbv1_025(), 1, 1004),
    ] {
        let p = Platform::diana();
        let (names, values) = synth_params(&g, seed);
        let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
        let mapping = synth_mapping(&g, seed ^ 7);
        let x = random_input(&g, batch, seed ^ 13);
        let scalar = compile(&g, &p, &params, &mapping, KernelBackend::Scalar);
        let fast = compile(&g, &p, &params, &mapping, KernelBackend::Simd);
        assert_eq!(scalar.isa(), Isa::Scalar);
        assert_ne!(fast.isa(), Isa::Scalar, "{}: Simd must not resolve to Scalar", g.name);
        let want = scalar.forward(&x, batch).unwrap();
        let got = fast.forward(&x, batch).unwrap();
        assert_eq!(got, want, "{}: {:?} diverged from scalar", g.name, fast.isa());
    }
}

#[test]
fn simd_matches_scalar_on_gap9_and_mpsoc4() {
    // gap9 has no D/A unit; mpsoc4 carries two distinct D/A widths, so
    // the per-width view materialization runs through the SIMD D/A pass
    let g = tinycnn();
    for (p, n_acc, seed) in [(Platform::gap9(), 2usize, 2001u64), (Platform::mpsoc4(), 4, 2002)] {
        let (names, values) = synth_params_on(&g, &p, seed);
        let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
        let x = random_input(&g, 3, seed ^ 5);
        for ms in [31u64, 32, 33] {
            let mapping = synth_mapping_n(&g, n_acc, ms);
            let scalar = compile(&g, &p, &params, &mapping, KernelBackend::Scalar);
            let fast = compile(&g, &p, &params, &mapping, KernelBackend::Simd);
            let want = scalar.forward(&x, 3).unwrap();
            let got = fast.forward(&x, 3).unwrap();
            assert_eq!(got, want, "{}/{ms}: simd diverged from scalar", p.name);
        }
    }
}

#[test]
fn backends_deterministic_across_thread_counts() {
    // every pooled execution mode (plain, batch-block, channel-tiled)
    // must be bit-identical across backends *and* thread counts
    let g = resnet20();
    let (names, values) = synth_params(&g, 3003);
    let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
    let mapping = synth_mapping(&g, 35);
    let p = Platform::diana();
    let x = random_input(&g, 4, 3007);
    let scalar = compile(&g, &p, &params, &mapping, KernelBackend::Scalar);
    let fast = compile(&g, &p, &params, &mapping, KernelBackend::Simd);
    let want = scalar.forward(&x, 4).unwrap();
    assert_eq!(fast.forward(&x, 4).unwrap(), want);
    for threads in [1usize, 2, 8] {
        let pool = ThreadPool::new(threads);
        for (engine, tag) in [(&scalar, "scalar"), (&fast, "simd")] {
            let got = engine.forward_pool(&x, 4, &pool).unwrap();
            assert_eq!(got, want, "{tag} x {threads} threads diverged");
        }
    }
}

#[test]
fn direct_conv_paths_match_im2col() {
    // resnet20 is full of 3x3 stride-1 convs (Direct3x3); mbv1_025's
    // pointwise layers are 1x1 stride-1 pad-0 (Direct1x1). Forcing
    // Im2col everywhere must not change a single bit, on either backend.
    for (g, want_algo, batch, seed) in [
        (resnet20(), ConvAlgo::Direct3x3, 2usize, 4001u64),
        (mbv1_025(), ConvAlgo::Direct1x1, 1, 4002),
    ] {
        let p = Platform::diana();
        let (names, values) = synth_params(&g, seed);
        let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
        let mapping = synth_mapping(&g, seed ^ 3);
        let x = random_input(&g, batch, seed ^ 9);
        for backend in [KernelBackend::Scalar, KernelBackend::Simd] {
            let auto = compile(&g, &p, &params, &mapping, backend);
            assert!(
                auto.conv_algos().iter().any(|(_, a)| *a == want_algo),
                "{}: heuristic never picked {want_algo:?}: {:?}",
                g.name,
                auto.conv_algos()
            );
            let im2col = QuantNet::compile_params_with(
                &params,
                &g,
                &mapping,
                &p,
                backend,
                Some(ConvAlgo::Im2col),
            )
            .unwrap();
            assert!(im2col.conv_algos().iter().all(|(_, a)| *a == ConvAlgo::Im2col));
            let want = im2col.forward(&x, batch).unwrap();
            let got = auto.forward(&x, batch).unwrap();
            assert_eq!(got, want, "{} ({backend:?}): direct path diverged from im2col", g.name);
        }
    }
}

#[test]
fn gemm_backends_agree_on_ragged_shapes() {
    // shapes straddling every register-tile edge: m < MR, n % lane
    // width != 0, k == 1, and combinations thereof
    let fast = KernelBackend::Simd.resolve();
    let mut rng = Pcg32::new(909, 17);
    for &m in &[1usize, 2, 3, 4, 5, 7] {
        for &n in &[1usize, 5, 15, 16, 17, 31, 33] {
            for &k in &[1usize, 3, 8, 9] {
                let a: Vec<f32> = (0..m * k).map(|_| rng.next_f32() - 0.5).collect();
                let b: Vec<f32> = (0..k * n).map(|_| rng.next_f32() - 0.5).collect();
                let mut want = vec![0f32; m * n];
                let mut got = vec![0f32; m * n];
                simd::gemm(Isa::Scalar, &a, &b, m, k, n, &mut want);
                simd::gemm(fast, &a, &b, m, k, n, &mut got);
                assert_eq!(got, want, "gemm {m}x{k}x{n} diverged on {fast:?}");
            }
        }
    }
}

#[test]
fn scratch_allocations_reach_steady_state() {
    // panel reuse: after the first forward per batch shape the pooled
    // scratches never touch the heap again — repeated runs allocate
    // exactly as much as a single run
    let g = tinycnn();
    let (names, values) = synth_params(&g, 5005);
    let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
    let mapping = synth_mapping(&g, 51);
    let p = Platform::diana();
    let x = random_input(&g, 3, 5009);

    let once = compile(&g, &p, &params, &mapping, KernelBackend::Simd);
    once.forward(&x, 3).unwrap();
    let single_run = once.scratch_allocs();
    assert!(single_run > 0, "presize must report its initial reservations");

    let thrice = compile(&g, &p, &params, &mapping, KernelBackend::Simd);
    for _ in 0..3 {
        thrice.forward(&x, 3).unwrap();
    }
    assert!(
        thrice.scratch_allocs() <= single_run,
        "3 runs allocated {} > 1 run's {}",
        thrice.scratch_allocs(),
        single_run
    );

    // steady-state delta is exactly zero on the sequential path...
    let before = thrice.scratch_allocs();
    thrice.forward(&x, 3).unwrap();
    assert_eq!(thrice.scratch_allocs(), before, "steady-state forward hit the heap");

    // ...and on the pooled paths. One engine per path: channel-tiled
    // (batch < threads, one scratch) and batch-block with uniform
    // blocks (batch % threads == 0, so any scratch fits any block —
    // the pool hands scratches back in nondeterministic order).
    let pool = ThreadPool::new(2);
    let tiled = compile(&g, &p, &params, &mapping, KernelBackend::Simd);
    let x1 = random_input(&g, 1, 5011);
    tiled.forward_pool(&x1, 1, &pool).unwrap();
    let warm = tiled.scratch_allocs();
    tiled.forward_pool(&x1, 1, &pool).unwrap();
    assert_eq!(tiled.scratch_allocs(), warm, "tiled steady-state forward hit the heap");

    let block = compile(&g, &p, &params, &mapping, KernelBackend::Simd);
    let x4 = random_input(&g, 4, 5013);
    block.forward_pool(&x4, 4, &pool).unwrap();
    let warm = block.scratch_allocs();
    block.forward_pool(&x4, 4, &pool).unwrap();
    assert_eq!(block.scratch_allocs(), warm, "batch-block steady-state forward hit the heap");
}
