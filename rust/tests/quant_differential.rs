//! Differential property tests for the planned quantized engine: under
//! seeded random channel mappings the im2col/GEMM engine must match the
//! naive interpreter oracle (`quant::ref`, the pre-rewrite code) within
//! 1e-4 on the logits, and the pooled paths must be bit-deterministic
//! across thread counts. No artifacts needed — parameters are synthetic.

use odimo::coordinator::Mapping;
use odimo::hw::Platform;
use odimo::model::{resnet20, tinycnn, Graph, AIMC};
use odimo::quant::r#ref::{calibrate_act_maxima_ref, RefNet};
use odimo::quant::{
    calibrate_act_maxima_params, synth_mapping as random_mapping, synth_params, ParamSet,
    QuantNet,
};
use odimo::util::pool::ThreadPool;
use odimo::util::prng::Pcg32;

fn random_input(g: &Graph, batch: usize, seed: u64) -> Vec<f32> {
    let (c, h, w) = g.input_shape;
    let mut rng = Pcg32::new(seed, 77);
    (0..batch * c * h * w).map(|_| rng.next_f32()).collect()
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max)
}

#[test]
fn engine_matches_oracle_random_mappings_tinycnn() {
    let g = tinycnn();
    let (names, values) = synth_params(&g, 101);
    let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
    let x = random_input(&g, 6, 41);
    for seed in [1u64, 2, 3, 4, 5] {
        let mapping = random_mapping(&g, seed);
        let engine = QuantNet::compile_params(&params, &g, &mapping, &Platform::diana()).unwrap();
        let oracle = RefNet::compile(&params, &g, &mapping, &Platform::diana()).unwrap();
        let got = engine.forward(&x, 6).unwrap();
        let want = oracle.forward(&x, 6).unwrap();
        let d = max_abs_diff(&got, &want);
        assert!(d < 1e-4, "seed {seed}: engine diverged from oracle by {d}");
    }
}

#[test]
fn engine_matches_oracle_random_mapping_resnet20() {
    let g = resnet20();
    let (names, values) = synth_params(&g, 202);
    let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
    let x = random_input(&g, 2, 43);
    for seed in [9u64, 10] {
        let mapping = random_mapping(&g, seed);
        let engine = QuantNet::compile_params(&params, &g, &mapping, &Platform::diana()).unwrap();
        let oracle = RefNet::compile(&params, &g, &mapping, &Platform::diana()).unwrap();
        let got = engine.forward(&x, 2).unwrap();
        let want = oracle.forward(&x, 2).unwrap();
        let d = max_abs_diff(&got, &want);
        assert!(d < 1e-4, "seed {seed}: engine diverged from oracle by {d}");
    }
}

#[test]
fn uniform_aimc_matches_oracle_resnet20() {
    // all-AIMC exercises the once-per-tensor 7-bit D/A path everywhere
    let g = resnet20();
    let (names, values) = synth_params(&g, 303);
    let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
    let x = random_input(&g, 1, 47);
    let mapping = Mapping::uniform(&g, AIMC);
    let engine = QuantNet::compile_params(&params, &g, &mapping, &Platform::diana()).unwrap();
    let oracle = RefNet::compile(&params, &g, &mapping, &Platform::diana()).unwrap();
    let d = max_abs_diff(&engine.forward(&x, 1).unwrap(), &oracle.forward(&x, 1).unwrap());
    assert!(d < 1e-4, "all-AIMC diverged by {d}");
}

#[test]
fn three_acc_engine_matches_oracle_random_mappings() {
    // the shipped 3-accelerator example platform: int8 / ternary / int4
    // channel groups coexist in every layer; the planned engine must
    // still match the naive oracle
    use odimo::quant::{synth_mapping_n, synth_params_on};
    let g = tinycnn();
    let p = Platform::diana_ne16();
    let (names, values) = synth_params_on(&g, &p, 808);
    let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
    let x = random_input(&g, 4, 67);
    for seed in [11u64, 12, 13] {
        let mapping = synth_mapping_n(&g, 3, seed);
        let engine = QuantNet::compile_params(&params, &g, &mapping, &p).unwrap();
        let oracle = RefNet::compile(&params, &g, &mapping, &p).unwrap();
        let d = max_abs_diff(&engine.forward(&x, 4).unwrap(), &oracle.forward(&x, 4).unwrap());
        assert!(d < 1e-4, "seed {seed}: 3-acc engine diverged from oracle by {d}");
    }
}

#[test]
fn distinct_da_width_macros_match_oracle() {
    // two IMC macros with distinct da_bits (7-bit imc0, 6-bit imc1 on
    // mpsoc4) plus digital/proportional units: per layer, channels read
    // the input through *different* D/A views and quantize outputs on
    // different grids; the planned engine must still be bit-exact vs
    // the naive oracle under seeded random 4-way mappings
    use odimo::quant::{synth_mapping_n, synth_params_on};
    let g = tinycnn();
    let p = Platform::mpsoc4();
    assert_eq!(p.da_widths(), vec![6, 7], "mpsoc4 must carry two distinct D/A widths");
    let (names, values) = synth_params_on(&g, &p, 909);
    let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
    let x = random_input(&g, 4, 71);
    for seed in [21u64, 22, 23] {
        let mapping = synth_mapping_n(&g, 4, seed);
        let engine = QuantNet::compile_params(&params, &g, &mapping, &p).unwrap();
        let oracle = RefNet::compile(&params, &g, &mapping, &p).unwrap();
        let d = max_abs_diff(&engine.forward(&x, 4).unwrap(), &oracle.forward(&x, 4).unwrap());
        assert!(d < 1e-4, "seed {seed}: distinct-da engine diverged from oracle by {d}");
    }
    // and the pooled paths stay bit-deterministic with per-width views
    let mapping = synth_mapping_n(&g, 4, 29);
    let engine = QuantNet::compile_params(&params, &g, &mapping, &p).unwrap();
    let want = engine.forward(&x, 4).unwrap();
    for threads in [2usize, 8] {
        let pool = ThreadPool::new(threads);
        let got = engine.forward_pool(&x, 4, &pool).unwrap();
        assert_eq!(got, want, "{threads}-thread pool changed mpsoc4 logits");
    }
}

#[test]
fn no_da_platform_matches_oracle() {
    // gap9 carries no D/A unit at all: the engine must skip view
    // materialization entirely and still match the oracle
    use odimo::quant::{synth_mapping_n, synth_params_on};
    let g = tinycnn();
    let p = Platform::gap9();
    assert!(p.da_widths().is_empty());
    let (names, values) = synth_params_on(&g, &p, 910);
    let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
    let x = random_input(&g, 3, 73);
    for seed in [31u64, 32] {
        let mapping = synth_mapping_n(&g, 2, seed);
        let engine = QuantNet::compile_params(&params, &g, &mapping, &p).unwrap();
        let oracle = RefNet::compile(&params, &g, &mapping, &p).unwrap();
        let d = max_abs_diff(&engine.forward(&x, 3).unwrap(), &oracle.forward(&x, 3).unwrap());
        assert!(d < 1e-4, "seed {seed}: gap9 engine diverged from oracle by {d}");
    }
}

#[test]
fn pool_parallelism_is_deterministic_resnet20() {
    // batch 4 against 1 / 2 / 8 workers walks every execution mode:
    // plain forward (t=1), batch-block (t=2, batch >= threads), and
    // per-layer channel tiling (t=8, batch < threads)
    let g = resnet20();
    let (names, values) = synth_params(&g, 404);
    let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
    let mapping = random_mapping(&g, 21);
    let engine = QuantNet::compile_params(&params, &g, &mapping, &Platform::diana()).unwrap();
    let x = random_input(&g, 4, 53);
    let want = engine.forward(&x, 4).unwrap();
    for threads in [1usize, 2, 8] {
        let pool = ThreadPool::new(threads);
        let got = engine.forward_pool(&x, 4, &pool).unwrap();
        assert_eq!(got, want, "{threads}-thread pool changed the logits");
    }
}

#[test]
fn tiled_small_batch_is_deterministic() {
    // batch < threads takes the per-layer (image x channel-block) path
    let g = tinycnn();
    let (names, values) = synth_params(&g, 505);
    let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
    let mapping = random_mapping(&g, 31);
    let engine = QuantNet::compile_params(&params, &g, &mapping, &Platform::diana()).unwrap();
    for batch in [1usize, 3] {
        let x = random_input(&g, batch, 59);
        let want = engine.forward(&x, batch).unwrap();
        for threads in [2usize, 8] {
            let pool = ThreadPool::new(threads);
            let got = engine.forward_pool(&x, batch, &pool).unwrap();
            assert_eq!(got, want, "batch {batch} x {threads} threads diverged");
        }
    }
}

#[test]
fn calibrate_engine_matches_naive_reference() {
    for (g, seed) in [(tinycnn(), 606u64), (resnet20(), 707)] {
        let (names, values) = synth_params(&g, seed);
        let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
        let x = random_input(&g, 2, 61);
        let got = calibrate_act_maxima_params(&params, &g, &x, 2).unwrap();
        let want = calibrate_act_maxima_ref(&params, &g, &x, 2).unwrap();
        assert_eq!(got.len(), want.len(), "{}: node set differs", g.name);
        for (k, v) in &got {
            let wv = want[k];
            assert!(
                (v - wv).abs() <= 1e-5 * wv.abs().max(1.0),
                "{}/{k}: engine max {v} vs reference {wv}",
                g.name
            );
        }
    }
}
