//! Property pins for the graph importer (model/import.rs) and the
//! multi-model serve plane (serve/multi.rs): the checked-in golden
//! fixtures are byte-canonical; an exported built-in re-imported from
//! disk serves digest-for-digest like the native builder; a graph that
//! exists only as JSON runs map → simulate → sweep → serve end-to-end;
//! every documented validation error fires on a targeted tamper of the
//! canonical document; a single-model `serve_multi` replays both
//! `Session::serve_cluster` and `Session::serve`; and a mixed
//! two-model cluster conserves requests per (model, tenant) with a
//! digest invariant across 1/2/8 worker threads.

mod common;

use std::path::{Path, PathBuf};

use common::{assert_reports_identical, serve_opts, serve_session, N_REQUESTS, SEED};
use odimo::api::{ClusterOpts, MappingSpec, SessionBuilder};
use odimo::model::{tinycnn, Graph};

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../config").join(name)
}

/// Both committed fixtures parse, validate, and re-emit byte-for-byte
/// — and the tinycnn fixture IS the native builder's export, so the
/// schema in the repo cannot drift from the builders.
#[test]
fn golden_fixtures_are_byte_canonical() {
    for name in ["graph_tinycnn.json", "graph_custom.json"] {
        let path = fixture(name);
        let text = std::fs::read_to_string(&path).unwrap();
        let g = Graph::from_json_file(&path).unwrap();
        assert_eq!(g.to_json().to_string(), text, "{name}: fixture is not canonical");
    }
    let native = tinycnn();
    let imported = Graph::from_json_file(&fixture("graph_tinycnn.json")).unwrap();
    assert_eq!(imported.to_json().to_string(), native.to_json().to_string());
    assert_eq!(imported.spec_hash(), native.spec_hash());
    let custom = Graph::from_json_file(&fixture("graph_custom.json")).unwrap();
    assert_eq!(custom.name, "customnet");
    assert_eq!(custom.input_shape, (3, 16, 16));
}

/// Export→import round-trip through the serve plane: a session built
/// from the exported .json digests identically to one built from the
/// native builder (cold caches on both sides).
#[test]
fn imported_builtin_serves_digest_identical_to_native() {
    let dir_native = fresh_dir("odimo_import_native");
    let dir_imported = fresh_dir("odimo_import_imported");
    let export = dir_imported.join("tinycnn_export.json");
    tinycnn().save_json(&export).unwrap();

    let native = serve_session(&dir_native, 2, SEED).serve(&serve_opts(4)).unwrap();
    let imported = SessionBuilder::new(export.to_str().unwrap())
        .platform("diana")
        .results_dir(&dir_imported)
        .threads(2)
        .seed(SEED)
        .sweep_calib(4)
        .sweep_blend_steps(2)
        .plan_cache_cap(8)
        .build()
        .unwrap()
        .serve(&serve_opts(4))
        .unwrap();
    assert_reports_identical(&native, &imported, "import round-trip");
}

/// A graph that exists only as JSON (no native builder) runs the whole
/// pipeline: map a baseline, simulate it, sweep a frontier, serve a
/// closed loop.
#[test]
fn custom_graph_runs_end_to_end() {
    let dir = fresh_dir("odimo_import_custom");
    let spec = fixture("graph_custom.json");
    let mut session = SessionBuilder::new(spec.to_str().unwrap())
        .platform("diana")
        .results_dir(&dir)
        .threads(2)
        .seed(SEED)
        .sweep_calib(4)
        .sweep_blend_steps(2)
        .plan_cache_cap(8)
        .build()
        .unwrap();
    let mapping = session.mapping(&MappingSpec::Baseline("all_8bit".into())).unwrap();
    let sim = session.simulate(&mapping).unwrap();
    assert!(sim.total_cycles > 0);
    assert!(sim.energy_uj > 0.0);
    let frontier_len = session.sweep().unwrap().points.len();
    assert!(frontier_len > 0, "customnet swept an empty frontier");
    let report = session.serve(&serve_opts(4)).unwrap();
    assert_eq!(report.total_requests, N_REQUESTS);
    assert_eq!(report.model, "customnet");
}

/// Targeted tampers of the canonical document each trip their
/// documented validation error, with the node and field in the
/// message. The base text is the committed golden fixture, so every
/// replacement below is anchored to known canonical bytes.
#[test]
fn validation_errors_fire_on_documented_triggers() {
    let dir = fresh_dir("odimo_import_tamper");
    std::fs::create_dir_all(&dir).unwrap();
    let base = std::fs::read_to_string(fixture("graph_tinycnn.json")).unwrap();
    let expect_err = |tag: &str, text: &str, needles: &[&str]| {
        let path = dir.join(format!("{tag}.json"));
        std::fs::write(&path, text).unwrap();
        let e = Graph::from_json_file(&path).unwrap_err().to_string();
        for needle in needles {
            assert!(e.contains(needle), "{tag}: error '{e}' missing '{needle}'");
        }
    };

    // envelope: wrong kind / wrong schema version
    expect_err("kind", &base.replace("\"kind\":\"odimo_graph\"", "\"kind\":\"frontier\""), &["kind"]);
    expect_err(
        "schema",
        &base.replace("\"schema_version\":1", "\"schema_version\":99"),
        &["schema version"],
    );
    // Empty: gut the node table
    let start = base.find("\"nodes\":[").unwrap() + "\"nodes\":[".len();
    let end = base.find("],\"train_batch\"").unwrap();
    expect_err("empty", &format!("{}{}", &base[..start], &base[end..]), &["no nodes"]);
    // FirstNotInput: node 0 is no longer the input op
    expect_err(
        "first",
        &base.replace("\"op\":\"input\"", "\"op\":\"gap\""),
        &["in", "first node"],
    );
    // ExtraInput: a second input op past position 0
    expect_err(
        "extra",
        &base.replace("\"op\":\"gap\"", "\"op\":\"input\""),
        &["gap", "exactly one 'input'"],
    );
    // DuplicateName: c2 renamed to stem
    expect_err(
        "dup",
        &base.replace("\"name\":\"c2\"", "\"name\":\"stem\""),
        &["stem", "duplicate node name"],
    );
    // DanglingInput: c1 references a ghost node
    expect_err(
        "dangling",
        &base.replace("\"inputs\":[\"stem\"]", "\"inputs\":[\"ghost\"]"),
        &["c1", "'ghost' is not defined"],
    );
    // Cycle: c1 feeds itself
    expect_err(
        "cycle",
        &base.replace("\"inputs\":[\"stem\"]", "\"inputs\":[\"c1\"]"),
        &["c1", "closes a cycle"],
    );
    // NotTopological: swap the stem and c1 node objects — c1 then
    // forward-references stem, which does not reach back to c1
    let stem_obj = &base[base.find("{\"cin\":3").unwrap()..base.find(",{\"cin\":8").unwrap()];
    let c1_obj = &base[base.find("{\"cin\":8").unwrap()..base.find(",{\"cin\":16").unwrap()];
    let swapped = base.replace(
        &format!("{stem_obj},{c1_obj}"),
        &format!("{c1_obj},{stem_obj}"),
    );
    assert_ne!(swapped, base, "swap anchor did not match the fixture");
    expect_err("topo", &swapped, &["c1", "topological order"]);
    // ShapeMismatch: c2 declares an out_hw inference disagrees with
    expect_err(
        "shape",
        &base.replace(
            "\"out_hw\":[8,8],\"pad\":1,\"relu\":false",
            "\"out_hw\":[9,9],\"pad\":1,\"relu\":false",
        ),
        &["c2", "out_hw", "shape inference"],
    );
    // BadField (arity): the add node with one operand
    expect_err(
        "arity",
        &base.replace("\"inputs\":[\"c2\",\"c1\"]", "\"inputs\":[\"c2\"]"),
        &["res", "add takes 2 input(s)"],
    );
    // BadField (classes): declared classes disagree with the final fc
    expect_err(
        "classes",
        &base.replace("\"classes\":10", "\"classes\":11"),
        &["classes", "final node 'fc'"],
    );
    // BadField (typing): a fractional channel count
    expect_err(
        "cin",
        &base.replace("\"cin\":3,", "\"cin\":3.5,"),
        &["cin", "non-negative integer"],
    );
}

/// The single-model pin: `serve_multi(["tinycnn"])` with one flush
/// replica replays `Session::serve_cluster` digest-for-digest, and its
/// embedded replica report replays `Session::serve`.
#[test]
fn single_model_serve_multi_pins_to_serve_and_serve_cluster() {
    let dir = fresh_dir("odimo_multi_pin");
    let copts = ClusterOpts {
        replicas: 1,
        serve: serve_opts(4),
        continuous: false,
        steal_max: 0,
        compile_cycles: 0,
        plan_cache_cap: 8,
    };
    let single = serve_session(&dir, 2, SEED).serve(&serve_opts(4)).unwrap();
    let cluster = serve_session(&dir, 2, SEED).serve_cluster(&copts, None).unwrap();
    let multi = serve_session(&dir, 2, SEED)
        .serve_multi(&["tinycnn".to_string()], &copts, None)
        .unwrap();
    assert_eq!(
        multi.deterministic_digest(),
        cluster.deterministic_digest(),
        "single-model serve_multi drifted from serve_cluster"
    );
    assert_eq!(multi.replicas.len(), 1);
    assert_reports_identical(&single, &multi.replicas[0], "serve_multi single-model pin");
    assert_eq!(multi.model, "tinycnn");
    // every (model, tenant) row carries the one model and conserves
    assert!(!multi.model_rows.is_empty());
    for row in &multi.model_rows {
        assert_eq!(row.model, "tinycnn");
        assert_eq!(row.arrivals, row.served + row.shed + row.failed);
    }
}

/// The mixed pin: a built-in plus the committed custom graph served by
/// one two-replica cluster. Requests are conserved per (model, tenant)
/// row, the rows partition the trace by model, batches never mix
/// models (every point row is model-prefixed), and the digest is
/// invariant across 1/2/8 worker threads.
#[test]
fn mixed_two_model_cluster_conserves_per_model_with_thread_invariant_digest() {
    let dir = fresh_dir("odimo_multi_mixed");
    let custom = fixture("graph_custom.json");
    let specs = vec!["tinycnn".to_string(), custom.to_str().unwrap().to_string()];
    let copts = ClusterOpts {
        replicas: 2,
        serve: serve_opts(4),
        continuous: true,
        steal_max: 2,
        compile_cycles: 5_000,
        plan_cache_cap: 8,
    };
    let total = (2 * N_REQUESTS) as u64; // N_REQUESTS per model
    let base = serve_session(&dir, 1, SEED).serve_multi(&specs, &copts, None).unwrap();
    assert_eq!(base.model, "tinycnn+customnet");
    assert_eq!(base.accounted(), total);
    let routed: u64 = base.dispatched.iter().sum();
    assert_eq!(routed, total, "router lost an arrival");
    // the (model, tenant) rows partition the trace by model
    let arrivals: u64 = base.model_rows.iter().map(|r| r.arrivals).sum();
    assert_eq!(arrivals, total);
    for model in ["tinycnn", "customnet"] {
        let per_model: u64 = base
            .model_rows
            .iter()
            .filter(|r| r.model == model)
            .map(|r| r.arrivals)
            .sum();
        assert_eq!(per_model, N_REQUESTS as u64, "{model}: arrivals not partitioned");
    }
    for row in &base.model_rows {
        assert_eq!(
            row.arrivals,
            row.served + row.shed + row.failed,
            "model {} tenant {} leaks requests",
            row.model,
            row.tenant
        );
    }
    // batches never mix models: every per-point row in every replica
    // report is namespaced by the model it executed
    for replica in &base.replicas {
        assert!(!replica.rows.is_empty());
        for row in &replica.rows {
            assert!(
                row.label.starts_with("tinycnn:") || row.label.starts_with("customnet:"),
                "point row '{}' is not model-prefixed",
                row.label
            );
        }
    }
    for threads in [2usize, 8] {
        let rep = serve_session(&dir, threads, SEED).serve_multi(&specs, &copts, None).unwrap();
        assert_eq!(
            base.deterministic_digest(),
            rep.deterministic_digest(),
            "mixed digest drifted between 1 and {threads} threads"
        );
    }
}
