//! Cross-check: the pure-rust quantized inference engine (quant::infer,
//! the DORY-substitute deployment artifact) must match the AOT
//! `infer_deploy` graph's logits on real inputs under arbitrary
//! mappings — certifying that what the DIANA simulator *costs* is
//! numerically the network that would execute.

use std::path::PathBuf;

use anyhow::anyhow;
use odimo::coordinator::Mapping;
use odimo::data::DataSource;
use odimo::model::{AIMC, DIG};
use odimo::quant::QuantNet;
use odimo::runtime::{assemble_inputs, literal_f32, ArtifactMeta, ParamState, Runtime};
use odimo::util::prng::Pcg32;

fn art_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn hlo_logits(
    rt: &Runtime,
    meta: &ArtifactMeta,
    values: &[Vec<f32>],
    mapping: &Mapping,
    x: &[f32],
    shape: &[usize],
) -> Vec<f32> {
    let exe = rt.load(meta.graph("infer_deploy").unwrap()).unwrap();
    let params = ParamState::from_host(meta, values.to_vec()).unwrap();
    let xl = literal_f32(x, shape).unwrap();
    let assigns: std::collections::BTreeMap<String, odimo::xla::Literal> = meta
        .mappable
        .iter()
        .map(|name| {
            let n = meta.model.node(name).unwrap();
            (name.clone(), literal_f32(&mapping.onehot(name, 2), &[2, n.cout]).unwrap())
        })
        .collect();
    let inputs = assemble_inputs(&exe.meta, |tm| match tm.name.as_str() {
        "x" => Ok(&xl),
        n if n.starts_with("param:") => params.leaf(&n[6..]),
        n if n.starts_with("assign:") => {
            assigns.get(&n[7..]).ok_or_else(|| anyhow!("missing {n}"))
        }
        n => Err(anyhow!("unexpected {n}")),
    })
    .unwrap();
    exe.run_to_host(&inputs).unwrap().into_iter().next_back().unwrap()
}

#[test]
fn quantnet_matches_hlo_logits_tinycnn() {
    if !art_dir().join("tinycnn_meta.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let meta = ArtifactMeta::load(&art_dir(), "tinycnn").unwrap();
    let rt = Runtime::cpu().unwrap();
    let values = meta.load_init_values().unwrap();
    let g = &meta.model;
    let ds = DataSource::test(g, 31);
    let batch = ds.batch(0, 8);
    let shape = [8, batch.c, batch.h, batch.w];

    for seed in [1u64, 5, 9] {
        let mut rng = Pcg32::new(seed, 21);
        let mut mapping = Mapping::uniform(g, DIG);
        for n in g.mappable() {
            let ids = (0..n.cout)
                .map(|_| if rng.next_f32() < 0.5 { AIMC as u8 } else { DIG as u8 })
                .collect();
            mapping.assign.insert(n.name.clone(), ids);
        }
        let want = hlo_logits(&rt, &meta, &values, &mapping, &batch.x, &shape);
        let net = QuantNet::compile(&meta, g, &values, &mapping, &odimo::hw::Platform::diana()).unwrap();
        let got = net.forward(&batch.x, 8).unwrap();
        assert_eq!(want.len(), got.len());
        let max_diff = want
            .iter()
            .zip(&got)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_diff < 5e-3, "seed {seed}: rust engine diverged by {max_diff}");
    }
}

#[test]
fn quantnet_matches_hlo_logits_uniform_mappings() {
    if !art_dir().join("tinycnn_meta.json").exists() {
        return;
    }
    let meta = ArtifactMeta::load(&art_dir(), "tinycnn").unwrap();
    let rt = Runtime::cpu().unwrap();
    let values = meta.load_init_values().unwrap();
    let g = &meta.model;
    let ds = DataSource::test(g, 32);
    let batch = ds.batch(0, 8);
    let shape = [8, batch.c, batch.h, batch.w];
    for acc in [DIG, AIMC] {
        let mapping = Mapping::uniform(g, acc);
        let want = hlo_logits(&rt, &meta, &values, &mapping, &batch.x, &shape);
        let net = QuantNet::compile(&meta, g, &values, &mapping, &odimo::hw::Platform::diana()).unwrap();
        let got = net.forward(&batch.x, 8).unwrap();
        let max_diff = want
            .iter()
            .zip(&got)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_diff < 5e-3, "acc {acc}: diverged by {max_diff}");
    }
}

#[test]
fn quantnet_mbv1_runs_with_dwconv() {
    // exercises the depthwise path (no HLO diff needed to be useful:
    // finite logits of the right shape at both uniform mappings)
    if !art_dir().join("mbv1_025_meta.json").exists() {
        return;
    }
    let meta = ArtifactMeta::load(&art_dir(), "mbv1_025").unwrap();
    let values = meta.load_init_values().unwrap();
    let g = &meta.model;
    let ds = DataSource::test(g, 33);
    let batch = ds.batch(0, 2);
    let mapping = Mapping::uniform(g, DIG);
    let net = QuantNet::compile(&meta, g, &values, &mapping, &odimo::hw::Platform::diana()).unwrap();
    let y = net.forward(&batch.x, 2).unwrap();
    assert_eq!(y.len(), 2 * g.classes);
    assert!(y.iter().all(|v| v.is_finite()));
}
