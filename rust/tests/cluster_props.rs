//! Differential/property pins for the cluster serving layer
//! (serve/cluster.rs): a one-replica cluster with continuous batching
//! off replays the single-Session loop digest-for-digest; every trace
//! request is accounted exactly once (served / shed / failed) under
//! replicas, faults and admission control; digests are invariant
//! across 1/2/8 worker threads; and a constructed overload scenario
//! forces a work-steal whose queue-time accounting provably spans the
//! move (measured from the request's *first* arrival, not the steal).

mod common;

use std::path::{Path, PathBuf};

use common::{
    assert_reports_identical, chaos_opts, chaos_session, probe_frontier, serve_opts,
    serve_session, units_used, N_REQUESTS, SEED,
};
use odimo::api::{AdmissionCfg, ClusterOpts, FaultEvent, FaultPlan, ServeOpts};
use odimo::hw::Platform;
use odimo::serve::{Sla, Trace, TraceRecord};

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn fixture_path() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../config/trace_demo.jsonl")
}

/// The differential pin: `--replicas 1` with continuous batching off,
/// stealing off and a zero compile gate is the single-session loop —
/// the embedded replica report digests identically to `Session::serve`
/// (both sides cold, so plan-cache counters agree too).
#[test]
fn one_flush_replica_replays_single_session_digest_for_digest() {
    let dir = fresh_dir("odimo_cluster_pin");
    let single = serve_session(&dir, 2, SEED).serve(&serve_opts(4)).unwrap();
    let copts = ClusterOpts {
        replicas: 1,
        serve: serve_opts(4),
        continuous: false,
        steal_max: 0,
        compile_cycles: 0,
        plan_cache_cap: 8,
    };
    let cluster = serve_session(&dir, 2, SEED).serve_cluster(&copts, None).unwrap();
    assert_eq!(cluster.replicas.len(), 1);
    assert_reports_identical(&single, &cluster.replicas[0], "r=1 flush pin");
    assert_eq!(cluster.dispatched, vec![N_REQUESTS as u64]);
    assert_eq!(cluster.steals, 0);
    assert_eq!(cluster.cold_compiles, 0, "zero-cycle gate must not be counted");
    assert_eq!(cluster.accounted(), N_REQUESTS as u64);
    // cluster-level aggregates agree with the embedded report
    assert_eq!(cluster.total_requests as usize, single.total_requests);
    assert_eq!(cluster.makespan_ms, single.makespan_ms);
}

/// The same pin under a scripted fault plan on `mpsoc4`: aborts,
/// retries and degraded re-maps all replay identically through the
/// cluster path.
#[test]
fn one_flush_replica_pin_holds_under_faults() {
    let dir = fresh_dir("odimo_cluster_pin_faults");
    let p = Platform::mpsoc4();
    let plan = FaultPlan::synth(3, &p, 400_000);
    let single = chaos_session(&dir, 2).serve(&chaos_opts(Some(plan.clone()))).unwrap();
    let copts = ClusterOpts {
        replicas: 1,
        serve: chaos_opts(Some(plan)),
        continuous: false,
        steal_max: 0,
        compile_cycles: 0,
        plan_cache_cap: 8,
    };
    let cluster = chaos_session(&dir, 2).serve_cluster(&copts, None).unwrap();
    assert_reports_identical(&single, &cluster.replicas[0], "r=1 fault pin");
    assert_eq!(cluster.replicas[0].batch_aborts, single.batch_aborts);
    assert_eq!(cluster.replicas[0].retries, single.retries);
    assert_eq!(cluster.accounted(), N_REQUESTS as u64);
}

/// Conservation at `--replicas 4` with continuous batching, stealing,
/// a compile gate, synthesized fault plans and overload admission all
/// active at once: every trace request ends served, shed or failed
/// exactly once, the router accounts every arrival, and the per-tenant
/// rows partition the trace.
#[test]
fn four_replicas_account_every_request_under_chaos() {
    let dir = fresh_dir("odimo_cluster_conserve");
    let p = Platform::mpsoc4();
    for seed in 0..3u64 {
        let plan = FaultPlan::synth(seed, &p, 400_000);
        let mut sopts = chaos_opts(Some(plan));
        sopts.admission = AdmissionCfg { overload_wait: 60_000 };
        sopts.max_retries = 4;
        let copts = ClusterOpts {
            replicas: 4,
            serve: sopts,
            continuous: true,
            steal_max: 2,
            compile_cycles: 5_000,
            plan_cache_cap: 8,
        };
        let rep = chaos_session(&dir, 2).serve_cluster(&copts, None).unwrap();
        assert_eq!(rep.replicas.len(), 4, "seed {seed}");
        assert_eq!(
            rep.accounted(),
            N_REQUESTS as u64,
            "seed {seed}: {} served + {} shed + {} failed != {N_REQUESTS}",
            rep.total_requests,
            rep.shed_requests,
            rep.failed_requests
        );
        let routed: u64 = rep.dispatched.iter().sum();
        assert_eq!(routed, N_REQUESTS as u64, "seed {seed}: router lost an arrival");
        let arrivals: u64 = rep.tenants.iter().map(|t| t.arrivals).sum();
        assert_eq!(arrivals, N_REQUESTS as u64, "seed {seed}");
        for t in &rep.tenants {
            assert_eq!(
                t.served + t.shed + t.failed,
                t.arrivals,
                "seed {seed}: tenant {} leaks requests",
                t.tenant
            );
        }
        let per_replica: u64 = rep.replicas.iter().map(|r| r.total_requests as u64).sum();
        assert_eq!(per_replica, rep.total_requests, "seed {seed}");
        assert!(rep.cold_compiles > 0, "seed {seed}: gate never charged a first batch");
    }
}

/// The digest is a pure function of (trace, platform, opts): invariant
/// across 1/2/8 worker threads for one, two and four replicas — the
/// thread pool only accelerates the real engine work inside a batch,
/// never the virtual schedule.
#[test]
fn digest_is_invariant_across_threads_and_replica_counts() {
    let dir = fresh_dir("odimo_cluster_threads");
    let p = Platform::mpsoc4();
    for replicas in [1usize, 2, 4] {
        let copts = ClusterOpts {
            replicas,
            serve: chaos_opts(Some(FaultPlan::synth(3, &p, 400_000))),
            continuous: true,
            steal_max: 2,
            compile_cycles: 5_000,
            plan_cache_cap: 8,
        };
        let base = chaos_session(&dir, 1).serve_cluster(&copts, None).unwrap();
        assert_eq!(base.accounted(), N_REQUESTS as u64, "r={replicas}");
        for threads in [2usize, 8] {
            let rep = chaos_session(&dir, threads).serve_cluster(&copts, None).unwrap();
            assert_eq!(
                base.deterministic_digest(),
                rep.deterministic_digest(),
                "r={replicas}: digest drifted between 1 and {threads} threads"
            );
        }
    }
}

/// Replaying the checked-in golden trace is deterministic run-to-run,
/// and conservation holds against the trace length (not the synthetic
/// default).
#[test]
fn golden_trace_replay_is_deterministic() {
    let dir = fresh_dir("odimo_cluster_golden");
    let trace = Trace::load(&fixture_path()).unwrap();
    assert!(!trace.is_empty(), "golden fixture must not be empty");
    let copts = ClusterOpts {
        replicas: 4,
        serve: chaos_opts(None),
        continuous: true,
        steal_max: 2,
        compile_cycles: 5_000,
        plan_cache_cap: 8,
    };
    let a = chaos_session(&dir, 2).serve_cluster(&copts, Some(&trace)).unwrap();
    let b = chaos_session(&dir, 2).serve_cluster(&copts, Some(&trace)).unwrap();
    assert_eq!(a.deterministic_digest(), b.deterministic_digest());
    assert_eq!(a.accounted(), trace.len() as u64);
    let routed: u64 = a.dispatched.iter().sum();
    assert_eq!(routed, trace.len() as u64);
    // tenant rows come from the trace, not the synthetic generator
    let arrivals: u64 = a.tenants.iter().map(|t| t.arrivals).sum();
    assert_eq!(arrivals, trace.len() as u64);
}

/// A constructed two-replica overload: six min-energy requests pile
/// onto replica 0 and six tight-budget requests onto replica 1 (the
/// least-loaded router alternates them exactly), and once the stream
/// ends the quiet drain flushes both batches at the tail cycle. A
/// unit death strictly inside replica 0's exec window (and past
/// replica 1's) aborts only replica 0's batch; its six requests are
/// re-queued below `max_batch` at the retry cycle, where replica 1 is
/// provably idle while replica 0's device is still busy — the only
/// legal steal window. The steal must happen, move work to replica 1,
/// conserve every request, keep the whole schedule replayable, and
/// account stolen queue time from the requests' *first* arrival (not
/// the steal cycle).
#[test]
fn forced_steal_moves_backlog_and_accounts_queue_time_from_first_arrival() {
    let dir = fresh_dir("odimo_cluster_steal");
    let p = Platform::mpsoc4();
    let frontier = probe_frontier(&p);
    assert!(frontier.len() >= 2, "need distinct fastest and cheapest points");
    // E: the min-energy point (where min-energy requests dispatch);
    // Cf: the fastest point's cycles (a budget of exactly Cf admits
    // only that point). Pareto non-domination makes Ce > Cf strict.
    let e = frontier
        .iter()
        .enumerate()
        .min_by(|(_, a), (_, b)| a.energy_uj.total_cmp(&b.energy_uj))
        .map(|(i, _)| i)
        .unwrap();
    let ce = frontier[e].cycles;
    let cf = frontier.iter().map(|fp| fp.cycles).min().unwrap();
    assert!(ce > cf, "frontier degenerate: cheapest point is also fastest");
    let victim_unit = units_used(&frontier[e], p.n_acc())
        .first()
        .copied()
        .expect("min-energy point maps at least one unit");
    let victim_name = p.accelerators[victim_unit].name.clone();

    const W: u64 = 50_000; // max_wait: never reached — the quiet drain preempts it
    const L: u64 = 10_000; // launch_cycles
    const BACKOFF: u64 = 1_000; // < L, so the retry lands while replica 0 is busy
    const TAIL: u64 = 11; // last arrival cycle: the quiet drain flushes here
    // Once arrival 11 is consumed the loop quiet-drains both residual
    // batches at the tail cycle: replica 0's six min-energy requests
    // run to d0, replica 1's six budget requests to d1 < d0. A unit
    // death strictly between them lands inside replica 0's exec
    // window only.
    let d0 = TAIL + L + 6 * ce;
    let d1 = TAIL + L + 6 * cf;
    assert!(d1 < d0);
    let kill_at = d1 + (d0 - d1) / 2;
    let retry_at = kill_at + BACKOFF;
    assert!(d1 < kill_at && kill_at < d0);

    let mut records = Vec::new();
    for t in 0..12u64 {
        let (sla, tenant) = if t % 2 == 0 {
            (Sla::MinEnergy, "batch")
        } else {
            (Sla::LatencyBudget(cf), "interactive")
        };
        records.push(TraceRecord {
            arrival_cycle: t,
            sla,
            tenant: tenant.to_string(),
            model: "tinycnn".to_string(),
            seed: SEED,
        });
    }
    let trace = Trace { records };

    let sopts = ServeOpts {
        n_requests: None,
        max_batch: 8,
        max_wait: W,
        mean_gap: 15_000,
        launch_cycles: L,
        fault_plan: Some(FaultPlan {
            events: vec![FaultEvent::UnitDown { unit: victim_name, at_cycle: kill_at }],
        }),
        retry_backoff: BACKOFF,
        ..ServeOpts::default()
    };
    let copts = ClusterOpts {
        replicas: 2,
        serve: sopts,
        continuous: false,
        steal_max: 4,
        compile_cycles: 0,
        plan_cache_cap: 8,
    };
    let rep = chaos_session(&dir, 2).serve_cluster(&copts, Some(&trace)).unwrap();

    assert_eq!(rep.dispatched, vec![6, 6], "router must alternate the arrivals");
    assert!(rep.steals >= 1, "constructed steal window never fired");
    assert!(rep.stolen_requests >= 1);
    assert_eq!(rep.accounted(), 12, "stealing lost or duplicated a request");
    assert_eq!(rep.shed_requests, 0);
    assert_eq!(rep.failed_requests, 0, "stolen requests must still be served");
    assert_eq!(rep.replicas[0].batch_aborts, 1, "only replica 0's batch spans the kill");
    assert_eq!(rep.replicas[1].batch_aborts, 0);
    assert!(
        rep.replicas[1].total_requests > 6,
        "replica 1 was routed 6 arrivals but served {}; the steal moved nothing",
        rep.replicas[1].total_requests
    );
    // queue-time accounting spans the move: a stolen request's wait
    // runs from its first arrival (~cycle 0) to its launch on the
    // thief at the retry cycle, which sits past kill_at. Replica 1's
    // own six requests launch at the tail drain with near-zero waits,
    // so its mean over 6 own + 4 stolen is ~0.4 * (kill_at + backoff)
    // — strictly above kill_at / 3. If stealing re-based queue time
    // at the steal cycle instead, all ten waits would be ~0 cycles
    // and the mean would collapse far below the floor.
    let to_ms = |cycles: u64| cycles as f64 / p.f_clk_hz * 1e3;
    assert!(
        rep.replicas[1].mean_queue_ms > to_ms(kill_at / 3),
        "stolen queue time was not measured from first arrival: mean {} ms vs floor {} ms \
         (retry was due at cycle {retry_at})",
        rep.replicas[1].mean_queue_ms,
        to_ms(kill_at / 3)
    );
    // the whole constructed schedule replays digest-for-digest
    let again = chaos_session(&dir, 2).serve_cluster(&copts, Some(&trace)).unwrap();
    assert_eq!(rep.deterministic_digest(), again.deterministic_digest());
}
