//! Golden DIANA parity: the generalized `Platform::diana()` path must
//! reproduce the pre-refactor hardwired 2-accelerator simulator
//! byte-for-byte.
//!
//! Two pins:
//!  1. a local, self-contained re-implementation of the seed's cost
//!     model (Eq. 6/7 integer latencies, Eq. 4 energy with the exact
//!     accumulation order of the old `hw::{latency,energy,soc}` code)
//!     is compared against `simulate(..., &Platform::diana(), ..)` with
//!     exact `==` on every Table-I metric, over fixed mappings on all
//!     four benchmark models;
//!  2. hardcoded golden `total_cycles` (computed from the seed formulas
//!     when this test was introduced) guard against the oracle and the
//!     platform path drifting together.

use odimo::hw::soc::{simulate, split_all_aimc, split_all_digital, ChannelSplit, SocConfig};
use odimo::hw::Platform;
use odimo::model::{build, Graph, Op, ALL_MODELS};

// ---- the seed simulator, frozen --------------------------------------

const AIMC_ROWS: u64 = 1152;
const AIMC_COLS: u64 = 512;
const DIG_PE: u64 = 16;
const F_CLK_HZ: f64 = 260e6;
const P_ACT: [f64; 2] = [24.0, 26.0];
const P_IDLE: [f64; 2] = [1.3, 1.3];

fn ceil_div(a: u64, b: u64) -> u64 {
    a.div_ceil(b)
}

fn lat_aimc(cin: u64, fx: u64, fy: u64, ox: u64, oy: u64, cout_a: u64) -> u64 {
    if cout_a == 0 {
        return 0;
    }
    let tiles_in = ceil_div(cin * fx * fy, AIMC_ROWS);
    let tiles_out = ceil_div(cout_a, AIMC_COLS);
    tiles_in * tiles_out * ox * oy + 2 * 4 * cin * tiles_out
}

fn lat_dig(cin: u64, fx: u64, fy: u64, ox: u64, oy: u64, cout_d: u64) -> u64 {
    if cout_d == 0 {
        return 0;
    }
    ceil_div(cout_d, DIG_PE) * ceil_div(oy, DIG_PE) * cin * ox * fx * fy
        + cin * cout_d * fx * fy
}

fn lat_dw(k: u64, ox: u64, oy: u64, cout: u64) -> u64 {
    ceil_div(cout, DIG_PE) * ceil_div(oy, DIG_PE) * ox * k * k + cout * k * k
}

fn layer_energy_uj(active_cycles: [u64; 2], span_cycles: u64) -> f64 {
    let mut e_mw_cycles = 0.0;
    for i in 0..2 {
        let act = active_cycles[i].min(span_cycles) as f64;
        let idle = (span_cycles - active_cycles[i].min(span_cycles)) as f64;
        e_mw_cycles += P_ACT[i] * act + P_IDLE[i] * idle;
    }
    e_mw_cycles / F_CLK_HZ * 1e3
}

struct SeedReport {
    total_cycles: u64,
    latency_ms: f64,
    energy_uj: f64,
    util: [f64; 2],
    aimc_channel_frac: f64,
}

/// The seed `hw::soc::simulate`, with the exact same statement order.
fn seed_simulate(graph: &Graph, split: &ChannelSplit) -> SeedReport {
    let mut t = 0u64;
    let mut energy = 0.0;
    let mut ch_total = 0usize;
    let mut ch_aimc = 0usize;
    let mut busy = [0u64; 2];
    for node in &graph.nodes {
        match node.op {
            Op::Conv | Op::Fc => {
                let counts = &split[&node.name];
                let (cd, ca) = (counts[0], counts[1]);
                assert_eq!(cd + ca, node.cout);
                ch_total += node.cout;
                ch_aimc += ca;
                let (oy, ox) = (node.out_hw.0 as u64, node.out_hw.1 as u64);
                let (cin, k) = (node.cin as u64, node.k as u64);
                let ld = lat_dig(cin, k, k, ox, oy, cd as u64);
                let la = lat_aimc(cin, k, k, ox, oy, ca as u64);
                let span = ld.max(la);
                busy[0] += ld;
                busy[1] += la;
                energy += layer_energy_uj([ld, la], span);
                t += span;
            }
            Op::DwConv => {
                let (oy, ox) = (node.out_hw.0 as u64, node.out_hw.1 as u64);
                let ld = lat_dw(node.k as u64, ox, oy, node.cout as u64);
                busy[0] += ld;
                energy += layer_energy_uj([ld, 0], ld);
                t += ld;
            }
            _ => {}
        }
    }
    SeedReport {
        total_cycles: t,
        latency_ms: t as f64 / F_CLK_HZ * 1e3,
        energy_uj: energy,
        util: [busy[0] as f64 / t as f64, busy[1] as f64 / t as f64],
        aimc_channel_frac: if ch_total == 0 { 0.0 } else { ch_aimc as f64 / ch_total as f64 },
    }
}

fn half_split(graph: &Graph) -> ChannelSplit {
    graph
        .mappable()
        .iter()
        .map(|n| (n.name.clone(), vec![n.cout / 2, n.cout - n.cout / 2]))
        .collect()
}

#[test]
fn platform_diana_reproduces_seed_simulator_exactly() {
    let p = Platform::diana();
    for model in ALL_MODELS {
        let g = build(model).unwrap();
        for (tag, split) in [
            ("all_digital", split_all_digital(&g)),
            ("all_aimc", split_all_aimc(&g)),
            ("half", half_split(&g)),
        ] {
            let want = seed_simulate(&g, &split);
            let got = simulate(&g, &split, &p, SocConfig::default());
            assert_eq!(got.total_cycles, want.total_cycles, "{model}/{tag}: cycles");
            assert_eq!(got.latency_ms, want.latency_ms, "{model}/{tag}: latency_ms");
            assert_eq!(got.energy_uj, want.energy_uj, "{model}/{tag}: energy_uj");
            assert_eq!(got.util.len(), 2);
            assert_eq!(got.util[0], want.util[0], "{model}/{tag}: util[0]");
            assert_eq!(got.util[1], want.util[1], "{model}/{tag}: util[1]");
            assert_eq!(
                got.aimc_channel_frac(),
                want.aimc_channel_frac,
                "{model}/{tag}: aimc channel frac"
            );
        }
    }
}

#[test]
fn golden_total_cycles_literals() {
    // computed from the seed Eq. 6/7 formulas at refactor time; exact
    // integers, so any drift in either path trips this
    let cases: [(&str, u64, u64, u64); 3] = [
        // (model, all_digital, all_aimc, half)
        ("tinycnn", 6_008, 729, 4_125),
        ("resnet20", 481_584, 15_321, 269_465),
        ("mbv1_025", 281_112, 35_699, 154_605),
    ];
    let p = Platform::diana();
    for (model, dig, aimc, half) in cases {
        let g = build(model).unwrap();
        let cyc = |s: &ChannelSplit| simulate(&g, s, &p, SocConfig::default()).total_cycles;
        assert_eq!(cyc(&split_all_digital(&g)), dig, "{model} all_digital");
        assert_eq!(cyc(&split_all_aimc(&g)), aimc, "{model} all_aimc");
        assert_eq!(cyc(&half_split(&g)), half, "{model} half");
    }
}

#[test]
fn golden_table1_scale_floats() {
    // float spot-checks (latency in ms / energy in uJ for resnet20
    // all-digital, from the seed model) — tight relative tolerance, the
    // exact-equality pin above is the byte-identical guarantee
    let p = Platform::diana();
    let g = build("resnet20").unwrap();
    let r = simulate(&g, &split_all_digital(&g), &p, SocConfig::default());
    assert!((r.latency_ms - 1.8522461538461539).abs() < 1e-12);
    assert!((r.energy_uj - 46.86182769230769).abs() / 46.86182769230769 < 1e-12);
}

#[test]
fn deploy_fragment_overhead_matches_seed_rule() {
    // the scheduler's fragmentation charge must stay the seed's
    // digital-only rule on DIANA: (frags-1) * cin * k^2 per layer with
    // >1 digital fragment (driven through the api facade, which wraps
    // the scheduler unchanged)
    use odimo::coordinator::Mapping;
    let session = odimo::api::SessionBuilder::new("tinycnn")
        .platform("diana")
        .threads(1)
        .build()
        .unwrap();
    let g = session.graph().clone();
    let mut m = Mapping::uniform(&g, 0);
    for n in g.mappable() {
        let ids = (0..n.cout).map(|i| (i % 2) as u8).collect();
        m.assign.insert(n.name.clone(), ids);
    }
    let rep = session.deploy(&m).unwrap();
    let mut want = 0u64;
    for n in g.mappable() {
        let frags_dig = n.cout.div_ceil(2) as u64; // alternating, starts digital
        if frags_dig > 1 {
            want += (frags_dig - 1) * (n.cin * n.k * n.k) as u64;
        }
    }
    assert_eq!(rep.fragment_overhead_cycles, want);
}
