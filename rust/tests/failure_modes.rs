//! Failure injection: the coordinator must fail *loudly and precisely*
//! on corrupted artifacts, malformed configs, and inconsistent inputs —
//! not with XLA shape errors three layers down.

use std::path::PathBuf;

use odimo::config::RunConfig;
use odimo::coordinator::Mapping;
use odimo::model::{tinycnn, DIG};
use odimo::runtime::ArtifactMeta;
use odimo::util::json;

fn art_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("odimo_fail_{tag}"));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn missing_meta_is_reported_with_path() {
    let err = ArtifactMeta::load(&tmpdir("nometa"), "tinycnn").unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("tinycnn_meta.json"), "{msg}");
}

#[test]
fn truncated_meta_fails_parse() {
    let d = tmpdir("truncmeta");
    std::fs::write(d.join("tinycnn_meta.json"), "{\"model\": {\"name\": \"tiny").unwrap();
    let err = ArtifactMeta::load(&d, "tinycnn").unwrap_err();
    assert!(format!("{err:#}").contains("pars"), "{err:#}");
}

#[test]
fn meta_with_missing_key_names_the_key() {
    let d = tmpdir("missingkey");
    std::fs::write(d.join("tinycnn_meta.json"), "{\"model\": {}}").unwrap();
    let err = ArtifactMeta::load(&d, "tinycnn").unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("missing json key"), "{msg}");
}

#[test]
fn corrupted_init_blob_reports_sizes() {
    if !art_dir().join("tinycnn_meta.json").exists() {
        return;
    }
    let d = tmpdir("badinit");
    // copy meta but write a short init blob
    std::fs::copy(
        art_dir().join("tinycnn_meta.json"),
        d.join("tinycnn_meta.json"),
    )
    .unwrap();
    std::fs::write(d.join("tinycnn_init.bin"), [0u8; 12]).unwrap();
    let meta = ArtifactMeta::load(&d, "tinycnn").unwrap();
    let err = meta.load_init_values().unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("12 bytes"), "{msg}");
}

#[test]
fn checkpoint_size_mismatch_detected() {
    if !art_dir().join("tinycnn_meta.json").exists() {
        return;
    }
    let meta = ArtifactMeta::load(&art_dir(), "tinycnn").unwrap();
    let d = tmpdir("badckpt");
    let p = d.join("ckpt.bin");
    std::fs::write(&p, [0u8; 100]).unwrap();
    let err = match odimo::runtime::ParamState::load(&meta, &p) {
        Err(e) => e,
        Ok(_) => panic!("bad checkpoint accepted"),
    };
    assert!(format!("{err:#}").contains("expected"), "{err:#}");
}

#[test]
fn config_bad_types_rejected() {
    let d = tmpdir("badcfg");
    let p = d.join("cfg.toml");
    std::fs::write(&p, "[run]\nmodel = 42\n").unwrap();
    assert!(RunConfig::from_file(&p).is_err());
    std::fs::write(&p, "[schedule]\nsearch_steps = \"many\"\n").unwrap();
    assert!(RunConfig::from_file(&p).is_err());
    std::fs::write(&p, "[search]\nlambdas = [1.0, \"x\"]\n").unwrap();
    assert!(RunConfig::from_file(&p).is_err());
}

#[test]
fn mapping_json_garbage_rejected() {
    for bad in ["[1,2,3]", "{\"stem\": \"x\"}", "{\"stem\": [0, 5]}"] {
        let v = json::parse(bad).unwrap();
        let m = Mapping::from_json(&v);
        match m {
            Err(_) => {}
            Ok(m) => {
                // ids out of range must be caught by validate
                assert!(m.validate(&tinycnn(), 2).is_err(), "{bad} accepted");
            }
        }
    }
}

#[test]
fn mapping_for_wrong_model_rejected() {
    let g_small = tinycnn();
    let g_big = odimo::model::resnet20();
    let m = Mapping::uniform(&g_small, DIG);
    assert!(m.validate(&g_big, 2).is_err());
}

#[test]
fn mapping_for_wrong_platform_rejected() {
    // a 3-accelerator mapping must not validate on a 2-accelerator SoC
    let g = tinycnn();
    let mut m = Mapping::uniform(&g, DIG);
    m.assign.get_mut("stem").unwrap()[0] = 2;
    assert!(m.validate(&g, 3).is_ok());
    assert!(m.validate(&g, 2).is_err());
}

#[test]
fn platform_toml_garbage_rejected() {
    use odimo::hw::Platform;
    let d = tmpdir("badplat");
    let p = d.join("p.toml");
    // missing accelerators array
    std::fs::write(&p, "[platform]\nname = \"x\"\nf_clk_hz = 1e6\n").unwrap();
    assert!(Platform::from_toml_file(&p).is_err());
    // unknown accelerator kind
    std::fs::write(
        &p,
        "[platform]\nname = \"x\"\nf_clk_hz = 1e6\naccelerators = [\"a\"]\n\
         [accel.a]\nkind = \"quantum\"\n",
    )
    .unwrap();
    let err = Platform::from_toml_file(&p).unwrap_err().to_string();
    assert!(err.contains("unknown kind"), "{err}");
    // dw accelerator not in the list
    std::fs::write(
        &p,
        "[platform]\nname = \"x\"\nf_clk_hz = 1e6\naccelerators = [\"a\"]\n\
         dw_accelerator = \"b\"\n[accel.a]\nkind = \"digital_pe\"\npe = 16\n\
         weight_bits = 8\nact_bits = 8\np_act_mw = 1.0\np_idle_mw = 0.1\n",
    )
    .unwrap();
    assert!(Platform::from_toml_file(&p).is_err());
}

#[test]
fn json_fuzz_roundtrip_never_panics() {
    // generate random JSON-ish strings; the parser must reject or accept
    // without panicking, and accepted values must re-emit + re-parse
    use odimo::util::prng::Pcg32;
    let mut rng = Pcg32::new(2024, 9);
    let tokens = [
        "{", "}", "[", "]", ",", ":", "\"k\"", "1", "-2.5e3", "true",
        "false", "null", "\"v\\n\"", " ",
    ];
    let mut ok = 0;
    for _ in 0..3000 {
        let len = 1 + rng.below(12) as usize;
        let s: String = (0..len)
            .map(|_| tokens[rng.below(tokens.len() as u32) as usize])
            .collect();
        if let Ok(v) = json::parse(&s) {
            ok += 1;
            let re = json::parse(&v.to_string()).unwrap();
            assert_eq!(v, re, "roundtrip failed for generated '{s}'");
        }
    }
    assert!(ok > 0, "fuzz never produced valid json — generator broken");
}

#[test]
fn simulator_rejects_overfull_split() {
    let g = tinycnn();
    let mut split = odimo::hw::soc::split_all_digital(&g);
    split.insert("stem".into(), vec![100, 100]);
    let r = std::panic::catch_unwind(|| {
        odimo::hw::soc::simulate(&g, &split, &odimo::hw::Platform::diana(), Default::default())
    });
    assert!(r.is_err(), "overfull split must panic (coordinator bug guard)");
}
