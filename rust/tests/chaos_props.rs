//! Chaos property tests for fault-tolerant serving: under any seeded
//! fault plan every admitted request completes or is reported shed
//! (none silently lost), degraded-mode re-mapping conserves channel
//! splits on the surviving units, and the serve report is
//! bit-deterministic — across re-runs with the same seed + plan and
//! across 1/2/8 worker threads. Scenarios run the real closed loop on
//! `mpsoc4` (4 units) at smoke sweep sizes; the victim unit is probed
//! from the swept frontier, never hard-coded, so the injected fault is
//! guaranteed to hit a unit the mapper actually uses.

mod common;

use std::collections::BTreeSet;

use common::{
    assert_reports_identical, chaos_opts, chaos_session, probe_frontier, units_used,
    N_REQUESTS,
};
use odimo::api::{AdmissionCfg, FaultPlan};
use odimo::coordinator::baselines::{min_cost, CostObjective};
use odimo::hw::{FaultEvent, FaultState, Platform, UnitHealth};
use odimo::model::tinycnn;

/// A unit that dies before the first request ever arrives: every batch
/// in the run must land on points that do not touch it — either
/// surviving originals or `deg[..]` re-map points.
#[test]
fn unit_down_from_cycle_zero_serves_only_surviving_units() {
    let dir = std::env::temp_dir().join("odimo_chaos_down0");
    let _ = std::fs::remove_dir_all(&dir);
    let p = Platform::mpsoc4();
    let frontier = probe_frontier(&p);
    // victim: a unit the fastest frontier point actually uses, so the
    // fault provably removes at least one dispatchable point
    let victim = units_used(&frontier[0], p.n_acc())
        .first()
        .copied()
        .expect("fastest point maps at least one unit");
    let victim_name = p.accelerators[victim].name.clone();
    let banned: BTreeSet<String> = frontier
        .iter()
        .filter(|fp| units_used(fp, p.n_acc()).contains(&victim))
        .map(|fp| fp.label.clone())
        .collect();
    assert!(!banned.is_empty(), "victim {victim_name} must appear in some mapping");
    let plan = FaultPlan {
        events: vec![FaultEvent::UnitDown { unit: victim_name.clone(), at_cycle: 0 }],
    };
    let rep = chaos_session(&dir, 2).serve(&chaos_opts(Some(plan))).unwrap();
    assert_eq!(rep.faults_injected, 1);
    assert_eq!(
        rep.accounted(),
        N_REQUESTS,
        "served {} + shed {} + failed {} must cover every request",
        rep.total_requests,
        rep.shed_requests,
        rep.failed_requests
    );
    assert_eq!(rep.shed_requests, 0, "no admission threshold configured");
    assert_eq!(rep.failed_requests, 0, "survivor points always dispatchable");
    assert_eq!(rep.batch_aborts, 0, "nothing was in flight when the unit died");
    for r in &rep.rows {
        assert!(
            !banned.contains(&r.label),
            "row '{}' executed on dead unit {victim_name}",
            r.label
        );
    }
}

/// The acceptance scenario: a unit dies mid-stream on `mpsoc4`. The
/// run completes with zero lost requests (in-flight batches abort and
/// retry on the degraded platform) and the report replays byte-for-byte
/// from a fresh session with the same seed and plan.
#[test]
fn unit_down_mid_run_loses_no_requests_and_replays_byte_for_byte() {
    let dir = std::env::temp_dir().join("odimo_chaos_midrun");
    let _ = std::fs::remove_dir_all(&dir);
    let p = Platform::mpsoc4();
    let frontier = probe_frontier(&p);
    let victim = units_used(&frontier[0], p.n_acc())
        .first()
        .copied()
        .expect("fastest point maps at least one unit");
    let victim_name = p.accelerators[victim].name.clone();
    // arrivals span roughly mean_gap * n ~ 360k cycles; kill mid-stream
    let plan = FaultPlan {
        events: vec![FaultEvent::UnitDown { unit: victim_name, at_cycle: 120_000 }],
    };
    let a = chaos_session(&dir, 2).serve(&chaos_opts(Some(plan.clone()))).unwrap();
    assert_eq!(a.faults_injected, 1);
    assert_eq!(
        a.accounted(),
        N_REQUESTS,
        "served {} + shed {} + failed {}: a request was silently lost",
        a.total_requests,
        a.shed_requests,
        a.failed_requests
    );
    assert_eq!(a.shed_requests, 0, "no admission threshold configured");
    assert_eq!(
        a.failed_requests, 0,
        "a permanent down always leaves dispatchable survivors, so the first \
         retry must succeed"
    );
    assert!(
        a.retries >= a.batch_aborts,
        "every aborted batch ({}) re-enters the queue ({} retries)",
        a.batch_aborts,
        a.retries
    );
    // byte-for-byte replay from a fresh session (cold plan cache, same
    // frontier via the disk cache)
    let b = chaos_session(&dir, 2).serve(&chaos_opts(Some(plan))).unwrap();
    assert_reports_identical(&a, &b, "mid-run replay");
    assert_eq!(a.batch_aborts, b.batch_aborts);
    assert_eq!(a.retries, b.retries);
}

/// Randomized chaos: for a range of synthesized fault plans (downs,
/// deratings, transients — by construction never all units at once)
/// with overload admission control active, the accounting identity
/// `completed + shed + failed == admitted` holds. Nothing is lost.
#[test]
fn synthesized_fault_plans_account_every_request() {
    let dir = std::env::temp_dir().join("odimo_chaos_synth");
    let _ = std::fs::remove_dir_all(&dir);
    let p = Platform::mpsoc4();
    for seed in 0..5u64 {
        let plan = FaultPlan::synth(seed, &p, 400_000);
        plan.validate().unwrap();
        assert!(!plan.events.is_empty(), "seed {seed}: synth plan is empty");
        let mut opts = chaos_opts(Some(plan.clone()));
        opts.admission = AdmissionCfg { overload_wait: 60_000 };
        opts.max_retries = 4;
        let rep = chaos_session(&dir, 2).serve(&opts).unwrap();
        assert_eq!(rep.faults_injected, plan.events.len() as u64, "seed {seed}");
        let served: usize = rep.rows.iter().map(|r| r.requests).sum();
        assert_eq!(served, rep.total_requests, "seed {seed}: rows disagree with total");
        assert_eq!(
            rep.accounted(),
            N_REQUESTS,
            "seed {seed}: served {} + shed {} + failed {} != {N_REQUESTS}",
            rep.total_requests,
            rep.shed_requests,
            rep.failed_requests
        );
    }
}

/// Degraded re-mapping is a real mapping: for every single-unit-down
/// state (and a representative derated state) the water-filling
/// `min_cost` on the degraded platform view conserves each layer's
/// channel count across exactly the surviving units.
#[test]
fn degraded_min_cost_conserves_channels_on_survivors() {
    let g = tinycnn();
    let p = Platform::mpsoc4();
    let n = p.n_acc();
    for down in 0..n {
        let mut health = vec![UnitHealth::Up; n];
        health[down] = UnitHealth::Down;
        let d = p.degraded(&FaultState { health }).unwrap();
        assert_eq!(d.n_acc(), n - 1, "down={down}: one unit must be gone");
        assert_ne!(d.spec_hash(), p.spec_hash(), "degraded view must re-key caches");
        for obj in [CostObjective::Latency, CostObjective::Energy] {
            let m = min_cost(&g, &d, obj);
            m.validate(&g, d.n_acc()).unwrap();
            let split = m.channel_split(d.n_acc());
            for node in g.mappable() {
                let counts = &split[&node.name];
                assert_eq!(counts.len(), d.n_acc(), "down={down} {}", node.name);
                let total: usize = counts.iter().sum();
                assert_eq!(
                    total, node.cout,
                    "down={down} {obj:?} {}: split loses channels",
                    node.name
                );
            }
        }
    }
    // derated: all units survive (mapping domain unchanged), but the
    // view is still cache-distinct from the healthy platform
    let mut health = vec![UnitHealth::Up; n];
    health[0] = UnitHealth::Derated(2.0);
    let d = p.degraded(&FaultState { health }).unwrap();
    assert_eq!(d.n_acc(), n);
    assert_ne!(d.spec_hash(), p.spec_hash());
    min_cost(&g, &d, CostObjective::Latency).validate(&g, n).unwrap();
}

/// The virtual-time schedule is independent of the worker-pool size:
/// the same seed + fault plan produces digest-identical reports at 1,
/// 2 and 8 threads. (The digest covers every outcome field and
/// excludes only wall-clock rates and the thread count itself.)
#[test]
fn reports_are_identical_across_1_2_8_threads() {
    let dir = std::env::temp_dir().join("odimo_chaos_threads");
    let _ = std::fs::remove_dir_all(&dir);
    let p = Platform::mpsoc4();
    let plan = FaultPlan::synth(3, &p, 400_000);
    let base = chaos_session(&dir, 1).serve(&chaos_opts(Some(plan.clone()))).unwrap();
    for threads in [2usize, 8] {
        let rep =
            chaos_session(&dir, threads).serve(&chaos_opts(Some(plan.clone()))).unwrap();
        assert_reports_identical(&base, &rep, &format!("threads {threads}"));
        assert_eq!(rep.threads, threads, "report must still record its own config");
    }
}

/// Attaching an *empty* fault plan must cost nothing semantically: the
/// report is byte-identical to serving with no plan at all, and all
/// fault counters stay zero. (The perf side of the same claim is the
/// `faults0` bench case gated by `tools/check_bench_overhead.py`.)
#[test]
fn empty_fault_plan_is_byte_identical_to_no_plan() {
    let dir = std::env::temp_dir().join("odimo_chaos_empty");
    let _ = std::fs::remove_dir_all(&dir);
    let bare = chaos_session(&dir, 2).serve(&chaos_opts(None)).unwrap();
    let inert = chaos_session(&dir, 2)
        .serve(&chaos_opts(Some(FaultPlan::empty())))
        .unwrap();
    assert_reports_identical(&bare, &inert, "empty plan");
    assert_eq!(bare.p50_ms, inert.p50_ms);
    assert_eq!(bare.p95_ms, inert.p95_ms);
    assert_eq!(bare.total_batches, inert.total_batches);
    for rep in [&bare, &inert] {
        assert_eq!(rep.faults_injected, 0);
        assert_eq!(rep.batch_aborts, 0);
        assert_eq!(rep.retries, 0);
        assert_eq!(rep.shed_requests, 0);
        assert_eq!(rep.failed_requests, 0);
        assert_eq!(rep.degraded_requests, 0);
        assert_eq!(rep.accounted(), N_REQUESTS);
    }
}
