//! Trace-format pins: the checked-in golden fixture is canonical
//! (load → re-emit reproduces the file byte-for-byte), record-then-
//! replay closes the loop (a synthesized trace saved to disk and
//! replayed through a fresh cluster digests identically to serving the
//! in-memory synthetic stream), malformed input surfaces as the typed
//! [`TraceError`] variant it documents (never a panic), and u64 values
//! above f64's 2^53 integer ceiling survive the decimal-string
//! transport through a real file on disk.

mod common;

use std::path::{Path, PathBuf};

use common::{chaos_opts, chaos_session};
use odimo::api::{ClusterOpts, Trace, TraceError};
use odimo::serve::{Sla, TraceRecord};

fn fixture_path() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../config/trace_demo.jsonl")
}

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A syntactically perfect line to mutate one field at a time.
fn good_line() -> String {
    concat!(
        r#"{"arrival_cycle":"100","sla":{"latency_budget":"800000"},"#,
        r#""tenant":"interactive","model":"tinycnn","seed":"42"}"#
    )
    .to_string()
}

#[test]
fn golden_fixture_is_canonical_and_well_formed() {
    let trace = Trace::load(&fixture_path()).unwrap();
    assert_eq!(trace.len(), 24, "golden fixture carries 24 requests");
    // canonical: re-emitting reproduces the checked-in bytes exactly,
    // so hand edits that drift from the writer's format fail loudly
    let on_disk = std::fs::read_to_string(fixture_path()).unwrap();
    assert_eq!(trace.to_jsonl_text(), on_disk, "fixture must stay in canonical form");
    let mut prev = 0u64;
    let mut min_energy = 0usize;
    let mut budget = 0usize;
    for r in &trace.records {
        assert!(r.arrival_cycle >= prev, "fixture arrivals must be sorted");
        prev = r.arrival_cycle;
        assert_eq!(r.model, "tinycnn");
        assert!(
            ["interactive", "batch", "bulk"].contains(&r.tenant.as_str()),
            "unexpected tenant {}",
            r.tenant
        );
        match r.sla {
            Sla::MinEnergy => min_energy += 1,
            Sla::LatencyBudget(_) => budget += 1,
        }
    }
    assert!(min_energy > 0 && budget > 0, "fixture must exercise both SLA kinds");
}

/// Record-then-replay: `serve --record-trace` then `serve --trace` is
/// the identity. A synthesized trace saved to disk, loaded back and
/// replayed through a fresh cluster produces the same digest as a
/// fresh cluster consuming the in-memory synthetic stream directly.
#[test]
fn recorded_trace_replays_digest_for_digest() {
    let dir = fresh_dir("odimo_trace_record_replay");
    let copts = ClusterOpts {
        replicas: 2,
        serve: chaos_opts(None),
        continuous: true,
        steal_max: 2,
        compile_cycles: 5_000,
        plan_cache_cap: 8,
    };
    let trace = chaos_session(&dir, 2).synth_trace(&copts.serve).unwrap();
    let path = dir.join("recorded.jsonl");
    trace.save(&path).unwrap();
    let loaded = Trace::load(&path).unwrap();
    assert_eq!(trace, loaded, "save/load must be the identity on records");
    let replayed = chaos_session(&dir, 2).serve_cluster(&copts, Some(&loaded)).unwrap();
    let synthetic = chaos_session(&dir, 2).serve_cluster(&copts, None).unwrap();
    assert_eq!(
        replayed.deterministic_digest(),
        synthetic.deterministic_digest(),
        "replaying the recorded trace must match serving the synthetic stream"
    );
    assert_eq!(replayed.accounted(), trace.len() as u64);
}

#[test]
fn u64_above_f64_precision_survives_a_file_roundtrip() {
    let dir = fresh_dir("odimo_trace_big_u64");
    std::fs::create_dir_all(&dir).unwrap();
    let big = (1u64 << 53) + 1; // unrepresentable as f64
    let trace = Trace {
        records: vec![TraceRecord {
            arrival_cycle: big,
            sla: Sla::LatencyBudget(u64::MAX),
            tenant: "bulk".to_string(),
            model: "tinycnn".to_string(),
            seed: u64::MAX - 1,
        }],
    };
    let path = dir.join("big.jsonl");
    trace.save(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(
        text.contains(&big.to_string()) && text.contains(&u64::MAX.to_string()),
        "values must travel as exact decimal strings: {text}"
    );
    let back = Trace::load(&path).unwrap();
    assert_eq!(back, trace);
}

#[test]
fn missing_file_is_a_typed_io_error() {
    let path = std::env::temp_dir().join("odimo_trace_does_not_exist.jsonl");
    let _ = std::fs::remove_file(&path);
    match Trace::load(&path) {
        Err(TraceError::Io { path: p, .. }) => {
            assert!(p.contains("odimo_trace_does_not_exist"), "{p}")
        }
        other => panic!("expected TraceError::Io, got {other:?}"),
    }
}

#[test]
fn truncated_json_is_a_parse_error_with_line_number() {
    let text = format!("{}\n{}\n", good_line(), r#"{"arrival_cycle":"200","#);
    match Trace::from_jsonl_text(&text) {
        Err(TraceError::Parse { line: 2, .. }) => {}
        other => panic!("expected Parse at line 2, got {other:?}"),
    }
    // a bare non-object is also Parse, not a panic
    match Trace::from_jsonl_text("[1, 2, 3]") {
        Err(TraceError::Parse { line: 1, .. }) => {}
        other => panic!("expected Parse at line 1, got {other:?}"),
    }
}

#[test]
fn each_field_failure_maps_to_its_documented_variant() {
    // missing field (drop tenant)
    let no_tenant = good_line().replace(r#""tenant":"interactive","#, "");
    match Trace::from_jsonl_text(&no_tenant) {
        Err(TraceError::MissingField { line: 1, field: "tenant" }) => {}
        other => panic!("expected MissingField(tenant), got {other:?}"),
    }
    // JSON-number cycle value: rejected to protect > 2^53 integers
    let numeric = good_line().replace(r#""arrival_cycle":"100""#, r#""arrival_cycle":100"#);
    match Trace::from_jsonl_text(&numeric) {
        Err(TraceError::BadNumber { line: 1, field: "arrival_cycle", .. }) => {}
        other => panic!("expected BadNumber(arrival_cycle), got {other:?}"),
    }
    // non-decimal seed string
    let bad_seed = good_line().replace(r#""seed":"42""#, r#""seed":"forty-two""#);
    match Trace::from_jsonl_text(&bad_seed) {
        Err(TraceError::BadNumber { line: 1, field: "seed", value }) => {
            assert!(value.contains("forty-two"), "{value}")
        }
        other => panic!("expected BadNumber(seed), got {other:?}"),
    }
    // uppercase tenant violates [a-z0-9_-]+
    let bad_tenant = good_line().replace(r#""tenant":"interactive""#, r#""tenant":"Interactive""#);
    match Trace::from_jsonl_text(&bad_tenant) {
        Err(TraceError::BadTenant { line: 1, tenant }) => assert_eq!(tenant, "Interactive"),
        other => panic!("expected BadTenant, got {other:?}"),
    }
    // unknown model
    let bad_model = good_line().replace(r#""model":"tinycnn""#, r#""model":"resnet999""#);
    match Trace::from_jsonl_text(&bad_model) {
        Err(TraceError::UnknownModel { line: 1, model }) => assert_eq!(model, "resnet999"),
        other => panic!("expected UnknownModel, got {other:?}"),
    }
    // sla neither "min_energy" nor {"latency_budget": "..."}
    let bad_sla = good_line().replace(r#"{"latency_budget":"800000"}"#, r#""fastest""#);
    match Trace::from_jsonl_text(&bad_sla) {
        Err(TraceError::BadSla { line: 1, .. }) => {}
        other => panic!("expected BadSla, got {other:?}"),
    }
    // sorted-arrival enforcement across records
    let text = format!(
        "{}\n{}\n",
        good_line(),
        good_line().replace(r#""arrival_cycle":"100""#, r#""arrival_cycle":"99""#)
    );
    match Trace::from_jsonl_text(&text) {
        Err(TraceError::OutOfOrder { line: 2, prev: 100, got: 99 }) => {}
        other => panic!("expected OutOfOrder, got {other:?}"),
    }
    // every error above implements Display + Error and carries its line
    let e = Trace::from_jsonl_text(&no_tenant).unwrap_err();
    let shown = format!("{e}");
    assert!(shown.contains("line 1"), "{shown}");
    let _dyn: &dyn std::error::Error = &e;
}

/// Blank lines separate sections in hand-maintained traces; they must
/// be ignored without shifting the reported line numbers of later
/// errors.
#[test]
fn blank_lines_are_skipped_but_line_numbers_stay_physical() {
    let text = format!("\n{}\n\n{}\n", good_line(), "not json");
    match Trace::from_jsonl_text(&text) {
        Err(TraceError::Parse { line: 4, .. }) => {}
        other => panic!("expected Parse at physical line 4, got {other:?}"),
    }
    let ok = format!("\n{}\n\n", good_line());
    let tr = Trace::from_jsonl_text(&ok).unwrap();
    assert_eq!(tr.len(), 1);
}
