//! Integration: load real artifacts, execute them over PJRT, verify the
//! numeric contract between the rust coordinator and the AOT graphs.
//!
//! These tests are skipped (with a notice) when `make artifacts` has not
//! run — CI invokes them through the Makefile which builds artifacts
//! first.

use std::path::PathBuf;

use anyhow::anyhow;
use odimo::data::DataSource;
use odimo::model::Graph;
use odimo::runtime::{
    assemble_inputs, literal_f32, literal_i32, literal_scalar, ArtifactMeta, ParamState,
    Runtime,
};

fn art_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    art_dir().join("tinycnn_meta.json").exists()
}

#[test]
fn eval_float_runs_and_counts() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let meta = ArtifactMeta::load(&art_dir(), "tinycnn").unwrap();
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(meta.graph("eval_float").unwrap()).unwrap();

    let params = ParamState::from_init(&meta).unwrap();
    let g = &meta.model;
    let ds = DataSource::test(g, 1234);
    let batch = ds.batch(0, g.eval_batch);
    let xb = literal_f32(&batch.x, &[batch.n, batch.c, batch.h, batch.w]).unwrap();
    let yb = literal_i32(&batch.y, &[batch.n]).unwrap();

    let inputs = assemble_inputs(&exe.meta, |tm| match tm.name.as_str() {
        "x" => Ok(&xb),
        "y" => Ok(&yb),
        n if n.starts_with("param:") => params.leaf(&n[6..]),
        n => Err(anyhow!("unexpected input {n}")),
    })
    .unwrap();
    let out = exe.run_to_host(&inputs).unwrap();
    let stats = &out[out.len() - 1];
    assert_eq!(stats.len(), 2, "stats vector");
    let correct = stats[0];
    let loss_sum = stats[1];
    assert!((0.0..=g.eval_batch as f32).contains(&correct), "correct={correct}");
    assert!(loss_sum > 0.0);
    // untrained network should be near chance
    let acc = correct / g.eval_batch as f32;
    assert!(acc < 0.5, "untrained acc suspiciously high: {acc}");
}

#[test]
fn train_float_step_updates_params() {
    if !have_artifacts() {
        return;
    }
    let meta = ArtifactMeta::load(&art_dir(), "tinycnn").unwrap();
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(meta.graph("train_float").unwrap()).unwrap();
    let g = &meta.model;

    let mut params = ParamState::from_init(&meta).unwrap();
    let mut mom = ParamState::zeros(&meta).unwrap();
    let before = params.leaf_to_host("stem/w").unwrap();

    let ds = DataSource::train(g, 1234);
    let batch = ds.batch(0, g.train_batch);
    let xb = literal_f32(&batch.x, &[batch.n, batch.c, batch.h, batch.w]).unwrap();
    let yb = literal_i32(&batch.y, &[batch.n]).unwrap();
    let lr = literal_scalar(0.1);
    let lr_a = literal_scalar(0.1);
    let mu = literal_scalar(0.9);
    let wd = literal_scalar(1e-4);

    let inputs = assemble_inputs(&exe.meta, |tm| match tm.name.as_str() {
        "x" => Ok(&xb),
        "y" => Ok(&yb),
        "lr" => Ok(&lr),
        "lr_alpha" => Ok(&lr_a),
        "mu" => Ok(&mu),
        "wd" => Ok(&wd),
        n if n.starts_with("param:") => params.leaf(&n[6..]),
        n if n.starts_with("mom:") => mom.leaf(&n[4..]),
        n => Err(anyhow!("unexpected input {n}")),
    })
    .unwrap();
    let mut out = exe.run(&inputs).unwrap();

    // outputs = params' (P) + mom' (P) + metrics(6)
    let p = meta.params.len();
    assert_eq!(out.len(), 2 * p + 1, "output leaf count");
    params.replace_from_outputs(&mut out);
    mom.replace_from_outputs(&mut out);
    let metrics = odimo::runtime::literal_to_f32(&out[0]).unwrap();
    assert_eq!(metrics.len(), 6);
    assert!(metrics[0].is_finite() && metrics[0] > 0.0, "loss {}", metrics[0]);
    assert!((0.0..=g.train_batch as f32).contains(&metrics[1]));

    let after = params.leaf_to_host("stem/w").unwrap();
    assert_eq!(after.len(), before.len());
    let diff: f32 = after
        .iter()
        .zip(&before)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(diff > 0.0, "params did not move");
}

#[test]
fn param_state_checkpoint_roundtrip() {
    if !have_artifacts() {
        return;
    }
    let meta = ArtifactMeta::load(&art_dir(), "tinycnn").unwrap();
    let params = ParamState::from_init(&meta).unwrap();
    let dir = std::env::temp_dir().join("odimo_ckpt_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("p.bin");
    params.save(&path).unwrap();
    let back = ParamState::load(&meta, &path).unwrap();
    for name in ["stem/w", "fc/b", "c1/alpha"] {
        assert_eq!(
            params.leaf_to_host(name).unwrap(),
            back.leaf_to_host(name).unwrap(),
            "{name}"
        );
    }
}

#[test]
fn graph_meta_matches_native_builder() {
    if !have_artifacts() {
        return;
    }
    for name in ["tinycnn", "resnet20", "resnet18s", "mbv1_025"] {
        if !art_dir().join(format!("{name}_meta.json")).exists() {
            continue;
        }
        let meta = ArtifactMeta::load(&art_dir(), name).unwrap();
        let native: Graph = odimo::model::build(name).unwrap();
        assert_eq!(meta.model.nodes.len(), native.nodes.len(), "{name} node count");
        for (a, b) in meta.model.nodes.iter().zip(&native.nodes) {
            assert_eq!(a.name, b.name, "{name}");
            assert_eq!(a.op, b.op, "{name}/{}", a.name);
            assert_eq!(a.cout, b.cout, "{name}/{}", a.name);
            assert_eq!(a.cin, b.cin, "{name}/{}", a.name);
            assert_eq!(a.out_hw, b.out_hw, "{name}/{}", a.name);
            assert_eq!(a.stride, b.stride, "{name}/{}", a.name);
        }
    }
}
