//! Hand-rolled CLI argument parser (clap is not in the vendored set).
//!
//! Grammar: `odimo <subcommand> [--flag value]... [--switch]...`
//! Flags may repeat the `--key value` or `--key=value` forms.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    /// Switch names the command accepts (everything else with no value
    /// is an error).
    known_switches: Vec<&'static str>,
}

impl Args {
    pub fn parse(argv: &[String], known_switches: &[&'static str]) -> Result<Args> {
        let mut a = Args {
            known_switches: known_switches.to_vec(),
            ..Default::default()
        };
        let mut it = argv.iter().peekable();
        if let Some(sub) = it.next() {
            if sub.starts_with('-') {
                return Err(anyhow!("expected subcommand, got '{sub}'"));
            }
            a.subcommand = sub.clone();
        }
        while let Some(tok) = it.next() {
            let Some(key) = tok.strip_prefix("--") else {
                return Err(anyhow!("unexpected positional argument '{tok}'"));
            };
            if let Some((k, v)) = key.split_once('=') {
                a.flags.insert(k.to_string(), v.to_string());
            } else if known_switches.contains(&key) {
                a.switches.push(key.to_string());
            } else if let Some(v) = it.peek() {
                if v.starts_with("--") {
                    return Err(anyhow!("flag --{key} needs a value"));
                }
                a.flags.insert(key.to_string(), it.next().unwrap().clone());
            } else {
                return Err(anyhow!("flag --{key} needs a value"));
            }
        }
        Ok(a)
    }

    pub fn from_env(known_switches: &[&'static str]) -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&argv, known_switches)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_f32(&self, key: &str) -> Result<Option<f32>> {
        self.get(key)
            .map(|v| v.parse::<f32>().map_err(|_| anyhow!("--{key}: bad number '{v}'")))
            .transpose()
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        self.get(key)
            .map(|v| v.parse::<usize>().map_err(|_| anyhow!("--{key}: bad number '{v}'")))
            .transpose()
    }

    pub fn get_u64(&self, key: &str) -> Result<Option<u64>> {
        self.get(key)
            .map(|v| v.parse::<u64>().map_err(|_| anyhow!("--{key}: bad number '{v}'")))
            .transpose()
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    /// Error on flags the command does not know (catches typos).
    pub fn expect_only(&self, keys: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !keys.contains(&k.as_str()) {
                return Err(anyhow!(
                    "unknown flag --{k} for '{}' (known: {})",
                    self.subcommand,
                    keys.join(", ")
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_flags_and_switches() {
        let a = Args::parse(&argv("fig4 --model resnet20 --smoke --lam=0.5"), &["smoke"]).unwrap();
        assert_eq!(a.subcommand, "fig4");
        assert_eq!(a.get("model"), Some("resnet20"));
        assert_eq!(a.get("lam"), Some("0.5"));
        assert!(a.has("smoke"));
        assert_eq!(a.get_f32("lam").unwrap(), Some(0.5));
    }

    #[test]
    fn parses_u64_values() {
        let a = Args::parse(&argv("serve --seed 42 --threads 8"), &[]).unwrap();
        assert_eq!(a.get_u64("seed").unwrap(), Some(42));
        assert_eq!(a.get_usize("threads").unwrap(), Some(8));
        assert!(a.get_u64("threads").is_ok());
        let b = Args::parse(&argv("serve --seed nope"), &[]).unwrap();
        assert!(b.get_u64("seed").is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&argv("run --model"), &[]).is_err());
        assert!(Args::parse(&argv("run --model --x y"), &[]).is_err());
    }

    #[test]
    fn unknown_flag_detected() {
        let a = Args::parse(&argv("fig4 --modell tiny"), &[]).unwrap();
        assert!(a.expect_only(&["model"]).is_err());
        let b = Args::parse(&argv("fig4 --model tiny"), &[]).unwrap();
        assert!(b.expect_only(&["model"]).is_ok());
    }

    #[test]
    fn positional_rejected() {
        assert!(Args::parse(&argv("fig4 oops"), &[]).is_err());
    }
}
