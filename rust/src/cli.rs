//! Hand-rolled CLI argument parser (clap is not in the vendored set).
//!
//! Grammar: `odimo <subcommand> [--flag value]... [--switch]...`
//! Flags may repeat the `--key value` or `--key=value` forms.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    /// Switch names the command accepts (everything else with no value
    /// is an error).
    known_switches: Vec<&'static str>,
}

impl Args {
    pub fn parse(argv: &[String], known_switches: &[&'static str]) -> Result<Args> {
        let mut a = Args {
            known_switches: known_switches.to_vec(),
            ..Default::default()
        };
        let mut it = argv.iter().peekable();
        if let Some(sub) = it.next() {
            if sub.starts_with('-') {
                return Err(anyhow!("expected subcommand, got '{sub}'"));
            }
            a.subcommand = sub.clone();
        }
        while let Some(tok) = it.next() {
            let Some(key) = tok.strip_prefix("--") else {
                return Err(anyhow!("unexpected positional argument '{tok}'"));
            };
            if let Some((k, v)) = key.split_once('=') {
                a.flags.insert(k.to_string(), v.to_string());
            } else if known_switches.contains(&key) {
                a.switches.push(key.to_string());
            } else if let Some(v) = it.peek() {
                if v.starts_with("--") {
                    return Err(anyhow!("flag --{key} needs a value"));
                }
                a.flags.insert(key.to_string(), it.next().unwrap().clone());
            } else {
                return Err(anyhow!("flag --{key} needs a value"));
            }
        }
        Ok(a)
    }

    pub fn from_env(known_switches: &[&'static str]) -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&argv, known_switches)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_f32(&self, key: &str) -> Result<Option<f32>> {
        self.get(key)
            .map(|v| v.parse::<f32>().map_err(|_| anyhow!("--{key}: bad number '{v}'")))
            .transpose()
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        self.get(key)
            .map(|v| v.parse::<usize>().map_err(|_| anyhow!("--{key}: bad number '{v}'")))
            .transpose()
    }

    pub fn get_u64(&self, key: &str) -> Result<Option<u64>> {
        self.get(key)
            .map(|v| v.parse::<u64>().map_err(|_| anyhow!("--{key}: bad number '{v}'")))
            .transpose()
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    /// Error on flags the command does not know (catches typos).
    pub fn expect_only(&self, keys: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !keys.contains(&k.as_str()) {
                return Err(anyhow!(
                    "unknown flag --{k} for '{}' (known: {})",
                    self.subcommand,
                    keys.join(", ")
                ));
            }
        }
        Ok(())
    }

    /// Validate every given flag and switch against one [`VerbSpec`]
    /// row of the shared verb table — a flag the verb would silently
    /// ignore is an error, not a no-op.
    pub fn expect_verb(&self, verb: &VerbSpec) -> Result<()> {
        for k in self.flags.keys() {
            if !verb.flags.contains(&k.as_str()) {
                return Err(anyhow!(
                    "unknown flag --{k} for '{}' (known: {})",
                    self.subcommand,
                    verb.flags
                        .iter()
                        .map(|f| format!("--{f}"))
                        .collect::<Vec<_>>()
                        .join(" ")
                ));
            }
        }
        for sw in &self.switches {
            if !verb.switches.contains(&sw.as_str()) {
                return Err(anyhow!("--{sw} has no effect on '{}'", self.subcommand));
            }
        }
        Ok(())
    }
}

// ---- CLI specification ------------------------------------------------
//
// The single source of truth for verbs and flags: `usage()` renders the
// help text from these tables and `Args::expect_verb` validates against
// the same rows, so the help can never drift from what is accepted
// (pinned by the `spec_*` tests below).

/// One flag the CLI understands. `value` is the placeholder rendered in
/// the usage text; `None` marks a switch (present/absent, no value).
pub struct FlagSpec {
    /// Flag name without the `--` prefix.
    pub name: &'static str,
    /// Value placeholder (`None` = switch).
    pub value: Option<&'static str>,
    /// One-line help rendered in the FLAGS section.
    pub help: &'static str,
}

/// Every flag or switch any verb accepts, in usage-text order.
pub const FLAGS: &[FlagSpec] = &[
    FlagSpec {
        name: "model",
        value: Some("<tinycnn|resnet20|resnet18s|mbv1_025>"),
        help: "model to operate on (default resnet20; sweep/serve default to tinycnn)",
    },
    FlagSpec { name: "config", value: Some("<file.toml>"), help: "load a RunConfig" },
    FlagSpec {
        name: "platform",
        value: Some("<name|file>"),
        help: "deployment SoC: built-in name (diana, diana_ne16, gap9, mpsoc4) or a \
               platform .toml path",
    },
    FlagSpec {
        name: "artifacts",
        value: Some("<dir>"),
        help: "artifacts directory (default artifacts)",
    },
    FlagSpec {
        name: "results",
        value: Some("<dir>"),
        help: "results directory (default results)",
    },
    FlagSpec {
        name: "smoke",
        value: None,
        help: "tiny schedules / request streams (CI, smoke testing)",
    },
    FlagSpec {
        name: "lambdas",
        value: Some("<a,b,c>"),
        help: "override the sweep lambda list",
    },
    FlagSpec {
        name: "baseline",
        value: Some("<name>"),
        help: "one of: all_8bit, all_ternary, io8_backbone_ternary, even_split, \
               min_cost_lat, min_cost_en",
    },
    FlagSpec {
        name: "non-ideal-l1",
        value: None,
        help: "enable L1 tiling penalties in the simulator",
    },
    FlagSpec {
        name: "threads",
        value: Some("<n>"),
        help: "worker threads for engine runs (ThreadPool size; default: machine \
               parallelism, capped)",
    },
    FlagSpec {
        name: "seed",
        value: Some("<u64>"),
        help: "global seed, default 1234: data seed for the pipeline verbs, \
               parameter/request streams for sweep/serve",
    },
    FlagSpec {
        name: "mapping",
        value: Some("<file.json>"),
        help: "simulate a mapping loaded from JSON instead of a baseline",
    },
    FlagSpec {
        name: "lambda",
        value: Some("<v>"),
        help: "search: regularization strength (default 0.5)",
    },
    FlagSpec { name: "reg", value: Some("<lat|en>"), help: "search: regularizer (default en)" },
    FlagSpec {
        name: "requests",
        value: Some("<n>"),
        help: "serve: requests in the synthetic stream (default 96; 24 with --smoke)",
    },
    FlagSpec {
        name: "max-batch",
        value: Some("<n>"),
        help: "serve: batcher flush threshold (1 = unbatched)",
    },
    FlagSpec {
        name: "max-wait",
        value: Some("<cyc>"),
        help: "serve: batcher wait bound, simulated cycles",
    },
    FlagSpec {
        name: "gap",
        value: Some("<cyc>"),
        help: "serve: mean inter-arrival gap, simulated cycles",
    },
    FlagSpec {
        name: "faults",
        value: Some("<file.toml|json>"),
        help: "serve: inject a scripted accelerator fault plan on the virtual timeline \
               (see EXPERIMENTS.md for the schema)",
    },
    FlagSpec {
        name: "overload-wait",
        value: Some("<cyc>"),
        help: "serve: admission control — shed/degrade arrivals whose projected device \
               wait exceeds this many simulated cycles (default: never)",
    },
    FlagSpec {
        name: "max-retries",
        value: Some("<n>"),
        help: "serve: re-enqueue budget per request before it is accounted failed \
               (default 3)",
    },
    FlagSpec {
        name: "replicas",
        value: Some("<n>"),
        help: "serve: replica count for the cluster driver (default 1; >1 enables \
               least-loaded routing + work stealing)",
    },
    FlagSpec {
        name: "models",
        value: Some("<a,b.json,...>"),
        help: "serve: comma-separated serving set — built-in names and/or imported \
               graph .json paths; trace records route to models by name and batches \
               never mix models (enables the cluster driver)",
    },
    FlagSpec {
        name: "trace",
        value: Some("<file.jsonl>"),
        help: "serve: replay this request trace instead of synthesizing one (JSONL, \
               see EXPERIMENTS.md for the schema)",
    },
    FlagSpec {
        name: "record-trace",
        value: Some("<file.jsonl>"),
        help: "serve: write the request trace this run used (synthesized or replayed) \
               for later replay",
    },
    FlagSpec {
        name: "steal-max",
        value: Some("<n>"),
        help: "serve: most requests one work-stealing event may move between replicas \
               (default 2; 0 disables stealing)",
    },
    FlagSpec {
        name: "compile-cycles",
        value: Some("<cyc>"),
        help: "serve: virtual cycles the first batch on a frontier point waits for \
               async plan compilation (default 0 = warm)",
    },
    FlagSpec {
        name: "flush",
        value: None,
        help: "serve: disable continuous batching (flush-and-wait, the single-session \
               behavior)",
    },
    FlagSpec {
        name: "kernels",
        value: Some("<scalar|simd|auto>"),
        help: "engine kernel backend: scalar reference loops, simd (AVX2/NEON when the \
               CPU has them, portable chunked otherwise), or auto runtime detection \
               (default auto; ODIMO_KERNELS overrides auto)",
    },
    FlagSpec {
        name: "trace-events",
        value: Some("<out.json>"),
        help: "serve: export the run's span/event stream as Chrome trace-event / \
               Perfetto JSON (implies --obs-level basic); trace-view: the file to \
               summarize",
    },
    FlagSpec {
        name: "obs-level",
        value: Some("<off|basic|full>"),
        help: "serve: observability level — basic records the deterministic \
               virtual-cycle event stream, full adds wall-clock engine/kernel spans \
               (default off, or basic when --trace-events is given)",
    },
    FlagSpec {
        name: "top",
        value: Some("<n>"),
        help: "trace-view: rows per section (default 10)",
    },
];

/// One subcommand: its help line plus exactly the flags and switches it
/// accepts (everything else is an error).
pub struct VerbSpec {
    /// Subcommand name.
    pub name: &'static str,
    /// One-line help rendered in the COMMANDS section.
    pub help: &'static str,
    /// Accepted value flags (names into [`FLAGS`]).
    pub flags: &'static [&'static str],
    /// Accepted switches (names into [`FLAGS`] with `value: None`).
    pub switches: &'static [&'static str],
}

/// Flags shared by the pipeline/experiment verbs.
const COMMON_FLAGS: &[&str] =
    &["model", "config", "platform", "artifacts", "results", "lambdas", "seed"];
const COMMON_SWITCHES: &[&str] = &["smoke", "non-ideal-l1"];
/// The serving verbs honor only these — `--config`/`--lambdas`/... and
/// `--non-ideal-l1` would be silent no-ops (the sweep always scores the
/// ideal-L1 simulator config), so they are rejected, not ignored.
const SERVE_FLAGS: &[&str] = &["model", "platform", "results", "threads", "seed", "kernels"];

/// Every subcommand, in usage-text order.
pub const VERBS: &[VerbSpec] = &[
    VerbSpec {
        name: "fig4",
        help: "accuracy-vs-latency/energy Pareto sweep (paper Fig. 4)",
        flags: COMMON_FLAGS,
        switches: COMMON_SWITCHES,
    },
    VerbSpec {
        name: "fig5",
        help: "abstract-hardware sweeps (paper Fig. 5)",
        flags: COMMON_FLAGS,
        switches: COMMON_SWITCHES,
    },
    VerbSpec {
        name: "table1",
        help: "deployment table on the SoC simulator (paper Table I)",
        flags: COMMON_FLAGS,
        switches: COMMON_SWITCHES,
    },
    VerbSpec {
        name: "fig6",
        help: "per-layer utilization breakdown (paper Fig. 6)",
        flags: COMMON_FLAGS,
        switches: COMMON_SWITCHES,
    },
    VerbSpec {
        name: "search",
        help: "single ODiMO run at a fixed lambda",
        flags: &["model", "config", "platform", "artifacts", "results", "lambdas",
                 "seed", "lambda", "reg"],
        switches: COMMON_SWITCHES,
    },
    VerbSpec {
        name: "simulate",
        help: "cost a baseline or mapping file on the SoC simulator",
        flags: &["model", "config", "platform", "baseline", "mapping"],
        switches: &["non-ideal-l1"],
    },
    VerbSpec {
        name: "inspect",
        help: "print model geometry and per-layer cost bounds",
        flags: &["model", "config", "platform"],
        switches: &[],
    },
    VerbSpec {
        name: "platforms",
        help: "list built-in platforms and their accelerators",
        flags: &[],
        switches: &[],
    },
    VerbSpec {
        name: "sweep",
        help: "build (or load) the cached mapping Pareto frontier",
        flags: SERVE_FLAGS,
        switches: &[],
    },
    VerbSpec {
        name: "serve",
        help: "closed-loop SLA-aware batched inference over the frontier",
        flags: &["model", "models", "platform", "results", "threads", "seed", "requests",
                 "max-batch", "max-wait", "gap", "faults", "overload-wait",
                 "max-retries", "replicas", "trace", "record-trace", "steal-max",
                 "compile-cycles", "kernels", "trace-events", "obs-level"],
        switches: &["smoke", "flush"],
    },
    VerbSpec {
        name: "serve-report",
        help: "render the dashboard of the last serve run",
        flags: &["model", "platform", "results"],
        switches: &[],
    },
    VerbSpec {
        name: "trace-view",
        help: "summarize an exported trace-events file (slowest spans, cache hit \
               rate, per-unit busy/energy split)",
        flags: &["trace-events", "top"],
        switches: &[],
    },
];

/// Look up a verb's spec row by subcommand name.
pub fn verb(name: &str) -> Option<&'static VerbSpec> {
    VERBS.iter().find(|v| v.name == name)
}

/// Look up a flag's spec row by name.
pub fn flag(name: &str) -> Option<&'static FlagSpec> {
    FLAGS.iter().find(|f| f.name == name)
}

/// Names of every switch in [`FLAGS`] (what [`Args::parse`] must treat
/// as valueless).
pub fn switch_names() -> Vec<&'static str> {
    FLAGS.iter().filter(|f| f.value.is_none()).map(|f| f.name).collect()
}

/// Append `text` word-wrapped at `width` columns, continuation lines
/// indented by `indent` spaces.
fn push_wrapped(out: &mut String, first_prefix: &str, indent: usize, width: usize, text: &str) {
    let mut line = first_prefix.to_string();
    for word in text.split_whitespace() {
        if line.len() + 1 + word.len() > width && line.len() > indent {
            out.push_str(line.trim_end());
            out.push('\n');
            line = " ".repeat(indent);
        }
        line.push(' ');
        line.push_str(word);
    }
    out.push_str(line.trim_end());
    out.push('\n');
}

/// Render the complete `odimo help` text from [`VERBS`] and [`FLAGS`]
/// — the only generator, so help and accepted flags cannot drift.
pub fn usage() -> String {
    let mut s = String::from(
        "odimo — precision-aware DNN mapping on multi-accelerator SoCs (ODiMO)\n\n\
         USAGE: odimo <command> [flags]\n\nCOMMANDS\n",
    );
    for v in VERBS {
        push_wrapped(&mut s, &format!("  {:<13}", v.name), 15, 78, v.help);
        let mut toks: Vec<String> = v.flags.iter().map(|f| format!("--{f}")).collect();
        toks.extend(v.switches.iter().map(|f| format!("[--{f}]")));
        if !toks.is_empty() {
            push_wrapped(&mut s, "                  flags:", 24, 78, &toks.join(" "));
        }
    }
    s.push_str("  help          this text\n\nFLAGS\n");
    for f in FLAGS {
        let head = match f.value {
            Some(v) => format!("  --{} {}", f.name, v),
            None => format!("  --{}", f.name),
        };
        if head.len() >= 28 {
            s.push_str(&head);
            s.push('\n');
            push_wrapped(&mut s, &" ".repeat(27), 27, 78, f.help);
        } else {
            push_wrapped(&mut s, &format!("{head:<27}"), 27, 78, f.help);
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_flags_and_switches() {
        let a = Args::parse(&argv("fig4 --model resnet20 --smoke --lam=0.5"), &["smoke"]).unwrap();
        assert_eq!(a.subcommand, "fig4");
        assert_eq!(a.get("model"), Some("resnet20"));
        assert_eq!(a.get("lam"), Some("0.5"));
        assert!(a.has("smoke"));
        assert_eq!(a.get_f32("lam").unwrap(), Some(0.5));
    }

    #[test]
    fn parses_u64_values() {
        let a = Args::parse(&argv("serve --seed 42 --threads 8"), &[]).unwrap();
        assert_eq!(a.get_u64("seed").unwrap(), Some(42));
        assert_eq!(a.get_usize("threads").unwrap(), Some(8));
        assert!(a.get_u64("threads").is_ok());
        let b = Args::parse(&argv("serve --seed nope"), &[]).unwrap();
        assert!(b.get_u64("seed").is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&argv("run --model"), &[]).is_err());
        assert!(Args::parse(&argv("run --model --x y"), &[]).is_err());
    }

    #[test]
    fn unknown_flag_detected() {
        let a = Args::parse(&argv("fig4 --modell tiny"), &[]).unwrap();
        assert!(a.expect_only(&["model"]).is_err());
        let b = Args::parse(&argv("fig4 --model tiny"), &[]).unwrap();
        assert!(b.expect_only(&["model"]).is_ok());
    }

    #[test]
    fn positional_rejected() {
        assert!(Args::parse(&argv("fig4 oops"), &[]).is_err());
    }

    // ---- spec-table consistency: help text cannot drift ----

    #[test]
    fn spec_verbs_reference_only_declared_flags() {
        for v in VERBS {
            for f in v.flags {
                let spec = flag(f).unwrap_or_else(|| panic!("{}: unknown flag '{f}'", v.name));
                assert!(
                    spec.value.is_some(),
                    "{}: '{f}' is a switch but listed under flags",
                    v.name
                );
            }
            for sw in v.switches {
                let spec =
                    flag(sw).unwrap_or_else(|| panic!("{}: unknown switch '{sw}'", v.name));
                assert!(
                    spec.value.is_none(),
                    "{}: '{sw}' takes a value but listed under switches",
                    v.name
                );
            }
        }
    }

    #[test]
    fn spec_every_flag_is_used_by_some_verb() {
        for f in FLAGS {
            let used = VERBS
                .iter()
                .any(|v| v.flags.contains(&f.name) || v.switches.contains(&f.name));
            assert!(used, "flag '--{}' is declared but no verb accepts it", f.name);
        }
    }

    #[test]
    fn spec_names_are_unique() {
        for (i, v) in VERBS.iter().enumerate() {
            assert!(VERBS[i + 1..].iter().all(|w| w.name != v.name), "dup verb {}", v.name);
        }
        for (i, f) in FLAGS.iter().enumerate() {
            assert!(FLAGS[i + 1..].iter().all(|g| g.name != f.name), "dup flag {}", f.name);
        }
    }

    #[test]
    fn usage_mentions_every_verb_and_flag() {
        let text = usage();
        for v in VERBS {
            assert!(
                text.lines().any(|l| l.trim_start().starts_with(v.name)),
                "usage lost verb '{}'",
                v.name
            );
            // every flag the verb accepts appears on its flags line(s)
            for f in v.flags.iter().chain(v.switches.iter()) {
                assert!(text.contains(&format!("--{f}")), "usage lost --{f}");
            }
        }
        for f in FLAGS {
            assert!(
                text.contains(&format!("--{}", f.name)),
                "FLAGS section lost --{}",
                f.name
            );
        }
    }

    #[test]
    fn expect_verb_accepts_declared_rejects_undeclared() {
        let serve = verb("serve").unwrap();
        let ok = Args::parse(
            &argv("serve --model tinycnn --requests 8 --smoke"),
            &switch_names(),
        )
        .unwrap();
        ok.expect_verb(serve).unwrap();
        // a declared-elsewhere flag is rejected for this verb
        let bad = Args::parse(&argv("serve --lambda 0.5"), &switch_names()).unwrap();
        assert!(bad.expect_verb(serve).is_err());
        // a globally-known switch the verb does not take is rejected
        let sw = Args::parse(&argv("serve --non-ideal-l1"), &switch_names()).unwrap();
        let e = sw.expect_verb(serve).unwrap_err().to_string();
        assert!(e.contains("non-ideal-l1"), "{e}");
        let sweep = verb("sweep").unwrap();
        let smk = Args::parse(&argv("sweep --smoke"), &switch_names()).unwrap();
        assert!(smk.expect_verb(sweep).is_err(), "--smoke has no effect on sweep");
    }
}
