//! Class-conditional synthetic images (datagen.py mirror).
//!
//! Every formula, constant and PRNG draw order here matches
//! `python/compile/datagen.py`; the cross-language contract is pinned by
//! the SplitMix64 known-answer tests on both sides plus the statistical
//! tests below. (Exact float equality across languages is *not* required
//! — libm sin/cos may differ in the last ulp — only stream/parameter
//! identity.)

use crate::util::prng::SplitMix64;

/// Bump in lockstep with datagen.ALGO_VERSION.
pub const ALGO_VERSION: u32 = 1;
pub const N_COMPONENTS: usize = 3;
pub const NOISE_SIGMA: f32 = 0.15;
pub const PHASE_JITTER: f64 = 0.15;

#[derive(Clone, Debug)]
struct Component {
    theta: f64,
    freq: f64,
    phase: f64,
    color: [f64; 3],
    amp: f64,
}

/// Per-class grating mixture, derived from (dataset_seed, class).
#[derive(Clone, Debug)]
pub struct ClassSpec {
    comps: Vec<Component>,
}

impl ClassSpec {
    pub fn new(dataset_seed: u64, cls: u32) -> Self {
        let state = dataset_seed
            .wrapping_mul(0x517C_C1B7_2722_0A95)
            .wrapping_add((cls as u64).wrapping_mul(0x2545_F491_4F6C_DD1D))
            .wrapping_add(1);
        let mut rng = SplitMix64::new(state);
        let comps = (0..N_COMPONENTS)
            .map(|_| {
                let u_th = rng.next_f64();
                let u_fr = rng.next_f64();
                let u_ph = rng.next_f64();
                let u_r = rng.next_f64();
                let u_g = rng.next_f64();
                let u_b = rng.next_f64();
                let u_a = rng.next_f64();
                Component {
                    theta: u_th * std::f64::consts::PI,
                    freq: 1.5 + 3.5 * u_fr,
                    phase: u_ph * 2.0 * std::f64::consts::PI,
                    color: [u_r, u_g, u_b],
                    amp: 0.5 + 0.5 * u_a,
                }
            })
            .collect();
        Self { comps }
    }
}

/// One (3, h, w) image in [0, 1]; `split` 0 = train, 1 = test.
pub fn gen_sample(dataset_seed: u64, split: u32, index: u64, cls: u32,
                  h: usize, w: usize) -> Vec<f32> {
    let spec = ClassSpec::new(dataset_seed, cls);
    let state = dataset_seed
        ^ (split as u64).wrapping_mul(0xD6E8_FEB8_6659_FD93)
        ^ (index.wrapping_mul(0xA5A5_A5A5_A5A5_A5A5).wrapping_add(0x123_4567));
    let mut rng = SplitMix64::new(state);
    let mut img = vec![0f32; 3 * h * w];
    let two_pi = 2.0 * std::f64::consts::PI;
    // scratch for the separable wave evaluation (see below)
    let mut col_sin = vec![0f64; w];
    let mut col_cos = vec![0f64; w];
    for comp in &spec.comps {
        let u_pj = rng.next_f64();
        let u_aj = rng.next_f64();
        let phase = comp.phase + (u_pj - 0.5) * two_pi * PHASE_JITTER;
        let amp = comp.amp * (0.8 + 0.4 * u_aj);
        let cx = comp.theta.cos() * comp.freq;
        let cy = comp.theta.sin() * comp.freq;
        // sin(2pi(cx*fx + cy*fy) + phase) factored with the angle-sum
        // identity: O(h + w) transcendentals instead of O(h*w) — the
        // hot path of batch generation (EXPERIMENTS.md §Perf #4).
        for (ix, (s, c)) in col_sin.iter_mut().zip(col_cos.iter_mut()).enumerate() {
            let x_ang = two_pi * cx * (ix as f64 / w as f64);
            *s = x_ang.sin();
            *c = x_ang.cos();
        }
        for iy in 0..h {
            let y_ang = two_pi * cy * (iy as f64 / h as f64) + phase;
            let (ys, yc) = (y_ang.sin(), y_ang.cos());
            for ix in 0..w {
                let wave = col_sin[ix] * yc + col_cos[ix] * ys;
                let px = iy * w + ix;
                for ch in 0..3 {
                    img[ch * h * w + px] += (amp * comp.color[ch] * wave) as f32;
                }
            }
        }
    }
    // gaussian noise, same Box-Muller stream shape as python
    let n = 3 * h * w;
    let mut i = 0;
    while i < n {
        let (a, b) = rng.next_gauss_pair();
        img[i] += NOISE_SIGMA * a as f32;
        if i + 1 < n {
            img[i + 1] += NOISE_SIGMA * b as f32;
        }
        i += 2;
    }
    let norm = 2.0 * N_COMPONENTS as f32;
    for v in img.iter_mut() {
        *v = (0.5 + *v / norm).clamp(0.0, 1.0);
    }
    img
}

/// A generated batch: images NCHW-flat plus labels.
#[derive(Clone, Debug)]
pub struct Batch {
    pub x: Vec<f32>,  // (n, c, h, w) flattened
    pub y: Vec<i32>,  // (n,)
    pub n: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
}

/// Deterministic batch: sample `i` has class `i % classes` (python
/// `gen_batch` mirror).
pub fn gen_batch(dataset_seed: u64, split: u32, start: u64, n: usize,
                 classes: usize, c: usize, h: usize, w: usize) -> Batch {
    assert_eq!(c, 3, "generator produces 3-channel images");
    let mut x = Vec::with_capacity(n * c * h * w);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let idx = start + i as u64;
        let cls = (idx % classes as u64) as u32;
        x.extend_from_slice(&gen_sample(dataset_seed, split, idx, cls, h, w));
        y.push(cls as i32);
    }
    Batch { x, y, n, c, h, w }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = gen_sample(7, 0, 3, 1, 16, 16);
        let b = gen_sample(7, 0, 3, 1, 16, 16);
        assert_eq!(a, b);
    }

    #[test]
    fn bounded_unit_interval() {
        let img = gen_sample(7, 0, 0, 2, 24, 24);
        assert!(img.iter().all(|v| (0.0..=1.0).contains(v)));
        assert_eq!(img.len(), 3 * 24 * 24);
    }

    #[test]
    fn splits_differ() {
        let a = gen_sample(7, 0, 3, 1, 16, 16);
        let b = gen_sample(7, 1, 3, 1, 16, 16);
        let d: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        assert!(d > 1.0);
    }

    #[test]
    fn classes_distinguishable() {
        // Mirror of python test_classes_are_distinguishable: class means
        // separate far more than within-class resampling noise.
        let avg = |cls: u32, offs: u64| -> Vec<f32> {
            let mut acc = vec![0f32; 3 * 32 * 32];
            for i in 0..8u64 {
                let s = gen_sample(7, 0, offs + i * 17 + cls as u64, cls, 32, 32);
                for (a, v) in acc.iter_mut().zip(&s) {
                    *a += v / 8.0;
                }
            }
            acc
        };
        let m0 = avg(0, 0);
        let m0b = avg(0, 1000);
        let m1 = avg(1, 0);
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum::<f32>() / a.len() as f32
        };
        assert!(dist(&m0, &m1) > 2.0 * dist(&m0, &m0b));
    }

    #[test]
    fn batch_labels_cycle() {
        let b = gen_batch(1, 0, 10, 20, 10, 3, 8, 8);
        let want: Vec<i32> = (10..30).map(|i| (i % 10) as i32).collect();
        assert_eq!(b.y, want);
        assert_eq!(b.x.len(), 20 * 3 * 8 * 8);
    }

    #[test]
    fn class_spec_deterministic_across_calls() {
        let a = ClassSpec::new(5, 3);
        let b = ClassSpec::new(5, 3);
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }
}
