//! Synthetic benchmark datasets — the runtime data source.
//!
//! Bit-compatible mirror of `python/compile/datagen.py` (same SplitMix64
//! streams, same per-class grating mixtures). The rust side generates
//! training batches on the fly for the coordinator; python only uses its
//! copy in unit tests. See DESIGN.md §Substitutions for why synthetic
//! data stands in for CIFAR-10 / Tiny-ImageNet / VWW.

mod synth;

pub use synth::{gen_batch, gen_sample, Batch, ClassSpec, ALGO_VERSION};

use crate::model::Graph;

/// Streaming batch source for one model's train or test split.
pub struct DataSource {
    pub seed: u64,
    pub split: u32, // 0 = train, 1 = test
    pub classes: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
}

impl DataSource {
    pub fn train(g: &Graph, seed: u64) -> Self {
        Self {
            seed,
            split: 0,
            classes: g.classes,
            c: g.input_shape.0,
            h: g.input_shape.1,
            w: g.input_shape.2,
        }
    }

    pub fn test(g: &Graph, seed: u64) -> Self {
        Self { split: 1, ..Self::train(g, seed) }
    }

    /// Deterministic batch starting at sample index `start`.
    pub fn batch(&self, start: u64, n: usize) -> Batch {
        gen_batch(self.seed, self.split, start, n, self.classes, self.c, self.h, self.w)
    }
}
