//! # odimo — precision-aware DNN mapping on multi-accelerator SoCs
//!
//! Rust + JAX + Pallas reproduction of *"Precision-aware Latency and
//! Energy Balancing on Multi-Accelerator Platforms for DNN Inference"*
//! (Risso et al., 2023): the ODiMO one-shot differentiable mapping
//! optimizer targeting the DIANA digital+analog-IMC edge SoC.
//!
//! Architecture (see DESIGN.md):
//! * **L1/L2 (build-time python)** — Pallas kernels + JAX supernet,
//!   AOT-lowered to HLO-text artifacts by `make artifacts`.
//! * **L3 (this crate)** — the coordinator: drives the AOT train/eval
//!   executables over PJRT ([`runtime`]), runs the ODiMO pipeline
//!   (pretrain → search → discretize → fine-tune → deploy,
//!   [`coordinator`]), and deploys mappings on the DIANA SoC simulator
//!   ([`hw`]). Python never runs on the request path.
//! * **Serving ([`serve`])** — the online side: a cached per-platform
//!   Pareto frontier of mappings, an SLA-aware dispatcher, a dynamic
//!   batcher with an LRU plan cache, and the `serve-report` dashboard.
//! * **API ([`api`])** — the typed workflow facade: a
//!   [`api::SessionBuilder`] validates (model, platform, threads, seed,
//!   dirs) once and yields a [`api::Session`] that owns the loaded
//!   graph, platform, thread pool, plan cache and cached frontier —
//!   the only supported entry point for
//!   map → simulate → deploy → infer → sweep → serve.

pub mod api;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod hw;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;
pub mod xla;

pub use anyhow::Result;
