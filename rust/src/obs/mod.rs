//! Structured observability: a deterministic span/event layer for the
//! serving stack plus a counters/histograms registry the serve report
//! reads from (docs/ARCHITECTURE.md §Observability).
//!
//! Two clock domains, never mixed:
//!
//! * **Virtual** — simulated cycles on a replica's device timeline.
//!   Every virtual event is a pure function of (model, platform, seed,
//!   opts), so a recorded stream is a replayable artifact like the
//!   JSONL request traces: [`Recorder::virtual_digest`] is invariant
//!   across worker-thread counts and host schedules, and equal across
//!   re-runs of the same configuration.
//! * **Wall** — engine-side nanoseconds (batch execution, per-op
//!   kernel spans). Wall events live on a separate clock domain (their
//!   own Perfetto process) and are *excluded* from the digest, exactly
//!   as the wall-clock fields of `ServeReport` are excluded from its
//!   digest.
//!
//! The [`Recorder`] is lock-light: a disabled recorder ([`ObsLevel::Off`])
//! costs one branch per call site — no lock is taken, no event is
//! built. The bench gate in `tools/check_bench_overhead.py` holds the
//! *enabled* recorder under 2% of the batched serve loop, which bounds
//! the disabled recorder a fortiori. Recording happens only on the
//! single-threaded virtual-time driver, so the interior mutex is
//! uncontended; it exists so `&Recorder` can thread through the stack
//! without infecting every signature with `&mut`.

pub mod export;

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

/// How much the recorder captures.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ObsLevel {
    /// Record nothing (counters in the [`Registry`] still accumulate —
    /// they are owned by `ServeMetrics`, not the recorder).
    Off,
    /// Virtual-domain events only: dispatch decisions, batch
    /// lifecycle, faults, retries, steals, plan-cache traffic. The
    /// exported trace is byte-deterministic at this level.
    Basic,
    /// Basic plus wall-clock engine spans and per-op kernel spans
    /// (engine batches run a traced single plan walk).
    Full,
}

impl ObsLevel {
    /// Parse a CLI `--obs-level` value.
    pub fn parse(s: &str) -> Option<ObsLevel> {
        match s {
            "off" => Some(ObsLevel::Off),
            "basic" => Some(ObsLevel::Basic),
            "full" => Some(ObsLevel::Full),
            _ => None,
        }
    }
}

/// Timestamp domain of one event (module docs: the two domains never
/// mix on one track, and only `Virtual` events are digested).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Clock {
    /// Simulated cycles on the replica's device timeline.
    Virtual(u64),
    /// Nanoseconds since the recorder's epoch (engine side).
    Wall(u64),
    /// Untimed note (mirrored log line); excluded from the digest and
    /// from the exported trace.
    None,
}

/// Why the batcher released a batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlushReason {
    /// The queue reached `max_batch`.
    Full,
    /// The queue's `max_wait` deadline fired.
    Deadline,
    /// The stream ended and the tail drained.
    Drain,
}

/// The typed event taxonomy — one vocabulary for everything the
/// serving stack used to scatter across ad-hoc `log::` calls.
#[derive(Clone, Debug)]
pub enum EventKind {
    /// Dispatch chose a frontier point for a request (instant).
    Dispatch {
        /// Request id.
        req: u64,
        /// Chosen frontier index.
        point: usize,
        /// Chosen frontier label.
        label: String,
        /// Dispatch-time SLA verdict (predicted; the outcome verdict
        /// lives in the serve report).
        sla_met: bool,
        /// Overload-degraded admission.
        degraded: bool,
    },
    /// No dispatchable mapping under the current health mask.
    DispatchDefer {
        /// Request id.
        req: u64,
        /// Frontier points currently enabled.
        enabled: usize,
        /// Frontier points total.
        total: usize,
    },
    /// Admission control shed a request under overload.
    AdmissionShed {
        /// Request id.
        req: u64,
        /// Projected device wait that tripped the controller, cycles.
        wait: u64,
    },
    /// First request queued on an empty per-point queue.
    BatchOpen {
        /// Frontier index of the queue.
        point: usize,
    },
    /// A request joined an already-open per-point queue.
    BatchJoin {
        /// Frontier index of the queue.
        point: usize,
        /// Queue depth after the join.
        pending: usize,
    },
    /// The batcher released a batch.
    BatchFlush {
        /// Frontier index of the batch.
        point: usize,
        /// Batch size.
        size: usize,
        /// What triggered the release.
        reason: FlushReason,
    },
    /// Continuous batching admitted a request into the in-flight
    /// window (cluster only).
    ContinuousJoin {
        /// Request id.
        req: u64,
        /// Window completion cycle after the join.
        done: u64,
    },
    /// One executed batch's device window (emitted at completion; the
    /// span `start..done` renders on the replica's driver track and
    /// expands into per-layer per-unit spans in the export).
    BatchExec {
        /// Model the executed plan belongs to (graph name; multi-model
        /// serve planes run several graphs on one replica timeline).
        model: String,
        /// Frontier index executed.
        point: usize,
        /// Frontier label.
        label: String,
        /// Window start cycle.
        start: u64,
        /// Window end cycle.
        done: u64,
        /// Member count.
        size: usize,
        /// Per-image cycles (derate-stretched when a unit is derated).
        per_img: u64,
        /// Fixed launch overhead inside the window, cycles.
        launch: u64,
        /// Whether a derated unit stretched the window.
        derated: bool,
        /// Simulated per-image energy of the mapping, uJ.
        energy_uj: f64,
        /// `(request id, first arrival cycle)` per member — spans the
        /// partition property in `tests/obs_props.rs` checks.
        members: Vec<(u64, u64)>,
    },
    /// A unit died under an in-flight batch.
    BatchAbort {
        /// Frontier index of the aborted batch.
        point: usize,
        /// Abort cycle.
        at: u64,
    },
    /// A request was re-enqueued for retry.
    Retry {
        /// Request id.
        req: u64,
        /// Attempt count after this re-enqueue.
        attempt: u32,
        /// Cycle the retry is scheduled at.
        retry_at: u64,
    },
    /// A request exhausted its retry budget and failed.
    RetryExhausted {
        /// Request id.
        req: u64,
        /// Attempts consumed.
        attempt: u32,
    },
    /// Work stealing moved requests between replicas.
    Steal {
        /// Victim replica.
        from: u32,
        /// Thief replica.
        to: u32,
        /// Requests moved.
        moved: usize,
    },
    /// The health tracker's enabled-point mask changed.
    FaultTransition {
        /// Frontier points enabled after the transition.
        enabled: usize,
        /// Frontier points total.
        total: usize,
    },
    /// Plan cache served a compiled plan.
    PlanCacheHit {
        /// Plan cache key.
        key: u64,
    },
    /// Plan cache compiled a new plan.
    PlanCacheMiss {
        /// Plan cache key.
        key: u64,
    },
    /// One real engine execution of a batch (wall domain).
    EngineRun {
        /// Frontier index executed.
        point: usize,
        /// Batch size.
        batch: usize,
        /// Worker threads available to the engine.
        threads: usize,
        /// Resolved kernel ISA.
        isa: String,
        /// Engine wall time, ns.
        dur_ns: u64,
    },
    /// One plan-node kernel execution (wall domain, [`ObsLevel::Full`]).
    KernelOp {
        /// Plan node (layer) name.
        node: String,
        /// Op kind tag (`conv`, `fc`, `dw`, ...).
        kind: &'static str,
        /// Conv algorithm, for conv nodes.
        algo: Option<&'static str>,
        /// Kernel wall time, ns.
        dur_ns: u64,
    },
    /// A mapping sweep finished (structured replacement for the old
    /// `log::info!` line; mirrored to the log sink).
    SweepDone {
        /// Model swept.
        model: String,
        /// Platform swept on.
        platform: String,
        /// Candidate mappings scored.
        candidates: usize,
        /// Frontier points kept after Pareto pruning.
        kept: usize,
    },
    /// The frontier cache satisfied a sweep request.
    FrontierCacheHit {
        /// Cache file path.
        path: String,
    },
    /// The frontier cache was stale and a re-sweep ran.
    FrontierCacheStale {
        /// Cache file path.
        path: String,
        /// Why it was stale (schema, knobs, platform spec).
        reason: String,
    },
    /// A fresh frontier cache was written.
    FrontierCacheWritten {
        /// Cache file path.
        path: String,
    },
    /// A report artifact was persisted.
    ReportWritten {
        /// Artifact kind (`serve_report`, `cluster_report`, ...).
        kind: &'static str,
        /// Destination path.
        path: String,
    },
}

impl EventKind {
    /// The human-readable mirror line (what `util/logging.rs` prints).
    pub fn human(&self) -> String {
        match self {
            EventKind::SweepDone { model, platform, candidates, kept } => format!(
                "sweep {model} on {platform}: {candidates} candidates -> {kept} frontier points"
            ),
            EventKind::FrontierCacheHit { path } => format!("frontier cache hit: {path}"),
            EventKind::FrontierCacheStale { path, reason } => {
                format!("frontier cache {path}: {reason}; re-sweeping")
            }
            EventKind::FrontierCacheWritten { path } => {
                format!("frontier cache written: {path}")
            }
            EventKind::ReportWritten { kind, path } => format!("{kind} written to {path}"),
            EventKind::DispatchDefer { req, enabled, total } => format!(
                "serve: request {req} has no dispatchable mapping ({enabled}/{total} points \
                 enabled)"
            ),
            other => format!("{other:?}"),
        }
    }
}

/// One recorded event: which virtual device (replica) it belongs to,
/// its clock domain, and the typed payload.
#[derive(Clone, Debug)]
pub struct Event {
    /// Replica index (0 for the single-session loop).
    pub replica: u32,
    /// Timestamp domain + value.
    pub clock: Clock,
    /// Typed payload.
    pub kind: EventKind,
}

/// The event sink. See the module docs for the clock-domain and
/// determinism contract.
pub struct Recorder {
    level: ObsLevel,
    epoch: Instant,
    buf: Mutex<Vec<Event>>,
}

impl Recorder {
    /// A recorder capturing at `level`.
    pub fn new(level: ObsLevel) -> Self {
        Recorder { level, epoch: Instant::now(), buf: Mutex::new(Vec::new()) }
    }

    /// A disabled recorder (the default everywhere a caller does not
    /// opt in) — every record call is a single branch.
    pub fn disabled() -> Self {
        Self::new(ObsLevel::Off)
    }

    /// The capture level this recorder was built with.
    pub fn level(&self) -> ObsLevel {
        self.level
    }

    /// Whether any events are captured.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.level != ObsLevel::Off
    }

    /// Whether wall-domain engine/kernel spans are captured.
    #[inline]
    pub fn full(&self) -> bool {
        self.level == ObsLevel::Full
    }

    /// Nanoseconds since this recorder's epoch (the wall domain's
    /// time base).
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Drop all recorded events (each serve run starts fresh so an
    /// export reflects exactly one run).
    pub fn reset(&self) {
        if self.enabled() {
            self.lock().clear();
        }
    }

    /// Events recorded so far.
    pub fn len(&self) -> usize {
        if !self.enabled() {
            return 0;
        }
        self.lock().len()
    }

    /// True when nothing was recorded.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Clone out the recorded stream.
    pub fn snapshot(&self) -> Vec<Event> {
        if !self.enabled() {
            return Vec::new();
        }
        self.lock().clone()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Vec<Event>> {
        // a poisoned buffer only loses trace events, never results:
        // recover the guard instead of propagating the panic
        self.buf.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Record a virtual-domain event at `cycles` on `replica`'s
    /// timeline.
    #[inline]
    pub fn virt(&self, replica: u32, cycles: u64, kind: EventKind) {
        if !self.enabled() {
            return;
        }
        self.lock().push(Event { replica, clock: Clock::Virtual(cycles), kind });
    }

    /// Record a wall-domain event at `ns` (from [`Recorder::now_ns`]).
    #[inline]
    pub fn wall(&self, replica: u32, ns: u64, kind: EventKind) {
        if !self.enabled() {
            return;
        }
        self.lock().push(Event { replica, clock: Clock::Wall(ns), kind });
    }

    /// Record an untimed note *and* mirror it to the log sink at
    /// `level` — the structured replacement for ad-hoc `log::` calls.
    /// The mirror always prints (subject to the log filter), recorder
    /// enabled or not, so human-readable behavior is unchanged.
    pub fn note(&self, level: log::Level, kind: EventKind) {
        log::log!(level, "{}", kind.human());
        if self.enabled() {
            self.lock().push(Event { replica: 0, clock: Clock::None, kind });
        }
    }

    /// FNV-1a digest over the virtual-domain event stream (replica,
    /// cycle, canonical payload encoding). Wall and untimed events are
    /// excluded, so the digest is invariant across thread counts and
    /// machine load — and equal across re-runs of one configuration.
    pub fn virtual_digest(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        };
        if !self.enabled() {
            return h;
        }
        for e in self.lock().iter() {
            let Clock::Virtual(t) = e.clock else { continue };
            eat(&e.replica.to_le_bytes());
            eat(&t.to_le_bytes());
            eat(format!("{:?}", e.kind).as_bytes());
        }
        h
    }
}

impl std::fmt::Debug for Recorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Recorder")
            .field("level", &self.level)
            .field("events", &self.len())
            .finish()
    }
}

// ---------------------------------------------------------------------------
// counters / histograms registry
// ---------------------------------------------------------------------------

/// Counter names (`serve.*`) the serve drivers bump and the report
/// reads back. Keeping them `&'static str` keys makes every metric
/// greppable from the report code to the bump site.
pub mod ctr {
    /// Batches executed by the real engine.
    pub const BATCHES: &str = "serve.batches";
    /// Engine wall time across batches, ns (compile time excluded).
    pub const ENGINE_WALL_NS: &str = "serve.engine_wall_ns";
    /// Plan-cache lookups served without compiling (run delta).
    pub const PLAN_HITS: &str = "serve.plan_cache.hits";
    /// Plan-cache lookups that compiled (run delta).
    pub const PLAN_MISSES: &str = "serve.plan_cache.misses";
    /// Wall time spent compiling plans, ns (run delta).
    pub const PLAN_COMPILE_NS: &str = "serve.plan_cache.compile_ns";
    /// Virtual completion cycle of the run (gauge).
    pub const END_CYCLE: &str = "serve.end_cycle";
    /// Fault events in the resolved plan (gauge).
    pub const FAULTS_INJECTED: &str = "serve.faults_injected";
    /// Batches aborted by a mid-flight unit loss.
    pub const BATCH_ABORTS: &str = "serve.batch_aborts";
    /// Request re-enqueues.
    pub const RETRIES: &str = "serve.retries";
    /// Requests shed by admission control.
    pub const SHED: &str = "serve.shed_requests";
    /// Requests that exhausted their retry budget.
    pub const FAILED: &str = "serve.failed_requests";
    /// Shed requests from the interactive (latency-budget) tenant.
    pub const SHED_INTERACTIVE: &str = "serve.shed.interactive";
    /// Shed requests from the batch (min-energy) tenant.
    pub const SHED_BATCH: &str = "serve.shed.batch";
}

/// Histogram names: raw per-request samples the report folds into
/// percentiles/means.
pub mod hist {
    /// Queue + compute latency per served request, cycles.
    pub const LATENCY_CYCLES: &str = "serve.latency_cycles";
    /// Queue wait per served request, cycles.
    pub const QUEUE_CYCLES: &str = "serve.queue_cycles";
    /// Batch compute per served request, cycles.
    pub const COMPUTE_CYCLES: &str = "serve.compute_cycles";
}

/// Counters + histograms, name-keyed. `ServeMetrics` owns one per run
/// and `ServeReport` is assembled from it (plus the per-request
/// outcome list for per-mapping/per-tenant rows).
#[derive(Clone, Debug, Default)]
pub struct Registry {
    counters: BTreeMap<&'static str, u64>,
    hists: BTreeMap<&'static str, Vec<f64>>,
}

impl Registry {
    /// An empty registry.
    pub fn new() -> Self {
        Registry::default()
    }

    /// Add 1 to counter `k`.
    pub fn inc(&mut self, k: &'static str) {
        self.add(k, 1);
    }

    /// Add `v` to counter `k`.
    pub fn add(&mut self, k: &'static str, v: u64) {
        *self.counters.entry(k).or_insert(0) += v;
    }

    /// Set counter `k` to `v` (gauges).
    pub fn set(&mut self, k: &'static str, v: u64) {
        self.counters.insert(k, v);
    }

    /// Current value of counter `k` (0 when never touched).
    pub fn counter(&self, k: &str) -> u64 {
        self.counters.get(k).copied().unwrap_or(0)
    }

    /// Append one sample to histogram `k`.
    pub fn observe(&mut self, k: &'static str, v: f64) {
        self.hists.entry(k).or_default().push(v);
    }

    /// Raw samples of histogram `k` in record order.
    pub fn samples(&self, k: &str) -> &[f64] {
        self.hists.get(k).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Sum of histogram `k`'s samples.
    pub fn sum(&self, k: &str) -> f64 {
        self.samples(k).iter().sum()
    }

    /// Nearest-rank `p`-th percentile of histogram `k` (0 when empty)
    /// — same rank rule the pre-registry report used.
    pub fn percentile(&self, k: &str, p: usize) -> f64 {
        let mut v = self.samples(k).to_vec();
        if v.is_empty() {
            return 0.0;
        }
        v.sort_by(f64::total_cmp);
        let rank = (p * v.len()).div_ceil(100).max(1);
        v[rank - 1]
    }

    /// All counters, name-sorted (dump/debug).
    pub fn counters(&self) -> impl Iterator<Item = (&'static str, u64)> + '_ {
        self.counters.iter().map(|(&k, &v)| (k, v))
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    #[test]
    fn disabled_recorder_records_nothing() {
        let r = Recorder::disabled();
        r.virt(0, 10, EventKind::BatchOpen { point: 0 });
        r.wall(0, 5, EventKind::PlanCacheHit { key: 1 });
        assert!(r.is_empty());
        assert_eq!(r.snapshot().len(), 0);
    }

    #[test]
    fn digest_covers_virtual_events_only() {
        let a = Recorder::new(ObsLevel::Full);
        a.virt(0, 10, EventKind::BatchOpen { point: 0 });
        a.wall(0, 123, EventKind::PlanCacheHit { key: 7 });
        let b = Recorder::new(ObsLevel::Full);
        b.virt(0, 10, EventKind::BatchOpen { point: 0 });
        b.wall(0, 999_999, EventKind::PlanCacheMiss { key: 8 });
        assert_eq!(a.virtual_digest(), b.virtual_digest());
        b.virt(1, 10, EventKind::BatchOpen { point: 0 });
        assert_ne!(a.virtual_digest(), b.virtual_digest());
    }

    #[test]
    fn notes_mirror_without_entering_digest() {
        let r = Recorder::new(ObsLevel::Basic);
        let before = r.virtual_digest();
        r.note(
            log::Level::Info,
            EventKind::ReportWritten { kind: "serve_report", path: "/tmp/x.json".into() },
        );
        assert_eq!(r.len(), 1);
        assert_eq!(r.virtual_digest(), before);
    }

    #[test]
    fn reset_clears_the_stream() {
        let r = Recorder::new(ObsLevel::Basic);
        r.virt(0, 1, EventKind::BatchOpen { point: 2 });
        assert_eq!(r.len(), 1);
        r.reset();
        assert!(r.is_empty());
    }

    #[test]
    fn registry_counters_and_percentiles() {
        let mut g = Registry::new();
        g.inc(ctr::RETRIES);
        g.add(ctr::RETRIES, 2);
        g.set(ctr::END_CYCLE, 99);
        assert_eq!(g.counter(ctr::RETRIES), 3);
        assert_eq!(g.counter(ctr::END_CYCLE), 99);
        assert_eq!(g.counter("never.touched"), 0);
        for v in [30.0, 10.0, 20.0] {
            g.observe(hist::LATENCY_CYCLES, v);
        }
        assert_eq!(g.percentile(hist::LATENCY_CYCLES, 50), 20.0);
        assert_eq!(g.percentile(hist::LATENCY_CYCLES, 95), 30.0);
        assert_eq!(g.percentile("empty", 50), 0.0);
        assert_eq!(g.sum(hist::LATENCY_CYCLES), 60.0);
        // samples keep record order (the report relies on exact sums)
        assert_eq!(g.samples(hist::LATENCY_CYCLES), &[30.0, 10.0, 20.0]);
    }

    #[test]
    fn obs_level_parse() {
        assert_eq!(ObsLevel::parse("off"), Some(ObsLevel::Off));
        assert_eq!(ObsLevel::parse("basic"), Some(ObsLevel::Basic));
        assert_eq!(ObsLevel::parse("full"), Some(ObsLevel::Full));
        assert_eq!(ObsLevel::parse("verbose"), None);
    }

    #[test]
    fn human_lines_for_note_kinds() {
        let k = EventKind::SweepDone {
            model: "tinycnn".into(),
            platform: "diana".into(),
            candidates: 12,
            kept: 5,
        };
        assert_eq!(
            k.human(),
            "sweep tinycnn on diana: 12 candidates -> 5 frontier points"
        );
        let d = EventKind::DispatchDefer { req: 3, enabled: 1, total: 4 };
        assert!(d.human().contains("1/4 points enabled"), "{}", d.human());
    }
}
