//! Chrome-trace-event / Perfetto JSON export of a recorded event
//! stream, plus the `trace-view` text summarizer.
//!
//! Layout (EXPERIMENTS.md §Trace events has the full schema):
//!
//! * one *process* per replica virtual device (`pid = replica + 1`),
//!   holding a `driver` track (batch-execution spans), one track per
//!   accelerator unit (per-layer attribution spans carrying per-image
//!   cycles and per-unit energy), and an `events` track (instants:
//!   dispatch decisions, sheds, retries, faults, plan-cache traffic);
//! * one *process* per replica engine on the wall-clock domain
//!   (`pid = 1000 + replica + 1`, [`ObsLevel::Full`] only), holding
//!   the engine-run spans and the per-op kernel spans.
//!
//! Virtual cycles convert to trace microseconds at the platform clock
//! (`cycles / f_clk_hz * 1e6`), so span widths in the viewer are real
//! simulated time. At [`ObsLevel::Basic`] the export contains only
//! virtual-domain data and is byte-deterministic across runs — pinned
//! by `tests/obs_props.rs`.
//!
//! Per-layer spans come from [`layer_breakdown`]: the executed point's
//! per-layer per-unit cycles/energy, scaled by batch size onto the
//! batch's device window (derated windows stretch the layers
//! proportionally; attribution keeps the healthy-platform energy
//! model). `tools/check_trace_events.py` validates pairing, per-track
//! monotonicity, and required args in CI.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::hw::soc::{layer_breakdown, LayerCost, SocConfig};
use crate::hw::Platform;
use crate::model::Graph;
use crate::serve::FrontierPoint;
use crate::util::json::Json;

use super::{Clock, Event, EventKind, ObsLevel};

#[cfg(doc)]
use super::Recorder;

/// Everything the exporter needs beyond the event stream: the model
/// and platform the run served, and the frontier the dispatch indices
/// refer to.
pub struct TraceCtx<'a> {
    /// The served model graph (layer names for attribution spans).
    pub graph: &'a Graph,
    /// The resolved platform (clock, unit names, energy model).
    pub platform: &'a Platform,
    /// The frontier the run dispatched over (`point` indices).
    pub points: &'a [FrontierPoint],
    /// Simulator config the frontier was costed under.
    pub cfg: SocConfig,
}

const EVENTS_TID_OFFSET: u64 = 1; // events track follows the unit tracks
const WALL_PID_BASE: u64 = 1000;

fn vpid(replica: u32) -> u64 {
    replica as u64 + 1
}

fn wpid(replica: u32) -> u64 {
    WALL_PID_BASE + replica as u64 + 1
}

struct TrackWriter {
    /// (pid, tid) -> events in emission order (already time-sorted by
    /// construction: the recorder's stream is monotone per track).
    tracks: BTreeMap<(u64, u64), Vec<Json>>,
}

impl TrackWriter {
    fn new() -> Self {
        TrackWriter { tracks: BTreeMap::new() }
    }

    fn span(
        &mut self,
        (pid, tid): (u64, u64),
        name: &str,
        cat: &str,
        ts_us: f64,
        end_us: f64,
        args: Vec<(&str, Json)>,
    ) {
        let t = self.tracks.entry((pid, tid)).or_default();
        t.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("cat", Json::str(cat)),
            ("ph", Json::str("B")),
            ("ts", Json::num(ts_us)),
            ("pid", Json::num(pid as f64)),
            ("tid", Json::num(tid as f64)),
            ("args", Json::obj(args)),
        ]));
        t.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("cat", Json::str(cat)),
            ("ph", Json::str("E")),
            ("ts", Json::num(end_us)),
            ("pid", Json::num(pid as f64)),
            ("tid", Json::num(tid as f64)),
        ]));
    }

    fn instant(
        &mut self,
        (pid, tid): (u64, u64),
        name: &str,
        cat: &str,
        ts_us: f64,
        args: Vec<(&str, Json)>,
    ) {
        self.tracks.entry((pid, tid)).or_default().push(Json::obj(vec![
            ("name", Json::str(name)),
            ("cat", Json::str(cat)),
            ("ph", Json::str("i")),
            ("s", Json::str("t")),
            ("ts", Json::num(ts_us)),
            ("pid", Json::num(pid as f64)),
            ("tid", Json::num(tid as f64)),
            ("args", Json::obj(args)),
        ]));
    }
}

fn meta(pid: u64, tid: Option<u64>, name: &str) -> Json {
    let mut fields = vec![
        (
            "name",
            Json::str(if tid.is_some() { "thread_name" } else { "process_name" }),
        ),
        ("ph", Json::str("M")),
        ("pid", Json::num(pid as f64)),
    ];
    if let Some(t) = tid {
        fields.push(("tid", Json::num(t as f64)));
    }
    fields.push(("args", Json::obj(vec![("name", Json::str(name))])));
    Json::obj(fields)
}

/// Render a recorded event stream as a Chrome-trace-event JSON
/// document (object form: `{"traceEvents": [...]}`) — the format
/// Perfetto and `chrome://tracing` load directly.
pub fn trace_events(events: &[Event], ctx: &TraceCtx) -> Json {
    let f_clk = ctx.platform.f_clk_hz;
    let n_acc = ctx.platform.n_acc() as u64;
    let us = |cycles: u64| cycles as f64 / f_clk * 1e6;
    let events_tid = n_acc + EVENTS_TID_OFFSET;
    let mut w = TrackWriter::new();
    // per-point layer breakdowns, computed once on first use
    let mut breakdowns: Vec<Option<Vec<LayerCost>>> = vec![None; ctx.points.len()];
    let mut virtual_replicas: std::collections::BTreeSet<u32> = Default::default();
    let mut wall_replicas: std::collections::BTreeSet<u32> = Default::default();

    for e in events {
        match e.clock {
            Clock::Virtual(_) => virtual_replicas.insert(e.replica),
            Clock::Wall(_) => wall_replicas.insert(e.replica),
            Clock::None => continue, // untimed notes have no track
        };
        match (&e.kind, e.clock) {
            (
                EventKind::BatchExec {
                    model,
                    point,
                    label,
                    start,
                    done,
                    size,
                    per_img,
                    launch,
                    derated,
                    energy_uj,
                    members,
                },
                Clock::Virtual(_),
            ) => {
                let pid = vpid(e.replica);
                let ids: Vec<Json> =
                    members.iter().map(|&(id, _)| Json::num(id as f64)).collect();
                w.span(
                    (pid, 0),
                    label,
                    "batch",
                    us(*start),
                    us(*done),
                    vec![
                        ("model", Json::str(model)),
                        ("point", Json::num(*point as f64)),
                        ("size", Json::num(*size as f64)),
                        ("per_img_cycles", Json::num(*per_img as f64)),
                        ("launch_cycles", Json::num(*launch as f64)),
                        ("derated", Json::str(if *derated { "true" } else { "false" })),
                        ("energy_uj_img", Json::num(*energy_uj)),
                        ("requests", Json::Arr(ids)),
                    ],
                );
                // per-layer / per-unit attribution inside the window
                if *point < ctx.points.len() {
                    let bd = breakdowns[*point].get_or_insert_with(|| {
                        layer_breakdown(
                            ctx.graph,
                            &ctx.points[*point].mapping.channel_split(ctx.platform.n_acc()),
                            ctx.platform,
                            ctx.cfg,
                        )
                    });
                    let model_cycles: u64 = bd.iter().map(|l| l.span).sum();
                    let window = done.saturating_sub(start + launch);
                    if model_cycles > 0 && *size > 0 && window > 0 {
                        // derated windows stretch every layer by the
                        // same factor (scale == 1 on a healthy run)
                        let scale =
                            window as f64 / (model_cycles as f64 * *size as f64);
                        let mut cursor = us(start + launch);
                        for l in bd.iter() {
                            let width =
                                l.span as f64 * *size as f64 * scale / f_clk * 1e6;
                            for (u, (&c, &ej)) in
                                l.unit_cycles.iter().zip(&l.unit_energy_uj).enumerate()
                            {
                                if c == 0 {
                                    continue;
                                }
                                let sub =
                                    c as f64 * *size as f64 * scale / f_clk * 1e6;
                                w.span(
                                    (pid, u as u64 + 1),
                                    &l.name,
                                    "layer",
                                    cursor,
                                    cursor + sub,
                                    vec![
                                        (
                                            "unit",
                                            Json::str(
                                                ctx.platform.accelerators[u].name.clone(),
                                            ),
                                        ),
                                        ("cycles_img", Json::num(c as f64)),
                                        ("energy_uj", Json::num(ej * *size as f64)),
                                        ("point", Json::num(*point as f64)),
                                    ],
                                );
                            }
                            cursor += width;
                        }
                    }
                }
            }
            (
                EventKind::EngineRun { point, batch, threads, isa, dur_ns },
                Clock::Wall(ns),
            ) => {
                let pid = wpid(e.replica);
                w.span(
                    (pid, 0),
                    "engine_run",
                    "engine",
                    ns as f64 / 1e3,
                    (ns + dur_ns) as f64 / 1e3,
                    vec![
                        ("point", Json::num(*point as f64)),
                        ("batch", Json::num(*batch as f64)),
                        ("threads", Json::num(*threads as f64)),
                        ("isa", Json::str(isa.clone())),
                    ],
                );
            }
            (EventKind::KernelOp { node, kind, algo, dur_ns }, Clock::Wall(ns)) => {
                let pid = wpid(e.replica);
                let mut args = vec![("kind", Json::str(*kind))];
                if let Some(a) = algo {
                    args.push(("algo", Json::str(*a)));
                }
                w.span(
                    (pid, 1),
                    node,
                    "kernel",
                    ns as f64 / 1e3,
                    (ns + dur_ns) as f64 / 1e3,
                    args,
                );
            }
            (kind, Clock::Virtual(t)) => {
                // instants on the per-replica events track
                let pid = vpid(e.replica);
                let ts = us(t);
                let (name, args): (&str, Vec<(&str, Json)>) = match kind {
                    EventKind::Dispatch { req, point, label, sla_met, degraded } => (
                        "dispatch",
                        vec![
                            ("req", Json::num(*req as f64)),
                            ("point", Json::num(*point as f64)),
                            ("label", Json::str(label.clone())),
                            ("sla_met", Json::str(if *sla_met { "true" } else { "false" })),
                            (
                                "degraded",
                                Json::str(if *degraded { "true" } else { "false" }),
                            ),
                        ],
                    ),
                    EventKind::DispatchDefer { req, enabled, total } => (
                        "defer",
                        vec![
                            ("req", Json::num(*req as f64)),
                            ("enabled", Json::num(*enabled as f64)),
                            ("total", Json::num(*total as f64)),
                        ],
                    ),
                    EventKind::AdmissionShed { req, wait } => (
                        "shed",
                        vec![
                            ("req", Json::num(*req as f64)),
                            ("wait_cycles", Json::num(*wait as f64)),
                        ],
                    ),
                    EventKind::BatchOpen { point } => {
                        ("batch_open", vec![("point", Json::num(*point as f64))])
                    }
                    EventKind::BatchJoin { point, pending } => (
                        "batch_join",
                        vec![
                            ("point", Json::num(*point as f64)),
                            ("pending", Json::num(*pending as f64)),
                        ],
                    ),
                    EventKind::BatchFlush { point, size, reason } => (
                        "batch_flush",
                        vec![
                            ("point", Json::num(*point as f64)),
                            ("size", Json::num(*size as f64)),
                            ("reason", Json::str(format!("{reason:?}").to_lowercase())),
                        ],
                    ),
                    EventKind::ContinuousJoin { req, done } => (
                        "continuous_join",
                        vec![
                            ("req", Json::num(*req as f64)),
                            ("done_cycle", Json::num(*done as f64)),
                        ],
                    ),
                    EventKind::BatchAbort { point, at } => (
                        "batch_abort",
                        vec![
                            ("point", Json::num(*point as f64)),
                            ("abort_cycle", Json::num(*at as f64)),
                        ],
                    ),
                    EventKind::Retry { req, attempt, retry_at } => (
                        "retry",
                        vec![
                            ("req", Json::num(*req as f64)),
                            ("attempt", Json::num(*attempt as f64)),
                            ("retry_at_cycle", Json::num(*retry_at as f64)),
                        ],
                    ),
                    EventKind::RetryExhausted { req, attempt } => (
                        "retry_exhausted",
                        vec![
                            ("req", Json::num(*req as f64)),
                            ("attempt", Json::num(*attempt as f64)),
                        ],
                    ),
                    EventKind::Steal { from, to, moved } => (
                        "steal",
                        vec![
                            ("from", Json::num(*from as f64)),
                            ("to", Json::num(*to as f64)),
                            ("moved", Json::num(*moved as f64)),
                        ],
                    ),
                    EventKind::FaultTransition { enabled, total } => (
                        "fault_transition",
                        vec![
                            ("enabled", Json::num(*enabled as f64)),
                            ("total", Json::num(*total as f64)),
                        ],
                    ),
                    EventKind::PlanCacheHit { key } => {
                        ("plan_cache_hit", vec![("key", Json::str(format!("{key:016x}")))])
                    }
                    EventKind::PlanCacheMiss { key } => (
                        "plan_cache_miss",
                        vec![("key", Json::str(format!("{key:016x}")))],
                    ),
                    // spans handled above; notes filtered before the match
                    _ => continue,
                };
                w.instant((pid, events_tid), name, "serve", ts, args);
            }
            _ => {}
        }
    }

    let mut out: Vec<Json> = Vec::new();
    for &r in &virtual_replicas {
        let pid = vpid(r);
        out.push(meta(pid, None, &format!("replica {r} (virtual cycles)")));
        out.push(meta(pid, Some(0), "driver"));
        for (u, a) in ctx.platform.accelerators.iter().enumerate() {
            out.push(meta(pid, Some(u as u64 + 1), &a.name));
        }
        out.push(meta(pid, Some(events_tid), "events"));
    }
    for &r in &wall_replicas {
        let pid = wpid(r);
        out.push(meta(pid, None, &format!("replica {r} engine (wall clock)")));
        out.push(meta(pid, Some(0), "engine"));
        out.push(meta(pid, Some(1), "kernels"));
    }
    for (_, track) in w.tracks {
        out.extend(track);
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(out)),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

/// Write the exported trace to `path` (atomic replace; plain Chrome
/// JSON, *not* the versioned store envelope — Perfetto must load the
/// file as-is).
pub fn write_trace_events(path: &Path, events: &[Event], ctx: &TraceCtx) -> Result<()> {
    let doc = trace_events(events, ctx);
    crate::exp::store::write_atomic(path, &format!("{doc}\n"))
}

/// `ObsLevel` implied by a `--trace-events` flag with no explicit
/// `--obs-level`.
pub fn default_trace_level() -> ObsLevel {
    ObsLevel::Basic
}

// ---------------------------------------------------------------------------
// trace-view: text summary of an exported trace
// ---------------------------------------------------------------------------

struct SpanRow {
    name: String,
    cat: String,
    track: String,
    ts: f64,
    dur: f64,
}

/// Per-track stack of open B events: (name, cat, ts).
type OpenStack = BTreeMap<(u64, u64), Vec<(String, String, f64)>>;

/// Summarize an exported trace: top-N slowest spans, plan-cache hit
/// rate, per-unit busy/energy split, and instant-event counts — the
/// CLI `trace-view` verb.
pub fn summarize(text: &str, top: usize) -> Result<String> {
    let doc = crate::util::json::parse(text).map_err(|e| anyhow!("trace parse: {e}"))?;
    let events = doc
        .req("traceEvents")?
        .as_arr()
        .ok_or_else(|| anyhow!("traceEvents must be an array"))?;

    let mut proc_names: BTreeMap<u64, String> = BTreeMap::new();
    let mut thread_names: BTreeMap<(u64, u64), String> = BTreeMap::new();
    let mut spans: Vec<SpanRow> = Vec::new();
    let mut counts: BTreeMap<String, usize> = BTreeMap::new();
    let mut unit_busy: BTreeMap<String, f64> = BTreeMap::new();
    let mut unit_energy: BTreeMap<String, f64> = BTreeMap::new();
    let mut open: OpenStack = BTreeMap::new();

    let field_u64 = |ev: &Json, k: &str| -> u64 {
        ev.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0) as u64
    };
    for ev in events {
        let ph = ev.get("ph").and_then(|v| v.as_str()).unwrap_or("");
        let pid = field_u64(ev, "pid");
        let tid = field_u64(ev, "tid");
        let name = ev.get("name").and_then(|v| v.as_str()).unwrap_or("").to_string();
        match ph {
            "M" => {
                let label = ev
                    .get("args")
                    .and_then(|a| a.get("name"))
                    .and_then(|v| v.as_str())
                    .unwrap_or("")
                    .to_string();
                if name == "process_name" {
                    proc_names.insert(pid, label);
                } else if name == "thread_name" {
                    thread_names.insert((pid, tid), label);
                }
            }
            "B" => {
                let cat = ev.get("cat").and_then(|v| v.as_str()).unwrap_or("").to_string();
                let ts = ev.get("ts").and_then(|v| v.as_f64()).unwrap_or(0.0);
                open.entry((pid, tid)).or_default().push((name, cat, ts));
            }
            "E" => {
                let ts = ev.get("ts").and_then(|v| v.as_f64()).unwrap_or(0.0);
                if let Some((name, cat, b_ts)) =
                    open.get_mut(&(pid, tid)).and_then(Vec::pop)
                {
                    let track = format!(
                        "{}/{}",
                        proc_names.get(&pid).cloned().unwrap_or_else(|| pid.to_string()),
                        thread_names
                            .get(&(pid, tid))
                            .cloned()
                            .unwrap_or_else(|| tid.to_string())
                    );
                    let dur = ts - b_ts;
                    if cat == "layer" {
                        let unit = thread_names
                            .get(&(pid, tid))
                            .cloned()
                            .unwrap_or_else(|| tid.to_string());
                        *unit_busy.entry(unit).or_insert(0.0) += dur;
                    }
                    spans.push(SpanRow { name, cat, track, ts: b_ts, dur });
                }
            }
            "i" => {
                *counts.entry(name).or_insert(0) += 1;
            }
            _ => {}
        }
    }
    // energy args live on the B event of layer spans; second pass
    for ev in events {
        if ev.get("ph").and_then(|v| v.as_str()) != Some("B")
            || ev.get("cat").and_then(|v| v.as_str()) != Some("layer")
        {
            continue;
        }
        if let Some(args) = ev.get("args") {
            let unit = args
                .get("unit")
                .and_then(|v| v.as_str())
                .unwrap_or("?")
                .to_string();
            let e = args.get("energy_uj").and_then(|v| v.as_f64()).unwrap_or(0.0);
            *unit_energy.entry(unit).or_insert(0.0) += e;
        }
    }

    let mut out = String::new();
    let _ = writeln!(
        out,
        "trace summary: {} events | {} spans | {} tracks",
        events.len(),
        spans.len(),
        spans.iter().map(|s| s.track.clone()).collect::<std::collections::BTreeSet<_>>().len()
    );

    let hits = counts.get("plan_cache_hit").copied().unwrap_or(0);
    let misses = counts.get("plan_cache_miss").copied().unwrap_or(0);
    if hits + misses > 0 {
        let _ = writeln!(
            out,
            "plan cache: {hits} hits / {misses} misses ({:.1}% hit rate)",
            100.0 * hits as f64 / (hits + misses) as f64
        );
    }

    let mut slow: Vec<&SpanRow> = spans.iter().filter(|s| s.cat != "layer").collect();
    slow.sort_by(|a, b| b.dur.total_cmp(&a.dur).then(a.ts.total_cmp(&b.ts)));
    let _ = writeln!(out, "\nslowest {} spans:", top.min(slow.len()));
    let _ = writeln!(out, "{:<24} {:>12} {:>12}  track", "name", "ts [ms]", "dur [ms]");
    for s in slow.iter().take(top) {
        let _ = writeln!(
            out,
            "{:<24} {:>12.4} {:>12.4}  {}",
            s.name,
            s.ts / 1e3,
            s.dur / 1e3,
            s.track
        );
    }

    if !unit_busy.is_empty() {
        let total_busy: f64 = unit_busy.values().sum();
        let total_energy: f64 = unit_energy.values().sum();
        let _ = writeln!(out, "\nper-unit busy / energy split:");
        let _ = writeln!(
            out,
            "{:<10} {:>12} {:>7} {:>14} {:>7}",
            "unit", "busy [ms]", "%", "energy [uJ]", "%"
        );
        for (unit, &busy) in &unit_busy {
            let e = unit_energy.get(unit).copied().unwrap_or(0.0);
            let _ = writeln!(
                out,
                "{:<10} {:>12.4} {:>6.1}% {:>14.3} {:>6.1}%",
                unit,
                busy / 1e3,
                if total_busy > 0.0 { 100.0 * busy / total_busy } else { 0.0 },
                e,
                if total_energy > 0.0 { 100.0 * e / total_energy } else { 0.0 },
            );
        }
    }

    if !counts.is_empty() {
        let _ = writeln!(out, "\nevents:");
        for (name, n) in &counts {
            let _ = writeln!(out, "{n:>8}  {name}");
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::coordinator::Mapping;
    use crate::model::tinycnn;

    fn ctx_points(graph: &Graph, platform: &Platform) -> Vec<FrontierPoint> {
        let mapping = Mapping::uniform(graph, 0);
        let rep = crate::hw::soc::simulate(
            graph,
            &mapping.channel_split(platform.n_acc()),
            platform,
            SocConfig::default(),
        );
        vec![FrontierPoint {
            label: "all_dig".into(),
            mapping,
            cycles: rep.total_cycles,
            latency_ms: rep.latency_ms,
            energy_uj: rep.energy_uj,
            acc_proxy: 1.0,
        }]
    }

    fn batch_event(graph: &Graph, platform: &Platform, points: &[FrontierPoint]) -> Event {
        let _ = platform;
        let cycles = points[0].cycles;
        Event {
            replica: 0,
            clock: Clock::Virtual(100),
            kind: EventKind::BatchExec {
                model: graph.name.clone(),
                point: 0,
                label: "all_dig".into(),
                start: 100,
                done: 100 + 10_000 + 2 * cycles,
                size: 2,
                per_img: cycles,
                launch: 10_000,
                derated: false,
                energy_uj: points[0].energy_uj,
                members: vec![(0, 50), (1, 80)],
            },
        }
    }

    #[test]
    fn export_contains_tracks_spans_and_energy_args() {
        let g = tinycnn();
        let p = Platform::diana();
        let points = ctx_points(&g, &p);
        let events = vec![
            Event {
                replica: 0,
                clock: Clock::Virtual(50),
                kind: EventKind::Dispatch {
                    req: 0,
                    point: 0,
                    label: "all_dig".into(),
                    sla_met: true,
                    degraded: false,
                },
            },
            batch_event(&g, &p, &points),
        ];
        let ctx = TraceCtx { graph: &g, platform: &p, points: &points, cfg: SocConfig::default() };
        let doc = trace_events(&events, &ctx);
        let text = format!("{doc}");
        assert!(text.contains("\"traceEvents\""));
        assert!(text.contains("process_name"), "process metadata present");
        assert!(text.contains("\"dig\""), "unit track named");
        assert!(text.contains("energy_uj"), "per-layer energy args present");
        assert!(text.contains("\"ph\":\"B\"") && text.contains("\"ph\":\"E\""));
        // every B has a matching E
        assert_eq!(text.matches("\"ph\":\"B\"").count(), text.matches("\"ph\":\"E\"").count());
    }

    #[test]
    fn export_is_deterministic() {
        let g = tinycnn();
        let p = Platform::diana();
        let points = ctx_points(&g, &p);
        let events = vec![batch_event(&g, &p, &points)];
        let ctx = TraceCtx { graph: &g, platform: &p, points: &points, cfg: SocConfig::default() };
        let a = format!("{}", trace_events(&events, &ctx));
        let b = format!("{}", trace_events(&events, &ctx));
        assert_eq!(a, b);
    }

    #[test]
    fn summarize_reports_units_and_counts() {
        let g = tinycnn();
        let p = Platform::diana();
        let points = ctx_points(&g, &p);
        let events = vec![
            Event {
                replica: 0,
                clock: Clock::Virtual(10),
                kind: EventKind::PlanCacheMiss { key: 42 },
            },
            Event {
                replica: 0,
                clock: Clock::Virtual(20),
                kind: EventKind::PlanCacheHit { key: 42 },
            },
            batch_event(&g, &p, &points),
        ];
        let ctx = TraceCtx { graph: &g, platform: &p, points: &points, cfg: SocConfig::default() };
        let text = format!("{}", trace_events(&events, &ctx));
        let summary = summarize(&text, 5).unwrap();
        assert!(summary.contains("plan cache: 1 hits / 1 misses"), "{summary}");
        assert!(summary.contains("slowest"), "{summary}");
        assert!(summary.contains("dig"), "{summary}");
        assert!(summary.contains("per-unit busy / energy split"), "{summary}");
    }

    #[test]
    fn wall_events_land_on_their_own_process() {
        let g = tinycnn();
        let p = Platform::diana();
        let points = ctx_points(&g, &p);
        let events = vec![Event {
            replica: 0,
            clock: Clock::Wall(1_000),
            kind: EventKind::EngineRun {
                point: 0,
                batch: 4,
                threads: 2,
                isa: "neon".into(),
                dur_ns: 50_000,
            },
        }];
        let ctx = TraceCtx { graph: &g, platform: &p, points: &points, cfg: SocConfig::default() };
        let text = format!("{}", trace_events(&events, &ctx));
        assert!(text.contains("engine (wall clock)"), "{text}");
        assert!(text.contains(&format!("\"pid\":{}", WALL_PID_BASE + 1)), "{text}");
    }
}
