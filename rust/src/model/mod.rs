//! DNN graph IR — the rust mirror of `python/compile/models.py`.
//!
//! Two construction paths that must agree (pinned by
//! `rust/tests/model_parity.rs`):
//!   * native builders ([`tinycnn`], [`resnet20`], [`resnet18s`],
//!     [`mbv1_025`]) — used by the simulator, baselines and benches
//!     without touching artifacts;
//!   * [`Graph::from_meta`] — parsed from the `<model>_meta.json`
//!     artifact, the source of truth for anything driving the AOT
//!     executables.

use std::collections::BTreeMap;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

pub mod import;

/// Accelerator indices of the built-in DIANA platform (the artifact /
/// AOT-graph contract: row 0 = digital int8, row 1 = ternary AIMC).
/// Platform-generic code queries `hw::Platform` instead — accelerator
/// counts, precisions, and cost models all live there now.
pub const DIG: usize = 0;
pub const AIMC: usize = 1;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Input,
    Conv,
    DwConv,
    Add,
    Gap,
    Fc,
}

impl Op {
    pub fn parse(s: &str) -> Result<Op> {
        Ok(match s {
            "input" => Op::Input,
            "conv" => Op::Conv,
            "dwconv" => Op::DwConv,
            "add" => Op::Add,
            "gap" => Op::Gap,
            "fc" => Op::Fc,
            _ => return Err(anyhow!("unknown op '{s}'")),
        })
    }
}

#[derive(Clone, Debug)]
pub struct NodeDef {
    pub name: String,
    pub op: Op,
    pub inputs: Vec<String>,
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    pub relu: bool,
    pub in_hw: (usize, usize),
    pub out_hw: (usize, usize),
}

impl NodeDef {
    pub fn mappable(&self) -> bool {
        matches!(self.op, Op::Conv | Op::Fc)
    }

    /// MAC count (python `ModelDef.macs` mirror).
    pub fn macs(&self) -> u64 {
        match self.op {
            Op::Conv => {
                (self.cin * self.k * self.k * self.cout * self.out_hw.0 * self.out_hw.1)
                    as u64
            }
            Op::DwConv => (self.cout * self.k * self.k * self.out_hw.0 * self.out_hw.1) as u64,
            Op::Fc => (self.cin * self.cout) as u64,
            _ => 0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Graph {
    pub name: String,
    pub input_shape: (usize, usize, usize), // (C, H, W)
    pub classes: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub nodes: Vec<NodeDef>,
    // name -> position in `nodes`, built once at construction so
    // `node()` is a map lookup, not a linear scan (hot in sweep
    // scoring and plan compilation for deep imported graphs).
    index: BTreeMap<String, usize>,
    // structural digest, cached at construction (see `spec_hash`)
    spec: u64,
}

impl Graph {
    /// The only constructor: derives the name→index map and the
    /// structural digest once, so lookups and cache keys never pay per
    /// call. Callers that mutate `nodes` afterwards (tests) must
    /// rebuild through `new` to keep both coherent.
    pub fn new(
        name: String,
        input_shape: (usize, usize, usize),
        classes: usize,
        train_batch: usize,
        eval_batch: usize,
        nodes: Vec<NodeDef>,
    ) -> Graph {
        let index =
            nodes.iter().enumerate().map(|(i, n)| (n.name.clone(), i)).collect();
        let spec = import::spec_hash_of(
            &name, input_shape, classes, train_batch, eval_batch, &nodes,
        );
        Graph { name, input_shape, classes, train_batch, eval_batch, nodes, index, spec }
    }

    pub fn node(&self, name: &str) -> Option<&NodeDef> {
        self.index.get(name).map(|&i| &self.nodes[i])
    }

    /// FNV-1a digest of the graph's structure (ops, shapes, edges) —
    /// the model-side analog of [`crate::hw::Platform::spec_hash`].
    /// Folded into the frontier-cache payload and the plan-cache key,
    /// so an edited graph file re-sweeps and re-compiles instead of
    /// silently reusing artifacts saved under the same model name.
    pub fn spec_hash(&self) -> u64 {
        self.spec
    }

    /// Mappable (conv/fc) nodes in topological (definition) order.
    pub fn mappable(&self) -> Vec<&NodeDef> {
        self.nodes.iter().filter(|n| n.mappable()).collect()
    }

    /// Mappable node names in *sorted* order — the flat order of the
    /// `assign:` inputs in the AOT graphs (python `assign_names`).
    pub fn mappable_sorted(&self) -> Vec<&NodeDef> {
        let mut v = self.mappable();
        v.sort_by(|a, b| a.name.cmp(&b.name));
        v
    }

    pub fn total_macs(&self) -> u64 {
        self.nodes.iter().map(|n| n.macs()).sum()
    }

    /// The (unique) consumer nodes of `name`'s activation output.
    pub fn consumers(&self, name: &str) -> Vec<&NodeDef> {
        self.nodes
            .iter()
            .filter(|n| n.inputs.iter().any(|i| i == name))
            .collect()
    }

    // ---- construction from artifact metadata --------------------------

    pub fn from_meta(meta: &Json) -> Result<Graph> {
        let m = meta.req("model")?;
        let ishape = m.req("input_shape")?.usize_vec()?;
        let nodes = m
            .req("nodes")?
            .as_arr()
            .ok_or_else(|| anyhow!("nodes not array"))?
            .iter()
            .map(|n| -> Result<NodeDef> {
                let in_hw = n.req("in_hw")?.usize_vec()?;
                let out_hw = n.req("out_hw")?.usize_vec()?;
                Ok(NodeDef {
                    name: n.req("name")?.as_str().unwrap_or("").to_string(),
                    op: Op::parse(n.req("op")?.as_str().unwrap_or(""))?,
                    inputs: n
                        .req("inputs")?
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|v| v.as_str().map(String::from))
                        .collect(),
                    cin: n.req("cin")?.as_usize().unwrap_or(0),
                    cout: n.req("cout")?.as_usize().unwrap_or(0),
                    k: n.req("k")?.as_usize().unwrap_or(1),
                    stride: n.req("stride")?.as_usize().unwrap_or(1),
                    pad: n.req("pad")?.as_usize().unwrap_or(0),
                    relu: n.req("relu")?.as_bool().unwrap_or(true),
                    in_hw: (in_hw[0], in_hw[1]),
                    out_hw: (out_hw[0], out_hw[1]),
                })
            })
            .collect::<Result<Vec<_>>>()
            .context("parsing node table")?;
        Ok(Graph::new(
            m.req("name")?.as_str().unwrap_or("").to_string(),
            (ishape[0], ishape[1], ishape[2]),
            m.req("classes")?.as_usize().unwrap_or(0),
            m.req("train_batch")?.as_usize().unwrap_or(32),
            m.req("eval_batch")?.as_usize().unwrap_or(128),
            nodes,
        ))
    }
}

// ---------------------------------------------------------------------------
// native builders (python models.py mirror)
// ---------------------------------------------------------------------------

struct Builder {
    nodes: Vec<NodeDef>,
    shapes: Vec<(String, (usize, usize, usize))>, // name -> (C, H, W)
    classes: usize,
}

impl Builder {
    fn new(input: (usize, usize, usize), classes: usize) -> Self {
        let mut b = Builder { nodes: Vec::new(), shapes: Vec::new(), classes };
        b.nodes.push(NodeDef {
            name: "in".into(),
            op: Op::Input,
            inputs: vec![],
            cin: 0,
            cout: input.0,
            k: 1,
            stride: 1,
            pad: 0,
            relu: true,
            in_hw: (input.1, input.2),
            out_hw: (input.1, input.2),
        });
        b.shapes.push(("in".into(), input));
        b
    }

    fn shape_of(&self, name: &str) -> (usize, usize, usize) {
        self.shapes
            .iter()
            .find(|(n, _)| n == name)
            .unwrap_or_else(|| panic!("unknown node '{name}'"))
            .1
    }

    #[allow(clippy::too_many_arguments)]
    fn conv(&mut self, name: &str, input: &str, cout: usize, k: usize,
            stride: usize, pad: usize, relu: bool) {
        let (c, h, w) = self.shape_of(input);
        let oh = (h + 2 * pad - k) / stride + 1;
        let ow = (w + 2 * pad - k) / stride + 1;
        self.nodes.push(NodeDef {
            name: name.into(),
            op: Op::Conv,
            inputs: vec![input.into()],
            cin: c,
            cout,
            k,
            stride,
            pad,
            relu,
            in_hw: (h, w),
            out_hw: (oh, ow),
        });
        self.shapes.push((name.into(), (cout, oh, ow)));
    }

    fn dwconv(&mut self, name: &str, input: &str, k: usize, stride: usize, pad: usize) {
        let (c, h, w) = self.shape_of(input);
        let oh = (h + 2 * pad - k) / stride + 1;
        let ow = (w + 2 * pad - k) / stride + 1;
        self.nodes.push(NodeDef {
            name: name.into(),
            op: Op::DwConv,
            inputs: vec![input.into()],
            cin: c,
            cout: c,
            k,
            stride,
            pad,
            relu: true,
            in_hw: (h, w),
            out_hw: (oh, ow),
        });
        self.shapes.push((name.into(), (c, oh, ow)));
    }

    fn add(&mut self, name: &str, a: &str, b: &str) {
        let sa = self.shape_of(a);
        assert_eq!(sa, self.shape_of(b), "add shape mismatch at {name}");
        self.nodes.push(NodeDef {
            name: name.into(),
            op: Op::Add,
            inputs: vec![a.into(), b.into()],
            cin: sa.0,
            cout: sa.0,
            k: 1,
            stride: 1,
            pad: 0,
            relu: true,
            in_hw: (sa.1, sa.2),
            out_hw: (sa.1, sa.2),
        });
        self.shapes.push((name.into(), sa));
    }

    fn gap(&mut self, name: &str, input: &str) {
        let (c, h, w) = self.shape_of(input);
        self.nodes.push(NodeDef {
            name: name.into(),
            op: Op::Gap,
            inputs: vec![input.into()],
            cin: c,
            cout: c,
            k: 1,
            stride: 1,
            pad: 0,
            relu: true,
            in_hw: (h, w),
            out_hw: (1, 1),
        });
        self.shapes.push((name.into(), (c, 1, 1)));
    }

    fn fc(&mut self, name: &str, input: &str) {
        let (c, _, _) = self.shape_of(input);
        self.nodes.push(NodeDef {
            name: name.into(),
            op: Op::Fc,
            inputs: vec![input.into()],
            cin: c,
            cout: self.classes,
            k: 1,
            stride: 1,
            pad: 0,
            relu: true,
            in_hw: (1, 1),
            out_hw: (1, 1),
        });
        self.shapes.push((name.into(), (self.classes, 1, 1)));
    }

    /// ResNet basic block (python `_basic_block` mirror).
    fn basic_block(&mut self, idx: usize, x: &str, cin: usize, cout: usize,
                   stride: usize) -> String {
        let c1 = format!("b{idx}_conv1");
        let c2 = format!("b{idx}_conv2");
        self.conv(&c1, x, cout, 3, stride, 1, true);
        self.conv(&c2, &c1, cout, 3, 1, 1, false);
        let skip = if stride != 1 || cin != cout {
            let sk = format!("b{idx}_down");
            self.conv(&sk, x, cout, 1, stride, 0, false);
            sk
        } else {
            x.to_string()
        };
        let out = format!("b{idx}_add");
        self.add(&out, &c2, &skip);
        out
    }

    fn finish(self, name: &str, input: (usize, usize, usize), train_batch: usize,
              eval_batch: usize) -> Graph {
        Graph::new(name.into(), input, self.classes, train_batch, eval_batch, self.nodes)
    }
}

/// 3-conv test model (python `tinycnn` mirror).
pub fn tinycnn() -> Graph {
    let input = (3, 16, 16);
    let mut b = Builder::new(input, 10);
    b.conv("stem", "in", 8, 3, 1, 1, true);
    b.conv("c1", "stem", 16, 3, 2, 1, true);
    b.conv("c2", "c1", 16, 3, 1, 1, false);
    b.add("res", "c2", "c1");
    b.gap("gap", "res");
    b.fc("fc", "gap");
    b.finish("tinycnn", input, 32, 128)
}

/// ResNet20 / CIFAR-10 (the paper's reference model).
pub fn resnet20() -> Graph {
    let input = (3, 32, 32);
    let mut b = Builder::new(input, 10);
    b.conv("stem", "in", 16, 3, 1, 1, true);
    let mut x = "stem".to_string();
    let mut cin = 16;
    let mut idx = 0;
    for (stage, cout) in [16usize, 32, 64].into_iter().enumerate() {
        for blk in 0..3 {
            let stride = if stage > 0 && blk == 0 { 2 } else { 1 };
            x = b.basic_block(idx, &x, cin, cout, stride);
            cin = cout;
            idx += 1;
        }
    }
    b.gap("gap", &x);
    b.fc("fc", "gap");
    b.finish("resnet20", input, 64, 256)
}

/// Width-0.25x ResNet18 on 64x64 (TinyImageNet substitution).
pub fn resnet18s() -> Graph {
    let input = (3, 64, 64);
    let mut b = Builder::new(input, 24);
    b.conv("stem", "in", 16, 3, 1, 1, true);
    let mut x = "stem".to_string();
    let mut cin = 16;
    let mut idx = 0;
    for (stage, cout) in [16usize, 32, 64, 128].into_iter().enumerate() {
        for blk in 0..2 {
            let stride = if stage > 0 && blk == 0 { 2 } else { 1 };
            x = b.basic_block(idx, &x, cin, cout, stride);
            cin = cout;
            idx += 1;
        }
    }
    b.gap("gap", &x);
    b.fc("fc", "gap");
    b.finish("resnet18s", input, 32, 128)
}

/// MobileNetV1 0.25x on 96x96 (VWW).
pub fn mbv1_025() -> Graph {
    fn ch(c: usize) -> usize {
        ((c as f64 * 0.25) as usize).max(8)
    }
    let input = (3, 96, 96);
    let mut b = Builder::new(input, 2);
    b.conv("stem", "in", ch(32), 3, 2, 1, true);
    let cfg: [(usize, usize); 13] = [
        (64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2),
        (512, 1), (512, 1), (512, 1), (512, 1), (512, 1), (1024, 2),
        (1024, 1),
    ];
    let mut x = "stem".to_string();
    for (i, (cout, stride)) in cfg.into_iter().enumerate() {
        let dw = format!("dw{i}");
        let pw = format!("pw{i}");
        b.dwconv(&dw, &x, 3, stride, 1);
        b.conv(&pw, &dw, ch(cout), 1, 1, 0, true);
        x = pw;
    }
    b.gap("gap", &x);
    b.fc("fc", "gap");
    b.finish("mbv1_025", input, 32, 128)
}

/// Builder registry (CLI `--model`).
pub fn build(name: &str) -> Result<Graph> {
    Ok(match name {
        "tinycnn" => tinycnn(),
        "resnet20" => resnet20(),
        "resnet18s" => resnet18s(),
        "mbv1_025" => mbv1_025(),
        _ => return Err(anyhow!("unknown model '{name}'")),
    })
}

pub const ALL_MODELS: [&str; 4] = ["tinycnn", "resnet20", "resnet18s", "mbv1_025"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet20_structure() {
        let g = resnet20();
        let convs = g.nodes.iter().filter(|n| n.op == Op::Conv).count();
        assert_eq!(convs, 21); // stem + 18 block + 2 downsample
        assert_eq!(g.mappable().len(), 22); // + fc
        assert_eq!(g.node("fc").unwrap().cin, 64);
    }

    #[test]
    fn tinycnn_shapes() {
        let g = tinycnn();
        let c1 = g.node("c1").unwrap();
        assert_eq!(c1.out_hw, (8, 8));
        assert_eq!(g.node("res").unwrap().cout, 16);
    }

    #[test]
    fn mbv1_structure() {
        let g = mbv1_025();
        assert_eq!(g.nodes.iter().filter(|n| n.op == Op::DwConv).count(), 13);
        assert_eq!(g.node("pw12").unwrap().cout, 256);
        assert_eq!(g.node("stem").unwrap().cout, 8);
        // dwconvs are not mappable
        assert!(g.mappable().iter().all(|n| n.op != Op::DwConv));
    }

    #[test]
    fn resnet18s_stage_dims() {
        let g = resnet18s();
        assert_eq!(g.node("b7_add").unwrap().cout, 128);
        assert_eq!(g.node("b7_add").unwrap().out_hw, (8, 8));
    }

    #[test]
    fn macs_positive_and_consistent() {
        for name in ALL_MODELS {
            let g = build(name).unwrap();
            assert!(g.total_macs() > 0);
            for n in g.mappable() {
                assert!(n.macs() > 0, "{}/{}", name, n.name);
            }
        }
    }

    #[test]
    fn consumers_found() {
        let g = tinycnn();
        let cons = g.consumers("c1");
        // c1 feeds c2 and the residual add
        let names: Vec<_> = cons.iter().map(|n| n.name.as_str()).collect();
        assert!(names.contains(&"c2") && names.contains(&"res"));
    }

    #[test]
    fn mappable_sorted_is_sorted() {
        let g = resnet20();
        let names: Vec<_> = g.mappable_sorted().iter().map(|n| n.name.clone()).collect();
        let mut s = names.clone();
        s.sort();
        assert_eq!(names, s);
    }
}
