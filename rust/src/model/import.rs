//! Graph import/export — the versioned `odimo_graph` JSON schema.
//!
//! A graph file is a [`crate::exp::store`] envelope (`kind:
//! "odimo_graph"`, schema v1) whose payload mirrors [`Graph`] field
//! for field. [`Graph::to_json`] emits the canonical document (object
//! keys sorted by the emitter, nodes in definition order) and
//! [`Graph::from_json_file`] parses it back through full structural
//! validation, so the four built-ins round-trip byte-for-byte and a
//! hand-written file that violates the IR's invariants fails with a
//! typed, field-level [`ImportError`] instead of crashing the sweep or
//! the engine downstream.
//!
//! Validation re-runs the same shape inference the native builders use
//! (`oh = (h + 2*pad - k)/stride + 1`) and checks every declared
//! `cin`/`cout`/`in_hw`/`out_hw` against it; node references must be
//! backward (definition order is topological order), so a forward
//! reference is diagnosed as either [`ImportError::Cycle`] (the
//! referenced node depends back on the referencing one) or
//! [`ImportError::NotTopological`] (a legal DAG written in the wrong
//! order).
//!
//! [`Graph::spec_hash`] is the model-side analog of
//! [`crate::hw::Platform::spec_hash`]: an FNV-1a digest over the
//! graph's ops, shapes and edges, computed once at construction.
//! The frontier cache and the plan cache fold it into their keys, so
//! an edited graph file re-sweeps/re-compiles instead of silently
//! reusing stale artifacts saved under the same model name.

use std::fmt;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::exp::store;
use crate::util::json::Json;

use super::{Graph, NodeDef, Op};

/// Envelope `kind` tag of a graph JSON file.
pub const GRAPH_KIND: &str = "odimo_graph";
/// Graph JSON schema version.
pub const GRAPH_SCHEMA: u32 = 1;

/// One structural-validation failure, carrying the node and field it
/// fired on so a hand-edited graph file is fixable from the message
/// alone.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ImportError {
    /// The node table is empty.
    Empty,
    /// Node 0 must be the single `input` node.
    FirstNotInput {
        /// Name of the offending first node.
        node: String,
    },
    /// An `input` op appeared past position 0 (exactly one is allowed).
    ExtraInput {
        /// Name of the extra input node.
        node: String,
    },
    /// Two nodes share a name.
    DuplicateName {
        /// The repeated name.
        node: String,
    },
    /// A node references an input name that no node defines.
    DanglingInput {
        /// Referencing node.
        node: String,
        /// The undefined input name.
        input: String,
    },
    /// A node (transitively) feeds itself.
    Cycle {
        /// Node on the cycle where detection fired.
        node: String,
        /// The forward edge that closes the cycle.
        input: String,
    },
    /// A forward reference in an acyclic graph: the node table is not
    /// in topological order (definition order is the schedule).
    NotTopological {
        /// Referencing node.
        node: String,
        /// The input defined later in the table.
        input: String,
    },
    /// A declared field disagrees with the value shape inference
    /// derives from the node's producers.
    ShapeMismatch {
        /// Offending node.
        node: String,
        /// Field that disagrees (`cin`, `cout`, `in_hw`, `out_hw`).
        field: &'static str,
        /// Value inference expects.
        expected: String,
        /// Value the file declares.
        got: String,
    },
    /// A field violates the op's structural contract (arity, zero
    /// stride, kernel larger than the padded input, ...).
    BadField {
        /// Offending node (empty for graph-level fields).
        node: String,
        /// Offending field.
        field: &'static str,
        /// What is wrong with it.
        msg: String,
    },
}

impl fmt::Display for ImportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ImportError::Empty => write!(f, "graph has no nodes"),
            ImportError::FirstNotInput { node } => {
                write!(f, "node '{node}': the first node must be the 'input' op")
            }
            ImportError::ExtraInput { node } => {
                write!(f, "node '{node}': exactly one 'input' node is allowed (at position 0)")
            }
            ImportError::DuplicateName { node } => {
                write!(f, "node '{node}': duplicate node name")
            }
            ImportError::DanglingInput { node, input } => {
                write!(f, "node '{node}': input '{input}' is not defined by any node")
            }
            ImportError::Cycle { node, input } => {
                write!(f, "node '{node}': input '{input}' closes a cycle back to '{node}'")
            }
            ImportError::NotTopological { node, input } => write!(
                f,
                "node '{node}': input '{input}' is defined later in the table — the node \
                 list must be in topological order"
            ),
            ImportError::ShapeMismatch { node, field, expected, got } => write!(
                f,
                "node '{node}': field '{field}' declares {got} but shape inference \
                 expects {expected}"
            ),
            ImportError::BadField { node, field, msg } => {
                if node.is_empty() {
                    write!(f, "graph field '{field}': {msg}")
                } else {
                    write!(f, "node '{node}': field '{field}': {msg}")
                }
            }
        }
    }
}

impl std::error::Error for ImportError {}

fn op_tag(op: Op) -> &'static str {
    match op {
        Op::Input => "input",
        Op::Conv => "conv",
        Op::DwConv => "dwconv",
        Op::Add => "add",
        Op::Gap => "gap",
        Op::Fc => "fc",
    }
}

fn op_code(op: Op) -> u8 {
    match op {
        Op::Input => 0,
        Op::Conv => 1,
        Op::DwConv => 2,
        Op::Add => 3,
        Op::Gap => 4,
        Op::Fc => 5,
    }
}

/// FNV-1a over everything that identifies the graph's structure:
/// name, input shape, class count, batch sizes, and every node's op,
/// edges and declared geometry. Strings are length-prefixed and enum
/// tags get a code byte, mirroring [`crate::hw::Platform::spec_hash`],
/// so field reorderings or boundary shifts cannot collide.
pub(super) fn spec_hash_of(
    name: &str,
    input_shape: (usize, usize, usize),
    classes: usize,
    train_batch: usize,
    eval_batch: usize,
    nodes: &[NodeDef],
) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    let eat_str = |s: &str, eat: &mut dyn FnMut(&[u8])| {
        eat(&(s.len() as u64).to_le_bytes());
        eat(s.as_bytes());
    };
    eat_str(name, &mut eat);
    for d in [input_shape.0, input_shape.1, input_shape.2, classes, train_batch, eval_batch] {
        eat(&(d as u64).to_le_bytes());
    }
    eat(&(nodes.len() as u64).to_le_bytes());
    for n in nodes {
        eat_str(&n.name, &mut eat);
        eat(&[op_code(n.op)]);
        eat(&(n.inputs.len() as u64).to_le_bytes());
        for i in &n.inputs {
            eat_str(i, &mut eat);
        }
        for d in
            [n.cin, n.cout, n.k, n.stride, n.pad, n.in_hw.0, n.in_hw.1, n.out_hw.0, n.out_hw.1]
        {
            eat(&(d as u64).to_le_bytes());
        }
        eat(&[n.relu as u8]);
    }
    h
}

fn hw_json(hw: (usize, usize)) -> Json {
    Json::arr_usize(&[hw.0, hw.1])
}

fn node_to_json(n: &NodeDef) -> Json {
    Json::obj(vec![
        ("name", Json::str(n.name.clone())),
        ("op", Json::str(op_tag(n.op))),
        ("inputs", Json::Arr(n.inputs.iter().map(Json::str).collect())),
        ("cin", Json::num(n.cin as f64)),
        ("cout", Json::num(n.cout as f64)),
        ("k", Json::num(n.k as f64)),
        ("stride", Json::num(n.stride as f64)),
        ("pad", Json::num(n.pad as f64)),
        ("relu", Json::Bool(n.relu)),
        ("in_hw", hw_json(n.in_hw)),
        ("out_hw", hw_json(n.out_hw)),
    ])
}

fn req_usize(v: &Json, node: &str, field: &'static str) -> Result<usize> {
    v.get(field)
        .and_then(Json::as_f64)
        .filter(|x| x.fract() == 0.0 && *x >= 0.0)
        .map(|x| x as usize)
        .ok_or_else(|| {
            ImportError::BadField {
                node: node.to_string(),
                field,
                msg: "missing or not a non-negative integer".into(),
            }
            .into()
        })
}

fn req_hw(v: &Json, node: &str, field: &'static str) -> Result<(usize, usize)> {
    let arr = v.get(field).and_then(Json::as_arr).ok_or_else(|| ImportError::BadField {
        node: node.to_string(),
        field,
        msg: "missing or not a 2-element array".into(),
    })?;
    if arr.len() != 2 {
        return Err(ImportError::BadField {
            node: node.to_string(),
            field,
            msg: format!("expected 2 elements, got {}", arr.len()),
        }
        .into());
    }
    let h = arr[0].as_usize().ok_or_else(|| ImportError::BadField {
        node: node.to_string(),
        field,
        msg: "height must be a number".into(),
    })?;
    let w = arr[1].as_usize().ok_or_else(|| ImportError::BadField {
        node: node.to_string(),
        field,
        msg: "width must be a number".into(),
    })?;
    Ok((h, w))
}

fn node_from_json(v: &Json) -> Result<NodeDef> {
    let name = v
        .get("name")
        .and_then(Json::as_str)
        .filter(|s| !s.is_empty())
        .ok_or_else(|| ImportError::BadField {
            node: String::new(),
            field: "name",
            msg: "every node needs a non-empty string name".into(),
        })?
        .to_string();
    let op_s = v.get("op").and_then(Json::as_str).ok_or_else(|| ImportError::BadField {
        node: name.clone(),
        field: "op",
        msg: "missing op string".into(),
    })?;
    let op = Op::parse(op_s).map_err(|_| ImportError::BadField {
        node: name.clone(),
        field: "op",
        msg: format!("unknown op '{op_s}' (input|conv|dwconv|add|gap|fc)"),
    })?;
    let inputs = v
        .get("inputs")
        .and_then(Json::as_arr)
        .ok_or_else(|| ImportError::BadField {
            node: name.clone(),
            field: "inputs",
            msg: "missing inputs array".into(),
        })?
        .iter()
        .map(|x| {
            x.as_str().map(String::from).ok_or_else(|| {
                anyhow::Error::from(ImportError::BadField {
                    node: name.clone(),
                    field: "inputs",
                    msg: "inputs must be node-name strings".into(),
                })
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let relu = v.get("relu").and_then(Json::as_bool).ok_or_else(|| ImportError::BadField {
        node: name.clone(),
        field: "relu",
        msg: "missing bool".into(),
    })?;
    Ok(NodeDef {
        cin: req_usize(v, &name, "cin")?,
        cout: req_usize(v, &name, "cout")?,
        k: req_usize(v, &name, "k")?,
        stride: req_usize(v, &name, "stride")?,
        pad: req_usize(v, &name, "pad")?,
        in_hw: req_hw(v, &name, "in_hw")?,
        out_hw: req_hw(v, &name, "out_hw")?,
        relu,
        inputs,
        op,
        name,
    })
}

/// Structural validation: unique names, backward (topological) edges,
/// exactly one leading `input` node, and declared geometry equal to
/// what shape inference derives. Runs on every import and on the
/// built-ins in tests, so the schema cannot drift from the builders.
pub fn validate(g: &Graph) -> Result<(), ImportError> {
    if g.nodes.is_empty() {
        return Err(ImportError::Empty);
    }
    if g.nodes[0].op != Op::Input {
        return Err(ImportError::FirstNotInput { node: g.nodes[0].name.clone() });
    }
    if let Some(extra) = g.nodes[1..].iter().find(|n| n.op == Op::Input) {
        return Err(ImportError::ExtraInput { node: extra.name.clone() });
    }
    let mut index = std::collections::BTreeMap::new();
    for (i, n) in g.nodes.iter().enumerate() {
        if index.insert(n.name.as_str(), i).is_some() {
            return Err(ImportError::DuplicateName { node: n.name.clone() });
        }
    }
    // edge sanity: every input resolves, and only to an earlier node
    for (i, n) in g.nodes.iter().enumerate() {
        for input in &n.inputs {
            let Some(&j) = index.get(input.as_str()) else {
                return Err(ImportError::DanglingInput {
                    node: n.name.clone(),
                    input: input.clone(),
                });
            };
            if j >= i {
                // forward (or self) edge: a cycle if the referenced
                // node reaches back to this one, else just mis-ordered
                return if j == i || reaches(g, &index, j, i) {
                    Err(ImportError::Cycle { node: n.name.clone(), input: input.clone() })
                } else {
                    Err(ImportError::NotTopological {
                        node: n.name.clone(),
                        input: input.clone(),
                    })
                };
            }
        }
    }
    let (c0, h0, w0) = g.input_shape;
    if c0 == 0 || h0 == 0 || w0 == 0 {
        return Err(ImportError::BadField {
            node: String::new(),
            field: "input_shape",
            msg: format!("all dims must be positive, got [{c0},{h0},{w0}]"),
        });
    }
    for (i, n) in g.nodes.iter().enumerate() {
        check_node(g, &index, i, n)?;
    }
    let last = g.nodes.last().unwrap_or_else(|| unreachable!());
    if last.cout != g.classes {
        return Err(ImportError::BadField {
            node: String::new(),
            field: "classes",
            msg: format!(
                "declared {} classes but the final node '{}' emits {} channels",
                g.classes, last.name, last.cout
            ),
        });
    }
    Ok(())
}

/// Is `to` reachable from `from` along input edges (backwards over the
/// table)? Used only to tell cycles from mis-ordered DAGs.
fn reaches(
    g: &Graph,
    index: &std::collections::BTreeMap<&str, usize>,
    from: usize,
    to: usize,
) -> bool {
    let mut stack = vec![from];
    let mut seen = vec![false; g.nodes.len()];
    while let Some(i) = stack.pop() {
        if i == to {
            return true;
        }
        if std::mem::replace(&mut seen[i], true) {
            continue;
        }
        for input in &g.nodes[i].inputs {
            if let Some(&j) = index.get(input.as_str()) {
                stack.push(j);
            }
        }
    }
    false
}

fn mismatch(
    node: &str,
    field: &'static str,
    expected: impl fmt::Debug,
    got: impl fmt::Debug,
) -> ImportError {
    ImportError::ShapeMismatch {
        node: node.to_string(),
        field,
        expected: format!("{expected:?}"),
        got: format!("{got:?}"),
    }
}

fn arity(n: &NodeDef, want: usize) -> Result<(), ImportError> {
    if n.inputs.len() != want {
        return Err(ImportError::BadField {
            node: n.name.clone(),
            field: "inputs",
            msg: format!("{} takes {} input(s), got {}", op_tag(n.op), want, n.inputs.len()),
        });
    }
    Ok(())
}

fn check_node(
    g: &Graph,
    index: &std::collections::BTreeMap<&str, usize>,
    i: usize,
    n: &NodeDef,
) -> Result<(), ImportError> {
    let producer = |name: &str| &g.nodes[index[name]];
    match n.op {
        Op::Input => {
            arity(n, 0)?;
            let (c0, h0, w0) = g.input_shape;
            if i != 0 {
                return Err(ImportError::ExtraInput { node: n.name.clone() });
            }
            if n.cin != 0 {
                return Err(mismatch(&n.name, "cin", 0usize, n.cin));
            }
            if n.cout != c0 {
                return Err(mismatch(&n.name, "cout", c0, n.cout));
            }
            if n.in_hw != (h0, w0) {
                return Err(mismatch(&n.name, "in_hw", (h0, w0), n.in_hw));
            }
            if n.out_hw != (h0, w0) {
                return Err(mismatch(&n.name, "out_hw", (h0, w0), n.out_hw));
            }
        }
        Op::Conv | Op::DwConv => {
            arity(n, 1)?;
            let p = producer(&n.inputs[0]);
            if n.cin != p.cout {
                return Err(mismatch(&n.name, "cin", p.cout, n.cin));
            }
            if n.op == Op::DwConv && n.cout != n.cin {
                return Err(mismatch(&n.name, "cout", n.cin, n.cout));
            }
            if n.cout == 0 {
                return Err(ImportError::BadField {
                    node: n.name.clone(),
                    field: "cout",
                    msg: "must be positive".into(),
                });
            }
            if n.stride == 0 || n.k == 0 {
                return Err(ImportError::BadField {
                    node: n.name.clone(),
                    field: if n.stride == 0 { "stride" } else { "k" },
                    msg: "must be positive".into(),
                });
            }
            if n.in_hw != p.out_hw {
                return Err(mismatch(&n.name, "in_hw", p.out_hw, n.in_hw));
            }
            let (h, w) = n.in_hw;
            if h + 2 * n.pad < n.k || w + 2 * n.pad < n.k {
                return Err(ImportError::BadField {
                    node: n.name.clone(),
                    field: "k",
                    msg: format!(
                        "kernel {} exceeds the padded input {}x{} (pad {})",
                        n.k, h, w, n.pad
                    ),
                });
            }
            let oh = (h + 2 * n.pad - n.k) / n.stride + 1;
            let ow = (w + 2 * n.pad - n.k) / n.stride + 1;
            if n.out_hw != (oh, ow) {
                return Err(mismatch(&n.name, "out_hw", (oh, ow), n.out_hw));
            }
        }
        Op::Add => {
            arity(n, 2)?;
            let a = producer(&n.inputs[0]);
            let b = producer(&n.inputs[1]);
            if a.cout != b.cout || a.out_hw != b.out_hw {
                return Err(ImportError::BadField {
                    node: n.name.clone(),
                    field: "inputs",
                    msg: format!(
                        "add operands disagree: {}x{:?} vs {}x{:?}",
                        a.cout, a.out_hw, b.cout, b.out_hw
                    ),
                });
            }
            if n.cin != a.cout {
                return Err(mismatch(&n.name, "cin", a.cout, n.cin));
            }
            if n.cout != a.cout {
                return Err(mismatch(&n.name, "cout", a.cout, n.cout));
            }
            if n.in_hw != a.out_hw {
                return Err(mismatch(&n.name, "in_hw", a.out_hw, n.in_hw));
            }
            if n.out_hw != a.out_hw {
                return Err(mismatch(&n.name, "out_hw", a.out_hw, n.out_hw));
            }
        }
        Op::Gap => {
            arity(n, 1)?;
            let p = producer(&n.inputs[0]);
            if n.cin != p.cout {
                return Err(mismatch(&n.name, "cin", p.cout, n.cin));
            }
            if n.cout != p.cout {
                return Err(mismatch(&n.name, "cout", p.cout, n.cout));
            }
            if n.in_hw != p.out_hw {
                return Err(mismatch(&n.name, "in_hw", p.out_hw, n.in_hw));
            }
            if n.out_hw != (1, 1) {
                return Err(mismatch(&n.name, "out_hw", (1usize, 1usize), n.out_hw));
            }
        }
        Op::Fc => {
            arity(n, 1)?;
            let p = producer(&n.inputs[0]);
            if p.out_hw != (1, 1) {
                return Err(ImportError::BadField {
                    node: n.name.clone(),
                    field: "inputs",
                    msg: format!(
                        "fc consumes a 1x1 feature map (use gap first); '{}' emits {:?}",
                        p.name, p.out_hw
                    ),
                });
            }
            if n.cin != p.cout {
                return Err(mismatch(&n.name, "cin", p.cout, n.cin));
            }
            if n.cout == 0 {
                return Err(ImportError::BadField {
                    node: n.name.clone(),
                    field: "cout",
                    msg: "must be positive".into(),
                });
            }
            if n.in_hw != (1, 1) {
                return Err(mismatch(&n.name, "in_hw", (1usize, 1usize), n.in_hw));
            }
            if n.out_hw != (1, 1) {
                return Err(mismatch(&n.name, "out_hw", (1usize, 1usize), n.out_hw));
            }
        }
    }
    Ok(())
}

impl Graph {
    /// The canonical graph document: the full versioned envelope, so
    /// `to_json().to_string()` is byte-for-byte what
    /// [`Graph::save_json`] writes and what [`Graph::from_json_file`]
    /// re-emits after a round-trip (the emitter sorts object keys).
    pub fn to_json(&self) -> Json {
        let nodes = Json::Arr(self.nodes.iter().map(node_to_json).collect());
        let payload = Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            (
                "input_shape",
                Json::arr_usize(&[self.input_shape.0, self.input_shape.1, self.input_shape.2]),
            ),
            ("classes", Json::num(self.classes as f64)),
            ("train_batch", Json::num(self.train_batch as f64)),
            ("eval_batch", Json::num(self.eval_batch as f64)),
            ("nodes", nodes),
        ]);
        Json::obj(vec![
            ("kind", Json::str(GRAPH_KIND)),
            ("schema_version", Json::num(GRAPH_SCHEMA as f64)),
            ("payload", payload),
        ])
    }

    /// Write the canonical document atomically.
    pub fn save_json(&self, path: &Path) -> Result<()> {
        store::write_atomic(path, &self.to_json().to_string())
    }

    /// Parse and validate a graph from an in-memory envelope document
    /// (what [`Graph::to_json`] emits).
    pub fn from_json(doc: &Json) -> Result<Graph> {
        let kind = doc.req("kind")?.as_str().unwrap_or("");
        if kind != GRAPH_KIND {
            return Err(anyhow!("graph kind '{kind}' != expected '{GRAPH_KIND}'"));
        }
        let version = doc.req("schema_version")?.as_usize().unwrap_or(0) as u32;
        if version != GRAPH_SCHEMA {
            return Err(anyhow!(
                "graph schema version {version} != expected {GRAPH_SCHEMA} — \
                 re-export the graph"
            ));
        }
        Self::from_payload(doc.req("payload")?)
    }

    fn from_payload(p: &Json) -> Result<Graph> {
        let name = p
            .req("name")?
            .as_str()
            .filter(|s| !s.is_empty())
            .ok_or_else(|| ImportError::BadField {
                node: String::new(),
                field: "name",
                msg: "graph needs a non-empty string name".into(),
            })?
            .to_string();
        let ishape = p.req("input_shape")?.usize_vec().map_err(|_| ImportError::BadField {
            node: String::new(),
            field: "input_shape",
            msg: "must be a numeric array".into(),
        })?;
        if ishape.len() != 3 {
            return Err(ImportError::BadField {
                node: String::new(),
                field: "input_shape",
                msg: format!("expected [C,H,W], got {} element(s)", ishape.len()),
            }
            .into());
        }
        let nodes = p
            .req("nodes")?
            .as_arr()
            .ok_or_else(|| ImportError::BadField {
                node: String::new(),
                field: "nodes",
                msg: "must be an array".into(),
            })?
            .iter()
            .map(node_from_json)
            .collect::<Result<Vec<_>>>()?;
        let g = Graph::new(
            name,
            (ishape[0], ishape[1], ishape[2]),
            req_usize(p, "", "classes")?,
            req_usize(p, "", "train_batch")?.max(1),
            req_usize(p, "", "eval_batch")?.max(1),
            nodes,
        );
        validate(&g)?;
        Ok(g)
    }

    /// Load, parse and validate a graph JSON file.
    pub fn from_json_file(path: &Path) -> Result<Graph> {
        let payload = store::load_versioned(path, GRAPH_KIND, GRAPH_SCHEMA)?;
        Self::from_payload(&payload)
            .map_err(|e| anyhow!("{}: {e:#}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{build, tinycnn, ALL_MODELS};

    #[test]
    fn builtins_validate_and_roundtrip_bytes() {
        for name in ALL_MODELS {
            let g = build(name).unwrap();
            validate(&g).unwrap_or_else(|e| panic!("{name}: {e}"));
            let text = g.to_json().to_string();
            let doc = crate::util::json::parse(&text).unwrap();
            let back = Graph::from_json(&doc).unwrap();
            assert_eq!(back.to_json().to_string(), text, "{name}: round-trip drifted");
            assert_eq!(back.spec_hash(), g.spec_hash(), "{name}");
            assert_eq!(back.nodes.len(), g.nodes.len(), "{name}");
        }
    }

    #[test]
    fn spec_hash_tracks_structure() {
        let base = tinycnn();
        assert_eq!(base.spec_hash(), tinycnn().spec_hash(), "deterministic");
        for name in &ALL_MODELS[1..] {
            assert_ne!(base.spec_hash(), build(name).unwrap().spec_hash());
        }
        // one edited channel count moves the hash (the stale-frontier case)
        let mut edited = tinycnn();
        edited.nodes[1].cout += 1;
        let rehashed = Graph::new(
            edited.name.clone(),
            edited.input_shape,
            edited.classes,
            edited.train_batch,
            edited.eval_batch,
            edited.nodes.clone(),
        );
        assert_ne!(base.spec_hash(), rehashed.spec_hash());
        // a renamed edge moves it too, same geometry
        let mut renamed = tinycnn();
        renamed.nodes[1].name = "stem2".into();
        renamed.nodes[2].inputs = vec!["stem2".into()];
        let rehashed = Graph::new(
            renamed.name.clone(),
            renamed.input_shape,
            renamed.classes,
            renamed.train_batch,
            renamed.eval_batch,
            renamed.nodes.clone(),
        );
        assert_ne!(base.spec_hash(), rehashed.spec_hash());
    }

    fn rebuilt(mut f: impl FnMut(&mut Graph)) -> Graph {
        let mut g = tinycnn();
        f(&mut g);
        Graph::new(g.name, g.input_shape, g.classes, g.train_batch, g.eval_batch, g.nodes)
    }

    #[test]
    fn validation_catches_structural_breakage() {
        // duplicate name
        let g = rebuilt(|g| g.nodes[2].name = "stem".into());
        assert!(matches!(validate(&g), Err(ImportError::DuplicateName { .. })));
        // dangling input
        let g = rebuilt(|g| g.nodes[2].inputs = vec!["ghost".into()]);
        match validate(&g) {
            Err(ImportError::DanglingInput { node, input }) => {
                assert_eq!(node, "c1");
                assert_eq!(input, "ghost");
            }
            other => panic!("expected DanglingInput, got {other:?}"),
        }
        // self-edge is a cycle
        let g = rebuilt(|g| g.nodes[2].inputs = vec!["c1".into()]);
        assert!(matches!(validate(&g), Err(ImportError::Cycle { .. })));
        // legal DAG, wrong order
        let g = rebuilt(|g| g.nodes.swap(1, 2));
        assert!(matches!(validate(&g), Err(ImportError::NotTopological { .. })));
        // declared shape drifts from inference
        let g = rebuilt(|g| g.nodes[2].out_hw = (9, 9));
        match validate(&g) {
            Err(ImportError::ShapeMismatch { node, field, .. }) => {
                assert_eq!(node, "c1");
                assert_eq!(field, "out_hw");
            }
            other => panic!("expected ShapeMismatch, got {other:?}"),
        }
        // arity violation
        let g = rebuilt(|g| g.nodes[4].inputs = vec!["c2".into()]);
        assert!(matches!(validate(&g), Err(ImportError::BadField { field: "inputs", .. })));
        // classes disagree with the final fc
        let g = rebuilt(|g| g.classes = 11);
        assert!(matches!(validate(&g), Err(ImportError::BadField { field: "classes", .. })));
    }

    #[test]
    fn file_roundtrip_through_disk() {
        let dir = std::env::temp_dir().join("odimo_graph_import");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("tinycnn.json");
        let g = tinycnn();
        g.save_json(&path).unwrap();
        let back = Graph::from_json_file(&path).unwrap();
        assert_eq!(back.to_json().to_string(), g.to_json().to_string());
        assert_eq!(std::fs::read_to_string(&path).unwrap(), g.to_json().to_string());
        // wrong envelope kind is a clear error
        store::save_versioned(&path, "frontier", GRAPH_SCHEMA, Json::obj(vec![])).unwrap();
        let e = Graph::from_json_file(&path).unwrap_err().to_string();
        assert!(e.contains("kind"), "{e}");
    }
}
