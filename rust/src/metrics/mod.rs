//! Result accounting: Pareto-front extraction and report writers
//! (markdown tables + CSV) for the experiment drivers.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

use crate::coordinator::SearchPoint;

/// Indices of the Pareto-optimal points maximizing accuracy while
/// minimizing `cost(point)`. O(n log n).
pub fn pareto_front(points: &[SearchPoint], cost: impl Fn(&SearchPoint) -> f64) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    // sort by cost ascending, accuracy descending for ties
    idx.sort_by(|&a, &b| {
        cost(&points[a])
            .partial_cmp(&cost(&points[b]))
            .unwrap()
            .then(points[b].accuracy.partial_cmp(&points[a].accuracy).unwrap())
    });
    let mut front = Vec::new();
    let mut best_acc = f64::NEG_INFINITY;
    for i in idx {
        if points[i].accuracy > best_acc {
            front.push(i);
            best_acc = points[i].accuracy;
        }
    }
    front
}

/// True iff `a` dominates `b` (better-or-equal on both axes, strictly
/// better on one).
pub fn dominates(a: &SearchPoint, b: &SearchPoint, cost: impl Fn(&SearchPoint) -> f64) -> bool {
    let (ca, cb) = (cost(a), cost(b));
    (a.accuracy >= b.accuracy && ca <= cb) && (a.accuracy > b.accuracy || ca < cb)
}

/// Markdown table in the Table-I column layout. The utilization column
/// carries one slash-separated entry per platform accelerator.
pub fn table_markdown(title: &str, points: &[SearchPoint]) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "### {title}\n");
    let _ = writeln!(s, "| Network | Acc. | lat. [ms] | E. [uJ] | Util. | A. Ch. |");
    let _ = writeln!(s, "|---------|------|-----------|---------|-------|--------|");
    for p in points {
        let util = p
            .util
            .iter()
            .map(|&u| format!("{:.1}%", 100.0 * u))
            .collect::<Vec<_>>()
            .join(" / ");
        let _ = writeln!(
            s,
            "| {} | {:.2} | {:.3} | {:.2} | {util} | {:.1}% |",
            p.label,
            100.0 * p.accuracy,
            p.latency_ms,
            p.energy_uj,
            100.0 * p.aimc_channel_frac,
        );
    }
    s
}

/// CSV rows (for plotting the Fig.-4/5 scatter externally). Utilization
/// columns are emitted per accelerator (`util_0..util_{n-1}`, n from
/// the first point).
pub fn points_csv(points: &[SearchPoint]) -> String {
    let n_acc = points.first().map(|p| p.util.len()).unwrap_or(2);
    let mut s = String::from("label,lambda,accuracy,latency_ms,energy_uj,total_cycles");
    for i in 0..n_acc {
        let _ = write!(s, ",util_{i}");
    }
    s.push_str(",aimc_ch_frac\n");
    for p in points {
        let _ = write!(
            s,
            "{},{},{:.6},{:.6},{:.4},{}",
            p.label, p.lambda, p.accuracy, p.latency_ms, p.energy_uj, p.total_cycles,
        );
        for i in 0..n_acc {
            let _ = write!(s, ",{:.4}", p.util.get(i).copied().unwrap_or(0.0));
        }
        let _ = writeln!(s, ",{:.4}", p.aimc_channel_frac);
    }
    s
}

pub fn write_results(dir: &Path, name: &str, md: &str, csv: &str) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join(format!("{name}.md")), md)?;
    std::fs::write(dir.join(format!("{name}.csv")), csv)?;
    Ok(())
}

/// ASCII scatter of accuracy (y) vs cost (x, log-scale) — the terminal
/// rendering of a Fig.-4 panel.
pub fn ascii_scatter(points: &[SearchPoint], cost: impl Fn(&SearchPoint) -> f64,
                     width: usize, height: usize) -> String {
    if points.is_empty() {
        return String::from("(no points)\n");
    }
    let xs: Vec<f64> = points.iter().map(|p| cost(p).max(1e-12).log10()).collect();
    let ys: Vec<f64> = points.iter().map(|p| p.accuracy).collect();
    let (x0, x1) = xs.iter().fold((f64::MAX, f64::MIN), |(a, b), &v| (a.min(v), b.max(v)));
    let (y0, y1) = ys.iter().fold((f64::MAX, f64::MIN), |(a, b), &v| (a.min(v), b.max(v)));
    let xr = (x1 - x0).max(1e-9);
    let yr = (y1 - y0).max(1e-9);
    let mut grid = vec![vec![' '; width]; height];
    for (i, p) in points.iter().enumerate() {
        let cx = ((xs[i] - x0) / xr * (width - 1) as f64) as usize;
        let cy = height - 1 - ((ys[i] - y0) / yr * (height - 1) as f64) as usize;
        let ch = if p.label.starts_with("odimo") { 'o' } else { 'B' };
        grid[cy][cx] = ch;
    }
    let mut s = String::new();
    let _ = writeln!(s, "acc {:.3} ─ {:.3}   cost(log10) {:.2} ─ {:.2}   o=ODiMO B=baseline",
                     y0, y1, x0, x1);
    for row in grid {
        let _ = writeln!(s, "|{}|", row.into_iter().collect::<String>());
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Mapping;
    use std::collections::BTreeMap;

    fn pt(label: &str, acc: f64, lat: f64) -> SearchPoint {
        SearchPoint {
            label: label.into(),
            lambda: 0.0,
            accuracy: acc,
            latency_ms: lat,
            energy_uj: lat * 10.0,
            total_cycles: (lat * 1000.0) as u64,
            util: vec![1.0, 0.0],
            aimc_channel_frac: 0.0,
            mapping: Mapping { assign: BTreeMap::new() },
        }
    }

    #[test]
    fn pareto_extraction() {
        let pts = vec![
            pt("a", 0.9, 10.0),
            pt("b", 0.8, 5.0),
            pt("c", 0.7, 8.0),  // dominated by b
            pt("d", 0.95, 20.0),
        ];
        let f = pareto_front(&pts, |p| p.latency_ms);
        let labels: Vec<&str> = f.iter().map(|&i| pts[i].label.as_str()).collect();
        assert_eq!(labels, vec!["b", "a", "d"]);
    }

    #[test]
    fn dominance() {
        let a = pt("a", 0.9, 5.0);
        let b = pt("b", 0.8, 10.0);
        assert!(dominates(&a, &b, |p| p.latency_ms));
        assert!(!dominates(&b, &a, |p| p.latency_ms));
        assert!(!dominates(&a, &a, |p| p.latency_ms));
    }

    #[test]
    fn markdown_has_all_rows() {
        let pts = vec![pt("all_8bit", 0.9, 1.55), pt("odimo_0.5", 0.89, 1.0)];
        let md = table_markdown("t", &pts);
        assert!(md.contains("all_8bit") && md.contains("odimo_0.5"));
        assert_eq!(md.lines().count(), 2 + 2 + 2);
    }

    #[test]
    fn csv_parses_back() {
        let pts = vec![pt("x", 0.5, 2.0)];
        let csv = points_csv(&pts);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[1].split(',').count(), 9);
    }

    #[test]
    fn scatter_renders() {
        let pts = vec![pt("a", 0.9, 10.0), pt("odimo_1", 0.8, 1.0)];
        let s = ascii_scatter(&pts, |p| p.latency_ms, 40, 10);
        assert!(s.contains('o') && s.contains('B'));
    }
}
