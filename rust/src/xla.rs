//! Host-side stand-in for the vendored `xla` crate (PJRT bindings).
//!
//! The PJRT toolchain (xla 0.1.6 / xla_extension 0.5.1 + libxla shared
//! objects) is only present on artifact-building machines. Declaring the
//! crate unconditionally would make `cargo build` fail everywhere else,
//! so the repo builds against this API-compatible shim instead:
//!
//!   * [`Literal`] is a real host container (dims + typed storage) —
//!     everything that only marshals tensors ([`crate::runtime::ParamState`],
//!     checkpoint save/load, literal round-trips) works unchanged;
//!   * compilation/execution entry points ([`PjRtClient::cpu`],
//!     [`HloModuleProto::from_text_file`]) return a descriptive error,
//!     and every artifact-driven test skips itself when `make artifacts`
//!     has not produced the HLO files anyway.
//!
//! Swapping the real backend in means replacing this module with
//! `pub use ::xla::*;` and adding the vendored crate to Cargo.toml; the
//! call sites (`crate::xla::...`) do not change.

use std::fmt;

/// Stub error — implements `std::error::Error` so `anyhow::Context`
/// attaches to fallible calls exactly like the real crate's error type.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT backend unavailable (built against the xla shim; \
         install the vendored xla crate to run AOT artifacts)"
    ))
}

/// Element types mirrored from the real crate (subset + spares so that
/// `match` arms over unsupported types stay reachable).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    F16,
    F32,
    F64,
}

#[derive(Clone, Debug)]
enum Data {
    F32(Vec<f32>),
    S32(Vec<i32>),
    /// only the real backend produces tuples (result downloads)
    #[allow(dead_code)]
    Tuple(Vec<Literal>),
}

/// Host literal: shape + typed storage.
#[derive(Clone, Debug)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

/// Types storable in a [`Literal`] (mirror of the real crate's trait).
pub trait NativeType: Copy {
    fn element_type() -> ElementType;
    fn store(v: &[Self]) -> Data;
    fn read(l: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn element_type() -> ElementType {
        ElementType::F32
    }
    fn store(v: &[Self]) -> Data {
        Data::F32(v.to_vec())
    }
    fn read(l: &Literal) -> Result<Vec<Self>> {
        match &l.data {
            Data::F32(v) => Ok(v.clone()),
            _ => Err(unavailable("to_vec::<f32> on non-f32 literal")),
        }
    }
}

impl NativeType for i32 {
    fn element_type() -> ElementType {
        ElementType::S32
    }
    fn store(v: &[Self]) -> Data {
        Data::S32(v.to_vec())
    }
    fn read(l: &Literal) -> Result<Vec<Self>> {
        match &l.data {
            Data::S32(v) => Ok(v.clone()),
            _ => Err(unavailable("to_vec::<i32> on non-s32 literal")),
        }
    }
}

impl Literal {
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: T::store(v) }
    }

    pub fn scalar(v: f32) -> Literal {
        Literal { dims: vec![], data: Data::F32(vec![v]) }
    }

    pub fn reshape(self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = self.element_count() as i64;
        if want != have {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: {have} elements vs {want}",
                self.dims
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data })
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::S32(v) => v.len(),
            Data::Tuple(v) => v.len(),
        }
    }

    pub fn ty(&self) -> Result<ElementType> {
        match &self.data {
            Data::F32(_) => Ok(ElementType::F32),
            Data::S32(_) => Ok(ElementType::S32),
            Data::Tuple(_) => Err(unavailable("ty of tuple literal")),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::read(self)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.data {
            Data::Tuple(v) => Ok(v.clone()),
            _ => Err(unavailable("to_tuple of non-tuple literal")),
        }
    }
}

/// Parsed HLO module (never constructible through the shim).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<Self> {
        Err(unavailable(&format!("parsing HLO text {path}")))
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable("creating PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "shim".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compiling computation"))
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _inputs: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("executing"))
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("downloading buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.ty().unwrap(), ElementType::F32);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_reshape_checks_count() {
        assert!(Literal::vec1(&[1i32, 2, 3]).reshape(&[2, 2]).is_err());
    }

    #[test]
    fn execution_surface_errors() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
