//! Slow reference path — the original naive scalar interpreter, kept
//! verbatim as the oracle for differential tests of the planned engine
//! (`quant::infer::QuantNet`).
//!
//! Semantics (shared contract, pinned against the AOT `infer_deploy`
//! HLO in `tests/quant_infer.rs`):
//!   - weights fake-quantized to the assigned format per channel
//!     (int8 digital / ternary AIMC, per-layer Eq.-5 scales)
//!   - digital sub-convs read the stored 8-bit activations; each
//!     IMC-style sub-conv re-reads them through its unit's n-bit D/A
//!     (fixed-range LSB truncation, one view per distinct `da_bits`
//!     on multi-macro platforms)
//!   - mixed output quantization: 8-bit digital channels, 7-bit AIMC
//!
//! All values live on their quantization grids; arithmetic is f32 like
//! the reference graph. The planned engine reproduces this path
//! bit-for-bit (identical per-element accumulation order), so the
//! differential tolerance in tests is a safety margin, not slack.
//!
//! The same ascending-k, separate-mul-add order is the anchor for the
//! SIMD backends too (`quant::simd`, no-FMA contract): scalar, AVX2,
//! NEON and the portable fallback all reduce to this interpreter's
//! arithmetic, which is what lets `tests/kernel_differential.rs` pin
//! backend equality with `assert_eq!` rather than a tolerance.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::coordinator::Mapping;
use crate::hw::Platform;
use crate::model::{Graph, NodeDef, Op};

use super::{da_q, fake_quant, quant_act, ParamSet};

struct QLayer {
    /// per-channel effective fake-quantized weights (already masked by
    /// the assignment: each channel on its accelerator's grid), OIHW
    w_eff: Vec<f32>,
    bias: Vec<f32>,
    act_scale: f32,
    assign: Vec<u8>,
}

/// Per-accelerator facts the forward pass needs (index = acc id).
#[derive(Clone, Copy)]
struct AccView {
    /// D/A read width of this unit (`None` = reads stored activations).
    da: Option<u32>,
    act_bits: u32,
}

/// The naive interpreter: string-keyed tensor map, fresh allocations
/// per node, direct scalar convolution. Correct and slow.
pub struct RefNet<'g> {
    graph: &'g Graph,
    layers: BTreeMap<String, QLayer>,
    dw: BTreeMap<String, QLayer>,
    add_scales: BTreeMap<String, f32>,
    accs: Vec<AccView>,
    dw_acc: usize,
    /// distinct D/A widths on the platform (one input view per width)
    da_widths: Vec<u32>,
}

impl<'g> RefNet<'g> {
    /// Compile from a parameter snapshot for `platform`.
    pub fn compile(
        params: &ParamSet<'_>,
        graph: &'g Graph,
        mapping: &Mapping,
        platform: &Platform,
    ) -> Result<Self> {
        mapping.validate(graph, platform.n_acc())?;
        let accs: Vec<AccView> = platform
            .accelerators
            .iter()
            .map(|a| AccView { da: a.da_bits, act_bits: a.act_bits })
            .collect();
        let scales: Vec<String> =
            platform.accelerators.iter().map(|a| a.scale_leaf()).collect();
        let wbits: Vec<u32> = platform.accelerators.iter().map(|a| a.weight_bits).collect();
        let da_widths = platform.da_widths();
        let mut layers = BTreeMap::new();
        let mut dw = BTreeMap::new();
        let mut add_scales = BTreeMap::new();
        for n in &graph.nodes {
            match n.op {
                Op::Conv | Op::Fc => {
                    let w = params.get(&n.name, "w")?;
                    let assign = mapping.layer(&n.name).to_vec();
                    // per-accelerator scales, fetched lazily so layers
                    // with no channels on a unit don't require its leaf
                    let mut acc_scale = vec![None::<f32>; platform.n_acc()];
                    let per_ch = w.len() / n.cout;
                    let mut w_eff = vec![0f32; w.len()];
                    for co in 0..n.cout {
                        let acc = assign[co] as usize;
                        let scale = match acc_scale[acc] {
                            Some(s) => s,
                            None => {
                                let s = params.get(&n.name, &scales[acc])?[0].exp();
                                acc_scale[acc] = Some(s);
                                s
                            }
                        };
                        for k in 0..per_ch {
                            w_eff[co * per_ch + k] =
                                fake_quant(w[co * per_ch + k], scale, wbits[acc]);
                        }
                    }
                    layers.insert(
                        n.name.clone(),
                        QLayer {
                            w_eff,
                            bias: params.get(&n.name, "b")?.to_vec(),
                            act_scale: params.get(&n.name, "lsa")?[0].exp(),
                            assign,
                        },
                    );
                }
                Op::DwConv => {
                    let w = params.get(&n.name, "w")?;
                    let leaf = &scales[platform.dw_acc];
                    let s = params.get(&n.name, leaf)?[0].exp();
                    let b = wbits[platform.dw_acc];
                    dw.insert(
                        n.name.clone(),
                        QLayer {
                            w_eff: w.iter().map(|&v| fake_quant(v, s, b)).collect(),
                            bias: params.get(&n.name, "b")?.to_vec(),
                            act_scale: params.get(&n.name, "lsa")?[0].exp(),
                            assign: vec![platform.dw_acc as u8; n.cout],
                        },
                    );
                }
                Op::Add => {
                    add_scales
                        .insert(n.name.clone(), params.get(&n.name, "lsa")?[0].exp());
                }
                _ => {}
            }
        }
        Ok(RefNet {
            graph,
            layers,
            dw,
            add_scales,
            accs,
            dw_acc: platform.dw_acc,
            da_widths,
        })
    }

    /// One D/A input view per distinct platform width (fixed [0,1]
    /// range, like the graph) for the accelerators that re-read
    /// activations through a converter.
    fn da_views(&self, inp: &[f32]) -> Vec<(u32, Vec<f32>)> {
        self.da_widths
            .iter()
            .map(|&w| (w, inp.iter().map(|&v| da_q(v, w)).collect()))
            .collect()
    }

    /// Forward one batch (NCHW in [0,1]); returns (batch, classes) logits.
    pub fn forward(&self, x: &[f32], batch: usize) -> Result<Vec<f32>> {
        let (c0, h0, w0) = self.graph.input_shape;
        assert_eq!(x.len(), batch * c0 * h0 * w0, "input size");
        let mut vals: BTreeMap<&str, Vec<f32>> = BTreeMap::new();
        for n in &self.graph.nodes {
            let out = match n.op {
                Op::Input => x
                    .iter()
                    .map(|&v| super::round_half_even(v * 255.0) / 255.0)
                    .collect(),
                Op::Conv => self.conv_mapped(n, &vals[n.inputs[0].as_str()], batch),
                Op::Fc => self.fc_mapped(n, &vals[n.inputs[0].as_str()], batch),
                Op::DwConv => self.dwconv(n, &vals[n.inputs[0].as_str()], batch),
                Op::Add => {
                    let a = &vals[n.inputs[0].as_str()];
                    let b = &vals[n.inputs[1].as_str()];
                    let s = self.add_scales[&n.name];
                    a.iter()
                        .zip(b)
                        .map(|(x, y)| {
                            let v = x + y;
                            let v = if n.relu { v.max(0.0) } else { v };
                            quant_act(v, s, 8)
                        })
                        .collect()
                }
                Op::Gap => {
                    let a = &vals[n.inputs[0].as_str()];
                    let (c, hw) = (n.cin, n.in_hw.0 * n.in_hw.1);
                    let mut y = vec![0f32; batch * c];
                    for b in 0..batch {
                        for ch in 0..c {
                            let base = (b * c + ch) * hw;
                            y[b * c + ch] =
                                a[base..base + hw].iter().sum::<f32>() / hw as f32;
                        }
                    }
                    y
                }
            };
            vals.insert(&n.name, out);
        }
        let out_name = &self.graph.nodes.last().unwrap().name;
        Ok(vals[out_name.as_str()].clone())
    }

    fn conv_mapped(&self, n: &NodeDef, inp: &[f32], batch: usize) -> Vec<f32> {
        let q = &self.layers[&n.name];
        let views = self.da_views(inp);
        let (oh, ow) = n.out_hw;
        let mut y = vec![0f32; batch * n.cout * oh * ow];
        for b in 0..batch {
            for co in 0..n.cout {
                let acc = self.accs[q.assign[co] as usize];
                let src: &[f32] = match acc.da {
                    Some(w) => &views.iter().find(|(vw, _)| *vw == w).unwrap().1,
                    None => inp,
                };
                conv_one_channel(
                    src, b, n.cin, n.in_hw, &q.w_eff, co, n.k, n.stride, n.pad,
                    oh, ow,
                    &mut y[(b * n.cout + co) * oh * ow..(b * n.cout + co + 1) * oh * ow],
                );
                for v in
                    y[(b * n.cout + co) * oh * ow..(b * n.cout + co + 1) * oh * ow].iter_mut()
                {
                    let t = *v + q.bias[co];
                    let t = if n.relu { t.max(0.0) } else { t };
                    *v = quant_act(t, q.act_scale, acc.act_bits);
                }
            }
        }
        y
    }

    fn fc_mapped(&self, n: &NodeDef, inp: &[f32], batch: usize) -> Vec<f32> {
        let q = &self.layers[&n.name];
        let views = self.da_views(inp);
        let mut y = vec![0f32; batch * n.cout];
        for b in 0..batch {
            for co in 0..n.cout {
                let src: &[f32] = match self.accs[q.assign[co] as usize].da {
                    Some(w) => &views.iter().find(|(vw, _)| *vw == w).unwrap().1,
                    None => inp,
                };
                let mut acc = 0f32;
                for ci in 0..n.cin {
                    acc += src[b * n.cin + ci] * q.w_eff[co * n.cin + ci];
                }
                y[b * n.cout + co] = acc + q.bias[co]; // logits stay float
            }
        }
        y
    }

    fn dwconv(&self, n: &NodeDef, inp: &[f32], batch: usize) -> Vec<f32> {
        let q = &self.dw[&n.name];
        let obits = self.accs[self.dw_acc].act_bits;
        let (oh, ow) = n.out_hw;
        let mut y = vec![0f32; batch * n.cout * oh * ow];
        for b in 0..batch {
            for ch in 0..n.cout {
                let dst = &mut y[(b * n.cout + ch) * oh * ow
                    ..(b * n.cout + ch + 1) * oh * ow];
                dw_one_channel(inp, b, n.cin, n.in_hw, &q.w_eff, ch, n.k, n.stride,
                               n.pad, oh, ow, dst);
                for v in dst.iter_mut() {
                    let t = *v + q.bias[ch];
                    let t = if n.relu { t.max(0.0) } else { t };
                    *v = quant_act(t, q.act_scale, obits);
                }
            }
        }
        y
    }
}

/// Naive float (quantization-free) calibration forward — the original
/// `calibrate_act_maxima`, kept as the oracle for the engine-based
/// rewrite in `quant::infer`.
pub fn calibrate_act_maxima_ref(
    params: &ParamSet<'_>,
    graph: &Graph,
    x: &[f32],
    batch: usize,
) -> Result<BTreeMap<String, f32>> {
    let mut maxima = BTreeMap::new();
    let mut vals: BTreeMap<&str, Vec<f32>> = BTreeMap::new();
    for n in &graph.nodes {
        let out: Vec<f32> = match n.op {
            Op::Input => x.to_vec(),
            Op::Conv | Op::DwConv => {
                let inp = &vals[n.inputs[0].as_str()];
                let w = params.get(&n.name, "w")?;
                let b = params.get(&n.name, "b")?;
                let (oh, ow) = n.out_hw;
                let mut y = vec![0f32; batch * n.cout * oh * ow];
                for bb in 0..batch {
                    for co in 0..n.cout {
                        let dst = &mut y[(bb * n.cout + co) * oh * ow
                            ..(bb * n.cout + co + 1) * oh * ow];
                        if n.op == Op::Conv {
                            conv_one_channel(inp, bb, n.cin, n.in_hw, w, co, n.k,
                                             n.stride, n.pad, oh, ow, dst);
                        } else {
                            dw_one_channel(inp, bb, n.cin, n.in_hw, w, co, n.k,
                                           n.stride, n.pad, oh, ow, dst);
                        }
                        for v in dst.iter_mut() {
                            *v += b[co];
                            if n.relu {
                                *v = v.max(0.0);
                            }
                        }
                    }
                }
                y
            }
            Op::Fc => {
                let inp = &vals[n.inputs[0].as_str()];
                let w = params.get(&n.name, "w")?;
                let b = params.get(&n.name, "b")?;
                let mut y = vec![0f32; batch * n.cout];
                for bb in 0..batch {
                    for co in 0..n.cout {
                        let mut acc = 0f32;
                        for ci in 0..n.cin {
                            acc += inp[bb * n.cin + ci] * w[co * n.cin + ci];
                        }
                        y[bb * n.cout + co] = acc + b[co];
                    }
                }
                y
            }
            Op::Add => {
                let a = &vals[n.inputs[0].as_str()];
                let c = &vals[n.inputs[1].as_str()];
                a.iter()
                    .zip(c)
                    .map(|(x, y)| {
                        let v = x + y;
                        if n.relu { v.max(0.0) } else { v }
                    })
                    .collect()
            }
            Op::Gap => {
                let a = &vals[n.inputs[0].as_str()];
                let (c, hw) = (n.cin, n.in_hw.0 * n.in_hw.1);
                let mut y = vec![0f32; batch * c];
                for bb in 0..batch {
                    for ch in 0..c {
                        let base = (bb * c + ch) * hw;
                        y[bb * c + ch] = a[base..base + hw].iter().sum::<f32>() / hw as f32;
                    }
                }
                y
            }
        };
        if matches!(n.op, Op::Conv | Op::DwConv | Op::Add) {
            let m = out.iter().fold(0f32, |m, &v| m.max(v));
            maxima.insert(n.name.clone(), m);
        }
        vals.insert(&n.name, out);
    }
    Ok(maxima)
}

/// One depthwise output channel (cin == cout, channel ch reads ch).
#[allow(clippy::too_many_arguments)]
fn dw_one_channel(
    x: &[f32],
    b: usize,
    cin: usize,
    in_hw: (usize, usize),
    w: &[f32],
    ch: usize,
    k: usize,
    stride: usize,
    pad: usize,
    oh: usize,
    ow: usize,
    out: &mut [f32],
) {
    let (hi, wi) = in_hw;
    let xbase = (b * cin + ch) * hi * wi;
    let wrow = ch * k * k;
    for oy in 0..oh {
        for ox in 0..ow {
            let mut acc = 0f32;
            for ky in 0..k {
                let iy = (oy * stride + ky) as isize - pad as isize;
                if iy < 0 || iy >= hi as isize {
                    continue;
                }
                for kx in 0..k {
                    let ix = (ox * stride + kx) as isize - pad as isize;
                    if ix < 0 || ix >= wi as isize {
                        continue;
                    }
                    acc += x[xbase + iy as usize * wi + ix as usize] * w[wrow + ky * k + kx];
                }
            }
            out[oy * ow + ox] = acc;
        }
    }
}

/// Accumulate one output channel of a standard conv into `out`.
#[allow(clippy::too_many_arguments)]
fn conv_one_channel(
    x: &[f32],
    b: usize,
    cin: usize,
    in_hw: (usize, usize),
    w: &[f32],
    co: usize,
    k: usize,
    stride: usize,
    pad: usize,
    oh: usize,
    ow: usize,
    out: &mut [f32],
) {
    let (hi, wi) = in_hw;
    let wbase = co * cin * k * k;
    for oy in 0..oh {
        for ox in 0..ow {
            let mut acc = 0f32;
            for ci in 0..cin {
                let xbase = (b * cin + ci) * hi * wi;
                let wrow = wbase + ci * k * k;
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= hi as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= wi as isize {
                            continue;
                        }
                        acc += x[xbase + iy as usize * wi + ix as usize]
                            * w[wrow + ky * k + kx];
                    }
                }
            }
            out[oy * ow + ox] = acc;
        }
    }
}
