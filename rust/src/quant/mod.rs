//! Quantization math (rust mirror of `python/compile/quantize.py`) and
//! the integer reference convolution used to cross-check deployments.
//!
//! The deploy path executes DIANA-format integer arithmetic: int8 weight
//! codes on the digital accelerator, ternary codes on the AIMC macro,
//! 8-/7-bit unsigned activation codes. `qconv2d` / `qfc` compute in i64
//! (exact), so they certify that the partitioned network the simulator
//! "runs" is numerically the network the JAX deploy graph evaluates.

pub mod gemm;
pub mod infer;
pub mod plan;
pub mod r#ref;
pub mod simd;

pub use infer::{calibrate_act_maxima, calibrate_act_maxima_params, QuantNet};
pub use plan::{ConvAlgo, KernelSpan, QuantPlan, Scratch};
pub use simd::{Isa, KernelBackend};

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::runtime::ArtifactMeta;
use crate::tensor::Tensor;

/// Name-indexed view over a flat parameter snapshot ("node/leaf" keys).
///
/// Both the planned engine ([`infer::QuantNet`]) and the reference
/// oracle ([`r#ref::RefNet`]) compile from one of these, so tests can
/// feed synthetic parameter sets without fabricating a full
/// [`ArtifactMeta`] (which needs the artifact JSON).
pub struct ParamSet<'a> {
    idx: BTreeMap<&'a str, usize>,
    values: &'a [Vec<f32>],
}

impl<'a> ParamSet<'a> {
    /// Build from parallel name/value slices (leaf order must match).
    pub fn new<I>(names: I, values: &'a [Vec<f32>]) -> Self
    where
        I: IntoIterator<Item = &'a str>,
    {
        ParamSet {
            idx: names.into_iter().enumerate().map(|(i, n)| (n, i)).collect(),
            values,
        }
    }

    /// View over an artifact snapshot (leaf order per `meta.params`).
    pub fn from_meta(meta: &'a ArtifactMeta, values: &'a [Vec<f32>]) -> Self {
        Self::new(meta.params.iter().map(|p| p.name.as_str()), values)
    }

    /// Look up the `node/leaf` parameter vector.
    pub fn get(&self, node: &str, leaf: &str) -> Result<&'a [f32]> {
        self.idx
            .get(format!("{node}/{leaf}").as_str())
            .map(|&i| self.values[i].as_slice())
            .ok_or_else(|| anyhow!("missing leaf {node}/{leaf}"))
    }
}

/// Deterministic synthetic parameter snapshot for a (graph, platform) —
/// test and bench support for machines without trained artifacts: small
/// random weights and plausible log-scales under the exact leaf layout
/// the engines expect (`node/{w,b,<scale leaves>,lsa}`). One weight
/// log-scale leaf is emitted per distinct accelerator precision, named
/// per the artifact contract (`ls8`, `lster`, `ls<bits>`).
pub fn synth_params_on(
    graph: &crate::model::Graph,
    platform: &crate::hw::Platform,
    seed: u64,
) -> (Vec<String>, Vec<Vec<f32>>) {
    use crate::model::Op;
    // scale leaves in accelerator order, deduplicated
    let mut leaves: Vec<String> = Vec::new();
    for a in &platform.accelerators {
        let l = a.scale_leaf();
        if !leaves.contains(&l) {
            leaves.push(l);
        }
    }
    let dw_leaf = platform.accelerators[platform.dw_acc].scale_leaf();
    let mut rng = crate::util::prng::Pcg32::new(seed, 17);
    let mut names: Vec<String> = Vec::new();
    let mut values: Vec<Vec<f32>> = Vec::new();
    for n in &graph.nodes {
        let mut push = |leaf: &str, v: Vec<f32>| {
            names.push(format!("{}/{leaf}", n.name));
            values.push(v);
        };
        match n.op {
            Op::Conv | Op::Fc => {
                let wlen = n.cout * n.cin * n.k * n.k;
                push("w", (0..wlen).map(|_| (rng.next_f32() - 0.5) * 0.6).collect());
                push("b", (0..n.cout).map(|_| (rng.next_f32() - 0.5) * 0.2).collect());
                for leaf in &leaves {
                    // ternary grids get the tighter range, like fold_bn
                    let lo = if leaf == "lster" { 0.15 } else { 0.25 };
                    push(leaf, vec![(lo + 0.2 * rng.next_f32()).ln()]);
                }
                push("lsa", vec![(1.0 + rng.next_f32()).ln()]);
            }
            Op::DwConv => {
                let wlen = n.cout * n.k * n.k;
                push("w", (0..wlen).map(|_| (rng.next_f32() - 0.5) * 0.6).collect());
                push("b", (0..n.cout).map(|_| (rng.next_f32() - 0.5) * 0.2).collect());
                push(&dw_leaf, vec![(0.25 + 0.2 * rng.next_f32()).ln()]);
                push("lsa", vec![(1.0 + rng.next_f32()).ln()]);
            }
            Op::Add => {
                push("lsa", vec![(1.0 + rng.next_f32()).ln()]);
            }
            _ => {}
        }
    }
    (names, values)
}

/// [`synth_params_on`] for the built-in DIANA platform (the historical
/// `node/{w,b,ls8,lster,lsa}` layout).
pub fn synth_params(graph: &crate::model::Graph, seed: u64) -> (Vec<String>, Vec<Vec<f32>>) {
    synth_params_on(graph, &crate::hw::Platform::diana(), seed)
}

/// Deterministic uniform-random channel mapping over `n_acc`
/// accelerators — the companion of [`synth_params_on`] for tests and
/// benches exercising mixed assignments.
pub fn synth_mapping_n(
    graph: &crate::model::Graph,
    n_acc: usize,
    seed: u64,
) -> crate::coordinator::Mapping {
    let mut rng = crate::util::prng::Pcg32::new(seed, 33);
    let mut m = crate::coordinator::Mapping::uniform(graph, 0);
    for n in graph.mappable() {
        let ids = (0..n.cout).map(|_| rng.below(n_acc as u32) as u8).collect();
        m.assign.insert(n.name.clone(), ids);
    }
    m
}

/// Deterministic ~50/50 DIG/AIMC channel mapping (DIANA convenience;
/// PRNG-stable with the pre-generalization generator).
pub fn synth_mapping(graph: &crate::model::Graph, seed: u64) -> crate::coordinator::Mapping {
    use crate::model::{AIMC, DIG};
    let mut rng = crate::util::prng::Pcg32::new(seed, 33);
    let mut m = crate::coordinator::Mapping::uniform(graph, DIG);
    for n in graph.mappable() {
        let ids = (0..n.cout)
            .map(|_| if rng.next_f32() < 0.5 { AIMC as u8 } else { DIG as u8 })
            .collect();
        m.assign.insert(n.name.clone(), ids);
    }
    m
}

/// Post-accumulation activation quantizer (8-bit digital / 7-bit AIMC
/// output grids) — shared by the planned engine and the reference
/// oracle so both paths stay bit-identical.
#[inline]
pub(crate) fn quant_act(v: f32, scale: f32, n_bits: u32) -> f32 {
    let levels = ((1u32 << n_bits) - 1) as f32;
    scale / levels * round_half_even(levels * (v / scale).clamp(0.0, 1.0))
}

/// Generic n-bit D/A input read: fixed [0, 1] range LSB truncation,
/// exactly as the deploy graph re-reads stored activations. On DIANA
/// the AIMC macro reads through a 7-bit D/A (`da_q(v, 7)`).
#[inline]
pub(crate) fn da_q(v: f32, bits: u32) -> f32 {
    let levels = ((1u32 << bits) - 1) as f32;
    round_half_even(v.clamp(0.0, 1.0) * levels) / levels
}

/// Round half to even — the rounding mode of `jnp.round` (and the XLA
/// round-nearest-even op the AOT graphs execute). Rust's `f32::round`
/// rounds half away from zero, which diverges on quantization grids
/// where exact .5 products occur; every quantizer here must match the
/// graphs bit-for-bit.
#[inline]
pub fn round_half_even(x: f32) -> f32 {
    let r = x.round();
    if (x - x.trunc()).abs() == 0.5 && r % 2.0 != 0.0 {
        r - x.signum()
    } else {
        r
    }
}

/// Symmetric fake-quantization, paper Eq. 5 with pre-normalization.
/// `scale` is e^s; n_bits=2 ternarizes, n_bits=8 is int8.
pub fn fake_quant(x: f32, scale: f32, n_bits: u32) -> f32 {
    let levels = ((1i64 << (n_bits - 1)) - 1) as f32;
    let v = (x / scale).clamp(-1.0, 1.0);
    scale / levels * round_half_even(levels * v)
}

/// Integer code of `fake_quant` (in [-L, L]); `q = code * scale / L`.
pub fn weight_code(x: f32, scale: f32, n_bits: u32) -> i8 {
    let levels = ((1i64 << (n_bits - 1)) - 1) as f32;
    let v = (x / scale).clamp(-1.0, 1.0);
    round_half_even(levels * v) as i8
}

/// Unsigned activation code on `n_bits` (post-ReLU tensors):
/// `code = round(L * clip(x / scale, 0, 1))`, L = 2^n - 1.
pub fn act_code(x: f32, scale: f32, n_bits: u32) -> u8 {
    let levels = ((1u32 << n_bits) - 1) as f32;
    let v = (x / scale).clamp(0.0, 1.0);
    round_half_even(levels * v) as u8
}

/// Dequantize an activation code.
pub fn act_decode(code: u8, scale: f32, n_bits: u32) -> f32 {
    let levels = ((1u32 << n_bits) - 1) as f32;
    scale / levels * code as f32
}

/// Quantize a whole weight tensor to codes, leading axis = out channel.
pub fn quantize_weights(w: &Tensor, scale: f32, n_bits: u32) -> Vec<i8> {
    w.data().iter().map(|&v| weight_code(v, scale, n_bits)).collect()
}

/// Per-tensor fake-quantized copy (float values on the grid).
pub fn fake_quant_tensor(w: &Tensor, scale: f32, n_bits: u32) -> Tensor {
    Tensor::from_vec(
        w.shape(),
        w.data().iter().map(|&v| fake_quant(v, scale, n_bits)).collect(),
    )
}

/// Exact integer conv2d over code tensors (NCHW x OIHW, i64 accum).
///
/// `x` codes are unsigned activations, `w` codes signed weights; output
/// is the raw integer accumulator per (n, co, oy, ox). The caller
/// rescales by `act_scale/act_L * w_scale/w_L` and adds the float bias.
#[allow(clippy::too_many_arguments)]
pub fn qconv2d(
    x: &[u8],
    xs: (usize, usize, usize, usize), // (N, C, H, W)
    w: &[i8],
    ws: (usize, usize, usize, usize), // (O, I, KH, KW)
    stride: usize,
    pad: usize,
) -> Vec<i64> {
    let (n, c, h, wd) = xs;
    let (o, i, kh, kw) = ws;
    assert_eq!(c, i, "cin mismatch");
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (wd + 2 * pad - kw) / stride + 1;
    let mut out = vec![0i64; n * o * oh * ow];
    for b in 0..n {
        for co in 0..o {
            let wbase = co * i * kh * kw;
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0i64;
                    for ci in 0..c {
                        let xbase = (b * c + ci) * h * wd;
                        let wrow = wbase + ci * kh * kw;
                        for ky in 0..kh {
                            let iy = (oy * stride + ky) as isize - pad as isize;
                            if iy < 0 || iy >= h as isize {
                                continue;
                            }
                            for kx in 0..kw {
                                let ix = (ox * stride + kx) as isize - pad as isize;
                                if ix < 0 || ix >= wd as isize {
                                    continue;
                                }
                                let xv = x[xbase + iy as usize * wd + ix as usize] as i64;
                                let wv = w[wrow + ky * kw + kx] as i64;
                                acc += xv * wv;
                            }
                        }
                    }
                    out[((b * o + co) * oh + oy) * ow + ox] = acc;
                }
            }
        }
    }
    out
}

/// Exact integer fully-connected: x (N, I) codes, w (O, I) codes.
pub fn qfc(x: &[u8], n: usize, i: usize, w: &[i8], o: usize) -> Vec<i64> {
    let mut out = vec![0i64; n * o];
    for b in 0..n {
        for co in 0..o {
            let mut acc = 0i64;
            for ci in 0..i {
                acc += x[b * i + ci] as i64 * w[co * i + ci] as i64;
            }
            out[b * o + co] = acc;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fake_quant_grid_int8() {
        let s = 0.7;
        for &x in &[-3.0f32, -0.5, 0.0, 0.31, 0.69, 2.0] {
            let q = fake_quant(x, s, 8);
            let code = q * 127.0 / s;
            assert!((code - code.round()).abs() < 1e-4, "x={x} q={q}");
            assert!(q.abs() <= s + 1e-6);
        }
    }

    #[test]
    fn ternary_three_values() {
        let s = 0.5;
        for x in (-20..=20).map(|i| i as f32 * 0.1) {
            let c = weight_code(x, s, 2);
            assert!((-1..=1).contains(&c), "x={x} c={c}");
            let q = fake_quant(x, s, 2);
            assert!((q - c as f32 * s).abs() < 1e-6);
        }
    }

    #[test]
    fn code_and_fake_quant_agree() {
        let s = 1.3;
        for i in -400..400 {
            let x = i as f32 * 0.01;
            let q = fake_quant(x, s, 8);
            let c = weight_code(x, s, 8);
            assert!((q - c as f32 * s / 127.0).abs() < 1e-5);
        }
    }

    #[test]
    fn act_code_range() {
        for n in [7u32, 8] {
            assert_eq!(act_code(-1.0, 1.0, n), 0);
            assert_eq!(act_code(2.0, 1.0, n), ((1u32 << n) - 1) as u8);
            let mid = act_code(0.5, 1.0, n);
            let dec = act_decode(mid, 1.0, n);
            assert!((dec - 0.5).abs() < 1.0 / (1 << n) as f32);
        }
    }

    #[test]
    fn qconv_identity_kernel() {
        // 1x1 kernel with weight code 1 and unit scales = passthrough
        let x: Vec<u8> = (0..9).map(|v| v as u8).collect();
        let w = vec![1i8];
        let out = qconv2d(&x, (1, 1, 3, 3), &w, (1, 1, 1, 1), 1, 0);
        assert_eq!(out, (0..9).map(|v| v as i64).collect::<Vec<_>>());
    }

    #[test]
    fn qconv_padding_and_stride() {
        // 3x3 ones kernel over 3x3 ones image, pad 1 stride 2: every
        // stride-2 tap sees a 2x2 valid corner window -> all outputs 4.
        let x = vec![1u8; 9];
        let w = vec![1i8; 9];
        let out = qconv2d(&x, (1, 1, 3, 3), &w, (1, 1, 3, 3), 2, 1);
        assert_eq!(out, vec![4i64; 4]);
    }

    #[test]
    fn qconv_center_full_window() {
        // stride 1 pad 1: the center tap of a 3x3 ones/ones conv is 9
        let x = vec![1u8; 9];
        let w = vec![1i8; 9];
        let out = qconv2d(&x, (1, 1, 3, 3), &w, (1, 1, 3, 3), 1, 1);
        assert_eq!(out[4], 9);
        assert_eq!(out[0], 4);
        assert_eq!(out[1], 6);
    }

    #[test]
    fn qfc_matches_manual() {
        let x = vec![1u8, 2, 3, 4, 5, 6]; // 2x3
        let w = vec![1i8, 0, -1, 2, 2, 2]; // 2x3
        let out = qfc(&x, 2, 3, &w, 2);
        assert_eq!(out, vec![1 - 3, 2 * (1 + 2 + 3), 4 - 6, 2 * 15]);
    }
}
