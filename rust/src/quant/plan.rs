//! Precompiled execution plan + buffer arena for the quantized
//! inference engine.
//!
//! [`QuantPlan::compile_quant`] resolves everything the naive
//! interpreter re-derived per `forward` into a one-time compile step:
//!
//!   * names -> indices: nodes execute over integer buffer ids, no
//!     string-keyed map lookups on the hot path;
//!   * weights packed per accelerator group (digital int8-grid rows,
//!     AIMC ternary-grid rows) so each sub-layer is one contiguous GEMM;
//!   * a liveness-scanned buffer arena: activation buffers are assigned
//!     by a linear scan over the DAG and recycled as soon as their last
//!     consumer has run (ping-pong along chains, an extra slot per live
//!     residual), so a [`Scratch`] reaches a fixed set of allocations
//!     after the first block and `forward` allocates nothing per node —
//!     the plan records per-buffer capacity classes and presizes every
//!     scratch vector up front, and [`Scratch::alloc_audit`] counts
//!     capacity growths so tests pin the steady state to zero;
//!   * D/A re-reads of an activation (the AIMC n-bit input truncation)
//!     are materialized at most once per tensor *per distinct D/A
//!     width* — platforms may carry several IMC macros with different
//!     `da_bits`; each width that some consumer actually reads gets its
//!     own arena view, and platforms with no D/A units (e.g. `gap9`)
//!     materialize none at all;
//!   * a [`KernelBackend`] resolved once to a concrete
//!     [`Isa`](super::simd::Isa) — every hot loop dispatches through
//!     `super::simd`, and the resolved ISA is folded into
//!     [`QuantPlan::cache_key`] so plan caches never mix backends;
//!   * a per-conv [`ConvAlgo`] chosen at compile time: 1x1 stride-1
//!     convs run the GEMM straight over the stored activation (the
//!     im2col panel would be a verbatim copy), and small 3x3 stride-1
//!     convs take a direct-convolution kernel that skips panel
//!     materialization entirely.
//!
//! Execution is bit-identical to the `quant::ref` oracle: every kernel
//! accumulates each output strictly in the oracle's reduction order
//! (see `quant::gemm` and `quant::simd`), and all element-wise
//! epilogues share the same helper functions.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::coordinator::Mapping;
use crate::hw::Platform;
use crate::model::{Graph, Op};
use crate::util::pool::ThreadPool;

use super::gemm::{im2col, transpose_into};
use super::simd::{self, Isa, KernelBackend};
use super::{fake_quant, ParamSet};

/// One packed run of output channels on a single accelerator.
pub(crate) struct Group {
    /// packed row -> output channel index (ascending)
    rows: Vec<usize>,
    /// rows.len() x kdim fake-quantized weights, row-major
    w: Vec<f32>,
    /// per packed row
    bias: Vec<f32>,
    /// index into the op's source-kind list (`ConvP::srcs` /
    /// `FcP::srcs`): which input view this group reads
    src: usize,
    /// output activation bits (per the accelerator spec)
    bits: u32,
}

/// Per-conv kernel algorithm, chosen once at compile time and recorded
/// in the plan ([`QuantPlan::conv_algos`] exposes the decisions).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConvAlgo {
    /// im2col panel + GEMM — the general path.
    Im2col,
    /// 1x1 stride-1 pad-0 conv: the im2col panel would be a verbatim
    /// copy of the stored activation, so the GEMM runs straight over
    /// the input slice. Bit-identical to [`ConvAlgo::Im2col`] by
    /// construction (same values in the same reduction order).
    Direct1x1,
    /// 3x3 stride-1 direct convolution that skips panel
    /// materialization. Taken when the input image stays cache-resident
    /// (`DIRECT_L1_ELEMS`); bit-identical to the im2col+GEMM result up
    /// to the sign of zero (an out-of-bounds tap skipped by the direct
    /// kernel is a `+0.0 * w` term in the panel path).
    Direct3x3,
}

/// Direct-3x3 eligibility cap: input images up to this many `f32`
/// elements (128 KiB) are treated as cache-resident. Below it the
/// direct kernel's overlapping re-reads hit L1/L2 and beat the im2col
/// panel's 9x memory blow-up; above it the panel's streaming access
/// pattern wins, so the plan falls back to [`ConvAlgo::Im2col`]. This
/// is the arithmetic-intensity heuristic recorded per op in the plan.
const DIRECT_L1_ELEMS: usize = 32 * 1024;

impl ConvAlgo {
    /// Short stable tag for traces and dashboards.
    pub fn name(&self) -> &'static str {
        match self {
            ConvAlgo::Im2col => "im2col",
            ConvAlgo::Direct1x1 => "direct1x1",
            ConvAlgo::Direct3x3 => "direct3x3",
        }
    }

    /// Plan-time choice for one conv. `force` (tests/benches) overrides
    /// the size heuristic but never geometry eligibility: forcing
    /// `Direct3x3` on a 5x5 conv still compiles the im2col path.
    fn choose(
        k: usize,
        stride: usize,
        pad: usize,
        cin: usize,
        hi: usize,
        wi: usize,
        force: Option<ConvAlgo>,
    ) -> ConvAlgo {
        let fits_1x1 = k == 1 && stride == 1 && pad == 0;
        let fits_3x3 = k == 3 && stride == 1;
        match force {
            Some(ConvAlgo::Direct1x1) if fits_1x1 => ConvAlgo::Direct1x1,
            Some(ConvAlgo::Direct3x3) if fits_3x3 => ConvAlgo::Direct3x3,
            Some(_) => ConvAlgo::Im2col,
            None if fits_1x1 => ConvAlgo::Direct1x1,
            None if fits_3x3 && cin * hi * wi <= DIRECT_L1_ELEMS => ConvAlgo::Direct3x3,
            None => ConvAlgo::Im2col,
        }
    }
}

pub(crate) struct ConvP {
    cin: usize,
    k: usize,
    stride: usize,
    pad: usize,
    hi: usize,
    wi: usize,
    oh: usize,
    ow: usize,
    cout: usize,
    relu: bool,
    /// <= 0.0 disables output quantization (float / calibration mode)
    act_scale: f32,
    /// input views the groups read: `None` = the stored activation,
    /// `Some(w)` = the w-bit D/A view (ascending widths after `None`)
    srcs: Vec<Option<u32>>,
    groups: Vec<Group>,
    /// kernel algorithm recorded at compile time
    algo: ConvAlgo,
}

pub(crate) struct FcP {
    cin: usize,
    cout: usize,
    /// see `ConvP::srcs`
    srcs: Vec<Option<u32>>,
    groups: Vec<Group>,
}

pub(crate) struct DwP {
    c: usize,
    k: usize,
    stride: usize,
    pad: usize,
    hi: usize,
    wi: usize,
    oh: usize,
    ow: usize,
    w: Vec<f32>,
    bias: Vec<f32>,
    relu: bool,
    act_scale: f32,
    /// output grid of the unit running depthwise convs
    obits: u32,
}

/// One traced plan-node execution (the obs layer's `Full`-level
/// per-op kernel span): what ran, which algorithm, and when — offsets
/// are nanoseconds from the start of the traced walk.
#[derive(Clone, Debug)]
pub struct KernelSpan {
    /// Plan node (layer) name.
    pub node: String,
    /// Op kind tag (`input`, `conv`, `fc`, `dw`, `add`, `gap`).
    pub kind: &'static str,
    /// Conv algorithm, for conv nodes.
    pub algo: Option<&'static str>,
    /// Start offset from the walk's begin, ns.
    pub start_ns: u64,
    /// Kernel wall time, ns.
    pub dur_ns: u64,
}

pub(crate) enum PlanOp {
    Input { quantize: bool },
    Conv(ConvP),
    Dw(DwP),
    Fc(FcP),
    Add { relu: bool, scale: f32, quantize: bool },
    Gap { c: usize, hw: usize },
}

pub(crate) struct PlanNode {
    pub(crate) name: String,
    pub(crate) op: PlanOp,
    /// arena buffer ids of the inputs (src[1] only used by Add)
    src: [usize; 2],
    dst: usize,
    /// conv/fc: arena ids of the *input* views, parallel to the op's
    /// `srcs` list (plain entries alias `src[0]`)
    src_views: Vec<usize>,
    /// D/A views of *this* node's output to materialize: one
    /// `(da_bits, arena id)` per distinct width some consumer reads
    da_out: Vec<(u32, usize)>,
    /// per-image output elements
    out_elems: usize,
    /// record the post-epilogue max (calibration)
    pub(crate) track_max: bool,
}

/// Per-thread scratch: the arena plus im2col/GEMM panels.
/// [`QuantPlan::presize`] grows every vector to the plan's recorded
/// capacity classes up front, so steady-state execution performs zero
/// heap allocations; [`Scratch::alloc_audit`] counts capacity growths
/// and the regression tests pin the steady-state delta to zero.
#[derive(Default)]
pub struct Scratch {
    bufs: Vec<Vec<f32>>,
    panel: Vec<f32>,
    cbuf: Vec<f32>,
    /// tiled mode: per-(image, view) im2col panels
    panels: Vec<f32>,
    /// tiled mode: per-job kernel scratch
    tiles: Vec<f32>,
    /// capacity growths since construction (see [`Self::alloc_audit`])
    audit: usize,
}

impl Scratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Heap allocations this scratch has performed: one count per
    /// vector growth past its capacity. Converges after the first block
    /// per (plan, batch, tiling) shape — repeat blocks add nothing, and
    /// a 3-batch run from fresh costs no more counts than a 1-batch
    /// run, because [`QuantPlan::presize`] reserves from compile-time
    /// capacity classes rather than growing on demand.
    pub fn alloc_audit(&self) -> usize {
        self.audit
    }

    /// Reset `buf` to `len` zeroed elements, counting a capacity growth
    /// in `audit` if the existing allocation cannot hold it (after
    /// presize that never fires — any hit is a missed capacity class).
    #[inline]
    pub(crate) fn ensure(buf: &mut Vec<f32>, len: usize, audit: &mut usize) {
        if buf.capacity() < len {
            *audit += 1;
        }
        buf.clear();
        buf.resize(len, 0.0);
    }
}

/// A compiled (graph, mapping, platform) ready to execute over an arena.
pub struct QuantPlan {
    nodes: Vec<PlanNode>,
    /// per-arena-buffer capacity class, in per-image elements
    buf_caps: Vec<usize>,
    in_elems: usize,
    out_elems: usize,
    /// concrete ISA every kernel dispatches to, resolved once at
    /// compile time from the requested [`KernelBackend`]
    isa: Isa,
}

impl QuantPlan {
    /// Compile the deploy-mode (quantized, mapped) plan for `platform`
    /// with the default ([`KernelBackend::Auto`]) kernel backend.
    pub fn compile_quant(
        params: &ParamSet<'_>,
        graph: &Graph,
        mapping: &Mapping,
        platform: &Platform,
    ) -> Result<Self> {
        Self::compile_quant_with(params, graph, mapping, platform, KernelBackend::Auto, None)
    }

    /// [`Self::compile_quant`] with an explicit kernel backend.
    pub fn compile_quant_backend(
        params: &ParamSet<'_>,
        graph: &Graph,
        mapping: &Mapping,
        platform: &Platform,
        backend: KernelBackend,
    ) -> Result<Self> {
        Self::compile_quant_with(params, graph, mapping, platform, backend, None)
    }

    /// Full-control compile: explicit kernel backend plus an optional
    /// [`ConvAlgo`] override. The override applies only where the
    /// geometry is eligible (see [`ConvAlgo`]); tests and benches use
    /// it to pin the im2col-vs-direct comparison through public API.
    pub fn compile_quant_with(
        params: &ParamSet<'_>,
        graph: &Graph,
        mapping: &Mapping,
        platform: &Platform,
        backend: KernelBackend,
        force_algo: Option<ConvAlgo>,
    ) -> Result<Self> {
        mapping.validate(graph, platform.n_acc())?;
        Self::compile(params, graph, Some((mapping, platform)), backend, force_algo)
    }

    /// Compile the float (quantization-free) plan — the calibration
    /// forward: raw weights, bias+ReLU epilogues, no grids anywhere.
    pub fn compile_float(params: &ParamSet<'_>, graph: &Graph) -> Result<Self> {
        Self::compile(params, graph, None, KernelBackend::Auto, None)
    }

    /// [`Self::compile_float`] with an explicit kernel backend.
    pub fn compile_float_backend(
        params: &ParamSet<'_>,
        graph: &Graph,
        backend: KernelBackend,
    ) -> Result<Self> {
        Self::compile(params, graph, None, backend, None)
    }

    fn compile(
        params: &ParamSet<'_>,
        graph: &Graph,
        mapping: Option<(&Mapping, &Platform)>,
        backend: KernelBackend,
        force_algo: Option<ConvAlgo>,
    ) -> Result<Self> {
        let n_nodes = graph.nodes.len();
        if n_nodes == 0 {
            return Err(anyhow!("empty graph"));
        }
        let node_idx = |name: &str| -> Result<usize> {
            graph
                .nodes
                .iter()
                .position(|n| n.name == name)
                .ok_or_else(|| anyhow!("unknown input tensor '{name}'"))
        };

        // ---- 1. lower each node to a PlanOp --------------------------
        let quant = mapping.is_some();
        let mut ops: Vec<PlanOp> = Vec::with_capacity(n_nodes);
        for n in &graph.nodes {
            let op = match n.op {
                Op::Input => PlanOp::Input { quantize: quant },
                Op::Conv | Op::Fc => {
                    let w = params.get(&n.name, "w")?;
                    let bias = params.get(&n.name, "b")?;
                    let act_scale =
                        if quant { params.get(&n.name, "lsa")?[0].exp() } else { 0.0 };
                    let per = w.len() / n.cout;
                    let (srcs, groups) = match mapping {
                        Some((m, platform)) => {
                            let assign = m.layer(&n.name);
                            // source-kind list: plain first (if any unit
                            // reads stored activations), then distinct
                            // D/A widths ascending
                            let mut srcs: Vec<Option<u32>> = Vec::new();
                            for spec in &platform.accelerators {
                                let kind = spec.da_bits;
                                if !srcs.contains(&kind) {
                                    srcs.push(kind);
                                }
                            }
                            srcs.sort(); // None sorts before Some, widths ascend
                            let mut gs = Vec::new();
                            for (acc, spec) in platform.accelerators.iter().enumerate() {
                                let rows: Vec<usize> = (0..n.cout)
                                    .filter(|&co| assign[co] as usize == acc)
                                    .collect();
                                if rows.is_empty() {
                                    continue;
                                }
                                let scale =
                                    params.get(&n.name, &spec.scale_leaf())?[0].exp();
                                let wbits = spec.weight_bits;
                                let wp: Vec<f32> = rows
                                    .iter()
                                    .flat_map(|&co| {
                                        w[co * per..(co + 1) * per]
                                            .iter()
                                            .map(move |&v| fake_quant(v, scale, wbits))
                                    })
                                    .collect();
                                let src = srcs
                                    .iter()
                                    .position(|&k| k == spec.da_bits)
                                    .expect("source kind registered above");
                                gs.push(Group {
                                    w: wp,
                                    bias: rows.iter().map(|&co| bias[co]).collect(),
                                    rows,
                                    src,
                                    bits: spec.act_bits,
                                });
                            }
                            // keep only the kinds some group actually
                            // reads (re-point group indices)
                            let used: Vec<Option<u32>> = srcs
                                .iter()
                                .copied()
                                .filter(|&k| {
                                    gs.iter().any(|g| srcs[g.src] == k)
                                })
                                .collect();
                            for g in &mut gs {
                                g.src = used
                                    .iter()
                                    .position(|&k| k == srcs[g.src])
                                    .expect("used kind present");
                            }
                            (used, gs)
                        }
                        None => (
                            vec![None],
                            vec![Group {
                                rows: (0..n.cout).collect(),
                                w: w.to_vec(),
                                bias: bias.to_vec(),
                                src: 0,
                                bits: 8,
                            }],
                        ),
                    };
                    if n.op == Op::Fc {
                        PlanOp::Fc(FcP { cin: n.cin, cout: n.cout, srcs, groups })
                    } else {
                        PlanOp::Conv(ConvP {
                            cin: n.cin,
                            k: n.k,
                            stride: n.stride,
                            pad: n.pad,
                            hi: n.in_hw.0,
                            wi: n.in_hw.1,
                            oh: n.out_hw.0,
                            ow: n.out_hw.1,
                            cout: n.cout,
                            relu: n.relu,
                            act_scale: if quant { act_scale } else { 0.0 },
                            srcs,
                            groups,
                            algo: ConvAlgo::choose(
                                n.k, n.stride, n.pad, n.cin, n.in_hw.0, n.in_hw.1,
                                force_algo,
                            ),
                        })
                    }
                }
                Op::DwConv => {
                    let w = params.get(&n.name, "w")?;
                    let weff = if let Some((_, platform)) = mapping {
                        let spec = &platform.accelerators[platform.dw_acc];
                        let s = params.get(&n.name, &spec.scale_leaf())?[0].exp();
                        w.iter().map(|&v| fake_quant(v, s, spec.weight_bits)).collect()
                    } else {
                        w.to_vec()
                    };
                    PlanOp::Dw(DwP {
                        c: n.cout,
                        k: n.k,
                        stride: n.stride,
                        pad: n.pad,
                        hi: n.in_hw.0,
                        wi: n.in_hw.1,
                        oh: n.out_hw.0,
                        ow: n.out_hw.1,
                        w: weff,
                        bias: params.get(&n.name, "b")?.to_vec(),
                        relu: n.relu,
                        act_scale: if quant {
                            params.get(&n.name, "lsa")?[0].exp()
                        } else {
                            0.0
                        },
                        obits: match mapping {
                            Some((_, platform)) => {
                                platform.accelerators[platform.dw_acc].act_bits
                            }
                            None => 8,
                        },
                    })
                }
                Op::Add => PlanOp::Add {
                    relu: n.relu,
                    scale: if quant { params.get(&n.name, "lsa")?[0].exp() } else { 1.0 },
                    quantize: quant,
                },
                Op::Gap => PlanOp::Gap { c: n.cin, hw: n.in_hw.0 * n.in_hw.1 },
            };
            ops.push(op);
        }

        // ---- 2. per-tensor use counts --------------------------------
        // plain_uses: consumers reading the stored activation;
        // da_uses: per D/A width, conv/fc consumers reading that view.
        fn view_kinds(op: &PlanOp, ii: usize) -> Option<&[Option<u32>]> {
            match op {
                PlanOp::Conv(cp) if ii == 0 => Some(&cp.srcs),
                PlanOp::Fc(fp) if ii == 0 => Some(&fp.srcs),
                _ => None,
            }
        }
        let mut plain_uses = vec![0usize; n_nodes];
        let mut da_uses: Vec<BTreeMap<u32, usize>> = vec![BTreeMap::new(); n_nodes];
        for (i, n) in graph.nodes.iter().enumerate() {
            for (ii, inp) in n.inputs.iter().enumerate() {
                let t = node_idx(inp)?;
                match view_kinds(&ops[i], ii) {
                    Some(kinds) => {
                        for k in kinds {
                            match k {
                                None => plain_uses[t] += 1,
                                Some(w) => *da_uses[t].entry(*w).or_insert(0) += 1,
                            }
                        }
                    }
                    None => plain_uses[t] += 1,
                }
            }
        }
        plain_uses[n_nodes - 1] += 1; // keep the logits buffer alive
        for i in 0..n_nodes {
            // materializing each D/A view reads the plain buffer once at
            // the producer itself — without this use a tensor consumed
            // only through D/A views would never be recycled
            plain_uses[i] += da_uses[i].len();
        }

        // ---- 3. linear-scan arena assignment -------------------------
        let mut buf_cap: Vec<usize> = Vec::new(); // capacity class per buffer
        let mut remaining: Vec<usize> = Vec::new();
        let mut free: Vec<usize> = Vec::new();
        fn grab(
            need: usize,
            uses: usize,
            buf_cap: &mut Vec<usize>,
            remaining: &mut Vec<usize>,
            free: &mut Vec<usize>,
        ) -> usize {
            // best fit >= need, else grow the largest free slot
            let mut best: Option<usize> = None;
            for (fi, &id) in free.iter().enumerate() {
                if buf_cap[id] >= need {
                    match best {
                        Some(b) if buf_cap[free[b]] <= buf_cap[id] => {}
                        _ => best = Some(fi),
                    }
                }
            }
            if best.is_none() && !free.is_empty() {
                let mut big = 0;
                for (fi, &id) in free.iter().enumerate() {
                    if buf_cap[id] > buf_cap[free[big]] {
                        big = fi;
                    }
                }
                best = Some(big);
            }
            let id = match best {
                Some(fi) => {
                    let id = free.swap_remove(fi);
                    buf_cap[id] = buf_cap[id].max(need);
                    id
                }
                None => {
                    buf_cap.push(need);
                    remaining.push(0);
                    buf_cap.len() - 1
                }
            };
            remaining[id] = uses;
            id
        }

        let mut tensor_buf = vec![usize::MAX; n_nodes];
        let mut tensor_da: Vec<BTreeMap<u32, usize>> = vec![BTreeMap::new(); n_nodes];
        let mut nodes: Vec<PlanNode> = Vec::with_capacity(n_nodes);
        for (i, (n, op)) in graph.nodes.iter().zip(ops.into_iter()).enumerate() {
            let out_elems = match &op {
                PlanOp::Input { .. } => n.cout * n.out_hw.0 * n.out_hw.1,
                PlanOp::Conv(cp) => cp.cout * cp.oh * cp.ow,
                PlanOp::Dw(dp) => dp.c * dp.oh * dp.ow,
                PlanOp::Fc(fp) => fp.cout,
                PlanOp::Add { .. } | PlanOp::Gap { .. } => {
                    n.cout * n.out_hw.0 * n.out_hw.1
                }
            };
            let dst = grab(out_elems, plain_uses[i], &mut buf_cap, &mut remaining, &mut free);
            tensor_buf[i] = dst;
            let mut da_out: Vec<(u32, usize)> = Vec::with_capacity(da_uses[i].len());
            for (&w, &uses) in &da_uses[i] {
                let id = grab(out_elems, uses, &mut buf_cap, &mut remaining, &mut free);
                tensor_da[i].insert(w, id);
                da_out.push((w, id));
                // retire the materialization read of dst (it happens at
                // this node, right after dst is produced)
                remaining[dst] -= 1;
                if remaining[dst] == 0 {
                    free.push(dst);
                }
            }

            // resolve inputs, then release them (after dst/views are
            // held, so a freed input can never alias this node's outputs)
            let mut src = [usize::MAX; 2];
            let mut src_views: Vec<usize> = Vec::new();
            for (ii, inp) in n.inputs.iter().enumerate().take(2) {
                let t = node_idx(inp)?;
                src[ii] = tensor_buf[t];
                match view_kinds(&op, ii) {
                    Some(kinds) => {
                        for k in kinds {
                            let id = match k {
                                None => src[ii],
                                Some(w) => *tensor_da[t].get(w).ok_or_else(|| {
                                    anyhow!("internal: no {w}-bit D/A view for '{inp}'")
                                })?,
                            };
                            src_views.push(id);
                            remaining[id] -= 1;
                            if remaining[id] == 0 {
                                free.push(id);
                            }
                        }
                    }
                    None => {
                        remaining[src[ii]] -= 1;
                        if remaining[src[ii]] == 0 {
                            free.push(src[ii]);
                        }
                    }
                }
            }

            let track_max = matches!(n.op, Op::Conv | Op::DwConv | Op::Add);
            nodes.push(PlanNode {
                name: n.name.clone(),
                op,
                src,
                dst,
                src_views,
                da_out,
                out_elems,
                track_max,
            });
        }

        let (c0, h0, w0) = graph.input_shape;
        Ok(QuantPlan {
            out_elems: nodes.last().unwrap().out_elems,
            in_elems: c0 * h0 * w0,
            isa: backend.resolve(),
            buf_caps: buf_cap,
            nodes,
        })
    }

    /// Stable cache key for a compiled (model, platform, mapping,
    /// backend) tuple — the plan-cache handle: everything that changes
    /// the compiled plan's packed weights, arena layout, or kernel
    /// dispatch is folded in (FNV-1a over the model name *and* its
    /// [`Graph::spec_hash`](crate::model::Graph::spec_hash), the
    /// platform name, the *resolved* kernel ISA, and every per-layer
    /// channel assignment). The structural hash matters for imported
    /// graphs: an edited graph file keeps its model name, and without
    /// it a long-lived cache would replay plans compiled for the old
    /// structure. Folding the resolved [`Isa`] rather than the
    /// requested [`KernelBackend`] means `Auto` shares a key with
    /// whatever it resolves to on this host — the compiled plans are
    /// identical — while scalar- and SIMD-compiled plans never collide.
    /// The serve-side LRU plan cache
    /// ([`crate::serve::batcher::PlanCache`]) uses this as its fast
    /// lookup filter — verifying the stored mapping on every hit, since
    /// a 64-bit hash alone cannot guarantee identity — so repeat
    /// requests for the same mapping reuse one compiled plan.
    pub fn cache_key(
        model: &str,
        model_hash: u64,
        platform: &str,
        mapping: &Mapping,
        backend: KernelBackend,
    ) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = FNV_OFFSET;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(FNV_PRIME);
            }
        };
        eat(model.as_bytes());
        eat(&[0xff]);
        eat(&model_hash.to_le_bytes());
        eat(&[0xff]);
        eat(platform.as_bytes());
        eat(&[0xff]);
        eat(&[backend.resolve().code()]);
        eat(&[0xff]);
        for (name, ids) in &mapping.assign {
            eat(name.as_bytes());
            eat(&[0xff]);
            eat(ids);
        }
        h
    }

    pub fn in_elems(&self) -> usize {
        self.in_elems
    }

    pub fn out_elems(&self) -> usize {
        self.out_elems
    }

    /// Number of distinct arena buffers (tests: should be far below the
    /// node count on deep graphs).
    pub fn arena_buffers(&self) -> usize {
        self.buf_caps.len()
    }

    /// The concrete ISA this plan's kernels dispatch to.
    pub fn isa(&self) -> Isa {
        self.isa
    }

    /// Per-conv algorithm decisions recorded at compile time, in graph
    /// order: `(layer name, algo)`.
    pub fn conv_algos(&self) -> Vec<(String, ConvAlgo)> {
        self.nodes
            .iter()
            .filter_map(|n| match &n.op {
                PlanOp::Conv(cp) => Some((n.name.clone(), cp.algo)),
                _ => None,
            })
            .collect()
    }

    /// Grow every scratch vector to this plan's steady-state capacity
    /// in one planned step: arena buffers from the compile-time
    /// capacity classes scaled by `batch`, panels/tiles from a walk
    /// over the plan's ops (`jobs_target = Some(_)` sizes the tiled
    /// path's per-job scratch as well). After presize the hot loop's
    /// [`Scratch::ensure`] calls never grow — audited growths here are
    /// the *first* sizing per (plan, batch, tiling) shape only.
    ///
    /// The logits buffer (the last node's `dst`) is excluded from the
    /// audit: `run_block` hands it to the caller by move, so its
    /// re-reservation on the next block is planned output traffic, not
    /// scratch churn.
    fn presize(&self, ws: &mut Scratch, batch: usize, jobs_target: Option<usize>) {
        let Scratch { bufs, panel, cbuf, panels, tiles, audit } = ws;
        if bufs.len() < self.buf_caps.len() {
            bufs.resize_with(self.buf_caps.len(), Vec::new);
        }
        let out_buf = self.nodes.last().map(|n| n.dst).unwrap_or(usize::MAX);
        for (id, (buf, &cap)) in bufs.iter_mut().zip(&self.buf_caps).enumerate() {
            let need = cap * batch;
            if buf.capacity() < need {
                if id != out_buf {
                    *audit += 1;
                }
                buf.reserve_exact(need - buf.len());
            }
        }
        let (mut p, mut cb, mut pp, mut tt) = (0usize, 0usize, 0usize, 0usize);
        for node in &self.nodes {
            match &node.op {
                PlanOp::Conv(cp) => {
                    let n = cp.oh * cp.ow;
                    let kdim = cp.cin * cp.k * cp.k;
                    let rows = cp.groups.iter().map(|g| g.rows.len()).max().unwrap_or(0);
                    match jobs_target {
                        None => {
                            if cp.algo == ConvAlgo::Im2col {
                                p = p.max(kdim * n);
                            }
                            cb = cb.max(rows * n);
                        }
                        Some(jt) => {
                            if cp.algo == ConvAlgo::Im2col {
                                pp = pp.max(batch * cp.srcs.len() * kdim * n);
                            }
                            let (cc, n_jobs) = conv_tile_shape(cp.cout, batch, jt);
                            tt = tt.max(n_jobs * cc * n);
                        }
                    }
                }
                PlanOp::Fc(fp) => {
                    let rows = fp.groups.iter().map(|g| g.rows.len()).max().unwrap_or(0);
                    p = p.max(fp.cin * batch);
                    cb = cb.max(rows * batch);
                }
                _ => {}
            }
        }
        for (buf, need) in [(panel, p), (cbuf, cb), (panels, pp), (tiles, tt)] {
            if buf.capacity() < need {
                *audit += 1;
                buf.reserve_exact(need - buf.len());
            }
        }
    }

    pub(crate) fn node_names(&self) -> impl Iterator<Item = (usize, &str, bool)> {
        self.nodes
            .iter()
            .enumerate()
            .map(|(i, n)| (i, n.name.as_str(), n.track_max))
    }

    pub(crate) fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Materialize the D/A views of a just-produced activation: one
    /// width-truncated copy per distinct `da_bits` consumers read.
    fn materialize_da(
        node: &PlanNode,
        dst: &[f32],
        bufs: &mut [Vec<f32>],
        audit: &mut usize,
        isa: Isa,
    ) {
        for &(w, id) in &node.da_out {
            let mut view = std::mem::take(&mut bufs[id]);
            Scratch::ensure(&mut view, dst.len(), audit);
            simd::da_q_into(isa, dst, w, &mut view);
            bufs[id] = view;
        }
    }

    /// Execute one node's kernel into `dst` — the single body shared by
    /// [`Self::run_block`] and the traced walk
    /// ([`Self::run_block_traced`]), so traced numerics are identical
    /// by construction.
    fn exec_node(
        &self,
        node: &PlanNode,
        x: &[f32],
        batch: usize,
        ws: &mut Scratch,
        dst: &mut Vec<f32>,
    ) {
        let isa = self.isa;
        match &node.op {
            PlanOp::Input { quantize } => {
                if *quantize {
                    simd::input_quant(isa, x, dst);
                } else {
                    dst.copy_from_slice(x);
                }
            }
            PlanOp::Conv(cp) => {
                exec_conv(
                    cp,
                    &ws.bufs,
                    &node.src_views,
                    batch,
                    &mut ws.panel,
                    &mut ws.cbuf,
                    &mut ws.audit,
                    isa,
                    dst,
                );
            }
            PlanOp::Fc(fp) => {
                exec_fc(
                    fp,
                    &ws.bufs,
                    &node.src_views,
                    batch,
                    &mut ws.panel,
                    &mut ws.cbuf,
                    &mut ws.audit,
                    isa,
                    dst,
                );
            }
            PlanOp::Dw(dp) => {
                let src = ws.bufs[node.src[0]].as_slice();
                exec_dw(dp, src, batch, 0, dp.c, isa, dst);
            }
            PlanOp::Add { relu, scale, quantize } => {
                let a = ws.bufs[node.src[0]].as_slice();
                let b = ws.bufs[node.src[1]].as_slice();
                simd::add_relu_quant(isa, a, b, *relu, *scale, *quantize, dst);
            }
            PlanOp::Gap { c, hw } => {
                let src = ws.bufs[node.src[0]].as_slice();
                exec_gap(src, batch, *c, *hw, dst);
            }
        }
    }

    /// Execute one batch block single-threaded. Returns the logits
    /// buffer *by move* out of the arena (no final clone). When
    /// `maxima` is given (len >= n_nodes), per-node post-epilogue
    /// maxima are folded into it.
    pub(crate) fn run_block(
        &self,
        x: &[f32],
        batch: usize,
        ws: &mut Scratch,
        mut maxima: Option<&mut [f32]>,
    ) -> Vec<f32> {
        assert_eq!(x.len(), batch * self.in_elems, "input size");
        self.presize(ws, batch, None);
        let isa = self.isa;
        for (ni, node) in self.nodes.iter().enumerate() {
            let mut dst = std::mem::take(&mut ws.bufs[node.dst]);
            Scratch::ensure(&mut dst, node.out_elems * batch, &mut ws.audit);
            self.exec_node(node, x, batch, ws, &mut dst);
            if let Some(m) = maxima.as_deref_mut() {
                if node.track_max {
                    m[ni] = dst.iter().fold(m[ni], |acc, &v| acc.max(v));
                }
            }
            Self::materialize_da(node, &dst, &mut ws.bufs, &mut ws.audit, isa);
            ws.bufs[node.dst] = dst;
        }
        std::mem::take(&mut ws.bufs[self.nodes.last().unwrap().dst])
    }

    /// [`Self::run_block`] with per-node wall timing — the obs layer's
    /// `Full`-level engine path. Numerics are identical by construction
    /// (same [`Self::exec_node`] body, same single-threaded walk); only
    /// the wall-clock spans differ run to run, and those live on the
    /// wall domain, which is excluded from every determinism digest.
    pub(crate) fn run_block_traced(
        &self,
        x: &[f32],
        batch: usize,
        ws: &mut Scratch,
    ) -> (Vec<f32>, Vec<KernelSpan>) {
        assert_eq!(x.len(), batch * self.in_elems, "input size");
        self.presize(ws, batch, None);
        let isa = self.isa;
        let epoch = std::time::Instant::now();
        let mut spans = Vec::with_capacity(self.nodes.len());
        for node in self.nodes.iter() {
            let t0 = epoch.elapsed().as_nanos() as u64;
            let mut dst = std::mem::take(&mut ws.bufs[node.dst]);
            Scratch::ensure(&mut dst, node.out_elems * batch, &mut ws.audit);
            self.exec_node(node, x, batch, ws, &mut dst);
            Self::materialize_da(node, &dst, &mut ws.bufs, &mut ws.audit, isa);
            ws.bufs[node.dst] = dst;
            let (kind, algo) = match &node.op {
                PlanOp::Input { .. } => ("input", None),
                PlanOp::Conv(cp) => ("conv", Some(cp.algo.name())),
                PlanOp::Fc(_) => ("fc", None),
                PlanOp::Dw(_) => ("dw", None),
                PlanOp::Add { .. } => ("add", None),
                PlanOp::Gap { .. } => ("gap", None),
            };
            spans.push(KernelSpan {
                node: node.name.clone(),
                kind,
                algo,
                start_ns: t0,
                dur_ns: (epoch.elapsed().as_nanos() as u64).saturating_sub(t0),
            });
        }
        (std::mem::take(&mut ws.bufs[self.nodes.last().unwrap().dst]), spans)
    }

    /// Execute one block with per-layer (image x output-channel-block)
    /// tiling over the pool — the small-batch parallel path. Numerics
    /// are identical to `run_block` at any thread count.
    pub(crate) fn run_block_tiled(
        &self,
        x: &[f32],
        batch: usize,
        ws: &mut Scratch,
        pool: &ThreadPool,
    ) -> Vec<f32> {
        assert_eq!(x.len(), batch * self.in_elems, "input size");
        let jobs_target = pool.threads().max(1) * 2;
        self.presize(ws, batch, Some(jobs_target));
        let isa = self.isa;
        for node in self.nodes.iter() {
            let mut dst = std::mem::take(&mut ws.bufs[node.dst]);
            Scratch::ensure(&mut dst, node.out_elems * batch, &mut ws.audit);
            match &node.op {
                PlanOp::Input { quantize } => {
                    if *quantize {
                        simd::input_quant(isa, x, &mut dst);
                    } else {
                        dst.copy_from_slice(x);
                    }
                }
                PlanOp::Conv(cp) => {
                    let n = cp.oh * cp.ow;
                    let kdim = cp.cin * cp.k * cp.k;
                    let in_elems = cp.cin * cp.hi * cp.wi;
                    let nsrc = cp.srcs.len();
                    let bufs = &ws.bufs;
                    let src_views = node.src_views.as_slice();
                    // phase 1: parallel im2col, one panel per (image,
                    // view) — the direct algorithms read the stored
                    // activation in place and skip it entirely
                    if cp.algo == ConvAlgo::Im2col {
                        Scratch::ensure(
                            &mut ws.panels, batch * nsrc * kdim * n, &mut ws.audit,
                        );
                        let items: Vec<(usize, &mut [f32])> =
                            ws.panels.chunks_mut(kdim * n).enumerate().collect();
                        pool.scoped_map(items, |(ci, chunk)| {
                            let b = ci / nsrc;
                            let s = bufs[src_views[ci % nsrc]].as_slice();
                            im2col(
                                &s[b * in_elems..(b + 1) * in_elems],
                                cp.cin, cp.hi, cp.wi, cp.k, cp.stride, cp.pad,
                                cp.oh, cp.ow, chunk,
                            );
                        });
                    }
                    // phase 2: parallel kernel + epilogue over channel
                    // blocks
                    let (cc, n_jobs) = conv_tile_shape(cp.cout, batch, jobs_target);
                    Scratch::ensure(&mut ws.tiles, n_jobs * cc * n, &mut ws.audit);
                    let panels = ws.panels.as_slice();
                    let mut items: Vec<(usize, usize, &mut [f32], &mut [f32])> =
                        Vec::with_capacity(n_jobs);
                    {
                        let mut scratch_it = ws.tiles.chunks_mut(cc * n);
                        for (b, img) in dst.chunks_mut(cp.cout * n).enumerate() {
                            for (cb, chunk) in img.chunks_mut(cc * n).enumerate() {
                                items.push((
                                    b,
                                    cb * cc,
                                    chunk,
                                    scratch_it.next().expect("tile scratch underrun"),
                                ));
                            }
                        }
                    }
                    pool.scoped_map(items, |(b, co0, chunk, scratch)| {
                        let co1 = (co0 + cc).min(cp.cout);
                        for g in &cp.groups {
                            let r0 = g.rows.partition_point(|&c| c < co0);
                            let r1 = g.rows.partition_point(|&c| c < co1);
                            if r1 == r0 {
                                continue;
                            }
                            let m = r1 - r0;
                            let gw = &g.w[r0 * kdim..r1 * kdim];
                            let out = &mut scratch[..m * n];
                            match cp.algo {
                                ConvAlgo::Im2col => {
                                    let panel = &panels
                                        [(b * nsrc + g.src) * kdim * n
                                            ..(b * nsrc + g.src + 1) * kdim * n];
                                    simd::gemm(isa, gw, panel, m, kdim, n, out);
                                }
                                ConvAlgo::Direct1x1 => {
                                    let s = bufs[src_views[g.src]].as_slice();
                                    let img = &s[b * in_elems..(b + 1) * in_elems];
                                    simd::gemm(isa, gw, img, m, kdim, n, out);
                                }
                                ConvAlgo::Direct3x3 => {
                                    let s = bufs[src_views[g.src]].as_slice();
                                    let img = &s[b * in_elems..(b + 1) * in_elems];
                                    simd::conv3x3(
                                        isa, img, cp.cin, cp.hi, cp.wi, gw, m,
                                        cp.pad, cp.oh, cp.ow, out,
                                    );
                                }
                            }
                            for r in 0..m {
                                let co = g.rows[r0 + r];
                                let drow = &mut chunk[(co - co0) * n..(co - co0 + 1) * n];
                                drow.copy_from_slice(&scratch[r * n..(r + 1) * n]);
                                simd::epilogue(
                                    isa,
                                    drow,
                                    g.bias[r0 + r],
                                    cp.relu,
                                    cp.act_scale,
                                    g.bits,
                                );
                            }
                        }
                    });
                }
                PlanOp::Fc(fp) => {
                    exec_fc(
                        fp,
                        &ws.bufs,
                        &node.src_views,
                        batch,
                        &mut ws.panel,
                        &mut ws.cbuf,
                        &mut ws.audit,
                        isa,
                        &mut dst,
                    );
                }
                PlanOp::Dw(dp) => {
                    let src = ws.bufs[node.src[0]].as_slice();
                    let n = dp.oh * dp.ow;
                    let (cc, n_jobs) = conv_tile_shape(dp.c, batch, jobs_target);
                    let mut items: Vec<(usize, usize, &mut [f32])> =
                        Vec::with_capacity(n_jobs);
                    for (b, img) in dst.chunks_mut(dp.c * n).enumerate() {
                        for (cb, chunk) in img.chunks_mut(cc * n).enumerate() {
                            items.push((b, cb * cc, chunk));
                        }
                    }
                    pool.scoped_map(items, |(b, c0, chunk)| {
                        let c1 = (c0 + cc).min(dp.c);
                        for (j, ch) in (c0..c1).enumerate() {
                            dw_channel(dp, src, b, ch, isa, &mut chunk[j * n..(j + 1) * n]);
                        }
                    });
                }
                PlanOp::Add { relu, scale, quantize } => {
                    let a = ws.bufs[node.src[0]].as_slice();
                    let b = ws.bufs[node.src[1]].as_slice();
                    simd::add_relu_quant(isa, a, b, *relu, *scale, *quantize, &mut dst);
                }
                PlanOp::Gap { c, hw } => {
                    let src = ws.bufs[node.src[0]].as_slice();
                    exec_gap(src, batch, *c, *hw, &mut dst);
                }
            }
            Self::materialize_da(node, &dst, &mut ws.bufs, &mut ws.audit, isa);
            ws.bufs[node.dst] = dst;
        }
        std::mem::take(&mut ws.bufs[self.nodes.last().unwrap().dst])
    }
}

/// Shared (exec, presize) tiling geometry for the pooled conv/dw path:
/// channel-block size and total job count for `cout` channels over
/// `batch` images aiming at `jobs_target` jobs.
#[inline]
fn conv_tile_shape(cout: usize, batch: usize, jobs_target: usize) -> (usize, usize) {
    let per_image = (jobs_target / batch.max(1)).max(1);
    let cc = ((cout + per_image - 1) / per_image).max(1);
    let n_jobs = batch * ((cout + cc - 1) / cc);
    (cc, n_jobs)
}

#[allow(clippy::too_many_arguments)]
fn exec_conv(
    cp: &ConvP,
    bufs: &[Vec<f32>],
    src_views: &[usize],
    batch: usize,
    panel: &mut Vec<f32>,
    cbuf: &mut Vec<f32>,
    audit: &mut usize,
    isa: Isa,
    dst: &mut [f32],
) {
    let n = cp.oh * cp.ow;
    let kdim = cp.cin * cp.k * cp.k;
    let in_elems = cp.cin * cp.hi * cp.wi;
    if cp.algo == ConvAlgo::Im2col {
        Scratch::ensure(panel, kdim * n, audit);
    }
    for b in 0..batch {
        // one im2col per (image, view): groups sharing a view (e.g. two
        // plain-reading units) reuse the panel
        for si in 0..cp.srcs.len() {
            let s = bufs[src_views[si]].as_slice();
            let img = &s[b * in_elems..(b + 1) * in_elems];
            if cp.algo == ConvAlgo::Im2col {
                im2col(
                    img, cp.cin, cp.hi, cp.wi, cp.k, cp.stride, cp.pad, cp.oh,
                    cp.ow, panel,
                );
            }
            for g in cp.groups.iter().filter(|g| g.src == si) {
                let m = g.rows.len();
                Scratch::ensure(cbuf, m * n, audit);
                match cp.algo {
                    ConvAlgo::Im2col => simd::gemm(isa, &g.w, panel, m, kdim, n, cbuf),
                    // the im2col panel would be a verbatim copy of the
                    // image, so the GEMM reads the activation directly
                    ConvAlgo::Direct1x1 => simd::gemm(isa, &g.w, img, m, kdim, n, cbuf),
                    ConvAlgo::Direct3x3 => simd::conv3x3(
                        isa, img, cp.cin, cp.hi, cp.wi, &g.w, m, cp.pad, cp.oh,
                        cp.ow, cbuf,
                    ),
                }
                for (r, &co) in g.rows.iter().enumerate() {
                    let drow =
                        &mut dst[(b * cp.cout + co) * n..(b * cp.cout + co + 1) * n];
                    drow.copy_from_slice(&cbuf[r * n..(r + 1) * n]);
                    simd::epilogue(isa, drow, g.bias[r], cp.relu, cp.act_scale, g.bits);
                }
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn exec_fc(
    fp: &FcP,
    bufs: &[Vec<f32>],
    src_views: &[usize],
    batch: usize,
    panel: &mut Vec<f32>,
    cbuf: &mut Vec<f32>,
    audit: &mut usize,
    isa: Isa,
    dst: &mut [f32],
) {
    Scratch::ensure(panel, fp.cin * batch, audit);
    // one transpose per view; groups sharing a view reuse the panel
    for si in 0..fp.srcs.len() {
        let s = bufs[src_views[si]].as_slice();
        transpose_into(s, batch, fp.cin, panel);
        for g in fp.groups.iter().filter(|g| g.src == si) {
            let m = g.rows.len();
            Scratch::ensure(cbuf, m * batch, audit);
            simd::gemm(isa, &g.w, panel, m, fp.cin, batch, cbuf);
            for (r, &co) in g.rows.iter().enumerate() {
                for b in 0..batch {
                    // logits stay float (no relu / no output grid)
                    dst[b * fp.cout + co] = cbuf[r * batch + b] + g.bias[r];
                }
            }
        }
    }
}

#[inline]
fn dw_channel(dp: &DwP, src: &[f32], b: usize, ch: usize, isa: Isa, drow: &mut [f32]) {
    let ie = dp.hi * dp.wi;
    let xs = &src[(b * dp.c + ch) * ie..(b * dp.c + ch + 1) * ie];
    simd::dwconv(
        isa, xs, dp.hi, dp.wi, &dp.w[ch * dp.k * dp.k..(ch + 1) * dp.k * dp.k],
        dp.k, dp.stride, dp.pad, dp.oh, dp.ow, drow,
    );
    simd::epilogue(isa, drow, dp.bias[ch], dp.relu, dp.act_scale, dp.obits);
}

fn exec_dw(
    dp: &DwP,
    src: &[f32],
    batch: usize,
    c0: usize,
    c1: usize,
    isa: Isa,
    dst: &mut [f32],
) {
    let n = dp.oh * dp.ow;
    for b in 0..batch {
        for ch in c0..c1 {
            let drow = &mut dst[(b * dp.c + ch) * n..(b * dp.c + ch + 1) * n];
            dw_channel(dp, src, b, ch, isa, drow);
        }
    }
}

fn exec_gap(src: &[f32], batch: usize, c: usize, hw: usize, dst: &mut [f32]) {
    for b in 0..batch {
        for ch in 0..c {
            let base = (b * c + ch) * hw;
            dst[b * c + ch] = src[base..base + hw].iter().sum::<f32>() / hw as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{tinycnn, DIG};
    use crate::quant::synth_mapping_n;

    #[test]
    fn cache_key_separates_inputs() {
        let g = tinycnn();
        let uniform = Mapping::uniform(&g, DIG);
        let mixed = synth_mapping_n(&g, 2, 3);
        let k = |model: &str, plat: &str, m: &Mapping| {
            QuantPlan::cache_key(model, g.spec_hash(), plat, m, KernelBackend::Scalar)
        };
        // identical inputs -> identical keys (the cache-hit contract)
        assert_eq!(k("tinycnn", "diana", &uniform), k("tinycnn", "diana", &uniform));
        // any coordinate change -> a different key
        assert_ne!(k("tinycnn", "diana", &uniform), k("tinycnn", "diana", &mixed));
        assert_ne!(k("tinycnn", "diana", &uniform), k("resnet20", "diana", &uniform));
        assert_ne!(k("tinycnn", "diana", &uniform), k("tinycnn", "mpsoc4", &uniform));
        // the structural hash is part of the key too: an edited graph
        // file keeps its model name, and must still miss
        assert_ne!(
            QuantPlan::cache_key(
                "tinycnn",
                g.spec_hash() ^ 1,
                "diana",
                &uniform,
                KernelBackend::Scalar,
            ),
            k("tinycnn", "diana", &uniform),
        );
        // backend is part of the key: Simd resolves to a non-scalar ISA
        // (a vector unit or the portable chunked fallback), so scalar-
        // and SIMD-compiled plans can never collide in a cache
        assert_ne!(
            QuantPlan::cache_key("tinycnn", g.spec_hash(), "diana", &uniform, KernelBackend::Scalar),
            QuantPlan::cache_key("tinycnn", g.spec_hash(), "diana", &uniform, KernelBackend::Simd),
        );
    }

    #[test]
    fn conv_algo_choice_respects_geometry() {
        // heuristic picks
        assert_eq!(ConvAlgo::choose(1, 1, 0, 16, 8, 8, None), ConvAlgo::Direct1x1);
        assert_eq!(ConvAlgo::choose(3, 1, 1, 16, 8, 8, None), ConvAlgo::Direct3x3);
        assert_eq!(ConvAlgo::choose(3, 2, 1, 16, 8, 8, None), ConvAlgo::Im2col);
        assert_eq!(ConvAlgo::choose(5, 1, 2, 16, 8, 8, None), ConvAlgo::Im2col);
        // above the cache-residency cap the 3x3 path falls back
        assert_eq!(ConvAlgo::choose(3, 1, 1, 64, 64, 64, None), ConvAlgo::Im2col);
        // force overrides the size cap but never geometry eligibility
        assert_eq!(
            ConvAlgo::choose(3, 1, 1, 64, 64, 64, Some(ConvAlgo::Direct3x3)),
            ConvAlgo::Direct3x3
        );
        assert_eq!(
            ConvAlgo::choose(5, 1, 2, 16, 8, 8, Some(ConvAlgo::Direct3x3)),
            ConvAlgo::Im2col
        );
        assert_eq!(
            ConvAlgo::choose(1, 1, 0, 16, 8, 8, Some(ConvAlgo::Im2col)),
            ConvAlgo::Im2col
        );
    }
}
