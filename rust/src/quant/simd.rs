//! Runtime-dispatched SIMD micro-kernels for the quantized engine.
//!
//! The engine's hot loops — the cache-blocked GEMM, the depthwise
//! convolution, the direct 3x3 convolution, and the element-wise
//! quantize / clamp / D-A passes — each exist in up to four
//! implementations behind one [`Isa`] dispatch:
//!
//!   * `scalar`  — the original register-tiled kernels in
//!     [`super::gemm`], kept verbatim as the differential oracle;
//!   * `avx2`    — x86_64 `std::arch` 8-lane kernels, selected at
//!     runtime via `is_x86_feature_detected!("avx2")`;
//!   * `neon`    — aarch64 4-lane mirrors of the AVX2 kernels;
//!   * `portable`— fixed-width chunked scalar loops (the compiler's
//!     autovectorizer handles them) for `--kernels simd` on hosts
//!     where no hand-written kernel exists.
//!
//! # Bit-exactness contract
//!
//! Every SIMD kernel is bit-identical to its scalar counterpart, up to
//! the sign of zero (see below), on finite inputs:
//!
//!   * **No FMA.** Accumulation uses separate multiply + add
//!     (`_mm256_add_ps(acc, _mm256_mul_ps(..))`, `vaddq_f32` +
//!     `vmulq_f32`) so no intermediate is kept at extended precision.
//!     Vectorization is across *independent outputs* only; every
//!     output element accumulates its K products in the same strictly
//!     ascending order as the scalar kernel and the `quant::ref`
//!     oracle.
//!   * **Same rounding.** `super::round_half_even` is IEEE
//!     round-to-nearest-even, which is exactly `_mm256_round_ps` with
//!     `_MM_FROUND_TO_NEAREST_INT` (and `vrndnq_f32` on aarch64).
//!     Divisions stay divisions (`_mm256_div_ps`) — never a
//!     reciprocal-multiply.
//!   * **Sign of zero.** `f32::clamp(-0.0, 0.0, 1.0)` keeps `-0.0`
//!     while the vector `max(min(x, 1), 0)` form returns `+0.0`; both
//!     compare equal and the difference cannot propagate into any
//!     nonzero magnitude, so outputs are equal under `==` everywhere
//!     (`assert_eq!` on `f32` treats `-0.0 == 0.0` as equal).
//!
//! The knob users see is [`KernelBackend`]; plans resolve it to an
//! [`Isa`] once at compile time and the resolved ISA is folded into
//! [`super::QuantPlan::cache_key`] so caches never mix backends.

use anyhow::anyhow;

use super::gemm::{dwconv_one, gemm_seqk};
use super::{da_q, quant_act, round_half_even};

/// Portable-fallback chunk width (f32 lanes per inner loop trip).
const CHUNK: usize = 8;

/// Which kernel family the engine compiles against — the `--kernels`
/// CLI knob, threaded through
/// [`SessionBuilder::kernels`](crate::api::SessionBuilder::kernels).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelBackend {
    /// The scalar register-tiled kernels (the differential oracle).
    Scalar,
    /// Explicit SIMD: AVX2 / NEON when available, else the portable
    /// chunked fallback.
    Simd,
    /// SIMD when the host supports it, scalar otherwise (default).
    #[default]
    Auto,
}

impl KernelBackend {
    /// Canonical lowercase name (the CLI spelling).
    pub fn name(self) -> &'static str {
        match self {
            KernelBackend::Scalar => "scalar",
            KernelBackend::Simd => "simd",
            KernelBackend::Auto => "auto",
        }
    }

    /// Resolve the knob to a concrete [`Isa`] on this host.
    ///
    /// `Scalar` always resolves to `Isa::Scalar`. `Simd` resolves to
    /// the best detected vector ISA, falling back to `Isa::Portable`
    /// (never scalar — the explicit-SIMD request is honored with the
    /// chunked kernels). `Auto` resolves like `Simd` but falls back to
    /// `Isa::Scalar`; the env var `ODIMO_KERNELS=scalar|simd` overrides
    /// `Auto` only (an explicit backend always wins), which is how the
    /// CI matrix runs the whole tier-1 suite per backend.
    pub fn resolve(self) -> Isa {
        match self {
            KernelBackend::Scalar => Isa::Scalar,
            KernelBackend::Simd => detect().unwrap_or(Isa::Portable),
            KernelBackend::Auto => match env_override() {
                Some(KernelBackend::Scalar) => Isa::Scalar,
                Some(KernelBackend::Simd) => detect().unwrap_or(Isa::Portable),
                _ => detect().unwrap_or(Isa::Scalar),
            },
        }
    }
}

impl std::fmt::Display for KernelBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for KernelBackend {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Ok(KernelBackend::Scalar),
            "simd" => Ok(KernelBackend::Simd),
            "auto" => Ok(KernelBackend::Auto),
            other => Err(anyhow!(
                "unknown kernel backend '{other}' (expected scalar|simd|auto)"
            )),
        }
    }
}

/// A concrete kernel implementation, resolved once per compiled plan.
/// `Avx2` / `Neon` are only ever constructed on a host where the
/// feature was positively detected (see [`KernelBackend::resolve`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Scalar register-tiled kernels.
    Scalar,
    /// x86_64 AVX2 8-lane kernels.
    Avx2,
    /// aarch64 NEON 4-lane kernels.
    Neon,
    /// Chunked autovectorizable fallback.
    Portable,
}

impl Isa {
    /// Stable one-byte code, folded into plan cache keys.
    pub fn code(self) -> u8 {
        match self {
            Isa::Scalar => 0,
            Isa::Avx2 => 1,
            Isa::Neon => 2,
            Isa::Portable => 3,
        }
    }

    /// Lowercase name for reports and logs.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
            Isa::Portable => "portable",
        }
    }
}

fn env_override() -> Option<KernelBackend> {
    std::env::var("ODIMO_KERNELS").ok()?.parse().ok()
}

/// Best vector ISA on this host, or `None` when only scalar/portable
/// kernels apply.
fn detect() -> Option<Isa> {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return Some(Isa::Avx2);
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        // NEON is baseline on aarch64 (std already requires it).
        return Some(Isa::Neon);
    }
    #[allow(unreachable_code)]
    None
}

// ---------------------------------------------------------------------
// dispatchers
// ---------------------------------------------------------------------

/// `C = A * B` with the engine's reduction-order contract (see
/// [`super::gemm::gemm_seqk`]); `a` is m x k, `b` is k x n, `c` is
/// m x n, all row-major.
pub fn gemm(isa: Isa, a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    match isa {
        Isa::Scalar => gemm_seqk(a, b, m, k, n, c),
        Isa::Portable => portable::gemm(a, b, m, k, n, c),
        _ => accel::gemm(a, b, m, k, n, c),
    }
}

/// One depthwise channel (see [`super::gemm::dwconv_one`]).
#[allow(clippy::too_many_arguments)]
pub fn dwconv(
    isa: Isa,
    x: &[f32],
    hi: usize,
    wi: usize,
    w: &[f32],
    k: usize,
    stride: usize,
    pad: usize,
    oh: usize,
    ow: usize,
    out: &mut [f32],
) {
    match isa {
        Isa::Scalar => dwconv_one(x, hi, wi, w, k, stride, pad, oh, ow, out),
        Isa::Portable => portable::dwconv(x, hi, wi, w, k, stride, pad, oh, ow, out),
        _ => accel::dwconv(x, hi, wi, w, k, stride, pad, oh, ow, out),
    }
}

/// Direct 3x3 stride-1 convolution: `m` filter rows (each cin x 3 x 3,
/// the packed-group weight layout) over one NCHW image, no im2col
/// panel. Accumulation order per output is (ci, ky, kx) with
/// out-of-bounds taps skipped — bit-identical (up to the sign of zero)
/// to lowering through `im2col` + [`gemm`].
#[allow(clippy::too_many_arguments)]
pub fn conv3x3(
    isa: Isa,
    x: &[f32],
    cin: usize,
    hi: usize,
    wi: usize,
    w: &[f32],
    m: usize,
    pad: usize,
    oh: usize,
    ow: usize,
    out: &mut [f32],
) {
    debug_assert!(x.len() >= cin * hi * wi);
    debug_assert!(w.len() >= m * cin * 9);
    debug_assert!(out.len() >= m * oh * ow);
    match isa {
        Isa::Scalar => conv3x3_scalar(x, cin, hi, wi, w, m, pad, oh, ow, out),
        Isa::Portable => portable::conv3x3(x, cin, hi, wi, w, m, pad, oh, ow, out),
        _ => accel::conv3x3(x, cin, hi, wi, w, m, pad, oh, ow, out),
    }
}

/// Input-grid quantization: `dst[i] = rne(x[i] * 255) / 255`.
pub(crate) fn input_quant(isa: Isa, x: &[f32], dst: &mut [f32]) {
    debug_assert_eq!(x.len(), dst.len());
    match isa {
        Isa::Scalar => input_quant_scalar(x, dst),
        Isa::Portable => portable::input_quant(x, dst),
        _ => accel::input_quant(x, dst),
    }
}

/// Fused bias + ReLU + output-grid quantization, in place over one
/// channel row (`act_scale <= 0` = float/calibration mode: bias+ReLU
/// only).
pub(crate) fn epilogue(
    isa: Isa,
    buf: &mut [f32],
    bias: f32,
    relu: bool,
    act_scale: f32,
    bits: u32,
) {
    match isa {
        Isa::Scalar => epilogue_scalar(buf, bias, relu, act_scale, bits),
        Isa::Portable => portable::epilogue(buf, bias, relu, act_scale, bits),
        _ => accel::epilogue(buf, bias, relu, act_scale, bits),
    }
}

/// Residual-add + ReLU + optional 8-bit requantization.
pub(crate) fn add_relu_quant(
    isa: Isa,
    a: &[f32],
    b: &[f32],
    relu: bool,
    scale: f32,
    quantize: bool,
    dst: &mut [f32],
) {
    debug_assert!(a.len() >= dst.len() && b.len() >= dst.len());
    match isa {
        Isa::Scalar => add_scalar(a, b, relu, scale, quantize, dst),
        Isa::Portable => portable::add_relu_quant(a, b, relu, scale, quantize, dst),
        _ => accel::add_relu_quant(a, b, relu, scale, quantize, dst),
    }
}

/// Materialize a D/A view: `dst[i] = da_q(src[i], bits)`.
pub(crate) fn da_q_into(isa: Isa, src: &[f32], bits: u32, dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    match isa {
        Isa::Scalar => da_scalar(src, bits, dst),
        Isa::Portable => portable::da_q_into(src, bits, dst),
        _ => accel::da_q_into(src, bits, dst),
    }
}

// ---------------------------------------------------------------------
// scalar element-wise bodies (also the vector kernels' remainder tails)
// ---------------------------------------------------------------------

/// One epilogue element — the single definition every backend's scalar
/// tail shares with the pure-scalar path.
#[inline]
fn epi1(v: f32, bias: f32, relu: bool, act_scale: f32, bits: u32) -> f32 {
    let t = v + bias;
    let t = if relu { t.max(0.0) } else { t };
    if act_scale > 0.0 {
        quant_act(t, act_scale, bits)
    } else {
        t
    }
}

fn epilogue_scalar(buf: &mut [f32], bias: f32, relu: bool, act_scale: f32, bits: u32) {
    for v in buf.iter_mut() {
        *v = epi1(*v, bias, relu, act_scale, bits);
    }
}

fn input_quant_scalar(x: &[f32], dst: &mut [f32]) {
    for (d, &v) in dst.iter_mut().zip(x) {
        *d = round_half_even(v * 255.0) / 255.0;
    }
}

fn add_scalar(a: &[f32], b: &[f32], relu: bool, scale: f32, quantize: bool, dst: &mut [f32]) {
    for (i, d) in dst.iter_mut().enumerate() {
        let v = a[i] + b[i];
        let v = if relu { v.max(0.0) } else { v };
        *d = if quantize { quant_act(v, scale, 8) } else { v };
    }
}

fn da_scalar(src: &[f32], bits: u32, dst: &mut [f32]) {
    for (d, &v) in dst.iter_mut().zip(src) {
        *d = da_q(v, bits);
    }
}

// ---------------------------------------------------------------------
// shared direct-conv scalar bodies
// ---------------------------------------------------------------------

/// Interior output rectangle for a 3x3 stride-1 conv: every tap in
/// bounds (same derivation as `dwconv_one`'s interior split).
fn interior3(
    hi: usize,
    wi: usize,
    pad: usize,
    oh: usize,
    ow: usize,
) -> (usize, usize, usize, usize) {
    let oy0 = pad.min(oh);
    let oy1 = if hi + pad >= 3 { (hi + pad - 2).min(oh) } else { oy0 };
    let ox0 = pad.min(ow);
    let ox1 = if wi + pad >= 3 { (wi + pad - 2).min(ow) } else { ox0 };
    (oy0, oy1, ox0, ox1)
}

/// One border output point of a 3x3 stride-1 conv for one filter row
/// `wr` (cin x 3 x 3): checked taps in (ci, ky, kx) order, skipping
/// out-of-bounds — the oracle's reduction order.
#[allow(clippy::too_many_arguments)]
fn conv3x3_point(
    x: &[f32],
    cin: usize,
    hi: usize,
    wi: usize,
    wr: &[f32],
    pad: usize,
    oy: usize,
    ox: usize,
) -> f32 {
    let mut acc = 0f32;
    for ci in 0..cin {
        let xc = &x[ci * hi * wi..(ci + 1) * hi * wi];
        let wc = &wr[ci * 9..(ci + 1) * 9];
        for ky in 0..3 {
            let iy = (oy + ky) as isize - pad as isize;
            if iy < 0 || iy >= hi as isize {
                continue;
            }
            for kx in 0..3 {
                let ix = (ox + kx) as isize - pad as isize;
                if ix < 0 || ix >= wi as isize {
                    continue;
                }
                acc += xc[iy as usize * wi + ix as usize] * wc[ky * 3 + kx];
            }
        }
    }
    acc
}

#[allow(clippy::too_many_arguments)]
fn conv3x3_scalar(
    x: &[f32],
    cin: usize,
    hi: usize,
    wi: usize,
    w: &[f32],
    m: usize,
    pad: usize,
    oh: usize,
    ow: usize,
    out: &mut [f32],
) {
    let n = oh * ow;
    let (oy0, oy1, ox0, ox1) = interior3(hi, wi, pad, oh, ow);
    for r in 0..m {
        let wr = &w[r * cin * 9..(r + 1) * cin * 9];
        for oy in 0..oh {
            let interior_y = (oy0..oy1).contains(&oy);
            for ox in 0..ow {
                let acc = if interior_y && (ox0..ox1).contains(&ox) {
                    let iy = oy - pad;
                    let ix = ox - pad;
                    let mut acc = 0f32;
                    for ci in 0..cin {
                        let xc = &x[ci * hi * wi..];
                        let wc = &wr[ci * 9..(ci + 1) * 9];
                        for ky in 0..3 {
                            let base = (iy + ky) * wi + ix;
                            let xrow = &xc[base..base + 3];
                            for kx in 0..3 {
                                acc += xrow[kx] * wc[ky * 3 + kx];
                            }
                        }
                    }
                    acc
                } else {
                    conv3x3_point(x, cin, hi, wi, wr, pad, oy, ox)
                };
                out[r * n + oy * ow + ox] = acc;
            }
        }
    }
}

/// One checked depthwise output point (the scalar body of
/// `dwconv_one`'s border branch; the vector kernels use it for borders
/// and non-unit strides).
#[allow(clippy::too_many_arguments)]
fn dw_point(
    x: &[f32],
    hi: usize,
    wi: usize,
    w: &[f32],
    k: usize,
    stride: usize,
    pad: usize,
    oy: usize,
    ox: usize,
) -> f32 {
    let mut acc = 0f32;
    for ky in 0..k {
        let iy = (oy * stride + ky) as isize - pad as isize;
        if iy < 0 || iy >= hi as isize {
            continue;
        }
        for kx in 0..k {
            let ix = (ox * stride + kx) as isize - pad as isize;
            if ix < 0 || ix >= wi as isize {
                continue;
            }
            acc += x[iy as usize * wi + ix as usize] * w[ky * k + kx];
        }
    }
    acc
}

// ---------------------------------------------------------------------
// portable: fixed-width chunks the autovectorizer can lower
// ---------------------------------------------------------------------

mod portable {
    use super::CHUNK;

    pub fn gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
        // gemm_seqk is already register-tiled in autovectorizable form
        super::gemm_seqk(a, b, m, k, n, c);
    }

    #[allow(clippy::too_many_arguments)]
    pub fn dwconv(
        x: &[f32],
        hi: usize,
        wi: usize,
        w: &[f32],
        k: usize,
        stride: usize,
        pad: usize,
        oh: usize,
        ow: usize,
        out: &mut [f32],
    ) {
        super::dwconv_one(x, hi, wi, w, k, stride, pad, oh, ow, out);
    }

    #[allow(clippy::too_many_arguments)]
    pub fn conv3x3(
        x: &[f32],
        cin: usize,
        hi: usize,
        wi: usize,
        w: &[f32],
        m: usize,
        pad: usize,
        oh: usize,
        ow: usize,
        out: &mut [f32],
    ) {
        super::conv3x3_scalar(x, cin, hi, wi, w, m, pad, oh, ow, out);
    }

    pub fn input_quant(x: &[f32], dst: &mut [f32]) {
        let mut it = dst.chunks_exact_mut(CHUNK);
        let mut xs = x.chunks_exact(CHUNK);
        for (d, s) in (&mut it).zip(&mut xs) {
            super::input_quant_scalar(s, d);
        }
        super::input_quant_scalar(xs.remainder(), it.into_remainder());
    }

    pub fn epilogue(buf: &mut [f32], bias: f32, relu: bool, act_scale: f32, bits: u32) {
        let mut it = buf.chunks_exact_mut(CHUNK);
        for ch in &mut it {
            super::epilogue_scalar(ch, bias, relu, act_scale, bits);
        }
        super::epilogue_scalar(it.into_remainder(), bias, relu, act_scale, bits);
    }

    pub fn add_relu_quant(
        a: &[f32],
        b: &[f32],
        relu: bool,
        scale: f32,
        quantize: bool,
        dst: &mut [f32],
    ) {
        let nl = dst.len() / CHUNK * CHUNK;
        let mut i = 0;
        while i < nl {
            super::add_scalar(
                &a[i..i + CHUNK],
                &b[i..i + CHUNK],
                relu,
                scale,
                quantize,
                &mut dst[i..i + CHUNK],
            );
            i += CHUNK;
        }
        super::add_scalar(
            &a[nl..dst.len()],
            &b[nl..dst.len()],
            relu,
            scale,
            quantize,
            &mut dst[nl..],
        );
    }

    pub fn da_q_into(src: &[f32], bits: u32, dst: &mut [f32]) {
        let mut it = dst.chunks_exact_mut(CHUNK);
        let mut xs = src.chunks_exact(CHUNK);
        for (d, s) in (&mut it).zip(&mut xs) {
            super::da_scalar(s, bits, d);
        }
        super::da_scalar(xs.remainder(), bits, it.into_remainder());
    }
}

// ---------------------------------------------------------------------
// accel: the arch-specific module `_ =>` dispatch arms resolve to.
// `Isa::Avx2` / `Isa::Neon` are only constructed on the matching arch
// after positive runtime detection, so each wrapper's feature
// precondition holds by construction.
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod accel {
    use super::avx2;

    pub fn gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
        unsafe { avx2::gemm(a, b, m, k, n, c) }
    }

    #[allow(clippy::too_many_arguments)]
    pub fn dwconv(
        x: &[f32],
        hi: usize,
        wi: usize,
        w: &[f32],
        k: usize,
        stride: usize,
        pad: usize,
        oh: usize,
        ow: usize,
        out: &mut [f32],
    ) {
        unsafe { avx2::dwconv(x, hi, wi, w, k, stride, pad, oh, ow, out) }
    }

    #[allow(clippy::too_many_arguments)]
    pub fn conv3x3(
        x: &[f32],
        cin: usize,
        hi: usize,
        wi: usize,
        w: &[f32],
        m: usize,
        pad: usize,
        oh: usize,
        ow: usize,
        out: &mut [f32],
    ) {
        unsafe { avx2::conv3x3(x, cin, hi, wi, w, m, pad, oh, ow, out) }
    }

    pub fn input_quant(x: &[f32], dst: &mut [f32]) {
        unsafe { avx2::input_quant(x, dst) }
    }

    pub fn epilogue(buf: &mut [f32], bias: f32, relu: bool, act_scale: f32, bits: u32) {
        unsafe { avx2::epilogue(buf, bias, relu, act_scale, bits) }
    }

    pub fn add_relu_quant(
        a: &[f32],
        b: &[f32],
        relu: bool,
        scale: f32,
        quantize: bool,
        dst: &mut [f32],
    ) {
        unsafe { avx2::add_relu_quant(a, b, relu, scale, quantize, dst) }
    }

    pub fn da_q_into(src: &[f32], bits: u32, dst: &mut [f32]) {
        unsafe { avx2::da_q_into(src, bits, dst) }
    }
}

#[cfg(target_arch = "aarch64")]
mod accel {
    use super::neon;

    pub fn gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
        unsafe { neon::gemm(a, b, m, k, n, c) }
    }

    #[allow(clippy::too_many_arguments)]
    pub fn dwconv(
        x: &[f32],
        hi: usize,
        wi: usize,
        w: &[f32],
        k: usize,
        stride: usize,
        pad: usize,
        oh: usize,
        ow: usize,
        out: &mut [f32],
    ) {
        unsafe { neon::dwconv(x, hi, wi, w, k, stride, pad, oh, ow, out) }
    }

    #[allow(clippy::too_many_arguments)]
    pub fn conv3x3(
        x: &[f32],
        cin: usize,
        hi: usize,
        wi: usize,
        w: &[f32],
        m: usize,
        pad: usize,
        oh: usize,
        ow: usize,
        out: &mut [f32],
    ) {
        unsafe { neon::conv3x3(x, cin, hi, wi, w, m, pad, oh, ow, out) }
    }

    pub fn input_quant(x: &[f32], dst: &mut [f32]) {
        unsafe { neon::input_quant(x, dst) }
    }

    pub fn epilogue(buf: &mut [f32], bias: f32, relu: bool, act_scale: f32, bits: u32) {
        unsafe { neon::epilogue(buf, bias, relu, act_scale, bits) }
    }

    pub fn add_relu_quant(
        a: &[f32],
        b: &[f32],
        relu: bool,
        scale: f32,
        quantize: bool,
        dst: &mut [f32],
    ) {
        unsafe { neon::add_relu_quant(a, b, relu, scale, quantize, dst) }
    }

    pub fn da_q_into(src: &[f32], bits: u32, dst: &mut [f32]) {
        unsafe { neon::da_q_into(src, bits, dst) }
    }
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
mod accel {
    // no hand-written kernels for this arch: resolve() never yields
    // Avx2/Neon here, and Simd falls back to Portable
    pub use super::portable::*;
}

// ---------------------------------------------------------------------
// AVX2 (x86_64, runtime-detected)
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    #![allow(clippy::missing_safety_doc)] // mod-private: callers are the
                                          // `accel` wrappers above, whose
                                          // precondition is documented

    use std::arch::x86_64::*;

    use crate::quant::gemm::{edge_rows, MR, NB, NR};
    use crate::quant::simd::{
        add_scalar, da_scalar, dw_point, epilogue_scalar, input_quant_scalar, interior3,
    };

    const RNE: i32 = _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC;

    /// AVX2 mirror of `gemm_seqk`: same NB/MR/NR blocking, same strict
    /// ascending-k accumulation per output, separate mul + add (no FMA)
    /// so every partial sum is bit-identical to the scalar kernel.
    #[target_feature(enable = "avx2")]
    pub unsafe fn gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
        debug_assert!(a.len() >= m * k);
        debug_assert!(b.len() >= k * n);
        debug_assert!(c.len() >= m * n);
        let mut j0 = 0;
        while j0 < n {
            let jn = (j0 + NB).min(n);
            let mut i0 = 0;
            while i0 + MR <= m {
                let mut j = j0;
                while j + NR <= jn {
                    micro(a, b, i0, j, k, n, c);
                    j += NR;
                }
                if j < jn {
                    edge_rows(a, b, i0, MR, j, jn, k, n, c);
                }
                i0 += MR;
            }
            if i0 < m {
                edge_rows(a, b, i0, m - i0, j0, jn, k, n, c);
            }
            j0 = jn;
        }
    }

    /// MR x NR tile: 2 ymm accumulators per row, broadcast-A x load-B.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn micro(a: &[f32], b: &[f32], i0: usize, j0: usize, k: usize, n: usize, c: &mut [f32]) {
        let mut acc = [[_mm256_setzero_ps(); 2]; MR];
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        for p in 0..k {
            let b0 = _mm256_loadu_ps(bp.add(p * n + j0));
            let b1 = _mm256_loadu_ps(bp.add(p * n + j0 + 8));
            for (r, accr) in acc.iter_mut().enumerate() {
                let av = _mm256_set1_ps(*ap.add((i0 + r) * k + p));
                accr[0] = _mm256_add_ps(accr[0], _mm256_mul_ps(av, b0));
                accr[1] = _mm256_add_ps(accr[1], _mm256_mul_ps(av, b1));
            }
        }
        for (r, accr) in acc.iter().enumerate() {
            let cp = c.as_mut_ptr().add((i0 + r) * n + j0);
            _mm256_storeu_ps(cp, accr[0]);
            _mm256_storeu_ps(cp.add(8), accr[1]);
        }
    }

    /// Depthwise conv: 8-lane interior for stride 1, checked scalar
    /// taps for borders and other strides (same tap order everywhere).
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    pub unsafe fn dwconv(
        x: &[f32],
        hi: usize,
        wi: usize,
        w: &[f32],
        k: usize,
        stride: usize,
        pad: usize,
        oh: usize,
        ow: usize,
        out: &mut [f32],
    ) {
        debug_assert!(x.len() >= hi * wi);
        debug_assert!(w.len() >= k * k);
        debug_assert!(out.len() >= oh * ow);
        let oy0 = ((pad + stride - 1) / stride).min(oh);
        let oy1 = if hi + pad >= k { ((hi + pad - k) / stride + 1).min(oh) } else { oy0 };
        let ox0 = ((pad + stride - 1) / stride).min(ow);
        let ox1 = if wi + pad >= k { ((wi + pad - k) / stride + 1).min(ow) } else { ox0 };
        for oy in 0..oh {
            let interior_y = stride == 1 && oy >= oy0 && oy < oy1;
            let mut ox = 0;
            while ox < ow {
                if interior_y && ox >= ox0 && ox + 8 <= ox1 {
                    let iy = oy - pad;
                    let ix = ox - pad;
                    let mut acc = _mm256_setzero_ps();
                    for ky in 0..k {
                        let rowp = x.as_ptr().add((iy + ky) * wi + ix);
                        let wrow = w.as_ptr().add(ky * k);
                        for kx in 0..k {
                            let wv = _mm256_set1_ps(*wrow.add(kx));
                            let xv = _mm256_loadu_ps(rowp.add(kx));
                            acc = _mm256_add_ps(acc, _mm256_mul_ps(wv, xv));
                        }
                    }
                    _mm256_storeu_ps(out.as_mut_ptr().add(oy * ow + ox), acc);
                    ox += 8;
                } else {
                    out[oy * ow + ox] = dw_point(x, hi, wi, w, k, stride, pad, oy, ox);
                    ox += 1;
                }
            }
        }
    }

    /// Direct 3x3 stride-1 conv: 8 output pixels per step, taps in
    /// (ci, ky, kx) order, checked scalar borders.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2")]
    pub unsafe fn conv3x3(
        x: &[f32],
        cin: usize,
        hi: usize,
        wi: usize,
        w: &[f32],
        m: usize,
        pad: usize,
        oh: usize,
        ow: usize,
        out: &mut [f32],
    ) {
        let n = oh * ow;
        let (oy0, oy1, ox0, ox1) = interior3(hi, wi, pad, oh, ow);
        for r in 0..m {
            let wr = &w[r * cin * 9..(r + 1) * cin * 9];
            for oy in 0..oh {
                let interior_y = oy >= oy0 && oy < oy1;
                let mut ox = 0;
                while ox < ow {
                    if interior_y && ox >= ox0 && ox + 8 <= ox1 {
                        let iy = oy - pad;
                        let ix = ox - pad;
                        let mut acc = _mm256_setzero_ps();
                        for ci in 0..cin {
                            let xp = x.as_ptr().add(ci * hi * wi);
                            let wc = wr.as_ptr().add(ci * 9);
                            for ky in 0..3 {
                                let rowp = xp.add((iy + ky) * wi + ix);
                                for kx in 0..3 {
                                    let wv = _mm256_set1_ps(*wc.add(ky * 3 + kx));
                                    let xv = _mm256_loadu_ps(rowp.add(kx));
                                    acc = _mm256_add_ps(acc, _mm256_mul_ps(wv, xv));
                                }
                            }
                        }
                        _mm256_storeu_ps(out.as_mut_ptr().add(r * n + oy * ow + ox), acc);
                        ox += 8;
                    } else {
                        out[r * n + oy * ow + ox] =
                            super::conv3x3_point(x, cin, hi, wi, wr, pad, oy, ox);
                        ox += 1;
                    }
                }
            }
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn input_quant(x: &[f32], dst: &mut [f32]) {
        let v255 = _mm256_set1_ps(255.0);
        let nl = x.len() / 8 * 8;
        let mut i = 0;
        while i < nl {
            let v = _mm256_loadu_ps(x.as_ptr().add(i));
            let r = _mm256_round_ps::<RNE>(_mm256_mul_ps(v, v255));
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_div_ps(r, v255));
            i += 8;
        }
        input_quant_scalar(&x[nl..], &mut dst[nl..]);
    }

    /// Quantize one lane group to the act grid: exact op-for-op mirror
    /// of `quant_act` (div, clamp via min/max, rne, scale-back).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn qact(
        t: __m256,
        vscale: __m256,
        vlev: __m256,
        vout: __m256,
        one: __m256,
        zero: __m256,
    ) -> __m256 {
        let q = _mm256_max_ps(_mm256_min_ps(_mm256_div_ps(t, vscale), one), zero);
        let r = _mm256_round_ps::<RNE>(_mm256_mul_ps(vlev, q));
        _mm256_mul_ps(vout, r)
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn epilogue(buf: &mut [f32], bias: f32, relu: bool, act_scale: f32, bits: u32) {
        let nl = buf.len() / 8 * 8;
        let vb = _mm256_set1_ps(bias);
        let zero = _mm256_setzero_ps();
        if act_scale > 0.0 {
            let levels = ((1u32 << bits) - 1) as f32;
            let vscale = _mm256_set1_ps(act_scale);
            let vlev = _mm256_set1_ps(levels);
            let vout = _mm256_set1_ps(act_scale / levels);
            let one = _mm256_set1_ps(1.0);
            let mut i = 0;
            while i < nl {
                let p = buf.as_mut_ptr().add(i);
                let mut t = _mm256_add_ps(_mm256_loadu_ps(p), vb);
                if relu {
                    t = _mm256_max_ps(t, zero);
                }
                _mm256_storeu_ps(p, qact(t, vscale, vlev, vout, one, zero));
                i += 8;
            }
        } else {
            let mut i = 0;
            while i < nl {
                let p = buf.as_mut_ptr().add(i);
                let mut t = _mm256_add_ps(_mm256_loadu_ps(p), vb);
                if relu {
                    t = _mm256_max_ps(t, zero);
                }
                _mm256_storeu_ps(p, t);
                i += 8;
            }
        }
        epilogue_scalar(&mut buf[nl..], bias, relu, act_scale, bits);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn add_relu_quant(
        a: &[f32],
        b: &[f32],
        relu: bool,
        scale: f32,
        quantize: bool,
        dst: &mut [f32],
    ) {
        let n = dst.len();
        let nl = n / 8 * 8;
        let zero = _mm256_setzero_ps();
        let levels = 255.0f32; // quantize path is always 8-bit
        let vscale = _mm256_set1_ps(scale);
        let vlev = _mm256_set1_ps(levels);
        let vout = _mm256_set1_ps(scale / levels);
        let one = _mm256_set1_ps(1.0);
        let mut i = 0;
        while i < nl {
            let mut v = _mm256_add_ps(
                _mm256_loadu_ps(a.as_ptr().add(i)),
                _mm256_loadu_ps(b.as_ptr().add(i)),
            );
            if relu {
                v = _mm256_max_ps(v, zero);
            }
            if quantize {
                v = qact(v, vscale, vlev, vout, one, zero);
            }
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), v);
            i += 8;
        }
        add_scalar(&a[nl..n], &b[nl..n], relu, scale, quantize, &mut dst[nl..]);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn da_q_into(src: &[f32], bits: u32, dst: &mut [f32]) {
        let levels = ((1u32 << bits) - 1) as f32;
        let vlev = _mm256_set1_ps(levels);
        let one = _mm256_set1_ps(1.0);
        let zero = _mm256_setzero_ps();
        let nl = src.len() / 8 * 8;
        let mut i = 0;
        while i < nl {
            let v = _mm256_loadu_ps(src.as_ptr().add(i));
            let q = _mm256_max_ps(_mm256_min_ps(v, one), zero);
            let r = _mm256_round_ps::<RNE>(_mm256_mul_ps(q, vlev));
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_div_ps(r, vlev));
            i += 8;
        }
        da_scalar(&src[nl..], bits, &mut dst[nl..]);
    }
}

// ---------------------------------------------------------------------
// NEON (aarch64; baseline feature)
// ---------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    #![allow(clippy::missing_safety_doc)]

    use std::arch::aarch64::*;

    use crate::quant::gemm::{edge_rows, MR, NB, NR};
    use crate::quant::simd::{
        add_scalar, da_scalar, dw_point, epilogue_scalar, input_quant_scalar, interior3,
    };

    /// NEON mirror of `gemm_seqk`: same blocking, mul + add (no FMA).
    #[target_feature(enable = "neon")]
    pub unsafe fn gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
        debug_assert!(a.len() >= m * k);
        debug_assert!(b.len() >= k * n);
        debug_assert!(c.len() >= m * n);
        let mut j0 = 0;
        while j0 < n {
            let jn = (j0 + NB).min(n);
            let mut i0 = 0;
            while i0 + MR <= m {
                let mut j = j0;
                while j + NR <= jn {
                    micro(a, b, i0, j, k, n, c);
                    j += NR;
                }
                if j < jn {
                    edge_rows(a, b, i0, MR, j, jn, k, n, c);
                }
                i0 += MR;
            }
            if i0 < m {
                edge_rows(a, b, i0, m - i0, j0, jn, k, n, c);
            }
            j0 = jn;
        }
    }

    /// MR x NR tile: 4 q-regs per row (NR = 16 = 4 x 4 lanes).
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn micro(a: &[f32], b: &[f32], i0: usize, j0: usize, k: usize, n: usize, c: &mut [f32]) {
        let mut acc = [[vdupq_n_f32(0.0); 4]; MR];
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        for p in 0..k {
            let base = bp.add(p * n + j0);
            let b0 = vld1q_f32(base);
            let b1 = vld1q_f32(base.add(4));
            let b2 = vld1q_f32(base.add(8));
            let b3 = vld1q_f32(base.add(12));
            for (r, accr) in acc.iter_mut().enumerate() {
                let av = vdupq_n_f32(*ap.add((i0 + r) * k + p));
                accr[0] = vaddq_f32(accr[0], vmulq_f32(av, b0));
                accr[1] = vaddq_f32(accr[1], vmulq_f32(av, b1));
                accr[2] = vaddq_f32(accr[2], vmulq_f32(av, b2));
                accr[3] = vaddq_f32(accr[3], vmulq_f32(av, b3));
            }
        }
        for (r, accr) in acc.iter().enumerate() {
            let cp = c.as_mut_ptr().add((i0 + r) * n + j0);
            vst1q_f32(cp, accr[0]);
            vst1q_f32(cp.add(4), accr[1]);
            vst1q_f32(cp.add(8), accr[2]);
            vst1q_f32(cp.add(12), accr[3]);
        }
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "neon")]
    pub unsafe fn dwconv(
        x: &[f32],
        hi: usize,
        wi: usize,
        w: &[f32],
        k: usize,
        stride: usize,
        pad: usize,
        oh: usize,
        ow: usize,
        out: &mut [f32],
    ) {
        debug_assert!(x.len() >= hi * wi);
        debug_assert!(w.len() >= k * k);
        debug_assert!(out.len() >= oh * ow);
        let oy0 = ((pad + stride - 1) / stride).min(oh);
        let oy1 = if hi + pad >= k { ((hi + pad - k) / stride + 1).min(oh) } else { oy0 };
        let ox0 = ((pad + stride - 1) / stride).min(ow);
        let ox1 = if wi + pad >= k { ((wi + pad - k) / stride + 1).min(ow) } else { ox0 };
        for oy in 0..oh {
            let interior_y = stride == 1 && oy >= oy0 && oy < oy1;
            let mut ox = 0;
            while ox < ow {
                if interior_y && ox >= ox0 && ox + 4 <= ox1 {
                    let iy = oy - pad;
                    let ix = ox - pad;
                    let mut acc = vdupq_n_f32(0.0);
                    for ky in 0..k {
                        let rowp = x.as_ptr().add((iy + ky) * wi + ix);
                        let wrow = w.as_ptr().add(ky * k);
                        for kx in 0..k {
                            let wv = vdupq_n_f32(*wrow.add(kx));
                            let xv = vld1q_f32(rowp.add(kx));
                            acc = vaddq_f32(acc, vmulq_f32(wv, xv));
                        }
                    }
                    vst1q_f32(out.as_mut_ptr().add(oy * ow + ox), acc);
                    ox += 4;
                } else {
                    out[oy * ow + ox] = dw_point(x, hi, wi, w, k, stride, pad, oy, ox);
                    ox += 1;
                }
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "neon")]
    pub unsafe fn conv3x3(
        x: &[f32],
        cin: usize,
        hi: usize,
        wi: usize,
        w: &[f32],
        m: usize,
        pad: usize,
        oh: usize,
        ow: usize,
        out: &mut [f32],
    ) {
        let n = oh * ow;
        let (oy0, oy1, ox0, ox1) = interior3(hi, wi, pad, oh, ow);
        for r in 0..m {
            let wr = &w[r * cin * 9..(r + 1) * cin * 9];
            for oy in 0..oh {
                let interior_y = oy >= oy0 && oy < oy1;
                let mut ox = 0;
                while ox < ow {
                    if interior_y && ox >= ox0 && ox + 4 <= ox1 {
                        let iy = oy - pad;
                        let ix = ox - pad;
                        let mut acc = vdupq_n_f32(0.0);
                        for ci in 0..cin {
                            let xp = x.as_ptr().add(ci * hi * wi);
                            let wc = wr.as_ptr().add(ci * 9);
                            for ky in 0..3 {
                                let rowp = xp.add((iy + ky) * wi + ix);
                                for kx in 0..3 {
                                    let wv = vdupq_n_f32(*wc.add(ky * 3 + kx));
                                    let xv = vld1q_f32(rowp.add(kx));
                                    acc = vaddq_f32(acc, vmulq_f32(wv, xv));
                                }
                            }
                        }
                        vst1q_f32(out.as_mut_ptr().add(r * n + oy * ow + ox), acc);
                        ox += 4;
                    } else {
                        out[r * n + oy * ow + ox] =
                            super::conv3x3_point(x, cin, hi, wi, wr, pad, oy, ox);
                        ox += 1;
                    }
                }
            }
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn input_quant(x: &[f32], dst: &mut [f32]) {
        let v255 = vdupq_n_f32(255.0);
        let nl = x.len() / 4 * 4;
        let mut i = 0;
        while i < nl {
            let v = vld1q_f32(x.as_ptr().add(i));
            let r = vrndnq_f32(vmulq_f32(v, v255));
            vst1q_f32(dst.as_mut_ptr().add(i), vdivq_f32(r, v255));
            i += 4;
        }
        input_quant_scalar(&x[nl..], &mut dst[nl..]);
    }

    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn qact(
        t: float32x4_t,
        vscale: float32x4_t,
        vlev: float32x4_t,
        vout: float32x4_t,
        one: float32x4_t,
        zero: float32x4_t,
    ) -> float32x4_t {
        let q = vmaxq_f32(vminq_f32(vdivq_f32(t, vscale), one), zero);
        let r = vrndnq_f32(vmulq_f32(vlev, q));
        vmulq_f32(vout, r)
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn epilogue(buf: &mut [f32], bias: f32, relu: bool, act_scale: f32, bits: u32) {
        let nl = buf.len() / 4 * 4;
        let vb = vdupq_n_f32(bias);
        let zero = vdupq_n_f32(0.0);
        if act_scale > 0.0 {
            let levels = ((1u32 << bits) - 1) as f32;
            let vscale = vdupq_n_f32(act_scale);
            let vlev = vdupq_n_f32(levels);
            let vout = vdupq_n_f32(act_scale / levels);
            let one = vdupq_n_f32(1.0);
            let mut i = 0;
            while i < nl {
                let p = buf.as_mut_ptr().add(i);
                let mut t = vaddq_f32(vld1q_f32(p), vb);
                if relu {
                    t = vmaxq_f32(t, zero);
                }
                vst1q_f32(p, qact(t, vscale, vlev, vout, one, zero));
                i += 4;
            }
        } else {
            let mut i = 0;
            while i < nl {
                let p = buf.as_mut_ptr().add(i);
                let mut t = vaddq_f32(vld1q_f32(p), vb);
                if relu {
                    t = vmaxq_f32(t, zero);
                }
                vst1q_f32(p, t);
                i += 4;
            }
        }
        epilogue_scalar(&mut buf[nl..], bias, relu, act_scale, bits);
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn add_relu_quant(
        a: &[f32],
        b: &[f32],
        relu: bool,
        scale: f32,
        quantize: bool,
        dst: &mut [f32],
    ) {
        let n = dst.len();
        let nl = n / 4 * 4;
        let zero = vdupq_n_f32(0.0);
        let levels = 255.0f32;
        let vscale = vdupq_n_f32(scale);
        let vlev = vdupq_n_f32(levels);
        let vout = vdupq_n_f32(scale / levels);
        let one = vdupq_n_f32(1.0);
        let mut i = 0;
        while i < nl {
            let mut v = vaddq_f32(vld1q_f32(a.as_ptr().add(i)), vld1q_f32(b.as_ptr().add(i)));
            if relu {
                v = vmaxq_f32(v, zero);
            }
            if quantize {
                v = qact(v, vscale, vlev, vout, one, zero);
            }
            vst1q_f32(dst.as_mut_ptr().add(i), v);
            i += 4;
        }
        add_scalar(&a[nl..n], &b[nl..n], relu, scale, quantize, &mut dst[nl..]);
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn da_q_into(src: &[f32], bits: u32, dst: &mut [f32]) {
        let levels = ((1u32 << bits) - 1) as f32;
        let vlev = vdupq_n_f32(levels);
        let one = vdupq_n_f32(1.0);
        let zero = vdupq_n_f32(0.0);
        let nl = src.len() / 4 * 4;
        let mut i = 0;
        while i < nl {
            let v = vld1q_f32(src.as_ptr().add(i));
            let q = vmaxq_f32(vminq_f32(v, one), zero);
            let r = vrndnq_f32(vmulq_f32(q, vlev));
            vst1q_f32(dst.as_mut_ptr().add(i), vdivq_f32(r, vlev));
            i += 4;
        }
        da_scalar(&src[nl..], bits, &mut dst[nl..]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::gemm::im2col;
    use crate::util::prng::Pcg32;

    /// Every ISA exercisable on this host: scalar + portable always,
    /// plus whatever `detect()` finds.
    fn isas() -> Vec<Isa> {
        let mut v = vec![Isa::Scalar, Isa::Portable];
        if let Some(i) = detect() {
            v.push(i);
        }
        v
    }

    fn rand_vec(rng: &mut Pcg32, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
    }

    #[test]
    fn backend_parse_and_display_roundtrip() {
        for b in [KernelBackend::Scalar, KernelBackend::Simd, KernelBackend::Auto] {
            assert_eq!(b.name().parse::<KernelBackend>().unwrap(), b);
            assert_eq!(format!("{b}").as_str(), b.name());
        }
        assert!("avx9000".parse::<KernelBackend>().is_err());
    }

    #[test]
    fn resolve_contract() {
        assert_eq!(KernelBackend::Scalar.resolve(), Isa::Scalar);
        // Simd never silently degrades to the scalar kernels
        assert_ne!(KernelBackend::Simd.resolve(), Isa::Scalar);
        // codes are distinct (the cache-key fold relies on this)
        let codes: Vec<u8> =
            [Isa::Scalar, Isa::Avx2, Isa::Neon, Isa::Portable].iter().map(|i| i.code()).collect();
        let mut dedup = codes.clone();
        dedup.dedup();
        assert_eq!(codes, dedup);
    }

    #[test]
    fn gemm_matches_scalar_on_ragged_shapes() {
        // m/n/k deliberately off the 4/16/8-lane grid
        let shapes = [
            (1usize, 1usize, 1usize),
            (3, 5, 7),
            (4, 16, 16),
            (5, 27, 33),
            (13, 100, 37),
            (17, 64, 300),
            (8, 9, 130),
            (16, 288, 64),
        ];
        for isa in isas() {
            let mut rng = Pcg32::new(11, 3);
            for &(m, k, n) in &shapes {
                let a = rand_vec(&mut rng, m * k);
                let b = rand_vec(&mut rng, k * n);
                let mut want = vec![0f32; m * n];
                gemm_seqk(&a, &b, m, k, n, &mut want);
                let mut got = vec![0f32; m * n];
                gemm(isa, &a, &b, m, k, n, &mut got);
                assert_eq!(got, want, "{} m={m} k={k} n={n}", isa.name());
            }
        }
    }

    #[test]
    fn dwconv_matches_scalar() {
        for isa in isas() {
            let mut rng = Pcg32::new(21, 2);
            for &(hi, wi, k, stride, pad) in &[
                (8usize, 8usize, 3usize, 1usize, 1usize),
                (13, 11, 3, 1, 1),
                (7, 9, 3, 2, 1),
                (5, 5, 5, 1, 2),
                (4, 4, 3, 1, 0),
                (3, 3, 3, 1, 2),
            ] {
                let oh = (hi + 2 * pad - k) / stride + 1;
                let ow = (wi + 2 * pad - k) / stride + 1;
                let x = rand_vec(&mut rng, hi * wi);
                let w = rand_vec(&mut rng, k * k);
                let mut want = vec![0f32; oh * ow];
                dwconv_one(&x, hi, wi, &w, k, stride, pad, oh, ow, &mut want);
                let mut got = vec![0f32; oh * ow];
                dwconv(isa, &x, hi, wi, &w, k, stride, pad, oh, ow, &mut got);
                assert_eq!(got, want, "{} hw=({hi},{wi}) k={k} s={stride}", isa.name());
            }
        }
    }

    #[test]
    fn conv3x3_matches_im2col_gemm() {
        for isa in isas() {
            let mut rng = Pcg32::new(5, 9);
            for &(cin, hi, wi, pad, m) in &[
                (3usize, 8usize, 8usize, 1usize, 4usize),
                (1, 5, 7, 1, 3),
                (4, 6, 6, 0, 5),
                (2, 9, 5, 1, 1),
                (2, 19, 13, 1, 2),
            ] {
                let oh = hi + 2 * pad - 2;
                let ow = wi + 2 * pad - 2;
                let n = oh * ow;
                let kdim = cin * 9;
                let x = rand_vec(&mut rng, cin * hi * wi);
                let w = rand_vec(&mut rng, m * kdim);
                let mut panel = vec![0f32; kdim * n];
                im2col(&x, cin, hi, wi, 3, 1, pad, oh, ow, &mut panel);
                let mut want = vec![0f32; m * n];
                gemm_seqk(&w, &panel, m, kdim, n, &mut want);
                let mut got = vec![0f32; m * n];
                conv3x3(isa, &x, cin, hi, wi, &w, m, pad, oh, ow, &mut got);
                assert_eq!(got, want, "{} cin={cin} hw=({hi},{wi}) p={pad}", isa.name());
            }
        }
    }

    #[test]
    fn elementwise_kernels_match_scalar() {
        let mut rng = Pcg32::new(7, 5);
        // length off the lane grid; include exact rounding ties
        // (v * 255 = k + 0.5) so RNE behavior is actually pinned
        let mut x = rand_vec(&mut rng, 203);
        for (i, v) in x.iter_mut().enumerate().take(40) {
            *v = (2 * i + 1) as f32 / 510.0;
        }
        let y = rand_vec(&mut rng, 203);
        for isa in isas() {
            let mut want = vec![0f32; x.len()];
            input_quant_scalar(&x, &mut want);
            let mut got = vec![0f32; x.len()];
            input_quant(isa, &x, &mut got);
            assert_eq!(got, want, "{} input_quant", isa.name());

            for (act_scale, bits) in [(0.73f32, 8u32), (1.31, 4), (0.2, 2), (0.0, 8)] {
                for relu in [false, true] {
                    let mut want = x.clone();
                    epilogue_scalar(&mut want, 0.11, relu, act_scale, bits);
                    let mut got = x.clone();
                    epilogue(isa, &mut got, 0.11, relu, act_scale, bits);
                    assert_eq!(got, want, "{} epilogue s={act_scale} b={bits}", isa.name());
                }
            }

            for quantize in [false, true] {
                let mut want = vec![0f32; x.len()];
                add_scalar(&x, &y, true, 0.9, quantize, &mut want);
                let mut got = vec![0f32; x.len()];
                add_relu_quant(isa, &x, &y, true, 0.9, quantize, &mut got);
                assert_eq!(got, want, "{} add q={quantize}", isa.name());
            }

            for bits in [2u32, 6, 7, 8] {
                let mut want = vec![0f32; x.len()];
                da_scalar(&x, bits, &mut want);
                let mut got = vec![0f32; x.len()];
                da_q_into(isa, &x, bits, &mut got);
                assert_eq!(got, want, "{} da_q bits={bits}", isa.name());
            }
        }
    }
}
