//! Pure-rust quantized inference engine — the deployment-side
//! executable artifact (the role DORY [26] plays in the paper: turn a
//! trained, mapped network into code for the target).
//!
//! Since the planned-engine rewrite this module is a thin API over
//! [`super::plan::QuantPlan`]:
//!
//!   * [`QuantNet::compile`] builds the plan once per (graph, mapping):
//!     packed per-accelerator weight groups, precomputed quantization
//!     constants, and a liveness-assigned buffer arena;
//!   * [`QuantNet::forward`] executes with zero per-node allocations
//!     (workspaces are pooled and reused across calls) through im2col +
//!     cache-blocked GEMM kernels;
//!   * [`QuantNet::forward_pool`] adds batch-block parallelism (one
//!     plan walk per sub-batch) and, when the batch is smaller than the
//!     pool, per-layer (image x output-channel-block) tiling;
//!   * [`calibrate_act_maxima`] runs the same engine in float mode.
//!
//! Platforms with several IMC macros of *distinct* `da_bits` are fully
//! supported: the plan materializes one D/A view per distinct width
//! (see `super::plan`), and platforms with no D/A unit at all (e.g.
//! `gap9`) materialize none.
//!
//! Numerics are bit-identical to the retired naive interpreter, which
//! lives on as the differential oracle in [`super::r#ref`]; the HLO
//! cross-check in `tests/quant_infer.rs` pins both against the AOT
//! `infer_deploy` graph.

use std::collections::BTreeMap;
use std::sync::Mutex;

use anyhow::Result;

use crate::coordinator::Mapping;
use crate::hw::Platform;
use crate::model::Graph;
use crate::runtime::ArtifactMeta;
use crate::util::pool::ThreadPool;

use super::plan::{ConvAlgo, KernelSpan, QuantPlan, Scratch};
use super::simd::{Isa, KernelBackend};
use super::ParamSet;

/// A fully quantized network ready to execute. Owns its compiled plan
/// outright (no borrow of the source [`Graph`]), so caches — e.g. the
/// [`Session`](crate::api::Session)-owned plan cache — can hold nets
/// alongside the graph they were compiled from.
pub struct QuantNet {
    plan: QuantPlan,
    /// reusable per-thread scratches (presized from the plan's capacity
    /// classes on first use, then allocation-free)
    ws: Mutex<Vec<Scratch>>,
}

impl QuantNet {
    /// Compile from an artifact parameter snapshot (leaf order per
    /// `meta`) for a deployment `platform`.
    pub fn compile(
        meta: &ArtifactMeta,
        graph: &Graph,
        values: &[Vec<f32>],
        mapping: &Mapping,
        platform: &Platform,
    ) -> Result<Self> {
        let params = ParamSet::from_meta(meta, values);
        Self::compile_params(&params, graph, mapping, platform)
    }

    /// Compile from any name-indexed parameter set (tests/benches) with
    /// the default ([`KernelBackend::Auto`]) kernel backend.
    pub fn compile_params(
        params: &ParamSet<'_>,
        graph: &Graph,
        mapping: &Mapping,
        platform: &Platform,
    ) -> Result<Self> {
        Self::compile_params_with(params, graph, mapping, platform, KernelBackend::Auto, None)
    }

    /// [`Self::compile_params`] with an explicit kernel backend.
    pub fn compile_params_backend(
        params: &ParamSet<'_>,
        graph: &Graph,
        mapping: &Mapping,
        platform: &Platform,
        backend: KernelBackend,
    ) -> Result<Self> {
        Self::compile_params_with(params, graph, mapping, platform, backend, None)
    }

    /// Full-control compile: explicit backend plus an optional per-conv
    /// algorithm override (see [`QuantPlan::compile_quant_with`]).
    pub fn compile_params_with(
        params: &ParamSet<'_>,
        graph: &Graph,
        mapping: &Mapping,
        platform: &Platform,
        backend: KernelBackend,
        force_algo: Option<ConvAlgo>,
    ) -> Result<Self> {
        Ok(QuantNet {
            plan: QuantPlan::compile_quant_with(
                params, graph, mapping, platform, backend, force_algo,
            )?,
            ws: Mutex::new(Vec::new()),
        })
    }

    /// Distinct arena buffers backing all activation tensors.
    pub fn arena_buffers(&self) -> usize {
        self.plan.arena_buffers()
    }

    /// The concrete ISA this net's kernels dispatch to.
    pub fn isa(&self) -> Isa {
        self.plan.isa()
    }

    /// Per-conv algorithm decisions recorded at compile time.
    pub fn conv_algos(&self) -> Vec<(String, ConvAlgo)> {
        self.plan.conv_algos()
    }

    /// Total heap allocations performed by every pooled scratch so far
    /// (see [`Scratch::alloc_audit`]): converges after the first block
    /// per batch shape, so the delta across steady-state forwards is
    /// zero — the allocation regression tests pin exactly that.
    pub fn scratch_allocs(&self) -> usize {
        self.ws.lock().unwrap().iter().map(Scratch::alloc_audit).sum()
    }

    fn take_ws(&self) -> Scratch {
        self.ws.lock().unwrap().pop().unwrap_or_default()
    }

    fn put_ws(&self, w: Scratch) {
        self.ws.lock().unwrap().push(w);
    }

    /// Forward one batch (NCHW in [0,1]); returns (batch, classes)
    /// logits, moved out of the plan's arena (no trailing clone).
    pub fn forward(&self, x: &[f32], batch: usize) -> Result<Vec<f32>> {
        assert_eq!(x.len(), batch * self.plan.in_elems(), "input size");
        let mut ws = self.take_ws();
        let y = self.plan.run_block(x, batch, &mut ws, None);
        self.put_ws(ws);
        Ok(y)
    }

    /// Single-threaded traced forward: bit-identical numerics to
    /// [`Self::forward`], plus one wall-timed [`KernelSpan`] per plan
    /// node — the engine path the serve loop takes at
    /// [`ObsLevel::Full`](crate::obs::ObsLevel::Full).
    pub fn forward_traced(&self, x: &[f32], batch: usize) -> Result<(Vec<f32>, Vec<KernelSpan>)> {
        assert_eq!(x.len(), batch * self.plan.in_elems(), "input size");
        let mut ws = self.take_ws();
        let out = self.plan.run_block_traced(x, batch, &mut ws);
        self.put_ws(ws);
        Ok(out)
    }

    /// Parallel forward over `pool`. Results are bit-identical to
    /// [`Self::forward`] at every thread count: images are independent,
    /// and channel tiles never split a reduction.
    pub fn forward_pool(&self, x: &[f32], batch: usize, pool: &ThreadPool) -> Result<Vec<f32>> {
        let threads = pool.threads();
        if threads <= 1 || batch <= 1 {
            if threads > 1 && batch == 1 {
                // single image: output-channel-block tiling
                let mut ws = self.take_ws();
                let y = self.plan.run_block_tiled(x, batch, &mut ws, pool);
                self.put_ws(ws);
                return Ok(y);
            }
            return self.forward(x, batch);
        }
        if batch < threads {
            // few images, many threads: per-layer tiling
            let mut ws = self.take_ws();
            let y = self.plan.run_block_tiled(x, batch, &mut ws, pool);
            self.put_ws(ws);
            return Ok(y);
        }
        // batch-block data parallelism: one full plan walk per block
        let ie = self.plan.in_elems();
        let oe = self.plan.out_elems();
        let base = batch / threads;
        let rem = batch % threads;
        let mut blocks = Vec::with_capacity(threads);
        let mut start = 0usize;
        for i in 0..threads {
            let len = base + usize::from(i < rem);
            if len > 0 {
                blocks.push((start, len));
            }
            start += len;
        }
        let outs = pool.scoped_map(blocks, |(s, l)| {
            let mut ws = self.take_ws();
            let y = self.plan.run_block(&x[s * ie..(s + l) * ie], l, &mut ws, None);
            self.put_ws(ws);
            (s, y)
        });
        let mut out = vec![0f32; batch * oe];
        for (s, y) in outs {
            out[s * oe..s * oe + y.len()].copy_from_slice(&y);
        }
        Ok(out)
    }
}

/// Float (quantization-free) forward over folded parameters, recording
/// the per-node maximum post-ReLU activation — the calibration pass
/// that sets each layer's activation scale e^lsa after BN folding
/// (fixed scales collapse deep networks: a 4.0 clip range on layers
/// whose activations live near 0.3 leaves ~5 effective levels of an
/// 8-bit grid, and the error compounds over 20 layers).
///
/// Runs on the planned engine in float mode — the naive duplicate
/// conv/dwconv kernels this function used to carry are gone (the
/// originals survive only as the oracle in `quant::ref`).
pub fn calibrate_act_maxima(
    meta: &ArtifactMeta,
    graph: &Graph,
    values: &[Vec<f32>],
    x: &[f32],
    batch: usize,
) -> Result<BTreeMap<String, f32>> {
    let params = ParamSet::from_meta(meta, values);
    calibrate_act_maxima_params(&params, graph, x, batch)
}

/// [`calibrate_act_maxima`] over any name-indexed parameter set.
pub fn calibrate_act_maxima_params(
    params: &ParamSet<'_>,
    graph: &Graph,
    x: &[f32],
    batch: usize,
) -> Result<BTreeMap<String, f32>> {
    let plan = QuantPlan::compile_float(params, graph)?;
    let mut ws = Scratch::new();
    // the reference pass folds from 0.0 (post-ReLU maxima are >= 0)
    let mut maxima = vec![0f32; plan.n_nodes()];
    let _ = plan.run_block(x, batch, &mut ws, Some(&mut maxima));
    Ok(plan
        .node_names()
        .filter(|&(_, _, tracked)| tracked)
        .map(|(i, name, _)| (name.to_string(), maxima[i]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{resnet20, tinycnn, AIMC, DIG};
    use crate::quant::{
        synth_mapping as random_mapping, synth_mapping_n, synth_params, synth_params_on,
        r#ref::RefNet,
    };
    use crate::util::prng::Pcg32;

    fn random_input(elems: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::new(seed, 91);
        (0..elems).map(|_| rng.next_f32()).collect()
    }

    #[test]
    fn engine_matches_oracle_tinycnn() {
        let g = tinycnn();
        let p = Platform::diana();
        let (names, values) = synth_params(&g, 3);
        let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
        let mapping = random_mapping(&g, 7);
        let net = QuantNet::compile_params(&params, &g, &mapping, &p).unwrap();
        let oracle = RefNet::compile(&params, &g, &mapping, &p).unwrap();
        let (c, h, w) = g.input_shape;
        let x = random_input(4 * c * h * w, 13);
        let got = net.forward(&x, 4).unwrap();
        let want = oracle.forward(&x, 4).unwrap();
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "engine {a} vs oracle {b}");
        }
    }

    #[test]
    fn uniform_mappings_match_oracle() {
        let g = tinycnn();
        let p = Platform::diana();
        let (names, values) = synth_params(&g, 4);
        let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
        let (c, h, w) = g.input_shape;
        let x = random_input(2 * c * h * w, 29);
        for acc in [DIG, AIMC] {
            let mapping = Mapping::uniform(&g, acc);
            let net = QuantNet::compile_params(&params, &g, &mapping, &p).unwrap();
            let oracle = RefNet::compile(&params, &g, &mapping, &p).unwrap();
            let got = net.forward(&x, 2).unwrap();
            let want = oracle.forward(&x, 2).unwrap();
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4, "acc {acc}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn three_acc_engine_matches_oracle() {
        // the 3-accelerator example platform through the full engine:
        // int8 / ternary / int4 channel groups in one layer
        let g = tinycnn();
        let p = Platform::diana_ne16();
        let (names, values) = synth_params_on(&g, &p, 9);
        let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
        let mapping = synth_mapping_n(&g, 3, 5);
        let net = QuantNet::compile_params(&params, &g, &mapping, &p).unwrap();
        let oracle = RefNet::compile(&params, &g, &mapping, &p).unwrap();
        let (c, h, w) = g.input_shape;
        let x = random_input(2 * c * h * w, 71);
        let got = net.forward(&x, 2).unwrap();
        let want = oracle.forward(&x, 2).unwrap();
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "3-acc engine {a} vs oracle {b}");
        }
    }

    #[test]
    fn gap9_no_da_platform_matches_oracle() {
        // gap9 has no IMC unit: no D/A view is ever materialized, and
        // the engine must still match the oracle bit-for-bit
        let g = tinycnn();
        let p = Platform::gap9();
        let (names, values) = synth_params_on(&g, &p, 21);
        let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
        let mapping = synth_mapping_n(&g, 2, 23);
        let net = QuantNet::compile_params(&params, &g, &mapping, &p).unwrap();
        let oracle = RefNet::compile(&params, &g, &mapping, &p).unwrap();
        let (c, h, w) = g.input_shape;
        let x = random_input(2 * c * h * w, 25);
        let got = net.forward(&x, 2).unwrap();
        let want = oracle.forward(&x, 2).unwrap();
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "gap9 engine {a} vs oracle {b}");
        }
    }

    #[test]
    fn mpsoc4_distinct_da_widths_match_oracle() {
        // two IMC macros with different da_bits (7 and 6) in the same
        // layers: the per-width D/A views must reproduce the oracle
        let g = tinycnn();
        let p = Platform::mpsoc4();
        let (names, values) = synth_params_on(&g, &p, 31);
        let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
        let mapping = synth_mapping_n(&g, 4, 37);
        let net = QuantNet::compile_params(&params, &g, &mapping, &p).unwrap();
        let oracle = RefNet::compile(&params, &g, &mapping, &p).unwrap();
        let (c, h, w) = g.input_shape;
        let x = random_input(3 * c * h * w, 41);
        let got = net.forward(&x, 3).unwrap();
        let want = oracle.forward(&x, 3).unwrap();
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "mpsoc4 engine {a} vs oracle {b}");
        }
    }

    #[test]
    fn arena_recycles_buffers_on_deep_graph() {
        let g = resnet20();
        let p = Platform::diana();
        let (names, values) = synth_params(&g, 5);
        let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
        // 67 nodes; the scan must reuse far fewer physical buffers —
        // including under all-AIMC, where every tensor is consumed only
        // through its 7-bit D/A view and must still be recycled
        for acc in [DIG, AIMC] {
            let net =
                QuantNet::compile_params(&params, &g, &Mapping::uniform(&g, acc), &p)
                    .unwrap();
            assert!(
                net.arena_buffers() < g.nodes.len() / 3,
                "acc {acc}: arena {} buffers for {} nodes",
                net.arena_buffers(),
                g.nodes.len()
            );
        }
    }

    #[test]
    fn repeated_forward_is_stable() {
        let g = tinycnn();
        let p = Platform::diana();
        let (names, values) = synth_params(&g, 6);
        let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
        let net =
            QuantNet::compile_params(&params, &g, &random_mapping(&g, 2), &p).unwrap();
        let (c, h, w) = g.input_shape;
        let x = random_input(3 * c * h * w, 31);
        let a = net.forward(&x, 3).unwrap();
        let b = net.forward(&x, 3).unwrap(); // workspace reuse path
        assert_eq!(a, b);
    }

    #[test]
    fn calibrate_matches_reference_pass() {
        let g = tinycnn();
        let (names, values) = synth_params(&g, 8);
        let params = ParamSet::new(names.iter().map(|s| s.as_str()), &values);
        let (c, h, w) = g.input_shape;
        let x = random_input(2 * c * h * w, 17);
        let got = calibrate_act_maxima_params(&params, &g, &x, 2).unwrap();
        let want =
            crate::quant::r#ref::calibrate_act_maxima_ref(&params, &g, &x, 2).unwrap();
        assert_eq!(
            got.keys().collect::<Vec<_>>(),
            want.keys().collect::<Vec<_>>()
        );
        for (k, v) in &got {
            let wv = want[k];
            assert!((v - wv).abs() <= 1e-5 * wv.abs().max(1.0), "{k}: {v} vs {wv}");
        }
    }
}
