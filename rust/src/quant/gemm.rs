//! im2col + cache-blocked GEMM microkernels for the planned inference
//! engine.
//!
//! Numerical contract: every output element accumulates its K products
//! in strictly increasing k order, exactly like the naive direct
//! convolution in `quant::ref` (`for ci { for ky { for kx } } }`), and
//! zero-padded panel entries contribute `acc + 0.0 * w == acc`. The
//! engine is therefore bit-identical to the oracle — M/N register
//! tiling and N cache blocking reorder *independent* outputs only,
//! never the reduction itself.

/// Register tile height (output channels per microkernel call).
/// Shared with the explicit-SIMD mirrors in `super::simd`.
pub(crate) const MR: usize = 4;
/// Register tile width (output pixels per microkernel call) — 16 f32
/// lanes autovectorize to 2-4 SIMD accumulator registers per row.
pub(crate) const NR: usize = 16;
/// Cache block over the panel columns: NB * K floats of the panel stay
/// resident in L1/L2 while the whole A (weight) block streams past.
pub(crate) const NB: usize = 256;

/// C[r, j] = sum_p A[r, p] * B[p, j] for r < m, j < n, p < k.
/// `a` is m x k row-major (packed weights), `b` is k x n row-major (the
/// im2col panel), `c` is m x n row-major and fully overwritten.
pub fn gemm_seqk(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    debug_assert!(a.len() >= m * k);
    debug_assert!(b.len() >= k * n);
    debug_assert!(c.len() >= m * n);
    let mut j0 = 0;
    while j0 < n {
        let jn = (j0 + NB).min(n);
        let mut i0 = 0;
        while i0 + MR <= m {
            let mut j = j0;
            while j + NR <= jn {
                micro_mr_nr(a, b, i0, j, k, n, c);
                j += NR;
            }
            if j < jn {
                edge_rows(a, b, i0, MR, j, jn, k, n, c);
            }
            i0 += MR;
        }
        if i0 < m {
            edge_rows(a, b, i0, m - i0, j0, jn, k, n, c);
        }
        j0 = jn;
    }
}

/// MR x NR register-tiled microkernel; each accumulator runs over the
/// full K sequentially (bit-exact with the scalar loop).
#[inline(always)]
fn micro_mr_nr(a: &[f32], b: &[f32], i0: usize, j0: usize, k: usize, n: usize, c: &mut [f32]) {
    let mut acc = [[0f32; NR]; MR];
    for p in 0..k {
        let brow = &b[p * n + j0..p * n + j0 + NR];
        for (r, accr) in acc.iter_mut().enumerate() {
            let av = a[(i0 + r) * k + p];
            for (cv, &bv) in accr.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        c[(i0 + r) * n + j0..(i0 + r) * n + j0 + NR].copy_from_slice(accr);
    }
}

/// Scalar fallback for row/column remainders (same accumulation order).
/// The `super::simd` kernels call it for their own edge tiles, so the
/// remainder path is one shared implementation across every backend.
#[inline]
#[allow(clippy::too_many_arguments)]
pub(crate) fn edge_rows(
    a: &[f32],
    b: &[f32],
    i0: usize,
    rows: usize,
    j0: usize,
    j1: usize,
    k: usize,
    n: usize,
    c: &mut [f32],
) {
    for r in 0..rows {
        let arow = &a[(i0 + r) * k..(i0 + r + 1) * k];
        for j in j0..j1 {
            let mut acc = 0f32;
            for (p, &av) in arow.iter().enumerate() {
                acc += av * b[p * n + j];
            }
            c[(i0 + r) * n + j] = acc;
        }
    }
}

/// Lower one NCHW image (`x`: cin * hi * wi) into a (cin*k*k) x (oh*ow)
/// panel, row-major, with zeros for out-of-bounds taps. Row order is
/// (ci, ky, kx) — the reduction order of the reference convolution.
#[allow(clippy::too_many_arguments)]
pub fn im2col(
    x: &[f32],
    cin: usize,
    hi: usize,
    wi: usize,
    k: usize,
    stride: usize,
    pad: usize,
    oh: usize,
    ow: usize,
    panel: &mut [f32],
) {
    let n = oh * ow;
    debug_assert!(panel.len() >= cin * k * k * n);
    debug_assert!(x.len() >= cin * hi * wi);
    let mut row = 0;
    for ci in 0..cin {
        let xc = &x[ci * hi * wi..(ci + 1) * hi * wi];
        for ky in 0..k {
            for kx in 0..k {
                let dst = &mut panel[row * n..(row + 1) * n];
                let mut idx = 0;
                for oy in 0..oh {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= hi as isize {
                        dst[idx..idx + ow].fill(0.0);
                        idx += ow;
                        continue;
                    }
                    let xrow = &xc[iy as usize * wi..(iy as usize + 1) * wi];
                    if stride == 1 {
                        // ix = ox + kx - pad: one contiguous valid run
                        let lo = pad.saturating_sub(kx).min(ow); // first valid ox
                        let hi_ox = (wi + pad).saturating_sub(kx).min(ow).max(lo);
                        dst[idx..idx + lo].fill(0.0);
                        if hi_ox > lo {
                            let src0 = lo + kx - pad;
                            dst[idx + lo..idx + hi_ox]
                                .copy_from_slice(&xrow[src0..src0 + (hi_ox - lo)]);
                        }
                        dst[idx + hi_ox..idx + ow].fill(0.0);
                        idx += ow;
                    } else {
                        for ox in 0..ow {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            dst[idx] = if ix < 0 || ix >= wi as isize {
                                0.0
                            } else {
                                xrow[ix as usize]
                            };
                            idx += 1;
                        }
                    }
                }
                row += 1;
            }
        }
    }
}

/// Transpose a (rows=n, cols=k) row-major matrix (e.g. a (batch, cin)
/// activation block) into a k x n panel for the FC GEMM.
pub fn transpose_into(x: &[f32], n: usize, k: usize, panel: &mut [f32]) {
    debug_assert!(x.len() >= n * k);
    debug_assert!(panel.len() >= k * n);
    for j in 0..n {
        let row = &x[j * k..(j + 1) * k];
        for (p, &v) in row.iter().enumerate() {
            panel[p * n + j] = v;
        }
    }
}

/// One depthwise channel: direct conv with a branch-free interior fast
/// path. Tap order is (ky, kx) with out-of-bounds taps skipped — the
/// same sequence of adds as the reference kernel.
#[allow(clippy::too_many_arguments)]
pub fn dwconv_one(
    x: &[f32],
    hi: usize,
    wi: usize,
    w: &[f32],
    k: usize,
    stride: usize,
    pad: usize,
    oh: usize,
    ow: usize,
    out: &mut [f32],
) {
    debug_assert!(x.len() >= hi * wi);
    debug_assert!(w.len() >= k * k);
    debug_assert!(out.len() >= oh * ow);
    // interior output range where every tap is in bounds:
    //   o*stride + 0 - pad >= 0        ->  o >= ceil(pad / stride)
    //   o*stride + k-1 - pad <= dim-1  ->  o <= (dim + pad - k) / stride
    let oy0 = ((pad + stride - 1) / stride).min(oh);
    let oy1 = if hi + pad >= k { ((hi + pad - k) / stride + 1).min(oh) } else { oy0 };
    let ox0 = ((pad + stride - 1) / stride).min(ow);
    let ox1 = if wi + pad >= k { ((wi + pad - k) / stride + 1).min(ow) } else { ox0 };
    for oy in 0..oh {
        let interior_y = (oy0..oy1).contains(&oy);
        for ox in 0..ow {
            if interior_y && (ox0..ox1).contains(&ox) {
                let iy = oy * stride - pad;
                let ix = ox * stride - pad;
                let mut acc = 0f32;
                for ky in 0..k {
                    let xrow = &x[(iy + ky) * wi + ix..(iy + ky) * wi + ix + k];
                    let wrow = &w[ky * k..(ky + 1) * k];
                    for kx in 0..k {
                        acc += xrow[kx] * wrow[kx];
                    }
                }
                out[oy * ow + ox] = acc;
            } else {
                let mut acc = 0f32;
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= hi as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= wi as isize {
                            continue;
                        }
                        acc += x[iy as usize * wi + ix as usize] * w[ky * k + kx];
                    }
                }
                out[oy * ow + ox] = acc;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    fn naive_gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for r in 0..m {
            for j in 0..n {
                let mut acc = 0f32;
                for p in 0..k {
                    acc += a[r * k + p] * b[p * n + j];
                }
                c[r * n + j] = acc;
            }
        }
        c
    }

    #[test]
    fn gemm_bit_exact_vs_naive() {
        let mut rng = Pcg32::new(11, 3);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 7, 5), (4, 16, 16),
                            (5, 27, 33), (17, 64, 300), (16, 288, 64)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
            let mut c = vec![0f32; m * n];
            gemm_seqk(&a, &b, m, k, n, &mut c);
            let want = naive_gemm(&a, &b, m, k, n);
            assert_eq!(c, want, "m={m} k={k} n={n}");
        }
    }

    fn naive_conv_one(
        x: &[f32], cin: usize, hi: usize, wi: usize, w: &[f32], k: usize,
        stride: usize, pad: usize, oh: usize, ow: usize,
    ) -> Vec<f32> {
        let mut out = vec![0f32; oh * ow];
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc = 0f32;
                for ci in 0..cin {
                    for ky in 0..k {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= hi as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= wi as isize {
                                continue;
                            }
                            acc += x[(ci * hi + iy as usize) * wi + ix as usize]
                                * w[(ci * k + ky) * k + kx];
                        }
                    }
                }
                out[oy * ow + ox] = acc;
            }
        }
        out
    }

    #[test]
    fn im2col_gemm_matches_direct_conv() {
        let mut rng = Pcg32::new(5, 9);
        for &(cin, hi, wi, k, stride, pad) in &[
            (3usize, 8usize, 8usize, 3usize, 1usize, 1usize),
            (4, 7, 5, 3, 2, 1),
            (2, 6, 6, 1, 1, 0),
            (1, 9, 9, 3, 1, 0),
            (5, 10, 10, 3, 2, 0),
        ] {
            let oh = (hi + 2 * pad - k) / stride + 1;
            let ow = (wi + 2 * pad - k) / stride + 1;
            let x: Vec<f32> = (0..cin * hi * wi).map(|_| rng.next_f32()).collect();
            let w: Vec<f32> = (0..cin * k * k).map(|_| rng.next_f32() - 0.5).collect();
            let kk = cin * k * k;
            let n = oh * ow;
            let mut panel = vec![0f32; kk * n];
            im2col(&x, cin, hi, wi, k, stride, pad, oh, ow, &mut panel);
            let mut got = vec![0f32; n];
            gemm_seqk(&w, &panel, 1, kk, n, &mut got);
            let want = naive_conv_one(&x, cin, hi, wi, &w, k, stride, pad, oh, ow);
            assert_eq!(got, want, "cin={cin} k={k} s={stride} p={pad}");
        }
    }

    #[test]
    fn dwconv_interior_matches_checked() {
        let mut rng = Pcg32::new(21, 2);
        for &(hi, wi, k, stride, pad) in &[
            (8usize, 8usize, 3usize, 1usize, 1usize),
            (7, 9, 3, 2, 1),
            (5, 5, 5, 1, 2),
            (4, 4, 3, 1, 0),
            (3, 3, 3, 1, 2),
        ] {
            let oh = (hi + 2 * pad - k) / stride + 1;
            let ow = (wi + 2 * pad - k) / stride + 1;
            let x: Vec<f32> = (0..hi * wi).map(|_| rng.next_f32()).collect();
            let w: Vec<f32> = (0..k * k).map(|_| rng.next_f32() - 0.5).collect();
            let mut got = vec![0f32; oh * ow];
            dwconv_one(&x, hi, wi, &w, k, stride, pad, oh, ow, &mut got);
            let want = naive_conv_one(&x, 1, hi, wi, &w, k, stride, pad, oh, ow);
            assert_eq!(got, want, "hw=({hi},{wi}) k={k} s={stride} p={pad}");
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let x: Vec<f32> = (0..12).map(|v| v as f32).collect(); // 3 x 4
        let mut p = vec![0f32; 12]; // 4 x 3
        transpose_into(&x, 3, 4, &mut p);
        for j in 0..3 {
            for q in 0..4 {
                assert_eq!(p[q * 3 + j], x[j * 4 + q]);
            }
        }
    }
}
