//! The ODiMO coordinator — the paper's system contribution, in rust.
//!
//! * [`mapping`] — the channel→accelerator assignment object
//! * [`trainer`] — drives the AOT train/eval executables (schedules,
//!   temperature annealing, metrics)
//! * [`fold`] — BatchNorm folding (float → search transition)
//! * [`discretize`] — argmax-alpha mapping extraction
//! * [`partition`] — the Fig.-3 layer re-organization pass
//! * [`scheduler`] — dispatch onto the DIANA simulator
//! * [`baselines`] — All-8bit / All-Ternary / IO-8bit / Min-Cost
//! * [`search`] — the full pipeline + lambda sweep (Fig. 4 / Fig. 5)

pub mod baselines;
pub mod discretize;
pub mod fold;
pub mod mapping;
pub mod partition;
pub mod scheduler;
pub mod search;
pub mod trainer;

pub use mapping::Mapping;
pub use search::{Pipeline, Regularizer, Schedule, SearchPoint};
pub use trainer::{EvalResult, Hyper, StepMetrics, Trainer};
