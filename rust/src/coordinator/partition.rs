//! Layer re-organization pass (paper Fig. 3).
//!
//! After discretization the channels assigned to one accelerator are
//! generally interleaved; deployment wants them contiguous so each
//! layer splits into N independent sub-layers whose outputs simply
//! concatenate in the shared L1 (no data marshaling). The pass
//! computes one channel permutation per *activation tensor*, permutes
//! every per-channel parameter of its producers, and compensates every
//! consumer by permuting its input-channel axis. Network function is
//! preserved exactly (pinned by the HLO cross-check in
//! tests/pipeline_e2e.rs).
//!
//! Residual constraint (not spelled out in the paper): tensors joined
//! by an `add` — both inputs and the output — must share a single
//! permutation, as must a depthwise conv's input and output (channel
//! `c` maps to channel `c`). We union those tensors into groups and
//! derive the group's permutation from its earliest mappable producer;
//! other producers in the group may end up with their channels split
//! into more than one contiguous run ("fragments", reported per layer
//! and charged by the scheduler). The network output (fc) keeps the
//! identity permutation so class order is preserved.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::model::{Graph, NodeDef, Op};
use crate::runtime::ArtifactMeta;

use super::mapping::Mapping;

/// Result of the reorganization pass.
pub struct Partitioned {
    /// Per-tensor (node output) channel permutation: out[i] = old[perm[i]].
    pub perms: BTreeMap<String, Vec<usize>>,
    /// Parameter snapshot with every per-channel leaf permuted.
    pub values: Vec<Vec<f32>>,
    /// The permuted mapping (grouped where the group leader allowed it).
    pub mapping: Mapping,
    /// Contiguous same-accelerator runs per mappable layer (1 or 2 = the
    /// ideal Fig.-3 outcome; more = fragmented secondary producer).
    pub fragments: BTreeMap<String, usize>,
}

/// Union-find over node names.
struct Uf {
    parent: BTreeMap<String, String>,
}

impl Uf {
    fn new(names: impl Iterator<Item = String>) -> Self {
        Uf { parent: names.map(|n| (n.clone(), n)).collect() }
    }

    /// Iterative two-pass find with path compression — the recursive
    /// form could blow the stack on the long union chains deep residual
    /// graphs produce (one group can thread through every block).
    fn find(&mut self, x: &str) -> String {
        let mut root = x.to_string();
        loop {
            let p = &self.parent[root.as_str()];
            if *p == root {
                break;
            }
            root = p.clone();
        }
        let mut cur = x.to_string();
        while cur != root {
            let next = self.parent[cur.as_str()].clone();
            self.parent.insert(cur, root.clone());
            cur = next;
        }
        root
    }

    fn union(&mut self, a: &str, b: &str) {
        let ra = self.find(a);
        let rb = self.find(b);
        if ra != rb {
            self.parent.insert(ra, rb);
        }
    }
}

/// Stable permutation putting digital (0) channels first.
fn group_perm(assign: &[u8]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..assign.len()).collect();
    idx.sort_by_key(|&i| (assign[i], i));
    idx
}

fn contiguous_runs(assign: &[u8]) -> usize {
    if assign.is_empty() {
        return 0;
    }
    1 + assign.windows(2).filter(|w| w[0] != w[1]).count()
}

pub fn partition(
    meta: &ArtifactMeta,
    graph: &Graph,
    mapping: &Mapping,
    values: &[Vec<f32>],
) -> Result<Partitioned> {
    let n_acc = meta.hw.n_acc();
    mapping.validate(graph, n_acc)?;
    let leaf_idx: BTreeMap<&str, usize> = meta
        .params
        .iter()
        .enumerate()
        .map(|(i, p)| (p.name.as_str(), i))
        .collect();
    let get = |node: &str, leaf: &str| leaf_idx.get(format!("{node}/{leaf}").as_str()).copied();

    // ---- 1. group tensors that must share a permutation ---------------
    let mut uf = Uf::new(graph.nodes.iter().map(|n| n.name.clone()));
    for n in &graph.nodes {
        match n.op {
            Op::Add => {
                uf.union(&n.inputs[0], &n.name);
                uf.union(&n.inputs[1], &n.name);
            }
            Op::DwConv | Op::Gap => {
                uf.union(&n.inputs[0], &n.name);
            }
            _ => {}
        }
    }

    // ---- 2. pick the permutation per group -----------------------------
    // leader = earliest mappable producer in the group; fc (the network
    // output) forces identity.
    let output_name = &graph.nodes.last().unwrap().name;
    let out_root = uf.find(output_name);
    let mut group_perms: BTreeMap<String, Vec<usize>> = BTreeMap::new();
    for n in &graph.nodes {
        if !n.mappable() {
            continue;
        }
        let root = uf.find(&n.name);
        if root == out_root {
            continue; // identity for the class-ordered output
        }
        group_perms
            .entry(root)
            .or_insert_with(|| group_perm(mapping.layer(&n.name)));
    }

    // resolve per-tensor permutation (identity when group has none)
    let mut perms: BTreeMap<String, Vec<usize>> = BTreeMap::new();
    for n in &graph.nodes {
        let root = uf.find(&n.name);
        let ident: Vec<usize> = (0..n.cout).collect();
        let p = group_perms.get(&root).cloned().unwrap_or(ident.clone());
        let p = if p.len() == n.cout { p } else { ident };
        perms.insert(n.name.clone(), p);
    }
    // the input tensor is never permuted (image channel order is fixed)
    perms.insert(
        graph.nodes[0].name.clone(),
        (0..graph.nodes[0].cout).collect(),
    );

    // ---- 3. permute parameters ----------------------------------------
    let mut out_values = values.to_vec();
    let permute_rows = |v: &mut Vec<f32>, perm: &[usize]| {
        let c = perm.len();
        let stride = v.len() / c;
        let mut nv = vec![0f32; v.len()];
        for (new_i, &old_i) in perm.iter().enumerate() {
            nv[new_i * stride..(new_i + 1) * stride]
                .copy_from_slice(&v[old_i * stride..(old_i + 1) * stride]);
        }
        *v = nv;
    };
    let permute_cols = |v: &mut Vec<f32>, rows: usize, perm: &[usize], inner: usize| {
        let c = perm.len();
        debug_assert_eq!(v.len(), rows * c * inner);
        let mut nv = vec![0f32; v.len()];
        for r in 0..rows {
            for (new_j, &old_j) in perm.iter().enumerate() {
                let dst = (r * c + new_j) * inner;
                let src = (r * c + old_j) * inner;
                nv[dst..dst + inner].copy_from_slice(&v[src..src + inner]);
            }
        }
        *v = nv;
    };

    for n in &graph.nodes {
        if !matches!(n.op, Op::Conv | Op::DwConv | Op::Fc) {
            continue;
        }
        let out_perm = &perms[&n.name];
        // output-channel leaves of the producer
        for leaf in ["w", "b", "gamma", "beta", "rm", "rv"] {
            if let Some(i) = get(&n.name, leaf) {
                permute_rows(&mut out_values[i], out_perm);
            }
        }
        if let Some(i) = get(&n.name, "alpha") {
            // (n_acc, C): permute the channel axis (columns); the row
            // count comes from the leaf itself, not a global constant
            let rows = out_values[i].len() / n.cout.max(1);
            permute_cols(&mut out_values[i], rows, out_perm, 1);
        }
        // input-channel fixup from the producer of our input tensor
        let in_perm = &perms[&n.inputs[0]];
        if n.op == Op::Conv || n.op == Op::Fc {
            if let Some(i) = get(&n.name, "w") {
                let k2 = n.k * n.k;
                let rows = n.cout;
                if n.op == Op::Fc {
                    permute_cols(&mut out_values[i], rows, in_perm, 1);
                } else {
                    permute_cols(&mut out_values[i], rows, in_perm, k2);
                }
            }
        }
        // dwconv: channel axis is both in and out; out_perm == in_perm by
        // the union, and the row permutation above already applied it.
    }

    // ---- 4. permuted mapping + fragment counts -------------------------
    let mut new_assign = BTreeMap::new();
    let mut fragments = BTreeMap::new();
    for n in graph.mappable() {
        let perm = &perms[&n.name];
        let old = mapping.layer(&n.name);
        let reordered: Vec<u8> = perm.iter().map(|&i| old[i]).collect();
        fragments.insert(n.name.clone(), contiguous_runs(&reordered));
        new_assign.insert(n.name.clone(), reordered);
    }
    let new_mapping = Mapping { assign: new_assign };
    new_mapping.validate(graph, n_acc)?;

    Ok(Partitioned { perms, values: out_values, mapping: new_mapping, fragments })
}

/// Sub-layers of one mappable layer after partitioning: contiguous
/// (accelerator, start, len) runs — what actually gets dispatched.
pub fn sublayers(node: &NodeDef, assign: &[u8]) -> Vec<(u8, usize, usize)> {
    assert_eq!(assign.len(), node.cout);
    let mut out = Vec::new();
    let mut start = 0usize;
    for i in 1..=assign.len() {
        if i == assign.len() || assign[i] != assign[start] {
            out.push((assign[start], start, i - start));
            start = i;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{tinycnn, AIMC, DIG};
    use crate::util::prng::Pcg32;

    fn random_mapping(g: &Graph, seed: u64) -> Mapping {
        let mut rng = Pcg32::new(seed, 1);
        let mut m = Mapping::uniform(g, DIG);
        for n in g.mappable() {
            let ids = (0..n.cout)
                .map(|_| if rng.next_f32() < 0.5 { DIG as u8 } else { AIMC as u8 })
                .collect();
            m.assign.insert(n.name.clone(), ids);
        }
        m
    }

    #[test]
    fn uf_find_survives_long_chains() {
        // a pathological 200k-deep parent chain: the old recursive find
        // overflowed the stack here; the two-pass loop must not.
        let n = 200_000usize;
        let mut uf = Uf::new((0..n).map(|i| i.to_string()));
        for i in 0..n - 1 {
            uf.parent.insert(i.to_string(), (i + 1).to_string());
        }
        assert_eq!(uf.find("0"), (n - 1).to_string());
        // compressed: a second find is a direct hop
        assert_eq!(uf.parent["0"], (n - 1).to_string());
        assert_eq!(uf.find("12345"), (n - 1).to_string());
    }

    #[test]
    fn group_perm_is_bijection_and_groups() {
        let assign = vec![1, 0, 1, 0, 0, 1];
        let p = group_perm(&assign);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..6).collect::<Vec<_>>());
        let reordered: Vec<u8> = p.iter().map(|&i| assign[i]).collect();
        assert_eq!(reordered, vec![0, 0, 0, 1, 1, 1]);
    }

    #[test]
    fn contiguous_runs_counts() {
        assert_eq!(contiguous_runs(&[0, 0, 1, 1]), 2);
        assert_eq!(contiguous_runs(&[0, 1, 0]), 3);
        assert_eq!(contiguous_runs(&[1, 1, 1]), 1);
    }

    #[test]
    fn sublayers_cover_everything() {
        let g = tinycnn();
        let n = g.node("c1").unwrap();
        let assign: Vec<u8> = (0..n.cout).map(|i| (i % 3 == 0) as u8).collect();
        let subs = sublayers(n, &assign);
        let total: usize = subs.iter().map(|s| s.2).sum();
        assert_eq!(total, n.cout);
        // adjacent runs alternate accelerator
        for w in subs.windows(2) {
            assert_ne!(w[0].0, w[1].0);
        }
    }

    // full partition tests that need artifacts live in
    // rust/tests/pipeline_e2e.rs (HLO equality cross-check)
}
