//! Deployment scheduler: dispatches a partitioned network onto the
//! DIANA SoC simulator.
//!
//! Per mappable layer the (post-partition) assignment decomposes into
//! contiguous sub-layers; both accelerators start in parallel on their
//! sub-layers (paper Sec. III-A: parallel execution minimizes both time
//! and idle energy). Fragmented secondary producers (see partition.rs)
//! pay one extra weight-DMA term per extra fragment on the digital
//! side — the AIMC cell-programming term is already per-tile.

use std::collections::BTreeMap;

use crate::hw::soc::{simulate, ChannelSplit, RunReport, SocConfig};
use crate::model::Graph;

use super::mapping::Mapping;
use super::partition::sublayers;

#[derive(Clone, Debug)]
pub struct DeployReport {
    pub run: RunReport,
    /// Extra digital DMA cycles charged for fragmentation.
    pub fragment_overhead_cycles: u64,
    pub fragments: BTreeMap<String, usize>,
}

/// Cost a mapping on the simulator, including fragmentation overhead.
pub fn deploy(graph: &Graph, mapping: &Mapping, cfg: SocConfig) -> DeployReport {
    let split: ChannelSplit = mapping.channel_split();
    let run = simulate(graph, &split, cfg);
    // fragmentation: each extra digital fragment refills the PE weight
    // registers once more (the second addend of Eq. 7 per fragment)
    let mut overhead = 0u64;
    let mut fragments = BTreeMap::new();
    for node in graph.mappable() {
        let assign = mapping.layer(&node.name);
        let subs = sublayers(node, assign);
        fragments.insert(node.name.clone(), subs.len());
        let dig_frags = subs.iter().filter(|s| s.0 == crate::model::DIG as u8).count();
        if dig_frags > 1 {
            let (cd, _) = split[&node.name];
            // extra DMA = (frags-1) * per-channel weight load already in
            // Eq. 7's second term, approximated as proportional share
            let dma_total = node.cin as u64 * cd as u64 * (node.k * node.k) as u64;
            overhead += (dig_frags as u64 - 1) * dma_total / (cd.max(1) as u64);
        }
    }
    DeployReport { run, fragment_overhead_cycles: overhead, fragments }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::soc::SocConfig;
    use crate::model::{tinycnn, AIMC, DIG};

    #[test]
    fn contiguous_mapping_no_overhead() {
        let g = tinycnn();
        let mut m = Mapping::uniform(&g, DIG);
        // grouped: first half digital, second half aimc
        for n in g.mappable() {
            let mut ids = vec![DIG as u8; n.cout];
            ids[n.cout / 2..].fill(AIMC as u8);
            m.assign.insert(n.name.clone(), ids);
        }
        let rep = deploy(&g, &m, SocConfig::default());
        assert_eq!(rep.fragment_overhead_cycles, 0);
        assert!(rep.fragments.values().all(|&f| f <= 2));
    }

    #[test]
    fn interleaved_mapping_pays_overhead() {
        let g = tinycnn();
        let mut m = Mapping::uniform(&g, DIG);
        for n in g.mappable() {
            let ids = (0..n.cout).map(|i| (i % 2) as u8).collect();
            m.assign.insert(n.name.clone(), ids);
        }
        let rep = deploy(&g, &m, SocConfig::default());
        assert!(rep.fragment_overhead_cycles > 0);
        assert!(rep.fragments.values().any(|&f| f > 2));
    }

    #[test]
    fn report_matches_simulator() {
        let g = tinycnn();
        let m = Mapping::uniform(&g, DIG);
        let rep = deploy(&g, &m, SocConfig::default());
        let direct = simulate(&g, &m.channel_split(), SocConfig::default());
        assert_eq!(rep.run.total_cycles, direct.total_cycles);
        assert_eq!(rep.run.energy_uj, direct.energy_uj);
    }
}
