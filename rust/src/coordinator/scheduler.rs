//! Deployment scheduler: dispatches a partitioned network onto the
//! platform's SoC simulator.
//!
//! Per mappable layer the (post-partition) assignment decomposes into
//! contiguous sub-layers; all accelerators start in parallel on their
//! sub-layers (paper Sec. III-A: parallel execution minimizes both time
//! and idle energy). Fragmented secondary producers (see partition.rs)
//! pay one extra weight-DMA term per extra fragment on every PE-array
//! accelerator — IMC cell-programming terms are already per-tile.

use std::collections::BTreeMap;

use crate::hw::soc::{simulate, ChannelSplit, RunReport, SocConfig};
use crate::hw::{LatencyModel, Platform};
use crate::model::Graph;

use super::mapping::Mapping;
use super::partition::sublayers;

#[derive(Clone, Debug)]
pub struct DeployReport {
    pub run: RunReport,
    /// Extra weight-DMA cycles charged for fragmentation.
    pub fragment_overhead_cycles: u64,
    pub fragments: BTreeMap<String, usize>,
}

/// Cost a mapping on the simulator, including fragmentation overhead.
///
/// Crate-internal since the `api::Session` facade landed: external
/// callers go through [`Session::deploy`](crate::api::Session::deploy),
/// which adds validation and carries the session's simulator config.
pub(crate) fn deploy(
    graph: &Graph,
    mapping: &Mapping,
    platform: &Platform,
    cfg: SocConfig,
) -> DeployReport {
    let n_acc = platform.n_acc();
    let split: ChannelSplit = mapping.channel_split(n_acc);
    let run = simulate(graph, &split, platform, cfg);
    // fragmentation: each extra fragment on a PE-array accelerator
    // refills its weight registers once more (the second addend of the
    // Eq.-7-style model per fragment)
    let mut overhead = 0u64;
    let mut fragments = BTreeMap::new();
    for node in graph.mappable() {
        let assign = mapping.layer(&node.name);
        let subs = sublayers(node, assign);
        fragments.insert(node.name.clone(), subs.len());
        for (acc, spec) in platform.accelerators.iter().enumerate() {
            if !matches!(spec.latency, LatencyModel::DigitalPe { .. }) {
                continue;
            }
            let acc_frags = subs.iter().filter(|s| s.0 as usize == acc).count();
            if acc_frags > 1 {
                let c = split[&node.name][acc];
                // extra DMA = (frags-1) * per-channel weight load already
                // in the model's second term, as a proportional share
                let dma_total = node.cin as u64 * c as u64 * (node.k * node.k) as u64;
                overhead += (acc_frags as u64 - 1) * dma_total / (c.max(1) as u64);
            }
        }
    }
    DeployReport { run, fragment_overhead_cycles: overhead, fragments }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::soc::SocConfig;
    use crate::model::{tinycnn, AIMC, DIG};

    #[test]
    fn contiguous_mapping_no_overhead() {
        let g = tinycnn();
        let p = Platform::diana();
        let mut m = Mapping::uniform(&g, DIG);
        // grouped: first half digital, second half aimc
        for n in g.mappable() {
            let mut ids = vec![DIG as u8; n.cout];
            ids[n.cout / 2..].fill(AIMC as u8);
            m.assign.insert(n.name.clone(), ids);
        }
        let rep = deploy(&g, &m, &p, SocConfig::default());
        assert_eq!(rep.fragment_overhead_cycles, 0);
        assert!(rep.fragments.values().all(|&f| f <= 2));
    }

    #[test]
    fn interleaved_mapping_pays_overhead() {
        let g = tinycnn();
        let p = Platform::diana();
        let mut m = Mapping::uniform(&g, DIG);
        for n in g.mappable() {
            let ids = (0..n.cout).map(|i| (i % 2) as u8).collect();
            m.assign.insert(n.name.clone(), ids);
        }
        let rep = deploy(&g, &m, &p, SocConfig::default());
        assert!(rep.fragment_overhead_cycles > 0);
        assert!(rep.fragments.values().any(|&f| f > 2));
    }

    #[test]
    fn report_matches_simulator() {
        let g = tinycnn();
        let p = Platform::diana();
        let m = Mapping::uniform(&g, DIG);
        let rep = deploy(&g, &m, &p, SocConfig::default());
        let direct = simulate(&g, &m.channel_split(2), &p, SocConfig::default());
        assert_eq!(rep.run.total_cycles, direct.total_cycles);
        assert_eq!(rep.run.energy_uj, direct.energy_uj);
    }

    #[test]
    fn four_acc_water_filled_deploy() {
        // water-filling min-cost end-to-end on the 4-unit MPSoC: the
        // contiguous-run mapping deploys without fragmentation overhead
        let g = tinycnn();
        let p = Platform::mpsoc4();
        let m = crate::coordinator::baselines::min_cost(
            &g,
            &p,
            crate::coordinator::baselines::CostObjective::Latency,
        );
        m.validate(&g, 4).unwrap();
        let rep = deploy(&g, &m, &p, SocConfig::default());
        assert_eq!(rep.run.util.len(), 4);
        assert_eq!(rep.fragment_overhead_cycles, 0, "contiguous runs never fragment");
        assert!(rep.run.total_cycles > 0);
    }

    #[test]
    fn three_acc_deploy_reports_all_units() {
        let g = tinycnn();
        let p = Platform::diana_ne16();
        let m = crate::coordinator::baselines::even_split(&g, 3);
        let rep = deploy(&g, &m, &p, SocConfig::default());
        assert_eq!(rep.run.util.len(), 3);
        assert!(rep.run.util.iter().all(|&u| u > 0.0), "{:?}", rep.run.util);
        // interleaved round-robin fragments across three units
        assert!(rep.fragments.values().any(|&f| f > 3));
        assert!(rep.fragment_overhead_cycles > 0);
    }
}
