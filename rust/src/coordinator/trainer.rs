//! Training orchestrator: drives the AOT train/eval executables through
//! the ODiMO phases. All schedule logic (lr decay, softmax-temperature
//! annealing, early stopping) lives here in rust — the lowered graphs
//! take every hyper-parameter as a runtime scalar.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::xla::Literal;

use crate::data::DataSource;
use crate::runtime::{
    assemble_inputs, literal_f32, literal_i32, literal_scalar, literal_to_f32,
    ArtifactMeta, ParamState, Runtime,
};

use super::fold::fold_bn;
use super::mapping::Mapping;

/// Hyper-parameters of one training phase (runtime inputs to the step).
#[derive(Clone, Copy, Debug)]
pub struct Hyper {
    pub lr: f32,
    pub lr_alpha: f32,
    pub mu: f32,
    pub wd: f32,
    pub lam: f32,
    /// Softmax temperature annealed linearly tau_start -> tau_end.
    pub tau_start: f32,
    pub tau_end: f32,
    /// Cosine-decay the lr to lr*lr_min_frac over the phase.
    pub lr_min_frac: f32,
}

impl Default for Hyper {
    fn default() -> Self {
        Hyper {
            lr: 0.05,
            lr_alpha: 0.05,
            mu: 0.9,
            wd: 1e-4,
            lam: 0.0,
            tau_start: 1.0,
            tau_end: 1.0,
            lr_min_frac: 0.1,
        }
    }
}

/// Metrics of one optimizer step (the graph's 6-vector).
#[derive(Clone, Copy, Debug, Default)]
pub struct StepMetrics {
    pub loss: f32,
    pub batch_acc: f32,
    pub lat_cycles: f32,
    pub energy_mw_cycles: f32,
    pub reg: f32,
}

pub struct Trainer<'a> {
    pub rt: &'a Runtime,
    pub meta: &'a ArtifactMeta,
    pub params: ParamState,
    pub mom: ParamState,
    train_ds: DataSource,
    test_ds: DataSource,
    next_sample: u64,
    pub history: Vec<StepMetrics>,
}

impl<'a> Trainer<'a> {
    pub fn new(rt: &'a Runtime, meta: &'a ArtifactMeta, data_seed: u64) -> Result<Self> {
        Ok(Trainer {
            rt,
            meta,
            params: ParamState::from_init(meta)?,
            mom: ParamState::zeros(meta)?,
            train_ds: DataSource::train(&meta.model, data_seed),
            test_ds: DataSource::test(&meta.model, data_seed),
            next_sample: 0,
            history: Vec::new(),
        })
    }

    /// Replace parameters with a host snapshot (checkpoint restore).
    pub fn set_params(&mut self, values: Vec<Vec<f32>>) -> Result<()> {
        self.params = ParamState::from_host(self.meta, values)?;
        self.mom = ParamState::zeros(self.meta)?;
        Ok(())
    }

    /// Fold BN into conv weights (float -> search transition), calibrate
    /// the per-layer activation scales on real data (PTQ-style: e^lsa =
    /// observed max post-ReLU activation), and reset the optimizer
    /// state. Without calibration, deep models collapse at the quantized
    /// starting point (see quant::infer::calibrate_act_maxima).
    pub fn fold_batchnorm(&mut self) -> Result<()> {
        let mut values = self.params.to_host()?;
        fold_bn(self.meta, &self.meta.model, &mut values)?;
        let g = &self.meta.model;
        let bt = g.train_batch.min(32);
        let batch = self.train_ds.batch(0, bt);
        let maxima = crate::quant::infer::calibrate_act_maxima(
            self.meta, g, &values, &batch.x, bt,
        )?;
        for (node, m) in &maxima {
            if let Ok(i) = self.meta.param_index(&format!("{node}/lsa")) {
                values[i][0] = (m * 1.02 + 1e-6).ln();
            }
        }
        log::debug!("act calibration: {maxima:?}");
        self.set_params(values)
    }

    fn assign_literals(&self, mapping: &Mapping) -> Result<BTreeMap<String, Literal>> {
        // accelerator count per the artifact contract (the AOT graphs'
        // alpha/assign tensors), not a compile-time constant
        let n_acc = self.meta.hw.n_acc();
        let mut out = BTreeMap::new();
        for name in &self.meta.mappable {
            let n = self
                .meta
                .model
                .node(name)
                .ok_or_else(|| anyhow!("mappable node {name} not in graph"))?;
            out.insert(
                name.clone(),
                literal_f32(&mapping.onehot(name, n_acc), &[n_acc, n.cout])?,
            );
        }
        Ok(out)
    }

    /// Run `steps` optimizer steps of `graph` (one of the train_*
    /// artifacts). `mapping` supplies the hard assignment for deploy-mode
    /// graphs; `hw` the flat [thpt.., p_act.., p_idle..] vector for the
    /// abstract-hw search graph (6 entries on the 2-accelerator
    /// artifacts).
    pub fn run_phase(
        &mut self,
        graph: &str,
        steps: usize,
        h: Hyper,
        mapping: Option<&Mapping>,
        hw: Option<&[f32]>,
    ) -> Result<Vec<StepMetrics>> {
        let exe = self.rt.load(self.meta.graph(graph)?)?;
        let assigns = match mapping {
            Some(m) => Some(self.assign_literals(m)?),
            None => None,
        };
        let hw_lit = match hw {
            Some(v) => Some(literal_f32(v, &[v.len()])?),
            None => None,
        };
        let bt = self.meta.model.train_batch;
        let (c, hh, ww) = self.meta.model.input_shape;
        let mu = literal_scalar(h.mu);
        let wd = literal_scalar(h.wd);
        let lam = literal_scalar(h.lam);
        let mut phase_metrics = Vec::with_capacity(steps);

        for step in 0..steps {
            let frac = if steps <= 1 { 0.0 } else { step as f32 / (steps - 1) as f32 };
            // cosine lr decay, linear tau anneal
            let cos = 0.5 * (1.0 + (std::f32::consts::PI * frac).cos());
            let lr_now = h.lr * (h.lr_min_frac + (1.0 - h.lr_min_frac) * cos);
            let lr_a_now = h.lr_alpha * (h.lr_min_frac + (1.0 - h.lr_min_frac) * cos);
            let tau_now = h.tau_start + (h.tau_end - h.tau_start) * frac;
            let lr = literal_scalar(lr_now);
            let lr_a = literal_scalar(lr_a_now);
            let tau = literal_scalar(tau_now);

            let batch = self.train_ds.batch(self.next_sample, bt);
            self.next_sample += bt as u64;
            let xb = literal_f32(&batch.x, &[bt, c, hh, ww])?;
            let yb = literal_i32(&batch.y, &[bt])?;

            let inputs = assemble_inputs(&exe.meta, |tm| match tm.name.as_str() {
                "x" => Ok(&xb),
                "y" => Ok(&yb),
                "lr" => Ok(&lr),
                "lr_alpha" => Ok(&lr_a),
                "mu" => Ok(&mu),
                "wd" => Ok(&wd),
                "lam" => Ok(&lam),
                "tau" => Ok(&tau),
                "hw" => hw_lit.as_ref().ok_or_else(|| anyhow!("graph needs hw vector")),
                n if n.starts_with("param:") => self.params.leaf(&n[6..]),
                n if n.starts_with("mom:") => self.mom.leaf(&n[4..]),
                n if n.starts_with("assign:") => assigns
                    .as_ref()
                    .and_then(|a| a.get(&n[7..]))
                    .ok_or_else(|| anyhow!("graph needs assignment for {n}")),
                n => Err(anyhow!("unexpected input '{n}'")),
            })?;
            let mut out = exe.run(&inputs)?;
            self.params.replace_from_outputs(&mut out);
            self.mom.replace_from_outputs(&mut out);
            let met = literal_to_f32(&out[0])?;
            let m = StepMetrics {
                loss: met[0],
                batch_acc: met[1] / bt as f32,
                lat_cycles: met[2],
                energy_mw_cycles: met[3],
                reg: met[4],
            };
            if !m.loss.is_finite() {
                return Err(anyhow!("{graph}: loss diverged at step {step}"));
            }
            if step % 20 == 0 || step + 1 == steps {
                log::debug!(
                    "{graph} step {step}/{steps}: loss {:.4} acc {:.3} reg {:.4}",
                    m.loss,
                    m.batch_acc,
                    m.reg
                );
            }
            phase_metrics.push(m);
            self.history.push(m);
        }
        Ok(phase_metrics)
    }

    /// Evaluate on `n_batches` of the held-out split.
    /// graph: eval_float | eval_search | eval_deploy.
    pub fn eval(&self, graph: &str, mapping: Option<&Mapping>, n_batches: usize) -> Result<EvalResult> {
        let exe = self.rt.load(self.meta.graph(graph)?)?;
        let assigns = match mapping {
            Some(m) => Some(self.assign_literals(m)?),
            None => None,
        };
        let be = self.meta.model.eval_batch;
        let (c, hh, ww) = self.meta.model.input_shape;
        let mut correct = 0.0f64;
        let mut loss_sum = 0.0f64;
        let mut n = 0usize;
        for b in 0..n_batches {
            let batch = self.test_ds.batch((b * be) as u64, be);
            let xb = literal_f32(&batch.x, &[be, c, hh, ww])?;
            let yb = literal_i32(&batch.y, &[be])?;
            let inputs = assemble_inputs(&exe.meta, |tm| match tm.name.as_str() {
                "x" => Ok(&xb),
                "y" => Ok(&yb),
                n if n.starts_with("param:") => self.params.leaf(&n[6..]),
                n if n.starts_with("assign:") => assigns
                    .as_ref()
                    .and_then(|a| a.get(&n[7..]))
                    .ok_or_else(|| anyhow!("graph needs assignment for {n}")),
                n => Err(anyhow!("unexpected input '{n}'")),
            })?;
            let out = exe.run_to_host(&inputs)?;
            let stats = &out[out.len() - 1];
            correct += stats[0] as f64;
            loss_sum += stats[1] as f64;
            n += be;
        }
        Ok(EvalResult { accuracy: correct / n as f64, avg_loss: loss_sum / n as f64, samples: n })
    }

    /// Download the current per-layer alpha logits: name -> (n_acc rows
    /// flattened, row-major) vectors, n_acc per the artifact contract
    /// (`meta.hw.n_acc()`).
    pub fn alphas(&self) -> Result<BTreeMap<String, Vec<f32>>> {
        let mut out = BTreeMap::new();
        for name in &self.meta.mappable {
            out.insert(name.clone(), self.params.leaf_to_host(&format!("{name}/alpha"))?);
        }
        Ok(out)
    }
}

#[derive(Clone, Copy, Debug)]
pub struct EvalResult {
    pub accuracy: f64,
    pub avg_loss: f64,
    pub samples: usize,
}
