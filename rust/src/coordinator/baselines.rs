//! Baseline mappings (paper Sec. IV-A), platform-generic:
//!
//! * **All-8bit** / **All-Ternary** — everything on accelerator 0 / 1
//!   (the DIANA digital / AIMC units on DIANA-family platforms).
//! * **IO-8bit / Backbone-Ternary** — the DIANA authors' rule of thumb:
//!   first and last layers on accelerator 0, everything in between on
//!   accelerator 1.
//! * **Even-Split** — channels round-robined over every platform
//!   accelerator (the N-accelerator smoke baseline).
//! * **Min-Cost** — ODiMO's channel-wise granularity, but statically
//!   minimizing Eq. 3 (latency) or Eq. 4 (energy) with no accuracy
//!   term; ties maximize earlier accelerators ("digital channels are
//!   maximized since this is expected to improve accuracy").

use crate::hw::Platform;
use crate::model::{Graph, NodeDef, AIMC, DIG};

use super::mapping::Mapping;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CostObjective {
    Latency,
    Energy,
}

pub fn all_8bit(graph: &Graph) -> Mapping {
    Mapping::uniform(graph, DIG)
}

pub fn all_ternary(graph: &Graph) -> Mapping {
    Mapping::uniform(graph, AIMC)
}

/// First and last mappable layers on accelerator 0, backbone on 1.
pub fn io8_backbone_ternary(graph: &Graph) -> Mapping {
    let mappable = graph.mappable();
    let n = mappable.len();
    let mut m = Mapping::uniform(graph, AIMC);
    if n > 0 {
        let first = &mappable[0].name;
        let last = &mappable[n - 1].name;
        m.assign.insert(first.clone(), vec![DIG as u8; mappable[0].cout]);
        m.assign.insert(last.clone(), vec![DIG as u8; mappable[n - 1].cout]);
    }
    m
}

/// Channels round-robined across all `n_acc` accelerators.
pub fn even_split(graph: &Graph, n_acc: usize) -> Mapping {
    let mut m = Mapping::uniform(graph, 0);
    for node in graph.mappable() {
        let ids = (0..node.cout).map(|c| (c % n_acc) as u8).collect();
        m.assign.insert(node.name.clone(), ids);
    }
    m
}

/// Per-layer cost of a candidate count vector under the objective.
fn layer_cost(
    platform: &Platform,
    node: &NodeDef,
    counts: &[usize],
    lats: &mut [u64],
    objective: CostObjective,
) -> f64 {
    for (i, &c) in counts.iter().enumerate() {
        lats[i] = platform.layer_cycles(i, node, c as u64);
    }
    let span = lats.iter().copied().max().unwrap_or(0) as f64;
    match objective {
        CostObjective::Latency => span,
        CostObjective::Energy => {
            let mut cost = 0.0;
            for (i, spec) in platform.accelerators.iter().enumerate() {
                cost += spec.p_act_mw * lats[i] as f64;
                cost += spec.p_idle_mw * (span - lats[i] as f64);
            }
            cost
        }
    }
}

/// Enumeration granularity keeping the per-layer composition count
/// bounded on platforms with many accelerators: the number of
/// compositions of `cout` channels in multiples of `step` over `n_acc`
/// units is C(cout/step + n - 1, n - 1), which explodes for n > 3.
/// Step 1 (exact enumeration) is preserved for every realistic
/// (cout <= 512, n <= 3) case — including the built-in platforms —
/// so the historical tie-break behavior is unchanged there.
fn enum_step(cout: usize, n_acc: usize) -> usize {
    const LIMIT: f64 = 300_000.0;
    let mut step = 1usize;
    loop {
        let m = (cout / step) as f64;
        let mut comps = 1.0f64;
        for i in 0..n_acc.saturating_sub(1) {
            comps *= (m + i as f64 + 1.0) / (i as f64 + 1.0);
        }
        if comps <= LIMIT || step >= cout.max(1) {
            return step;
        }
        step *= 2;
    }
}

/// Enumerate channel-count compositions of `rem` over accelerators
/// `acc..n_acc` (in multiples of `step`, plus the exact remainder),
/// earlier accelerators taking the larger share first so that
/// strict-improvement keeps the earliest (digital-heaviest) split on
/// ties.
#[allow(clippy::too_many_arguments)]
fn min_cost_layer(
    platform: &Platform,
    node: &NodeDef,
    objective: CostObjective,
    acc: usize,
    rem: usize,
    step: usize,
    counts: &mut Vec<usize>,
    lats: &mut [u64],
    best: &mut Option<(f64, Vec<usize>)>,
) {
    let n_acc = platform.n_acc();
    if acc == n_acc - 1 {
        counts[acc] = rem;
        let cost = layer_cost(platform, node, counts, lats, objective);
        match best {
            Some((b, _)) if cost >= *b => {}
            _ => *best = Some((cost, counts.clone())),
        }
        return;
    }
    // candidates: rem itself, then multiples of step descending (for
    // step == 1 this is exactly rem, rem-1, ..., 0)
    let mut c = rem;
    loop {
        counts[acc] = c;
        min_cost_layer(platform, node, objective, acc + 1, rem - c, step, counts, lats,
                       best);
        if c == 0 {
            break;
        }
        let top = (rem / step) * step;
        c = if c == rem && top != rem { top } else { c.saturating_sub(step) };
    }
}

/// Channel-wise static cost minimization. Per layer, enumerate every
/// split (cout <= 512 for all benchmarks, so exhaustive search is exact
/// and, for the 2-3 accelerator platforms modeled here, instant; many-
/// accelerator TOML platforms fall back to a coarser channel
/// granularity, see [`enum_step`]) and keep the cheapest; ties pick the
/// split with the most channels on the earliest accelerators.
pub fn min_cost(graph: &Graph, platform: &Platform, objective: CostObjective) -> Mapping {
    let n_acc = platform.n_acc();
    let mut m = Mapping::uniform(graph, 0);
    let mut lats = vec![0u64; n_acc];
    for node in graph.mappable() {
        let mut best: Option<(f64, Vec<usize>)> = None;
        let mut counts = vec![0usize; n_acc];
        let step = enum_step(node.cout, n_acc);
        min_cost_layer(platform, node, objective, 0, node.cout, step, &mut counts,
                       &mut lats, &mut best);
        let (_, counts) = best.expect("at least one composition");
        // contiguous runs: acc 0 channels first, then acc 1, ...
        let mut ids = Vec::with_capacity(node.cout);
        for (i, &c) in counts.iter().enumerate() {
            ids.extend(std::iter::repeat(i as u8).take(c));
        }
        m.assign.insert(node.name.clone(), ids);
    }
    m
}

/// All baselines by name (experiment drivers / CLI).
pub fn by_name(graph: &Graph, platform: &Platform, name: &str) -> Option<Mapping> {
    Some(match name {
        "all_8bit" => all_8bit(graph),
        "all_ternary" => all_ternary(graph),
        "io8_backbone_ternary" => io8_backbone_ternary(graph),
        "even_split" => even_split(graph, platform.n_acc()),
        "min_cost_lat" => min_cost(graph, platform, CostObjective::Latency),
        "min_cost_en" => min_cost(graph, platform, CostObjective::Energy),
        _ => return None,
    })
}

pub const BASELINE_NAMES: [&str; 6] = [
    "all_8bit",
    "all_ternary",
    "io8_backbone_ternary",
    "even_split",
    "min_cost_lat",
    "min_cost_en",
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::soc::{simulate, SocConfig};
    use crate::model::{resnet20, tinycnn};

    #[test]
    fn io8_structure() {
        let g = resnet20();
        let m = io8_backbone_ternary(&g);
        assert!(m.layer("stem").iter().all(|&v| v == DIG as u8));
        assert!(m.layer("fc").iter().all(|&v| v == DIG as u8));
        assert!(m.layer("b4_conv1").iter().all(|&v| v == AIMC as u8));
        m.validate(&g, 2).unwrap();
    }

    #[test]
    fn min_cost_latency_beats_all_single_acc() {
        let g = resnet20();
        let p = Platform::diana();
        let cfg = SocConfig::default();
        let lat = |m: &Mapping| simulate(&g, &m.channel_split(2), &p, cfg).total_cycles;
        let mc = lat(&min_cost(&g, &p, CostObjective::Latency));
        assert!(mc <= lat(&all_8bit(&g)));
        assert!(mc <= lat(&all_ternary(&g)));
    }

    #[test]
    fn min_cost_energy_beats_all_8bit() {
        let g = resnet20();
        let p = Platform::diana();
        let cfg = SocConfig::default();
        let en = |m: &Mapping| simulate(&g, &m.channel_split(2), &p, cfg).energy_uj;
        assert!(en(&min_cost(&g, &p, CostObjective::Energy)) <= en(&all_8bit(&g)));
    }

    #[test]
    fn min_cost_mostly_aimc_on_big_layers() {
        // the AIMC macro dominates, so min-cost should push most
        // channels analog (paper Table I: Min-Cost = 97.5% A.Ch.)
        let g = resnet20();
        let m = min_cost(&g, &Platform::diana(), CostObjective::Latency);
        assert!(m.aimc_fraction() > 0.6, "aimc frac {}", m.aimc_fraction());
    }

    #[test]
    fn ties_prefer_digital() {
        // a hypothetical layer where several splits tie: tinycnn fc is
        // tiny; just assert validity + digital-heavy under energy
        let g = tinycnn();
        let m = min_cost(&g, &Platform::diana(), CostObjective::Energy);
        m.validate(&g, 2).unwrap();
    }

    #[test]
    fn min_cost_three_acc_uses_best_units() {
        let g = resnet20();
        let p = Platform::diana_ne16();
        let m = min_cost(&g, &p, CostObjective::Latency);
        m.validate(&g, 3).unwrap();
        // the 3-acc optimum can only improve on the 2-acc optimum
        let m2 = min_cost(&g, &Platform::diana(), CostObjective::Latency);
        let cfg = SocConfig::default();
        let l3 = simulate(&g, &m.channel_split(3), &p, cfg).total_cycles;
        let l2 = simulate(&g, &m2.channel_split(3), &p, cfg).total_cycles;
        assert!(l3 <= l2, "3-acc min_cost {l3} worse than 2-acc {l2}");
    }

    #[test]
    fn enum_step_exact_for_builtin_platforms() {
        // every benchmark layer (cout <= 512) enumerates exactly on the
        // 2- and 3-accelerator built-ins; only many-unit custom
        // platforms coarsen
        assert_eq!(enum_step(512, 2), 1);
        assert_eq!(enum_step(512, 3), 1);
        assert_eq!(enum_step(64, 3), 1);
        assert!(enum_step(512, 6) > 1);
    }

    #[test]
    fn even_split_covers_all_units() {
        let g = resnet20();
        let m = even_split(&g, 3);
        m.validate(&g, 3).unwrap();
        let f = m.channel_frac(3);
        assert!(f.iter().all(|&x| x > 0.2), "{f:?}");
    }

    #[test]
    fn by_name_covers_all() {
        let g = tinycnn();
        let p = Platform::diana();
        for n in BASELINE_NAMES {
            assert!(by_name(&g, &p, n).is_some(), "{n}");
        }
        assert!(by_name(&g, &p, "nope").is_none());
    }
}
