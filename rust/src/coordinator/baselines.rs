//! Baseline mappings (paper Sec. IV-A), platform-generic:
//!
//! * **All-8bit** / **All-Ternary** — everything on accelerator 0 / 1
//!   (the DIANA digital / AIMC units on DIANA-family platforms).
//! * **IO-8bit / Backbone-Ternary** — the DIANA authors' rule of thumb:
//!   first and last layers on accelerator 0, everything in between on
//!   accelerator 1.
//! * **Even-Split** — channels round-robined over every platform
//!   accelerator (the N-accelerator smoke baseline).
//! * **Min-Cost** — ODiMO's channel-wise granularity, but statically
//!   minimizing Eq. 3 (latency) or Eq. 4 (energy) with no accuracy
//!   term; ties maximize earlier accelerators ("digital channels are
//!   maximized since this is expected to improve accuracy").
//!
//! # Min-cost algorithms
//!
//! [`min_cost`] no longer brute-forces N-way channel compositions
//! (`O(cout^(N-1))` per layer). Per objective:
//!
//! * **Latency** — *water-filling*: binary-search the minimal feasible
//!   span `T`, where a span is feasible iff the per-unit channel
//!   capacities `cap_i(T) = max{c : lat_i(c) <= T}` (each a binary
//!   search over a monotone latency model) sum to at least `cout`;
//!   then fill units in platform order up to their capacity. Exact for
//!   every accelerator count, `O(N log(cout) log(latmax))` per layer,
//!   and reproduces the enumerator's lexicographic tie-break (earlier
//!   units maximized) by construction.
//! * **Energy** — a *bounded-granularity Pareto DP* over units: state =
//!   channels assigned so far, value = the Pareto set of
//!   `(weighted-latency sum, running max latency)` prefixes (dominated
//!   prefixes can never complete into a cheaper split, because the
//!   idle-power term is monotone in the span). The final candidates are
//!   re-scored with the same cost function as the enumerator, so on
//!   platforms where the grid is exact (step 1 — every built-in) the
//!   minimal cost is identical to exhaustive enumeration. On many-unit
//!   platforms the channel granularity coarsens (see `dp_step`) to
//!   keep the DP polynomial; the last unit always absorbs the exact
//!   remainder, so splits conserve channels at every granularity.
//!
//! The historical exhaustive enumerator survives as [`min_cost_enum`]:
//! the parity oracle for differential tests
//! (`tests/coordinator_props.rs`) and the slow side of
//! `benches/bench_mincost.rs`.

#![deny(missing_docs)]

use crate::hw::Platform;
use crate::model::{Graph, NodeDef, AIMC, DIG};

use super::mapping::Mapping;

/// Which static cost `min_cost` minimizes per layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CostObjective {
    /// Paper Eq. 3: the per-layer span (max accelerator latency).
    Latency,
    /// Paper Eq. 4: active + idle energy over the per-layer span.
    Energy,
}

/// Everything on accelerator 0 (the DIANA int8 digital unit).
pub fn all_8bit(graph: &Graph) -> Mapping {
    Mapping::uniform(graph, DIG)
}

/// Everything on accelerator 1 (the DIANA ternary AIMC macro).
pub fn all_ternary(graph: &Graph) -> Mapping {
    Mapping::uniform(graph, AIMC)
}

/// First and last mappable layers on accelerator 0, backbone on 1.
pub fn io8_backbone_ternary(graph: &Graph) -> Mapping {
    let mappable = graph.mappable();
    let n = mappable.len();
    let mut m = Mapping::uniform(graph, AIMC);
    if n > 0 {
        let first = &mappable[0].name;
        let last = &mappable[n - 1].name;
        m.assign.insert(first.clone(), vec![DIG as u8; mappable[0].cout]);
        m.assign.insert(last.clone(), vec![DIG as u8; mappable[n - 1].cout]);
    }
    m
}

/// Channels round-robined across all `n_acc` accelerators.
pub fn even_split(graph: &Graph, n_acc: usize) -> Mapping {
    let mut m = Mapping::uniform(graph, 0);
    for node in graph.mappable() {
        let ids = (0..node.cout).map(|c| (c % n_acc) as u8).collect();
        m.assign.insert(node.name.clone(), ids);
    }
    m
}

/// Per-layer cost of a candidate count vector under the objective.
fn layer_cost(
    platform: &Platform,
    node: &NodeDef,
    counts: &[usize],
    lats: &mut [u64],
    objective: CostObjective,
) -> f64 {
    for (i, &c) in counts.iter().enumerate() {
        lats[i] = platform.layer_cycles(i, node, c as u64);
    }
    let span = lats.iter().copied().max().unwrap_or(0) as f64;
    match objective {
        CostObjective::Latency => span,
        CostObjective::Energy => {
            let mut cost = 0.0;
            for (i, spec) in platform.accelerators.iter().enumerate() {
                cost += spec.p_act_mw * lats[i] as f64;
                cost += spec.p_idle_mw * (span - lats[i] as f64);
            }
            cost
        }
    }
}

// ---- water-filling (latency objective) --------------------------------

/// Largest channel count `c <= cout` whose latency on `acc` stays
/// within `span` (binary search; every latency model is monotone
/// nondecreasing in the assigned channel count).
fn cap_within(platform: &Platform, node: &NodeDef, acc: usize, cout: usize, span: u64) -> usize {
    if platform.layer_cycles(acc, node, cout as u64) <= span {
        return cout;
    }
    // invariant: lat(lo) <= span < lat(hi)
    let (mut lo, mut hi) = (0usize, cout);
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        if platform.layer_cycles(acc, node, mid as u64) <= span {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Exact latency-optimal split by water-filling: binary-search the
/// minimal feasible span, then fill units in platform order (the
/// lexicographically largest minimizer — the enumerator's tie-break).
fn water_fill_counts(platform: &Platform, node: &NodeDef) -> Vec<usize> {
    let n_acc = platform.n_acc();
    let cout = node.cout;
    if n_acc == 1 {
        return vec![cout];
    }
    let feasible = |span: u64| -> bool {
        let mut total = 0usize;
        for acc in 0..n_acc {
            total += cap_within(platform, node, acc, cout, span);
            if total >= cout {
                return true;
            }
        }
        false
    };
    // putting every channel on the single fastest unit is feasible
    let mut hi = (0..n_acc)
        .map(|acc| platform.layer_cycles(acc, node, cout as u64))
        .min()
        .unwrap_or(0);
    let mut lo = 0u64;
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if feasible(mid) {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    let span = lo;
    let mut counts = vec![0usize; n_acc];
    let mut rem = cout;
    for (acc, c) in counts.iter_mut().enumerate() {
        *c = cap_within(platform, node, acc, cout, span).min(rem);
        rem -= *c;
    }
    debug_assert_eq!(rem, 0, "water-filling must conserve channels");
    counts
}

// ---- Pareto DP (energy objective) -------------------------------------

/// One Pareto-optimal prefix: channel counts for the units processed so
/// far, their weighted active-energy sum, and their running max latency.
struct DpEntry {
    wsum: f64,
    max_lat: u64,
    counts: Vec<usize>,
}

/// Channel granularity of the energy DP: step 1 (exact) whenever the
/// worst-case transition count fits the budget — which covers every
/// built-in platform at benchmark widths (`cout <= 512`, `N <= 4` after
/// coarsening only above N=3) — doubling otherwise. The final unit
/// always takes the exact remainder, so coarse grids still conserve
/// channels (regression-pinned in `tests/coordinator_props.rs`).
fn dp_step(cout: usize, n_acc: usize) -> usize {
    const LIMIT: f64 = 600_000.0;
    let mut step = 1usize;
    loop {
        let m = (cout / step) as f64 + 1.0;
        if m * m * (n_acc as f64 - 1.0) <= LIMIT || step >= cout.max(1) {
            return step;
        }
        step *= 2;
    }
}

/// Insert `e` into a Pareto bucket: drop it if a kept entry weakly
/// dominates it in `(wsum, max_lat)` (on full equality the
/// lexicographically larger counts win — the enumerator's preference
/// for earlier units), and evict entries it dominates.
fn push_pruned(bucket: &mut Vec<DpEntry>, e: DpEntry) {
    for q in bucket.iter() {
        if q.wsum <= e.wsum
            && q.max_lat <= e.max_lat
            && (q.wsum < e.wsum || q.max_lat < e.max_lat || q.counts >= e.counts)
        {
            return;
        }
    }
    bucket.retain(|q| {
        !(e.wsum <= q.wsum
            && e.max_lat <= q.max_lat
            && (e.wsum < q.wsum || e.max_lat < q.max_lat || e.counts > q.counts))
    });
    bucket.push(e);
}

/// Energy-optimal split via the bounded-granularity Pareto DP; final
/// candidates are re-scored through `layer_cost` so the selected cost
/// (and the tie-break) matches exhaustive enumeration wherever the grid
/// is exact.
fn energy_dp_counts(platform: &Platform, node: &NodeDef) -> Vec<usize> {
    let n_acc = platform.n_acc();
    let cout = node.cout;
    if n_acc == 1 {
        return vec![cout];
    }
    let step = dp_step(cout, n_acc);
    let mut cands: Vec<usize> = (0..=cout).step_by(step).collect();
    if *cands.last().unwrap() != cout {
        cands.push(cout); // the whole layer on one unit is always a candidate
    }
    let dp_weight: Vec<f64> = platform
        .accelerators
        .iter()
        .map(|a| a.p_act_mw - a.p_idle_mw)
        .collect();

    // unit 0 seeds one prefix per candidate count
    let mut buckets: Vec<Vec<DpEntry>> = Vec::with_capacity(cout + 1);
    buckets.resize_with(cout + 1, Vec::new);
    for &c in &cands {
        let lat = platform.layer_cycles(0, node, c as u64);
        buckets[c].push(DpEntry {
            wsum: dp_weight[0] * lat as f64,
            max_lat: lat,
            counts: vec![c],
        });
    }
    // middle units extend prefixes; the last unit is handled exactly
    for acc in 1..n_acc - 1 {
        let mut next: Vec<Vec<DpEntry>> = Vec::with_capacity(cout + 1);
        next.resize_with(cout + 1, Vec::new);
        for (b, bucket) in buckets.iter().enumerate() {
            if bucket.is_empty() {
                continue;
            }
            for &c in &cands {
                if b + c > cout {
                    break;
                }
                let lat = platform.layer_cycles(acc, node, c as u64);
                for e in bucket {
                    let mut counts = e.counts.clone();
                    counts.push(c);
                    push_pruned(
                        &mut next[b + c],
                        DpEntry {
                            wsum: e.wsum + dp_weight[acc] * lat as f64,
                            max_lat: e.max_lat.max(lat),
                            counts,
                        },
                    );
                }
            }
        }
        buckets = next;
    }
    // last unit absorbs the exact remainder; re-score candidates with
    // the enumerator's cost function (identical f64 accumulation order)
    let mut lats = vec![0u64; n_acc];
    let mut best: Option<(f64, Vec<usize>)> = None;
    for (b, bucket) in buckets.iter().enumerate() {
        for e in bucket {
            let mut counts = e.counts.clone();
            counts.push(cout - b);
            let cost = layer_cost(platform, node, &counts, &mut lats, CostObjective::Energy);
            let better = match &best {
                // the enumerator's rule: strictly cheaper wins; exact
                // ties go to the lexicographically larger split
                Some((bc, bv)) => cost < *bc || (cost == *bc && counts > *bv),
                None => true,
            };
            if better {
                best = Some((cost, counts));
            }
        }
    }
    best.expect("at least one composition").1
}

// ---- retained exhaustive enumerator (parity oracle) -------------------

/// Enumeration granularity keeping the per-layer composition count
/// bounded on platforms with many accelerators: the number of
/// compositions of `cout` channels in multiples of `step` over `n_acc`
/// units is C(cout/step + n - 1, n - 1), which explodes for n > 3.
/// Step 1 (exact enumeration) is preserved for every realistic
/// (cout <= 512, n <= 3) case — including the 2- and 3-unit built-in
/// platforms — so the historical tie-break behavior is unchanged there.
fn enum_step(cout: usize, n_acc: usize) -> usize {
    const LIMIT: f64 = 300_000.0;
    let mut step = 1usize;
    loop {
        let m = (cout / step) as f64;
        let mut comps = 1.0f64;
        for i in 0..n_acc.saturating_sub(1) {
            comps *= (m + i as f64 + 1.0) / (i as f64 + 1.0);
        }
        if comps <= LIMIT || step >= cout.max(1) {
            return step;
        }
        step *= 2;
    }
}

/// Enumerate channel-count compositions of `rem` over accelerators
/// `acc..n_acc` (in multiples of `step`, plus the exact remainder),
/// earlier accelerators taking the larger share first so that
/// strict-improvement keeps the earliest (digital-heaviest) split on
/// ties.
#[allow(clippy::too_many_arguments)]
fn min_cost_layer(
    platform: &Platform,
    node: &NodeDef,
    objective: CostObjective,
    acc: usize,
    rem: usize,
    step: usize,
    counts: &mut Vec<usize>,
    lats: &mut [u64],
    best: &mut Option<(f64, Vec<usize>)>,
) {
    let n_acc = platform.n_acc();
    if acc == n_acc - 1 {
        counts[acc] = rem;
        let cost = layer_cost(platform, node, counts, lats, objective);
        match best {
            Some((b, _)) if cost >= *b => {}
            _ => *best = Some((cost, counts.clone())),
        }
        return;
    }
    // candidates: rem itself, then multiples of step descending (for
    // step == 1 this is exactly rem, rem-1, ..., 0)
    let mut c = rem;
    loop {
        counts[acc] = c;
        min_cost_layer(platform, node, objective, acc + 1, rem - c, step, counts, lats,
                       best);
        if c == 0 {
            break;
        }
        let top = (rem / step) * step;
        c = if c == rem && top != rem { top } else { c.saturating_sub(step) };
    }
}

// ---- public min-cost API ----------------------------------------------

/// Per-layer min-cost split on the fast path: water-filling for
/// [`CostObjective::Latency`], the Pareto DP for
/// [`CostObjective::Energy`]. Counts are in platform accelerator order
/// and always sum to `node.cout`.
pub fn layer_counts(
    platform: &Platform,
    node: &NodeDef,
    objective: CostObjective,
) -> Vec<usize> {
    match objective {
        CostObjective::Latency => water_fill_counts(platform, node),
        CostObjective::Energy => energy_dp_counts(platform, node),
    }
}

/// Per-layer min-cost split by exhaustive composition enumeration (the
/// historical algorithm) — the parity oracle for [`layer_counts`].
pub fn layer_counts_enum(
    platform: &Platform,
    node: &NodeDef,
    objective: CostObjective,
) -> Vec<usize> {
    let n_acc = platform.n_acc();
    let mut lats = vec![0u64; n_acc];
    let mut best: Option<(f64, Vec<usize>)> = None;
    let mut counts = vec![0usize; n_acc];
    let step = enum_step(node.cout, n_acc);
    min_cost_layer(platform, node, objective, 0, node.cout, step, &mut counts, &mut lats,
                   &mut best);
    best.expect("at least one composition").1
}

/// Channel-wise static cost minimization (the Min-Cost baseline),
/// computed per layer on the fast path ([`layer_counts`]): exact
/// water-filling under the latency objective, the bounded-granularity
/// Pareto DP under energy. Ties pick the split with the most channels
/// on the earliest accelerators. Differential parity against the
/// retained enumerator is pinned in `tests/coordinator_props.rs`.
pub fn min_cost(graph: &Graph, platform: &Platform, objective: CostObjective) -> Mapping {
    let mut m = Mapping::uniform(graph, 0);
    for node in graph.mappable() {
        let counts = layer_counts(platform, node, objective);
        m.set_layer_counts(&node.name, &counts);
    }
    m
}

/// Min-cost by exhaustive per-layer composition enumeration — the
/// pre-water-filling algorithm, kept verbatim as the differential
/// oracle and the slow side of `make bench-mincost`. `O(cout^(N-1))`
/// per layer (granularity-coarsened above ~300k compositions, see
/// `enum_step`); use [`min_cost`] everywhere else.
pub fn min_cost_enum(graph: &Graph, platform: &Platform, objective: CostObjective) -> Mapping {
    let mut m = Mapping::uniform(graph, 0);
    for node in graph.mappable() {
        let counts = layer_counts_enum(platform, node, objective);
        m.set_layer_counts(&node.name, &counts);
    }
    m
}

/// Cost of an explicit per-unit channel-count vector under `objective`
/// — the quantity both min-cost implementations minimize (exposed for
/// differential tests and `bench_mincost`).
pub fn cost_of_counts(
    platform: &Platform,
    node: &NodeDef,
    counts: &[usize],
    objective: CostObjective,
) -> f64 {
    let mut lats = vec![0u64; platform.n_acc()];
    layer_cost(platform, node, counts, &mut lats, objective)
}

/// All baselines by name (experiment drivers / CLI).
pub fn by_name(graph: &Graph, platform: &Platform, name: &str) -> Option<Mapping> {
    Some(match name {
        "all_8bit" => all_8bit(graph),
        "all_ternary" => all_ternary(graph),
        "io8_backbone_ternary" => io8_backbone_ternary(graph),
        "even_split" => even_split(graph, platform.n_acc()),
        "min_cost_lat" => min_cost(graph, platform, CostObjective::Latency),
        "min_cost_en" => min_cost(graph, platform, CostObjective::Energy),
        _ => return None,
    })
}

/// Names accepted by [`by_name`] (CLI `--baseline` values).
pub const BASELINE_NAMES: [&str; 6] = [
    "all_8bit",
    "all_ternary",
    "io8_backbone_ternary",
    "even_split",
    "min_cost_lat",
    "min_cost_en",
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::soc::{simulate, SocConfig};
    use crate::model::{resnet20, tinycnn};

    #[test]
    fn io8_structure() {
        let g = resnet20();
        let m = io8_backbone_ternary(&g);
        assert!(m.layer("stem").iter().all(|&v| v == DIG as u8));
        assert!(m.layer("fc").iter().all(|&v| v == DIG as u8));
        assert!(m.layer("b4_conv1").iter().all(|&v| v == AIMC as u8));
        m.validate(&g, 2).unwrap();
    }

    #[test]
    fn min_cost_latency_beats_all_single_acc() {
        let g = resnet20();
        let p = Platform::diana();
        let cfg = SocConfig::default();
        let lat = |m: &Mapping| simulate(&g, &m.channel_split(2), &p, cfg).total_cycles;
        let mc = lat(&min_cost(&g, &p, CostObjective::Latency));
        assert!(mc <= lat(&all_8bit(&g)));
        assert!(mc <= lat(&all_ternary(&g)));
    }

    #[test]
    fn min_cost_energy_beats_all_8bit() {
        let g = resnet20();
        let p = Platform::diana();
        let cfg = SocConfig::default();
        let en = |m: &Mapping| simulate(&g, &m.channel_split(2), &p, cfg).energy_uj;
        assert!(en(&min_cost(&g, &p, CostObjective::Energy)) <= en(&all_8bit(&g)));
    }

    #[test]
    fn min_cost_mostly_aimc_on_big_layers() {
        // the AIMC macro dominates, so min-cost should push most
        // channels analog (paper Table I: Min-Cost = 97.5% A.Ch.)
        let g = resnet20();
        let m = min_cost(&g, &Platform::diana(), CostObjective::Latency);
        assert!(m.aimc_fraction() > 0.6, "aimc frac {}", m.aimc_fraction());
    }

    #[test]
    fn ties_prefer_digital() {
        // a hypothetical layer where several splits tie: tinycnn fc is
        // tiny; just assert validity + digital-heavy under energy
        let g = tinycnn();
        let m = min_cost(&g, &Platform::diana(), CostObjective::Energy);
        m.validate(&g, 2).unwrap();
    }

    #[test]
    fn min_cost_three_acc_uses_best_units() {
        let g = resnet20();
        let p = Platform::diana_ne16();
        let m = min_cost(&g, &p, CostObjective::Latency);
        m.validate(&g, 3).unwrap();
        // the 3-acc optimum can only improve on the 2-acc optimum
        let m2 = min_cost(&g, &Platform::diana(), CostObjective::Latency);
        let cfg = SocConfig::default();
        let l3 = simulate(&g, &m.channel_split(3), &p, cfg).total_cycles;
        let l2 = simulate(&g, &m2.channel_split(3), &p, cfg).total_cycles;
        assert!(l3 <= l2, "3-acc min_cost {l3} worse than 2-acc {l2}");
    }

    #[test]
    fn enum_step_exact_for_builtin_platforms() {
        // every benchmark layer (cout <= 512) enumerates exactly on the
        // 2- and 3-accelerator built-ins; only many-unit custom
        // platforms coarsen
        assert_eq!(enum_step(512, 2), 1);
        assert_eq!(enum_step(512, 3), 1);
        assert_eq!(enum_step(64, 3), 1);
        assert!(enum_step(512, 6) > 1);
    }

    #[test]
    fn dp_step_exact_for_builtin_platforms() {
        assert_eq!(dp_step(512, 2), 1);
        assert_eq!(dp_step(512, 3), 1);
        assert_eq!(dp_step(64, 4), 1);
        assert!(dp_step(512, 6) > 1);
    }

    #[test]
    fn water_fill_matches_enum_on_diana_models() {
        for g in [tinycnn(), resnet20()] {
            for p in [Platform::diana(), Platform::diana_ne16()] {
                let fast = min_cost(&g, &p, CostObjective::Latency);
                let slow = min_cost_enum(&g, &p, CostObjective::Latency);
                assert_eq!(fast, slow, "{} on {}", g.name, p.name);
            }
        }
    }

    #[test]
    fn energy_dp_cost_matches_enum_on_diana_models() {
        for g in [tinycnn(), resnet20()] {
            for p in [Platform::diana(), Platform::diana_ne16()] {
                let n = p.n_acc();
                let mut lats = vec![0u64; n];
                for node in g.mappable() {
                    let fast = layer_counts(&p, node, CostObjective::Energy);
                    let slow = layer_counts_enum(&p, node, CostObjective::Energy);
                    let cf = layer_cost(&p, node, &fast, &mut lats, CostObjective::Energy);
                    let cs = layer_cost(&p, node, &slow, &mut lats, CostObjective::Energy);
                    assert!(
                        (cf - cs).abs() <= 1e-9 * cs.abs().max(1.0),
                        "{} {} on {}: {cf} vs {cs}",
                        g.name,
                        node.name,
                        p.name
                    );
                }
            }
        }
    }

    #[test]
    fn even_split_covers_all_units() {
        let g = resnet20();
        let m = even_split(&g, 3);
        m.validate(&g, 3).unwrap();
        let f = m.channel_frac(3);
        assert!(f.iter().all(|&x| x > 0.2), "{f:?}");
    }

    #[test]
    fn by_name_covers_all() {
        let g = tinycnn();
        let p = Platform::diana();
        for n in BASELINE_NAMES {
            assert!(by_name(&g, &p, n).is_some(), "{n}");
        }
        assert!(by_name(&g, &p, "nope").is_none());
    }
}
