//! Baseline mappings (paper Sec. IV-A):
//!
//! * **All-8bit** / **All-Ternary** — everything on one accelerator.
//! * **IO-8bit / Backbone-Ternary** — the DIANA authors' rule of thumb:
//!   first and last layers on the 8-bit digital accelerator, everything
//!   in between ternary on the AIMC macro.
//! * **Min-Cost** — ODiMO's channel-wise granularity, but statically
//!   minimizing Eq. 3 (latency) or Eq. 4 (energy) with no accuracy term;
//!   ties maximize digital channels ("since this is expected to improve
//!   accuracy").

use crate::hw::energy::{P_ACT, P_IDLE};
use crate::hw::latency::layer_lats;
use crate::model::{Graph, AIMC, DIG};

use super::mapping::Mapping;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CostObjective {
    Latency,
    Energy,
}

pub fn all_8bit(graph: &Graph) -> Mapping {
    Mapping::uniform(graph, DIG)
}

pub fn all_ternary(graph: &Graph) -> Mapping {
    Mapping::uniform(graph, AIMC)
}

/// First and last mappable layers digital, backbone ternary.
pub fn io8_backbone_ternary(graph: &Graph) -> Mapping {
    let mappable = graph.mappable();
    let n = mappable.len();
    let mut m = Mapping::uniform(graph, AIMC);
    if n > 0 {
        let first = &mappable[0].name;
        let last = &mappable[n - 1].name;
        m.assign.insert(first.clone(), vec![DIG as u8; mappable[0].cout]);
        m.assign.insert(last.clone(), vec![DIG as u8; mappable[n - 1].cout]);
    }
    m
}

/// Channel-wise static cost minimization. Per layer, enumerate every
/// split (cout <= 512 for all benchmarks, so exhaustive search is
/// exact and instant) and keep the cheapest; ties pick the split with
/// the most digital channels.
pub fn min_cost(graph: &Graph, objective: CostObjective) -> Mapping {
    let mut m = Mapping::uniform(graph, DIG);
    for node in graph.mappable() {
        let mut best_cd = node.cout;
        let mut best_cost = f64::INFINITY;
        for cd in (0..=node.cout).rev() {
            // reverse order: at equal cost, the larger cd (seen first)
            // is kept -> digital maximized on ties
            let ca = node.cout - cd;
            let (ld, la) = layer_lats(node, cd as u64, ca as u64);
            let span = ld.max(la) as f64;
            let cost = match objective {
                CostObjective::Latency => span,
                CostObjective::Energy => {
                    P_ACT[DIG] * ld as f64
                        + P_IDLE[DIG] * (span - ld as f64)
                        + P_ACT[AIMC] * la as f64
                        + P_IDLE[AIMC] * (span - la as f64)
                }
            };
            if cost < best_cost {
                best_cost = cost;
                best_cd = cd;
            }
        }
        let mut ids = vec![DIG as u8; node.cout];
        ids[best_cd..].fill(AIMC as u8);
        m.assign.insert(node.name.clone(), ids);
    }
    m
}

/// All baselines by name (experiment drivers / CLI).
pub fn by_name(graph: &Graph, name: &str) -> Option<Mapping> {
    Some(match name {
        "all_8bit" => all_8bit(graph),
        "all_ternary" => all_ternary(graph),
        "io8_backbone_ternary" => io8_backbone_ternary(graph),
        "min_cost_lat" => min_cost(graph, CostObjective::Latency),
        "min_cost_en" => min_cost(graph, CostObjective::Energy),
        _ => return None,
    })
}

pub const BASELINE_NAMES: [&str; 5] = [
    "all_8bit",
    "all_ternary",
    "io8_backbone_ternary",
    "min_cost_lat",
    "min_cost_en",
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::soc::{simulate, SocConfig};
    use crate::model::{resnet20, tinycnn};

    #[test]
    fn io8_structure() {
        let g = resnet20();
        let m = io8_backbone_ternary(&g);
        assert!(m.layer("stem").iter().all(|&v| v == DIG as u8));
        assert!(m.layer("fc").iter().all(|&v| v == DIG as u8));
        assert!(m.layer("b4_conv1").iter().all(|&v| v == AIMC as u8));
        m.validate(&g).unwrap();
    }

    #[test]
    fn min_cost_latency_beats_all_single_acc() {
        let g = resnet20();
        let cfg = SocConfig::default();
        let lat = |m: &Mapping| simulate(&g, &m.channel_split(), cfg).total_cycles;
        let mc = lat(&min_cost(&g, CostObjective::Latency));
        assert!(mc <= lat(&all_8bit(&g)));
        assert!(mc <= lat(&all_ternary(&g)));
    }

    #[test]
    fn min_cost_energy_beats_all_8bit() {
        let g = resnet20();
        let cfg = SocConfig::default();
        let en = |m: &Mapping| simulate(&g, &m.channel_split(), cfg).energy_uj;
        assert!(en(&min_cost(&g, CostObjective::Energy)) <= en(&all_8bit(&g)));
    }

    #[test]
    fn min_cost_mostly_aimc_on_big_layers() {
        // the AIMC macro dominates, so min-cost should push most
        // channels analog (paper Table I: Min-Cost = 97.5% A.Ch.)
        let g = resnet20();
        let m = min_cost(&g, CostObjective::Latency);
        assert!(m.aimc_fraction() > 0.6, "aimc frac {}", m.aimc_fraction());
    }

    #[test]
    fn ties_prefer_digital() {
        // a hypothetical layer where several splits tie: tinycnn fc is
        // tiny; just assert validity + digital-heavy under energy
        let g = tinycnn();
        let m = min_cost(&g, CostObjective::Energy);
        m.validate(&g).unwrap();
    }

    #[test]
    fn by_name_covers_all() {
        let g = tinycnn();
        for n in BASELINE_NAMES {
            assert!(by_name(&g, n).is_some(), "{n}");
        }
        assert!(by_name(&g, "nope").is_none());
    }
}
