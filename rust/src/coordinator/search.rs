//! The full ODiMO pipeline and the lambda-sweep search driver.
//!
//! Pipeline (paper Sec. III-B):
//!   1. pre-train float (with BN), checkpoint-cached per model
//!   2. fold BN, re-derive quantizer scales
//!   3. SEARCH: optimize Eq. 2 = task loss + lambda * L_R
//!   4. discretize: argmax alpha per channel
//!   5. fine-tune at exact precision under the fixed assignment
//!   6. deploy: partition pass + SoC simulator -> Table-I metrics
//!
//! Each lambda value yields one point in the accuracy-vs-cost plane;
//! the sweep plus the baselines regenerates Fig. 4 / Fig. 5. The
//! deploy step costs mappings on the pipeline's [`Platform`] (DIANA by
//! default); the train/search phases run the AOT artifacts, whose
//! accelerator count comes from the artifact metadata.

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::hw::soc::SocConfig;
use crate::hw::Platform;
use crate::runtime::{ArtifactMeta, ParamState, Runtime};

use super::baselines;
use super::discretize::discretize;
use super::mapping::Mapping;
use super::scheduler::{deploy, DeployReport};
use super::trainer::{Hyper, Trainer};

/// Which L_R regularizer drives the search phase.
#[derive(Clone, Debug, PartialEq)]
pub enum Regularizer {
    /// Eq. 3 with the DIANA models.
    LatencyDiana,
    /// Eq. 4 with the DIANA models.
    EnergyDiana,
    /// Fig.-5 abstract proportional model with runtime hw constants
    /// (flat [thpt.., p_act.., p_idle..] vector, see
    /// `AbstractHw::to_input_vec`).
    Proportional(Vec<f32>),
}

impl Regularizer {
    pub fn graph_name(&self) -> &'static str {
        match self {
            Regularizer::LatencyDiana => "train_search_lat",
            Regularizer::EnergyDiana => "train_search_en",
            Regularizer::Proportional(_) => "train_search_prop",
        }
    }

    pub fn hw(&self) -> Option<&[f32]> {
        match self {
            Regularizer::Proportional(hw) => Some(hw),
            _ => None,
        }
    }
}

/// Schedule lengths for the pipeline phases (reduced-budget schedules by
/// default; the paper trains to convergence on real datasets).
#[derive(Clone, Copy, Debug)]
pub struct Schedule {
    pub pretrain_steps: usize,
    pub search_steps: usize,
    pub finetune_steps: usize,
    pub eval_batches: usize,
}

impl Default for Schedule {
    fn default() -> Self {
        Schedule { pretrain_steps: 300, search_steps: 200, finetune_steps: 120, eval_batches: 4 }
    }
}

impl Schedule {
    /// Fast schedule for tests / smoke runs.
    pub fn smoke() -> Self {
        Schedule { pretrain_steps: 60, search_steps: 40, finetune_steps: 30, eval_batches: 2 }
    }
}

/// One evaluated mapping (an ODiMO point or a baseline).
#[derive(Clone, Debug)]
pub struct SearchPoint {
    pub label: String,
    pub lambda: f64,
    pub accuracy: f64,
    pub latency_ms: f64,
    pub energy_uj: f64,
    pub total_cycles: u64,
    /// Busy fraction per platform accelerator.
    pub util: Vec<f64>,
    /// Fraction of channels on accelerator 1 (Table I "A. Ch.").
    pub aimc_channel_frac: f64,
    pub mapping: Mapping,
}

impl SearchPoint {
    pub fn from_deploy(label: impl Into<String>, lambda: f64, accuracy: f64,
                       mapping: Mapping, rep: &DeployReport) -> Self {
        SearchPoint {
            label: label.into(),
            lambda,
            accuracy,
            latency_ms: rep.run.latency_ms,
            energy_uj: rep.run.energy_uj,
            total_cycles: rep.run.total_cycles,
            util: rep.run.util.clone(),
            aimc_channel_frac: rep.run.aimc_channel_frac(),
            mapping,
        }
    }
}

pub struct Pipeline<'a> {
    pub rt: &'a Runtime,
    pub meta: &'a ArtifactMeta,
    pub schedule: Schedule,
    pub data_seed: u64,
    pub ckpt_dir: PathBuf,
    pub soc_cfg: SocConfig,
    /// Deployment target for the simulator phase.
    pub platform: Platform,
}

impl<'a> Pipeline<'a> {
    pub fn new(rt: &'a Runtime, meta: &'a ArtifactMeta, schedule: Schedule) -> Self {
        Pipeline {
            rt,
            meta,
            schedule,
            data_seed: 1234,
            ckpt_dir: PathBuf::from("results"),
            soc_cfg: SocConfig::default(),
            platform: Platform::diana(),
        }
    }

    fn ckpt_path(&self) -> PathBuf {
        self.ckpt_dir.join(format!(
            "{}_float_s{}_{}.bin",
            self.meta.model.name, self.schedule.pretrain_steps, self.data_seed
        ))
    }

    /// Pre-train (or restore) the float model, fold BN, return the
    /// folded parameter snapshot the search phases start from.
    pub fn pretrained_folded(&self) -> Result<Vec<Vec<f32>>> {
        std::fs::create_dir_all(&self.ckpt_dir).ok();
        let path = self.ckpt_path();
        let mut trainer = Trainer::new(self.rt, self.meta, self.data_seed)?;
        if path.exists() {
            log::info!("restoring float checkpoint {}", path.display());
            trainer.params = ParamState::load(self.meta, &path)
                .with_context(|| format!("loading {}", path.display()))?;
        } else {
            log::info!(
                "pre-training {} for {} steps",
                self.meta.model.name,
                self.schedule.pretrain_steps
            );
            let h = Hyper { lr: 0.1, lr_alpha: 0.0, wd: 1e-4, ..Default::default() };
            trainer.run_phase("train_float", self.schedule.pretrain_steps, h, None, None)?;
            let ev = trainer.eval("eval_float", None, self.schedule.eval_batches)?;
            log::info!("float accuracy: {:.4}", ev.accuracy);
            trainer.params.save(&path)?;
        }
        trainer.fold_batchnorm()?;
        trainer.params.to_host()
    }

    /// One full ODiMO run at a given lambda.
    ///
    /// The search phase is split: a lambda=0 *warm-up* first adapts the
    /// supernet weights to the quantized mixture (recovering accuracy so
    /// the task loss carries a per-channel signal), then the regularized
    /// phase trades channels toward the cheap accelerator. The paper
    /// trains the fake-quantized DNN "until convergence" before the
    /// trade-off matters; on our reduced schedules the explicit split is
    /// what preserves that property.
    pub fn search_point(&self, folded: &[Vec<f32>], reg: &Regularizer, lambda: f32)
                        -> Result<SearchPoint> {
        let mut trainer = Trainer::new(self.rt, self.meta, self.data_seed)?;
        trainer.set_params(folded.to_vec())?;
        let warm = (self.schedule.search_steps * 2) / 5;
        // momentum-free, low-lr warm-up: the quantized-supernet landscape
        // is sharp right after folding; momentum amplifies the first
        // large transient gradient into a catastrophic step (observed on
        // resnet20: loss 1.2 -> 40 with mu=0.9 vs 1.2 -> 0.12 with mu=0)
        let h_warm = Hyper {
            lr: 0.001,
            lr_alpha: 0.0,
            mu: 0.0,
            wd: 1e-4,
            lam: 0.0,
            tau_start: 1.0,
            tau_end: 1.0,
            lr_min_frac: 1.0, // constant lr through the warm-up
            ..Default::default()
        };
        trainer.run_phase(reg.graph_name(), warm, h_warm, None, reg.hw())?;
        let h = Hyper {
            lr: 0.005,
            lr_alpha: 0.1,
            wd: 1e-4,
            lam: lambda,
            tau_start: 1.0,
            tau_end: 0.2, // anneal toward hard selection
            ..Default::default()
        };
        trainer.run_phase(
            reg.graph_name(),
            self.schedule.search_steps - warm,
            h,
            None,
            reg.hw(),
        )?;
        let mapping =
            discretize(&self.meta.model, &trainer.alphas()?, self.meta.hw.n_acc())?;
        self.finetune_and_score(
            &mut trainer,
            mapping,
            format!("odimo_{}", lambda),
            lambda as f64,
        )
    }

    /// Fine-tune under a fixed mapping and score it on the simulator.
    /// Used both for ODiMO points (post-search) and for baselines.
    pub fn finetune_and_score(&self, trainer: &mut Trainer, mapping: Mapping,
                              label: String, lambda: f64) -> Result<SearchPoint> {
        // short momentum-free settling then momentum fine-tuning (same
        // sharp-landscape rationale as the search warm-up)
        let h0 = Hyper { lr: 0.001, lr_alpha: 0.0, mu: 0.0, wd: 1e-4,
                         lr_min_frac: 1.0, ..Default::default() };
        let settle = (self.schedule.finetune_steps / 4).max(1);
        trainer.run_phase("train_ft", settle, h0, Some(&mapping), None)?;
        let h = Hyper { lr: 0.005, lr_alpha: 0.0, wd: 1e-4, ..Default::default() };
        trainer.run_phase("train_ft", self.schedule.finetune_steps, h, Some(&mapping), None)?;
        let ev = trainer.eval("eval_deploy", Some(&mapping), self.schedule.eval_batches)?;
        let rep = deploy(&self.meta.model, &mapping, &self.platform, self.soc_cfg);
        log::info!(
            "{label}: acc {:.4} lat {:.3} ms en {:.2} uJ aimc {:.1}%",
            ev.accuracy,
            rep.run.latency_ms,
            rep.run.energy_uj,
            100.0 * rep.run.aimc_channel_frac()
        );
        Ok(SearchPoint::from_deploy(label, lambda, ev.accuracy, mapping, &rep))
    }

    /// Score a baseline mapping (fine-tune from the folded snapshot).
    pub fn baseline_point(&self, folded: &[Vec<f32>], name: &str) -> Result<SearchPoint> {
        let mapping = baselines::by_name(&self.meta.model, &self.platform, name)
            .ok_or_else(|| anyhow::anyhow!("unknown baseline '{name}'"))?;
        let mut trainer = Trainer::new(self.rt, self.meta, self.data_seed)?;
        trainer.set_params(folded.to_vec())?;
        self.finetune_and_score(&mut trainer, mapping, name.to_string(), f64::NAN)
    }

    /// Full lambda sweep (the Fig.-4 x-axis).
    pub fn sweep(&self, folded: &[Vec<f32>], reg: &Regularizer, lambdas: &[f32])
                 -> Result<Vec<SearchPoint>> {
        lambdas
            .iter()
            .map(|&l| self.search_point(folded, reg, l))
            .collect()
    }
}
