//! Channel-to-accelerator mapping: the object ODiMO searches for.
//!
//! A [`Mapping`] assigns every output channel of every mappable layer to
//! one accelerator (an index into the platform's ordered accelerator
//! list; on DIANA: 0 = digital int8, 1 = ternary AIMC). It reduces to
//! per-layer counts for the simulator ([`ChannelSplit`]) and expands to
//! the one-hot `assign:` input tensors of the deploy-mode AOT graphs.
//!
//! The mapping itself is platform-agnostic — validation against a
//! concrete accelerator count happens wherever a platform is in scope.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::hw::soc::ChannelSplit;
use crate::model::Graph;
use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Mapping {
    /// layer name -> accelerator id per output channel
    pub assign: BTreeMap<String, Vec<u8>>,
}

impl Mapping {
    /// All channels of every mappable layer on one accelerator.
    pub fn uniform(graph: &Graph, acc: usize) -> Self {
        assert!(acc < u8::MAX as usize);
        Mapping {
            assign: graph
                .mappable()
                .iter()
                .map(|n| (n.name.clone(), vec![acc as u8; n.cout]))
                .collect(),
        }
    }

    pub fn layer(&self, name: &str) -> &[u8] {
        &self.assign[name]
    }

    /// Validate against the graph and an accelerator count: every
    /// mappable layer present, channel counts match, ids in range.
    pub fn validate(&self, graph: &Graph, n_acc: usize) -> Result<()> {
        for n in graph.mappable() {
            let a = self
                .assign
                .get(&n.name)
                .ok_or_else(|| anyhow!("mapping missing layer '{}'", n.name))?;
            if a.len() != n.cout {
                return Err(anyhow!(
                    "layer {}: {} assignments for {} channels",
                    n.name,
                    a.len(),
                    n.cout
                ));
            }
            if a.iter().any(|&v| v as usize >= n_acc) {
                return Err(anyhow!(
                    "layer {}: accelerator id out of range (platform has {n_acc})",
                    n.name
                ));
            }
        }
        if self.assign.len() != graph.mappable().len() {
            return Err(anyhow!(
                "mapping has {} layers, graph has {} mappable",
                self.assign.len(),
                graph.mappable().len()
            ));
        }
        Ok(())
    }

    /// Overwrite `name`'s assignment with contiguous runs per
    /// accelerator: `counts[i]` channels on accelerator `i`, earliest
    /// accelerators first (the layout min-cost and the partition pass
    /// produce — contiguous runs never fragment).
    pub fn set_layer_counts(&mut self, name: &str, counts: &[usize]) {
        let mut ids = Vec::with_capacity(counts.iter().sum());
        for (i, &c) in counts.iter().enumerate() {
            ids.extend(std::iter::repeat(i as u8).take(c));
        }
        self.assign.insert(name.to_string(), ids);
    }

    /// Per-layer channel counts per accelerator for the simulator.
    pub fn channel_split(&self, n_acc: usize) -> ChannelSplit {
        self.assign
            .iter()
            .map(|(name, a)| {
                let mut counts = vec![0usize; n_acc];
                for &v in a {
                    counts[v as usize] += 1;
                }
                (name.clone(), counts)
            })
            .collect()
    }

    /// Fraction of all channels assigned to accelerator `acc`.
    pub fn acc_fraction(&self, acc: usize) -> f64 {
        let total: usize = self.assign.values().map(|a| a.len()).sum();
        if total == 0 {
            return 0.0;
        }
        let on: usize = self
            .assign
            .values()
            .map(|a| a.iter().filter(|&&v| v as usize == acc).count())
            .sum();
        on as f64 / total as f64
    }

    /// Per-accelerator channel fractions.
    pub fn channel_frac(&self, n_acc: usize) -> Vec<f64> {
        (0..n_acc).map(|i| self.acc_fraction(i)).collect()
    }

    /// Fraction of channels on the AIMC accelerator (Table I "A. Ch.";
    /// accelerator 1 on DIANA-family platforms).
    pub fn aimc_fraction(&self) -> f64 {
        self.acc_fraction(crate::model::AIMC)
    }

    /// One-hot (n_acc, Cout) f32 tensor for the `assign:<layer>` input.
    pub fn onehot(&self, name: &str, n_acc: usize) -> Vec<f32> {
        let a = &self.assign[name];
        let c = a.len();
        let mut v = vec![0f32; n_acc * c];
        for (i, &acc) in a.iter().enumerate() {
            v[acc as usize * c + i] = 1.0;
        }
        v
    }

    // ---- (de)serialization --------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::Obj(
            self.assign
                .iter()
                .map(|(k, v)| {
                    (k.clone(), Json::Arr(v.iter().map(|&b| Json::Num(b as f64)).collect()))
                })
                .collect(),
        )
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let obj = v.as_obj().ok_or_else(|| anyhow!("mapping json must be object"))?;
        let mut assign = BTreeMap::new();
        for (k, arr) in obj {
            let ids = arr
                .as_arr()
                .ok_or_else(|| anyhow!("layer {k}: not an array"))?
                .iter()
                .map(|x| x.as_usize().map(|v| v as u8).ok_or_else(|| anyhow!("bad id")))
                .collect::<Result<Vec<u8>>>()?;
            assign.insert(k.clone(), ids);
        }
        Ok(Mapping { assign })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{tinycnn, AIMC, DIG};

    #[test]
    fn uniform_mappings() {
        let g = tinycnn();
        let d = Mapping::uniform(&g, DIG);
        assert!(d.validate(&g, 2).is_ok());
        assert_eq!(d.aimc_fraction(), 0.0);
        let a = Mapping::uniform(&g, AIMC);
        assert_eq!(a.aimc_fraction(), 1.0);
    }

    #[test]
    fn split_counts() {
        let g = tinycnn();
        let mut m = Mapping::uniform(&g, DIG);
        m.assign.get_mut("c1").unwrap()[0..5].fill(AIMC as u8);
        let s = m.channel_split(2);
        assert_eq!(s["c1"], vec![11, 5]);
        assert_eq!(s["stem"], vec![8, 0]);
    }

    #[test]
    fn three_acc_split_counts() {
        let g = tinycnn();
        let mut m = Mapping::uniform(&g, 0);
        let c1 = m.assign.get_mut("c1").unwrap();
        c1[0..4].fill(1);
        c1[4..6].fill(2);
        assert!(m.validate(&g, 3).is_ok());
        assert!(m.validate(&g, 2).is_err(), "id 2 out of range on a 2-acc platform");
        let s = m.channel_split(3);
        assert_eq!(s["c1"], vec![10, 4, 2]);
        assert_eq!(m.channel_frac(3).len(), 3);
        assert!((m.channel_frac(3).iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn onehot_layout() {
        let g = tinycnn();
        let mut m = Mapping::uniform(&g, DIG);
        m.assign.get_mut("stem").unwrap()[2] = AIMC as u8;
        let oh = m.onehot("stem", 2);
        let c = 8;
        assert_eq!(oh.len(), 2 * c);
        assert_eq!(oh[2], 0.0); // dig row, channel 2
        assert_eq!(oh[c + 2], 1.0); // aimc row, channel 2
        // every channel one-hot
        for i in 0..c {
            assert_eq!(oh[i] + oh[c + i], 1.0);
        }
    }

    #[test]
    fn set_layer_counts_contiguous() {
        let g = tinycnn();
        let mut m = Mapping::uniform(&g, DIG);
        m.set_layer_counts("c1", &[6, 7, 3]);
        assert!(m.validate(&g, 3).is_ok());
        assert_eq!(m.channel_split(3)["c1"], vec![6, 7, 3]);
        let ids = m.layer("c1");
        assert!(ids.windows(2).all(|w| w[0] <= w[1]), "runs must be contiguous");
    }

    #[test]
    fn json_roundtrip() {
        let g = tinycnn();
        let mut m = Mapping::uniform(&g, DIG);
        m.assign.get_mut("c2").unwrap()[7] = 1;
        let j = m.to_json().to_string();
        let back = Mapping::from_json(&crate::util::json::parse(&j).unwrap()).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn validate_catches_mismatch() {
        let g = tinycnn();
        let mut m = Mapping::uniform(&g, DIG);
        m.assign.get_mut("c1").unwrap().pop();
        assert!(m.validate(&g, 2).is_err());
        let mut m2 = Mapping::uniform(&g, DIG);
        m2.assign.remove("fc");
        assert!(m2.validate(&g, 2).is_err());
    }
}
