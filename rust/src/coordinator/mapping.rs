//! Channel-to-accelerator mapping: the object ODiMO searches for.
//!
//! A [`Mapping`] assigns every output channel of every mappable layer to
//! one accelerator (DIG = digital int8, AIMC = ternary analog). It
//! reduces to per-layer counts for the simulator ([`ChannelSplit`]) and
//! expands to the one-hot `assign:` input tensors of the deploy-mode
//! AOT graphs.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::hw::soc::ChannelSplit;
use crate::model::{Graph, AIMC, DIG, N_ACC};
use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Mapping {
    /// layer name -> accelerator id per output channel (0 = DIG, 1 = AIMC)
    pub assign: BTreeMap<String, Vec<u8>>,
}

impl Mapping {
    /// All channels of every mappable layer on one accelerator.
    pub fn uniform(graph: &Graph, acc: usize) -> Self {
        assert!(acc < N_ACC);
        Mapping {
            assign: graph
                .mappable()
                .iter()
                .map(|n| (n.name.clone(), vec![acc as u8; n.cout]))
                .collect(),
        }
    }

    pub fn layer(&self, name: &str) -> &[u8] {
        &self.assign[name]
    }

    /// Validate against the graph: every mappable layer present, channel
    /// counts match, ids in range.
    pub fn validate(&self, graph: &Graph) -> Result<()> {
        for n in graph.mappable() {
            let a = self
                .assign
                .get(&n.name)
                .ok_or_else(|| anyhow!("mapping missing layer '{}'", n.name))?;
            if a.len() != n.cout {
                return Err(anyhow!(
                    "layer {}: {} assignments for {} channels",
                    n.name,
                    a.len(),
                    n.cout
                ));
            }
            if a.iter().any(|&v| v as usize >= N_ACC) {
                return Err(anyhow!("layer {}: accelerator id out of range", n.name));
            }
        }
        if self.assign.len() != graph.mappable().len() {
            return Err(anyhow!(
                "mapping has {} layers, graph has {} mappable",
                self.assign.len(),
                graph.mappable().len()
            ));
        }
        Ok(())
    }

    /// Per-layer (digital, aimc) counts for the simulator.
    pub fn channel_split(&self) -> ChannelSplit {
        self.assign
            .iter()
            .map(|(name, a)| {
                let ca = a.iter().filter(|&&v| v as usize == AIMC).count();
                (name.clone(), (a.len() - ca, ca))
            })
            .collect()
    }

    /// Fraction of all channels on the AIMC accelerator (Table I "A. Ch.").
    pub fn aimc_fraction(&self) -> f64 {
        let total: usize = self.assign.values().map(|a| a.len()).sum();
        if total == 0 {
            return 0.0;
        }
        let aimc: usize = self
            .assign
            .values()
            .map(|a| a.iter().filter(|&&v| v as usize == AIMC).count())
            .sum();
        aimc as f64 / total as f64
    }

    /// One-hot (N_ACC, Cout) f32 tensor for the `assign:<layer>` input.
    pub fn onehot(&self, name: &str) -> Vec<f32> {
        let a = &self.assign[name];
        let c = a.len();
        let mut v = vec![0f32; N_ACC * c];
        for (i, &acc) in a.iter().enumerate() {
            v[acc as usize * c + i] = 1.0;
        }
        v
    }

    // ---- (de)serialization --------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::Obj(
            self.assign
                .iter()
                .map(|(k, v)| {
                    (k.clone(), Json::Arr(v.iter().map(|&b| Json::Num(b as f64)).collect()))
                })
                .collect(),
        )
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let obj = v.as_obj().ok_or_else(|| anyhow!("mapping json must be object"))?;
        let mut assign = BTreeMap::new();
        for (k, arr) in obj {
            let ids = arr
                .as_arr()
                .ok_or_else(|| anyhow!("layer {k}: not an array"))?
                .iter()
                .map(|x| x.as_usize().map(|v| v as u8).ok_or_else(|| anyhow!("bad id")))
                .collect::<Result<Vec<u8>>>()?;
            assign.insert(k.clone(), ids);
        }
        Ok(Mapping { assign })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tinycnn;

    #[test]
    fn uniform_mappings() {
        let g = tinycnn();
        let d = Mapping::uniform(&g, DIG);
        assert!(d.validate(&g).is_ok());
        assert_eq!(d.aimc_fraction(), 0.0);
        let a = Mapping::uniform(&g, AIMC);
        assert_eq!(a.aimc_fraction(), 1.0);
    }

    #[test]
    fn split_counts() {
        let g = tinycnn();
        let mut m = Mapping::uniform(&g, DIG);
        m.assign.get_mut("c1").unwrap()[0..5].fill(AIMC as u8);
        let s = m.channel_split();
        assert_eq!(s["c1"], (11, 5));
        assert_eq!(s["stem"], (8, 0));
    }

    #[test]
    fn onehot_layout() {
        let g = tinycnn();
        let mut m = Mapping::uniform(&g, DIG);
        m.assign.get_mut("stem").unwrap()[2] = AIMC as u8;
        let oh = m.onehot("stem");
        let c = 8;
        assert_eq!(oh.len(), 2 * c);
        assert_eq!(oh[2], 0.0); // dig row, channel 2
        assert_eq!(oh[c + 2], 1.0); // aimc row, channel 2
        // every channel one-hot
        for i in 0..c {
            assert_eq!(oh[i] + oh[c + i], 1.0);
        }
    }

    #[test]
    fn json_roundtrip() {
        let g = tinycnn();
        let mut m = Mapping::uniform(&g, DIG);
        m.assign.get_mut("c2").unwrap()[7] = 1;
        let j = m.to_json().to_string();
        let back = Mapping::from_json(&crate::util::json::parse(&j).unwrap()).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn validate_catches_mismatch() {
        let g = tinycnn();
        let mut m = Mapping::uniform(&g, DIG);
        m.assign.get_mut("c1").unwrap().pop();
        assert!(m.validate(&g).is_err());
        let mut m2 = Mapping::uniform(&g, DIG);
        m2.assign.remove("fc");
        assert!(m2.validate(&g).is_err());
    }
}
