//! BatchNorm folding — the float -> search phase transition (paper
//! Sec. III-B: "we first fold Batch Normalization layers with Conv/FC,
//! since the DIANA accelerators do not implement BN in hardware").
//!
//! Exact mirror of `python/compile/train.fold_params`; operates on the
//! host snapshot of a [`ParamState`].

use std::collections::BTreeMap;

use anyhow::Result;

use crate::model::{Graph, Op};
use crate::runtime::ArtifactMeta;

pub const BN_EPS: f32 = 1e-5;
/// Post-fold activation-scale init: e^lsa = 4.0 (post-BN ReLU range).
pub const POST_FOLD_ACT_SCALE: f32 = 4.0;
/// Ternary scale shrink factor vs the int8 range (keeps more weights
/// off zero — see fold_params in python).
pub const TERNARY_RANGE_FACTOR: f32 = 0.4;
/// Digital-side alpha bias after folding: softmax([2, 0]) ~ 88% int8,
/// so the search starts from a *functioning* (near-8-bit) supernet and
/// the task loss produces a meaningful per-channel signal. Starting at
/// the uniform 50/50 mix leaves the network broken (the ternary half
/// destroys it) and the CE gradient on alpha is noise — exactly the
/// failure the paper avoids by searching from a pretrained model.
pub const ALPHA_DIG_INIT: f32 = 2.0;

/// Fold BN into conv weights/biases in-place on a host param snapshot.
/// `values` is the flat leaf-ordered vector from `ParamState::to_host`.
pub fn fold_bn(meta: &ArtifactMeta, graph: &Graph, values: &mut [Vec<f32>]) -> Result<()> {
    // leaf name -> index
    let idx: BTreeMap<&str, usize> = meta
        .params
        .iter()
        .enumerate()
        .map(|(i, p)| (p.name.as_str(), i))
        .collect();
    let get = |name: &String, leaf: &str| idx.get(format!("{name}/{leaf}").as_str()).copied();

    for node in &graph.nodes {
        if !matches!(node.op, Op::Conv | Op::DwConv | Op::Fc | Op::Add) {
            continue;
        }
        // activation scale reset (all quant nodes with lsa)
        if let Some(i_lsa) = get(&node.name, "lsa") {
            values[i_lsa][0] = POST_FOLD_ACT_SCALE.ln();
        }
        // digital-biased mapping prior (alpha layout: [dig row, aimc row])
        if let Some(i_a) = get(&node.name, "alpha") {
            let c = values[i_a].len() / 2;
            values[i_a][..c].fill(ALPHA_DIG_INIT);
            values[i_a][c..].fill(0.0);
        }
        if let (Some(i_g), Some(i_b2), Some(i_rm), Some(i_rv)) = (
            get(&node.name, "gamma"),
            get(&node.name, "beta"),
            get(&node.name, "rm"),
            get(&node.name, "rv"),
        ) {
            let (i_w, i_b) = (
                get(&node.name, "w").expect("conv without w"),
                get(&node.name, "b").expect("conv without b"),
            );
            let cout = values[i_g].len();
            let w_per_ch = values[i_w].len() / cout;
            for c in 0..cout {
                let inv = values[i_g][c] / (values[i_rv][c] + BN_EPS).sqrt();
                for k in 0..w_per_ch {
                    values[i_w][c * w_per_ch + k] *= inv;
                }
                values[i_b][c] =
                    (values[i_b][c] - values[i_rm][c]) * inv + values[i_b2][c];
            }
            // reset BN to identity so a second fold is a no-op
            values[i_g].fill(1.0);
            values[i_b2].fill(0.0);
            values[i_rm].fill(0.0);
            values[i_rv].fill(1.0);
        }
        // fresh Eq.-5 quantizer ranges from the (possibly folded)
        // weights — including BN-less layers (fc), whose weights also
        // drift from the init-time range during pre-training
        if let (Some(i_ls8), Some(i_w)) = (get(&node.name, "ls8"), get(&node.name, "w")) {
            let wmax = values[i_w]
                .iter()
                .fold(0f32, |m, v| m.max(v.abs()))
                .max(1e-4);
            values[i_ls8][0] = wmax.ln();
            if let Some(i_lster) = get(&node.name, "lster") {
                values[i_lster][0] = (wmax * TERNARY_RANGE_FACTOR + 1e-8).ln();
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn art_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn fold_is_idempotent_and_resets_bn() {
        if !art_dir().join("tinycnn_meta.json").exists() {
            return;
        }
        let meta = ArtifactMeta::load(&art_dir(), "tinycnn").unwrap();
        let g = meta.model.clone();
        let mut v = meta.load_init_values().unwrap();
        // make BN non-trivial
        let i_g = meta.param_index("stem/gamma").unwrap();
        let i_rv = meta.param_index("stem/rv").unwrap();
        v[i_g].fill(2.0);
        v[i_rv].fill(4.0);
        let i_w = meta.param_index("stem/w").unwrap();
        let w_before = v[i_w].clone();
        fold_bn(&meta, &g, &mut v).unwrap();
        // w scaled by gamma/sqrt(rv+eps) ~ 1.0 (2/sqrt(4) = 1) -> close
        let scale = 2.0 / (4.0f32 + BN_EPS).sqrt();
        for (a, b) in v[i_w].iter().zip(&w_before) {
            assert!((a - b * scale).abs() < 1e-6);
        }
        assert!(v[i_g].iter().all(|&x| x == 1.0));
        assert!(v[i_rv].iter().all(|&x| x == 1.0));
        // second fold leaves weights untouched (up to the eps in
        // 1/sqrt(1 + BN_EPS))
        let w_once = v[i_w].clone();
        fold_bn(&meta, &g, &mut v).unwrap();
        for (a, b) in v[i_w].iter().zip(&w_once) {
            assert!((a - b).abs() <= 1e-5 * b.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn fold_sets_quant_scales() {
        if !art_dir().join("tinycnn_meta.json").exists() {
            return;
        }
        let meta = ArtifactMeta::load(&art_dir(), "tinycnn").unwrap();
        let g = meta.model.clone();
        let mut v = meta.load_init_values().unwrap();
        fold_bn(&meta, &g, &mut v).unwrap();
        let i_w = meta.param_index("c1/w").unwrap();
        let wmax = v[i_w].iter().fold(0f32, |m, x| m.max(x.abs()));
        let ls8 = v[meta.param_index("c1/ls8").unwrap()][0];
        let lster = v[meta.param_index("c1/lster").unwrap()][0];
        assert!((ls8 - wmax.ln()).abs() < 1e-5);
        assert!(lster < ls8);
        let lsa = v[meta.param_index("c1/lsa").unwrap()][0];
        assert!((lsa - 4.0f32.ln()).abs() < 1e-6);
    }
}
