//! Mapping discretization (paper Sec. III-A, end of training): for each
//! channel select the accelerator with the largest alpha logit.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::model::Graph;

use super::mapping::Mapping;

/// alpha: layer name -> flattened (n_acc, Cout) logits, row-major.
/// `n_acc` is the accelerator count the alphas were trained against
/// (the platform's, or the artifact contract's 2 for the AOT graphs).
pub fn discretize(
    graph: &Graph,
    alphas: &BTreeMap<String, Vec<f32>>,
    n_acc: usize,
) -> Result<Mapping> {
    if n_acc == 0 {
        return Err(anyhow!("discretize: n_acc must be positive"));
    }
    let mut assign = BTreeMap::new();
    for node in graph.mappable() {
        let a = alphas
            .get(&node.name)
            .ok_or_else(|| anyhow!("no alphas for layer '{}'", node.name))?;
        if a.len() != n_acc * node.cout {
            return Err(anyhow!(
                "layer {}: {} logits for {}x{} expected",
                node.name,
                a.len(),
                n_acc,
                node.cout
            ));
        }
        let mut ids = Vec::with_capacity(node.cout);
        for c in 0..node.cout {
            let mut best = 0usize;
            let mut best_v = a[c]; // row 0
            for acc in 1..n_acc {
                let v = a[acc * node.cout + c];
                if v > best_v {
                    best_v = v;
                    best = acc;
                }
            }
            ids.push(best as u8);
        }
        assign.insert(node.name.clone(), ids);
    }
    let m = Mapping { assign };
    m.validate(graph, n_acc)?;
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{tinycnn, AIMC, DIG};

    fn logits(graph: &Graph, f: impl Fn(&str, usize) -> (f32, f32)) -> BTreeMap<String, Vec<f32>> {
        graph
            .mappable()
            .iter()
            .map(|n| {
                let mut v = vec![0f32; 2 * n.cout];
                for c in 0..n.cout {
                    let (d, a) = f(&n.name, c);
                    v[c] = d;
                    v[n.cout + c] = a;
                }
                (n.name.clone(), v)
            })
            .collect()
    }

    #[test]
    fn argmax_per_channel() {
        let g = tinycnn();
        let al = logits(&g, |_, c| if c % 2 == 0 { (1.0, 0.0) } else { (0.0, 1.0) });
        let m = discretize(&g, &al, 2).unwrap();
        for n in g.mappable() {
            for c in 0..n.cout {
                let want = if c % 2 == 0 { DIG } else { AIMC } as u8;
                assert_eq!(m.layer(&n.name)[c], want);
            }
        }
    }

    #[test]
    fn ties_go_digital() {
        // equal logits -> accelerator 0 (digital) wins, matching the
        // paper's "digital channels are maximized" tie-break
        let g = tinycnn();
        let al = logits(&g, |_, _| (0.5, 0.5));
        let m = discretize(&g, &al, 2).unwrap();
        assert_eq!(m.aimc_fraction(), 0.0);
    }

    #[test]
    fn three_acc_argmax() {
        let g = tinycnn();
        let al: BTreeMap<String, Vec<f32>> = g
            .mappable()
            .iter()
            .map(|n| {
                let mut v = vec![0f32; 3 * n.cout];
                for c in 0..n.cout {
                    v[(c % 3) * n.cout + c] = 1.0; // winner cycles 0,1,2
                }
                (n.name.clone(), v)
            })
            .collect();
        let m = discretize(&g, &al, 3).unwrap();
        for n in g.mappable() {
            for c in 0..n.cout {
                assert_eq!(m.layer(&n.name)[c], (c % 3) as u8);
            }
        }
    }

    #[test]
    fn missing_layer_errors() {
        let g = tinycnn();
        let mut al = logits(&g, |_, _| (1.0, 0.0));
        al.remove("fc");
        assert!(discretize(&g, &al, 2).is_err());
    }

    #[test]
    fn wrong_len_errors() {
        let g = tinycnn();
        let mut al = logits(&g, |_, _| (1.0, 0.0));
        al.get_mut("stem").unwrap().pop();
        assert!(discretize(&g, &al, 2).is_err());
        // the same logits against the wrong accelerator count also fail
        let al2 = logits(&g, |_, _| (1.0, 0.0));
        assert!(discretize(&g, &al2, 3).is_err());
    }
}
