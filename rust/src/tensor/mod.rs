//! Minimal owned tensors (f32 / i32, NCHW convention).
//!
//! This is deliberately small: the request-path math that matters runs
//! inside compiled XLA executables; rust-side tensors exist for data
//! generation, weight transformation passes (fold / partition), the
//! integer reference convolution used to cross-check deployments, and
//! literal marshalling.

use std::fmt;

#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}(n={})", self.shape, self.data.len())
    }
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} vs data len {}",
            data.len()
        );
        Self { shape: shape.to_vec(), data }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        let n: usize = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(self.data.len(), shape.iter().product::<usize>());
        self.shape = shape.to_vec();
        self
    }

    // ---- 4-D (OIHW / NCHW) indexing ----------------------------------

    #[inline]
    pub fn at4(&self, a: usize, b: usize, c: usize, d: usize) -> f32 {
        let (s1, s2, s3) = (self.shape[1], self.shape[2], self.shape[3]);
        self.data[((a * s1 + b) * s2 + c) * s3 + d]
    }

    #[inline]
    pub fn set4(&mut self, a: usize, b: usize, c: usize, d: usize, v: f32) {
        let (s1, s2, s3) = (self.shape[1], self.shape[2], self.shape[3]);
        self.data[((a * s1 + b) * s2 + c) * s3 + d] = v;
    }

    #[inline]
    pub fn at2(&self, a: usize, b: usize) -> f32 {
        self.data[a * self.shape[1] + b]
    }

    /// Slice of the elements belonging to leading index `a` (e.g. one
    /// output-channel filter of an OIHW weight, or one NCHW image).
    pub fn outer(&self, a: usize) -> &[f32] {
        let stride: usize = self.shape[1..].iter().product();
        &self.data[a * stride..(a + 1) * stride]
    }

    pub fn outer_mut(&mut self, a: usize) -> &mut [f32] {
        let stride: usize = self.shape[1..].iter().product();
        &mut self.data[a * stride..(a + 1) * stride]
    }

    // ---- reductions ---------------------------------------------------

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, v| m.max(v.abs()))
    }

    /// Largest element (`-inf` when empty) — the activation-calibration
    /// reduction (post-ReLU maxima set the e^lsa grids).
    pub fn max(&self) -> f32 {
        self.data.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v))
    }

    /// Index of the first maximum element (top-1 class of a logits
    /// row). Panics on an empty tensor — index 0 would be out of range.
    pub fn argmax(&self) -> usize {
        assert!(!self.data.is_empty(), "argmax of empty tensor");
        let mut best = 0;
        for (i, &v) in self.data.iter().enumerate() {
            if v > self.data[best] {
                best = i;
            }
        }
        best
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }

    /// Largest absolute difference against another tensor.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()))
    }

    /// Reorder the leading (outer) axis by `perm`: out[i] = self[perm[i]].
    pub fn permute_outer(&self, perm: &[usize]) -> Tensor {
        assert_eq!(perm.len(), self.shape[0], "perm len vs axis 0");
        let stride: usize = self.shape[1..].iter().product();
        let mut out = Tensor::zeros(&self.shape);
        for (i, &src) in perm.iter().enumerate() {
            out.data[i * stride..(i + 1) * stride]
                .copy_from_slice(&self.data[src * stride..(src + 1) * stride]);
        }
        out
    }

    /// Reorder the *second* axis by `perm` (input-channel reorder of an
    /// OIHW weight — the Fig.-3 next-layer fixup).
    pub fn permute_axis1(&self, perm: &[usize]) -> Tensor {
        assert!(self.shape.len() >= 2);
        assert_eq!(perm.len(), self.shape[1]);
        let inner: usize = self.shape[2..].iter().product();
        let s1 = self.shape[1];
        let mut out = Tensor::zeros(&self.shape);
        for a in 0..self.shape[0] {
            for (j, &src) in perm.iter().enumerate() {
                let dst_off = (a * s1 + j) * inner;
                let src_off = (a * s1 + src) * inner;
                out.data[dst_off..dst_off + inner]
                    .copy_from_slice(&self.data[src_off..src_off + inner]);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_roundtrip() {
        let mut t = Tensor::zeros(&[2, 3, 4, 5]);
        t.set4(1, 2, 3, 4, 7.5);
        assert_eq!(t.at4(1, 2, 3, 4), 7.5);
        assert_eq!(t.data().iter().filter(|v| **v != 0.0).count(), 1);
    }

    #[test]
    fn permute_outer_roundtrip() {
        let t = Tensor::from_vec(&[3, 2], vec![0., 1., 10., 11., 20., 21.]);
        let p = t.permute_outer(&[2, 0, 1]);
        assert_eq!(p.data(), &[20., 21., 0., 1., 10., 11.]);
        // inverse permutation restores
        let inv = p.permute_outer(&[1, 2, 0]);
        assert_eq!(inv, t);
    }

    #[test]
    fn permute_axis1() {
        let t = Tensor::from_vec(&[2, 2, 2], vec![0., 1., 2., 3., 4., 5., 6., 7.]);
        let p = t.permute_axis1(&[1, 0]);
        assert_eq!(p.data(), &[2., 3., 0., 1., 6., 7., 4., 5.]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0; 5]);
    }

    #[test]
    fn abs_max_and_diff() {
        let a = Tensor::from_vec(&[3], vec![-2.0, 0.5, 1.0]);
        let b = Tensor::from_vec(&[3], vec![-2.0, 1.0, 1.0]);
        assert_eq!(a.abs_max(), 2.0);
        assert_eq!(a.max_abs_diff(&b), 0.5);
    }

    #[test]
    fn max_and_argmax() {
        let a = Tensor::from_vec(&[4], vec![-2.0, 3.5, 1.0, 3.5]);
        assert_eq!(a.max(), 3.5);
        assert_eq!(a.argmax(), 1); // first maximum wins
        assert_eq!(Tensor::zeros(&[0]).max(), f32::NEG_INFINITY);
    }
}
