//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the only module that touches the `xla` crate. Interchange is
//! HLO *text* (xla_extension 0.5.1 rejects jax>=0.5 serialized protos —
//! see /opt/xla-example/README.md); executables are compiled once and
//! cached; parameters live as device buffers between steps so the train
//! loop never round-trips host literals for state (the L3 hot-path
//! optimization recorded in EXPERIMENTS.md §Perf).

mod artifact;
mod client;
mod state;

pub use artifact::{ArtifactMeta, Dtype, GraphMeta, TensorMeta};
pub use client::{
    assemble_inputs, literal_f32, literal_for, literal_i32, literal_scalar,
    literal_to_f32, Executable, Runtime,
};
pub use state::ParamState;
