//! Artifact metadata — the contract `python/compile/aot.py` writes next
//! to every HLO file (`<model>_meta.json`): flat parameter order, per-
//! graph input/output signatures, node table, hw calibration constants.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::model::Graph;
use crate::util::json::{self, Json};

#[derive(Clone, Debug, PartialEq)]
pub struct TensorMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    S32,
}

impl TensorMeta {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<Self> {
        Ok(TensorMeta {
            name: v.req("name")?.as_str().unwrap_or("").to_string(),
            shape: v.req("shape")?.usize_vec()?,
            dtype: match v.req("dtype")?.as_str() {
                Some("f32") => Dtype::F32,
                Some("s32") => Dtype::S32,
                other => return Err(anyhow!("unsupported dtype {other:?}")),
            },
        })
    }
}

#[derive(Clone, Debug)]
pub struct GraphMeta {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
}

impl GraphMeta {
    /// Index of the input named `name` (exact match).
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|t| t.name == name)
            .ok_or_else(|| anyhow!("graph {}: no input '{name}'", self.name))
    }

    /// Indices of inputs whose name starts with `prefix` (e.g. "param:").
    pub fn input_range(&self, prefix: &str) -> Vec<usize> {
        self.inputs
            .iter()
            .enumerate()
            .filter(|(_, t)| t.name.starts_with(prefix))
            .map(|(i, _)| i)
            .collect()
    }
}

/// Hardware calibration constants exported by the python cost model —
/// asserted against the rust mirrors in tests/model_parity.rs. The
/// power vectors carry one entry per accelerator; their length is the
/// artifact's accelerator count (2 for the DIANA training graphs).
#[derive(Clone, Debug)]
pub struct HwMeta {
    pub p_act: Vec<f64>,
    pub p_idle: Vec<f64>,
    pub f_clk_hz: f64,
    pub aimc_rows: u64,
    pub aimc_cols: u64,
    pub dig_pe: u64,
}

impl HwMeta {
    /// Accelerator count of the artifact contract (alpha/assign rows).
    pub fn n_acc(&self) -> usize {
        self.p_act.len()
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub model: Graph,
    /// Flat parameter leaves ("node/leaf") in HLO parameter order.
    pub params: Vec<TensorMeta>,
    /// Mappable node names in assign-input order (sorted).
    pub mappable: Vec<String>,
    pub graphs: BTreeMap<String, GraphMeta>,
    pub hw: HwMeta,
    pub norm_lat0: f64,
    pub norm_en0: f64,
    pub init_seed: u64,
    pub init_bin: PathBuf,
}

impl ArtifactMeta {
    /// Load `<dir>/<model>_meta.json`.
    pub fn load(dir: &Path, model: &str) -> Result<Self> {
        let path = dir.join(format!("{model}_meta.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        Self::from_json(&v, dir, model)
    }

    pub fn from_json(v: &Json, dir: &Path, model: &str) -> Result<Self> {
        let graph = Graph::from_meta(v)?;
        let params = v
            .req("params")?
            .as_arr()
            .ok_or_else(|| anyhow!("params not array"))?
            .iter()
            .map(TensorMeta::from_json)
            .collect::<Result<Vec<_>>>()?;
        let mappable = v
            .req("mappable")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|x| x.as_str().map(String::from))
            .collect();
        let mut graphs = BTreeMap::new();
        for (gname, g) in v.req("graphs")?.as_obj().ok_or_else(|| anyhow!("graphs"))? {
            let inputs = g
                .req("inputs")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(TensorMeta::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = g
                .req("outputs")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(TensorMeta::from_json)
                .collect::<Result<Vec<_>>>()?;
            graphs.insert(
                gname.clone(),
                GraphMeta {
                    name: gname.clone(),
                    file: dir.join(g.req("file")?.as_str().unwrap_or("")),
                    inputs,
                    outputs,
                },
            );
        }
        let hw = v.req("hw")?;
        let pa: Vec<f64> = hw
            .req("p_act")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|x| x.as_f64().unwrap_or(0.0))
            .collect();
        let pi: Vec<f64> = hw
            .req("p_idle")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|x| x.as_f64().unwrap_or(0.0))
            .collect();
        if pa.len() != pi.len() || pa.is_empty() {
            return Err(anyhow!(
                "hw meta: p_act ({}) and p_idle ({}) must be equal-length, non-empty",
                pa.len(),
                pi.len()
            ));
        }
        Ok(ArtifactMeta {
            model: graph,
            params,
            mappable,
            graphs,
            hw: HwMeta {
                p_act: pa,
                p_idle: pi,
                f_clk_hz: hw.req("f_clk_hz")?.as_f64().unwrap_or(0.0),
                aimc_rows: hw.req("aimc_rows")?.as_i64().unwrap_or(0) as u64,
                aimc_cols: hw.req("aimc_cols")?.as_i64().unwrap_or(0) as u64,
                dig_pe: hw.req("dig_pe")?.as_i64().unwrap_or(0) as u64,
            },
            norm_lat0: v.req("norm")?.req("lat0")?.as_f64().unwrap_or(0.0),
            norm_en0: v.req("norm")?.req("en0")?.as_f64().unwrap_or(0.0),
            init_seed: v.req("init_seed")?.as_i64().unwrap_or(0) as u64,
            init_bin: dir.join(format!("{model}_init.bin")),
        })
    }

    pub fn graph(&self, name: &str) -> Result<&GraphMeta> {
        self.graphs
            .get(name)
            .ok_or_else(|| anyhow!("model {}: no graph '{name}'", self.model.name))
    }

    pub fn param_index(&self, leaf: &str) -> Result<usize> {
        self.params
            .iter()
            .position(|t| t.name == leaf)
            .ok_or_else(|| anyhow!("no param leaf '{leaf}'"))
    }

    /// Read the python-initialized parameter values (flat f32 blob in
    /// leaf order) into per-leaf vectors.
    pub fn load_init_values(&self) -> Result<Vec<Vec<f32>>> {
        let bytes = std::fs::read(&self.init_bin)
            .with_context(|| format!("reading {}", self.init_bin.display()))?;
        let total: usize = self.params.iter().map(|p| p.elems()).sum();
        if bytes.len() != total * 4 {
            return Err(anyhow!(
                "init blob {} bytes, expected {} ({} elems)",
                bytes.len(),
                total * 4,
                total
            ));
        }
        let mut off = 0usize;
        let mut out = Vec::with_capacity(self.params.len());
        for p in &self.params {
            let n = p.elems();
            let mut v = Vec::with_capacity(n);
            for i in 0..n {
                let b = &bytes[(off + i) * 4..(off + i) * 4 + 4];
                v.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
            }
            off += n;
            out.push(v);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn art_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn load_tinycnn_meta() {
        let dir = art_dir();
        if !dir.join("tinycnn_meta.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = ArtifactMeta::load(&dir, "tinycnn").unwrap();
        assert_eq!(m.model.name, "tinycnn");
        assert!(m.graphs.contains_key("train_float"));
        assert!(m.graphs.contains_key("train_search_en"));
        // param order matches sorted node/leaf names
        let mut sorted = m.params.clone();
        sorted.sort_by(|a, b| a.name.cmp(&b.name));
        assert_eq!(
            m.params.iter().map(|p| &p.name).collect::<Vec<_>>(),
            sorted.iter().map(|p| &p.name).collect::<Vec<_>>()
        );
        // init blob parses and matches shapes
        let init = m.load_init_values().unwrap();
        assert_eq!(init.len(), m.params.len());
        for (v, p) in init.iter().zip(&m.params) {
            assert_eq!(v.len(), p.elems());
        }
    }

    #[test]
    fn graph_meta_indexing() {
        let dir = art_dir();
        if !dir.join("tinycnn_meta.json").exists() {
            return;
        }
        let m = ArtifactMeta::load(&dir, "tinycnn").unwrap();
        let g = m.graph("train_search_en").unwrap();
        let params = g.input_range("param:");
        let moms = g.input_range("mom:");
        assert_eq!(params.len(), m.params.len());
        assert_eq!(moms.len(), m.params.len());
        assert!(g.input_index("x").is_ok());
        assert!(g.input_index("lam").is_ok());
        assert!(g.input_index("nonexistent").is_err());
    }
}
