//! PJRT client wrapper: compile-once executable cache + typed execution.
//!
//! Calling convention: the `xla` 0.1.6 / xla_extension 0.5.1 PJRT C
//! shim returns the computation result as ONE tuple buffer (no device-
//! side untupling), so state round-trips through host `Literal`s each
//! step: inputs are `Literal`s (uploaded internally by `execute`), the
//! output tuple is downloaded and decomposed back into per-leaf
//! `Literal`s that feed the next step. The per-step memcpy cost is
//! measured in EXPERIMENTS.md §Perf and is small against the step's
//! compute on every benchmark model.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use crate::xla::{self, ElementType, Literal, PjRtClient, PjRtLoadedExecutable};

use super::artifact::{Dtype, GraphMeta, TensorMeta};

/// Process-wide PJRT CPU runtime. Compilation results are cached by
/// artifact path, so repeated pipeline runs (lambda sweeps!) compile
/// each graph exactly once.
pub struct Runtime {
    client: PjRtClient,
    cache: Mutex<BTreeMap<String, Arc<PjRtLoadedExecutable>>>,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::info!(
            "PJRT client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Self { client, cache: Mutex::new(BTreeMap::new()) })
    }

    /// Load + compile an HLO-text artifact (cached).
    pub fn load(&self, meta: &GraphMeta) -> Result<Executable> {
        let key = meta.file.display().to_string();
        {
            let cache = self.cache.lock().unwrap();
            if let Some(exe) = cache.get(&key) {
                return Ok(Executable { exe: exe.clone(), meta: meta.clone() });
            }
        }
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            meta.file.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", meta.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", meta.file.display()))?;
        log::info!("compiled {} in {:.2}s", meta.name, t0.elapsed().as_secs_f64());
        let exe = Arc::new(exe);
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(Executable { exe, meta: meta.clone() })
    }
}

// ---- literal constructors -------------------------------------------------

pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<Literal> {
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Literal::vec1(data)
        .reshape(&dims_i64)
        .context("reshaping f32 literal")
}

pub fn literal_i32(data: &[i32], dims: &[usize]) -> Result<Literal> {
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Literal::vec1(data)
        .reshape(&dims_i64)
        .context("reshaping i32 literal")
}

pub fn literal_scalar(v: f32) -> Literal {
    Literal::scalar(v)
}

/// Build a literal matching a tensor signature from f32 host data.
pub fn literal_for(tm: &TensorMeta, f32_data: &[f32]) -> Result<Literal> {
    if f32_data.len() != tm.elems() {
        return Err(anyhow!(
            "{}: {} elems supplied, shape {:?} needs {}",
            tm.name,
            f32_data.len(),
            tm.shape,
            tm.elems()
        ));
    }
    match tm.dtype {
        Dtype::F32 => literal_f32(f32_data, &tm.shape),
        Dtype::S32 => {
            let ints: Vec<i32> = f32_data.iter().map(|v| *v as i32).collect();
            literal_i32(&ints, &tm.shape)
        }
    }
}

/// A compiled graph plus its metadata signature.
pub struct Executable {
    exe: Arc<PjRtLoadedExecutable>,
    pub meta: GraphMeta,
}

impl Executable {
    /// Execute with named inputs; returns one `Literal` per output leaf
    /// (the result tuple is downloaded and decomposed). Input count is
    /// validated against the metadata signature so mismatches fail with
    /// names, not XLA shape errors.
    pub fn run(&self, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        if inputs.len() != self.meta.inputs.len() {
            return Err(anyhow!(
                "graph {}: {} inputs supplied, signature has {}",
                self.meta.name,
                inputs.len(),
                self.meta.inputs.len()
            ));
        }
        let mut out = self
            .exe
            .execute::<&Literal>(inputs)
            .with_context(|| format!("executing {}", self.meta.name))?;
        let buf = out
            .drain(..)
            .next()
            .and_then(|mut d| if d.is_empty() { None } else { Some(d.remove(0)) })
            .ok_or_else(|| anyhow!("no output buffer"))?;
        let lit = buf.to_literal_sync().context("downloading result tuple")?;
        let leaves = lit.to_tuple().context("decomposing result tuple")?;
        if leaves.len() != self.meta.outputs.len() {
            return Err(anyhow!(
                "graph {}: {} output leaves, metadata says {}",
                self.meta.name,
                leaves.len(),
                self.meta.outputs.len()
            ));
        }
        Ok(leaves)
    }

    /// Execute and convert every output to host f32 vectors.
    pub fn run_to_host(&self, inputs: &[&Literal]) -> Result<Vec<Vec<f32>>> {
        self.run(inputs)?.iter().map(literal_to_f32).collect()
    }
}

/// Assemble the input literal list for a graph by *name*: jax prunes
/// unused arguments at lowering, so the metadata's input list (already
/// filtered to the kept ones, in order) drives the marshalling.
pub fn assemble_inputs<'a>(
    meta: &GraphMeta,
    mut get: impl FnMut(&TensorMeta) -> Result<&'a Literal>,
) -> Result<Vec<&'a Literal>> {
    meta.inputs.iter().map(|tm| get(tm)).collect()
}

pub fn literal_to_f32(lit: &Literal) -> Result<Vec<f32>> {
    match lit.ty().context("literal type")? {
        ElementType::F32 => lit.to_vec::<f32>().context("reading f32 literal"),
        ElementType::S32 => Ok(lit
            .to_vec::<i32>()
            .context("reading s32 literal")?
            .into_iter()
            .map(|v| v as f32)
            .collect()),
        other => Err(anyhow!("unsupported literal type {other:?}")),
    }
}
