//! Training state held as XLA literals between steps.
//!
//! The train-step artifacts return `[params..., mom..., metrics]`; the
//! output leaves feed straight back as the next step's inputs without
//! any f32-vector conversion (literal -> literal), keeping the host work
//! per step at two memcpys of the state.

use anyhow::{anyhow, Result};

use crate::xla::Literal;

use super::artifact::ArtifactMeta;
use super::client::{literal_for, literal_to_f32};

/// One flat leaf-ordered set of tensors (params OR momentum).
pub struct ParamState {
    pub lits: Vec<Literal>,
    names: Vec<String>,
}

impl ParamState {
    /// Load the python-initialized parameters from the artifact blob.
    pub fn from_init(meta: &ArtifactMeta) -> Result<Self> {
        let values = meta.load_init_values()?;
        Self::from_host(meta, values)
    }

    /// Build from host vectors (leaf order must match the metadata).
    pub fn from_host(meta: &ArtifactMeta, values: Vec<Vec<f32>>) -> Result<Self> {
        if values.len() != meta.params.len() {
            return Err(anyhow!(
                "{} leaves supplied, metadata has {}",
                values.len(),
                meta.params.len()
            ));
        }
        let mut lits = Vec::with_capacity(values.len());
        let mut names = Vec::with_capacity(values.len());
        for (v, tm) in values.iter().zip(&meta.params) {
            lits.push(literal_for(tm, v)?);
            names.push(tm.name.clone());
        }
        Ok(Self { lits, names })
    }

    /// All-zero state with the same shapes (momentum init).
    pub fn zeros(meta: &ArtifactMeta) -> Result<Self> {
        let values: Vec<Vec<f32>> = meta.params.iter().map(|p| vec![0.0; p.elems()]).collect();
        Self::from_host(meta, values)
    }

    pub fn len(&self) -> usize {
        self.lits.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lits.is_empty()
    }

    pub fn index_of(&self, leaf: &str) -> Result<usize> {
        self.names
            .iter()
            .position(|n| n == leaf)
            .ok_or_else(|| anyhow!("no leaf '{leaf}'"))
    }

    pub fn leaf(&self, leaf: &str) -> Result<&Literal> {
        Ok(&self.lits[self.index_of(leaf)?])
    }

    /// Download one leaf to host f32.
    pub fn leaf_to_host(&self, leaf: &str) -> Result<Vec<f32>> {
        literal_to_f32(&self.lits[self.index_of(leaf)?])
    }

    /// Download the whole state (checkpoints / weight transforms).
    pub fn to_host(&self) -> Result<Vec<Vec<f32>>> {
        self.lits.iter().map(literal_to_f32).collect()
    }

    /// Take the leading `self.len()` leaves out of a step's outputs as
    /// the new state (train outputs are `[params..., mom..., metrics]`:
    /// params call this first, momentum second).
    pub fn replace_from_outputs(&mut self, outputs: &mut Vec<Literal>) {
        assert!(outputs.len() >= self.lits.len(), "output underrun");
        let tail = outputs.split_off(self.lits.len());
        self.lits = std::mem::replace(outputs, tail);
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    /// Checkpoint to a flat little-endian f32 blob (same layout as the
    /// python `<model>_init.bin`).
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        let mut bytes = Vec::new();
        for v in self.to_host()? {
            for x in v {
                bytes.extend_from_slice(&x.to_le_bytes());
            }
        }
        std::fs::write(path, bytes)?;
        Ok(())
    }

    /// Restore from a checkpoint blob.
    pub fn load(meta: &ArtifactMeta, path: &std::path::Path) -> Result<Self> {
        let bytes = std::fs::read(path)?;
        let total: usize = meta.params.iter().map(|p| p.elems()).sum();
        if bytes.len() != total * 4 {
            return Err(anyhow!(
                "checkpoint {} has {} bytes, expected {}",
                path.display(),
                bytes.len(),
                total * 4
            ));
        }
        let mut off = 0;
        let mut values = Vec::with_capacity(meta.params.len());
        for p in &meta.params {
            let n = p.elems();
            let mut v = Vec::with_capacity(n);
            for i in 0..n {
                let b = &bytes[(off + i) * 4..(off + i) * 4 + 4];
                v.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
            }
            off += n;
            values.push(v);
        }
        Self::from_host(meta, values)
    }
}
