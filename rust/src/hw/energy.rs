//! Power/energy model — paper Eq. 4.
//!
//! Constants mirror `python/compile/costmodel.py` (asserted against the
//! artifact metadata by tests/model_parity.rs). Calibration note: chosen
//! so the All-8bit CIFAR-10/ResNet20 deployment lands on the paper's
//! Table-I scale (~1.55 ms / ~38.7 uJ at 260 MHz); see EXPERIMENTS.md.

use super::latency::F_CLK_HZ;

/// Average active power, mW: [digital, aimc].
pub const P_ACT: [f64; 2] = [24.0, 26.0];
/// Average idle power, mW: [digital, aimc].
pub const P_IDLE: [f64; 2] = [1.3, 1.3];

/// Energy (uJ) of one layer interval: each accelerator is active for
/// `active_cycles[i]` within a layer lasting `span_cycles`.
pub fn layer_energy_uj(active_cycles: [u64; 2], span_cycles: u64) -> f64 {
    let mut e_mw_cycles = 0.0;
    for i in 0..2 {
        let act = active_cycles[i].min(span_cycles) as f64;
        let idle = (span_cycles - active_cycles[i].min(span_cycles)) as f64;
        e_mw_cycles += P_ACT[i] * act + P_IDLE[i] * idle;
    }
    // mW * cycles / (cycles/s) = mW*s = mJ; * 1e3 -> uJ
    e_mw_cycles / F_CLK_HZ * 1e3
}

/// mW*cycles -> uJ (for totals accumulated in model units).
pub fn mw_cycles_to_uj(v: f64) -> f64 {
    v / F_CLK_HZ * 1e3
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_idle_layer() {
        let e = layer_energy_uj([0, 0], 260_000); // 1 ms
        let want = (P_IDLE[0] + P_IDLE[1]) * 1e-3 * 1e3; // mW * ms = uJ
        assert!((e - want).abs() < 1e-9, "{e} vs {want}");
    }

    #[test]
    fn fully_active_digital() {
        let e = layer_energy_uj([260_000, 0], 260_000);
        let want = (P_ACT[0] + P_IDLE[1]) * 1.0;
        assert!((e - want).abs() < 1e-9);
    }

    #[test]
    fn active_caps_at_span() {
        // an accelerator can't be active longer than the layer span
        let a = layer_energy_uj([300_000, 0], 260_000);
        let b = layer_energy_uj([260_000, 0], 260_000);
        assert_eq!(a, b);
    }

    #[test]
    fn parallel_beats_sequential() {
        // running both accelerators in parallel (span = max) must cost
        // less than running them back-to-back (span = sum): Eq. 4's
        // rationale for parallel execution.
        let (ld, la) = (200_000u64, 150_000u64);
        let par = layer_energy_uj([ld, la], ld.max(la));
        let seq = layer_energy_uj([ld, 0], ld) + layer_energy_uj([0, la], la);
        assert!(par < seq);
    }
}
