//! DIANA SoC substrate: analytical accelerator models (paper Eq. 6/7),
//! shared-L1 constraints, the cycle-approximate execution simulator, the
//! utilization timeline (Fig. 6), energy integration (Eq. 4), and the
//! abstract hardware models of Fig. 5.
//!
//! This module is the substitution for the physical DIANA chip — see
//! DESIGN.md §Substitutions for the fidelity argument.

pub mod abstracthw;
pub mod energy;
pub mod l1;
pub mod latency;
pub mod soc;
pub mod timeline;

pub use abstracthw::AbstractHw;
pub use soc::{simulate, ChannelSplit, RunReport, SocConfig};
pub use timeline::{Timeline, Unit, Utilization};
