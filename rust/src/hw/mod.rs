//! SoC substrate: the declarative platform registry (N-accelerator
//! SoCs, [`platform`]), analytical accelerator models (paper Eq. 6/7,
//! [`latency`]), shared-L1 constraints, the cycle-approximate execution
//! simulator, the utilization timeline (Fig. 6), energy integration
//! (Eq. 4), and the abstract hardware models of Fig. 5.
//!
//! The built-in [`Platform::diana`] is the substitution for the
//! physical DIANA chip — see DESIGN.md §Substitutions for the fidelity
//! argument. Further built-ins: `diana_ne16` (3 accelerators), `gap9`
//! (no-IMC RISC-V cluster + NE16), and `mpsoc4` (4 units with two
//! distinct D/A widths); arbitrary SoCs load from `config/*.toml`.
//!
//! [`soc::simulate`] is the low-level costing kernel (raw
//! [`ChannelSplit`] in, [`RunReport`] out) kept public for parity
//! oracles and property tests; workflow code goes through
//! [`Session::simulate`](crate::api::Session::simulate), which owns
//! validation and the simulator config.

pub mod abstracthw;
pub mod energy;
pub mod faults;
pub mod l1;
pub mod latency;
pub mod platform;
pub mod soc;
pub mod timeline;

pub use abstracthw::AbstractHw;
pub use faults::{FaultEvent, FaultPlan, FaultState, ResolvedFaults, UnitHealth};
pub use platform::{AcceleratorSpec, LatencyModel, Platform};
pub use soc::{ChannelSplit, RunReport, SocConfig};
pub use timeline::{Timeline, Utilization};
