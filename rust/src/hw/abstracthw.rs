//! Abstract hardware cost models — paper Fig. 5.
//!
//! Latency simply proportional to assigned MACs per accelerator
//! (`lat_i = macs_i / thpt_i`), energy per Eq. 4 with configurable
//! active/idle powers. Two canonical configs reproduce the figure:
//! no-shutdown (P_idle = P_act) and ideal-shutdown (P_idle = 0), both
//! with the 8-bit accelerator burning 10x the ternary one's power.
//! Mirrors `python/compile/costmodel.loss_proportional` (which is what
//! the `train_search_prop` artifact optimizes with these constants as
//! runtime inputs).

use crate::model::{Graph, Op};

use super::soc::ChannelSplit;

#[derive(Clone, Copy, Debug)]
pub struct AbstractHw {
    /// MACs per cycle per accelerator [digital(8b), aimc(ternary)].
    pub thpt: [f64; 2],
    pub p_act: [f64; 2],
    pub p_idle: [f64; 2],
}

impl AbstractHw {
    /// Fig. 5 top: no shutdown — idle power equals active power, and
    /// energy minimization degenerates to latency minimization.
    pub fn no_shutdown() -> Self {
        AbstractHw { thpt: [1.0, 8.0], p_act: [10.0, 1.0], p_idle: [10.0, 1.0] }
    }

    /// Fig. 5 bottom: ideal shutdown — zero idle power.
    pub fn ideal_shutdown() -> Self {
        AbstractHw { thpt: [1.0, 8.0], p_act: [10.0, 1.0], p_idle: [0.0, 0.0] }
    }

    /// The 6-vector the `train_search_prop` artifact takes as its `hw`
    /// input: [thpt_d, thpt_a, p_act_d, p_act_a, p_idle_d, p_idle_a].
    pub fn to_input_vec(&self) -> [f32; 6] {
        [
            self.thpt[0] as f32, self.thpt[1] as f32,
            self.p_act[0] as f32, self.p_act[1] as f32,
            self.p_idle[0] as f32, self.p_idle[1] as f32,
        ]
    }

    /// (latency_cycles, energy_mw_cycles) of a mapped network.
    pub fn cost(&self, graph: &Graph, split: &ChannelSplit) -> (f64, f64) {
        let mut lat = 0.0;
        let mut en = 0.0;
        for node in &graph.nodes {
            match node.op {
                Op::Conv | Op::Fc => {
                    let (cd, ca) = split[&node.name];
                    let macs_per_ch = node.macs() as f64 / node.cout as f64;
                    let ld = macs_per_ch * cd as f64 / self.thpt[0];
                    let la = macs_per_ch * ca as f64 / self.thpt[1];
                    let span = ld.max(la);
                    lat += span;
                    en += self.p_act[0] * ld + self.p_idle[0] * (span - ld);
                    en += self.p_act[1] * la + self.p_idle[1] * (span - la);
                }
                Op::DwConv => {
                    let ld = node.macs() as f64 / self.thpt[0];
                    lat += ld;
                    en += self.p_act[0] * ld + self.p_idle[1] * ld;
                }
                _ => {}
            }
        }
        (lat, en)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::soc::{split_all_aimc, split_all_digital};
    use crate::model::tinycnn;

    #[test]
    fn no_shutdown_energy_tracks_latency() {
        // with p_idle == p_act, energy == latency * total power
        let hw = AbstractHw::no_shutdown();
        let g = tinycnn();
        for split in [split_all_digital(&g), split_all_aimc(&g)] {
            let (lat, en) = hw.cost(&g, &split);
            let p_tot: f64 = hw.p_act.iter().sum();
            assert!((en - lat * p_tot).abs() < 1e-6 * en.max(1.0), "{en} vs {}", lat * p_tot);
        }
    }

    #[test]
    fn ideal_shutdown_prefers_aimc_harder() {
        let g = tinycnn();
        let hw0 = AbstractHw::no_shutdown();
        let hw1 = AbstractHw::ideal_shutdown();
        let d = split_all_digital(&g);
        let a = split_all_aimc(&g);
        // energy ratio all-dig / all-aimc is larger under shutdown
        let r0 = hw0.cost(&g, &d).1 / hw0.cost(&g, &a).1;
        let r1 = hw1.cost(&g, &d).1 / hw1.cost(&g, &a).1;
        assert!(r1 > r0);
    }

    #[test]
    fn input_vec_layout() {
        let v = AbstractHw::ideal_shutdown().to_input_vec();
        assert_eq!(v, [1.0, 8.0, 10.0, 1.0, 0.0, 0.0]);
    }
}
