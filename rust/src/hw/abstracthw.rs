//! Abstract hardware cost models — paper Fig. 5, generalized to N
//! accelerators.
//!
//! Latency simply proportional to assigned MACs per accelerator
//! (`lat_i = macs_i / thpt_i`), energy per Eq. 4 with configurable
//! active/idle powers. Two canonical 2-accelerator configs reproduce
//! the figure: no-shutdown (P_idle = P_act) and ideal-shutdown
//! (P_idle = 0), both with the 8-bit accelerator burning 10x the
//! ternary one's power. Mirrors
//! `python/compile/costmodel.loss_proportional` (which is what the
//! `train_search_prop` artifact optimizes with these constants as
//! runtime inputs).

use crate::model::{Graph, Op};

use super::soc::ChannelSplit;

#[derive(Clone, Debug)]
pub struct AbstractHw {
    /// MACs per cycle per accelerator.
    pub thpt: Vec<f64>,
    pub p_act: Vec<f64>,
    pub p_idle: Vec<f64>,
}

impl AbstractHw {
    pub fn n_acc(&self) -> usize {
        self.thpt.len()
    }

    /// Fig. 5 top: no shutdown — idle power equals active power, and
    /// energy minimization degenerates to latency minimization.
    pub fn no_shutdown() -> Self {
        AbstractHw {
            thpt: vec![1.0, 8.0],
            p_act: vec![10.0, 1.0],
            p_idle: vec![10.0, 1.0],
        }
    }

    /// Fig. 5 bottom: ideal shutdown — zero idle power.
    pub fn ideal_shutdown() -> Self {
        AbstractHw {
            thpt: vec![1.0, 8.0],
            p_act: vec![10.0, 1.0],
            p_idle: vec![0.0, 0.0],
        }
    }

    /// The flat vector the `train_search_prop` artifact takes as its
    /// `hw` input: [thpt_0.., p_act_0.., p_idle_0..]. For the
    /// 2-accelerator artifacts this is the historical 6-vector
    /// [thpt_d, thpt_a, p_act_d, p_act_a, p_idle_d, p_idle_a].
    pub fn to_input_vec(&self) -> Vec<f32> {
        self.thpt
            .iter()
            .chain(self.p_act.iter())
            .chain(self.p_idle.iter())
            .map(|&v| v as f32)
            .collect()
    }

    /// (latency_cycles, energy_mw_cycles) of a mapped network.
    pub fn cost(&self, graph: &Graph, split: &ChannelSplit) -> (f64, f64) {
        let n_acc = self.n_acc();
        let mut lat = 0.0;
        let mut en = 0.0;
        let mut lats = vec![0.0f64; n_acc];
        for node in &graph.nodes {
            match node.op {
                Op::Conv | Op::Fc => {
                    let counts = &split[&node.name];
                    assert_eq!(counts.len(), n_acc, "split arity at {}", node.name);
                    let macs_per_ch = node.macs() as f64 / node.cout as f64;
                    for i in 0..n_acc {
                        lats[i] = macs_per_ch * counts[i] as f64 / self.thpt[i];
                    }
                    let span = lats.iter().copied().fold(0.0f64, f64::max);
                    lat += span;
                    for i in 0..n_acc {
                        en += self.p_act[i] * lats[i] + self.p_idle[i] * (span - lats[i]);
                    }
                }
                Op::DwConv => {
                    // depthwise runs on accelerator 0; the rest idle
                    let ld = node.macs() as f64 / self.thpt[0];
                    lat += ld;
                    let mut e_layer = self.p_act[0] * ld;
                    for i in 1..n_acc {
                        e_layer += self.p_idle[i] * ld;
                    }
                    en += e_layer;
                }
                _ => {}
            }
        }
        (lat, en)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::soc::{split_all_aimc, split_all_digital, split_all_on};
    use crate::model::tinycnn;

    #[test]
    fn no_shutdown_energy_tracks_latency() {
        // with p_idle == p_act, energy == latency * total power
        let hw = AbstractHw::no_shutdown();
        let g = tinycnn();
        for split in [split_all_digital(&g), split_all_aimc(&g)] {
            let (lat, en) = hw.cost(&g, &split);
            let p_tot: f64 = hw.p_act.iter().sum();
            assert!((en - lat * p_tot).abs() < 1e-6 * en.max(1.0), "{en} vs {}", lat * p_tot);
        }
    }

    #[test]
    fn ideal_shutdown_prefers_aimc_harder() {
        let g = tinycnn();
        let hw0 = AbstractHw::no_shutdown();
        let hw1 = AbstractHw::ideal_shutdown();
        let d = split_all_digital(&g);
        let a = split_all_aimc(&g);
        // energy ratio all-dig / all-aimc is larger under shutdown
        let r0 = hw0.cost(&g, &d).1 / hw0.cost(&g, &a).1;
        let r1 = hw1.cost(&g, &d).1 / hw1.cost(&g, &a).1;
        assert!(r1 > r0);
    }

    #[test]
    fn input_vec_layout() {
        let v = AbstractHw::ideal_shutdown().to_input_vec();
        assert_eq!(v, vec![1.0, 8.0, 10.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn three_acc_abstract_cost() {
        let hw = AbstractHw {
            thpt: vec![1.0, 8.0, 4.0],
            p_act: vec![10.0, 1.0, 3.0],
            p_idle: vec![1.0, 0.5, 0.3],
        };
        let g = tinycnn();
        // everything on the fastest unit is cheapest in latency
        let on0 = hw.cost(&g, &split_all_on(&g, 3, 0)).0;
        let on1 = hw.cost(&g, &split_all_on(&g, 3, 1)).0;
        let on2 = hw.cost(&g, &split_all_on(&g, 3, 2)).0;
        assert!(on1 < on2 && on2 < on0);
    }
}
