//! Accelerator fault model for fault-tolerant serving.
//!
//! A [`FaultPlan`] is a deterministic script of unit-level fault events
//! on the serve loop's *virtual* cycle timeline — nothing here is
//! sampled at run time, so a (seed, plan) pair always reproduces the
//! same degraded run byte-for-byte:
//!
//!   * [`FaultEvent::UnitDown`] — the unit is permanently lost from
//!     `at_cycle` on;
//!   * [`FaultEvent::UnitDerated`] — the unit keeps running from
//!     `at_cycle` on but `factor`x slower (thermal throttling, a dead
//!     sub-array); overlapping deratings take the worst factor;
//!   * [`FaultEvent::Transient`] — the unit is down for
//!     `[at_cycle, at_cycle + duration)` and then healthy again (a
//!     recoverable hang + reset).
//!
//! Plans load from TOML (`config/faults_demo.toml`, schema in
//! EXPERIMENTS.md §Fault plans) or JSON, or are synthesized
//! deterministically from a seed ([`FaultPlan::synth`]). Unit names are
//! resolved against a concrete [`Platform`] once, up front
//! ([`FaultPlan::resolve`]), so a typo'd unit is a load-time error, not
//! a silently ignored event. The resolved form answers the questions
//! the serve health tracker actually asks: the [`FaultState`] at a
//! cycle, the next state-change cycle after a cycle, and the earliest
//! cycle in a window at which a unit is down.

#![deny(missing_docs)]

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::config::{parse_toml, TomlValue};
use crate::util::json::Json;
use crate::util::prng::Pcg32;

use super::platform::Platform;

/// One scripted fault on the virtual serve timeline.
#[derive(Clone, Debug, PartialEq)]
pub enum FaultEvent {
    /// `unit` is permanently lost from `at_cycle` on.
    UnitDown {
        /// Accelerator name (resolved against the platform at load).
        unit: String,
        /// Virtual cycle at which the unit dies.
        at_cycle: u64,
    },
    /// `unit` runs `factor`x slower from `at_cycle` on (factor >= 1.0;
    /// overlapping deratings take the worst factor).
    UnitDerated {
        /// Accelerator name.
        unit: String,
        /// Slowdown factor (>= 1.0).
        factor: f64,
        /// Virtual cycle at which the derating starts.
        at_cycle: u64,
    },
    /// `unit` is down for `[at_cycle, at_cycle + duration)`, then
    /// healthy again.
    Transient {
        /// Accelerator name.
        unit: String,
        /// Virtual cycle at which the outage starts.
        at_cycle: u64,
        /// Outage length in cycles (> 0).
        duration: u64,
    },
}

impl FaultEvent {
    /// The accelerator name this event targets.
    pub fn unit(&self) -> &str {
        match self {
            FaultEvent::UnitDown { unit, .. }
            | FaultEvent::UnitDerated { unit, .. }
            | FaultEvent::Transient { unit, .. } => unit,
        }
    }

    /// The virtual cycle at which this event takes effect.
    pub fn at_cycle(&self) -> u64 {
        match *self {
            FaultEvent::UnitDown { at_cycle, .. }
            | FaultEvent::UnitDerated { at_cycle, .. }
            | FaultEvent::Transient { at_cycle, .. } => at_cycle,
        }
    }

    fn to_json(&self) -> Json {
        match self {
            FaultEvent::UnitDown { unit, at_cycle } => Json::obj(vec![
                ("kind", Json::str("unit_down")),
                ("unit", Json::str(unit.clone())),
                ("at_cycle", Json::num(*at_cycle as f64)),
            ]),
            FaultEvent::UnitDerated { unit, factor, at_cycle } => Json::obj(vec![
                ("kind", Json::str("derated")),
                ("unit", Json::str(unit.clone())),
                ("factor", Json::num(*factor)),
                ("at_cycle", Json::num(*at_cycle as f64)),
            ]),
            FaultEvent::Transient { unit, at_cycle, duration } => Json::obj(vec![
                ("kind", Json::str("transient")),
                ("unit", Json::str(unit.clone())),
                ("at_cycle", Json::num(*at_cycle as f64)),
                ("duration", Json::num(*duration as f64)),
            ]),
        }
    }

    fn from_json(v: &Json) -> Result<FaultEvent> {
        let kind = v
            .req("kind")?
            .as_str()
            .ok_or_else(|| anyhow!("fault event: 'kind' must be a string"))?;
        let unit = v
            .req("unit")?
            .as_str()
            .ok_or_else(|| anyhow!("fault event: 'unit' must be a string"))?
            .to_string();
        let at_cycle = v.req_f64("at_cycle")? as u64;
        match kind {
            "unit_down" => Ok(FaultEvent::UnitDown { unit, at_cycle }),
            "derated" => {
                Ok(FaultEvent::UnitDerated { unit, factor: v.req_f64("factor")?, at_cycle })
            }
            "transient" => Ok(FaultEvent::Transient {
                unit,
                at_cycle,
                duration: v.req_f64("duration")? as u64,
            }),
            other => {
                Err(anyhow!("fault event: unknown kind '{other}' (unit_down|derated|transient)"))
            }
        }
    }
}

/// Health of one accelerator at one instant of the virtual timeline.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum UnitHealth {
    /// Fully operational.
    Up,
    /// Operational, but all layer latencies scale by this factor.
    Derated(f64),
    /// Not accepting work.
    Down,
}

/// Per-unit health snapshot (indexed like `Platform::accelerators`).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultState {
    /// Health of each accelerator, in platform order.
    pub health: Vec<UnitHealth>,
}

impl FaultState {
    /// The all-healthy state for an `n`-unit platform.
    pub fn healthy(n: usize) -> FaultState {
        FaultState { health: vec![UnitHealth::Up; n] }
    }

    /// True when every unit is `Up`.
    pub fn all_up(&self) -> bool {
        self.health.iter().all(|h| matches!(h, UnitHealth::Up))
    }

    /// True when unit `i` is down.
    pub fn is_down(&self, i: usize) -> bool {
        matches!(self.health.get(i), Some(UnitHealth::Down))
    }

    /// Latency scale factor of unit `i` (1.0 for `Up`; a down unit has
    /// no meaningful factor and also reports 1.0 — callers gate on
    /// [`FaultState::is_down`] first).
    pub fn factor(&self, i: usize) -> f64 {
        match self.health.get(i) {
            Some(UnitHealth::Derated(f)) => *f,
            _ => 1.0,
        }
    }

    /// Indices of the units that are *not* down, in platform order.
    pub fn survivors(&self) -> Vec<usize> {
        (0..self.health.len()).filter(|&i| !self.is_down(i)).collect()
    }

    /// FNV-1a hash of the snapshot — the cache key for per-fault-state
    /// artifacts (degraded platforms, re-mapped frontier points).
    /// Derating factors hash by exact bit pattern.
    pub fn key(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        };
        eat(&(self.health.len() as u64).to_le_bytes());
        for u in &self.health {
            match u {
                UnitHealth::Up => eat(&[0]),
                UnitHealth::Derated(f) => {
                    eat(&[1]);
                    eat(&f.to_bits().to_le_bytes());
                }
                UnitHealth::Down => eat(&[2]),
            }
        }
        h
    }
}

/// A deterministic script of fault events (unit names unresolved).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// The scripted events, in file/declaration order.
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// The empty plan (no faults; serve behaves exactly as without one).
    pub fn empty() -> FaultPlan {
        FaultPlan { events: Vec::new() }
    }

    /// Structural validation: finite factors >= 1.0, non-zero
    /// transient durations. (Unit names are checked at
    /// [`FaultPlan::resolve`] time, against a concrete platform.)
    pub fn validate(&self) -> Result<()> {
        for (i, e) in self.events.iter().enumerate() {
            match e {
                FaultEvent::UnitDerated { factor, .. } => {
                    if !factor.is_finite() || *factor < 1.0 {
                        return Err(anyhow!(
                            "fault plan event {i}: derating factor {factor} must be finite \
                             and >= 1.0"
                        ));
                    }
                }
                FaultEvent::Transient { duration, .. } => {
                    if *duration == 0 {
                        return Err(anyhow!(
                            "fault plan event {i}: transient duration must be > 0"
                        ));
                    }
                }
                FaultEvent::UnitDown { .. } => {}
            }
        }
        Ok(())
    }

    /// Load a plan from a `.toml` or `.json` file (by extension).
    pub fn from_file(path: &Path) -> Result<FaultPlan> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        let ext = path.extension().and_then(|e| e.to_str()).unwrap_or("");
        match ext {
            "toml" => FaultPlan::from_toml_text(&text),
            "json" => FaultPlan::from_json_text(&text),
            other => Err(anyhow!(
                "fault plan {}: unsupported extension '{other}' (.toml or .json)",
                path.display()
            )),
        }
    }

    /// Parse the TOML schema (EXPERIMENTS.md §Fault plans): a `[plan]`
    /// section with an `events` ordering array, one `[event.<id>]`
    /// section per event.
    pub fn from_toml_text(text: &str) -> Result<FaultPlan> {
        let doc = parse_toml(text)?;
        let order = match doc.get("plan.events") {
            Some(TomlValue::Arr(a)) => a
                .iter()
                .map(|v| match v {
                    TomlValue::Str(s) => Ok(s.clone()),
                    _ => Err(anyhow!("fault plan toml: plan.events entries must be strings")),
                })
                .collect::<Result<Vec<String>>>()?,
            _ => return Err(anyhow!("fault plan toml: missing plan.events array")),
        };
        let mut events = Vec::with_capacity(order.len());
        for id in &order {
            let key = |f: &str| format!("event.{id}.{f}");
            let get_str = |f: &str| -> Result<String> {
                match doc.get(&key(f)) {
                    Some(TomlValue::Str(s)) => Ok(s.clone()),
                    Some(_) => Err(anyhow!("fault plan toml: {} must be a string", key(f))),
                    None => Err(anyhow!("fault plan toml: missing {}", key(f))),
                }
            };
            let get_num = |f: &str| -> Result<f64> {
                match doc.get(&key(f)) {
                    Some(TomlValue::Num(n)) => Ok(*n),
                    Some(_) => Err(anyhow!("fault plan toml: {} must be a number", key(f))),
                    None => Err(anyhow!("fault plan toml: missing {}", key(f))),
                }
            };
            let kind = get_str("kind")?;
            let unit = get_str("unit")?;
            let at_cycle = get_num("at_cycle")? as u64;
            events.push(match kind.as_str() {
                "unit_down" => FaultEvent::UnitDown { unit, at_cycle },
                "derated" => {
                    FaultEvent::UnitDerated { unit, factor: get_num("factor")?, at_cycle }
                }
                "transient" => FaultEvent::Transient {
                    unit,
                    at_cycle,
                    duration: get_num("duration")? as u64,
                },
                other => {
                    return Err(anyhow!(
                        "fault plan toml: event.{id}: unknown kind '{other}' \
                         (unit_down|derated|transient)"
                    ))
                }
            });
        }
        let plan = FaultPlan { events };
        plan.validate()?;
        Ok(plan)
    }

    /// Parse the JSON form: `{"events": [{...}, ...]}`.
    pub fn from_json_text(text: &str) -> Result<FaultPlan> {
        let v = crate::util::json::parse(text)
            .map_err(|e| anyhow!("fault plan json: {e}"))?;
        FaultPlan::from_json(&v)
    }

    /// Serialize to the JSON form.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "events",
            Json::Arr(self.events.iter().map(|e| e.to_json()).collect()),
        )])
    }

    /// Deserialize the JSON form (inverse of [`FaultPlan::to_json`]).
    pub fn from_json(v: &Json) -> Result<FaultPlan> {
        let arr = v
            .req("events")?
            .as_arr()
            .ok_or_else(|| anyhow!("fault plan json: 'events' must be an array"))?;
        let events =
            arr.iter().map(FaultEvent::from_json).collect::<Result<Vec<FaultEvent>>>()?;
        let plan = FaultPlan { events };
        plan.validate()?;
        Ok(plan)
    }

    /// Synthesize a seed-deterministic plan against `platform`: 1-4
    /// events over `[0, horizon)` cycles, never downing the last
    /// surviving unit (at most `n_acc - 1` permanent losses, and the
    /// one transient outage the generator emits never overlaps them).
    pub fn synth(seed: u64, platform: &Platform, horizon: u64) -> FaultPlan {
        let mut rng = Pcg32::new(seed, 909);
        let n = platform.n_acc();
        let horizon = horizon.max(8);
        let half = (horizon / 2).min(u32::MAX as u64) as u32;
        let quarter = (horizon / 4).min(u32::MAX as u64) as u32;
        let n_events = 1 + rng.below(4) as usize;
        let mut permanently_down = vec![false; n];
        let mut transient_done = false;
        let mut events = Vec::with_capacity(n_events);
        for _ in 0..n_events {
            let unit_idx = rng.below(n as u32) as usize;
            let unit = platform.accelerators[unit_idx].name.clone();
            let at_cycle = rng.below(half) as u64;
            let kind = rng.below(3);
            let down_budget_left =
                permanently_down.iter().filter(|&&d| d).count() + 1 < n;
            match kind {
                0 if down_budget_left && !permanently_down[unit_idx] => {
                    permanently_down[unit_idx] = true;
                    events.push(FaultEvent::UnitDown { unit, at_cycle });
                }
                // a transient outage also removes a unit for its span;
                // cap at one so a synthetic plan can never have every
                // unit simultaneously unavailable
                2 if !transient_done
                    && down_budget_left
                    && !permanently_down[unit_idx] =>
                {
                    transient_done = true;
                    permanently_down[unit_idx] = true;
                    let duration = horizon / 8 + rng.below(quarter) as u64;
                    events.push(FaultEvent::Transient { unit, at_cycle, duration });
                }
                _ => {
                    let factor = 1.25 + 2.75 * rng.next_f32() as f64;
                    events.push(FaultEvent::UnitDerated { unit, factor, at_cycle });
                }
            }
        }
        FaultPlan { events }
    }

    /// Resolve unit names against `platform`, producing the indexed
    /// form the serve health tracker queries. Errors on unknown units
    /// and on structural problems ([`FaultPlan::validate`]).
    pub fn resolve(&self, platform: &Platform) -> Result<ResolvedFaults> {
        self.validate()?;
        let mut events = Vec::with_capacity(self.events.len());
        for e in &self.events {
            let unit = platform.acc_index(e.unit()).ok_or_else(|| {
                anyhow!(
                    "fault plan: unknown unit '{}' on platform {} (units: {:?})",
                    e.unit(),
                    platform.name,
                    platform.acc_names()
                )
            })?;
            events.push(ResolvedEvent { unit, event: e.clone() });
        }
        let mut changes: Vec<u64> = Vec::new();
        for e in &events {
            changes.push(e.event.at_cycle());
            if let FaultEvent::Transient { at_cycle, duration, .. } = e.event {
                changes.push(at_cycle.saturating_add(duration));
            }
        }
        changes.sort_unstable();
        changes.dedup();
        Ok(ResolvedFaults { n_units: platform.n_acc(), events, changes })
    }
}

/// One event with its unit name resolved to a platform index.
#[derive(Clone, Debug)]
struct ResolvedEvent {
    unit: usize,
    event: FaultEvent,
}

/// A [`FaultPlan`] resolved against a concrete platform: the queryable
/// timeline form.
#[derive(Clone, Debug)]
pub struct ResolvedFaults {
    n_units: usize,
    events: Vec<ResolvedEvent>,
    /// Sorted, deduplicated cycles at which the fault state changes.
    changes: Vec<u64>,
}

impl ResolvedFaults {
    /// Number of scripted events.
    pub fn n_events(&self) -> usize {
        self.events.len()
    }

    /// Number of platform units the plan was resolved against.
    pub fn n_units(&self) -> usize {
        self.n_units
    }

    /// The health snapshot at virtual cycle `t`.
    pub fn state_at(&self, t: u64) -> FaultState {
        let mut health = vec![UnitHealth::Up; self.n_units];
        // down wins over derated; overlapping deratings take the max
        let mut factor = vec![1.0f64; self.n_units];
        let mut down = vec![false; self.n_units];
        for e in &self.events {
            match e.event {
                FaultEvent::UnitDown { at_cycle, .. } => {
                    if t >= at_cycle {
                        down[e.unit] = true;
                    }
                }
                FaultEvent::Transient { at_cycle, duration, .. } => {
                    if t >= at_cycle && t < at_cycle.saturating_add(duration) {
                        down[e.unit] = true;
                    }
                }
                FaultEvent::UnitDerated { factor: f, at_cycle, .. } => {
                    if t >= at_cycle && f > factor[e.unit] {
                        factor[e.unit] = f;
                    }
                }
            }
        }
        for i in 0..self.n_units {
            health[i] = if down[i] {
                UnitHealth::Down
            } else if factor[i] > 1.0 {
                UnitHealth::Derated(factor[i])
            } else {
                UnitHealth::Up
            };
        }
        FaultState { health }
    }

    /// The first state-change cycle strictly after `t`, if any.
    pub fn next_change_after(&self, t: u64) -> Option<u64> {
        self.changes.iter().copied().find(|&c| c > t)
    }

    /// Earliest cycle in `[from, to)` at which unit `u` is down, if
    /// any — the abort point for a batch occupying `u` over that span.
    pub fn down_in(&self, u: usize, from: u64, to: u64) -> Option<u64> {
        let mut earliest: Option<u64> = None;
        for e in &self.events {
            if e.unit != u {
                continue;
            }
            let (a, b) = match e.event {
                FaultEvent::UnitDown { at_cycle, .. } => (at_cycle, u64::MAX),
                FaultEvent::Transient { at_cycle, duration, .. } => {
                    (at_cycle, at_cycle.saturating_add(duration))
                }
                FaultEvent::UnitDerated { .. } => continue,
            };
            if b > from && a < to {
                let hit = a.max(from);
                match earliest {
                    Some(cur) if hit >= cur => {}
                    _ => earliest = Some(hit),
                }
            }
        }
        earliest
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    fn demo_plan() -> FaultPlan {
        FaultPlan {
            events: vec![
                FaultEvent::UnitDerated { unit: "npu".into(), factor: 2.0, at_cycle: 1_000 },
                FaultEvent::UnitDown { unit: "imc0".into(), at_cycle: 5_000 },
                FaultEvent::Transient { unit: "gpu".into(), at_cycle: 8_000, duration: 2_000 },
            ],
        }
    }

    #[test]
    fn state_timeline_matches_events() {
        let r = demo_plan().resolve(&Platform::mpsoc4()).unwrap();
        assert_eq!(r.n_events(), 3);
        assert!(r.state_at(0).all_up());
        let s = r.state_at(1_000);
        assert_eq!(s.health[0], UnitHealth::Derated(2.0));
        assert!(!s.is_down(1));
        let s = r.state_at(6_000);
        assert!(s.is_down(1), "imc0 down from 5000");
        assert_eq!(s.survivors(), vec![0, 2, 3]);
        // transient: down inside the window, back up after
        assert!(r.state_at(9_999).is_down(3));
        assert!(!r.state_at(10_000).is_down(3));
        // factors: derated reports its factor, up/down report 1.0
        assert_eq!(r.state_at(2_000).factor(0), 2.0);
        assert_eq!(r.state_at(0).factor(0), 1.0);
    }

    #[test]
    fn change_cycles_and_down_windows() {
        let r = demo_plan().resolve(&Platform::mpsoc4()).unwrap();
        assert_eq!(r.next_change_after(0), Some(1_000));
        assert_eq!(r.next_change_after(1_000), Some(5_000));
        assert_eq!(r.next_change_after(8_000), Some(10_000));
        assert_eq!(r.next_change_after(10_000), None);
        // permanent down: any window past at_cycle hits
        assert_eq!(r.down_in(1, 0, 4_000), None);
        assert_eq!(r.down_in(1, 0, 6_000), Some(5_000));
        assert_eq!(r.down_in(1, 7_000, 8_000), Some(7_000), "already down at start");
        // transient: only inside its span
        assert_eq!(r.down_in(3, 0, 8_000), None);
        assert_eq!(r.down_in(3, 0, 9_000), Some(8_000));
        assert_eq!(r.down_in(3, 10_000, u64::MAX), None);
        // derated unit never reports down
        assert_eq!(r.down_in(0, 0, u64::MAX), None);
    }

    #[test]
    fn state_key_distinguishes_states() {
        let r = demo_plan().resolve(&Platform::mpsoc4()).unwrap();
        let healthy = r.state_at(0);
        assert_eq!(healthy.key(), FaultState::healthy(4).key());
        let keys: Vec<u64> =
            [0, 1_000, 5_000, 8_000, 10_000].iter().map(|&t| r.state_at(t).key()).collect();
        for i in 0..keys.len() {
            for j in i + 1..keys.len() {
                assert_ne!(keys[i], keys[j], "states at steps {i} and {j} must key apart");
            }
        }
    }

    #[test]
    fn unknown_unit_is_a_load_error() {
        let plan = FaultPlan {
            events: vec![FaultEvent::UnitDown { unit: "warp_core".into(), at_cycle: 0 }],
        };
        let e = plan.resolve(&Platform::diana()).unwrap_err().to_string();
        assert!(e.contains("warp_core"), "{e}");
        assert!(e.contains("diana"), "{e}");
    }

    #[test]
    fn validation_rejects_bad_fields() {
        let bad = FaultPlan {
            events: vec![FaultEvent::UnitDerated {
                unit: "dig".into(),
                factor: 0.5,
                at_cycle: 0,
            }],
        };
        assert!(bad.validate().is_err(), "factor < 1.0");
        let bad = FaultPlan {
            events: vec![FaultEvent::Transient {
                unit: "dig".into(),
                at_cycle: 0,
                duration: 0,
            }],
        };
        assert!(bad.validate().is_err(), "zero duration");
        assert!(FaultPlan::empty().validate().is_ok());
    }

    #[test]
    fn toml_and_json_roundtrip() {
        let text = "\
[plan]
events = [\"e0\", \"e1\", \"e2\"]

[event.e0]
kind = \"derated\"
unit = \"npu\"
factor = 2.0
at_cycle = 1000

[event.e1]
kind = \"unit_down\"
unit = \"imc0\"
at_cycle = 5000

[event.e2]
kind = \"transient\"
unit = \"gpu\"
at_cycle = 8000
duration = 2000
";
        let from_toml = FaultPlan::from_toml_text(text).unwrap();
        assert_eq!(from_toml, demo_plan());
        let back = FaultPlan::from_json(&from_toml.to_json()).unwrap();
        assert_eq!(back, from_toml);
    }

    #[test]
    fn toml_errors_are_specific() {
        assert!(FaultPlan::from_toml_text("x = 1\n").is_err(), "missing plan.events");
        let e = FaultPlan::from_toml_text(
            "[plan]\nevents = [\"e0\"]\n[event.e0]\nkind = \"warp\"\nunit = \"a\"\n\
             at_cycle = 0\n",
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("unknown kind"), "{e}");
        let e = FaultPlan::from_toml_text("[plan]\nevents = [\"e0\"]\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("event.e0"), "{e}");
    }

    #[test]
    fn synth_is_deterministic_and_never_kills_every_unit() {
        let p = Platform::mpsoc4();
        for seed in 0..50u64 {
            let a = FaultPlan::synth(seed, &p, 1_000_000);
            let b = FaultPlan::synth(seed, &p, 1_000_000);
            assert_eq!(a, b, "seed {seed}");
            assert!(!a.events.is_empty() && a.events.len() <= 4, "seed {seed}");
            a.validate().unwrap();
            let r = a.resolve(&p).unwrap();
            // at every state change at least one unit survives
            for t in [0u64, 1, 250_000, 500_000, 999_999, u64::MAX / 2] {
                assert!(
                    !r.state_at(t).survivors().is_empty(),
                    "seed {seed}: all units down at {t}"
                );
            }
        }
        // single-unit platform: synth can only derate
        let mut solo = Platform::diana();
        solo.accelerators.truncate(1);
        for seed in 0..20u64 {
            let plan = FaultPlan::synth(seed, &solo, 100_000);
            for e in &plan.events {
                assert!(
                    matches!(e, FaultEvent::UnitDerated { .. }),
                    "seed {seed}: single unit must never go down"
                );
            }
        }
    }
}
