//! Multi-accelerator SoC simulator — executes one end-to-end inference
//! of a mapped network on a [`Platform`] and produces the
//! measured-equivalent numbers of Table I: latency (ms), energy (uJ),
//! per-accelerator utilization, plus the Fig.-6 timeline.
//!
//! Execution model (paper Sec. III-A): layers run sequentially (data
//! dependence through the shared L1); within a mappable layer all
//! platform accelerators run their channel sub-layers in parallel, each
//! costing its spec's latency model; depthwise convs run on the
//! platform's designated unit; add/gap/input run on the control core
//! and are not charged (the paper's models do not count them either).
//!
//! With [`Platform::diana`] this reproduces the pre-refactor hardwired
//! 2-accelerator simulator byte-for-byte (tests/diana_parity.rs).

use std::collections::BTreeMap;

use crate::model::{Graph, Op};

use super::l1::{check_layer_bytes, tiling_penalty_bytes};
use super::platform::Platform;
use super::timeline::Timeline;

/// Per-layer channel split: mappable node name -> channel count per
/// accelerator (one entry per platform accelerator, in platform order).
pub type ChannelSplit = BTreeMap<String, Vec<usize>>;

#[derive(Clone, Copy, Debug, Default)]
pub struct SocConfig {
    /// Charge tiling penalties when activations overflow L1 (the paper's
    /// analytical models neglect this; off by default for parity).
    pub non_ideal_l1: bool,
}

#[derive(Clone, Debug)]
pub struct RunReport {
    pub total_cycles: u64,
    pub latency_ms: f64,
    pub energy_uj: f64,
    /// Busy fraction per accelerator (Table I util columns).
    pub util: Vec<f64>,
    /// Fraction of channels (over all mappable layers) per accelerator.
    pub channel_frac: Vec<f64>,
    pub timeline: Timeline,
    /// Layers whose activations overflowed L1 (only flagged non-ideal).
    pub l1_overflows: Vec<String>,
}

impl RunReport {
    /// Table I "A. Ch.": fraction of channels on accelerator 1 (the
    /// AIMC macro on DIANA-family platforms).
    pub fn aimc_channel_frac(&self) -> f64 {
        self.channel_frac.get(1).copied().unwrap_or(0.0)
    }
}

/// Simulate one inference of `graph` under `split` on `platform`.
///
/// Low-level costing kernel: workflow code goes through
/// [`Session::simulate`](crate::api::Session::simulate), which owns
/// validation and the simulator config; this raw-`ChannelSplit` entry
/// stays public for parity oracles and property tests.
///
/// Panics if `split` is missing a mappable layer, has the wrong number
/// of per-accelerator counts, or counts that do not sum to the layer
/// width — those are coordinator bugs, not run-time conditions.
pub fn simulate(
    graph: &Graph,
    split: &ChannelSplit,
    platform: &Platform,
    cfg: SocConfig,
) -> RunReport {
    let n_acc = platform.n_acc();
    let mut tl = Timeline::new(n_acc);
    let mut t = 0u64; // current cycle
    let mut energy = 0.0;
    let mut ch_total = 0usize;
    let mut ch_acc = vec![0usize; n_acc];
    let mut overflows = Vec::new();
    let mut lats = vec![0u64; n_acc];
    let mut dw_lats = vec![0u64; n_acc];
    let dw_wmem = platform.accelerators[platform.dw_acc]
        .wmem_bytes
        .unwrap_or(usize::MAX);

    for node in &graph.nodes {
        match node.op {
            Op::Conv | Op::Fc => {
                let counts = split
                    .get(&node.name)
                    .unwrap_or_else(|| panic!("split missing layer '{}'", node.name));
                assert_eq!(
                    counts.len(),
                    n_acc,
                    "layer {}: {} counts for {} accelerators",
                    node.name,
                    counts.len(),
                    n_acc
                );
                let total: usize = counts.iter().sum();
                assert_eq!(
                    total,
                    node.cout,
                    "layer {}: split {counts:?} sums to {total} != cout {}",
                    node.name,
                    node.cout
                );
                ch_total += node.cout;
                for (i, &c) in counts.iter().enumerate() {
                    ch_acc[i] += c;
                    lats[i] = platform.layer_cycles(i, node, c as u64);
                }
                // the digital-unit weight footprint drives the l1 report's
                // w_overflow flag only; act overflow drives the penalty
                let rep = check_layer_bytes(
                    platform.l1_bytes,
                    dw_wmem,
                    node.cin,
                    node.in_hw,
                    node.cout,
                    node.out_hw,
                    node.k,
                    counts[platform.dw_acc],
                );
                if rep.act_overflow {
                    overflows.push(node.name.clone());
                    if cfg.non_ideal_l1 {
                        let p = tiling_penalty_bytes(rep.act_bytes, platform.l1_bytes);
                        for l in lats.iter_mut() {
                            *l *= p;
                        }
                    }
                }
                let span = lats.iter().copied().max().unwrap_or(0);
                let layer = tl.intern(&node.name);
                for (i, &l) in lats.iter().enumerate() {
                    tl.push(i, layer, t, t + l);
                }
                energy += platform.layer_energy_uj(&lats, span);
                t += span;
            }
            Op::DwConv => {
                let ld = platform.dw_layer_cycles(node);
                let layer = tl.intern(&node.name);
                tl.push(platform.dw_acc, layer, t, t + ld);
                dw_lats.fill(0);
                dw_lats[platform.dw_acc] = ld;
                energy += platform.layer_energy_uj(&dw_lats, ld);
                t += ld;
            }
            Op::Input | Op::Add | Op::Gap => {
                // control-core work, not modeled (paper convention)
            }
        }
    }
    tl.total_cycles = t;
    let util = tl.utilization();
    RunReport {
        total_cycles: t,
        latency_ms: platform.cycles_to_ms(t),
        energy_uj: energy,
        util: util.busy_frac,
        channel_frac: ch_acc
            .iter()
            .map(|&c| if ch_total == 0 { 0.0 } else { c as f64 / ch_total as f64 })
            .collect(),
        timeline: tl,
        l1_overflows: overflows,
    }
}

/// Per-layer per-unit cost of one inference — the trace exporter's
/// attribution source (docs/ARCHITECTURE.md §Observability).
#[derive(Clone, Debug)]
pub struct LayerCost {
    /// Layer (graph node) name.
    pub name: String,
    /// Active cycles per accelerator inside this layer's window
    /// (tiling penalties applied exactly as [`simulate`] does).
    pub unit_cycles: Vec<u64>,
    /// Window length: max over `unit_cycles` (sequential layers, so
    /// the windows sum to [`RunReport::total_cycles`]).
    pub span: u64,
    /// Per-unit energy split (active + idle share), uJ; sums to this
    /// layer's contribution to [`RunReport::energy_uj`].
    pub unit_energy_uj: Vec<f64>,
}

/// Break one inference of `graph` under `split` into per-layer
/// per-unit costs. Mirrors [`simulate`]'s execution model exactly —
/// same latency models, same L1 tiling penalty, depthwise on the
/// platform's `dw_acc` — so `sum(span) == total_cycles` and
/// `sum(unit_energy_uj) == energy_uj` of the corresponding
/// [`RunReport`] (pinned by a test below). Uncharged ops (input, add,
/// gap) produce no entry, matching the paper's cost convention.
pub fn layer_breakdown(
    graph: &Graph,
    split: &ChannelSplit,
    platform: &Platform,
    cfg: SocConfig,
) -> Vec<LayerCost> {
    let n_acc = platform.n_acc();
    let mut out = Vec::new();
    let mut lats = vec![0u64; n_acc];
    let dw_wmem = platform.accelerators[platform.dw_acc]
        .wmem_bytes
        .unwrap_or(usize::MAX);

    for node in &graph.nodes {
        match node.op {
            Op::Conv | Op::Fc => {
                let counts = split
                    .get(&node.name)
                    .unwrap_or_else(|| panic!("split missing layer '{}'", node.name));
                for (i, &c) in counts.iter().enumerate() {
                    lats[i] = platform.layer_cycles(i, node, c as u64);
                }
                let rep = check_layer_bytes(
                    platform.l1_bytes,
                    dw_wmem,
                    node.cin,
                    node.in_hw,
                    node.cout,
                    node.out_hw,
                    node.k,
                    counts[platform.dw_acc],
                );
                if rep.act_overflow && cfg.non_ideal_l1 {
                    let p = tiling_penalty_bytes(rep.act_bytes, platform.l1_bytes);
                    for l in lats.iter_mut() {
                        *l *= p;
                    }
                }
                let span = lats.iter().copied().max().unwrap_or(0);
                out.push(LayerCost {
                    name: node.name.clone(),
                    unit_cycles: lats.clone(),
                    span,
                    unit_energy_uj: platform.layer_energy_split_uj(&lats, span),
                });
            }
            Op::DwConv => {
                let ld = platform.dw_layer_cycles(node);
                let mut dw_lats = vec![0u64; n_acc];
                dw_lats[platform.dw_acc] = ld;
                let unit_energy_uj = platform.layer_energy_split_uj(&dw_lats, ld);
                out.push(LayerCost {
                    name: node.name.clone(),
                    unit_cycles: dw_lats,
                    span: ld,
                    unit_energy_uj,
                });
            }
            Op::Input | Op::Add | Op::Gap => {}
        }
    }
    out
}

/// All channels of every mappable layer on accelerator `acc` of an
/// `n_acc`-accelerator platform.
pub fn split_all_on(graph: &Graph, n_acc: usize, acc: usize) -> ChannelSplit {
    assert!(acc < n_acc);
    graph
        .mappable()
        .iter()
        .map(|n| {
            let mut counts = vec![0usize; n_acc];
            counts[acc] = n.cout;
            (n.name.clone(), counts)
        })
        .collect()
}

/// Convenience DIANA splits (2 accelerators).
pub fn split_all_digital(graph: &Graph) -> ChannelSplit {
    split_all_on(graph, 2, 0)
}

pub fn split_all_aimc(graph: &Graph) -> ChannelSplit {
    split_all_on(graph, 2, 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{resnet20, tinycnn};

    fn diana() -> Platform {
        Platform::diana()
    }

    #[test]
    fn all_digital_fully_utilizes_digital() {
        let g = tinycnn();
        let r = simulate(&g, &split_all_digital(&g), &diana(), SocConfig::default());
        assert!((r.util[0] - 1.0).abs() < 1e-9, "digital util {}", r.util[0]);
        assert_eq!(r.util[1], 0.0);
        assert_eq!(r.aimc_channel_frac(), 0.0);
        assert!(r.latency_ms > 0.0 && r.energy_uj > 0.0);
    }

    #[test]
    fn all_aimc_is_faster_and_cheaper() {
        let g = resnet20();
        let p = diana();
        let d = simulate(&g, &split_all_digital(&g), &p, SocConfig::default());
        let a = simulate(&g, &split_all_aimc(&g), &p, SocConfig::default());
        assert!(a.total_cycles < d.total_cycles / 3,
                "aimc {} vs dig {}", a.total_cycles, d.total_cycles);
        assert!(a.energy_uj < d.energy_uj);
        assert!((a.aimc_channel_frac() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn balanced_split_overlaps() {
        let g = tinycnn();
        let mut split = ChannelSplit::new();
        for n in g.mappable() {
            split.insert(n.name.clone(), vec![n.cout / 2, n.cout - n.cout / 2]);
        }
        let r = simulate(&g, &split, &diana(), SocConfig::default());
        assert!(r.timeline.overlap_cycles() > 0);
        assert!(r.util[0] > 0.0 && r.util[1] > 0.0);
    }

    #[test]
    fn split_latency_never_exceeds_all_digital() {
        // moving channels to the (parallel, faster) AIMC can only shrink
        // the per-layer max
        let g = resnet20();
        let p = diana();
        let d = simulate(&g, &split_all_digital(&g), &p, SocConfig::default());
        let mut split = ChannelSplit::new();
        for n in g.mappable() {
            split.insert(n.name.clone(), vec![n.cout / 2, n.cout - n.cout / 2]);
        }
        let h = simulate(&g, &split, &p, SocConfig::default());
        assert!(h.total_cycles <= d.total_cycles);
    }

    #[test]
    #[should_panic(expected = "split missing layer")]
    fn missing_layer_panics() {
        let g = tinycnn();
        simulate(&g, &ChannelSplit::new(), &diana(), SocConfig::default());
    }

    #[test]
    #[should_panic(expected = "!= cout")]
    fn wrong_count_panics() {
        let g = tinycnn();
        let mut s = split_all_digital(&g);
        s.insert("stem".into(), vec![3, 3]);
        simulate(&g, &s, &diana(), SocConfig::default());
    }

    #[test]
    #[should_panic(expected = "counts for")]
    fn wrong_arity_panics() {
        let g = tinycnn();
        let mut s = split_all_digital(&g);
        s.insert("stem".into(), vec![8]);
        simulate(&g, &s, &diana(), SocConfig::default());
    }

    #[test]
    fn layer_breakdown_partitions_simulate_exactly() {
        // the breakdown is the trace exporter's ground truth: its
        // windows must tile the simulated run with no gap or overlap,
        // in both ideal and non-ideal-L1 modes, on 2- and 4-unit SoCs
        for (g, p) in [
            (resnet20(), Platform::diana()),
            (resnet20(), Platform::mpsoc4()),
        ] {
            let n_acc = p.n_acc();
            let mut split = ChannelSplit::new();
            for n in g.mappable() {
                let q = n.cout / n_acc;
                let mut counts = vec![q; n_acc];
                counts[0] = n.cout - q * (n_acc - 1);
                split.insert(n.name.clone(), counts);
            }
            for cfg in [SocConfig::default(), SocConfig { non_ideal_l1: true }] {
                let r = simulate(&g, &split, &p, cfg);
                let layers = layer_breakdown(&g, &split, &p, cfg);
                let cycles: u64 = layers.iter().map(|l| l.span).sum();
                assert_eq!(cycles, r.total_cycles, "{} cfg {cfg:?}", p.name);
                let energy: f64 = layers
                    .iter()
                    .map(|l| l.unit_energy_uj.iter().sum::<f64>())
                    .sum();
                assert!(
                    (energy - r.energy_uj).abs() < 1e-9 * r.energy_uj.max(1.0),
                    "{}: {energy} vs {}",
                    p.name,
                    r.energy_uj
                );
                for l in &layers {
                    assert_eq!(l.unit_cycles.len(), n_acc);
                    assert_eq!(l.unit_energy_uj.len(), n_acc);
                    assert_eq!(l.span, l.unit_cycles.iter().copied().max().unwrap_or(0));
                }
            }
        }
    }

    #[test]
    fn resnet20_all_digital_near_paper_scale() {
        // Table I: All-8bit ResNet20 = 1.55 ms / 38.71 uJ. The analytical
        // models won't match silicon exactly, but the simulator must land
        // on the same order of magnitude for the calibration to be
        // meaningful.
        let g = resnet20();
        let r = simulate(&g, &split_all_digital(&g), &diana(), SocConfig::default());
        assert!(r.latency_ms > 0.3 && r.latency_ms < 8.0, "lat {}", r.latency_ms);
        assert!(r.energy_uj > 8.0 && r.energy_uj < 200.0, "en {}", r.energy_uj);
    }

    #[test]
    fn four_acc_mpsoc_runs_and_reports_all_units() {
        // the 4-unit MPSoC (distinct D/A widths are a quant-engine
        // concern; the simulator only sees latency/power specs)
        let p = Platform::mpsoc4();
        let g = resnet20();
        let mut split = ChannelSplit::new();
        for n in g.mappable() {
            let q = n.cout / 4;
            split.insert(n.name.clone(), vec![q, q, q, n.cout - 3 * q]);
        }
        let r = simulate(&g, &split, &p, SocConfig::default());
        assert_eq!(r.util.len(), 4);
        assert_eq!(r.channel_frac.len(), 4);
        assert!(r.util.iter().all(|&u| (0.0..=1.0).contains(&u)));
        assert!((r.channel_frac.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(r.total_cycles > 0 && r.energy_uj > 0.0);
    }

    #[test]
    fn three_acc_platform_runs_and_reports_all_units() {
        let p = Platform::diana_ne16();
        let g = resnet20();
        // round-robin thirds per layer
        let mut split = ChannelSplit::new();
        for n in g.mappable() {
            let a = n.cout / 3;
            let b = n.cout / 3;
            split.insert(n.name.clone(), vec![a, b, n.cout - a - b]);
        }
        let r = simulate(&g, &split, &p, SocConfig::default());
        assert_eq!(r.util.len(), 3);
        assert_eq!(r.channel_frac.len(), 3);
        assert!(r.util.iter().all(|&u| (0.0..=1.0).contains(&u)));
        assert!(r.util.iter().all(|&u| u > 0.0), "all units busy: {:?}", r.util);
        assert!((r.channel_frac.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(r.total_cycles > 0 && r.energy_uj > 0.0);
    }
}
