//! DIANA SoC simulator — executes one end-to-end inference of a mapped
//! network and produces the measured-equivalent numbers of Table I:
//! latency (ms), energy (uJ), per-accelerator utilization, plus the
//! Fig.-6 timeline.
//!
//! Execution model (paper Sec. III-A): layers run sequentially (data
//! dependence through the shared L1); within a mappable layer the two
//! accelerators run their channel sub-layers in parallel, each costing
//! its Eq. 6/7 cycles; depthwise convs run digital-only; add/gap/input
//! run on the RISC-V control core and are not charged (the paper's
//! models do not count them either).

use std::collections::BTreeMap;

use crate::model::{Graph, Op};

use super::energy::layer_energy_uj;
use super::l1::{check_layer, tiling_penalty};
use super::latency::{cycles_to_ms, lat_dw, layer_lats};
use super::timeline::{Timeline, Unit};

/// Per-layer channel split: mappable node name -> (digital, aimc) counts.
pub type ChannelSplit = BTreeMap<String, (usize, usize)>;

#[derive(Clone, Copy, Debug, Default)]
pub struct SocConfig {
    /// Charge tiling penalties when activations overflow L1 (the paper's
    /// analytical models neglect this; off by default for parity).
    pub non_ideal_l1: bool,
}

#[derive(Clone, Debug)]
pub struct RunReport {
    pub total_cycles: u64,
    pub latency_ms: f64,
    pub energy_uj: f64,
    /// Busy fraction per unit [digital, aimc] (Table I "D./A. util.").
    pub util: [f64; 2],
    /// Fraction of channels (over all mappable layers) on the AIMC
    /// accelerator (Table I "A. Ch.").
    pub aimc_channel_frac: f64,
    pub timeline: Timeline,
    /// Layers whose activations overflowed L1 (only flagged non-ideal).
    pub l1_overflows: Vec<String>,
}

/// Simulate one inference of `graph` under `split`.
///
/// Panics if `split` is missing a mappable layer or a count exceeds the
/// layer width — those are coordinator bugs, not run-time conditions.
pub fn simulate(graph: &Graph, split: &ChannelSplit, cfg: SocConfig) -> RunReport {
    let mut tl = Timeline::default();
    let mut t = 0u64; // current cycle
    let mut energy = 0.0;
    let mut ch_total = 0usize;
    let mut ch_aimc = 0usize;
    let mut overflows = Vec::new();

    for node in &graph.nodes {
        match node.op {
            Op::Conv | Op::Fc => {
                let (cd, ca) = *split
                    .get(&node.name)
                    .unwrap_or_else(|| panic!("split missing layer '{}'", node.name));
                assert_eq!(
                    cd + ca,
                    node.cout,
                    "layer {}: split {cd}+{ca} != cout {}",
                    node.name,
                    node.cout
                );
                ch_total += node.cout;
                ch_aimc += ca;
                let (mut ld, mut la) = layer_lats(node, cd as u64, ca as u64);
                let rep = check_layer(node.cin, node.in_hw, node.cout, node.out_hw,
                                      node.k, cd);
                if rep.act_overflow {
                    overflows.push(node.name.clone());
                    if cfg.non_ideal_l1 {
                        let p = tiling_penalty(rep.act_bytes);
                        ld *= p;
                        la *= p;
                    }
                }
                let span = ld.max(la);
                tl.push(Unit::Digital, &node.name, t, t + ld);
                tl.push(Unit::Aimc, &node.name, t, t + la);
                energy += layer_energy_uj([ld, la], span);
                t += span;
            }
            Op::DwConv => {
                let (oy, ox) = (node.out_hw.0 as u64, node.out_hw.1 as u64);
                let ld = lat_dw(node.k as u64, ox, oy, node.cout as u64);
                tl.push(Unit::Digital, &node.name, t, t + ld);
                energy += layer_energy_uj([ld, 0], ld);
                t += ld;
            }
            Op::Input | Op::Add | Op::Gap => {
                // control-core work, not modeled (paper convention)
            }
        }
    }
    tl.total_cycles = t;
    let util = tl.utilization();
    RunReport {
        total_cycles: t,
        latency_ms: cycles_to_ms(t),
        energy_uj: energy,
        util: util.busy_frac,
        aimc_channel_frac: if ch_total == 0 { 0.0 } else { ch_aimc as f64 / ch_total as f64 },
        timeline: tl,
        l1_overflows: overflows,
    }
}

/// Convenience splits.
pub fn split_all_digital(graph: &Graph) -> ChannelSplit {
    graph
        .mappable()
        .iter()
        .map(|n| (n.name.clone(), (n.cout, 0)))
        .collect()
}

pub fn split_all_aimc(graph: &Graph) -> ChannelSplit {
    graph
        .mappable()
        .iter()
        .map(|n| (n.name.clone(), (0, n.cout)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{resnet20, tinycnn};

    #[test]
    fn all_digital_fully_utilizes_digital() {
        let g = tinycnn();
        let r = simulate(&g, &split_all_digital(&g), SocConfig::default());
        assert!((r.util[0] - 1.0).abs() < 1e-9, "digital util {}", r.util[0]);
        assert_eq!(r.util[1], 0.0);
        assert_eq!(r.aimc_channel_frac, 0.0);
        assert!(r.latency_ms > 0.0 && r.energy_uj > 0.0);
    }

    #[test]
    fn all_aimc_is_faster_and_cheaper() {
        let g = resnet20();
        let d = simulate(&g, &split_all_digital(&g), SocConfig::default());
        let a = simulate(&g, &split_all_aimc(&g), SocConfig::default());
        assert!(a.total_cycles < d.total_cycles / 3,
                "aimc {} vs dig {}", a.total_cycles, d.total_cycles);
        assert!(a.energy_uj < d.energy_uj);
        assert!((a.aimc_channel_frac - 1.0).abs() < 1e-9);
    }

    #[test]
    fn balanced_split_overlaps() {
        let g = tinycnn();
        let mut split = ChannelSplit::new();
        for n in g.mappable() {
            split.insert(n.name.clone(), (n.cout / 2, n.cout - n.cout / 2));
        }
        let r = simulate(&g, &split, SocConfig::default());
        assert!(r.timeline.overlap_cycles() > 0);
        assert!(r.util[0] > 0.0 && r.util[1] > 0.0);
    }

    #[test]
    fn split_latency_never_exceeds_all_digital() {
        // moving channels to the (parallel, faster) AIMC can only shrink
        // the per-layer max
        let g = resnet20();
        let d = simulate(&g, &split_all_digital(&g), SocConfig::default());
        let mut split = ChannelSplit::new();
        for n in g.mappable() {
            split.insert(n.name.clone(), (n.cout / 2, n.cout - n.cout / 2));
        }
        let h = simulate(&g, &split, SocConfig::default());
        assert!(h.total_cycles <= d.total_cycles);
    }

    #[test]
    #[should_panic(expected = "split missing layer")]
    fn missing_layer_panics() {
        let g = tinycnn();
        simulate(&g, &ChannelSplit::new(), SocConfig::default());
    }

    #[test]
    #[should_panic(expected = "!= cout")]
    fn wrong_count_panics() {
        let g = tinycnn();
        let mut s = split_all_digital(&g);
        s.insert("stem".into(), (3, 3));
        simulate(&g, &s, SocConfig::default());
    }

    #[test]
    fn resnet20_all_digital_near_paper_scale() {
        // Table I: All-8bit ResNet20 = 1.55 ms / 38.71 uJ. The analytical
        // models won't match silicon exactly, but the simulator must land
        // on the same order of magnitude for the calibration to be
        // meaningful.
        let g = resnet20();
        let r = simulate(&g, &split_all_digital(&g), SocConfig::default());
        assert!(r.latency_ms > 0.3 && r.latency_ms < 8.0, "lat {}", r.latency_ms);
        assert!(r.energy_uj > 8.0 && r.energy_uj < 200.0, "en {}", r.energy_uj);
    }
}
