//! Analytical accelerator latency models — paper Sec. III-C, Eq. 6/7.
//!
//! The generic forms ([`lat_pe_array`], [`lat_imc_macro`], [`lat_dw_pe`])
//! are parameterized on the accelerator geometry and back
//! [`crate::hw::platform::LatencyModel`]; the DIANA-constant wrappers
//! ([`lat_dig`], [`lat_aimc`], [`lat_dw`]) are the exact integer mirror
//! of `python/compile/costmodel.py` (whose traced versions feed the
//! training loss). Parity is pinned by `rust/tests/model_parity.rs`
//! against constants exported in the artifact metadata, and the
//! platform path is pinned to these wrappers by `tests/diana_parity.rs`.

use crate::model::NodeDef;

/// AIMC macro geometry: 1152 rows x 512 columns of compute cells.
pub const AIMC_ROWS: u64 = 1152;
pub const AIMC_COLS: u64 = 512;
/// Digital PE array: 16 x 16.
pub const DIG_PE: u64 = 16;
/// DIANA clock (260 MHz) for cycle -> time conversion.
pub const F_CLK_HZ: f64 = 260e6;

#[inline]
fn ceil_div(a: u64, b: u64) -> u64 {
    a.div_ceil(b)
}

/// Generic Eq. 7: `pe` x `pe` digital array latency in cycles for
/// `cout` assigned channels (pe output channels x pe output rows per
/// pass, plus the weight-load DMA term).
pub fn lat_pe_array(pe: u64, cin: u64, fx: u64, fy: u64, ox: u64, oy: u64, cout: u64) -> u64 {
    if cout == 0 {
        return 0;
    }
    ceil_div(cout, pe) * ceil_div(oy, pe) * cin * ox * fx * fy + cin * cout * fx * fy
}

/// Generic Eq. 6: `rows` x `cols` IMC macro latency in cycles for
/// `cout` assigned channels. First addend: compute passes; second:
/// cell-programming DMA.
#[allow(clippy::too_many_arguments)]
pub fn lat_imc_macro(
    rows: u64,
    cols: u64,
    cin: u64,
    fx: u64,
    fy: u64,
    ox: u64,
    oy: u64,
    cout: u64,
) -> u64 {
    if cout == 0 {
        return 0;
    }
    let tiles_in = ceil_div(cin * fx * fy, rows);
    let tiles_out = ceil_div(cout, cols);
    tiles_in * tiles_out * ox * oy + 2 * 4 * cin * tiles_out
}

/// Generic depthwise conv on a `pe` x `pe` array (per-channel dataflow).
pub fn lat_dw_pe(pe: u64, k: u64, ox: u64, oy: u64, cout: u64) -> u64 {
    ceil_div(cout, pe) * ceil_div(oy, pe) * ox * k * k + cout * k * k
}

/// Paper Eq. 6: AIMC latency in cycles for `cout_a` assigned channels.
pub fn lat_aimc(cin: u64, fx: u64, fy: u64, ox: u64, oy: u64, cout_a: u64) -> u64 {
    lat_imc_macro(AIMC_ROWS, AIMC_COLS, cin, fx, fy, ox, oy, cout_a)
}

/// Paper Eq. 7: digital accelerator latency in cycles for `cout_d`
/// assigned channels.
pub fn lat_dig(cin: u64, fx: u64, fy: u64, ox: u64, oy: u64, cout_d: u64) -> u64 {
    lat_pe_array(DIG_PE, cin, fx, fy, ox, oy, cout_d)
}

/// Depthwise conv (digital-only, per-channel dataflow) — mirrors
/// `costmodel.layer_lats_dw_diana`.
pub fn lat_dw(k: u64, ox: u64, oy: u64, cout: u64) -> u64 {
    lat_dw_pe(DIG_PE, k, ox, oy, cout)
}

/// Per-accelerator latency of one mappable layer under a channel split
/// on the DIANA units. FC layers cost as 1x1 convs with 1x1 outputs
/// (paper convention). Platform-generic code uses
/// [`crate::hw::Platform::layer_cycles`] instead.
pub fn layer_lats(node: &NodeDef, cout_d: u64, cout_a: u64) -> (u64, u64) {
    let (oy, ox) = (node.out_hw.0 as u64, node.out_hw.1 as u64);
    let (cin, k) = (node.cin as u64, node.k as u64);
    (
        lat_dig(cin, k, k, ox, oy, cout_d),
        lat_aimc(cin, k, k, ox, oy, cout_a),
    )
}

pub fn cycles_to_ms(cycles: u64) -> f64 {
    cycles as f64 / F_CLK_HZ * 1e3
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq7_hand_example() {
        // cin=16, f=3, o=16x16, cout=32 (same as the python test)
        let want = (32u64.div_ceil(16)) * (16u64.div_ceil(16)) * 16 * 16 * 9 + 16 * 32 * 9;
        assert_eq!(lat_dig(16, 3, 3, 16, 16, 32), want);
    }

    #[test]
    fn eq6_hand_example() {
        let want = ((16 * 9u64).div_ceil(1152)) * (32u64.div_ceil(512)) * 256 + 8 * 16;
        assert_eq!(lat_aimc(16, 3, 3, 16, 16, 32), want);
    }

    #[test]
    fn zero_channels_cost_nothing() {
        assert_eq!(lat_aimc(64, 3, 3, 8, 8, 0), 0);
        assert_eq!(lat_dig(64, 3, 3, 8, 8, 0), 0);
        assert_eq!(lat_pe_array(32, 64, 3, 3, 8, 8, 0), 0);
        assert_eq!(lat_imc_macro(512, 256, 64, 3, 3, 8, 8, 0), 0);
    }

    #[test]
    fn monotone_in_channels() {
        for c in 1..512 {
            assert!(lat_dig(64, 3, 3, 16, 16, c + 1) >= lat_dig(64, 3, 3, 16, 16, c));
            assert!(lat_aimc(64, 3, 3, 16, 16, c + 1) >= lat_aimc(64, 3, 3, 16, 16, c));
        }
    }

    #[test]
    fn aimc_parallelism_dominates() {
        // at full width the AIMC macro is >5x faster than the PE array
        assert!(lat_aimc(64, 3, 3, 16, 16, 64) * 5 < lat_dig(64, 3, 3, 16, 16, 64));
    }

    #[test]
    fn wider_pe_array_is_faster() {
        // a 32x32 grid retires channel/row passes 4x faster; the DMA
        // term is unchanged, so the total strictly shrinks
        assert!(
            lat_pe_array(32, 64, 3, 3, 16, 16, 64) < lat_pe_array(16, 64, 3, 3, 16, 16, 64)
        );
    }

    #[test]
    fn clock_conversion() {
        let ms = cycles_to_ms(260_000);
        assert!((ms - 1.0).abs() < 1e-9);
    }
}
