//! Declarative multi-accelerator platform description — the
//! generalization of the hardwired 2-accelerator DIANA model to
//! arbitrary N-accelerator SoCs.
//!
//! A [`Platform`] is an ordered list of [`AcceleratorSpec`]s (name,
//! weight/activation precision, analytical latency model, active/idle
//! power) plus the SoC-level facts the simulator needs (clock, shared-L1
//! size, which unit runs depthwise convs). Everything downstream —
//! simulator, scheduler, baselines, quantized engine — iterates the
//! platform's accelerators instead of matching on DIG/AIMC.
//!
//! Four platforms ship built in:
//!   * [`Platform::diana`] — the paper's SoC, byte-identical to the
//!     pre-refactor hardwired model (pinned by tests/diana_parity.rs);
//!   * [`Platform::diana_ne16`] — DIANA plus an NE16-style 4-bit
//!     digital unit, the shipped 3-accelerator example;
//!   * [`Platform::gap9`] — a GAP9-style SoC (RISC-V compute cluster +
//!     NE16 accelerator), the no-IMC example: no unit re-reads
//!     activations through a D/A;
//!   * [`Platform::mpsoc4`] — a 4-unit heterogeneous MPSoC (NPU + two
//!     IMC macros with *distinct* D/A widths + a GPU-style unit), the
//!     many-unit stress case for min-cost water-filling and the
//!     per-width D/A buffers of the quantized engine.
//!
//! Platforms also load from TOML (see `config/*.toml` and the schema in
//! EXPERIMENTS.md §Platforms).

#![deny(missing_docs)]

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::config::{parse_toml, TomlValue};
use crate::model::NodeDef;

use super::energy::{P_ACT, P_IDLE};
use super::faults::{FaultState, UnitHealth};
use super::l1::L1_BYTES;
use super::latency::{lat_dw_pe, lat_imc_macro, lat_pe_array, AIMC_COLS, AIMC_ROWS, DIG_PE,
                     F_CLK_HZ};

/// Analytical per-layer latency model of one accelerator.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LatencyModel {
    /// Eq.-7-style digital PE array (`pe` x `pe`): output-stationary
    /// passes plus a weight-load DMA term.
    DigitalPe {
        /// PE grid edge (the array is `pe` x `pe`).
        pe: u64,
    },
    /// Eq.-6-style in-memory-compute macro (`rows` x `cols` cells):
    /// tile passes plus a cell-programming term.
    ImcMacro {
        /// Compute-cell rows (input-side tile dimension).
        rows: u64,
        /// Compute-cell columns (output-channel tile dimension).
        cols: u64,
    },
    /// Abstract proportional model: `macs / macs_per_cycle` (Fig. 5).
    Proportional {
        /// Sustained MAC throughput per cycle.
        macs_per_cycle: f64,
    },
}

impl LatencyModel {
    /// Latency in cycles of `cout` assigned output channels of a
    /// conv/fc layer (fc costs as a 1x1 conv with 1x1 output).
    pub fn cycles(&self, cin: u64, fx: u64, fy: u64, ox: u64, oy: u64, cout: u64) -> u64 {
        if cout == 0 {
            return 0;
        }
        match *self {
            LatencyModel::DigitalPe { pe } => lat_pe_array(pe, cin, fx, fy, ox, oy, cout),
            LatencyModel::ImcMacro { rows, cols } => {
                lat_imc_macro(rows, cols, cin, fx, fy, ox, oy, cout)
            }
            LatencyModel::Proportional { macs_per_cycle } => {
                ((cin * fx * fy * ox * oy * cout) as f64 / macs_per_cycle).ceil() as u64
            }
        }
    }

    /// Depthwise-conv latency (per-channel dataflow). Only meaningful
    /// for the accelerator designated as the platform's `dw_acc`.
    pub fn dw_cycles(&self, k: u64, ox: u64, oy: u64, cout: u64) -> u64 {
        if cout == 0 {
            return 0;
        }
        match *self {
            LatencyModel::DigitalPe { pe } => lat_dw_pe(pe, k, ox, oy, cout),
            // an IMC macro runs dw as cin=1 tiles; proportional by MACs
            LatencyModel::ImcMacro { rows, cols } => {
                lat_imc_macro(rows, cols, 1, k, k, ox, oy, cout)
            }
            LatencyModel::Proportional { macs_per_cycle } => {
                ((cout * k * k * ox * oy) as f64 / macs_per_cycle).ceil() as u64
            }
        }
    }

    /// This model slowed down by `factor` (>= 1.0). Proportional units
    /// scale throughput exactly; grid models shrink each edge by
    /// `sqrt(factor)` (floor, min 1) — the discrete approximation of a
    /// partially disabled array, so a derated grid is never *faster*
    /// than the healthy one.
    pub fn derated(&self, factor: f64) -> LatencyModel {
        let shrink = |edge: u64| ((edge as f64 / factor.sqrt()).floor() as u64).max(1);
        match *self {
            LatencyModel::DigitalPe { pe } => LatencyModel::DigitalPe { pe: shrink(pe) },
            LatencyModel::ImcMacro { rows, cols } => {
                LatencyModel::ImcMacro { rows: shrink(rows), cols: shrink(cols) }
            }
            LatencyModel::Proportional { macs_per_cycle } => {
                LatencyModel::Proportional { macs_per_cycle: macs_per_cycle / factor }
            }
        }
    }
}

/// One accelerator of the SoC.
#[derive(Clone, Debug, PartialEq)]
pub struct AcceleratorSpec {
    /// Unit name (unique within the platform; mapping reports use it).
    pub name: String,
    /// Weight precision in bits (8 = int8, 2 = ternary, 4 = int4...).
    pub weight_bits: u32,
    /// Output-activation grid in bits (8 digital / 7 AIMC on DIANA).
    pub act_bits: u32,
    /// Input D/A re-read truncation in bits (the AIMC 7-bit read);
    /// `None` = the unit reads stored activations exactly. Units may
    /// declare *distinct* widths — the quantized engine materializes
    /// one D/A view per distinct width (see `quant/plan.rs`).
    pub da_bits: Option<u32>,
    /// Analytical latency model costing this unit's channel sub-layers.
    pub latency: LatencyModel,
    /// Average active power, mW.
    pub p_act_mw: f64,
    /// Average idle power, mW.
    pub p_idle_mw: f64,
    /// Private weight memory, bytes (refilled by the DMA latency term).
    pub wmem_bytes: Option<usize>,
}

impl AcceleratorSpec {
    /// Parameter leaf holding this accelerator's log weight scale.
    /// Follows the artifact contract: int8 -> "ls8", ternary -> "lster",
    /// any other width -> "ls<bits>".
    pub fn scale_leaf(&self) -> String {
        match self.weight_bits {
            8 => "ls8".to_string(),
            2 => "lster".to_string(),
            n => format!("ls{n}"),
        }
    }
}

/// A multi-accelerator SoC: ordered accelerators + SoC-level facts.
#[derive(Clone, Debug, PartialEq)]
pub struct Platform {
    /// Platform id (CLI output, reports, cache keys).
    pub name: String,
    /// SoC clock in Hz, for cycle -> time conversion.
    pub f_clk_hz: f64,
    /// Shared L1 activation scratchpad, bytes.
    pub l1_bytes: usize,
    /// Index of the accelerator that runs depthwise convolutions.
    pub dw_acc: usize,
    /// Ordered unit list; a mapping's accelerator id indexes this.
    pub accelerators: Vec<AcceleratorSpec>,
}

impl Platform {
    /// Number of accelerators on the SoC.
    pub fn n_acc(&self) -> usize {
        self.accelerators.len()
    }

    /// Index of the accelerator named `name`, if present.
    pub fn acc_index(&self, name: &str) -> Option<usize> {
        self.accelerators.iter().position(|a| a.name == name)
    }

    /// Unit names in platform order.
    pub fn acc_names(&self) -> Vec<&str> {
        self.accelerators.iter().map(|a| a.name.as_str()).collect()
    }

    /// Latency in cycles of `cout_assigned` channels of `node` on
    /// accelerator `acc` (conv/fc geometry; fc as 1x1).
    pub fn layer_cycles(&self, acc: usize, node: &NodeDef, cout_assigned: u64) -> u64 {
        let (oy, ox) = (node.out_hw.0 as u64, node.out_hw.1 as u64);
        self.accelerators[acc].latency.cycles(
            node.cin as u64,
            node.k as u64,
            node.k as u64,
            ox,
            oy,
            cout_assigned,
        )
    }

    /// Depthwise-conv latency on the platform's `dw_acc`.
    pub fn dw_layer_cycles(&self, node: &NodeDef) -> u64 {
        let (oy, ox) = (node.out_hw.0 as u64, node.out_hw.1 as u64);
        self.accelerators[self.dw_acc]
            .latency
            .dw_cycles(node.k as u64, ox, oy, node.cout as u64)
    }

    /// Convert cycles to milliseconds at the platform clock.
    pub fn cycles_to_ms(&self, cycles: u64) -> f64 {
        cycles as f64 / self.f_clk_hz * 1e3
    }

    /// Energy (uJ) of one layer interval: accelerator `i` is active for
    /// `active[i]` cycles within a layer lasting `span` cycles (Eq. 4,
    /// generalized to N accelerators; accumulation order matches the
    /// pre-refactor 2-accelerator code exactly).
    pub fn layer_energy_uj(&self, active: &[u64], span: u64) -> f64 {
        debug_assert_eq!(active.len(), self.n_acc());
        let mut e_mw_cycles = 0.0;
        for (spec, &a) in self.accelerators.iter().zip(active) {
            let act = a.min(span) as f64;
            let idle = (span - a.min(span)) as f64;
            e_mw_cycles += spec.p_act_mw * act + spec.p_idle_mw * idle;
        }
        e_mw_cycles / self.f_clk_hz * 1e3
    }

    /// Per-unit split of [`Platform::layer_energy_uj`] (uJ per
    /// accelerator, active + idle share): trace attribution needs to
    /// say *which* unit burned a layer's energy, not just the total.
    /// The entries sum to `layer_energy_uj(active, span)` up to float
    /// association.
    pub fn layer_energy_split_uj(&self, active: &[u64], span: u64) -> Vec<f64> {
        debug_assert_eq!(active.len(), self.n_acc());
        self.accelerators
            .iter()
            .zip(active)
            .map(|(spec, &a)| {
                let act = a.min(span) as f64;
                let idle = (span - a.min(span)) as f64;
                (spec.p_act_mw * act + spec.p_idle_mw * idle) / self.f_clk_hz * 1e3
            })
            .collect()
    }

    /// Distinct D/A truncation widths declared across the platform's
    /// accelerators, ascending and deduplicated (empty when no unit
    /// re-reads activations through a D/A, e.g. [`Platform::gap9`]).
    /// The quantized engine materializes one D/A view of an activation
    /// tensor per width in this list that some consumer actually reads.
    pub fn da_widths(&self) -> Vec<u32> {
        let mut widths: Vec<u32> = self.accelerators.iter().filter_map(|a| a.da_bits).collect();
        widths.sort_unstable();
        widths.dedup();
        widths
    }

    /// FNV-1a hash over the *resolved* platform spec — every field that
    /// changes simulated cost or engine numerics (clock, L1, dw unit,
    /// and each accelerator's precision/latency/power/D-A/wmem facts).
    /// Two platforms sharing a `name` but differing in any spec field
    /// hash differently, so caches keyed by name alone (e.g. the sweep
    /// frontier) can detect an edited platform TOML instead of silently
    /// serving stale points. Floats are hashed by their exact bit
    /// pattern — any numeric edit, however small, changes the hash.
    pub fn spec_hash(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        };
        let eat_str = |s: &str, eat: &mut dyn FnMut(&[u8])| {
            eat(&(s.len() as u64).to_le_bytes());
            eat(s.as_bytes());
        };
        eat_str(&self.name, &mut eat);
        eat(&self.f_clk_hz.to_bits().to_le_bytes());
        eat(&(self.l1_bytes as u64).to_le_bytes());
        eat(&(self.dw_acc as u64).to_le_bytes());
        eat(&(self.accelerators.len() as u64).to_le_bytes());
        for a in &self.accelerators {
            eat_str(&a.name, &mut eat);
            eat(&a.weight_bits.to_le_bytes());
            eat(&a.act_bits.to_le_bytes());
            // Option fields: tag byte then payload, so None never
            // collides with a zero-valued Some
            match a.da_bits {
                Some(b) => {
                    eat(&[1]);
                    eat(&b.to_le_bytes());
                }
                None => eat(&[0]),
            }
            match a.latency {
                LatencyModel::DigitalPe { pe } => {
                    eat(&[1]);
                    eat(&pe.to_le_bytes());
                }
                LatencyModel::ImcMacro { rows, cols } => {
                    eat(&[2]);
                    eat(&rows.to_le_bytes());
                    eat(&cols.to_le_bytes());
                }
                LatencyModel::Proportional { macs_per_cycle } => {
                    eat(&[3]);
                    eat(&macs_per_cycle.to_bits().to_le_bytes());
                }
            }
            eat(&a.p_act_mw.to_bits().to_le_bytes());
            eat(&a.p_idle_mw.to_bits().to_le_bytes());
            match a.wmem_bytes {
                Some(w) => {
                    eat(&[1]);
                    eat(&(w as u64).to_le_bytes());
                }
                None => eat(&[0]),
            }
        }
        h
    }

    /// A degraded *view* of this platform under a fault state: down
    /// units are removed (surviving order preserved), derated units
    /// keep their name with a latency model scaled `factor`x slower
    /// (see [`LatencyModel::derated`]). The view's `name` embeds
    /// [`FaultState::key`], so its [`Platform::spec_hash`] — and every
    /// cache keyed by it (frontier, plan cache) — is distinct from the
    /// healthy platform's and from every other fault state's. The
    /// all-up state returns the platform unchanged. If the depthwise
    /// unit is down, depthwise layers fall back to the first surviving
    /// unit. Errors when the state's arity mismatches or no unit
    /// survives.
    pub fn degraded(&self, state: &FaultState) -> Result<Platform> {
        if state.health.len() != self.n_acc() {
            return Err(anyhow!(
                "fault state covers {} units but platform {} has {}",
                state.health.len(),
                self.name,
                self.n_acc()
            ));
        }
        if state.all_up() {
            return Ok(self.clone());
        }
        let survivors = state.survivors();
        if survivors.is_empty() {
            return Err(anyhow!("platform {}: every accelerator is down", self.name));
        }
        let mut accelerators = Vec::with_capacity(survivors.len());
        for &i in &survivors {
            let mut spec = self.accelerators[i].clone();
            if let UnitHealth::Derated(f) = state.health[i] {
                spec.latency = spec.latency.derated(f);
            }
            accelerators.push(spec);
        }
        let dw_acc = survivors.iter().position(|&i| i == self.dw_acc).unwrap_or(0);
        Platform {
            name: format!("{}~f{:016x}", self.name, state.key()),
            f_clk_hz: self.f_clk_hz,
            l1_bytes: self.l1_bytes,
            dw_acc,
            accelerators,
        }
        .validate()
    }

    fn validate(self) -> Result<Self> {
        if self.accelerators.is_empty() {
            return Err(anyhow!("platform {}: no accelerators", self.name));
        }
        if self.dw_acc >= self.n_acc() {
            return Err(anyhow!(
                "platform {}: dw_acc {} out of range ({} accelerators)",
                self.name,
                self.dw_acc,
                self.n_acc()
            ));
        }
        if self.f_clk_hz <= 0.0 {
            return Err(anyhow!("platform {}: f_clk_hz must be positive", self.name));
        }
        let mut seen = std::collections::BTreeSet::new();
        for a in &self.accelerators {
            if !seen.insert(a.name.clone()) {
                return Err(anyhow!("platform {}: duplicate accelerator '{}'", self.name, a.name));
            }
            if let Some(b) = a.da_bits {
                if b == 0 || b > 16 {
                    return Err(anyhow!(
                        "platform {}: accelerator '{}' da_bits {b} out of range (1..=16)",
                        self.name,
                        a.name
                    ));
                }
            }
        }
        Ok(self)
    }

    // ---- built-in platforms -------------------------------------------

    /// The DIANA SoC exactly as the pre-refactor hardwired model: a
    /// 16x16 int8 PE array and a 1152x512 ternary AIMC macro sharing a
    /// 256 kB L1 at 260 MHz. Table-I numbers under this platform are
    /// byte-identical to the seed simulator (tests/diana_parity.rs).
    pub fn diana() -> Platform {
        Platform {
            name: "diana".into(),
            f_clk_hz: F_CLK_HZ,
            l1_bytes: L1_BYTES,
            dw_acc: 0,
            accelerators: vec![
                AcceleratorSpec {
                    name: "dig".into(),
                    weight_bits: 8,
                    act_bits: 8,
                    da_bits: None,
                    latency: LatencyModel::DigitalPe { pe: DIG_PE },
                    p_act_mw: P_ACT[0],
                    p_idle_mw: P_IDLE[0],
                    wmem_bytes: Some(super::l1::DIG_WMEM_BYTES),
                },
                AcceleratorSpec {
                    name: "aimc".into(),
                    weight_bits: 2,
                    act_bits: 7,
                    da_bits: Some(7),
                    latency: LatencyModel::ImcMacro { rows: AIMC_ROWS, cols: AIMC_COLS },
                    p_act_mw: P_ACT[1],
                    p_idle_mw: P_IDLE[1],
                    wmem_bytes: None,
                },
            ],
        }
    }

    /// The shipped 3-accelerator example: DIANA plus an NE16-style
    /// 4-bit digital unit (32x32 MAC grid, int4 weights, 8-bit
    /// activations) — demonstrates N>2 generality end-to-end.
    pub fn diana_ne16() -> Platform {
        let mut p = Platform::diana();
        p.name = "diana_ne16".into();
        p.accelerators.push(AcceleratorSpec {
            name: "ne16".into(),
            weight_bits: 4,
            act_bits: 8,
            da_bits: None,
            latency: LatencyModel::DigitalPe { pe: 32 },
            p_act_mw: 18.0,
            p_idle_mw: 1.2,
            wmem_bytes: Some(128 * 1024),
        });
        p
    }

    /// A GAP9-style SoC: an 8-core RISC-V compute cluster (abstract
    /// proportional model, ~2 MACs/cycle/core) plus an NE16-style
    /// convolution accelerator, sharing a 128 kB L1 at 370 MHz. The
    /// no-IMC example — `da_bits` is absent on every unit, so the
    /// quantized engine materializes no D/A views at all.
    pub fn gap9() -> Platform {
        Platform {
            name: "gap9".into(),
            f_clk_hz: 370e6,
            l1_bytes: 128 * 1024,
            dw_acc: 0,
            accelerators: vec![
                AcceleratorSpec {
                    name: "cluster".into(),
                    weight_bits: 8,
                    act_bits: 8,
                    da_bits: None,
                    latency: LatencyModel::Proportional { macs_per_cycle: 16.0 },
                    p_act_mw: 48.0,
                    p_idle_mw: 2.5,
                    wmem_bytes: None,
                },
                AcceleratorSpec {
                    name: "ne16".into(),
                    weight_bits: 4,
                    act_bits: 8,
                    da_bits: None,
                    latency: LatencyModel::DigitalPe { pe: 32 },
                    p_act_mw: 22.0,
                    p_idle_mw: 1.5,
                    wmem_bytes: Some(128 * 1024),
                },
            ],
        }
    }

    /// A 4-unit heterogeneous MPSoC a la Map-and-Conquer: an int8 NPU
    /// (PE array), two analog IMC macros with *distinct* D/A read
    /// widths (7-bit and 6-bit — the case the quantized engine's
    /// per-width D/A buffers exist for), and a GPU-style proportional
    /// unit. Stresses the min-cost water-filling fast path at N=4.
    pub fn mpsoc4() -> Platform {
        Platform {
            name: "mpsoc4".into(),
            f_clk_hz: 500e6,
            l1_bytes: 512 * 1024,
            dw_acc: 0,
            accelerators: vec![
                AcceleratorSpec {
                    name: "npu".into(),
                    weight_bits: 8,
                    act_bits: 8,
                    da_bits: None,
                    latency: LatencyModel::DigitalPe { pe: 32 },
                    p_act_mw: 80.0,
                    p_idle_mw: 4.0,
                    wmem_bytes: Some(256 * 1024),
                },
                AcceleratorSpec {
                    name: "imc0".into(),
                    weight_bits: 2,
                    act_bits: 7,
                    da_bits: Some(7),
                    latency: LatencyModel::ImcMacro { rows: 1152, cols: 512 },
                    p_act_mw: 26.0,
                    p_idle_mw: 1.3,
                    wmem_bytes: None,
                },
                AcceleratorSpec {
                    name: "imc1".into(),
                    weight_bits: 2,
                    act_bits: 6,
                    da_bits: Some(6),
                    latency: LatencyModel::ImcMacro { rows: 512, cols: 256 },
                    p_act_mw: 14.0,
                    p_idle_mw: 0.9,
                    wmem_bytes: None,
                },
                AcceleratorSpec {
                    name: "gpu".into(),
                    weight_bits: 8,
                    act_bits: 8,
                    da_bits: None,
                    latency: LatencyModel::Proportional { macs_per_cycle: 64.0 },
                    p_act_mw: 220.0,
                    p_idle_mw: 18.0,
                    wmem_bytes: None,
                },
            ],
        }
    }

    /// Built-in platform registry (CLI `--platform <name>`).
    pub fn by_name(name: &str) -> Option<Platform> {
        match name {
            "diana" => Some(Platform::diana()),
            "diana_ne16" => Some(Platform::diana_ne16()),
            "gap9" => Some(Platform::gap9()),
            "mpsoc4" => Some(Platform::mpsoc4()),
            _ => None,
        }
    }

    /// Names [`Platform::by_name`] accepts (CLI `platforms` listing).
    pub const BUILTIN_NAMES: [&'static str; 4] = ["diana", "diana_ne16", "gap9", "mpsoc4"];

    /// Resolve a CLI argument: built-in name first, then TOML path.
    pub fn resolve(arg: &str) -> Result<Platform> {
        if let Some(p) = Platform::by_name(arg) {
            return Ok(p);
        }
        let path = Path::new(arg);
        if path.exists() {
            return Platform::from_toml_file(path);
        }
        Err(anyhow!(
            "unknown platform '{arg}' (built-ins: {:?}; or pass a .toml path)",
            Platform::BUILTIN_NAMES
        ))
    }

    // ---- TOML loading -------------------------------------------------

    /// Load a platform from a TOML file (schema: EXPERIMENTS.md
    /// §Platforms; examples under `config/`).
    pub fn from_toml_file(path: &Path) -> Result<Platform> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        let doc = parse_toml(&text)?;
        Platform::from_toml(&doc)
    }

    /// Build a platform from a parsed TOML document (flattened
    /// `section.key` keys, as produced by [`crate::config::parse_toml`];
    /// schema in EXPERIMENTS.md §Platforms).
    ///
    /// ```
    /// use odimo::config::parse_toml;
    /// use odimo::hw::Platform;
    ///
    /// let doc = parse_toml(
    ///     "[platform]\n\
    ///      name = \"mini\"\n\
    ///      f_clk_hz = 100e6\n\
    ///      accelerators = [\"pe\"]\n\
    ///      [accel.pe]\n\
    ///      kind = \"digital_pe\"\n\
    ///      pe = 16\n\
    ///      weight_bits = 8\n\
    ///      act_bits = 8\n\
    ///      p_act_mw = 10.0\n\
    ///      p_idle_mw = 1.0\n",
    /// )
    /// .unwrap();
    /// let p = Platform::from_toml(&doc).unwrap();
    /// assert_eq!(p.name, "mini");
    /// assert_eq!(p.n_acc(), 1);
    /// assert_eq!(p.dw_acc, 0); // defaults to the first unit
    /// ```
    pub fn from_toml(doc: &BTreeMap<String, TomlValue>) -> Result<Platform> {
        let get_str = |k: &str| -> Result<String> {
            match doc.get(k) {
                Some(TomlValue::Str(s)) => Ok(s.clone()),
                Some(_) => Err(anyhow!("platform toml: '{k}' must be a string")),
                None => Err(anyhow!("platform toml: missing key '{k}'")),
            }
        };
        let get_num = |k: &str| -> Result<Option<f64>> {
            match doc.get(k) {
                Some(TomlValue::Num(n)) => Ok(Some(*n)),
                Some(_) => Err(anyhow!("platform toml: '{k}' must be a number")),
                None => Ok(None),
            }
        };
        let name = get_str("platform.name")?;
        let f_clk_hz = get_num("platform.f_clk_hz")?
            .ok_or_else(|| anyhow!("platform toml: missing platform.f_clk_hz"))?;
        let l1_bytes = match get_num("platform.l1_kb")? {
            Some(kb) => (kb * 1024.0) as usize,
            None => L1_BYTES,
        };
        let order = match doc.get("platform.accelerators") {
            Some(TomlValue::Arr(a)) => a
                .iter()
                .map(|v| match v {
                    TomlValue::Str(s) => Ok(s.clone()),
                    _ => Err(anyhow!("platform.accelerators entries must be strings")),
                })
                .collect::<Result<Vec<String>>>()?,
            _ => return Err(anyhow!("platform toml: missing platform.accelerators array")),
        };
        if order.is_empty() {
            return Err(anyhow!("platform toml: platform.accelerators must not be empty"));
        }
        let mut accelerators = Vec::with_capacity(order.len());
        for acc in &order {
            let key = |f: &str| format!("accel.{acc}.{f}");
            let num = |f: &str| -> Result<f64> {
                get_num(&key(f))?
                    .ok_or_else(|| anyhow!("platform toml: missing {}", key(f)))
            };
            let kind = match doc.get(&key("kind")) {
                Some(TomlValue::Str(s)) => s.clone(),
                _ => return Err(anyhow!("platform toml: missing {}", key("kind"))),
            };
            let latency = match kind.as_str() {
                "digital_pe" => LatencyModel::DigitalPe { pe: num("pe")? as u64 },
                "imc_macro" => LatencyModel::ImcMacro {
                    rows: num("rows")? as u64,
                    cols: num("cols")? as u64,
                },
                "proportional" => LatencyModel::Proportional {
                    macs_per_cycle: num("macs_per_cycle")?,
                },
                other => {
                    return Err(anyhow!(
                        "accel.{acc}: unknown kind '{other}' \
                         (digital_pe|imc_macro|proportional)"
                    ))
                }
            };
            accelerators.push(AcceleratorSpec {
                name: acc.clone(),
                weight_bits: num("weight_bits")? as u32,
                act_bits: num("act_bits")? as u32,
                da_bits: get_num(&key("da_bits"))?.map(|b| b as u32),
                latency,
                p_act_mw: num("p_act_mw")?,
                p_idle_mw: num("p_idle_mw")?,
                wmem_bytes: get_num(&key("wmem_kb"))?.map(|kb| (kb * 1024.0) as usize),
            });
        }
        let dw_name = match doc.get("platform.dw_accelerator") {
            Some(TomlValue::Str(s)) => s.clone(),
            Some(_) => {
                return Err(anyhow!(
                    "platform toml: dw_accelerator must be a string (an accelerator name)"
                ))
            }
            None => order[0].clone(),
        };
        let dw_acc = order
            .iter()
            .position(|n| *n == dw_name)
            .ok_or_else(|| anyhow!("platform toml: dw_accelerator '{dw_name}' not listed"))?;
        Platform { name, f_clk_hz, l1_bytes, dw_acc, accelerators }.validate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::latency::{lat_aimc, lat_dig};

    #[test]
    fn diana_matches_hardwired_constants() {
        let p = Platform::diana();
        assert_eq!(p.n_acc(), 2);
        assert_eq!(p.f_clk_hz, F_CLK_HZ);
        assert_eq!(p.l1_bytes, L1_BYTES);
        assert_eq!(p.acc_index("dig"), Some(0));
        assert_eq!(p.acc_index("aimc"), Some(1));
        assert_eq!(
            p.accelerators.iter().map(|a| a.weight_bits).collect::<Vec<_>>(),
            vec![8, 2]
        );
        assert_eq!(p.accelerators[0].latency, LatencyModel::DigitalPe { pe: DIG_PE });
        assert_eq!(
            p.accelerators[1].latency,
            LatencyModel::ImcMacro { rows: AIMC_ROWS, cols: AIMC_COLS }
        );
    }

    #[test]
    fn spec_hash_tracks_every_cost_field() {
        let base = Platform::diana();
        assert_eq!(base.spec_hash(), Platform::diana().spec_hash(), "deterministic");
        assert_ne!(base.spec_hash(), Platform::diana_ne16().spec_hash());
        assert_ne!(base.spec_hash(), Platform::mpsoc4().spec_hash());
        // same name, one edited power number: the hash must move (this
        // is exactly the "operator edited the platform TOML" case the
        // frontier cache invalidates on)
        let mut edited = Platform::diana();
        edited.accelerators[1].p_act_mw += 0.5;
        assert_ne!(base.spec_hash(), edited.spec_hash());
        let mut clocked = Platform::diana();
        clocked.f_clk_hz *= 1.01;
        assert_ne!(base.spec_hash(), clocked.spec_hash());
        // None vs Some(0)-adjacent fields must not collide
        let mut da = Platform::diana();
        da.accelerators[0].da_bits = Some(8);
        assert_ne!(base.spec_hash(), da.spec_hash());
    }

    #[test]
    fn latency_model_mirrors_eq6_eq7() {
        let dig = LatencyModel::DigitalPe { pe: DIG_PE };
        let aimc = LatencyModel::ImcMacro { rows: AIMC_ROWS, cols: AIMC_COLS };
        for cin in [3u64, 16, 64, 130] {
            for cout in [0u64, 1, 16, 100, 512] {
                assert_eq!(dig.cycles(cin, 3, 3, 16, 16, cout),
                           lat_dig(cin, 3, 3, 16, 16, cout));
                assert_eq!(aimc.cycles(cin, 3, 3, 16, 16, cout),
                           lat_aimc(cin, 3, 3, 16, 16, cout));
            }
        }
    }

    #[test]
    fn diana_energy_matches_hardwired() {
        let p = Platform::diana();
        for (act, span) in [([0u64, 0], 260_000u64), ([260_000, 0], 260_000),
                            ([200_000, 150_000], 200_000)] {
            assert_eq!(
                p.layer_energy_uj(&act, span),
                crate::hw::energy::layer_energy_uj(act, span)
            );
        }
    }

    #[test]
    fn energy_split_sums_to_layer_energy() {
        for p in [Platform::diana(), Platform::mpsoc4()] {
            let n = p.n_acc();
            let active: Vec<u64> = (0..n as u64).map(|i| 10_000 * i).collect();
            let span = active.iter().copied().max().unwrap_or(0) + 5_000;
            let split = p.layer_energy_split_uj(&active, span);
            assert_eq!(split.len(), n);
            let total: f64 = split.iter().sum();
            let whole = p.layer_energy_uj(&active, span);
            assert!((total - whole).abs() < 1e-9 * whole.max(1.0), "{total} vs {whole}");
            assert!(split.iter().all(|&e| e >= 0.0));
        }
    }

    #[test]
    fn ne16_example_has_three_units() {
        let p = Platform::diana_ne16();
        assert_eq!(p.n_acc(), 3);
        assert_eq!(p.acc_index("ne16"), Some(2));
        assert_eq!(p.accelerators[2].weight_bits, 4);
        assert_eq!(p.accelerators[2].scale_leaf(), "ls4");
        assert_eq!(p.da_widths(), vec![7]);
    }

    #[test]
    fn gap9_has_no_da_widths() {
        let p = Platform::gap9();
        assert_eq!(p.n_acc(), 2);
        assert_eq!(p.acc_names(), vec!["cluster", "ne16"]);
        assert!(p.da_widths().is_empty(), "gap9 models no D/A re-read");
        assert_eq!(
            p.accelerators[0].latency,
            LatencyModel::Proportional { macs_per_cycle: 16.0 }
        );
        assert_eq!(p.accelerators[1].latency, LatencyModel::DigitalPe { pe: 32 });
        assert_eq!(p.dw_acc, 0);
    }

    #[test]
    fn mpsoc4_has_two_distinct_da_widths() {
        let p = Platform::mpsoc4();
        assert_eq!(p.n_acc(), 4);
        assert_eq!(p.acc_names(), vec!["npu", "imc0", "imc1", "gpu"]);
        assert_eq!(p.da_widths(), vec![6, 7]);
        // both macros are ternary -> one shared scale leaf
        assert_eq!(p.accelerators[1].scale_leaf(), "lster");
        assert_eq!(p.accelerators[2].scale_leaf(), "lster");
        assert_eq!(p.accelerators[2].act_bits, 6);
    }

    #[test]
    fn scale_leaf_contract() {
        let p = Platform::diana();
        assert_eq!(p.accelerators[0].scale_leaf(), "ls8");
        assert_eq!(p.accelerators[1].scale_leaf(), "lster");
    }

    #[test]
    fn toml_roundtrip_three_acc() {
        let text = "\
[platform]
name = \"tri\"
f_clk_hz = 260e6
l1_kb = 256
accelerators = [\"dig\", \"aimc\", \"ne16\"]
dw_accelerator = \"dig\"

[accel.dig]
kind = \"digital_pe\"
pe = 16
weight_bits = 8
act_bits = 8
p_act_mw = 24.0
p_idle_mw = 1.3
wmem_kb = 64

[accel.aimc]
kind = \"imc_macro\"
rows = 1152
cols = 512
weight_bits = 2
act_bits = 7
da_bits = 7
p_act_mw = 26.0
p_idle_mw = 1.3

[accel.ne16]
kind = \"digital_pe\"
pe = 32
weight_bits = 4
act_bits = 8
p_act_mw = 18.0
p_idle_mw = 1.2
";
        let doc = parse_toml(text).unwrap();
        let p = Platform::from_toml(&doc).unwrap();
        assert_eq!(p.name, "tri");
        assert_eq!(p.n_acc(), 3);
        assert_eq!(p.dw_acc, 0);
        assert_eq!(p.l1_bytes, 256 * 1024);
        // first two accelerators identical to the built-in DIANA specs
        assert_eq!(p.accelerators[..2], Platform::diana().accelerators[..]);
        assert_eq!(p.accelerators[2].latency, LatencyModel::DigitalPe { pe: 32 });
    }

    #[test]
    fn shipped_tomls_match_builtins() {
        for (name, built) in [
            ("diana_ne16", Platform::diana_ne16()),
            ("gap9", Platform::gap9()),
            ("mpsoc4", Platform::mpsoc4()),
        ] {
            let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
                .join("..")
                .join("config")
                .join(format!("{name}.toml"));
            let p = Platform::from_toml_file(&path).unwrap();
            assert_eq!(p, built, "config/{name}.toml drifted from the built-in");
        }
    }

    #[test]
    fn toml_errors_are_specific() {
        let no_order = parse_toml("[platform]\nname = \"x\"\nf_clk_hz = 1e6\n").unwrap();
        assert!(Platform::from_toml(&no_order).is_err());
        let empty = parse_toml(
            "[platform]\nname = \"x\"\nf_clk_hz = 1e6\naccelerators = []\n",
        )
        .unwrap();
        let e = Platform::from_toml(&empty).unwrap_err().to_string();
        assert!(e.contains("must not be empty"), "{e}");
        let bad_kind = parse_toml(
            "[platform]\nname = \"x\"\nf_clk_hz = 1e6\naccelerators = [\"a\"]\n\
             [accel.a]\nkind = \"warp\"\n",
        )
        .unwrap();
        let e = Platform::from_toml(&bad_kind).unwrap_err().to_string();
        assert!(e.contains("unknown kind"), "{e}");
        // dw_accelerator must be a string naming a listed unit
        let bad_dw = parse_toml(
            "[platform]\nname = \"x\"\nf_clk_hz = 1e6\naccelerators = [\"a\"]\n\
             dw_accelerator = 0\n[accel.a]\nkind = \"digital_pe\"\npe = 16\n\
             weight_bits = 8\nact_bits = 8\np_act_mw = 1.0\np_idle_mw = 0.1\n",
        )
        .unwrap();
        let e = Platform::from_toml(&bad_dw).unwrap_err().to_string();
        assert!(e.contains("dw_accelerator"), "{e}");
    }

    #[test]
    fn distinct_da_bits_accepted() {
        // two units with different D/A widths are a supported platform
        // since the per-width D/A buffers landed in the quant engine
        let mut p = Platform::diana_ne16();
        p.accelerators[2].da_bits = Some(5);
        let p = p.validate().unwrap();
        assert_eq!(p.da_widths(), vec![5, 7]);
    }

    #[test]
    fn absurd_da_bits_rejected() {
        let mut p = Platform::diana();
        p.accelerators[1].da_bits = Some(0);
        assert!(p.clone().validate().is_err());
        p.accelerators[1].da_bits = Some(17);
        assert!(p.validate().is_err());
    }

    #[test]
    fn resolve_prefers_builtin() {
        assert_eq!(Platform::resolve("diana").unwrap().n_acc(), 2);
        assert_eq!(Platform::resolve("gap9").unwrap().n_acc(), 2);
        assert_eq!(Platform::resolve("mpsoc4").unwrap().n_acc(), 4);
        assert!(Platform::resolve("no_such_platform").is_err());
    }

    #[test]
    fn all_builtins_resolve_and_validate() {
        for name in Platform::BUILTIN_NAMES {
            let p = Platform::by_name(name).unwrap();
            assert_eq!(p.name, name);
            assert!(p.clone().validate().is_ok(), "{name}");
        }
    }

    #[test]
    fn degraded_view_drops_down_units_and_rekeys() {
        use crate::hw::faults::{FaultState, UnitHealth};
        let p = Platform::mpsoc4();
        // all-up state: the view is the platform itself
        let same = p.degraded(&FaultState::healthy(4)).unwrap();
        assert_eq!(same, p);
        // imc0 down: three survivors in platform order, distinct hash
        let mut st = FaultState::healthy(4);
        st.health[1] = UnitHealth::Down;
        let d = p.degraded(&st).unwrap();
        assert_eq!(d.acc_names(), vec!["npu", "imc1", "gpu"]);
        assert_ne!(d.spec_hash(), p.spec_hash());
        assert!(d.name.starts_with("mpsoc4~f"), "{}", d.name);
        assert_eq!(d.dw_acc, 0, "dw unit npu survives at index 0");
        assert_eq!(d.da_widths(), vec![6], "imc0's 7-bit D/A went with it");
        // two distinct fault states never collide on name/hash
        let mut st2 = FaultState::healthy(4);
        st2.health[3] = UnitHealth::Down;
        let d2 = p.degraded(&st2).unwrap();
        assert_ne!(d.name, d2.name);
        assert_ne!(d.spec_hash(), d2.spec_hash());
        // dw unit down: depthwise falls back to the first survivor
        let mut st3 = FaultState::healthy(4);
        st3.health[0] = UnitHealth::Down;
        assert_eq!(p.degraded(&st3).unwrap().dw_acc, 0);
        // no survivors is an error, as is an arity mismatch
        let all_down = FaultState { health: vec![UnitHealth::Down; 4] };
        assert!(p.degraded(&all_down).is_err());
        assert!(p.degraded(&FaultState::healthy(2)).is_err());
    }

    #[test]
    fn derated_models_are_never_faster() {
        use crate::hw::faults::{FaultState, UnitHealth};
        for model in [
            LatencyModel::DigitalPe { pe: DIG_PE },
            LatencyModel::ImcMacro { rows: AIMC_ROWS, cols: AIMC_COLS },
            LatencyModel::Proportional { macs_per_cycle: 64.0 },
        ] {
            for factor in [1.0, 1.5, 2.0, 10.0] {
                let slow = model.derated(factor);
                let base = model.cycles(64, 3, 3, 16, 16, 128);
                assert!(
                    slow.cycles(64, 3, 3, 16, 16, 128) >= base,
                    "{model:?} derated {factor} got faster"
                );
            }
            // extreme factors clamp the grid at 1x1 instead of zeroing
            let floor = model.derated(1e12);
            assert!(floor.cycles(8, 3, 3, 4, 4, 16) > 0);
        }
        // derating changes the spec hash through the platform view
        let p = Platform::diana();
        let mut st = FaultState::healthy(2);
        st.health[0] = UnitHealth::Derated(2.0);
        let d = p.degraded(&st).unwrap();
        assert_eq!(d.n_acc(), 2, "derated units stay present");
        assert_ne!(d.spec_hash(), p.spec_hash());
    }

    #[test]
    fn proportional_model_is_mac_linear() {
        let m = LatencyModel::Proportional { macs_per_cycle: 2.0 };
        assert_eq!(m.cycles(8, 3, 3, 4, 4, 16), (8 * 9 * 16 * 16) as u64 / 2);
        assert_eq!(m.cycles(8, 3, 3, 4, 4, 0), 0);
    }
}
