//! Shared L1 scratchpad model.
//!
//! DIANA's two accelerators share a 256 kB L1 activation memory (the
//! property that makes ODiMO's channel-split mapping free of
//! data-marshaling overhead — paper Sec. III-A, condition ii), and the
//! digital accelerator has a 64 kB weight memory that Eq. 7's DMA term
//! refills. The paper's analytical models *neglect* tiling overheads for
//! activations that exceed L1; the simulator checks footprints and can
//! optionally charge a tiling penalty (the `NonIdeal` config), which the
//! ablation bench uses to probe rank preservation.

/// Shared L1 activation scratchpad, bytes (DIANA; other platforms set
/// their own budget via `Platform::l1_bytes`).
pub const L1_BYTES: usize = 256 * 1024;
/// Digital accelerator weight memory, bytes.
pub const DIG_WMEM_BYTES: usize = 64 * 1024;

/// Activation footprint of one layer execution: input + output tensors
/// live in L1 simultaneously (single-buffered; batch 1 at deployment,
/// 8-bit activations = 1 byte each).
pub fn act_footprint_bytes(cin: usize, in_hw: (usize, usize), cout: usize,
                           out_hw: (usize, usize)) -> usize {
    cin * in_hw.0 * in_hw.1 + cout * out_hw.0 * out_hw.1
}

/// Digital weight-tile footprint: int8 codes for the channels mapped to
/// the digital accelerator.
pub fn dig_weight_bytes(cin: usize, k: usize, cout_d: usize) -> usize {
    cout_d * cin * k * k
}

#[derive(Clone, Copy, Debug)]
pub struct L1Report {
    pub act_bytes: usize,
    pub dig_w_bytes: usize,
    pub act_overflow: bool,
    pub w_overflow: bool,
}

/// Platform-generic check against explicit byte budgets.
#[allow(clippy::too_many_arguments)]
pub fn check_layer_bytes(l1_bytes: usize, wmem_bytes: usize, cin: usize,
                         in_hw: (usize, usize), cout: usize, out_hw: (usize, usize),
                         k: usize, cout_d: usize) -> L1Report {
    let act = act_footprint_bytes(cin, in_hw, cout, out_hw);
    let w = dig_weight_bytes(cin, k, cout_d);
    L1Report {
        act_bytes: act,
        dig_w_bytes: w,
        act_overflow: act > l1_bytes,
        w_overflow: w > wmem_bytes,
    }
}

pub fn check_layer(cin: usize, in_hw: (usize, usize), cout: usize,
                   out_hw: (usize, usize), k: usize, cout_d: usize) -> L1Report {
    check_layer_bytes(L1_BYTES, DIG_WMEM_BYTES, cin, in_hw, cout, out_hw, k, cout_d)
}

/// Multiplicative compute penalty under the non-ideal configuration:
/// activations that do not fit must be processed in ceil(act/L1) tiles,
/// each paying an extra DMA round-trip; we approximate the slowdown as
/// the tile count on the compute term.
pub fn tiling_penalty_bytes(act_bytes: usize, l1_bytes: usize) -> u64 {
    (act_bytes.div_ceil(l1_bytes)) as u64
}

pub fn tiling_penalty(act_bytes: usize) -> u64 {
    tiling_penalty_bytes(act_bytes, L1_BYTES)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn footprints() {
        // 16ch 32x32 in, 32ch 16x16 out = 16*1024 + 32*256 bytes
        assert_eq!(act_footprint_bytes(16, (32, 32), 32, (16, 16)), 16384 + 8192);
        assert_eq!(dig_weight_bytes(16, 3, 32), 32 * 16 * 9);
    }

    #[test]
    fn benchmark_layers_fit_l1() {
        // every layer of the three benchmark models fits the shared L1
        // at batch 1 (the paper deploys batch-1 inference)
        for name in crate::model::ALL_MODELS {
            let g = crate::model::build(name).unwrap();
            for n in g.nodes.iter() {
                if matches!(n.op, crate::model::Op::Conv | crate::model::Op::DwConv) {
                    let r = check_layer(n.cin, n.in_hw, n.cout, n.out_hw, n.k, n.cout);
                    assert!(!r.act_overflow, "{}/{} overflows L1", name, n.name);
                }
            }
        }
    }

    #[test]
    fn large_resnet18_layer_exceeds_dig_wmem() {
        // 128x128x3x3 int8 = 147 kB > 64 kB: the DMA term in Eq. 7 is
        // what pays for the refill — flag it
        let r = check_layer(128, (8, 8), 128, (8, 8), 3, 128);
        assert!(r.w_overflow);
    }

    #[test]
    fn penalty_is_tile_count() {
        assert_eq!(tiling_penalty(L1_BYTES), 1);
        assert_eq!(tiling_penalty(L1_BYTES + 1), 2);
        assert_eq!(tiling_penalty(10), 1);
    }
}
