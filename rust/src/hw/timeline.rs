//! Execution timeline — per-accelerator busy intervals over one
//! end-to-end inference. This is the substrate behind Table I's
//! per-unit utilization columns and the Fig.-6 breakdown, generalized
//! to N accelerators: a unit is an index into the platform's
//! accelerator list, and layer names are interned into a shared table
//! (`u32` ids) so the simulator hot loop allocates at most one `String`
//! per unique layer instead of one per interval.

use std::fmt::Write as _;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interval {
    /// Accelerator index (into the platform's ordered accelerators).
    pub unit: usize,
    /// Interned layer-name id — resolve with [`Timeline::layer_name`].
    pub layer: u32,
    pub start: u64, // cycles
    pub end: u64,
}

#[derive(Clone, Debug, Default)]
pub struct Timeline {
    pub intervals: Vec<Interval>,
    pub total_cycles: u64,
    /// Interned layer names; `Interval::layer` indexes this table.
    names: Vec<String>,
    n_units: usize,
}

#[derive(Clone, Debug)]
pub struct Utilization {
    /// Fraction of total time each unit is busy (Table I util columns).
    pub busy_frac: Vec<f64>,
    /// Fraction of total time ALL units are busy simultaneously (the
    /// Fig.-6 "everything working" share; for 2 units, "both busy").
    pub all_busy_frac: f64,
    /// Fraction with at least one unit busy.
    pub union_frac: f64,
    /// Fraction with no unit busy (`1 - union_frac` by construction).
    pub idle_frac: f64,
}

impl Timeline {
    pub fn new(n_units: usize) -> Self {
        Timeline { intervals: Vec::new(), total_cycles: 0, names: Vec::new(), n_units }
    }

    pub fn n_units(&self) -> usize {
        self.n_units
    }

    /// Intern a layer name, returning its id. Idempotent; the common
    /// simulator pattern is one `intern` per layer followed by one
    /// `push` per unit, so repeated pushes are allocation-free.
    pub fn intern(&mut self, name: &str) -> u32 {
        if let Some(i) = self.names.iter().rposition(|n| n == name) {
            return i as u32;
        }
        self.names.push(name.to_string());
        (self.names.len() - 1) as u32
    }

    pub fn layer_name(&self, id: u32) -> &str {
        &self.names[id as usize]
    }

    pub fn push(&mut self, unit: usize, layer: u32, start: u64, end: u64) {
        debug_assert!(end >= start);
        debug_assert!(unit < self.n_units, "unit {unit} out of range");
        if end > start {
            self.intervals.push(Interval { unit, layer, start, end });
        }
        self.total_cycles = self.total_cycles.max(end);
    }

    /// Busy cycles of one unit (intervals of the same unit never overlap
    /// in this scheduler: layers are sequential, sub-layers parallel
    /// across units, not within one).
    pub fn busy_cycles(&self, unit: usize) -> u64 {
        self.intervals
            .iter()
            .filter(|iv| iv.unit == unit)
            .map(|iv| iv.end - iv.start)
            .sum()
    }

    pub fn utilization(&self) -> Utilization {
        if self.total_cycles == 0 {
            return Utilization {
                busy_frac: vec![0.0; self.n_units],
                all_busy_frac: 0.0,
                union_frac: 0.0,
                idle_frac: 0.0,
            };
        }
        let t = self.total_cycles as f64;
        let busy_frac: Vec<f64> = (0..self.n_units)
            .map(|u| self.busy_cycles(u) as f64 / t)
            .collect();
        let (union, all) = self.union_all_cycles();
        Utilization {
            busy_frac,
            all_busy_frac: all as f64 / t,
            union_frac: union as f64 / t,
            idle_frac: (self.total_cycles - union) as f64 / t,
        }
    }

    /// Cycles during which ALL units are busy (event sweep).
    pub fn overlap_cycles(&self) -> u64 {
        self.union_all_cycles().1
    }

    /// (cycles with >=1 unit busy, cycles with every unit busy).
    fn union_all_cycles(&self) -> (u64, u64) {
        if self.intervals.is_empty() || self.n_units == 0 {
            return (0, 0);
        }
        // events: (time, unit, +1/-1); per-unit counters tolerate
        // overlapping same-unit intervals from hand-built timelines
        let mut events: Vec<(u64, usize, i64)> = Vec::with_capacity(self.intervals.len() * 2);
        for iv in &self.intervals {
            events.push((iv.start, iv.unit, 1));
            events.push((iv.end, iv.unit, -1));
        }
        events.sort_unstable();
        let mut counts = vec![0i64; self.n_units];
        let mut n_busy = 0usize;
        let mut union = 0u64;
        let mut all = 0u64;
        let mut prev_t = events[0].0;
        let mut i = 0usize;
        while i < events.len() {
            let t = events[i].0;
            let seg = t - prev_t;
            if seg > 0 {
                if n_busy >= 1 {
                    union += seg;
                }
                if n_busy == self.n_units {
                    all += seg;
                }
            }
            while i < events.len() && events[i].0 == t {
                let (_, u, d) = events[i];
                let was = counts[u] > 0;
                counts[u] += d;
                let is = counts[u] > 0;
                if !was && is {
                    n_busy += 1;
                } else if was && !is {
                    n_busy -= 1;
                }
                i += 1;
            }
            prev_t = t;
        }
        (union, all)
    }

    /// Per-layer (name, busy cycles per unit, span) in cycles — the
    /// Fig.-6 rows. Layers appear in first-seen order.
    pub fn per_layer(&self) -> Vec<(String, Vec<u64>, u64)> {
        let mut order: Vec<u32> = Vec::new();
        for iv in &self.intervals {
            if !order.contains(&iv.layer) {
                order.push(iv.layer);
            }
        }
        order
            .into_iter()
            .map(|layer| {
                let mut busy = vec![0u64; self.n_units];
                let mut lo = u64::MAX;
                let mut hi = 0;
                for iv in self.intervals.iter().filter(|iv| iv.layer == layer) {
                    busy[iv.unit] += iv.end - iv.start;
                    lo = lo.min(iv.start);
                    hi = hi.max(iv.end);
                }
                (self.names[layer as usize].clone(), busy, hi.saturating_sub(lo))
            })
            .collect()
    }

    /// ASCII rendering of the per-layer utilization (Fig.-6 substitute
    /// for a plotting stack). One row per interval; the fill character
    /// cycles per unit ('#' unit 0, '%' unit 1, '@' unit 2, ...).
    pub fn render_ascii(&self, width: usize) -> String {
        const UNIT_CHARS: [char; 8] = ['#', '%', '@', '+', '*', '=', '~', '$'];
        let mut out = String::new();
        let t = self.total_cycles.max(1) as f64;
        for iv in &self.intervals {
            let pre = (iv.start as f64 / t * width as f64) as usize;
            let len = (((iv.end - iv.start) as f64 / t) * width as f64).ceil() as usize;
            let ch = UNIT_CHARS[iv.unit % UNIT_CHARS.len()];
            let _ = writeln!(
                out,
                "{:>10} {} |{}{}{}|",
                self.names[iv.layer as usize],
                iv.unit,
                " ".repeat(pre.min(width)),
                ch.to_string().repeat(len.clamp(1, width - pre.min(width))),
                " ".repeat(width.saturating_sub(pre + len.max(1)))
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utilization_parallel_layer() {
        let mut tl = Timeline::new(2);
        let c1 = tl.intern("c1");
        tl.push(0, c1, 0, 100);
        tl.push(1, c1, 0, 60);
        let u = tl.utilization();
        assert!((u.busy_frac[0] - 1.0).abs() < 1e-9);
        assert!((u.busy_frac[1] - 0.6).abs() < 1e-9);
        assert!((u.all_busy_frac - 0.6).abs() < 1e-9);
        assert!((u.union_frac - 1.0).abs() < 1e-9);
        assert!(u.idle_frac.abs() < 1e-9);
    }

    #[test]
    fn overlap_disjoint_is_zero() {
        let mut tl = Timeline::new(2);
        let a = tl.intern("a");
        let b = tl.intern("b");
        tl.push(0, a, 0, 50);
        tl.push(1, b, 50, 100);
        assert_eq!(tl.overlap_cycles(), 0);
        let u = tl.utilization();
        assert!((u.busy_frac[0] - 0.5).abs() < 1e-9);
        assert!(u.idle_frac.abs() < 1e-9);
    }

    #[test]
    fn idle_gap_counted() {
        let mut tl = Timeline::new(2);
        let a = tl.intern("a");
        let b = tl.intern("b");
        tl.push(0, a, 0, 25);
        tl.push(0, b, 75, 100);
        let u = tl.utilization();
        assert!((u.idle_frac - 0.5).abs() < 1e-9);
        assert!((u.union_frac - 0.5).abs() < 1e-9);
    }

    #[test]
    fn per_layer_rows() {
        let mut tl = Timeline::new(2);
        let c1 = tl.intern("c1");
        let c2 = tl.intern("c2");
        tl.push(0, c1, 0, 100);
        tl.push(1, c1, 0, 40);
        tl.push(0, c2, 100, 150);
        let rows = tl.per_layer();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0], ("c1".to_string(), vec![100, 40], 100));
        assert_eq!(rows[1], ("c2".to_string(), vec![50, 0], 50));
    }

    #[test]
    fn zero_len_intervals_skipped() {
        let mut tl = Timeline::new(2);
        let x = tl.intern("x");
        tl.push(1, x, 10, 10);
        assert!(tl.intervals.is_empty());
        assert_eq!(tl.total_cycles, 10);
    }

    #[test]
    fn intern_is_idempotent() {
        let mut tl = Timeline::new(1);
        let a = tl.intern("conv1");
        let b = tl.intern("conv2");
        assert_ne!(a, b);
        assert_eq!(tl.intern("conv1"), a);
        assert_eq!(tl.layer_name(a), "conv1");
        assert_eq!(tl.layer_name(b), "conv2");
    }

    #[test]
    fn ascii_render_has_rows() {
        let mut tl = Timeline::new(2);
        let c1 = tl.intern("c1");
        tl.push(0, c1, 0, 10);
        tl.push(1, c1, 0, 5);
        let s = tl.render_ascii(40);
        assert_eq!(s.lines().count(), 2);
        assert!(s.contains('#') && s.contains('%'));
    }

    #[test]
    fn four_unit_staggered_layer() {
        // the mpsoc4-shaped case: four units starting together on one
        // layer, finishing at different times (water-filled spans)
        let mut tl = Timeline::new(4);
        let l = tl.intern("conv");
        for (u, end) in [(0usize, 100u64), (1, 80), (2, 60), (3, 100)] {
            tl.push(u, l, 0, end);
        }
        let u = tl.utilization();
        assert!((u.all_busy_frac - 0.6).abs() < 1e-9);
        assert!((u.union_frac - 1.0).abs() < 1e-9);
        assert!((u.busy_frac[1] - 0.8).abs() < 1e-9);
        let rows = tl.per_layer();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].1, vec![100, 80, 60, 100]);
        assert_eq!(rows[0].2, 100);
    }

    #[test]
    fn three_unit_all_busy_and_union() {
        let mut tl = Timeline::new(3);
        let l = tl.intern("l");
        tl.push(0, l, 0, 100);
        tl.push(1, l, 20, 80);
        tl.push(2, l, 50, 120);
        tl.total_cycles = 120;
        let u = tl.utilization();
        // all three overlap on [50, 80)
        assert!((u.all_busy_frac - 30.0 / 120.0).abs() < 1e-9);
        // union covers [0, 120)
        assert!((u.union_frac - 1.0).abs() < 1e-9);
        assert!(u.idle_frac.abs() < 1e-9);
        assert!((u.busy_frac[2] - 70.0 / 120.0).abs() < 1e-9);
    }
}
