//! Execution timeline — per-accelerator busy intervals over one
//! end-to-end inference. This is the substrate behind Table I's
//! "D./A. util." columns and the Fig.-6 utilization breakdown.

use std::fmt::Write as _;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Unit {
    Digital = 0,
    Aimc = 1,
}

#[derive(Clone, Debug)]
pub struct Interval {
    pub unit: Unit,
    pub layer: String,
    pub start: u64, // cycles
    pub end: u64,
}

#[derive(Clone, Debug, Default)]
pub struct Timeline {
    pub intervals: Vec<Interval>,
    pub total_cycles: u64,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct Utilization {
    /// Fraction of total time each unit is busy (Table I "D./A. util.").
    pub busy_frac: [f64; 2],
    /// Fraction of total time both units are busy simultaneously
    /// (the Fig.-6 "both working" share).
    pub both_frac: f64,
    /// Fraction with neither busy.
    pub idle_frac: f64,
}

impl Timeline {
    pub fn push(&mut self, unit: Unit, layer: &str, start: u64, end: u64) {
        debug_assert!(end >= start);
        if end > start {
            self.intervals.push(Interval { unit, layer: layer.to_string(), start, end });
        }
        self.total_cycles = self.total_cycles.max(end);
    }

    /// Busy cycles of one unit (intervals of the same unit never overlap
    /// in this scheduler: layers are sequential, sub-layers parallel
    /// across units, not within one).
    pub fn busy_cycles(&self, unit: Unit) -> u64 {
        self.intervals
            .iter()
            .filter(|iv| iv.unit == unit)
            .map(|iv| iv.end - iv.start)
            .sum()
    }

    pub fn utilization(&self) -> Utilization {
        if self.total_cycles == 0 {
            return Utilization::default();
        }
        let t = self.total_cycles as f64;
        let bd = self.busy_cycles(Unit::Digital) as f64;
        let ba = self.busy_cycles(Unit::Aimc) as f64;
        let both = self.overlap_cycles() as f64;
        Utilization {
            busy_frac: [bd / t, ba / t],
            both_frac: both / t,
            idle_frac: ((t - bd - ba + both) / t).max(0.0),
        }
    }

    /// Cycles during which BOTH units are busy (sweep-line).
    pub fn overlap_cycles(&self) -> u64 {
        let mut dig: Vec<(u64, u64)> = self
            .intervals
            .iter()
            .filter(|iv| iv.unit == Unit::Digital)
            .map(|iv| (iv.start, iv.end))
            .collect();
        let mut aimc: Vec<(u64, u64)> = self
            .intervals
            .iter()
            .filter(|iv| iv.unit == Unit::Aimc)
            .map(|iv| (iv.start, iv.end))
            .collect();
        dig.sort_unstable();
        aimc.sort_unstable();
        let (mut i, mut j, mut total) = (0usize, 0usize, 0u64);
        while i < dig.len() && j < aimc.len() {
            let lo = dig[i].0.max(aimc[j].0);
            let hi = dig[i].1.min(aimc[j].1);
            if hi > lo {
                total += hi - lo;
            }
            if dig[i].1 < aimc[j].1 {
                i += 1;
            } else {
                j += 1;
            }
        }
        total
    }

    /// Per-layer (digital_busy, aimc_busy, span) in cycles — the Fig.-6
    /// rows. Layers appear in first-seen order.
    pub fn per_layer(&self) -> Vec<(String, u64, u64, u64)> {
        let mut order: Vec<String> = Vec::new();
        for iv in &self.intervals {
            if !order.contains(&iv.layer) {
                order.push(iv.layer.clone());
            }
        }
        order
            .into_iter()
            .map(|layer| {
                let mut d = 0;
                let mut a = 0;
                let mut lo = u64::MAX;
                let mut hi = 0;
                for iv in self.intervals.iter().filter(|iv| iv.layer == layer) {
                    match iv.unit {
                        Unit::Digital => d += iv.end - iv.start,
                        Unit::Aimc => a += iv.end - iv.start,
                    }
                    lo = lo.min(iv.start);
                    hi = hi.max(iv.end);
                }
                (layer, d, a, hi.saturating_sub(lo))
            })
            .collect()
    }

    /// ASCII rendering of the per-layer utilization (Fig.-6 substitute
    /// for a plotting stack). One row per layer; '#' digital, '%' AIMC.
    pub fn render_ascii(&self, width: usize) -> String {
        let mut out = String::new();
        let t = self.total_cycles.max(1) as f64;
        for iv in &self.intervals {
            let pre = (iv.start as f64 / t * width as f64) as usize;
            let len = (((iv.end - iv.start) as f64 / t) * width as f64).ceil() as usize;
            let ch = match iv.unit {
                Unit::Digital => '#',
                Unit::Aimc => '%',
            };
            let _ = writeln!(
                out,
                "{:>10} {} |{}{}{}|",
                iv.layer,
                if iv.unit == Unit::Digital { "D" } else { "A" },
                " ".repeat(pre.min(width)),
                ch.to_string().repeat(len.clamp(1, width - pre.min(width))),
                " ".repeat(width.saturating_sub(pre + len.max(1)))
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utilization_parallel_layer() {
        let mut tl = Timeline::default();
        tl.push(Unit::Digital, "c1", 0, 100);
        tl.push(Unit::Aimc, "c1", 0, 60);
        let u = tl.utilization();
        assert!((u.busy_frac[0] - 1.0).abs() < 1e-9);
        assert!((u.busy_frac[1] - 0.6).abs() < 1e-9);
        assert!((u.both_frac - 0.6).abs() < 1e-9);
        assert!(u.idle_frac.abs() < 1e-9);
    }

    #[test]
    fn overlap_disjoint_is_zero() {
        let mut tl = Timeline::default();
        tl.push(Unit::Digital, "a", 0, 50);
        tl.push(Unit::Aimc, "b", 50, 100);
        assert_eq!(tl.overlap_cycles(), 0);
        let u = tl.utilization();
        assert!((u.busy_frac[0] - 0.5).abs() < 1e-9);
        assert!(u.idle_frac.abs() < 1e-9);
    }

    #[test]
    fn idle_gap_counted() {
        let mut tl = Timeline::default();
        tl.push(Unit::Digital, "a", 0, 25);
        tl.push(Unit::Digital, "b", 75, 100);
        let u = tl.utilization();
        assert!((u.idle_frac - 0.5).abs() < 1e-9);
    }

    #[test]
    fn per_layer_rows() {
        let mut tl = Timeline::default();
        tl.push(Unit::Digital, "c1", 0, 100);
        tl.push(Unit::Aimc, "c1", 0, 40);
        tl.push(Unit::Digital, "c2", 100, 150);
        let rows = tl.per_layer();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0], ("c1".to_string(), 100, 40, 100));
        assert_eq!(rows[1], ("c2".to_string(), 50, 0, 50));
    }

    #[test]
    fn zero_len_intervals_skipped() {
        let mut tl = Timeline::default();
        tl.push(Unit::Aimc, "x", 10, 10);
        assert!(tl.intervals.is_empty());
        assert_eq!(tl.total_cycles, 10);
    }

    #[test]
    fn ascii_render_has_rows() {
        let mut tl = Timeline::default();
        tl.push(Unit::Digital, "c1", 0, 10);
        tl.push(Unit::Aimc, "c1", 0, 5);
        let s = tl.render_ascii(40);
        assert_eq!(s.lines().count(), 2);
        assert!(s.contains('#') && s.contains('%'));
    }
}
