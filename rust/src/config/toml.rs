//! TOML-subset parser: `[section]` headers, `key = value` pairs,
//! values = quoted strings, numbers, booleans, flat `[a, b, c]` arrays.
//! Keys are flattened to "section.key". Comments with `#`.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

pub fn parse_toml(text: &str) -> Result<BTreeMap<String, TomlValue>> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                return Err(anyhow!("line {}: bad section header", lineno + 1));
            }
            section = line[1..line.len() - 1].trim().to_string();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
        let key = line[..eq].trim();
        let val = line[eq + 1..].trim();
        if key.is_empty() {
            return Err(anyhow!("line {}: empty key", lineno + 1));
        }
        let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        out.insert(full, parse_value(val).map_err(|e| anyhow!("line {}: {e}", lineno + 1))?);
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // cut at the first '#' that is not inside a quoted string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Result<TomlValue> {
    if v.starts_with('"') {
        if v.len() < 2 || !v.ends_with('"') {
            return Err(anyhow!("unterminated string"));
        }
        return Ok(TomlValue::Str(v[1..v.len() - 1].to_string()));
    }
    if v == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if v == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if v.starts_with('[') {
        if !v.ends_with(']') {
            return Err(anyhow!("unterminated array"));
        }
        let inner = v[1..v.len() - 1].trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        let items = inner
            .split(',')
            .map(|s| parse_value(s.trim()))
            .collect::<Result<Vec<_>>>()?;
        return Ok(TomlValue::Arr(items));
    }
    v.parse::<f64>()
        .map(TomlValue::Num)
        .map_err(|_| anyhow!("cannot parse value '{v}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse_toml(
            "# comment\ntop = 1\n[a]\ns = \"hi\" # trailing\nn = -2.5\nb = false\n\
             arr = [1, 2, 3]\n[b]\nx = 0\n",
        )
        .unwrap();
        assert_eq!(doc["top"], TomlValue::Num(1.0));
        assert_eq!(doc["a.s"], TomlValue::Str("hi".into()));
        assert_eq!(doc["a.n"], TomlValue::Num(-2.5));
        assert_eq!(doc["a.b"], TomlValue::Bool(false));
        assert_eq!(
            doc["a.arr"],
            TomlValue::Arr(vec![TomlValue::Num(1.0), TomlValue::Num(2.0), TomlValue::Num(3.0)])
        );
        assert_eq!(doc["b.x"], TomlValue::Num(0.0));
    }

    #[test]
    fn errors_with_line_numbers() {
        let e = parse_toml("ok = 1\nbroken").unwrap_err().to_string();
        assert!(e.contains("line 2"), "{e}");
        assert!(parse_toml("[unclosed\n").is_err());
        assert!(parse_toml("k = [1, 2\n").is_err());
        assert!(parse_toml("k = \"oops\n").is_err());
    }

    #[test]
    fn empty_array() {
        let doc = parse_toml("a = []\n").unwrap();
        assert_eq!(doc["a"], TomlValue::Arr(vec![]));
    }
}
