//! Run configuration: typed config struct + a small TOML-subset parser
//! (sections, `key = value` with strings / numbers / bools / flat
//! arrays) + CLI override layer. Covers everything the experiment
//! drivers need without serde.

mod toml;

pub use toml::{parse_toml, TomlValue};

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{anyhow, Result};

use crate::coordinator::Schedule;
use crate::hw::Platform;

/// Everything a pipeline/experiment run needs.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub model: String,
    pub artifacts_dir: PathBuf,
    pub results_dir: PathBuf,
    pub data_seed: u64,
    pub schedule: Schedule,
    pub lambdas: Vec<f32>,
    /// Non-ideal L1 modeling in the simulator (ablation knob).
    pub non_ideal_l1: bool,
    /// Deployment target SoC (built-in name or loaded from TOML).
    pub platform: Platform,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "resnet20".into(),
            artifacts_dir: PathBuf::from("artifacts"),
            results_dir: PathBuf::from("results"),
            data_seed: 1234,
            schedule: Schedule::default(),
            lambdas: vec![0.5, 2.0, 6.0, 15.0],
            non_ideal_l1: false,
            platform: Platform::diana(),
        }
    }
}

impl RunConfig {
    /// Load from a TOML-subset file; missing keys keep defaults.
    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        let doc = parse_toml(&text)?;
        let mut cfg = RunConfig::default();
        cfg.apply(&doc)?;
        Ok(cfg)
    }

    pub fn apply(&mut self, doc: &BTreeMap<String, TomlValue>) -> Result<()> {
        for (k, v) in doc {
            match (k.as_str(), v) {
                ("run.model", TomlValue::Str(s)) => self.model = s.clone(),
                ("run.artifacts_dir", TomlValue::Str(s)) => self.artifacts_dir = s.into(),
                ("run.results_dir", TomlValue::Str(s)) => self.results_dir = s.into(),
                ("run.data_seed", TomlValue::Num(n)) => self.data_seed = *n as u64,
                ("schedule.pretrain_steps", TomlValue::Num(n)) => {
                    self.schedule.pretrain_steps = *n as usize
                }
                ("schedule.search_steps", TomlValue::Num(n)) => {
                    self.schedule.search_steps = *n as usize
                }
                ("schedule.finetune_steps", TomlValue::Num(n)) => {
                    self.schedule.finetune_steps = *n as usize
                }
                ("schedule.eval_batches", TomlValue::Num(n)) => {
                    self.schedule.eval_batches = *n as usize
                }
                ("search.lambdas", TomlValue::Arr(a)) => {
                    self.lambdas = a
                        .iter()
                        .map(|x| match x {
                            TomlValue::Num(n) => Ok(*n as f32),
                            _ => Err(anyhow!("search.lambdas must be numbers")),
                        })
                        .collect::<Result<Vec<f32>>>()?;
                }
                ("hw.non_ideal_l1", TomlValue::Bool(b)) => self.non_ideal_l1 = *b,
                ("hw.platform", TomlValue::Str(s)) => {
                    self.platform = Platform::resolve(s)?;
                }
                (key, _) => return Err(anyhow!("unknown or mistyped config key '{key}'")),
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = RunConfig::default();
        assert_eq!(c.model, "resnet20");
        assert!(!c.lambdas.is_empty());
    }

    #[test]
    fn apply_overrides() {
        let doc = parse_toml(
            "[run]\nmodel = \"tinycnn\"\ndata_seed = 7\n[schedule]\nsearch_steps = 11\n\
             [search]\nlambdas = [0.1, 1.0]\n[hw]\nnon_ideal_l1 = true\n\
             platform = \"diana_ne16\"\n",
        )
        .unwrap();
        let mut c = RunConfig::default();
        c.apply(&doc).unwrap();
        assert_eq!(c.model, "tinycnn");
        assert_eq!(c.data_seed, 7);
        assert_eq!(c.schedule.search_steps, 11);
        assert_eq!(c.lambdas, vec![0.1, 1.0]);
        assert!(c.non_ideal_l1);
        assert_eq!(c.platform.n_acc(), 3);
    }

    #[test]
    fn unknown_key_rejected() {
        let doc = parse_toml("[run]\nbogus = 1\n").unwrap();
        let mut c = RunConfig::default();
        assert!(c.apply(&doc).is_err());
    }
}
