//! Experiment drivers — one per table/figure of the paper's evaluation
//! (DESIGN.md §Per-experiment-index). Each driver runs the required
//! pipelines (reusing cached sweeps where possible), writes
//! `results/<exp>_*.{md,csv,json}`, and prints a terminal summary.

pub mod store;

use std::path::PathBuf;

use anyhow::{anyhow, Result};

use crate::config::RunConfig;
use crate::coordinator::{Pipeline, Regularizer, SearchPoint};
use crate::hw::soc::SocConfig;
use crate::hw::AbstractHw;
use crate::metrics;
use crate::runtime::{ArtifactMeta, Runtime};

pub struct ExpContext {
    pub rt: Runtime,
    pub cfg: RunConfig,
}

impl ExpContext {
    pub fn new(cfg: RunConfig) -> Result<Self> {
        Ok(ExpContext { rt: Runtime::cpu()?, cfg })
    }

    fn meta(&self) -> Result<ArtifactMeta> {
        ArtifactMeta::load(&self.cfg.artifacts_dir, &self.cfg.model)
    }

    fn pipeline<'a>(&'a self, meta: &'a ArtifactMeta) -> Pipeline<'a> {
        let mut p = Pipeline::new(&self.rt, meta, self.cfg.schedule);
        p.data_seed = self.cfg.data_seed;
        p.ckpt_dir = self.cfg.results_dir.clone();
        p.soc_cfg = SocConfig { non_ideal_l1: self.cfg.non_ideal_l1 };
        p.platform = self.cfg.platform.clone();
        p
    }

    /// Cache paths are keyed by (model, platform, tag): points computed
    /// for one SoC must never be reused — or even parsed — under
    /// another (their mappings can carry accelerator ids the other
    /// platform does not have). This also sidesteps pre-registry cache
    /// files, which used a different JSON shape.
    fn points_path(&self, tag: &str) -> PathBuf {
        self.cfg.results_dir.join(format!(
            "points_{}_{}_{}.json",
            self.cfg.model, self.cfg.platform.name, tag
        ))
    }

    fn table1_path(&self) -> PathBuf {
        self.cfg.results_dir.join(format!(
            "table1_{}_{}.json",
            self.cfg.model, self.cfg.platform.name
        ))
    }

    /// Run (or reload) the lambda sweep + baselines for one regularizer.
    pub fn sweep_cached(&self, reg: &Regularizer, tag: &str, baselines: &[&str])
                        -> Result<Vec<SearchPoint>> {
        let path = self.points_path(tag);
        if path.exists() {
            log::info!("reusing cached sweep {}", path.display());
            return store::load_points(&path);
        }
        let meta = self.meta()?;
        let pipe = self.pipeline(&meta);
        let folded = pipe.pretrained_folded()?;
        let mut points = pipe.sweep(&folded, reg, &self.cfg.lambdas)?;
        for b in baselines {
            // All-Ternary / Min-Cost can fail to converge on the hardest
            // tasks (the paper drops them for VWW); keep going.
            match pipe.baseline_point(&folded, b) {
                Ok(p) => points.push(p),
                Err(e) => log::warn!("baseline {b} failed: {e:#}"),
            }
        }
        store::save_points(&path, &points)?;
        Ok(points)
    }
}

/// Default baselines per figure (paper Sec. IV-A).
pub const FIG4_BASELINES: [&str; 4] =
    ["all_8bit", "all_ternary", "io8_backbone_ternary", "min_cost_lat"];

/// Fig. 4 — accuracy vs latency (top) and vs energy (bottom) with the
/// DIANA cost models, for the configured model.
pub fn fig4(ctx: &ExpContext) -> Result<()> {
    let model = ctx.cfg.model.clone();
    for (reg, tag, cost_name) in [
        (Regularizer::LatencyDiana, "lat", "latency_ms"),
        (Regularizer::EnergyDiana, "en", "energy_uj"),
    ] {
        let baselines: Vec<&str> = if tag == "lat" {
            vec!["all_8bit", "all_ternary", "io8_backbone_ternary", "min_cost_lat"]
        } else {
            vec!["all_8bit", "all_ternary", "io8_backbone_ternary", "min_cost_en"]
        };
        let points = ctx.sweep_cached(&reg, tag, &baselines)?;
        let cost = |p: &SearchPoint| if tag == "lat" { p.latency_ms } else { p.energy_uj };
        let front = metrics::pareto_front(&points, cost);
        let md = format!(
            "# Fig. 4 ({model}, accuracy vs {cost_name})\n\n{}\nPareto front: {:?}\n\n```\n{}\n```\n",
            metrics::table_markdown(&format!("{model} / {tag}"), &points),
            front.iter().map(|&i| points[i].label.clone()).collect::<Vec<_>>(),
            metrics::ascii_scatter(&points, cost, 64, 16),
        );
        metrics::write_results(
            &ctx.cfg.results_dir,
            &format!("fig4_{model}_{tag}"),
            &md,
            &metrics::points_csv(&points),
        )?;
        println!("{md}");
        summarize_vs_baseline(&points, cost, cost_name);
    }
    Ok(())
}

/// The §IV-B headline numbers: best ODiMO point within small accuracy
/// drops of All-8bit.
pub fn summarize_vs_baseline(points: &[SearchPoint], cost: impl Fn(&SearchPoint) -> f64,
                             cost_name: &str) {
    let Some(base) = points.iter().find(|p| p.label == "all_8bit") else {
        return;
    };
    for drop in [0.005, 0.02, 0.05] {
        let best = points
            .iter()
            .filter(|p| p.label.starts_with("odimo") && p.accuracy >= base.accuracy - drop)
            .min_by(|a, b| cost(a).partial_cmp(&cost(b)).unwrap());
        if let Some(p) = best {
            println!(
                "  <= {:.1}% acc drop: {} saves {:.1}% {} ({:.4} vs {:.4}), acc {:.2}% vs {:.2}%",
                100.0 * drop,
                p.label,
                100.0 * (1.0 - cost(p) / cost(base)),
                cost_name,
                cost(p),
                cost(base),
                100.0 * p.accuracy,
                100.0 * base.accuracy,
            );
        }
    }
}

/// Fig. 5 — abstract hardware models (no-shutdown / ideal-shutdown) on
/// the configured model (the paper shows TinyImageNet).
pub fn fig5(ctx: &ExpContext) -> Result<()> {
    let model = ctx.cfg.model.clone();
    let meta = ctx.meta()?;
    for (hw, tag) in [
        (AbstractHw::no_shutdown(), "prop_noshutdown"),
        (AbstractHw::ideal_shutdown(), "prop_shutdown"),
    ] {
        let reg = Regularizer::Proportional(hw.to_input_vec());
        let mut points = ctx.sweep_cached(&reg, tag, &["all_8bit", "io8_backbone_ternary"])?;
        // cost for fig5 points is the *abstract* model's energy
        for p in &mut points {
            let (lat, en) = hw.cost(&meta.model, &p.mapping.channel_split(hw.n_acc()));
            p.latency_ms = lat; // abstract cycles
            p.energy_uj = en; // abstract mW*cycles
        }
        let cost = |p: &SearchPoint| p.energy_uj;
        let md = format!(
            "# Fig. 5 ({model}, abstract hw: {tag})\n\n{}\n```\n{}\n```\n",
            metrics::table_markdown(tag, &points),
            metrics::ascii_scatter(&points, cost, 64, 16),
        );
        metrics::write_results(
            &ctx.cfg.results_dir,
            &format!("fig5_{model}_{tag}"),
            &md,
            &metrics::points_csv(&points),
        )?;
        println!("{md}");
        summarize_vs_baseline(&points, cost, "abstract_energy");
    }
    Ok(())
}

/// Select the Table-I style deployment points from a sweep: the
/// highest-accuracy ODiMO point (Large) and the cheapest point within a
/// liberal accuracy window (Small).
pub fn select_large_small(points: &[SearchPoint], cost: impl Fn(&SearchPoint) -> f64)
                          -> (Option<usize>, Option<usize>) {
    let odimo: Vec<usize> = (0..points.len())
        .filter(|&i| points[i].label.starts_with("odimo"))
        .collect();
    let large = odimo
        .iter()
        .copied()
        .max_by(|&a, &b| points[a].accuracy.partial_cmp(&points[b].accuracy).unwrap());
    let max_acc = odimo
        .iter()
        .map(|&i| points[i].accuracy)
        .fold(f64::NEG_INFINITY, f64::max);
    let small = odimo
        .iter()
        .copied()
        .filter(|&i| points[i].accuracy >= max_acc - 0.08)
        .min_by(|&a, &b| cost(&points[a]).partial_cmp(&cost(&points[b])).unwrap());
    (large, small.filter(|s| Some(*s) != large))
}

/// Table I — deployment of selected Fig.-4 points on the DIANA
/// simulator (All-8bit, ODiMO Large/Small x Lat/En, Min-Cost).
pub fn table1(ctx: &ExpContext) -> Result<()> {
    let model = ctx.cfg.model.clone();
    let mut rows: Vec<SearchPoint> = Vec::new();
    let variants: [(&str, fn(&SearchPoint) -> f64); 2] =
        [("lat", |p| p.latency_ms), ("en", |p| p.energy_uj)];
    for (tag, cost) in variants {
        let reg = if tag == "lat" { Regularizer::LatencyDiana } else { Regularizer::EnergyDiana };
        let baselines: Vec<&str> = if tag == "lat" {
            vec!["all_8bit", "all_ternary", "io8_backbone_ternary", "min_cost_lat"]
        } else {
            vec!["all_8bit", "all_ternary", "io8_backbone_ternary", "min_cost_en"]
        };
        let points = ctx.sweep_cached(&reg, tag, &baselines)?;
        if tag == "lat" {
            if let Some(b) = points.iter().find(|p| p.label == "all_8bit") {
                rows.push(b.clone());
            }
        }
        let (large, small) = select_large_small(&points, cost);
        if let Some(i) = large {
            let mut p = points[i].clone();
            p.label = format!("ODiMO Large - {}", tag.to_uppercase());
            rows.push(p);
        }
        if let Some(i) = small {
            let mut p = points[i].clone();
            p.label = format!("ODiMO Small - {}", tag.to_uppercase());
            rows.push(p);
        }
        if tag == "en" {
            if let Some(b) = points.iter().find(|p| p.label.starts_with("min_cost")) {
                rows.push(b.clone());
            }
        }
    }
    let md = metrics::table_markdown(
        &format!("Table I — {model} on {} (simulated)", ctx.cfg.platform.name),
        &rows,
    );
    metrics::write_results(
        &ctx.cfg.results_dir,
        &format!("table1_{model}"),
        &md,
        &metrics::points_csv(&rows),
    )?;
    store::save_points(&ctx.table1_path(), &rows)?;
    println!("{md}");
    Ok(())
}

/// Fig. 6 — per-layer utilization breakdown of the ODiMO-Small-En
/// mapping (falls back to Large or min-cost if Small was not found).
pub fn fig6(ctx: &ExpContext) -> Result<()> {
    let model = ctx.cfg.model.clone();
    let t1_path = ctx.table1_path();
    if !t1_path.exists() {
        table1(ctx)?;
    }
    let rows = store::load_points(&t1_path)?;
    let pick = rows
        .iter()
        .find(|p| p.label.contains("Small - EN"))
        .or_else(|| rows.iter().find(|p| p.label.contains("Large - EN")))
        .or_else(|| rows.iter().find(|p| p.label.starts_with("odimo")))
        .ok_or_else(|| anyhow!("no ODiMO row in table1 output"))?;
    let meta = ctx.meta()?;
    let platform = &ctx.cfg.platform;
    let rep = crate::coordinator::scheduler::deploy(
        &meta.model,
        &pick.mapping,
        platform,
        SocConfig { non_ideal_l1: ctx.cfg.non_ideal_l1 },
    );
    let tl = &rep.run.timeline;
    let u = tl.utilization();
    let mut csv = String::from("layer");
    for a in &platform.accelerators {
        csv.push_str(&format!(",{}_cycles", a.name));
    }
    csv.push_str(",span_cycles\n");
    for (layer, busy, span) in tl.per_layer() {
        csv.push_str(&layer);
        for b in &busy {
            csv.push_str(&format!(",{b}"));
        }
        csv.push_str(&format!(",{span}\n"));
    }
    let busy_list = platform
        .accelerators
        .iter()
        .zip(&u.busy_frac)
        .map(|(a, b)| format!("{} busy: {:.1}%", a.name, 100.0 * b))
        .collect::<Vec<_>>()
        .join(" | ");
    let md = format!(
        "# Fig. 6 — accelerator utilization, {} ({} on {})\n\n\
         all busy: {:.1}% | {busy_list} | idle: {:.1}%\n\n\
         ```\n{}```\n",
        pick.label,
        model,
        platform.name,
        100.0 * u.all_busy_frac,
        100.0 * u.idle_frac,
        tl.render_ascii(72),
    );
    metrics::write_results(&ctx.cfg.results_dir, &format!("fig6_{model}"), &md, &csv)?;
    println!("{md}");
    Ok(())
}
