//! Persistence for evaluated search points (mapping + metrics), so the
//! expensive sweeps (fig4) are computed once and reused by table1/fig6.
//!
//! All writers here are crash-safe: [`write_atomic`] stages the payload
//! in a sibling temp file and `rename`s it into place, so a killed
//! process can never leave a half-written cache that a later run would
//! silently misparse. Long-lived caches (the serve frontier, the serve
//! metrics report) additionally go through the
//! [`save_versioned`]/[`load_versioned`] envelope, which pins a `kind`
//! tag and a schema version and turns any mismatch into a clear error
//! instead of a garbage parse.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::coordinator::{Mapping, SearchPoint};
use crate::util::json::{self, Json};

/// Write `text` to `path` atomically: stage in a uniquely named
/// `<path>.<pid>.<n>.tmp` sibling (same directory, hence same
/// filesystem, so the rename cannot cross devices) and rename over the
/// destination. Readers either see the old file or the complete new
/// one — never a truncated write — and concurrent writers to one path
/// cannot clobber each other's staging file (last rename wins whole).
pub fn write_atomic(path: &Path, text: &str) -> Result<()> {
    static COUNTER: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut tmp_name = path.as_os_str().to_os_string();
    tmp_name.push(format!(
        ".{}.{}.tmp",
        std::process::id(),
        COUNTER.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    ));
    let tmp = PathBuf::from(tmp_name);
    std::fs::write(&tmp, text).map_err(|e| {
        let _ = std::fs::remove_file(&tmp);
        anyhow!("writing {}: {e}", tmp.display())
    })?;
    std::fs::rename(&tmp, path).map_err(|e| {
        let _ = std::fs::remove_file(&tmp);
        anyhow!("renaming {} -> {}: {e}", tmp.display(), path.display())
    })?;
    Ok(())
}

/// Wrap `payload` in a `{kind, schema_version, payload}` envelope and
/// write it atomically. The companion [`load_versioned`] refuses files
/// whose kind or version disagree, so cache-format evolutions surface
/// as actionable errors instead of misparses.
pub fn save_versioned(path: &Path, kind: &str, version: u32, payload: Json) -> Result<()> {
    let doc = Json::obj(vec![
        ("kind", Json::str(kind)),
        ("schema_version", Json::num(version as f64)),
        ("payload", payload),
    ]);
    write_atomic(path, &doc.to_string())
}

/// Load a [`save_versioned`] envelope, checking the `kind` tag and the
/// schema version before handing back the payload.
pub fn load_versioned(path: &Path, kind: &str, version: u32) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
    let doc = json::parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
    let got_kind = doc.req("kind")?.as_str().unwrap_or("").to_string();
    if got_kind != kind {
        return Err(anyhow!(
            "{}: cache kind '{got_kind}' != expected '{kind}'",
            path.display()
        ));
    }
    let got_v = doc.req("schema_version")?.as_usize().unwrap_or(0) as u32;
    if got_v != version {
        return Err(anyhow!(
            "{}: schema version {got_v} != expected {version} — \
             regenerate the cache (or delete the stale file)",
            path.display()
        ));
    }
    Ok(doc.req("payload")?.clone())
}

pub fn point_to_json(p: &SearchPoint) -> Json {
    Json::obj(vec![
        ("label", Json::str(p.label.clone())),
        ("lambda", Json::num(p.lambda)),
        ("accuracy", Json::num(p.accuracy)),
        ("latency_ms", Json::num(p.latency_ms)),
        ("energy_uj", Json::num(p.energy_uj)),
        ("total_cycles", Json::num(p.total_cycles as f64)),
        // per-accelerator busy fractions, in platform order
        ("util", Json::Arr(p.util.iter().map(|&u| Json::num(u)).collect())),
        ("aimc_ch_frac", Json::num(p.aimc_channel_frac)),
        ("mapping", p.mapping.to_json()),
    ])
}

pub fn point_from_json(v: &Json) -> Result<SearchPoint> {
    let util = v
        .req("util")?
        .as_arr()
        .ok_or_else(|| anyhow!("point util must be an array"))?
        .iter()
        .map(|x| x.as_f64().unwrap_or(0.0))
        .collect();
    Ok(SearchPoint {
        label: v.req("label")?.as_str().unwrap_or("").to_string(),
        lambda: v.req("lambda")?.as_f64().unwrap_or(f64::NAN),
        accuracy: v.req("accuracy")?.as_f64().unwrap_or(0.0),
        latency_ms: v.req("latency_ms")?.as_f64().unwrap_or(0.0),
        energy_uj: v.req("energy_uj")?.as_f64().unwrap_or(0.0),
        total_cycles: v.req("total_cycles")?.as_f64().unwrap_or(0.0) as u64,
        util,
        aimc_channel_frac: v.req("aimc_ch_frac")?.as_f64().unwrap_or(0.0),
        mapping: Mapping::from_json(v.req("mapping")?)?,
    })
}

pub fn save_points(path: &Path, points: &[SearchPoint]) -> Result<()> {
    let arr = Json::Arr(points.iter().map(point_to_json).collect());
    write_atomic(path, &arr.to_string())
}

pub fn load_points(path: &Path) -> Result<Vec<SearchPoint>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
    json::parse(&text)?
        .as_arr()
        .ok_or_else(|| anyhow!("points file must be a json array"))?
        .iter()
        .map(point_from_json)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{tinycnn, DIG};

    #[test]
    fn roundtrip() {
        let g = tinycnn();
        let p = SearchPoint {
            label: "odimo_0.5".into(),
            lambda: 0.5,
            accuracy: 0.91,
            latency_ms: 1.23,
            energy_uj: 33.3,
            total_cycles: 319_800,
            util: vec![1.0, 0.4],
            aimc_channel_frac: 0.3,
            mapping: Mapping::uniform(&g, DIG),
        };
        let dir = std::env::temp_dir().join("odimo_store_test");
        let path = dir.join("pts.json");
        save_points(&path, &[p.clone()]).unwrap();
        let back = load_points(&path).unwrap();
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].label, p.label);
        assert_eq!(back[0].mapping, p.mapping);
        assert!((back[0].accuracy - p.accuracy).abs() < 1e-9);
        assert_eq!(back[0].total_cycles, p.total_cycles);
        // crash-safety: no staging file survives a clean save
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "temp files left behind: {leftovers:?}");
    }

    #[test]
    fn write_atomic_replaces_existing() {
        let dir = std::env::temp_dir().join("odimo_store_atomic");
        let path = dir.join("v.json");
        write_atomic(&path, "old").unwrap();
        write_atomic(&path, "new").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "new");
    }

    #[test]
    fn versioned_envelope_roundtrip_and_mismatch() {
        let dir = std::env::temp_dir().join("odimo_store_versioned");
        let path = dir.join("cache.json");
        let payload = Json::obj(vec![("x", Json::num(3.0))]);
        save_versioned(&path, "frontier", 2, payload.clone()).unwrap();
        let back = load_versioned(&path, "frontier", 2).unwrap();
        assert_eq!(back, payload);
        // wrong schema version -> a clear error, not a misparse
        let e = load_versioned(&path, "frontier", 3).unwrap_err().to_string();
        assert!(e.contains("schema version 2"), "{e}");
        // wrong kind -> a clear error too
        let e = load_versioned(&path, "serve_report", 2).unwrap_err().to_string();
        assert!(e.contains("kind"), "{e}");
    }
}
