//! Persistence for evaluated search points (mapping + metrics), so the
//! expensive sweeps (fig4) are computed once and reused by table1/fig6.

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::coordinator::{Mapping, SearchPoint};
use crate::util::json::{self, Json};

pub fn point_to_json(p: &SearchPoint) -> Json {
    Json::obj(vec![
        ("label", Json::str(p.label.clone())),
        ("lambda", Json::num(p.lambda)),
        ("accuracy", Json::num(p.accuracy)),
        ("latency_ms", Json::num(p.latency_ms)),
        ("energy_uj", Json::num(p.energy_uj)),
        ("total_cycles", Json::num(p.total_cycles as f64)),
        // per-accelerator busy fractions, in platform order
        ("util", Json::Arr(p.util.iter().map(|&u| Json::num(u)).collect())),
        ("aimc_ch_frac", Json::num(p.aimc_channel_frac)),
        ("mapping", p.mapping.to_json()),
    ])
}

pub fn point_from_json(v: &Json) -> Result<SearchPoint> {
    let util = v
        .req("util")?
        .as_arr()
        .ok_or_else(|| anyhow!("point util must be an array"))?
        .iter()
        .map(|x| x.as_f64().unwrap_or(0.0))
        .collect();
    Ok(SearchPoint {
        label: v.req("label")?.as_str().unwrap_or("").to_string(),
        lambda: v.req("lambda")?.as_f64().unwrap_or(f64::NAN),
        accuracy: v.req("accuracy")?.as_f64().unwrap_or(0.0),
        latency_ms: v.req("latency_ms")?.as_f64().unwrap_or(0.0),
        energy_uj: v.req("energy_uj")?.as_f64().unwrap_or(0.0),
        total_cycles: v.req("total_cycles")?.as_f64().unwrap_or(0.0) as u64,
        util,
        aimc_channel_frac: v.req("aimc_ch_frac")?.as_f64().unwrap_or(0.0),
        mapping: Mapping::from_json(v.req("mapping")?)?,
    })
}

pub fn save_points(path: &Path, points: &[SearchPoint]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let arr = Json::Arr(points.iter().map(point_to_json).collect());
    std::fs::write(path, arr.to_string())?;
    Ok(())
}

pub fn load_points(path: &Path) -> Result<Vec<SearchPoint>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
    json::parse(&text)?
        .as_arr()
        .ok_or_else(|| anyhow!("points file must be a json array"))?
        .iter()
        .map(point_from_json)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{tinycnn, DIG};

    #[test]
    fn roundtrip() {
        let g = tinycnn();
        let p = SearchPoint {
            label: "odimo_0.5".into(),
            lambda: 0.5,
            accuracy: 0.91,
            latency_ms: 1.23,
            energy_uj: 33.3,
            total_cycles: 319_800,
            util: vec![1.0, 0.4],
            aimc_channel_frac: 0.3,
            mapping: Mapping::uniform(&g, DIG),
        };
        let dir = std::env::temp_dir().join("odimo_store_test");
        let path = dir.join("pts.json");
        save_points(&path, &[p.clone()]).unwrap();
        let back = load_points(&path).unwrap();
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].label, p.label);
        assert_eq!(back[0].mapping, p.mapping);
        assert!((back[0].accuracy - p.accuracy).abs() < 1e-9);
        assert_eq!(back[0].total_cycles, p.total_cycles);
    }
}
