//! Minimal JSON parser/emitter.
//!
//! serde is not in the vendored crate set, and the only JSON this
//! project touches is its own artifact metadata (written by
//! `python/compile/aot.py`) and its own result files — a full-featured
//! parser is unnecessary. This implements RFC 8259 minus `\u` surrogate
//! pairs (the metadata is pure ASCII) with precise error positions.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse failure with a byte-precise position. Display/Error are
/// hand-rolled: thiserror is not in the vendored crate set (see
/// Cargo.toml), and two impls are cheaper than a dependency.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- typed accessors (panic-free, Option-based) ------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that reports *which* key is missing.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key '{key}'"))
    }

    /// Required numeric field: errors (naming the key) when the field
    /// is missing *or* mistyped — loaders of long-lived caches must
    /// never let a malformed field silently decay to a default.
    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("json key '{key}' must be a number"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|v| v as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|v| v as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Array of numbers -> Vec<usize> (shape fields).
    pub fn usize_vec(&self) -> anyhow::Result<Vec<usize>> {
        self.as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected array"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("expected number")))
            .collect()
    }

    // ---- constructors -------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn str(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    pub fn arr_f64(vs: &[f64]) -> Json {
        Json::Arr(vs.iter().map(|v| Json::Num(*v)).collect())
    }

    pub fn arr_usize(vs: &[usize]) -> Json {
        Json::Arr(vs.iter().map(|v| Json::Num(*v as f64)).collect())
    }
}

// ---------------------------------------------------------------------------
// parsing
// ---------------------------------------------------------------------------

pub fn parse(src: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: src.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            // python json.dump can emit these for f64 inf/nan
            Some(b'N') => self.lit("NaN", Json::Num(f64::NAN)),
            Some(b'I') => self.lit("Infinity", Json::Num(f64::INFINITY)),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16)
                                    .ok_or_else(|| self.err("bad \\u digit"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // re-decode UTF-8 multibyte sequence
                    let start = self.pos - 1;
                    let len = if c >= 0xF0 { 4 } else if c >= 0xE0 { 3 } else { 2 };
                    if start + len > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|_| self.err("bad utf-8"))?;
                    s.push_str(chunk);
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
            // python emits -Infinity
            if self.peek() == Some(b'I') {
                self.lit("Infinity", Json::Null)?;
                return Ok(Json::Num(f64::NEG_INFINITY));
            }
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { pos: start, msg: format!("bad number '{txt}'") })
    }
}

// ---------------------------------------------------------------------------
// emission
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 && n.is_finite() {
                    write!(f, "{}", *n as i64)
                } else if n.is_finite() {
                    write!(f, "{n}")
                } else if n.is_nan() {
                    write!(f, "NaN")
                } else if *n > 0.0 {
                    write!(f, "Infinity")
                } else {
                    write!(f, "-Infinity")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[0].as_f64(), Some(1.0));
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[1].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"shape":[2,3],"name":"param:w","v":1.25,"flags":[true,null]}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn error_position() {
        let e = parse("[1, @]").unwrap_err();
        assert_eq!(e.pos, 4);
    }

    #[test]
    fn unicode_strings() {
        let v = parse("\"caf\\u00e9 — ☕\"").unwrap();
        assert_eq!(v.as_str(), Some("café — ☕"));
    }

    #[test]
    fn python_inf_nan() {
        assert!(parse("NaN").unwrap().as_f64().unwrap().is_nan());
        assert_eq!(parse("-Infinity").unwrap().as_f64(), Some(f64::NEG_INFINITY));
    }

    #[test]
    fn usize_vec_helper() {
        let v = parse("[2, 16, 3, 3]").unwrap();
        assert_eq!(v.usize_vec().unwrap(), vec![2, 16, 3, 3]);
    }

    #[test]
    fn req_f64_errors_on_missing_and_mistyped() {
        let v = parse(r#"{"cycles": 12.5, "label": "x"}"#).unwrap();
        assert_eq!(v.req_f64("cycles").unwrap(), 12.5);
        assert!(v.req_f64("nope").unwrap_err().to_string().contains("nope"));
        let e = v.req_f64("label").unwrap_err().to_string();
        assert!(e.contains("must be a number"), "{e}");
    }
}
