//! Deterministic PRNGs shared with the python mirror.
//!
//! `SplitMix64` matches `python/compile/datagen.py::splitmix64`
//! bit-for-bit (pinned by known-answer tests on both sides), so the
//! synthetic datasets the rust runtime generates are the same streams
//! the python unit tests see. `Pcg32` is the general-purpose generator
//! for everything that does not need the cross-language contract
//! (shuffles, property-test case generation).

/// SplitMix64 — the cross-language stream (python mirror in datagen.py).
#[derive(Clone, Copy, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// One step; returns the 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1) from the top 53 bits — identical to the python
    /// `_u01` helper.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Standard normal pair via Box-Muller on the same stream (python
    /// mirror: datagen.gen_sample noise loop).
    #[inline]
    pub fn next_gauss_pair(&mut self) -> (f64, f64) {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        (r * th.cos(), r * th.sin())
    }
}

/// PCG32 (O'Neill) — fast general-purpose stream, not cross-language.
#[derive(Clone, Copy, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Self { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Uniform in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u32() as u64;
            let m = x * n as u64;
            let l = m as u32;
            if l >= n || l >= (u32::MAX - n + 1) % n {
                return (m >> 32) as u32;
            }
        }
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_vectors() {
        // Same vectors pinned in python/tests/test_datagen.py.
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(r.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(r.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn u01_in_range() {
        let mut r = SplitMix64::new(42);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn pcg_deterministic() {
        let mut a = Pcg32::new(1, 2);
        let mut b = Pcg32::new(1, 2);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn pcg_below_unbiased_bounds() {
        let mut r = Pcg32::new(7, 1);
        for _ in 0..1000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn gauss_moments() {
        let mut r = SplitMix64::new(9);
        let mut sum = 0.0;
        let mut sq = 0.0;
        let n = 20_000;
        for _ in 0..n / 2 {
            let (a, b) = r.next_gauss_pair();
            sum += a + b;
            sq += a * a + b * b;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(3, 4);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
