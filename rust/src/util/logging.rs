//! Leveled stderr logger (backend for the `log` facade).
//!
//! `ODIMO_LOG=debug|info|warn|error` selects the level (default info).

use std::io::Write;
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};
use once_cell::sync::OnceCell;

static START: OnceCell<Instant> = OnceCell::new();

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, _: &Metadata) -> bool {
        true
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.get_or_init(Instant::now).elapsed();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        let mut err = std::io::stderr().lock();
        let _ = writeln!(
            err,
            "[{:>9.3}s {} {}] {}",
            t.as_secs_f64(),
            lvl,
            record.target().split("::").last().unwrap_or(""),
            record.args()
        );
    }

    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;

/// Install the logger; safe to call more than once.
pub fn init() {
    let level = match std::env::var("ODIMO_LOG").as_deref() {
        Ok("trace") => LevelFilter::Trace,
        Ok("debug") => LevelFilter::Debug,
        Ok("warn") => LevelFilter::Warn,
        Ok("error") => LevelFilter::Error,
        _ => LevelFilter::Info,
    };
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
    START.get_or_init(Instant::now);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_twice_is_fine() {
        super::init();
        super::init();
        log::debug!("logger smoke");
    }
}
