//! Fixed-size thread pool (std::thread + channels).
//!
//! tokio/rayon are not in the vendored crate set; the coordinator's
//! parallelism needs are simple and structured — fan a batch of
//! independent jobs out, wait for all of them (lambda sweeps, parallel
//! dataset generation, parallel simulator runs) — so a small
//! work-queue pool with a scoped `map` API covers everything.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Pool sized to the machine (physical parallelism), capped.
    pub fn with_default_size() -> Self {
        let n = std::thread::available_parallelism()
            .map(|v| v.get())
            .unwrap_or(4)
            .min(16);
        Self::new(n)
    }

    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx: Arc<Mutex<Receiver<Job>>> = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("odimo-pool-{i}"))
                    .spawn(move || loop {
                        let job = match rx.lock().unwrap().recv() {
                            Ok(j) => j,
                            Err(_) => break, // sender dropped: shut down
                        };
                        // a panicking job must not kill the worker; the
                        // panic is surfaced to the caller through the
                        // result channel it holds
                        let _ = catch_unwind(AssertUnwindSafe(job));
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self { tx: Some(tx), workers }
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Fire-and-forget.
    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("pool queue closed");
    }

    /// Apply `f` to every item, in parallel, preserving order.
    /// Panics in `f` are propagated to the caller (first one wins).
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        self.scoped_map(items, f)
    }

    /// [`Self::map`] without the `'static` bounds: items, results and
    /// the closure may borrow from the caller's stack (slices of a
    /// tensor, `&self` of an engine, disjoint `&mut` chunks of an
    /// output buffer), which is what the inference engine fans out.
    ///
    /// Must NOT be called from inside a pool job: the caller blocks on
    /// the same queue its sub-jobs wait in, which can deadlock once
    /// every worker is a blocked caller.
    pub fn scoped_map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Sync,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let fref = &f;
        let (rtx, rrx) = channel::<(usize, std::thread::Result<R>)>();
        for (i, item) in items.into_iter().enumerate() {
            let rtx = rtx.clone();
            let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                let r = catch_unwind(AssertUnwindSafe(|| fref(item)));
                let _ = rtx.send((i, r));
            });
            // SAFETY: the loop below blocks until every job has sent a
            // result (including caught panics), so all borrows captured
            // by `job` strictly outlive its execution on a worker; the
            // transmute only erases the lifetime, not the layout.
            let job: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Job>(job)
            };
            self.tx
                .as_ref()
                .expect("pool shut down")
                .send(job)
                .expect("pool queue closed");
        }
        drop(rtx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut panic: Option<Box<dyn std::any::Any + Send>> = None;
        for _ in 0..n {
            let (i, r) = rrx.recv().expect("worker dropped result");
            match r {
                Ok(v) => out[i] = Some(v),
                Err(e) => panic = panic.or(Some(e)),
            }
        }
        if let Some(e) = panic {
            std::panic::resume_unwind(e);
        }
        out.into_iter().map(|v| v.unwrap()).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the queue
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map((0..100).collect(), |i: i32| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn spawn_runs_everything() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn map_propagates_panic() {
        let pool = ThreadPool::new(2);
        let _ = pool.map(vec![1, 2, 3], |i| {
            if i == 2 {
                panic!("boom");
            }
            i
        });
    }

    #[test]
    fn scoped_map_borrows_stack_data() {
        let pool = ThreadPool::new(4);
        let data: Vec<i32> = (0..100).collect();
        let refs: Vec<&i32> = data.iter().collect();
        let out = pool.scoped_map(refs, |v: &i32| *v * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_map_disjoint_mut_chunks() {
        let pool = ThreadPool::new(3);
        let mut buf = vec![0u32; 90];
        let items: Vec<(usize, &mut [u32])> = buf.chunks_mut(30).enumerate().collect();
        pool.scoped_map(items, |(i, chunk)| {
            for (j, v) in chunk.iter_mut().enumerate() {
                *v = (i * 30 + j) as u32;
            }
        });
        assert_eq!(buf, (0..90).collect::<Vec<u32>>());
    }

    #[test]
    fn scoped_map_empty_is_noop() {
        let pool = ThreadPool::new(2);
        let out: Vec<i32> = pool.scoped_map(Vec::<i32>::new(), |v| v);
        assert!(out.is_empty());
    }

    #[test]
    fn pool_survives_job_panic() {
        let pool = ThreadPool::new(1);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.map(vec![1], |_| panic!("x"))
        }));
        assert!(r.is_err());
        // the single worker must still be alive
        assert_eq!(pool.map(vec![5], |i: i32| i + 1), vec![6]);
    }
}
