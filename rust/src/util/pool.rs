//! Fixed-size thread pool (std::thread + channels).
//!
//! tokio/rayon are not in the vendored crate set; the coordinator's
//! parallelism needs are simple and structured — fan a batch of
//! independent jobs out, wait for all of them (lambda sweeps, parallel
//! dataset generation, parallel simulator runs) — so a small
//! work-queue pool with a scoped `map` API covers everything.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Pool sized to the machine (physical parallelism), capped.
    pub fn with_default_size() -> Self {
        let n = std::thread::available_parallelism()
            .map(|v| v.get())
            .unwrap_or(4)
            .min(16);
        Self::new(n)
    }

    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx: Arc<Mutex<Receiver<Job>>> = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("odimo-pool-{i}"))
                    .spawn(move || loop {
                        let job = match rx.lock().unwrap().recv() {
                            Ok(j) => j,
                            Err(_) => break, // sender dropped: shut down
                        };
                        // a panicking job must not kill the worker; the
                        // panic is surfaced to the caller through the
                        // result channel it holds
                        let _ = catch_unwind(AssertUnwindSafe(job));
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self { tx: Some(tx), workers }
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Fire-and-forget.
    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("pool queue closed");
    }

    /// Apply `f` to every item, in parallel, preserving order.
    /// Panics in `f` are propagated to the caller (first one wins).
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (rtx, rrx) = channel::<(usize, std::thread::Result<R>)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.spawn(move || {
                let r = catch_unwind(AssertUnwindSafe(|| f(item)));
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut panic: Option<Box<dyn std::any::Any + Send>> = None;
        for _ in 0..n {
            let (i, r) = rrx.recv().expect("worker dropped result");
            match r {
                Ok(v) => out[i] = Some(v),
                Err(e) => panic = panic.or(Some(e)),
            }
        }
        if let Some(e) = panic {
            std::panic::resume_unwind(e);
        }
        out.into_iter().map(|v| v.unwrap()).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the queue
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map((0..100).collect(), |i: i32| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn spawn_runs_everything() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn map_propagates_panic() {
        let pool = ThreadPool::new(2);
        let _ = pool.map(vec![1, 2, 3], |i| {
            if i == 2 {
                panic!("boom");
            }
            i
        });
    }

    #[test]
    fn pool_survives_job_panic() {
        let pool = ThreadPool::new(1);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.map(vec![1], |_| panic!("x"))
        }));
        assert!(r.is_err());
        // the single worker must still be alive
        assert_eq!(pool.map(vec![5], |i: i32| i + 1), vec![6]);
    }
}
