//! Micro-benchmark harness (criterion substitute).
//!
//! `cargo bench` targets are `harness = false` binaries that call
//! [`Bench::run`]: warmup, then timed iterations until both a minimum
//! iteration count and a minimum wall-time are reached; reports
//! median / p10 / p90 / mean over per-iteration times. Results also
//! append to `results/bench_<name>.csv` so perf history survives runs
//! (EXPERIMENTS.md §Perf reads these).

use std::fmt::Write as _;
use std::io::Write as _;
use std::time::{Duration, Instant};

pub struct Bench {
    name: String,
    min_iters: usize,
    min_time: Duration,
    warmup: Duration,
    rows: Vec<(String, Stats)>,
}

#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            min_iters: 10,
            min_time: Duration::from_millis(300),
            warmup: Duration::from_millis(100),
            rows: Vec::new(),
        }
    }

    /// Quick profile for expensive end-to-end cases.
    pub fn slow(mut self) -> Self {
        self.min_iters = 3;
        self.min_time = Duration::from_millis(100);
        self.warmup = Duration::from_millis(0);
        self
    }

    /// Single-repetition smoke profile (CI: exercises the bench
    /// plumbing and emits the JSON, without timing fidelity).
    pub fn smoke(mut self) -> Self {
        self.min_iters = 1;
        self.min_time = Duration::from_millis(0);
        self.warmup = Duration::from_millis(0);
        self
    }

    pub fn run<F: FnMut()>(&mut self, case: &str, mut f: F) -> Stats {
        // warmup
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        let mut times = Vec::new();
        let t0 = Instant::now();
        while times.len() < self.min_iters || t0.elapsed() < self.min_time {
            let it = Instant::now();
            f();
            times.push(it.elapsed().as_nanos() as f64);
            if times.len() > 10_000 {
                break;
            }
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = times.len();
        let stats = Stats {
            iters: n,
            mean_ns: times.iter().sum::<f64>() / n as f64,
            median_ns: times[n / 2],
            p10_ns: times[n / 10],
            p90_ns: times[(n * 9) / 10],
        };
        println!(
            "{:<42} {:>12} median {:>12} mean {:>12} p90   ({} iters)",
            format!("{}/{}", self.name, case),
            fmt_ns(stats.median_ns),
            fmt_ns(stats.mean_ns),
            fmt_ns(stats.p90_ns),
            n
        );
        self.rows.push((case.to_string(), stats));
        stats
    }

    /// Write the accumulated rows to results/bench_<name>.csv (append).
    pub fn finish(&self) {
        let _ = std::fs::create_dir_all("results");
        let path = format!("results/bench_{}.csv", self.name);
        let new = !std::path::Path::new(&path).exists();
        if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(&path) {
            let mut buf = String::new();
            if new {
                buf.push_str("unix_time,case,iters,median_ns,mean_ns,p10_ns,p90_ns\n");
            }
            let now = std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_secs())
                .unwrap_or(0);
            for (case, s) in &self.rows {
                let _ = writeln!(
                    buf,
                    "{now},{case},{},{:.0},{:.0},{:.0},{:.0}",
                    s.iters, s.median_ns, s.mean_ns, s.p10_ns, s.p90_ns
                );
            }
            let _ = f.write_all(buf.as_bytes());
        }
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_ordered() {
        let mut b = Bench::new("selftest").slow();
        let s = b.run("noop", || {
            black_box(1 + 1);
        });
        assert!(s.p10_ns <= s.median_ns && s.median_ns <= s.p90_ns);
        assert!(s.iters >= 3);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
