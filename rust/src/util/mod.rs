//! Infrastructure substrates built in-tree (the vendored crate set has
//! no serde/clap/tokio/rayon/criterion — DESIGN.md §Substitutions).

pub mod bench;
pub mod json;
pub mod logging;
pub mod pool;
pub mod prng;
