//! `odimo` — CLI entrypoint (L3 leader).
//!
//! Subcommands map 1:1 to the paper's experiments plus utilities:
//!   fig4 | fig5 | table1 | fig6   regenerate a table/figure
//!   search                        one ODiMO run at a fixed lambda
//!   simulate                      cost a mapping on the DIANA simulator
//!   inspect                       print a model's geometry + cost table
//! Common flags: --model, --config, --smoke.

use anyhow::{anyhow, Result};

use odimo::cli::Args;
use odimo::config::RunConfig;
use odimo::coordinator::{baselines, Pipeline, Regularizer, Schedule};
use odimo::exp::{self, ExpContext};
use odimo::hw::latency::layer_lats;
use odimo::hw::soc::{simulate, SocConfig};
use odimo::model::ALL_MODELS;
use odimo::runtime::{ArtifactMeta, Runtime};
use odimo::util::logging;

const USAGE: &str = "\
odimo — precision-aware DNN mapping on multi-accelerator SoCs (ODiMO)

USAGE: odimo <command> [flags]

COMMANDS
  fig4      accuracy-vs-latency/energy Pareto sweep (paper Fig. 4)
  fig5      abstract-hardware sweeps (paper Fig. 5)
  table1    deployment table on the DIANA simulator (paper Table I)
  fig6      per-layer utilization breakdown (paper Fig. 6)
  search    single ODiMO run: --lambda <v> [--reg lat|en]
  simulate  cost a mapping: --baseline <name> | --mapping <file.json>
  inspect   print model geometry and per-layer cost bounds
  help      this text

FLAGS
  --model <tinycnn|resnet20|resnet18s|mbv1_025>   (default resnet20)
  --config <file.toml>      load a RunConfig
  --artifacts <dir>         artifacts directory (default artifacts)
  --results <dir>           results directory (default results)
  --smoke                   tiny schedules (CI / smoke testing)
  --lambdas <a,b,c>         override the sweep lambda list
  --baseline <name>         all_8bit|all_ternary|io8_backbone_ternary|min_cost_lat|min_cost_en
  --non-ideal-l1            enable L1 tiling penalties in the simulator
";

fn build_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_file(std::path::Path::new(path))?,
        None => RunConfig::default(),
    };
    if let Some(m) = args.get("model") {
        if !ALL_MODELS.contains(&m) {
            return Err(anyhow!("unknown model '{m}' (choose from {ALL_MODELS:?})"));
        }
        cfg.model = m.to_string();
    }
    if let Some(d) = args.get("artifacts") {
        cfg.artifacts_dir = d.into();
    }
    if let Some(d) = args.get("results") {
        cfg.results_dir = d.into();
    }
    if args.has("smoke") {
        cfg.schedule = Schedule::smoke();
        cfg.lambdas = vec![1.0, 8.0];
    }
    if let Some(ls) = args.get("lambdas") {
        cfg.lambdas = ls
            .split(',')
            .map(|s| s.trim().parse::<f32>().map_err(|_| anyhow!("bad lambda '{s}'")))
            .collect::<Result<Vec<f32>>>()?;
    }
    if args.has("non-ideal-l1") {
        cfg.non_ideal_l1 = true;
    }
    Ok(cfg)
}

const COMMON_FLAGS: [&str; 6] = ["model", "config", "artifacts", "results", "lambdas", "baseline"];
const SWITCHES: [&str; 2] = ["smoke", "non-ideal-l1"];

fn main() {
    logging::init();
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env(&SWITCHES)?;
    match args.subcommand.as_str() {
        "" | "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        "fig4" => {
            args.expect_only(&COMMON_FLAGS)?;
            exp::fig4(&ExpContext::new(build_config(&args)?)?)
        }
        "fig5" => {
            args.expect_only(&COMMON_FLAGS)?;
            exp::fig5(&ExpContext::new(build_config(&args)?)?)
        }
        "table1" => {
            args.expect_only(&COMMON_FLAGS)?;
            exp::table1(&ExpContext::new(build_config(&args)?)?)
        }
        "fig6" => {
            args.expect_only(&COMMON_FLAGS)?;
            exp::fig6(&ExpContext::new(build_config(&args)?)?)
        }
        "search" => {
            let mut flags = COMMON_FLAGS.to_vec();
            flags.extend(["lambda", "reg"]);
            args.expect_only(&flags)?;
            let cfg = build_config(&args)?;
            let lambda = args.get_f32("lambda")?.unwrap_or(0.5);
            let reg = match args.get_or("reg", "en") {
                "lat" => Regularizer::LatencyDiana,
                "en" => Regularizer::EnergyDiana,
                other => return Err(anyhow!("--reg must be lat|en, got '{other}'")),
            };
            let rt = Runtime::cpu()?;
            let meta = ArtifactMeta::load(&cfg.artifacts_dir, &cfg.model)?;
            let mut pipe = Pipeline::new(&rt, &meta, cfg.schedule);
            pipe.data_seed = cfg.data_seed;
            pipe.ckpt_dir = cfg.results_dir.clone();
            let folded = pipe.pretrained_folded()?;
            let p = pipe.search_point(&folded, reg, lambda)?;
            println!(
                "{}: acc {:.4} | {:.3} ms | {:.2} uJ | D/A util {:.1}%/{:.1}% | A.Ch {:.1}%",
                p.label,
                p.accuracy,
                p.latency_ms,
                p.energy_uj,
                100.0 * p.util[0],
                100.0 * p.util[1],
                100.0 * p.aimc_channel_frac
            );
            Ok(())
        }
        "simulate" => {
            let mut flags = COMMON_FLAGS.to_vec();
            flags.push("mapping");
            args.expect_only(&flags)?;
            let cfg = build_config(&args)?;
            let graph = odimo::model::build(&cfg.model)?;
            let mapping = if let Some(file) = args.get("mapping") {
                let text = std::fs::read_to_string(file)?;
                odimo::coordinator::Mapping::from_json(&odimo::util::json::parse(&text)?)?
            } else {
                let name = args.get_or("baseline", "all_8bit");
                baselines::by_name(&graph, name)
                    .ok_or_else(|| anyhow!("unknown baseline '{name}'"))?
            };
            mapping.validate(&graph)?;
            let rep = simulate(
                &graph,
                &mapping.channel_split(),
                SocConfig { non_ideal_l1: cfg.non_ideal_l1 },
            );
            println!(
                "{}: {:.3} ms | {:.2} uJ | {} cycles | D/A util {:.1}%/{:.1}% | A.Ch {:.1}%",
                cfg.model,
                rep.latency_ms,
                rep.energy_uj,
                rep.total_cycles,
                100.0 * rep.util[0],
                100.0 * rep.util[1],
                100.0 * rep.aimc_channel_frac
            );
            Ok(())
        }
        "inspect" => {
            args.expect_only(&COMMON_FLAGS)?;
            let cfg = build_config(&args)?;
            let graph = odimo::model::build(&cfg.model)?;
            println!(
                "{}: input {:?}, {} classes, {} nodes, {} mappable, {:.1} MMACs",
                graph.name,
                graph.input_shape,
                graph.classes,
                graph.nodes.len(),
                graph.mappable().len(),
                graph.total_macs() as f64 / 1e6
            );
            println!(
                "{:<12} {:>5} {:>5} {:>3} {:>7} {:>12} {:>12}",
                "layer", "cin", "cout", "k", "out", "lat_dig", "lat_aimc"
            );
            for n in graph.mappable() {
                let (ld, la) = layer_lats(n, n.cout as u64, n.cout as u64);
                println!(
                    "{:<12} {:>5} {:>5} {:>3} {:>3}x{:<3} {:>12} {:>12}",
                    n.name, n.cin, n.cout, n.k, n.out_hw.0, n.out_hw.1, ld, la
                );
            }
            Ok(())
        }
        other => Err(anyhow!("unknown command '{other}' — try `odimo help`")),
    }
}
