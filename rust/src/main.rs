//! `odimo` — CLI entrypoint (L3 leader).
//!
//! Subcommands map 1:1 to the paper's experiments plus utilities:
//!   fig4 | fig5 | table1 | fig6   regenerate a table/figure
//!   search                        one ODiMO run at a fixed lambda
//!   simulate                      cost a mapping on the SoC simulator
//!   inspect                       print a model's geometry + cost table
//!   platforms                     list built-in platforms + their units
//!   sweep | serve | serve-report  the online serving stack (serve/)
//! Common flags: --model, --config, --platform, --smoke, --threads,
//! --seed.

use anyhow::{anyhow, Result};

use odimo::cli::Args;
use odimo::config::RunConfig;
use odimo::coordinator::{baselines, Pipeline, Regularizer, Schedule};
use odimo::exp::{self, ExpContext};
use odimo::hw::soc::{simulate, SocConfig};
use odimo::hw::Platform;
use odimo::model::ALL_MODELS;
use odimo::runtime::{ArtifactMeta, Runtime};
use odimo::util::logging;

const USAGE: &str = "\
odimo — precision-aware DNN mapping on multi-accelerator SoCs (ODiMO)

USAGE: odimo <command> [flags]

COMMANDS
  fig4      accuracy-vs-latency/energy Pareto sweep (paper Fig. 4)
  fig5      abstract-hardware sweeps (paper Fig. 5)
  table1    deployment table on the SoC simulator (paper Table I)
  fig6      per-layer utilization breakdown (paper Fig. 6)
  search    single ODiMO run: --lambda <v> [--reg lat|en]
  simulate  cost a mapping: --baseline <name> | --mapping <file.json>
  inspect   print model geometry and per-layer cost bounds
  platforms list built-in platforms and their accelerators
  sweep     build (or load) the cached mapping Pareto frontier
  serve     closed-loop SLA-aware batched inference over the frontier
            [--requests n --max-batch n --max-wait cyc --gap cyc]
  serve-report  render the dashboard of the last serve run
  help      this text

FLAGS
  --model <tinycnn|resnet20|resnet18s|mbv1_025>   (default resnet20;
                            sweep/serve default to tinycnn)
  --config <file.toml>      load a RunConfig
  --platform <name|file>    deployment SoC: built-in name (diana,
                            diana_ne16, gap9, mpsoc4) or a platform
                            .toml path
  --artifacts <dir>         artifacts directory (default artifacts)
  --results <dir>           results directory (default results)
  --smoke                   tiny schedules (CI / smoke testing)
  --lambdas <a,b,c>         override the sweep lambda list
  --baseline <name>         all_8bit|all_ternary|io8_backbone_ternary|\
even_split|min_cost_lat|min_cost_en
  --non-ideal-l1            enable L1 tiling penalties in the simulator
  --threads <n>             worker threads for sweep/serve engine runs
                            (ThreadPool size; default: machine
                            parallelism, capped; sweep/serve only)
  --seed <u64>              global seed, default 1234: data_seed for the
                            pipeline verbs, request/calibration streams
                            for sweep/serve
";

fn build_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_file(std::path::Path::new(path))?,
        None => RunConfig::default(),
    };
    if let Some(m) = args.get("model") {
        if !ALL_MODELS.contains(&m) {
            return Err(anyhow!("unknown model '{m}' (choose from {ALL_MODELS:?})"));
        }
        cfg.model = m.to_string();
    }
    if let Some(p) = args.get("platform") {
        cfg.platform = Platform::resolve(p)?;
    }
    if let Some(d) = args.get("artifacts") {
        cfg.artifacts_dir = d.into();
    }
    if let Some(d) = args.get("results") {
        cfg.results_dir = d.into();
    }
    if args.has("smoke") {
        cfg.schedule = Schedule::smoke();
        cfg.lambdas = vec![1.0, 8.0];
    }
    if let Some(ls) = args.get("lambdas") {
        cfg.lambdas = ls
            .split(',')
            .map(|s| s.trim().parse::<f32>().map_err(|_| anyhow!("bad lambda '{s}'")))
            .collect::<Result<Vec<f32>>>()?;
    }
    if args.has("non-ideal-l1") {
        cfg.non_ideal_l1 = true;
    }
    if let Some(s) = args.get_u64("seed")? {
        cfg.data_seed = s;
    }
    Ok(cfg)
}

/// Model for the serving verbs: defaults to `tinycnn` (the closed loop
/// executes the real engine per batch; see `serve::ServeCfg`).
fn serve_model(args: &Args) -> Result<String> {
    let m = args.get_or("model", "tinycnn");
    if !ALL_MODELS.contains(&m) {
        return Err(anyhow!("unknown model '{m}' (choose from {ALL_MODELS:?})"));
    }
    Ok(m.to_string())
}

/// Platform for the serving verbs (default DIANA).
fn serve_platform(args: &Args) -> Result<Platform> {
    match args.get("platform") {
        Some(p) => Platform::resolve(p),
        None => Ok(Platform::diana()),
    }
}

/// "name 12.3%/4.5%/..." per-accelerator utilization string.
fn util_str(platform: &Platform, util: &[f64]) -> String {
    platform
        .accelerators
        .iter()
        .zip(util)
        .map(|(a, u)| format!("{} {:.1}%", a.name, 100.0 * u))
        .collect::<Vec<_>>()
        .join(" / ")
}

// --seed is honored by every verb (build_config plumbs it to
// data_seed); --threads only drives the serving verbs' thread pools,
// so it lives in SERVE_FLAGS alone — a verb that would silently ignore
// it must reject it.
const COMMON_FLAGS: [&str; 8] =
    ["model", "config", "platform", "artifacts", "results", "lambdas", "baseline", "seed"];
/// The serving verbs honor only these (no --config/--lambdas/...): a
/// flag they would silently ignore is an error, not a no-op.
const SERVE_FLAGS: [&str; 5] = ["model", "platform", "results", "threads", "seed"];
/// serve-report only reads a stored report; threads/seed do not apply.
const SERVE_REPORT_FLAGS: [&str; 3] = ["model", "platform", "results"];
const SWITCHES: [&str; 2] = ["smoke", "non-ideal-l1"];

/// Switch hygiene for the serving verbs: the sweep scorer always uses
/// the ideal-L1 simulator config, so `--non-ideal-l1` is an error (not
/// a silent no-op that would make frontier numbers disagree with
/// `simulate --non-ideal-l1`); `--smoke` is only meaningful where the
/// caller says so (the serve request stream).
fn reject_serve_switches(args: &Args, allow_smoke: bool) -> Result<()> {
    if args.has("non-ideal-l1") {
        return Err(anyhow!(
            "--non-ideal-l1 is not supported by {} (the frontier is scored \
             with the ideal-L1 simulator config)",
            args.subcommand
        ));
    }
    if !allow_smoke && args.has("smoke") {
        return Err(anyhow!("--smoke has no effect on {}", args.subcommand));
    }
    Ok(())
}

fn main() {
    logging::init();
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env(&SWITCHES)?;
    match args.subcommand.as_str() {
        "" | "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        "fig4" => {
            args.expect_only(&COMMON_FLAGS)?;
            exp::fig4(&ExpContext::new(build_config(&args)?)?)
        }
        "fig5" => {
            args.expect_only(&COMMON_FLAGS)?;
            exp::fig5(&ExpContext::new(build_config(&args)?)?)
        }
        "table1" => {
            args.expect_only(&COMMON_FLAGS)?;
            exp::table1(&ExpContext::new(build_config(&args)?)?)
        }
        "fig6" => {
            args.expect_only(&COMMON_FLAGS)?;
            exp::fig6(&ExpContext::new(build_config(&args)?)?)
        }
        "search" => {
            let mut flags = COMMON_FLAGS.to_vec();
            flags.extend(["lambda", "reg"]);
            args.expect_only(&flags)?;
            let cfg = build_config(&args)?;
            let lambda = args.get_f32("lambda")?.unwrap_or(0.5);
            let reg = match args.get_or("reg", "en") {
                "lat" => Regularizer::LatencyDiana,
                "en" => Regularizer::EnergyDiana,
                other => return Err(anyhow!("--reg must be lat|en, got '{other}'")),
            };
            let rt = Runtime::cpu()?;
            let meta = ArtifactMeta::load(&cfg.artifacts_dir, &cfg.model)?;
            let mut pipe = Pipeline::new(&rt, &meta, cfg.schedule);
            pipe.data_seed = cfg.data_seed;
            pipe.ckpt_dir = cfg.results_dir.clone();
            pipe.platform = cfg.platform.clone();
            let folded = pipe.pretrained_folded()?;
            let p = pipe.search_point(&folded, &reg, lambda)?;
            println!(
                "{}: acc {:.4} | {:.3} ms | {:.2} uJ | util {} | A.Ch {:.1}%",
                p.label,
                p.accuracy,
                p.latency_ms,
                p.energy_uj,
                util_str(&cfg.platform, &p.util),
                100.0 * p.aimc_channel_frac
            );
            Ok(())
        }
        "simulate" => {
            let mut flags = COMMON_FLAGS.to_vec();
            flags.push("mapping");
            args.expect_only(&flags)?;
            let cfg = build_config(&args)?;
            let platform = &cfg.platform;
            let graph = odimo::model::build(&cfg.model)?;
            let mapping = if let Some(file) = args.get("mapping") {
                let text = std::fs::read_to_string(file)?;
                odimo::coordinator::Mapping::from_json(&odimo::util::json::parse(&text)?)?
            } else {
                let name = args.get_or("baseline", "all_8bit");
                baselines::by_name(&graph, platform, name)
                    .ok_or_else(|| anyhow!("unknown baseline '{name}'"))?
            };
            mapping.validate(&graph, platform.n_acc())?;
            let rep = simulate(
                &graph,
                &mapping.channel_split(platform.n_acc()),
                platform,
                SocConfig { non_ideal_l1: cfg.non_ideal_l1 },
            );
            println!(
                "{} on {}: {:.3} ms | {:.2} uJ | {} cycles | util {} | ch {}",
                cfg.model,
                platform.name,
                rep.latency_ms,
                rep.energy_uj,
                rep.total_cycles,
                util_str(platform, &rep.util),
                rep.channel_frac
                    .iter()
                    .zip(&platform.accelerators)
                    .map(|(f, a)| format!("{} {:.1}%", a.name, 100.0 * f))
                    .collect::<Vec<_>>()
                    .join(" / "),
            );
            Ok(())
        }
        "inspect" => {
            args.expect_only(&COMMON_FLAGS)?;
            let cfg = build_config(&args)?;
            let platform = &cfg.platform;
            let graph = odimo::model::build(&cfg.model)?;
            println!(
                "{}: input {:?}, {} classes, {} nodes, {} mappable, {:.1} MMACs (platform {})",
                graph.name,
                graph.input_shape,
                graph.classes,
                graph.nodes.len(),
                graph.mappable().len(),
                graph.total_macs() as f64 / 1e6,
                platform.name,
            );
            print!("{:<12} {:>5} {:>5} {:>3} {:>7}", "layer", "cin", "cout", "k", "out");
            for a in &platform.accelerators {
                print!(" {:>12}", format!("lat_{}", a.name));
            }
            println!();
            for n in graph.mappable() {
                print!(
                    "{:<12} {:>5} {:>5} {:>3} {:>3}x{:<3}",
                    n.name, n.cin, n.cout, n.k, n.out_hw.0, n.out_hw.1
                );
                for acc in 0..platform.n_acc() {
                    print!(" {:>12}", platform.layer_cycles(acc, n, n.cout as u64));
                }
                println!();
            }
            Ok(())
        }
        "sweep" => {
            args.expect_only(&SERVE_FLAGS)?;
            reject_serve_switches(&args, false)?;
            let platform = serve_platform(&args)?;
            let model = serve_model(&args)?;
            let results = std::path::PathBuf::from(args.get_or("results", "results"));
            let seed = args.get_u64("seed")?.unwrap_or(1234);
            odimo::serve::sweep_cmd(&model, &platform, &results, seed,
                                    args.get_usize("threads")?)
        }
        "serve" => {
            let mut flags = SERVE_FLAGS.to_vec();
            flags.extend(["requests", "max-batch", "max-wait", "gap"]);
            args.expect_only(&flags)?;
            reject_serve_switches(&args, true)?;
            let mut cfg = odimo::serve::ServeCfg {
                model: serve_model(&args)?,
                platform: serve_platform(&args)?,
                results_dir: args.get_or("results", "results").into(),
                threads: args.get_usize("threads")?,
                seed: args.get_u64("seed")?.unwrap_or(1234),
                ..Default::default()
            };
            if args.has("smoke") {
                cfg.n_requests = 24;
            }
            if let Some(n) = args.get_usize("requests")? {
                cfg.n_requests = n;
            }
            if let Some(n) = args.get_usize("max-batch")? {
                cfg.max_batch = n;
            }
            if let Some(n) = args.get_u64("max-wait")? {
                cfg.max_wait = n;
            }
            if let Some(n) = args.get_u64("gap")? {
                cfg.mean_gap = n;
            }
            let report = odimo::serve::run_serve(&cfg)?;
            println!("{}", report.dashboard());
            Ok(())
        }
        "serve-report" => {
            args.expect_only(&SERVE_REPORT_FLAGS)?;
            reject_serve_switches(&args, false)?;
            let platform = serve_platform(&args)?;
            let model = serve_model(&args)?;
            let results = std::path::PathBuf::from(args.get_or("results", "results"));
            odimo::serve::report_cmd(&model, &platform.name, &results)
        }
        "platforms" => {
            args.expect_only(&[])?;
            for name in Platform::BUILTIN_NAMES {
                let p = Platform::by_name(name).unwrap();
                println!(
                    "{name}: {} accelerators @ {:.0} MHz, L1 {} kB",
                    p.n_acc(),
                    p.f_clk_hz / 1e6,
                    p.l1_bytes / 1024
                );
                for (i, a) in p.accelerators.iter().enumerate() {
                    let da = match a.da_bits {
                        Some(b) => format!("  D/A {b}b"),
                        None => String::new(),
                    };
                    println!(
                        "  [{i}] {:<7} w{}b/a{}b  {:?}  P_act {} mW  P_idle {} mW{}{}",
                        a.name,
                        a.weight_bits,
                        a.act_bits,
                        a.latency,
                        a.p_act_mw,
                        a.p_idle_mw,
                        da,
                        if i == p.dw_acc { "  (runs depthwise)" } else { "" },
                    );
                }
            }
            println!("\ncustom platforms: --platform <file.toml> (see config/*.toml)");
            Ok(())
        }
        other => Err(anyhow!("unknown command '{other}' — try `odimo help`")),
    }
}
