//! `odimo` — CLI entrypoint (L3 leader).
//!
//! Subcommands map 1:1 to the paper's experiments plus utilities; the
//! verb/flag table (and the generated `help` text) lives in
//! `odimo::cli` so accepted flags and documentation cannot drift. The
//! deploy-flow verbs (`simulate`, `inspect`, `sweep`, `serve`,
//! `serve-report`) all route through one `odimo::api::Session`; only
//! the training-pipeline verbs (`fig*`, `search`) still drive the AOT
//! runtime directly.

use anyhow::{anyhow, Result};

use odimo::api::{ClusterOpts, FaultPlan, MappingSpec, ServeOpts, Session, SessionBuilder, Trace};
use odimo::cli::{self, Args};
use odimo::config::RunConfig;
use odimo::coordinator::{Pipeline, Regularizer, Schedule};
use odimo::exp::{self, ExpContext};
use odimo::hw::Platform;
use odimo::model::ALL_MODELS;
use odimo::obs::{export, ObsLevel};
use odimo::runtime::{ArtifactMeta, Runtime};
use odimo::serve::multi;
use odimo::util::logging;

fn build_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_file(std::path::Path::new(path))?,
        None => RunConfig::default(),
    };
    if let Some(m) = args.get("model") {
        if !ALL_MODELS.contains(&m) {
            return Err(anyhow!("unknown model '{m}' (choose from {ALL_MODELS:?})"));
        }
        cfg.model = m.to_string();
    }
    if let Some(p) = args.get("platform") {
        cfg.platform = Platform::resolve(p)?;
    }
    if let Some(d) = args.get("artifacts") {
        cfg.artifacts_dir = d.into();
    }
    if let Some(d) = args.get("results") {
        cfg.results_dir = d.into();
    }
    if args.has("smoke") {
        cfg.schedule = Schedule::smoke();
        cfg.lambdas = vec![1.0, 8.0];
    }
    if let Some(ls) = args.get("lambdas") {
        cfg.lambdas = ls
            .split(',')
            .map(|s| s.trim().parse::<f32>().map_err(|_| anyhow!("bad lambda '{s}'")))
            .collect::<Result<Vec<f32>>>()?;
    }
    if args.has("non-ideal-l1") {
        cfg.non_ideal_l1 = true;
    }
    if let Some(s) = args.get_u64("seed")? {
        cfg.data_seed = s;
    }
    Ok(cfg)
}

/// Build the session every deploy-flow verb runs on, from the same
/// flags: `--config` seeds the builder, explicit flags override it.
/// `default_model` differs per verb (the serving verbs default to
/// `tinycnn` — the closed loop executes the real engine per batch).
fn build_session(args: &Args, default_model: &str) -> Result<Session> {
    let mut b = match args.get("config") {
        Some(path) => {
            SessionBuilder::from_run_config(&RunConfig::from_file(std::path::Path::new(path))?)
        }
        None => SessionBuilder::new(default_model),
    };
    if let Some(m) = args.get("model") {
        b = b.model(m);
    }
    if let Some(p) = args.get("platform") {
        b = b.platform(p);
    }
    if let Some(d) = args.get("artifacts") {
        b = b.artifacts_dir(d);
    }
    if let Some(d) = args.get("results") {
        b = b.results_dir(d);
    }
    if let Some(n) = args.get_usize("threads")? {
        b = b.threads(n);
    }
    if let Some(s) = args.get_u64("seed")? {
        b = b.seed(s);
    }
    if let Some(kb) = args.get("kernels") {
        b = b.kernels(kb.parse()?);
    }
    if args.has("smoke") {
        b = b.smoke(true);
    }
    if args.has("non-ideal-l1") {
        b = b.non_ideal_l1(true);
    }
    // --trace-events with no explicit --obs-level turns recording on at
    // the exporter's default level, so the flag works on its own.
    let level = match args.get("obs-level") {
        Some(s) => Some(
            ObsLevel::parse(s)
                .ok_or_else(|| anyhow!("--obs-level must be off|basic|full, got '{s}'"))?,
        ),
        None if args.get("trace-events").is_some() => Some(export::default_trace_level()),
        None => None,
    };
    if let Some(level) = level {
        b = b.observer(level);
    }
    b.build()
}

/// "name 12.3% / ..." per-accelerator percentage string.
fn pct_str(platform: &Platform, vals: &[f64]) -> String {
    platform
        .accelerators
        .iter()
        .zip(vals)
        .map(|(a, v)| format!("{} {:.1}%", a.name, 100.0 * v))
        .collect::<Vec<_>>()
        .join(" / ")
}

fn main() {
    logging::init();
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let switches = cli::switch_names();
    let args = Args::from_env(&switches)?;
    let name = args.subcommand.as_str();
    if matches!(name, "" | "help" | "--help" | "-h") {
        println!("{}", cli::usage());
        return Ok(());
    }
    let verb = cli::verb(name).ok_or_else(|| anyhow!("unknown command '{name}' — try `odimo help`"))?;
    args.expect_verb(verb)?;
    match name {
        "fig4" => exp::fig4(&ExpContext::new(build_config(&args)?)?),
        "fig5" => exp::fig5(&ExpContext::new(build_config(&args)?)?),
        "table1" => exp::table1(&ExpContext::new(build_config(&args)?)?),
        "fig6" => exp::fig6(&ExpContext::new(build_config(&args)?)?),
        "search" => {
            let cfg = build_config(&args)?;
            let lambda = args.get_f32("lambda")?.unwrap_or(0.5);
            let reg = match args.get_or("reg", "en") {
                "lat" => Regularizer::LatencyDiana,
                "en" => Regularizer::EnergyDiana,
                other => return Err(anyhow!("--reg must be lat|en, got '{other}'")),
            };
            let rt = Runtime::cpu()?;
            let meta = ArtifactMeta::load(&cfg.artifacts_dir, &cfg.model)?;
            let mut pipe = Pipeline::new(&rt, &meta, cfg.schedule);
            pipe.data_seed = cfg.data_seed;
            pipe.ckpt_dir = cfg.results_dir.clone();
            pipe.platform = cfg.platform.clone();
            let folded = pipe.pretrained_folded()?;
            let p = pipe.search_point(&folded, &reg, lambda)?;
            println!(
                "{}: acc {:.4} | {:.3} ms | {:.2} uJ | util {} | A.Ch {:.1}%",
                p.label,
                p.accuracy,
                p.latency_ms,
                p.energy_uj,
                pct_str(&cfg.platform, &p.util),
                100.0 * p.aimc_channel_frac
            );
            Ok(())
        }
        "simulate" => {
            let session = build_session(&args, "resnet20")?;
            let spec = match args.get("mapping") {
                Some(file) => MappingSpec::File(file.into()),
                None => MappingSpec::Baseline(args.get_or("baseline", "all_8bit").to_string()),
            };
            let mapping = session.mapping(&spec)?;
            let rep = session.simulate(&mapping)?;
            let platform = session.platform();
            println!(
                "{} on {}: {:.3} ms | {:.2} uJ | {} cycles | util {} | ch {}",
                session.graph().name,
                platform.name,
                rep.latency_ms,
                rep.energy_uj,
                rep.total_cycles,
                pct_str(platform, &rep.util),
                pct_str(platform, &rep.channel_frac),
            );
            Ok(())
        }
        "inspect" => {
            let session = build_session(&args, "resnet20")?;
            let graph = session.graph();
            let platform = session.platform();
            println!(
                "{}: input {:?}, {} classes, {} nodes, {} mappable, {:.1} MMACs (platform {})",
                graph.name,
                graph.input_shape,
                graph.classes,
                graph.nodes.len(),
                graph.mappable().len(),
                graph.total_macs() as f64 / 1e6,
                platform.name,
            );
            print!("{:<12} {:>5} {:>5} {:>3} {:>7}", "layer", "cin", "cout", "k", "out");
            for a in &platform.accelerators {
                print!(" {:>12}", format!("lat_{}", a.name));
            }
            println!();
            for n in graph.mappable() {
                print!(
                    "{:<12} {:>5} {:>5} {:>3} {:>3}x{:<3}",
                    n.name, n.cin, n.cout, n.k, n.out_hw.0, n.out_hw.1
                );
                for acc in 0..platform.n_acc() {
                    print!(" {:>12}", platform.layer_cycles(acc, n, n.cout as u64));
                }
                println!();
            }
            Ok(())
        }
        "sweep" => {
            let mut session = build_session(&args, "tinycnn")?;
            let (n_points, cache_hit) = {
                let sw = session.sweep()?;
                (sw.points.len(), sw.cache_hit)
            };
            println!(
                "frontier for {} on {}: {} points ({} at {})",
                session.graph().name,
                session.platform().name,
                n_points,
                if cache_hit { "cache hit" } else { "computed and cached" },
                session.frontier_path().display()
            );
            println!(
                "{:<24} {:>12} {:>10} {:>10} {:>7}",
                "mapping", "cycles", "lat [ms]", "E [uJ]", "acc~"
            );
            for p in &session.sweep()?.points {
                println!(
                    "{:<24} {:>12} {:>10.4} {:>10.2} {:>7.3}",
                    p.label, p.cycles, p.latency_ms, p.energy_uj, p.acc_proxy
                );
            }
            Ok(())
        }
        "serve" => {
            let mut session = build_session(&args, "tinycnn")?;
            let mut opts = ServeOpts::default();
            if let Some(n) = args.get_usize("requests")? {
                opts.n_requests = Some(n);
            }
            if let Some(n) = args.get_usize("max-batch")? {
                opts.max_batch = n;
            }
            if let Some(n) = args.get_u64("max-wait")? {
                opts.max_wait = n;
            }
            if let Some(n) = args.get_u64("gap")? {
                opts.mean_gap = n;
            }
            if let Some(file) = args.get("faults") {
                let plan = FaultPlan::from_file(std::path::Path::new(file))?;
                println!("serve: fault plan {} ({} events)", file, plan.events.len());
                opts.fault_plan = Some(plan);
            }
            if let Some(n) = args.get_u64("overload-wait")? {
                opts.admission.overload_wait = n;
            }
            if let Some(n) = args.get_usize("max-retries")? {
                opts.max_retries = n as u32;
            }
            // --models enables the multi-model cluster plane: the
            // serving set is exactly these specs (built-in names or
            // imported graph .json paths), not the session's own model
            let model_specs: Option<Vec<String>> = args.get("models").map(|s| {
                s.split(',')
                    .map(|m| m.trim().to_string())
                    .filter(|m| !m.is_empty())
                    .collect()
            });
            if model_specs.is_none() {
                let (n_points, cache_hit) = {
                    let sw = session.sweep()?;
                    (sw.points.len(), sw.cache_hit)
                };
                println!(
                    "serve: frontier {} ({n_points} points, {})",
                    session.frontier_path().display(),
                    if cache_hit { "cache hit" } else { "swept fresh" }
                );
            }
            let cluster_mode = model_specs.is_some()
                || args.get("replicas").is_some()
                || args.get("trace").is_some()
                || args.get("record-trace").is_some()
                || args.get("steal-max").is_some()
                || args.get("compile-cycles").is_some()
                || args.has("flush");
            if cluster_mode {
                let mut copts = ClusterOpts { serve: opts, ..ClusterOpts::default() };
                if let Some(n) = args.get_usize("replicas")? {
                    copts.replicas = n.max(1);
                }
                if let Some(n) = args.get_usize("steal-max")? {
                    copts.steal_max = n;
                }
                if let Some(n) = args.get_u64("compile-cycles")? {
                    copts.compile_cycles = n;
                }
                if args.has("flush") {
                    copts.continuous = false;
                }
                let trace = match args.get("trace") {
                    Some(file) => {
                        let t = match &model_specs {
                            // validate records against the serving set,
                            // not the built-in model list
                            Some(specs) => {
                                let names = specs
                                    .iter()
                                    .map(|s| multi::resolve_graph(s).map(|g| g.name))
                                    .collect::<Result<Vec<String>>>()?;
                                let refs: Vec<&str> =
                                    names.iter().map(String::as_str).collect();
                                Trace::load_known(std::path::Path::new(file), &refs)?
                            }
                            None => Trace::load(std::path::Path::new(file))?,
                        };
                        println!("serve: replaying trace {} ({} requests)", file, t.len());
                        Some(t)
                    }
                    None => None,
                };
                let trace = match trace {
                    Some(t) => t,
                    None => match &model_specs {
                        Some(specs) => session.synth_trace_multi(specs, &copts.serve)?,
                        None => session.synth_trace(&copts.serve)?,
                    },
                };
                if let Some(out) = args.get("record-trace") {
                    let path = std::path::Path::new(out);
                    trace.save(path)?;
                    println!("serve: trace recorded to {out}");
                }
                let report = match &model_specs {
                    Some(specs) => session.serve_multi(specs, &copts, Some(&trace))?,
                    None => session.serve_cluster(&copts, Some(&trace))?,
                };
                println!("{}", report.dashboard());
            } else {
                let report = session.serve(&opts)?;
                println!("serve: report written to {}", session.report_path().display());
                println!("{}", report.dashboard());
            }
            if let Some(out) = args.get("trace-events") {
                session.export_trace(std::path::Path::new(out))?;
                println!("serve: trace events written to {out}");
            }
            Ok(())
        }
        "serve-report" => {
            let session = build_session(&args, "tinycnn")?;
            println!("{}", session.serve_report()?.dashboard());
            Ok(())
        }
        "trace-view" => {
            let file = args
                .get("trace-events")
                .ok_or_else(|| anyhow!("trace-view needs --trace-events <file.json>"))?;
            let top = args.get_usize("top")?.unwrap_or(10);
            let text = std::fs::read_to_string(file)
                .map_err(|e| anyhow!("cannot read trace file '{file}': {e}"))?;
            println!("{}", export::summarize(&text, top)?);
            Ok(())
        }
        "platforms" => {
            for name in Platform::BUILTIN_NAMES {
                let p = Platform::by_name(name).unwrap();
                println!(
                    "{name}: {} accelerators @ {:.0} MHz, L1 {} kB",
                    p.n_acc(),
                    p.f_clk_hz / 1e6,
                    p.l1_bytes / 1024
                );
                for (i, a) in p.accelerators.iter().enumerate() {
                    let da = match a.da_bits {
                        Some(b) => format!("  D/A {b}b"),
                        None => String::new(),
                    };
                    println!(
                        "  [{i}] {:<7} w{}b/a{}b  {:?}  P_act {} mW  P_idle {} mW{}{}",
                        a.name,
                        a.weight_bits,
                        a.act_bits,
                        a.latency,
                        a.p_act_mw,
                        a.p_idle_mw,
                        da,
                        if i == p.dw_acc { "  (runs depthwise)" } else { "" },
                    );
                }
            }
            println!("\ncustom platforms: --platform <file.toml> (see config/*.toml)");
            Ok(())
        }
        other => Err(anyhow!("unknown command '{other}' — try `odimo help`")),
    }
}
